//! §Perf — prefix-affinity routing across engine replicas.
//!
//! Runtime-free **ring section** first: consistent-hash lookup rate and
//! the remap fraction when one replica leaves a 4-ring (the
//! consistent-hashing property: ~K/N of K keys move, not all of them).
//!
//! With artifacts, the **routing comparison**: a Zipfian shared-image QA
//! mix (a few popular images dominate, a long tail of rare ones) driven
//! at `--replicas 2` under the affinity router vs the round-robin
//! control arm, plus a single-replica reference. Affinity sends every
//! request naming one image to the replica whose prefix cache holds it,
//! so the 2-replica hit rate should stay near the single-replica one;
//! round-robin splits each image across both pools and pays the cold
//! prefill once per (image, replica) pair.
//!
//! Acceptance (CI-gated here, trended by `make bench-trend`):
//!   * affinity hit rate >= 0.9 x the single-replica hit rate
//!   * affinity hit rate strictly above round-robin's
//!
//! Emits `BENCH_perf_router.json` with `prefix_hit_rate_affinity`,
//! `prefix_hit_rate_round_robin`, `prefix_hit_rate_single` and
//! `shed_total` (no shedding is configured, so a non-zero count here
//! means the router shed traffic it was never asked to).

use std::sync::mpsc;
use std::time::Instant;

use hae_serve::harness::*;
use hae_serve::obs::BenchReport;
use hae_serve::router::{HashRing, RouterPolicy, DEFAULT_VNODES};
use hae_serve::server::client_request;
use hae_serve::util::json::Json;

/// xorshift64* — deterministic request-stream randomness (no rand crate).
struct XorShift(u64);

impl XorShift {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545_f491_4f6c_dd1d)
    }

    fn next_f64(&mut self) -> f64 {
        (self.next() >> 11) as f64 / (1u64 << 53) as f64
    }
}

/// Zipf(s) sampler over ranks `0..n` via the precomputed CDF — rank 0 is
/// the most popular image.
struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    fn new(n: usize, s: f64) -> Zipf {
        let weights: Vec<f64> = (1..=n).map(|r| 1.0 / (r as f64).powf(s)).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        let cdf = weights
            .iter()
            .map(|w| {
                acc += w / total;
                acc
            })
            .collect();
        Zipf { cdf }
    }

    fn sample(&self, rng: &mut XorShift) -> usize {
        let u = rng.next_f64();
        self.cdf.iter().position(|&c| u <= c).unwrap_or(self.cdf.len() - 1)
    }
}

/// Ring microbench: lookup rate over a 4-replica ring and the fraction of
/// keys that remap when one replica leaves. Runtime-free.
fn ring_section(report: &mut BenchReport) {
    let ring = HashRing::new(4, DEFAULT_VNODES);
    let keys: Vec<u64> = (0..200_000u64).map(|i| i.wrapping_mul(0x9e37_79b9_7f4a_7c15)).collect();
    let t0 = Instant::now();
    let mut acc = 0u64;
    for &k in &keys {
        acc = acc.wrapping_add(ring.primary(k).unwrap_or(0) as u64);
    }
    let lookup_mops = keys.len() as f64 / t0.elapsed().as_secs_f64() / 1e6;
    // keep `acc` observable so the loop cannot be optimised away
    assert!(acc > 0, "degenerate ring ownership");

    let mut less = ring.clone();
    less.remove(2);
    let moved = keys
        .iter()
        .filter(|&&k| ring.primary(k) != less.primary(k))
        .count();
    let remap_frac = moved as f64 / keys.len() as f64;

    println!(
        "## consistent-hash ring (4 replicas x {} vnodes)\n\
         lookup: {:.1} Mops/s over {} keys\n\
         removing 1 of 4 replicas remaps {:.1}% of keys (ideal 25%)",
        DEFAULT_VNODES,
        lookup_mops,
        keys.len(),
        remap_frac * 100.0
    );
    report.metric("ring_lookup_mops", lookup_mops, "Mops/s");
    report.metric("ring_remap_frac", remap_frac, "frac");
    assert!(
        remap_frac < 0.5,
        "removing 1 of 4 replicas remapped {:.0}% of keys — the ring lost \
         the consistent-hashing property",
        remap_frac * 100.0
    );
}

/// Drive the Zipfian shared-image QA mix: `clients` connections, each
/// sending `per_client` requests whose image is drawn Zipf(s) from
/// `images` ranks. Deterministic per (client, i). Returns the number of
/// failed requests.
fn drive_zipf(addr: &str, clients: usize, per_client: usize, images: usize) -> usize {
    let (tx, rx) = mpsc::channel();
    for c in 0..clients {
        let tx = tx.clone();
        let addr = addr.to_string();
        std::thread::spawn(move || {
            let zipf = Zipf::new(images, 1.1);
            let mut rng = XorShift(0xC0FFEE ^ ((c as u64 + 1) << 17));
            for i in 0..per_client {
                let image = zipf.sample(&mut rng);
                let q = if (c + i) % 2 == 0 { "color" } else { "shape" };
                let line = format!(
                    r#"{{"id": {}, "kind": "qa", "image_seed": {}, "q": "{}"}}"#,
                    c * 1000 + i,
                    image + 1,
                    q
                );
                let resp = client_request(&addr, &line).unwrap_or_default();
                let ok = Json::parse(&resp)
                    .map(|j| j.get("error").is_none())
                    .unwrap_or(false);
                tx.send(ok).unwrap();
            }
        });
    }
    drop(tx);
    rx.iter().filter(|ok| !ok).count()
}

/// One routing arm: spawn the tier, drive the mix, read the (merged)
/// stats snapshot back. Returns (prefix_hit_rate, shed_total).
fn run_arm(
    replicas: usize,
    router_policy: RouterPolicy,
    widest: usize,
    clients: usize,
    per_client: usize,
    images: usize,
) -> (f64, f64) {
    let (handle, addr) = spawn_server_replicas(ServerRig {
        batch: widest,
        replicas,
        router_policy,
        ..ServerRig::default()
    });
    assert!(wait_listening(&addr), "server on {}", addr);
    let errors = drive_zipf(&addr, clients, per_client, images);
    let stats = client_request(&addr, r#"{"kind": "stats"}"#)
        .ok()
        .and_then(|r| Json::parse(&r).ok());
    let _ = client_request(&addr, "shutdown");
    let _ = handle.join();
    assert_eq!(errors, 0, "routing arm saw failed requests");
    let stats = stats.expect("stats snapshot");
    let hit_rate = stats
        .get("prefix_hit_rate")
        .and_then(|v| v.as_f64())
        .expect("stats carry prefix_hit_rate");
    let shed = stats
        .path(&["router", "shed_total"])
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0);
    (hit_rate, shed)
}

fn main() -> anyhow::Result<()> {
    let mut report = BenchReport::new("perf_router");
    ring_section(&mut report);

    if load_runtime().is_err() {
        eprintln!(
            "artifacts not built (run `make artifacts`) — skipping the\n\
             routing comparison"
        );
        let path = report.write().expect("write BENCH_perf_router.json");
        println!("\nbench report: {}", path.display());
        return Ok(());
    }

    let widest = widest_batch();
    let clients = 4usize;
    let per_client = bench_n(6) * 2;
    let images = 12usize;
    report.engine_threads(2);
    report.config("clients", clients);
    report.config("per_client", per_client);
    report.config("images", images);
    report.config("zipf_s", "1.1");

    let (single, _) = run_arm(1, RouterPolicy::Affinity, widest, clients, per_client, images);
    let (affinity, shed) =
        run_arm(2, RouterPolicy::Affinity, widest, clients, per_client, images);
    let (round_robin, _) =
        run_arm(2, RouterPolicy::RoundRobin, widest, clients, per_client, images);

    let mut table = Table::new(
        &format!(
            "Zipfian shared-image routing: {} clients x {} requests, {} images",
            clients, per_client, images
        ),
        &["arm", "replicas", "prefix hit rate"],
    );
    table.row(vec!["single".into(), "1".into(), pct(single)]);
    table.row(vec!["affinity".into(), "2".into(), pct(affinity)]);
    table.row(vec!["round_robin".into(), "2".into(), pct(round_robin)]);
    table.print();
    println!(
        "\n(affinity pins each image to one replica's prefix cache, so the\n\
         2-replica hit rate stays near the 1-replica reference; round-robin\n\
         pays the cold prefill once per (image, replica) pair)"
    );

    report.config("routing_sections", "true");
    report.metric("prefix_hit_rate_single", single, "frac");
    report.metric("prefix_hit_rate_affinity", affinity, "frac");
    report.metric("prefix_hit_rate_round_robin", round_robin, "frac");
    report.metric("shed_total", shed, "count");

    assert!(
        affinity >= single * 0.9,
        "2-replica affinity hit rate {:.3} fell below 0.9x the single-replica \
         reference {:.3} — the ring is splitting images across replicas",
        affinity,
        single
    );
    assert!(
        affinity > round_robin,
        "affinity hit rate {:.3} is not above round-robin's {:.3} — the \
         router's placement is not buying prefix locality",
        affinity,
        round_robin
    );
    assert_eq!(shed, 0.0, "router shed traffic with no shed bound configured");

    let path = report.write().expect("write BENCH_perf_router.json");
    println!("\nbench report: {}", path.display());
    Ok(())
}
