//! Paper Fig. 2 — variance of cumulative attention scores: visual vs text.
//!
//! Runs the analysis artifact over N mixed samples and pools the layer-0
//! cumulative column scores by modality. Expected shape: the two
//! distributions differ significantly (the observation motivating
//! stage-specific eviction).

use hae_serve::attention::cumulative_variance_split;
use hae_serve::harness::*;
use hae_serve::model::vocab;
use hae_serve::workload::{RequestBuilder, WorkloadKind};

fn main() -> anyhow::Result<()> {
    let n = bench_n(50);
    let rt = load_runtime()?;
    let meta = rt.meta().clone();
    let grammar = load_grammar(&artifact_dir());
    let mut builder = RequestBuilder::new(&meta, &grammar, 505);

    let bucket = *rt.manifest.shapes.analysis_buckets.first().unwrap();
    let mut per_layer: Vec<Vec<(Vec<f32>, Vec<bool>, usize)>> =
        vec![Vec::new(); meta.n_layers];

    for i in 0..n {
        let kind = if i % 2 == 0 { WorkloadKind::Understanding } else { WorkloadKind::Mixed };
        let req = builder.make(kind);
        if req.prompt_len() > bucket {
            continue;
        }
        let mut ids = req.ids.clone();
        ids.resize(bucket, vocab::PAD);
        let mut patches = req.patches.clone();
        patches.resize(bucket * meta.patch_dim, 0.0);
        let mut isv: Vec<f32> =
            req.is_vision.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect();
        isv.resize(bucket, 0.0);
        let (out, _) = rt.analysis(bucket, &ids, &patches, &isv, req.prompt_len())?;
        let mut is_vision = req.is_vision.clone();
        is_vision.resize(bucket, false);
        for l in 0..meta.n_layers {
            per_layer[l].push((
                out.layer_colsum(l).to_vec(),
                is_vision.clone(),
                req.prompt_len(),
            ));
        }
    }

    let mut table = Table::new(
        &format!("Fig. 2 — cumulative-score variance by modality ({} samples)", n),
        &["Layer", "Var(visual)", "Var(text)", "ratio", "Mean(visual)", "Mean(text)"],
    );
    for (l, samples) in per_layer.iter().enumerate() {
        let v = cumulative_variance_split(samples);
        let ratio = if v.visual_var > 0.0 { v.text_var / v.visual_var } else { 0.0 };
        table.row(vec![
            format!("{}", l),
            format!("{:.5}", v.visual_var),
            format!("{:.5}", v.text_var),
            f2(ratio),
            f4(v.visual_mean),
            f4(v.text_mean),
        ]);
    }
    table.print();
    println!("\npaper shape: visual and text cumulative-score distributions \
              differ markedly in the first layer — a uniform eviction rule \
              cannot serve both modalities.");
    Ok(())
}
