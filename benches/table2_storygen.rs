//! Paper Table 2 — multi-image story generation: quality + speed.
//!
//! The paper reports judge scores (style/engaging/coherence) and seconds
//! per sample on Seed-Story; the reproduction measures generation-quality
//! proxies (distinct-2, repetition, image grounding), fidelity, and
//! wall-clock per sample on the synthetic story workload. Expected shape:
//! HAE is the fastest method (paper: 1.5× over full cache) with quality
//! between Full and H2O/MustDrop.

use hae_serve::cache::PolicyKind;
use hae_serve::eval::quality::degeneration;
use hae_serve::harness::*;
use hae_serve::workload::RequestBuilder;

fn main() -> anyhow::Result<()> {
    let n = bench_n(10);
    let rt = load_runtime()?;
    let meta = rt.meta().clone();
    let grammar = load_grammar(&artifact_dir());
    drop(rt);

    // long-generation episodes: 3 images, 160 new tokens
    let mut builder = RequestBuilder::new(&meta, &grammar, 202);
    let requests: Vec<_> = (0..n).map(|_| builder.story(3, 12, 256)).collect();

    let policies: Vec<PolicyKind> = vec![
        PolicyKind::Full,
        PolicyKind::parse("h2o").unwrap(),
        PolicyKind::parse("mustdrop").unwrap(),
        PolicyKind::hae_default(),
    ];

    let mut table = Table::new(
        &format!("Table 2 — story generation, {} episodes × 256 tokens", n),
        &[
            "Method", "Distinct2", "Repeat", "Grounding", "Top1-agree", "s/sample",
            "tok/s", "Decisions",
        ],
    );

    for kind in policies {
        let mut engine = engine_for(kind.clone(), 1, false)?;
        let run = run_policy(&mut engine, requests.clone())?;
        let mut d2 = 0.0;
        let mut rep = 0.0;
        let mut gr = 0.0;
        let mut toks = 0usize;
        let mut decisions = 0u64;
        for ar in &run.finished {
            let d = degeneration(&ar.generated, &ar.req.images);
            d2 += d.distinct_2;
            rep += d.repetition_rate;
            gr += d.grounding;
            toks += ar.generated.len();
            decisions += ar.stats.decisions;
        }
        let k = run.finished.len() as f64;
        let fids = fidelity_vs_full(kind.clone(), &requests[..n.min(4)])?;
        let f = mean_fidelity(&fids);
        table.row(vec![
            run.label,
            f3(d2 / k),
            f3(rep / k),
            pct(gr / k),
            pct(f.top1_agreement),
            f3(run.wall_s / k),
            f2(toks as f64 / run.wall_s),
            format!("{}", decisions / run.finished.len() as u64),
        ]);
    }
    table.print();
    println!("\npaper shape: HAE fastest (7.40s→4.96s, 1.5×) with quality \
              between Full and H2O/MustDrop; H2O slowest per decision count.");
    Ok(())
}
