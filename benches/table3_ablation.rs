//! Paper Table 3 — stage-wise ablation on the MMMU-like mixed workload.
//!
//! Columns mirror the paper: mean retained tokens, accuracy (QA subset +
//! fidelity), KV cache footprint, and time per sample, across Full /
//! MustDrop / H2O / SnapKV / AdaKV and the three HAE stage configurations.
//! Expected shape: HAE (Pre-filling) is the fastest; H2O is *slower* than
//! Full (per-step sorting on short generations); HAE (All Stage) sits
//! between the two HAE stages and beats every baseline.

use hae_serve::cache::PolicyKind;
use hae_serve::eval::mean_peak_kv_mib;
use hae_serve::harness::*;
use hae_serve::workload::{RequestBuilder, WorkloadKind};

fn main() -> anyhow::Result<()> {
    let n = bench_n(24);
    let rt = load_runtime()?;
    let meta = rt.meta().clone();
    let grammar = load_grammar(&artifact_dir());
    drop(rt);

    let requests =
        RequestBuilder::new(&meta, &grammar, 303).make_batch(WorkloadKind::Mixed, n);

    let policies: Vec<PolicyKind> = vec![
        PolicyKind::Full,
        PolicyKind::parse("mustdrop").unwrap(),
        PolicyKind::parse("h2o").unwrap(),
        PolicyKind::parse("snapkv:budget=64,window=8").unwrap(),
        PolicyKind::parse("adakv").unwrap(),
        PolicyKind::parse("hae:stage=prefill").unwrap(),
        PolicyKind::parse("hae:stage=decode").unwrap(),
        PolicyKind::hae_default(),
    ];

    let mut table = Table::new(
        &format!("Table 3 — MMMU-like ablation, {} mixed samples", n),
        &[
            "Method", "Tokens", "Acc", "Top1-agree", "KV MiB", "ms/sample",
            "Decisions",
        ],
    );

    for kind in policies {
        let mut engine = engine_for(kind.clone(), 1, false)?;
        let run = run_policy(&mut engine, requests.clone())?;
        let k = run.finished.len() as f64;
        let tokens: f64 = run
            .finished
            .iter()
            .map(|ar| (ar.stats.prompt_tokens - ar.stats.pruned_at_prefill
                + ar.generated.len()) as f64)
            .sum::<f64>()
            / k;
        let acc = answer_accuracy(&run.finished);
        let fids = fidelity_vs_full(kind.clone(), &requests[..n.min(8)])?;
        let f = mean_fidelity(&fids);
        let peaks: Vec<usize> =
            run.finished.iter().map(|ar| ar.stats.peak_kv_bytes).collect();
        let decisions: u64 =
            run.finished.iter().map(|ar| ar.stats.decisions).sum::<u64>() / run.finished.len() as u64;
        table.row(vec![
            run.label,
            f2(tokens),
            pct(acc),
            pct(f.top1_agreement),
            f4(mean_peak_kv_mib(&peaks)),
            f2(run.wall_s * 1000.0 / k),
            format!("{}", decisions),
        ]);
    }
    table.print();
    println!("\npaper shape: HAE(Pre-filling) fastest (0.21s), HAE(All) 0.36s, \
              HAE(Decoding) 0.49s, Full 0.58s, H2O slowest (0.63s); \
              decision counts explain the ordering.");
    Ok(())
}
