//! Paper Table 1 (and Table 6) — eviction strategies on multimodal
//! understanding.
//!
//! The paper reports seven LLaVA benchmark columns at a 192/576 visual
//! retain budget; the reproduction measures, on the synthetic understanding
//! workload (DESIGN.md §3): QA answer accuracy, fidelity to the full-cache
//! model (top-1 agreement / logit KL under teacher forcing), mean retained
//! visual tokens and KV footprint. Expected shape: HAE ≈ Full ≥ MustDrop ≈
//! SparseVLM > FastV ≈ ToMe.
//!
//!     cargo bench --offline --bench table1_understanding
//!     HAE_BENCH_N=100 cargo bench ...   # bigger sample
//!     HAE_RETAIN=0.222 ...              # Table 6's 128/576 operating point

use hae_serve::cache::{PolicyKind, PAPER_RETAIN_RATIO};
use hae_serve::harness::*;
use hae_serve::workload::{RequestBuilder, WorkloadKind};

fn main() -> anyhow::Result<()> {
    let n = bench_n(40);
    let ratio: f32 = std::env::var("HAE_RETAIN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(PAPER_RETAIN_RATIO);
    let rt = load_runtime()?;
    let meta = rt.meta().clone();
    let grammar = load_grammar(&artifact_dir());
    drop(rt);

    let requests =
        RequestBuilder::new(&meta, &grammar, 101).make_batch(WorkloadKind::Understanding, n);

    // Two operating points: the paper's headline ratio (Table 1, 192/576)
    // and an aggressive one (Table 6's 128/576 and below) where policy
    // differences become visible on the redundancy-rich synthetic task.
    for (point, ratio, hae_spec, mustdrop_spec) in [
        ("paper 192/576", ratio, "hae".to_string(), "mustdrop".to_string()),
        (
            "paper-rate ~2/3 evicted",
            0.125,
            "hae:rrel=1.0,alpha=0.1".to_string(),
            "mustdrop:r=0.12".to_string(),
        ),
    ] {
    let policies: Vec<PolicyKind> = vec![
        PolicyKind::Full,
        PolicyKind::ToMe { retain_ratio: ratio },
        PolicyKind::FastV { retain_ratio: ratio },
        PolicyKind::SparseVlm { retain_ratio: ratio },
        PolicyKind::parse(&mustdrop_spec).unwrap(),
        PolicyKind::parse(&hae_spec).unwrap(),
        PolicyKind::Random { budget: None, seed: 7 },
    ];

    let mut table = Table::new(
        &format!(
            "Table 1 — understanding, {} samples, retain ratio {:.2} ({})",
            n, ratio, point
        ),
        &["Method", "Acc", "Top1-agree", "meanKL", "VisKept", "KV KiB", "ms/req"],
    );

    for kind in policies {
        let mut engine = engine_for(kind.clone(), 1, false)?;
        let run = run_policy(&mut engine, requests.clone())?;
        let acc = answer_accuracy(&run.finished);
        let fids = fidelity_vs_full(kind.clone(), &requests)?;
        let f = mean_fidelity(&fids);
        let vis_kept: f64 = run
            .finished
            .iter()
            .map(|ar| (ar.stats.vision_tokens - ar.stats.pruned_at_prefill) as f64)
            .sum::<f64>()
            / run.finished.len() as f64;
        let kv_kib: f64 = run
            .finished
            .iter()
            .map(|ar| ar.stats.peak_kv_bytes as f64 / 1024.0)
            .sum::<f64>()
            / run.finished.len() as f64;
        table.row(vec![
            run.label,
            pct(acc),
            pct(f.top1_agreement),
            f4(f.mean_kl),
            f2(vis_kept),
            f2(kv_kib),
            f2(run.wall_s * 1000.0 / n as f64),
        ]);
    }
    table.print();
    }
    println!("\npaper shape: HAE tracks Full Cache closely (0.3% drop) while \
              pruning ~2/3 of visual tokens; rank HAE > MustDrop/SparseVLM > FastV/ToMe.");
    Ok(())
}
