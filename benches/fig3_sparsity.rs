//! Paper Fig. 3 — attention sparsity rates across layers (relative threshold),
//! split into overall / visual / text components.
//!
//! Expected shape: all layers are highly sparse; in the first layers the
//! VISUAL component is sparser than the text component (the asymmetry DAP
//! exploits), and deeper layers are at least as sparse as layer 1 (the
//! premise of index broadcasting).

use hae_serve::harness::*;
use hae_serve::model::vocab;
use hae_serve::workload::{RequestBuilder, WorkloadKind};

fn main() -> anyhow::Result<()> {
    let n = bench_n(50);
    let rt = load_runtime()?;
    let meta = rt.meta().clone();
    let grammar = load_grammar(&artifact_dir());
    let mut builder = RequestBuilder::new(&meta, &grammar, 606);

    let bucket = *rt.manifest.shapes.analysis_buckets.first().unwrap();
    let mut acc = vec![[0.0f64; 3]; meta.n_layers];
    let mut count = 0usize;

    for i in 0..n {
        let kind = if i % 2 == 0 { WorkloadKind::Understanding } else { WorkloadKind::Mixed };
        let req = builder.make(kind);
        if req.prompt_len() > bucket {
            continue;
        }
        let mut ids = req.ids.clone();
        ids.resize(bucket, vocab::PAD);
        let mut patches = req.patches.clone();
        patches.resize(bucket * meta.patch_dim, 0.0);
        let mut isv: Vec<f32> =
            req.is_vision.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect();
        isv.resize(bucket, 0.0);
        let (out, _) = rt.analysis(bucket, &ids, &patches, &isv, req.prompt_len())?;
        for l in 0..meta.n_layers {
            let (o, v, t) = out.layer_sparsity(l);
            acc[l][0] += o as f64;
            acc[l][1] += v as f64;
            acc[l][2] += t as f64;
        }
        count += 1;
    }

    let mut table = Table::new(
        &format!("Fig. 3 — sparsity rates per layer (relative ε=0.25/n, {} samples)", count),
        &["Layer", "Overall", "Visual", "Text", "Vis−Text"],
    );
    for (l, a) in acc.iter().enumerate() {
        let (o, v, t) = (a[0] / count as f64, a[1] / count as f64, a[2] / count as f64);
        table.row(vec![
            format!("{}", l),
            pct(o),
            pct(v),
            pct(t),
            format!("{:+.1}pp", (v - t) * 100.0),
        ]);
    }
    table.print();
    println!("\npaper shape: visual sparsity ≥ text sparsity in early layers; \
              later layers at least as sparse as layer 0 (broadcast premise).");
    Ok(())
}
