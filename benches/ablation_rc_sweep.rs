//! Extension ablation (beyond the paper's tables): recycle-bin size sweep
//! and batch-width scaling.
//!
//! RC_size is HAE's main decode-stage knob (paper Table 5 sets 56/128
//! per task without justification). The sweep shows the trade-off: small
//! bins approach greedy H2O (frequent flushes, more decisions), large bins
//! approach no-eviction (bigger caches, slower steps but fewer decisions).
//! The batch section checks the continuous batcher scales decode
//! throughput across compiled batch widths.

use hae_serve::cache::PolicyKind;
use hae_serve::harness::*;
use hae_serve::workload::RequestBuilder;

fn main() -> anyhow::Result<()> {
    let n = bench_n(6);
    let rt = load_runtime()?;
    let meta = rt.meta().clone();
    let batches = rt.manifest.shapes.decode_batches.clone();
    let grammar = load_grammar(&artifact_dir());
    drop(rt);

    let mut builder = RequestBuilder::new(&meta, &grammar, 909);
    let requests: Vec<_> = (0..n).map(|_| builder.story(3, 12, 160)).collect();

    let mut table = Table::new(
        &format!("RC_size sweep — HAE decode stage, {} story episodes", n),
        &["RC_size", "s/sample", "Top1-agree", "mean live KV KiB", "Decisions"],
    );
    for rc in [4usize, 8, 16, 24, 48, 96] {
        let kind = PolicyKind::parse(&format!("hae:rc={}", rc)).unwrap();
        let mut engine = engine_for(kind.clone(), 1, false)?;
        let run = run_policy(&mut engine, requests.clone())?;
        let k = run.finished.len() as f64;
        let mean_kv: f64 = run
            .finished
            .iter()
            .map(|ar| ar.stats.mean_kv_bytes() / 1024.0)
            .sum::<f64>()
            / k;
        let decisions: u64 = run.finished.iter().map(|ar| ar.stats.decisions).sum::<u64>()
            / run.finished.len() as u64;
        let fids = fidelity_vs_full(kind, &requests[..2])?;
        let f = mean_fidelity(&fids);
        table.row(vec![
            format!("{}", rc),
            f3(run.wall_s / k),
            pct(f.top1_agreement),
            f2(mean_kv),
            format!("{}", decisions),
        ]);
    }
    table.print();

    let mut t2 = Table::new(
        "Batch-width scaling — HAE, story workload",
        &["batch", "wall s", "tok/s", "mean step cap"],
    );
    for &b in &batches {
        let mut engine = engine_for(PolicyKind::hae_default(), b, false)?;
        engine.warmup()?;
        let reqs: Vec<_> = (0..b * 3)
            .map(|_| {
                let mut bb = RequestBuilder::new(&meta, &grammar, 1000 + b as u64);
                bb.story(3, 12, 120)
            })
            .collect();
        let t0 = std::time::Instant::now();
        let (finished, reports) = engine.run_batched(reqs)?;
        let wall = t0.elapsed().as_secs_f64();
        let toks: usize = finished.iter().map(|ar| ar.generated.len()).sum();
        let mean_cap: f64 = reports.iter().map(|r| r.capacity as f64).sum::<f64>()
            / reports.len().max(1) as f64;
        t2.row(vec![
            format!("{}", b),
            f3(wall),
            f2(toks as f64 / wall),
            f2(mean_cap),
        ]);
    }
    t2.print();
    Ok(())
}
