//! Theorem 2.1 / Corollary 2.1 — executable theory checks on real traces.
//!
//! * Thm 2.1: fit the decay rate λ from measured per-step slot scores on
//!   story runs, compute the k bound for a sweep of ε, and verify the
//!   worst-case loss relation.
//! * Cor 2.1: run the same story requests under DDES (HAE decode stage)
//!   and greedy (H2O) with teacher forcing on the same scripts, and compare
//!   realized eviction losses — DDES ≤ greedy is the corollary's claim.

use hae_serve::attention::decay_rate_fit;
use hae_serve::cache::PolicyKind;
use hae_serve::harness::*;
use hae_serve::theory;
use hae_serve::workload::RequestBuilder;

fn main() -> anyhow::Result<()> {
    let n = bench_n(6);
    let rt = load_runtime()?;
    let meta = rt.meta().clone();
    let grammar = load_grammar(&artifact_dir());
    drop(rt);

    let mut builder = RequestBuilder::new(&meta, &grammar, 808);
    let requests: Vec<_> = (0..n).map(|_| builder.story(3, 12, 120)).collect();

    // reference scripts + per-step score traces (greedy full cache)
    let mut reference = engine_for(PolicyKind::Full, 1, false)?;
    reference.cfg.capture_scores = true;
    let mut scripts = Vec::new();
    for req in &requests {
        let ar = reference.generate(req.clone())?;
        scripts.push((ar.generated.clone(), ar.score_trace));
    }

    // --- decay-rate fit (Thm 2.1 input) --------------------------------
    // mean last_score over the cache as a function of step on full-cache
    // runs approximates S(t): each slot's per-step mass dilutes as the
    // context grows.
    let mut series = Vec::new();
    {
        let mut engine = engine_for(PolicyKind::Full, 1, false)?;
        let mut ar = engine.prefill(requests[0].clone())?;
        while !ar.done {
            let mean_last: f64 = ar
                .slab
                .meta()
                .iter()
                .map(|m| m.last_score as f64)
                .sum::<f64>()
                / ar.slab.len().max(1) as f64;
            if ar.stats.steps > 0 {
                series.push(mean_last);
            }
            let mut lanes = [&mut ar];
            engine.decode_step(&mut lanes)?;
        }
    }
    let lambda = decay_rate_fit(&series);
    println!("fitted decay rate λ = {:.4} over {} steps", lambda, series.len());

    let attn_max = series.iter().cloned().fold(0.0f64, f64::max);
    let mut t1 = Table::new(
        "Theorem 2.1 — eviction threshold k(ε) under the fitted decay model",
        &["ε", "k bound", "worst-case loss at k", "< ε?"],
    );
    for eps in [0.01, 0.005, 0.001, 0.0005] {
        match theory::integrity_bound(eps, attn_max, lambda) {
            Some(k) => {
                let loss = theory::worst_case_loss(attn_max, lambda, k.ceil());
                t1.row(vec![
                    format!("{}", eps),
                    f2(k),
                    format!("{:.6}", loss),
                    format!("{}", loss <= eps + 1e-12),
                ]);
            }
            None => t1.row(vec![
                format!("{}", eps),
                "vacuous".into(),
                "-".into(),
                "true".into(),
            ]),
        }
    }
    t1.print();

    // --- Corollary 2.1: DDES vs greedy realized loss --------------------
    let mut t2 = Table::new(
        "Corollary 2.1 — per-eviction FORWARD loss (mass the victim would \
         still have received, from the full-cache trace): DDES vs greedy",
        &["episode", "DDES fwd", "greedy fwd", "DDES ≤ greedy", "DDES evicts", "greedy evicts"],
    );
    let mut holds = 0usize;
    for (i, (req, (script, ref_trace))) in requests.iter().zip(&scripts).enumerate() {
        let mut ddes_engine =
            engine_for(PolicyKind::parse("hae:stage=decode").unwrap(), 1, false)?;
        let ddes = ddes_engine.generate_forced(req.clone(), script)?;
        let mut greedy_engine = engine_for(PolicyKind::parse("h2o").unwrap(), 1, false)?;
        let greedy = greedy_engine.generate_forced(req.clone(), script)?;
        // forward loss: what the evicted positions would have earned had
        // they stayed — Corollary 2.1's ε_i (eviction without urgency
        // picks tokens whose future relevance is lower)
        let dl = theory::forward_loss(&ddes.evictions, ref_trace);
        let gl = theory::forward_loss(&greedy.evictions, ref_trace);
        let dn = ddes.evictions.iter().map(|e| e.victims.len()).sum::<usize>().max(1);
        let gn = greedy.evictions.iter().map(|e| e.victims.len()).sum::<usize>().max(1);
        let (dpt, gpt) = (dl / dn as f64, gl / gn as f64);
        if dpt <= gpt + 1e-9 {
            holds += 1;
        }
        t2.row(vec![
            format!("{}", i),
            format!("{:.5}", dpt),
            format!("{:.5}", gpt),
            format!("{}", dpt <= gpt + 1e-9),
            format!("{}", dn),
            format!("{}", gn),
        ]);
    }
    t2.print();
    println!(
        "\nCorollary 2.1 (forward loss) holds on {}/{} episodes. Note: measured \
         by *backward* cumulative score DDES victims are slightly warmer than \
         greedy's (they keep accumulating while marked) — the bin's benefit is \
         precisely that the extra observation time selects tokens with lower \
         FUTURE relevance.",
        holds, n
    );
    Ok(())
}
