//! §Perf — radix-tree prefix cache: cold vs warm prefill on the
//! shared-image multi-question QA workload (many questions, one image).
//!
//! Two sections:
//!
//! 1. **Runtime-free primitives** (always run): key hashing + trie
//!    lookup throughput, and the CoW adopt/fork costs against a
//!    synthetic arena — the host-side budget of a warm admission.
//! 2. **Cold vs warm engine table** (needs artifacts): N images × 8
//!    questions each, prefix cache off vs on. Asserts the acceptance
//!    criteria: warm `generate` outputs are byte-identical to the cold
//!    path, and ≥ 50% of prefill tokens are skipped at 8 questions per
//!    image (2 distinct question prompts → 6 of 8 admissions are warm).

use std::time::Instant;

use hae_serve::cache::{KvSlab, Modality, PagePool, PolicyKind};
use hae_serve::coordinator::{Engine, EngineConfig};
use hae_serve::harness::{artifact_dir, bench_n, f2, load_grammar, load_runtime, Table};
use hae_serve::model::ModelMeta;
use hae_serve::obs::BenchReport;
use hae_serve::prefix::{request_fingerprint, request_key, PrefixCache, PrefixStats};
use hae_serve::workload::{Request, RequestBuilder, StoryGrammar};

fn tiny_meta() -> ModelMeta {
    ModelMeta {
        vocab: 512,
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        d_head: 32,
        d_mlp: 256,
        patch_dim: 32,
        n_patches: 16,
        max_pos: 640,
        dap_layer: 1,
    }
}

/// Key hashing + trie lookup throughput over the shared-image workload.
fn primitives(table: &mut Table, report: &mut BenchReport, iters: usize) {
    let m = tiny_meta();
    let g = StoryGrammar::uniform();
    let mut b = RequestBuilder::new(&m, &g, 3);
    // 8 distinct images × 2 questions each: 16 entries in the trie
    let reqs: Vec<_> = (0..8).flat_map(|i| b.shared_image_qa(100 + i, 2)).collect();

    let t0 = Instant::now();
    let mut keys = Vec::new();
    for _ in 0..iters {
        keys.clear();
        keys.extend(reqs.iter().map(request_key));
    }
    let key_us = t0.elapsed().as_secs_f64() * 1e6 / (iters * reqs.len()) as f64;
    report.metric("request_key_us", key_us, "us");
    table.row(vec![
        "request_key (18-token prompt)".into(),
        format!("{}", iters * reqs.len()),
        f2(key_us),
        "-".into(),
    ]);

    // populate a cache over a synthetic arena, then measure warm lookups
    let row = m.n_heads * m.d_head;
    let mut pool = PagePool::new(m.n_layers, row, 256, 16);
    let mut cache = PrefixCache::new(64);
    let fps: Vec<u64> = reqs.iter().map(request_fingerprint).collect();
    for (k, &fp) in keys.iter().zip(&fps) {
        let pages = vec![pool.alloc().unwrap()];
        let meta = vec![
            hae_serve::cache::SlotMeta {
                position: 0,
                modality: Modality::Vision,
                cum_score: 0.0,
                cum_peak: 0.0,
                last_score: 0.0,
                marked: false,
                age: 0,
            };
            12
        ];
        cache.register(&mut pool, k.clone(), fp, pages, meta, 18, vec![0.0; m.vocab]);
    }
    let t0 = Instant::now();
    let mut hits = 0usize;
    for _ in 0..iters {
        for (k, &fp) in keys.iter().zip(&fps) {
            if cache.lookup(k, fp).is_some() {
                hits += 1;
            }
        }
    }
    let lk_us = t0.elapsed().as_secs_f64() * 1e6 / (iters * keys.len()) as f64;
    assert_eq!(hits, iters * keys.len(), "every key registered must hit");
    report.metric("trie_lookup_us", lk_us, "us");
    table.row(vec![
        "trie lookup + snapshot (16 entries)".into(),
        format!("{}", hits),
        f2(lk_us),
        "-".into(),
    ]);
}

/// CoW adopt vs fork cost against a synthetic arena.
fn cow_costs(table: &mut Table, report: &mut BenchReport, iters: usize) {
    let m = tiny_meta();
    let row = m.n_heads * m.d_head;
    let pool = PagePool::new_shared(m.n_layers, row, 512, 16);
    let token_row = vec![0.5f32; m.n_layers * row];
    let mut donor = KvSlab::in_pool(&pool, 64);
    for i in 0..48 {
        donor.append(&token_row, &token_row, i, Modality::Vision, 0.0);
    }
    let pages = donor.mark_all_shared();
    {
        let mut p = pool.lock().unwrap();
        for &pg in &pages {
            p.retain_page(pg);
        }
    }
    let meta = donor.meta().to_vec();

    let t0 = Instant::now();
    for _ in 0..iters {
        let mut s = KvSlab::in_pool(&pool, 64);
        assert!(s.adopt_shared(&pages, meta.clone()));
    }
    let adopt_us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;
    report.metric("cow_adopt_us", adopt_us, "us");
    table.row(vec![
        "adopt 3-page prefix (zero copy)".into(),
        format!("{}", iters),
        f2(adopt_us),
        "0".into(),
    ]);

    let forks0 = pool.lock().unwrap().stats().forks;
    let t0 = Instant::now();
    for _ in 0..iters {
        let mut s = KvSlab::in_pool(&pool, 64);
        assert!(s.adopt_shared(&pages, meta.clone()));
        // first write inside the shared prefix forks the written page(s)
        s.evict(&[40]);
    }
    let fork_us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;
    let forked = pool.lock().unwrap().stats().forks - forks0;
    report.metric("cow_fork_us", fork_us, "us");
    table.row(vec![
        "adopt + diverge (CoW fork)".into(),
        format!("{}", iters),
        f2(fork_us),
        f2(forked as f64 / iters.max(1) as f64),
    ]);
}

/// Generate every request serially on a fresh engine; returns
/// (wall, Σ prefill_s, token streams, prefix stats, extend calls,
/// effective extend chunk).
fn run_mode(
    prefix_cache: bool,
    requests: &[Request],
) -> anyhow::Result<(f64, f64, Vec<Vec<i32>>, PrefixStats, u64, usize)> {
    let mut engine = Engine::from_artifact_dir(
        &artifact_dir(),
        EngineConfig {
            policy: PolicyKind::hae_default(),
            prefix_cache,
            ..EngineConfig::default()
        },
    )?;
    engine.warmup()?;
    let t0 = Instant::now();
    let mut outputs = Vec::new();
    let mut prefill_s = 0.0f64;
    for r in requests {
        let ar = engine.generate(r.clone())?;
        prefill_s += ar.stats.prefill_s;
        outputs.push(ar.generated.clone());
    }
    Ok((
        t0.elapsed().as_secs_f64(),
        prefill_s,
        outputs,
        engine.prefix_stats(),
        engine.extend_calls(),
        engine.effective_extend_chunk(),
    ))
}

/// Cold vs warm serving table + the acceptance assertions.
fn engine_table(report: &mut BenchReport, n_images: usize) -> anyhow::Result<()> {
    let rt = match load_runtime() {
        Ok(rt) => rt,
        Err(_) => {
            hae_serve::harness::skip_or_fail(
                "artifacts not built (run `make artifacts`) — \
                 cold-vs-warm engine section",
            );
            return Ok(());
        }
    };
    let grammar = load_grammar(&artifact_dir());
    let meta = rt.meta().clone();
    let questions_per_image = 8usize;
    let mut b = RequestBuilder::new(&meta, &grammar, 9);
    let requests: Vec<_> = (0..n_images)
        .flat_map(|i| b.shared_image_qa(1000 + i as u64, questions_per_image))
        .collect();
    let total_prompt_tokens: usize = requests.iter().map(|r| r.prompt_len()).sum();

    drop(rt);
    let (cold_wall, cold_prefill, cold_out, _, _, _) = run_mode(false, &requests)?;
    let (warm_wall, warm_prefill, warm_out, ps, _, _) =
        run_mode(true, &requests)?;

    // acceptance: byte-identical outputs, ≥50% prefill tokens skipped
    assert_eq!(cold_out.len(), warm_out.len());
    for (i, (c, w)) in cold_out.iter().zip(&warm_out).enumerate() {
        assert_eq!(c, w, "request {} diverged between cold and warm", i);
    }
    let skipped_frac = ps.prefill_tokens_skipped as f64 / total_prompt_tokens as f64;
    report.metric("cold_prefill_s", cold_prefill, "s");
    report.metric("warm_prefill_s", warm_prefill, "s");
    report.metric("warm_skipped_frac", skipped_frac, "fraction");
    assert!(
        skipped_frac >= 0.5,
        "prefill tokens skipped {:.1}% < 50% at {} questions/image",
        skipped_frac * 100.0,
        questions_per_image
    );

    let mut table = Table::new(
        &format!(
            "cold vs warm: {} images × {} questions (outputs byte-identical)",
            n_images, questions_per_image
        ),
        &["mode", "wall s", "prefill s", "hits", "hit rate",
          "prefill tok skipped", "pages pinned"],
    );
    table.row(vec![
        "prefix cache off".into(),
        f2(cold_wall),
        f2(cold_prefill),
        "0".into(),
        "-".into(),
        "0".into(),
        "0".into(),
    ]);
    table.row(vec![
        "prefix cache on".into(),
        f2(warm_wall),
        f2(warm_prefill),
        format!("{}", ps.hits),
        format!("{:.0}%", 100.0 * ps.hits as f64 / (ps.hits + ps.misses) as f64),
        format!("{} ({:.0}%)", ps.prefill_tokens_skipped, skipped_frac * 100.0),
        format!("{}", ps.pinned_pages),
    ]);
    table.print();
    println!(
        "\n(per distinct image the DAP decision and visual-prefix KV are\n\
         computed once; the other {} of {} admissions adopt the pinned\n\
         pages copy-on-write and skip prefill entirely)",
        ps.hits,
        requests.len()
    );
    Ok(())
}

/// Partial-prefix warm starts: a multi-turn dialog — distinct question
/// prompts, one image — where exact-match reuse is impossible. Asserts
/// the acceptance criteria: every warm turn's output is byte-identical
/// to its own cold run, no exact hits occur, every turn after the first
/// is a partial hit, and the prefill tokens skipped reach at least the
/// shared-prefix fraction of the warm turns' prompt tokens.
fn dialog_table(report: &mut BenchReport, n_turns: usize) -> anyhow::Result<()> {
    let rt = match load_runtime() {
        Ok(rt) => rt,
        Err(_) => {
            hae_serve::harness::skip_or_fail(
                "artifacts not built (run `make artifacts`) — \
                 partial-hit dialog section",
            );
            return Ok(());
        }
    };
    let grammar = load_grammar(&artifact_dir());
    let meta = rt.meta().clone();
    let mut b = RequestBuilder::new(&meta, &grammar, 11);
    let turns = b.shared_image_dialog(2000, n_turns);
    let prefix_len = 1 + meta.n_patches; // [BOS][img]
    let warm_prompt_tokens: usize = turns[1..].iter().map(|r| r.prompt_len()).sum();

    drop(rt);
    let (cold_wall, cold_prefill, cold_out, _, _, _) = run_mode(false, &turns)?;
    let (warm_wall, warm_prefill, warm_out, ps, extend_calls, eff_chunk) =
        run_mode(true, &turns)?;

    // acceptance: byte-identity per turn, partial hits only, skip rate ≥
    // the shared-prefix fraction
    assert_eq!(cold_out.len(), warm_out.len());
    for (i, (c, w)) in cold_out.iter().zip(&warm_out).enumerate() {
        assert_eq!(c, w, "turn {} diverged between cold and warm", i);
    }
    assert_eq!(ps.hits, 0, "distinct prompts: exact hits are impossible");
    assert!(
        ps.partial_hits as usize >= n_turns - 1,
        "turns 1..{} must warm-start partially: {:?}",
        n_turns,
        ps
    );
    let skipped = ps.prefill_tokens_skipped as usize;
    let shared = (n_turns - 1) * prefix_len;
    assert!(
        skipped >= shared,
        "skipped {} < {} ({} warm turns × {}-token shared prefix)",
        skipped,
        shared,
        n_turns - 1,
        prefix_len
    );
    // the chunked suffix recompute: never more device calls than
    // ⌈suffix/chunk⌉ per warm turn at the chunk the engine actually ran
    // (the default clamped to the artifacts' largest compiled bucket —
    // 1 on a pre-extend artifact set, where the bound degrades to the
    // old one-call-per-token loop instead of hard-failing the bench)
    let call_bound: u64 = turns[1..]
        .iter()
        .map(|r| {
            hae_serve::scheduler::AdmissionController::extend_chunk_calls(
                r.prompt_len() - prefix_len,
                eff_chunk,
            ) as u64
        })
        .sum();
    assert!(
        extend_calls <= call_bound,
        "extend calls {} > Σ⌈suffix/{}⌉ = {}",
        extend_calls,
        eff_chunk,
        call_bound
    );
    let shared_frac = shared as f64 / warm_prompt_tokens as f64;
    let skip_frac = skipped as f64 / warm_prompt_tokens as f64;
    report.metric("dialog_cold_wall_s", cold_wall, "s");
    report.metric("dialog_warm_wall_s", warm_wall, "s");
    report.metric("dialog_extend_calls", extend_calls as f64, "calls");
    report.metric("dialog_skip_frac", skip_frac, "fraction");
    assert!(
        skip_frac + 1e-9 >= shared_frac,
        "skip rate {:.1}% below the shared-prefix fraction {:.1}%",
        skip_frac * 100.0,
        shared_frac * 100.0
    );

    let mut table = Table::new(
        &format!(
            "partial warm starts: {}-turn dialog, 1 image, distinct prompts \
             (outputs byte-identical per turn)",
            n_turns
        ),
        &["mode", "wall s", "prefill s", "partial hits", "extend calls",
          "prefill tok skipped", "skip rate vs shared-prefix frac"],
    );
    table.row(vec![
        "prefix cache off".into(),
        f2(cold_wall),
        f2(cold_prefill),
        "0".into(),
        "0".into(),
        "0".into(),
        "-".into(),
    ]);
    table.row(vec![
        "prefix cache on".into(),
        f2(warm_wall),
        f2(warm_prefill),
        format!("{}", ps.partial_hits),
        format!("{}", extend_calls),
        format!("{}", skipped),
        format!("{:.1}% ≥ {:.1}%", skip_frac * 100.0, shared_frac * 100.0),
    ]);
    table.print();
    println!(
        "\n(no two turns share a whole prompt, so PR 3's exact matching would\n\
         recompute every visual prefix; the partial path adopts the image's\n\
         unpruned KV copy-on-write, recomputes only the dialog text through\n\
         the decode executables, and re-runs the DAP decision per turn)"
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let iters = bench_n(200);
    let mut report = BenchReport::new("prefix_cache");
    report.config("iters", iters);
    let mut table = Table::new(
        &format!("prefix-cache primitives, {} iterations", iters),
        &["primitive", "ops", "µs/op", "pages forked/op"],
    );
    primitives(&mut table, &mut report, iters);
    cow_costs(&mut table, &mut report, iters);
    table.print();
    engine_table(&mut report, 3)?;
    dialog_table(&mut report, 8)?;
    let path = report.write().expect("write BENCH_prefix_cache.json");
    println!("\nbench report: {}", path.display());
    Ok(())
}
