//! Paper Table 4 — video understanding (TGIF/MSVD/MSRVT stand-in).
//!
//! Multi-frame QA where the question references the LAST frame, so a
//! policy that indiscriminately prunes visual tokens across frames loses
//! the referent. Expected shape: HAE within a fraction of a point of the
//! best baseline (paper: HAE 57.8 avg vs MustDrop 58.1 vs Video-LLaVA
//! 58.2 full).

use hae_serve::cache::{PolicyKind, PAPER_RETAIN_RATIO};
use hae_serve::harness::*;
use hae_serve::workload::RequestBuilder;

fn main() -> anyhow::Result<()> {
    let n = bench_n(32);
    let rt = load_runtime()?;
    let meta = rt.meta().clone();
    let grammar = load_grammar(&artifact_dir());
    drop(rt);

    // 4-frame "videos" (64 visual tokens per request)
    let mut builder = RequestBuilder::new(&meta, &grammar, 404);
    let requests: Vec<_> = (0..n).map(|_| builder.video(4)).collect();

    let policies: Vec<PolicyKind> = vec![
        PolicyKind::Full,
        PolicyKind::SparseVlm { retain_ratio: PAPER_RETAIN_RATIO },
        PolicyKind::FastV { retain_ratio: PAPER_RETAIN_RATIO },
        PolicyKind::parse("mustdrop").unwrap(),
        PolicyKind::hae_default(),
    ];

    let mut table = Table::new(
        &format!("Table 4 — video QA, {} samples × 4 frames", n),
        &["Method", "Acc", "Top1-agree", "meanKL", "VisKept", "ms/req"],
    );

    for kind in policies {
        let mut engine = engine_for(kind.clone(), 1, false)?;
        let run = run_policy(&mut engine, requests.clone())?;
        let acc = answer_accuracy(&run.finished);
        let fids = fidelity_vs_full(kind.clone(), &requests)?;
        let f = mean_fidelity(&fids);
        let vis_kept: f64 = run
            .finished
            .iter()
            .map(|ar| (ar.stats.vision_tokens - ar.stats.pruned_at_prefill) as f64)
            .sum::<f64>()
            / run.finished.len() as f64;
        table.row(vec![
            run.label,
            pct(acc),
            pct(f.top1_agreement),
            f4(f.mean_kl),
            f2(vis_kept),
            f2(run.wall_s * 1000.0 / n as f64),
        ]);
    }
    table.print();
    println!("\npaper shape: HAE within ~0.5pt of the best compression \
              baseline; adaptive thresholds preserve the referenced frame's \
              informative patches.");
    Ok(())
}
