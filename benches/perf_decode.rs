//! §Perf — decode hot-path breakdown.
//!
//! Measures per-step time split into host-side batch assembly (coordinator),
//! host→device upload, PJRT execute and device→host readback, per capacity
//! bucket and batch width. This is the profile that drives the EXPERIMENTS.md
//! §Perf iteration log.

use std::time::Instant;

use hae_serve::cache::PolicyKind;
use hae_serve::harness::*;
use hae_serve::obs::BenchReport;
use hae_serve::workload::RequestBuilder;

fn main() -> anyhow::Result<()> {
    let steps = bench_n(200);
    let mut report = BenchReport::new("decode");
    report.config("steps", steps);
    let rt = match load_runtime() {
        Ok(rt) => rt,
        Err(_) => {
            // no artifacts: fall back to the runtime-free host-side slice
            // of the decode step (lane sync), so this bench still leaves
            // a schema-valid report instead of exiting empty-handed
            skip_or_fail(
                "artifacts not built (run `make artifacts`) — \
                 PJRT decode breakdown; reporting host-side lane sync only",
            );
            report.config("mode", "host-only");
            let s = measure_lane_sync(512, steps.max(50));
            report.metric("lane_sync_full_us_per_step", s.full_us_per_step, "us");
            report.metric("lane_sync_incr_us_per_step", s.incr_us_per_step, "us");
            report.metric(
                "lane_sync_incr_pages_per_step",
                s.incr_pages_per_step,
                "pages",
            );
            let path = report.write().expect("write BENCH_decode.json");
            println!("bench report: {}", path.display());
            return Ok(());
        }
    };
    report.config("mode", "pjrt");
    let meta = rt.meta().clone();
    let caps = rt.manifest.shapes.decode_capacities.clone();
    let batches = rt.manifest.shapes.decode_batches.clone();
    let grammar = load_grammar(&artifact_dir());
    drop(rt);

    let mut table = Table::new(
        &format!("decode step breakdown ({} steps per cell)", steps),
        &["batch", "capacity", "assemble µs", "upload µs", "execute µs",
          "download µs", "host-post µs", "step µs", "tok/s"],
    );

    for &b in &batches {
        for &c in &caps {
            let mut engine = engine_for(PolicyKind::Full, b, false)?;
            engine.warmup()?;
            // build b requests whose caches sit just under capacity bucket c
            let prev_cap = caps.iter().filter(|&&x| x < c).max().copied().unwrap_or(0);
            let target_len = (prev_cap + c) / 2; // mid-bucket
            let mut builder = RequestBuilder::new(&meta, &grammar, 4242);
            let mut lanes = Vec::new();
            for _ in 0..b {
                let mut req = builder.story(3, 12, 500);
                req.min_new_tokens = 480;
                let mut ar = engine.prefill(req)?;
                // grow the cache to the target length
                while ar.slab.len() < target_len && !ar.done {
                    let mut ls = [&mut ar];
                    engine.decode_step(&mut ls)?;
                }
                lanes.push(ar);
            }
            // measure steady-state steps, evicting back to target each step
            // so the bucket stays fixed
            let mut assemble = 0.0;
            let mut upload = 0.0;
            let mut execute = 0.0;
            let mut download = 0.0;
            let mut host_post = 0.0;
            let t_all = Instant::now();
            let mut done_steps = 0;
            for _ in 0..steps {
                for ar in lanes.iter_mut() {
                    if ar.slab.len() > target_len {
                        let extra: Vec<usize> =
                            (0..ar.slab.len() - target_len).collect();
                        ar.slab.evict(&extra);
                    }
                    ar.done = false;
                }
                let mut refs: Vec<&mut _> = lanes.iter_mut().collect();
                let t0 = Instant::now();
                let rep = engine.decode_step(&mut refs)?;
                let step_total = t0.elapsed().as_secs_f64();
                // StepReport: coord_s covers assemble+post; timing covers PJRT
                assemble += rep.coord_s; // assembly + host post-processing
                let (u, e, d) = engine.last_timing();
                upload += u;
                execute += e;
                download += d;
                host_post += step_total - rep.coord_s - (u + e + d);
                done_steps += 1;
            }
            let wall = t_all.elapsed().as_secs_f64();
            let n = done_steps as f64;
            report.metric(
                &format!("step_us_b{}_c{}", b, c),
                wall / n * 1e6,
                "us",
            );
            report.metric(
                &format!("tok_s_b{}_c{}", b, c),
                (b as f64) * n / wall,
                "tok/s",
            );
            table.row(vec![
                format!("{}", b),
                format!("{}", c),
                format!("{:.0}", assemble / n * 1e6),
                format!("{:.0}", upload / n * 1e6),
                format!("{:.0}", execute / n * 1e6),
                format!("{:.0}", download / n * 1e6),
                format!("{:.0}", host_post / n * 1e6),
                format!("{:.0}", wall / n * 1e6),
                format!("{:.0}", (b as f64) * n / wall),
            ]);
        }
    }
    table.print();
    let path = report.write().expect("write BENCH_decode.json");
    println!("\nbench report: {}", path.display());
    Ok(())
}
