//! §Perf — paged KV arena microbenchmarks (no PJRT required).
//!
//! Measures the three host-side primitives the serving hot path leans
//! on: page alloc/free churn (admission + retirement), the full lane
//! gather (cold sync after a lane/capacity change), and the incremental
//! dirty-page gather (steady-state decode). The headline claim: at
//! steady state the per-step copy cost is O(dirty pages) ≈ 1 page,
//! independent of the live cache length.

use std::time::Instant;

use hae_serve::cache::PagePool;
use hae_serve::harness::{bench_n, f2, measure_lane_sync, Table};
use hae_serve::obs::BenchReport;

/// Alloc-all / free-all churn over a fixed arena.
fn alloc_free(table: &mut Table, report: &mut BenchReport, iters: usize) {
    let n_pages = 1024;
    let mut pool = PagePool::new(2, 64, n_pages, 16);
    let mut held = Vec::with_capacity(n_pages);
    let t0 = Instant::now();
    for _ in 0..iters {
        while let Some(p) = pool.alloc() {
            held.push(p);
        }
        for p in held.drain(..) {
            pool.release(p);
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let s = pool.stats();
    let ops = s.allocs + s.frees;
    report.metric("alloc_free_mops", ops as f64 / dt / 1e6, "Mops/s");
    report.metric(
        "page_reuse_frac",
        s.reused as f64 / s.allocs.max(1) as f64,
        "fraction",
    );
    table.row(vec![
        "alloc/free churn".into(),
        format!("{}", ops),
        f2(ops as f64 / dt / 1e6),
        "-".into(),
        format!("{:.1}%", 100.0 * s.reused as f64 / s.allocs.max(1) as f64),
    ]);
}

/// Lane gather: full resync vs steady-state incremental sync (the shared
/// harness measurement; perf_serve_batch sweeps it over live lengths).
fn gather(table: &mut Table, report: &mut BenchReport, iters: usize) {
    let s = measure_lane_sync(1024, iters);
    let full_bytes = s.pages as f64 * s.page_bytes as f64;
    report.metric(
        "gather_full_gbs",
        full_bytes / (s.full_us_per_step * 1e-6) / 1e9,
        "GB/s",
    );
    report.metric("gather_incr_us_per_step", s.incr_us_per_step, "us");
    report.metric("gather_incr_pages_per_step", s.incr_pages_per_step, "pages");
    table.row(vec![
        "gather full".into(),
        format!("{}", iters),
        "-".into(),
        format!("{}", s.pages),
        f2(full_bytes / (s.full_us_per_step * 1e-6) / 1e9),
    ]);
    table.row(vec![
        "gather incremental".into(),
        format!("{}", iters),
        "-".into(),
        f2(s.incr_pages_per_step),
        f2(s.incr_pages_per_step * s.page_bytes as f64 / (s.incr_us_per_step * 1e-6) / 1e9),
    ]);
    println!(
        "\n(live cache {} slots over {} pages: the incremental gather moves ~1\n\
         page per steady-state step; the full gather moves all of them)",
        s.live_slots, s.pages
    );
}

fn main() {
    let iters = bench_n(200);
    let mut report = BenchReport::new("page_pool");
    report.config("iters", iters);
    let mut table = Table::new(
        &format!("page-pool primitives, {} iterations", iters),
        &["primitive", "ops", "Mops/s", "pages/step", "GB/s | reuse"],
    );
    alloc_free(&mut table, &mut report, iters);
    gather(&mut table, &mut report, iters);
    table.print();
    let path = report.write().expect("write BENCH_page_pool.json");
    println!("\nbench report: {}", path.display());
}
