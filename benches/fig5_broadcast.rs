//! Paper Fig. 5 (+ §4.4) — broadcast coverage: do layer-0 DAP eviction
//! decisions coincide with per-layer decisions?
//!
//! For a sweep of r thresholds, computes each layer's own DAP evict set
//! from that layer's column statistics and reports
//! |evict₀ ∩ evict_l| / |evict₀|. The paper finds ≥80–90% coverage at the
//! chosen threshold, justifying index broadcasting.

use hae_serve::cache::hae::Hae;
use hae_serve::harness::*;
use hae_serve::model::vocab;
use hae_serve::workload::{RequestBuilder, WorkloadKind};

fn main() -> anyhow::Result<()> {
    let n = bench_n(30);
    let rt = load_runtime()?;
    let meta = rt.meta().clone();
    let grammar = load_grammar(&artifact_dir());
    let mut builder = RequestBuilder::new(&meta, &grammar, 707);

    let bucket = *rt.manifest.shapes.analysis_buckets.first().unwrap();
    // r sweep around the calibrated default (uniform share = 1/16); the
    // paper sweeps 0.001/0.0012/0.0015/0.002 around its 576-token share.
    let r_values = [0.04f32, 0.05, 0.0625, 0.08];
    let alpha = 0.1f32;

    // coverage[r][layer] accumulators
    let mut cov = vec![vec![0.0f64; meta.n_layers]; r_values.len()];
    let mut cov_n = vec![vec![0usize; meta.n_layers]; r_values.len()];

    for i in 0..n {
        let kind = if i % 2 == 0 { WorkloadKind::Understanding } else { WorkloadKind::Mixed };
        let req = builder.make(kind);
        if req.prompt_len() > bucket {
            continue;
        }
        let mut ids = req.ids.clone();
        ids.resize(bucket, vocab::PAD);
        let mut patches = req.patches.clone();
        patches.resize(bucket * meta.patch_dim, 0.0);
        let mut isv: Vec<f32> =
            req.is_vision.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect();
        isv.resize(bucket, 0.0);
        let (out, _) = rt.analysis(bucket, &ids, &patches, &isv, req.prompt_len())?;
        let mut is_vision = req.is_vision.clone();
        is_vision.resize(bucket, false);

        for (ri, &r) in r_values.iter().enumerate() {
            let evict0: std::collections::BTreeSet<usize> = Hae::dap_evict_set(
                out.layer_colsum(0),
                out.layer_colmax(0),
                &is_vision,
                req.prompt_len(),
                r,
                alpha,
                None,
            )
            .into_iter()
            .collect();
            if evict0.is_empty() {
                continue;
            }
            for l in 0..meta.n_layers {
                let evict_l: std::collections::BTreeSet<usize> = Hae::dap_evict_set(
                    out.layer_colsum(l),
                    out.layer_colmax(l),
                    &is_vision,
                    req.prompt_len(),
                    r,
                    alpha,
                    None,
                )
                .into_iter()
                .collect();
                let inter = evict0.intersection(&evict_l).count();
                cov[ri][l] += inter as f64 / evict0.len() as f64;
                cov_n[ri][l] += 1;
            }
        }
    }

    let mut headers: Vec<String> = vec!["r".to_string()];
    headers.extend((0..meta.n_layers).map(|l| format!("layer {}", l)));
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        &format!("Fig. 5 — layer-0 eviction coverage at other layers ({} samples)", n),
        &header_refs,
    );
    for (ri, &r) in r_values.iter().enumerate() {
        let mut row = vec![format!("{}", r)];
        for l in 0..meta.n_layers {
            let c = if cov_n[ri][l] == 0 { 0.0 } else { cov[ri][l] / cov_n[ri][l] as f64 };
            row.push(pct(c));
        }
        table.row(row);
    }
    table.print();
    println!("\npaper shape: coverage ≥80% at every layer for well-chosen r \
              (paper: 90.43% average at its best threshold) — broadcasting \
              layer-0 indices is safe. Layer 0 column is 100% by definition.");
    Ok(())
}
