//! §Perf — serving throughput under concurrency: the continuous-batching
//! scheduler vs the serial path, HAE vs Full Cache.
//!
//! For policy ∈ {hae, full} × batch ∈ {1 (serial), widest compiled} ×
//! clients ∈ {1, 4, 8}: spin up a fresh server, drive `clients`
//! concurrent connections each issuing `HAE_BENCH_N` (default 6)
//! requests, and report requests/sec, p50/p95 latency, the widest batch
//! any decode step actually ran at, and peak aggregate live KV. The
//! batch=1 rows reproduce the seed's serial `engine.generate()` behaviour
//! (one lane, one request at a time); the batch>1 rows show eviction
//! converting into admission headroom and throughput.
//!
//! Also runs a runtime-free **lane-sync comparison** first: the per-step
//! host copy of one decode lane under (a) the old regime — the whole
//! live region re-copied every step — vs (b) the paged arena's
//! dirty-page incremental gather. Steady-state decode copies O(dirty
//! pages), not O(live slots).
//!
//! Closes with a **shared-image client mix** (8 clients, 1 image,
//! prefix cache on vs off): admitted-batch width and TTFT with the
//! radix-tree prefix cache serving repeat questions from pinned pages.
//!
//! Also runtime-free: the **tracing-overhead guardrail** — steady-state
//! decode throughput with observability on vs off must stay within 2%.

use std::sync::mpsc;
use std::time::Instant;

use hae_serve::cache::{KvSlab, Modality, PagePool, PolicyKind};
use hae_serve::harness::*;
use hae_serve::obs::{BenchReport, Obs, SharedObs, TraceEvent};
use hae_serve::scheduler::SchedPolicy;
use hae_serve::server::client_request;
use hae_serve::util::json::Json;
use hae_serve::util::stats::percentiles;

/// Drive `clients` concurrent connections, each sending `per_client`
/// requests built by `payload(client, i)`; returns (wall, latencies,
/// errors).
fn drive_with<F>(
    addr: &str,
    clients: usize,
    per_client: usize,
    payload: F,
) -> (f64, Vec<f64>, usize)
where
    F: Fn(usize, usize) -> String + Clone + Send + 'static,
{
    let (tx, rx) = mpsc::channel();
    let t0 = Instant::now();
    for c in 0..clients {
        let tx = tx.clone();
        let addr = addr.to_string();
        let payload = payload.clone();
        std::thread::spawn(move || {
            for i in 0..per_client {
                let line = payload(c, i);
                let t = Instant::now();
                let resp = client_request(&addr, &line).unwrap_or_default();
                let ok = Json::parse(&resp)
                    .map(|j| j.get("error").is_none())
                    .unwrap_or(false);
                tx.send((t.elapsed().as_secs_f64(), ok)).unwrap();
            }
        });
    }
    drop(tx);
    let mut lats = Vec::new();
    let mut errors = 0usize;
    for (lat, ok) in rx {
        lats.push(lat);
        if !ok {
            errors += 1;
        }
    }
    (t0.elapsed().as_secs_f64(), lats, errors)
}

/// The mixed-kind client workload of the main throughput table.
fn drive(addr: &str, clients: usize, per_client: usize) -> (f64, Vec<f64>, usize) {
    drive_with(addr, clients, per_client, |c, i| {
        let kind = match (c + i) % 3 {
            0 => "qa",
            1 => "mixed",
            _ => "story",
        };
        format!(r#"{{"id": {}, "kind": "{}", "max_new": 32}}"#, c * 1000 + i, kind)
    })
}

/// Paged-vs-copy lane sync: per-step host copy cost at several live
/// cache lengths, full resync (the pre-arena behaviour: O(live slots)
/// every step) vs incremental dirty-page gather (O(dirty pages)).
/// Runtime-free — runs even without artifacts.
fn lane_sync_comparison(report: &mut BenchReport, steps: usize) {
    let mut table = Table::new(
        &format!("lane sync per decode step, {} steps", steps),
        &["live slots", "pages", "full µs/step", "incr µs/step", "incr pages/step"],
    );
    for &len in &[128usize, 512, 1024] {
        let s = measure_lane_sync(len, steps);
        if len == 1024 {
            report.metric("lane_sync_full_us_per_step", s.full_us_per_step, "us");
            report.metric("lane_sync_incr_us_per_step", s.incr_us_per_step, "us");
        }
        table.row(vec![
            format!("{}", s.live_slots),
            format!("{}", s.pages),
            format!("{:.1}", s.full_us_per_step),
            format!("{:.1}", s.incr_us_per_step),
            f2(s.incr_pages_per_step),
        ]);
    }
    table.print();
    println!(
        "\n(full µs/step grows with the live length; incremental stays flat at\n\
         ~1 page/step — the arena makes the host copy cost page-incremental)"
    );
}

/// One steady-state decode loop over the synthetic arena — the same
/// per-step host work as `measure_lane_sync`'s incremental phase — with
/// the per-step observability sequence the engine and scheduler perform
/// spliced in: one enabled check, histogram records (including the
/// profiler's step-section and queue-depth spans), one trace event per
/// lane. Returns steps/sec.
fn traced_decode_steps_per_sec(obs: &SharedObs, lanes: usize, steps: usize) -> f64 {
    let (n_layers, row, ps) = (4usize, 128usize, 16usize);
    let live = 256usize;
    let cap = live + steps + 1;
    let pool = PagePool::new_shared(n_layers, row, cap.div_ceil(ps) + 1, ps);
    let token_row = vec![0.5f32; n_layers * row];
    let mut slab = KvSlab::in_pool(&pool, cap);
    for i in 0..live {
        slab.append(&token_row, &token_row, i as i32, Modality::Text, 0.0);
    }
    let mut dst_k = vec![0.0f32; 2 * n_layers * cap * row];
    let mut dst_v = dst_k.clone();
    slab.copy_into_lane(&mut dst_k, &mut dst_v, 0, cap); // prime
    let t0 = Instant::now();
    for i in 0..steps {
        slab.append(
            &token_row,
            &token_row,
            (live + i) as i32,
            Modality::Text,
            0.0,
        );
        slab.copy_into_lane(&mut dst_k, &mut dst_v, 0, cap);
        if obs.enabled() {
            obs.record(|o| {
                o.decode_step_ms.record(0.2);
                o.profile.step_finish_ms.record(0.2);
                o.profile.device_queue_depth.record(1.0);
            });
        }
        for lane in 0..lanes {
            obs.event(lane as u64, TraceEvent::DecodeStep);
        }
    }
    steps as f64 / t0.elapsed().as_secs_f64()
}

/// Tracing-overhead guardrail (runtime-free): steady-state decode
/// throughput with observability enabled must stay within 2% of
/// disabled. Best-of-trials per mode, alternating, so scheduler noise
/// cannot fail the ratio — only a real per-step cost can.
fn tracing_overhead_guardrail(report: &mut BenchReport, steps: usize) {
    let steps = steps.max(500);
    let lanes = 8;
    let trials = 5;
    let mut best_off = 0.0f64;
    let mut best_on = 0.0f64;
    for _ in 0..trials {
        best_off = best_off.max(traced_decode_steps_per_sec(&Obs::shared(false), lanes, steps));
        best_on = best_on.max(traced_decode_steps_per_sec(&Obs::shared(true), lanes, steps));
    }
    let ratio = best_on / best_off;
    println!(
        "\n## tracing overhead guardrail\n\
         decode steps/s: tracing off {:.0}, on {:.0} — ratio {:.4} \
         (floor 0.98)",
        best_off, best_on, ratio
    );
    report.metric("tracing_overhead_ratio", ratio, "on/off");
    assert!(
        ratio >= 0.98,
        "tracing-on decode throughput is {:.1}% of tracing-off \
         ({:.0} vs {:.0} steps/s) — the <2% overhead guardrail failed",
        ratio * 100.0,
        best_on,
        best_off
    );
}

/// Drive a fixed story workload and count the tokens actually decoded;
/// returns (wall, total tokens, errors). Token-level throughput is what
/// the pipeline comparison needs — req/s hides generation length.
fn drive_story_tokens(addr: &str, clients: usize, per_client: usize) -> (f64, usize, usize) {
    let (tx, rx) = mpsc::channel();
    let t0 = Instant::now();
    for c in 0..clients {
        let tx = tx.clone();
        let addr = addr.to_string();
        std::thread::spawn(move || {
            for i in 0..per_client {
                let line = format!(
                    r#"{{"id": {}, "kind": "story", "max_new": 32}}"#,
                    c * 1000 + i
                );
                let resp = client_request(&addr, &line).unwrap_or_default();
                let toks = Json::parse(&resp)
                    .ok()
                    .and_then(|j| {
                        j.get("tokens").and_then(|v| v.as_arr()).map(|a| a.len())
                    })
                    .unwrap_or(0);
                tx.send(toks).unwrap();
            }
        });
    }
    drop(tx);
    let mut tokens = 0usize;
    let mut errors = 0usize;
    for t in rx {
        if t == 0 {
            errors += 1;
        }
        tokens += t;
    }
    (t0.elapsed().as_secs_f64(), tokens, errors)
}

/// Single-thread vs pipelined serve loop, captured in the SAME run over
/// the SAME workload: decode token throughput, TTFT, and the pipelined
/// loop's measured host/device overlap fraction. Best-of-trials per
/// mode, alternating, so a scheduler hiccup in one trial cannot decide
/// the comparison.
fn pipeline_comparison(report: &mut BenchReport, per_client: usize, widest: usize) {
    let clients = 4usize;
    let trials = 3usize;
    // per mode: (tok/s, ttft p50 ms, overlap frac)
    let mut best: [Option<(f64, f64, f64)>; 2] = [None, None];
    for _ in 0..trials {
        for (mode, &threads) in [1usize, 2].iter().enumerate() {
            let (handle, addr) = spawn_server(
                PolicyKind::parse("hae").unwrap(),
                widest,
                None,
                SchedPolicy::Fifo,
                true,
                threads,
            );
            assert!(wait_listening(&addr), "server on {}", addr);
            let (wall, tokens, errors) = drive_story_tokens(&addr, clients, per_client);
            let stats = client_request(&addr, r#"{"kind": "stats"}"#)
                .ok()
                .and_then(|r| Json::parse(&r).ok());
            let _ = client_request(&addr, "shutdown");
            let _ = handle.join();
            assert_eq!(errors, 0, "pipeline comparison saw failed requests");
            let g = |k: &str| {
                stats
                    .as_ref()
                    .and_then(|j| j.get(k))
                    .and_then(|v| v.as_f64())
                    .unwrap_or(0.0)
            };
            let sample =
                (tokens as f64 / wall, g("ttft_p50_ms"), g("host_device_overlap_frac"));
            if best[mode].map_or(true, |b| sample.0 > b.0) {
                best[mode] = Some(sample);
            }
        }
    }
    let single = best[0].expect("single-thread trials ran");
    let pipe = best[1].expect("pipelined trials ran");

    let mut table = Table::new(
        &format!(
            "serve loop pipeline: {} clients × {} story requests, batch {}",
            clients, per_client, widest
        ),
        &["engine threads", "decode tok/s", "ttft p50 ms", "overlap frac"],
    );
    table.row(vec!["1 (sequential)".into(), f2(single.0), f2(single.1), f3(single.2)]);
    table.row(vec!["2 (pipelined)".into(), f2(pipe.0), f2(pipe.1), f3(pipe.2)]);
    table.print();
    println!(
        "\n(overlap frac = mean fraction of each device window the scheduler\n\
         spent on host work — reply delivery, ingest, lane backfill; the\n\
         sequential loop honestly measures ~0)"
    );

    report.metric("decode_tok_s_single_thread", single.0, "tok/s");
    report.metric("decode_tok_s_pipelined", pipe.0, "tok/s");
    report.metric("ttft_p50_ms_single_thread", single.1, "ms");
    report.metric("ttft_p50_ms_pipelined", pipe.1, "ms");
    report.metric("host_device_overlap_frac", pipe.2, "frac");

    assert!(
        (0.0..=1.0).contains(&pipe.2),
        "overlap fraction out of range: {}",
        pipe.2
    );
    // acceptance: pipelining must not cost decode throughput (best-of-
    // trials; the 3% allowance absorbs single-core CI timer noise, not a
    // real regression — a serialization bug costs far more than 3%)
    assert!(
        pipe.0 >= single.0 * 0.97,
        "pipelined decode throughput fell below the single-thread baseline: \
         {:.1} vs {:.1} tok/s",
        pipe.0,
        single.0
    );
}

/// Drive `clients` connections all asking questions about ONE image
/// (`image_seed` fixed, color/shape alternating): the prefix cache's
/// target pattern. Returns (wall, latencies, errors).
fn drive_shared_image(addr: &str, clients: usize, per_client: usize) -> (f64, Vec<f64>, usize) {
    drive_with(addr, clients, per_client, |c, i| {
        let q = if (c + i) % 2 == 0 { "color" } else { "shape" };
        format!(
            r#"{{"id": {}, "kind": "qa", "image_seed": 1, "q": "{}"}}"#,
            c * 1000 + i,
            q
        )
    })
}

/// Shared-image client mix: 8 clients, 1 image, prefix cache on vs off —
/// the admitted-batch width and TTFT show sharing turning into admission
/// headroom (shared pages are charged once against the KV budget).
fn shared_image_mix(per_client: usize, widest: usize) {
    let mut table = Table::new(
        &format!("shared-image mix: 8 clients × {} questions, 1 image", per_client),
        &["prefix cache", "req/s", "ttft p50 ms", "p50 ms", "max lanes",
          "hit rate", "prefill tok skipped", "errors"],
    );
    for &cache_on in &[false, true] {
        // port 0: the OS hands out a free port, so parallel bench/test
        // binaries never collide on a hard-coded one
        let (handle, addr) = spawn_server(
            PolicyKind::parse("hae").unwrap(),
            widest,
            None,
            SchedPolicy::Fifo,
            cache_on,
            2,
        );
        assert!(wait_listening(&addr), "server on {}", addr);
        let (wall, lats, errors) = drive_shared_image(&addr, 8, per_client);
        let stats = client_request(&addr, r#"{"kind": "stats"}"#)
            .ok()
            .and_then(|r| Json::parse(&r).ok());
        let _ = client_request(&addr, "shutdown");
        let _ = handle.join();
        let g = |k: &str| {
            stats
                .as_ref()
                .and_then(|j| j.get(k))
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0)
        };
        table.row(vec![
            if cache_on { "on" } else { "off" }.into(),
            f2(lats.len() as f64 / wall),
            format!("{:.1}", g("ttft_p50_ms")),
            format!("{:.0}", percentiles(&lats, &[0.5])[0] * 1000.0),
            format!("{:.0}", g("max_lanes_step")),
            format!("{:.0}%", 100.0 * g("prefix_hit_rate")),
            format!("{:.0}", g("prefill_tokens_skipped")),
            format!("{}", errors),
        ]);
    }
    table.print();
    println!(
        "\n(every client asks about the same image: with the cache on, one\n\
         retained visual prefix serves all of them — warm TTFT drops to the\n\
         host-only path and the charged-once pages widen admission)"
    );
}

fn main() -> anyhow::Result<()> {
    let per_client = bench_n(6);
    let mut report = BenchReport::new("serve_batch");
    report.config("per_client", per_client);
    lane_sync_comparison(&mut report, bench_n(6) * 50);
    tracing_overhead_guardrail(&mut report, bench_n(6) * 100);
    if load_runtime().is_err() {
        eprintln!(
            "artifacts not built (run `make artifacts`) — skipping the\n\
             server throughput section"
        );
        let path = report.write().expect("write BENCH_serve_batch.json");
        println!("\nbench report: {}", path.display());
        return Ok(());
    }
    let widest = widest_batch();
    // the serve sections (throughput table, shared-image mix, pipeline
    // comparison) all run the 2-thread engine core
    report.engine_threads(2);
    let batches: Vec<usize> = if widest > 1 { vec![1, widest] } else { vec![1] };

    let mut table = Table::new(
        &format!("serve throughput, {} requests per client", per_client),
        &["policy", "batch", "clients", "req/s", "p50 ms", "p95 ms",
          "max lanes", "peak KV KiB", "errors"],
    );

    for policy_spec in ["hae", "full"] {
        for &batch in &batches {
            for &clients in &[1usize, 4, 8] {
                let policy = PolicyKind::parse(policy_spec).unwrap();
                let (handle, addr) =
                    spawn_server(policy, batch, None, SchedPolicy::Fifo, true, 2);
                assert!(wait_listening(&addr), "server on {}", addr);
                let (wall, lats, errors) = drive(&addr, clients, per_client);
                let stats = client_request(&addr, r#"{"kind": "stats"}"#)
                    .ok()
                    .and_then(|r| Json::parse(&r).ok());
                let _ = client_request(&addr, "shutdown");
                let _ = handle.join();

                let g = |k: &str| {
                    stats
                        .as_ref()
                        .and_then(|j| j.get(k))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(0.0)
                };
                let ps = percentiles(&lats, &[0.5, 0.95]);
                if clients == 8 {
                    report.metric(
                        &format!("req_s_{}_b{}_c8", policy_spec, batch),
                        lats.len() as f64 / wall,
                        "req/s",
                    );
                }
                table.row(vec![
                    policy_spec.into(),
                    format!("{}", batch),
                    format!("{}", clients),
                    f2(lats.len() as f64 / wall),
                    format!("{:.0}", ps[0] * 1000.0),
                    format!("{:.0}", ps[1] * 1000.0),
                    format!("{:.0}", g("max_lanes_step")),
                    format!("{:.0}", g("peak_live_kv_bytes") / 1024.0),
                    format!("{}", errors),
                ]);
            }
        }
    }
    table.print();
    println!(
        "\n(batch=1 rows are the serial seed path; batch={} rows share lanes\n\
         via the scheduler — compare req/s at 8 clients, and peak KV for\n\
         hae vs full to see eviction becoming admission headroom)",
        widest
    );
    shared_image_mix(per_client, widest);
    // engine sections ran: bench_verify requires the pipeline-comparison
    // keys exactly when this flag is present
    report.config("engine_sections", "true");
    pipeline_comparison(&mut report, per_client, widest);
    let path = report.write().expect("write BENCH_serve_batch.json");
    println!("\nbench report: {}", path.display());
    Ok(())
}
