# Entry points shared verbatim by CI (.github/workflows/ci.yml) and
# local use, so the two invocations cannot drift.
#
#   make artifacts     — AOT-build the JAX artifacts into ./artifacts
#                        (the directory runtime/mod.rs and the test
#                        harness look in; $HAE_ARTIFACTS overrides).
#                        HAE_SMALL_ARTIFACTS=1 builds the trimmed CI
#                        bucket grid; HAE_TRAIN_STEPS overrides the
#                        training length. Needs python with jax + numpy
#                        (CI: pip install "jax[cpu]" numpy).
#   make test          — the tier-1 suite. With artifacts present the
#                        artifact-gated e2e suites run for real;
#                        HAE_REQUIRE_ARTIFACTS=1 (CI) turns any
#                        would-be skip into a failure.
#   make bench-smoke   — the five assertion-bearing perf benches
#                        (prefix cache byte-identity, page-pool ops,
#                        decode primitives, serve-batch + tracing
#                        overhead guardrail, router affinity-vs-
#                        round-robin routing). HAE_BENCH_N scales
#                        samples. Each bench leaves a machine-readable
#                        BENCH_<name>.json report (HAE_BENCH_DIR
#                        overrides the destination).
#   make bench-verify  — schema-check the BENCH_*.json reports and
#                        require at least HAE_BENCH_MIN (default 5).
#   make bench-trend   — append the current BENCH_*.json run to the
#                        trend history (benches/trend/data.json) and
#                        gate headline metrics against the committed
#                        baseline reports in benches/baseline/: exits
#                        non-zero when one regresses beyond
#                        HAE_TREND_THRESHOLD (default 0.10 relative).
#                        Refresh procedure in docs/OBSERVABILITY.md.
#   make lint-hae      — run the project invariant checker over the
#                        tree: lock-order (R1), refcount pairing (R2),
#                        forbidden APIs (R3) and metric/doc drift (R4).
#                        Rule catalog in docs/STATIC_ANALYSIS.md.
#   make stress        — repeat the threaded e2e suites (scheduler_e2e,
#                        server_e2e, router_e2e) HAE_STRESS_N times
#                        (default 10)
#                        with a high in-process test-thread count, to
#                        shake out thread-interleaving bugs a single
#                        green run can miss (docs/CONCURRENCY.md).
#                        Artifact-gated tests self-skip without
#                        ./artifacts; build those first (or set
#                        HAE_REQUIRE_ARTIFACTS=1) for full coverage.

PYTHON ?= python3
HAE_STRESS_N ?= 10

.PHONY: artifacts check-extend test bench-smoke bench-verify bench-trend lint-hae stress

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

# numeric equivalence of the chunked extend graph vs prefill/decode
# (random weights, no artifacts needed — a build-time sanity gate)
check-extend:
	cd python && $(PYTHON) -m compile.check_extend

test:
	cargo test -q

bench-smoke:
	cargo bench --bench perf_prefix_cache
	cargo bench --bench perf_page_pool
	cargo bench --bench perf_decode
	cargo bench --bench perf_serve_batch
	cargo bench --bench perf_router

stress:
	@for i in $$(seq 1 $(HAE_STRESS_N)); do \
		echo "=== stress round $$i/$(HAE_STRESS_N) ==="; \
		cargo test -q --test scheduler_e2e --test server_e2e \
			--test router_e2e -- --test-threads 8 || exit 1; \
	done

bench-verify:
	cargo run --release --bin bench_verify

bench-trend:
	cargo run --release --bin bench_trend

lint-hae:
	cargo run --release --bin hae_lint
