//! Quickstart: load the AOT artifacts, serve one multimodal QA request and
//! one story request under HAE, and print what happened.
//!
//!     make artifacts && cargo run --release --offline --example quickstart

use anyhow::Result;
use hae_serve::cache::PolicyKind;
use hae_serve::coordinator::{Engine, EngineConfig};
use hae_serve::model::vocab;
use hae_serve::workload::{RequestBuilder, StoryGrammar, WorkloadKind};

fn main() -> Result<()> {
    let artifact_dir = std::path::Path::new("artifacts");
    let cfg = EngineConfig { policy: PolicyKind::hae_default(), ..EngineConfig::default() };
    let mut engine = Engine::from_artifact_dir(artifact_dir, cfg)?;
    println!(
        "loaded TinyMM: {} layers, d_model {}, vocab {} ({} weights)",
        engine.meta().n_layers,
        engine.meta().d_model,
        engine.meta().vocab,
        engine.manifest().weights.len()
    );

    let grammar = StoryGrammar::load(artifact_dir).unwrap_or_else(|_| StoryGrammar::uniform());
    let meta = engine.meta().clone();
    let mut builder = RequestBuilder::new(&meta, &grammar, 42);
    let qa = builder.make(WorkloadKind::Understanding);
    let story = builder.story(3, 12, 64);

    println!("\n=== understanding request ===");
    let expected = qa.expected_answer.unwrap();
    let done = engine.generate(qa)?;
    println!(
        "prompt {} tokens ({} vision) → pruned {} at prefill (DAP)",
        done.stats.prompt_tokens, done.stats.vision_tokens, done.stats.pruned_at_prefill
    );
    // generated[0] is the ANS_MARK scaffold token; [1] is the answer,
    // produced through the DAP-pruned cache
    let answer = done.generated.get(1).copied().unwrap_or(vocab::PAD);
    println!(
        "model answered '{}' (expected '{}') — {}",
        vocab::describe(answer),
        vocab::describe(expected),
        if answer == expected { "CORRECT" } else { "wrong" }
    );

    println!("\n=== story request ===");
    let done = engine.generate(story)?;
    let text: Vec<String> = done.generated.iter().map(|&t| vocab::describe(t)).collect();
    println!(
        "generated {} tokens in {:.3}s prefill + {:.3}s decode ({} decode evictions, peak KV {} KiB)",
        done.generated.len(),
        done.stats.prefill_s,
        done.stats.decode_s,
        done.stats.evicted_at_decode,
        done.stats.peak_kv_bytes / 1024
    );
    println!("story: {}", text.join(" "));
    Ok(())
}
