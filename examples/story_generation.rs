//! Long multi-image story generation — the paper's Table 2 scenario.
//!
//! Generates story episodes under Full Cache, H2O and HAE with sampling
//! (temperature 0.7, as the paper's Table 5 configures the story task),
//! printing the rendered stories side by side with per-policy timing and
//! cache behaviour — the qualitative Figure 4 comparison plus the
//! quantitative speed story.
//!
//!     cargo run --release --offline --example story_generation

use anyhow::Result;
use hae_serve::cache::PolicyKind;
use hae_serve::coordinator::{Engine, EngineConfig};
use hae_serve::eval::quality::degeneration;
use hae_serve::harness::{artifact_dir, load_grammar};
use hae_serve::model::vocab;
use hae_serve::workload::RequestBuilder;

fn main() -> Result<()> {
    let grammar = load_grammar(&artifact_dir());

    for spec in ["full", "h2o", "hae"] {
        let mut engine = Engine::from_artifact_dir(
            &artifact_dir(),
            EngineConfig {
                policy: PolicyKind::parse(spec).unwrap(),
                temperature: 0.7,
                top_k: 8,
                seed: 9,
                ..EngineConfig::default()
            },
        )?;
        let meta = engine.meta().clone();
        engine.warmup()?;

        // same episode for all policies (same builder seed)
        let mut builder = RequestBuilder::new(&meta, &grammar, 31337);
        let req = builder.story(3, 12, 120);
        let images = req.images.clone();

        let t0 = std::time::Instant::now();
        let ar = engine.generate(req)?;
        let wall = t0.elapsed().as_secs_f64();
        let d = degeneration(&ar.generated, &images);

        println!("\n=== {} ===", engine.cfg.policy.label());
        println!(
            "{} tokens in {:.2}s ({:.0} tok/s) | pruned {} | evicted {} | \
             peak KV {} KiB | distinct-2 {:.2} | repetition {:.2} | grounding {:.0}%",
            ar.generated.len(),
            wall,
            ar.generated.len() as f64 / wall,
            ar.stats.pruned_at_prefill,
            ar.stats.evicted_at_decode,
            ar.stats.peak_kv_bytes / 1024,
            d.distinct_2,
            d.repetition_rate,
            d.grounding * 100.0,
        );
        let text: Vec<String> = ar.generated.iter().map(|&t| vocab::describe(t)).collect();
        println!("story: {}", text.join(" "));
    }
    Ok(())
}
