//! Multimodal QA under aggressive cache pressure — the paper's Table 1
//! scenario as a runnable demo.
//!
//! Sweeps DAP aggressiveness (r, α) on the image-QA workload and prints
//! accuracy vs visual tokens kept, showing the adaptive-threshold behaviour
//! that distinguishes HAE from fixed-budget pruning: the retained count
//! varies per sample, tracking how concentrated each image's information
//! actually is.
//!
//!     cargo run --release --offline --example multimodal_qa

use anyhow::Result;
use hae_serve::cache::PolicyKind;
use hae_serve::harness::{answer_accuracy, artifact_dir, engine_for, load_grammar, run_policy, Table};
use hae_serve::model::Manifest;
use hae_serve::workload::{RequestBuilder, WorkloadKind};

fn main() -> Result<()> {
    // cheap manifest read — no PJRT client needed for workload synthesis
    let meta = Manifest::load(&artifact_dir())?.model;
    let grammar = load_grammar(&artifact_dir());
    let n = 30;
    let requests =
        RequestBuilder::new(&meta, &grammar, 77).make_batch(WorkloadKind::Understanding, n);

    let mut table = Table::new(
        "DAP aggressiveness sweep — image QA",
        &["policy", "accuracy", "mean visual kept (of 16)", "min", "max"],
    );
    for spec in [
        "full",
        "hae:rrel=0.4,alpha=0.03",
        "hae:rrel=0.6,alpha=0.05",
        "hae:rrel=1.0,alpha=0.1",
        "hae:rrel=1.5,alpha=0.2",
        "fastv:ratio=0.33",
        "fastv:ratio=0.125",
    ] {
        let kind = PolicyKind::parse(spec).unwrap();
        let mut engine = engine_for(kind, 1, false)?;
        let run = run_policy(&mut engine, requests.clone())?;
        let kept: Vec<usize> = run
            .finished
            .iter()
            .map(|ar| ar.stats.vision_tokens - ar.stats.pruned_at_prefill)
            .collect();
        let mean = kept.iter().sum::<usize>() as f64 / kept.len() as f64;
        table.row(vec![
            spec.to_string(),
            format!("{:.1}%", 100.0 * answer_accuracy(&run.finished)),
            format!("{:.2}", mean),
            format!("{}", kept.iter().min().unwrap()),
            format!("{}", kept.iter().max().unwrap()),
        ]);
    }
    table.print();
    println!(
        "\nNote the min/max spread under HAE: retention adapts per image \
         (Definition 1's dynamic |V^p|), unlike FastV's fixed budget."
    );
    Ok(())
}
