//! End-to-end serving driver (the DESIGN.md §validation run): starts the
//! JSON-lines TCP server with the HAE policy, drives a mixed client
//! workload over real sockets from several concurrent client threads, and
//! reports per-request latency percentiles and aggregate throughput —
//! proving all three layers compose: rust coordinator → PJRT executables →
//! AOT-compiled JAX/Pallas graphs.
//!
//!     cargo run --release --offline --example serve_e2e
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;
use hae_serve::cache::PolicyKind;
use hae_serve::coordinator::{Engine, EngineConfig};
use hae_serve::harness::{artifact_dir, load_grammar};
use hae_serve::runtime::Runtime;
use hae_serve::server::{client_request, serve, ServerConfig};
use hae_serve::util::json::Json;
use hae_serve::util::stats::percentile;

const ADDR: &str = "127.0.0.1:8491";

fn main() -> Result<()> {
    // server thread — the PJRT client is !Send, so the engine is
    // constructed inside the thread that owns it
    let server = std::thread::spawn(move || {
        let rt = Runtime::load(&artifact_dir()).expect("artifacts built?");
        let engine = Engine::new(
            rt,
            EngineConfig { policy: PolicyKind::hae_default(), ..EngineConfig::default() },
        )
        .unwrap();
        let cfg = ServerConfig { addr: ADDR.into(), queue_depth: 64 };
        let _ = serve(engine, cfg, load_grammar(&artifact_dir()));
    });
    // wait for the listener
    for _ in 0..100 {
        if std::net::TcpStream::connect(ADDR).is_ok() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }

    let n_clients = 4;
    let per_client = 8;
    let (tx, rx) = mpsc::channel();
    let t0 = Instant::now();
    for c in 0..n_clients {
        let tx = tx.clone();
        std::thread::spawn(move || {
            for i in 0..per_client {
                let kind = match (c + i) % 3 {
                    0 => "qa",
                    1 => "mixed",
                    _ => "story",
                };
                let payload = format!(
                    r#"{{"id": {}, "kind": "{}", "max_new": 48}}"#,
                    c * 100 + i,
                    kind
                );
                let t = Instant::now();
                let resp = client_request(ADDR, &payload).unwrap_or_default();
                tx.send((t.elapsed().as_secs_f64(), resp)).unwrap();
            }
        });
    }
    drop(tx);

    let mut latencies = Vec::new();
    let mut steps = 0usize;
    let mut pruned = 0usize;
    let mut evicted = 0usize;
    let mut errors = 0usize;
    for (lat, resp) in rx {
        latencies.push(lat);
        match Json::parse(&resp) {
            Ok(j) if j.get("error").is_none() => {
                steps += j.get("steps").and_then(|v| v.as_usize()).unwrap_or(0);
                pruned += j.get("pruned").and_then(|v| v.as_usize()).unwrap_or(0);
                evicted += j.get("evicted").and_then(|v| v.as_usize()).unwrap_or(0);
            }
            _ => errors += 1,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let _ = client_request(ADDR, "shutdown");
    let _ = server.join();

    let n = latencies.len();
    println!("\n=== serve_e2e: {} requests over {} client threads ===", n, n_clients);
    println!(
        "wall {:.2}s | {:.2} req/s | {:.1} decode tok/s | errors {}",
        wall,
        n as f64 / wall,
        steps as f64 / wall,
        errors
    );
    println!(
        "latency p50 {:.0} ms | p95 {:.0} ms | max {:.0} ms",
        percentile(&latencies, 0.5) * 1000.0,
        percentile(&latencies, 0.95) * 1000.0,
        percentile(&latencies, 1.0) * 1000.0
    );
    println!(
        "HAE activity: {} prompt tokens pruned (DAP), {} cache slots evicted (DDES)",
        pruned, evicted
    );
    assert_eq!(errors, 0, "all requests must succeed");
    assert_eq!(n, n_clients * per_client);
    println!("serve_e2e OK");
    Ok(())
}
