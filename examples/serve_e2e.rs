//! End-to-end serving driver (the DESIGN.md §validation run): starts the
//! JSON-lines TCP server with the HAE policy and the continuous-batching
//! scheduler at the widest compiled batch, drives a mixed client workload
//! over real sockets from several concurrent client threads, and reports
//! per-request latency percentiles, aggregate throughput and the
//! scheduler's own metrics — proving all three layers compose: rust
//! scheduler/coordinator → PJRT executables → AOT-compiled JAX/Pallas
//! graphs.
//!
//!     cargo run --release --offline --example serve_e2e
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;
use hae_serve::cache::PolicyKind;
use hae_serve::harness::{spawn_server, wait_listening, widest_batch};
use hae_serve::scheduler::SchedPolicy;
use hae_serve::server::client_request;
use hae_serve::util::json::Json;
use hae_serve::util::stats::percentiles;

fn main() -> Result<()> {
    let batch = widest_batch();
    // port 0: the OS picks a free port, read back from the bound listener
    let (server, addr) = spawn_server(
        PolicyKind::hae_default(),
        batch,
        None,
        SchedPolicy::Priority,
        true,
        2,
    );
    assert!(wait_listening(&addr), "server came up");

    let n_clients = 4;
    let per_client = 8;
    let (tx, rx) = mpsc::channel();
    let t0 = Instant::now();
    for c in 0..n_clients {
        let tx = tx.clone();
        let addr = addr.clone();
        std::thread::spawn(move || {
            for i in 0..per_client {
                let kind = match (c + i) % 3 {
                    0 => "qa",
                    1 => "mixed",
                    _ => "story",
                };
                let payload = format!(
                    r#"{{"id": {}, "kind": "{}", "max_new": 48}}"#,
                    c * 100 + i,
                    kind
                );
                let t = Instant::now();
                let resp = client_request(&addr, &payload).unwrap_or_default();
                tx.send((t.elapsed().as_secs_f64(), resp)).unwrap();
            }
        });
    }
    drop(tx);

    let mut latencies = Vec::new();
    let mut steps = 0usize;
    let mut pruned = 0usize;
    let mut evicted = 0usize;
    let mut errors = 0usize;
    for (lat, resp) in rx {
        latencies.push(lat);
        match Json::parse(&resp) {
            Ok(j) if j.get("error").is_none() => {
                steps += j.get("steps").and_then(|v| v.as_usize()).unwrap_or(0);
                pruned += j.get("pruned").and_then(|v| v.as_usize()).unwrap_or(0);
                evicted += j.get("evicted").and_then(|v| v.as_usize()).unwrap_or(0);
            }
            _ => errors += 1,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = client_request(&addr, r#"{"kind": "stats"}"#)
        .ok()
        .and_then(|r| Json::parse(&r).ok());
    let _ = client_request(&addr, "shutdown");
    let _ = server.join();

    let n = latencies.len();
    println!("\n=== serve_e2e: {} requests over {} client threads ===", n, n_clients);
    println!(
        "wall {:.2}s | {:.2} req/s | {:.1} decode tok/s | errors {}",
        wall,
        n as f64 / wall,
        steps as f64 / wall,
        errors
    );
    let lat = percentiles(&latencies, &[0.5, 0.95, 1.0]);
    println!(
        "latency p50 {:.0} ms | p95 {:.0} ms | max {:.0} ms",
        lat[0] * 1000.0,
        lat[1] * 1000.0,
        lat[2] * 1000.0
    );
    println!(
        "HAE activity: {} prompt tokens pruned (DAP), {} cache slots evicted (DDES)",
        pruned, evicted
    );
    if let Some(st) = stats {
        let g = |k: &str| st.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        println!(
            "scheduler: batch {} | max lanes/step {:.0} | ttft p50 {:.0} ms | peak KV {:.0} KiB of {:.0} KiB budget",
            batch,
            g("max_lanes_step"),
            g("ttft_p50_ms"),
            g("peak_live_kv_bytes") / 1024.0,
            g("kv_budget") / 1024.0,
        );
    }
    assert_eq!(errors, 0, "all requests must succeed");
    assert_eq!(n, n_clients * per_client);
    println!("serve_e2e OK");
    Ok(())
}
