//! Property-based tests over the cache substrate and eviction policies
//! (DESIGN.md §6 invariants). No PJRT required.

use hae_serve::cache::policy::{DecodeCtx, EvictionPolicy, PrefillCtx};
use hae_serve::cache::{KvSlab, Modality, PagePool, PolicyKind, SlotMeta};
use hae_serve::model::ModelMeta;
use hae_serve::prefix::DapAccumulator;
use hae_serve::util::prop::{gen_modality, run_prop, PropConfig};
use hae_serve::util::rng::Rng;

fn tiny_meta() -> ModelMeta {
    ModelMeta {
        vocab: 32,
        d_model: 8,
        n_layers: 2,
        n_heads: 2,
        d_head: 4,
        d_mlp: 8,
        patch_dim: 4,
        n_patches: 4,
        max_pos: 256,
        dap_layer: 1,
    }
}

fn fill_slab(rng: &mut Rng, m: &ModelMeta, n: usize, cap: usize) -> KvSlab {
    let mut slab = KvSlab::new(m, cap);
    let row = m.n_layers * m.n_heads * m.d_head;
    for i in 0..n {
        let k: Vec<f32> = (0..row).map(|_| rng.f32()).collect();
        let v: Vec<f32> = (0..row).map(|_| rng.f32()).collect();
        let modality = if rng.bool(0.4) { Modality::Vision } else { Modality::Text };
        slab.append(&k, &v, i as i32, modality, rng.f32());
    }
    slab
}

/// Slab integrity: any eviction sequence leaves live slots equal to the
/// inserted-and-not-evicted tokens, in original order, with KV intact.
#[test]
fn prop_slab_integrity_under_random_evictions() {
    let m = tiny_meta();
    run_prop("slab-integrity", PropConfig::default(), |rng, _| {
        let n = 4 + rng.below(40);
        let cap = n + 8;
        let mut slab = fill_slab(rng, &m, n, cap);
        // tag each slot's first K element so we can track identity
        let tags: Vec<(i32, f32)> = (0..slab.len())
            .map(|i| (slab.meta()[i].position, slab.k_row(0, i)[0]))
            .collect();
        let mut alive: Vec<usize> = (0..n).collect();
        for _ in 0..3 {
            if alive.len() <= 1 {
                break;
            }
            let k = rng.below(alive.len().min(5));
            let evict_now: Vec<usize> = rng.choose_k(slab.len(), k);
            slab.evict(&evict_now);
            // mirror on the model
            let mut sorted = evict_now.clone();
            sorted.sort_unstable();
            sorted.dedup();
            for &e in sorted.iter().rev() {
                alive.remove(e);
            }
            assert_eq!(slab.len(), alive.len());
        }
        for (slot, &orig) in alive.iter().enumerate() {
            assert_eq!(slab.meta()[slot].position, tags[orig].0, "position preserved");
            assert_eq!(slab.k_row(0, slot)[0], tags[orig].1, "KV row follows slot");
        }
        // positions strictly increasing (order preserved)
        for w in slab.meta().windows(2) {
            assert!(w[0].position < w[1].position);
        }
    });
}

/// Reference contiguous slab: the dumbest possible model of the KvSlab
/// contract — one owned `[L, H, Dh]` row per live token, compacted by
/// rebuilding the vector. The paged arena must be indistinguishable
/// from it.
struct RefSlab {
    /// (k_row, v_row) per live token, each `[L * H * Dh]` layer-major
    rows: Vec<(Vec<f32>, Vec<f32>)>,
    meta: Vec<SlotMeta>,
}

impl RefSlab {
    fn new() -> Self {
        RefSlab { rows: Vec::new(), meta: Vec::new() }
    }

    fn append(&mut self, k: &[f32], v: &[f32], position: i32, modality: Modality, s: f32) {
        self.rows.push((k.to_vec(), v.to_vec()));
        self.meta.push(SlotMeta {
            position,
            modality,
            cum_score: s,
            cum_peak: s,
            last_score: s,
            marked: false,
            age: 0,
        });
    }

    fn add_scores(&mut self, mean: &[f32], peak: &[f32]) {
        for (i, m) in self.meta.iter_mut().enumerate() {
            m.cum_score += mean[i];
            m.cum_peak += peak[i];
            m.last_score = mean[i];
            m.age += 1;
        }
    }

    fn evict(&mut self, evict: &[usize]) {
        let mut drop_mask = vec![false; self.meta.len()];
        for &i in evict {
            if i < drop_mask.len() {
                drop_mask[i] = true;
            }
        }
        let keep = |i: &usize| !drop_mask[*i];
        let idx: Vec<usize> = (0..self.meta.len()).filter(keep).collect();
        self.rows = idx.iter().map(|&i| self.rows[i].clone()).collect();
        self.meta = idx.iter().map(|&i| self.meta[i]).collect();
    }

    /// Lane-0 batch buffer `[L, C, H, Dh]` with the live region filled.
    fn gather(&self, n_layers: usize, row: usize, cap_c: usize) -> (Vec<f32>, Vec<f32>) {
        let mut k = vec![0.0f32; n_layers * cap_c * row];
        let mut v = k.clone();
        for (s, (kr, vr)) in self.rows.iter().enumerate() {
            for l in 0..n_layers {
                let dst = (l * cap_c + s) * row;
                k[dst..dst + row].copy_from_slice(&kr[l * row..(l + 1) * row]);
                v[dst..dst + row].copy_from_slice(&vr[l * row..(l + 1) * row]);
            }
        }
        (k, v)
    }
}

fn assert_meta_eq(a: &SlotMeta, b: &SlotMeta, what: &str) {
    assert_eq!(a.position, b.position, "{}: position", what);
    assert_eq!(a.modality, b.modality, "{}: modality", what);
    assert_eq!(a.marked, b.marked, "{}: marked", what);
    assert_eq!(a.age, b.age, "{}: age", what);
    assert!((a.cum_score - b.cum_score).abs() < 1e-5, "{}: cum_score", what);
    assert!((a.cum_peak - b.cum_peak).abs() < 1e-5, "{}: cum_peak", what);
    assert!((a.last_score - b.last_score).abs() < 1e-5, "{}: last_score", what);
}

/// The paged slab and the contiguous reference produce byte-identical
/// lane buffers and identical metadata under randomized
/// append/evict/score/sync sequences — including mid-sequence lane syncs
/// at varying capacities, so the dirty-page incremental gather is
/// exercised against stale scratch content.
#[test]
fn prop_paged_slab_matches_contiguous_reference() {
    let m = tiny_meta();
    let row = m.n_heads * m.d_head;
    let token_row = m.n_layers * row;
    run_prop("paged-vs-reference", PropConfig { cases: 64, seed: 11 }, |rng, _| {
        let cap = 24 + rng.below(16);
        // 4-slot pages force frequent page-boundary crossings
        let pool = PagePool::new_shared(m.n_layers, row, (cap / 4) + 2, 4);
        let mut paged = KvSlab::in_pool(&pool, cap);
        let mut reference = RefSlab::new();
        // persistent scratch, as the engine keeps it across steps
        let caps = [cap, cap + 8];
        let mut dst_k = vec![0.0f32; m.n_layers * (cap + 8) * row];
        let mut dst_v = dst_k.clone();
        let mut pos = 0i32;
        for _ in 0..60 {
            match rng.below(5) {
                // append (biased: two arms)
                0 | 1 => {
                    if paged.len() < cap {
                        let k: Vec<f32> = (0..token_row).map(|_| rng.f32()).collect();
                        let v: Vec<f32> = (0..token_row).map(|_| rng.f32()).collect();
                        let md =
                            if rng.bool(0.3) { Modality::Vision } else { Modality::Text };
                        let s = rng.f32();
                        paged.append(&k, &v, pos, md, s);
                        reference.append(&k, &v, pos, md, s);
                        pos += 1;
                    }
                }
                // evict a random subset
                2 => {
                    if paged.len() > 1 {
                        let k = rng.below(paged.len().min(6));
                        let victims = rng.choose_k(paged.len(), k);
                        paged.evict(&victims);
                        reference.evict(&victims);
                    }
                }
                // score accumulation + random marking
                3 => {
                    let n = paged.len();
                    let mean: Vec<f32> = (0..n).map(|_| rng.f32() * 0.1).collect();
                    let peak: Vec<f32> = (0..n).map(|_| rng.f32() * 0.1).collect();
                    paged.add_scores(&mean, &peak);
                    reference.add_scores(&mean, &peak);
                    if n > 0 && rng.bool(0.3) {
                        let s = rng.below(n);
                        paged.meta_mut()[s].marked = true;
                        reference.meta[s].marked = true;
                    }
                }
                // mid-sequence lane sync at a random capacity (primes the
                // incremental path; correctness is checked at the end)
                _ => {
                    let c = caps[rng.below(2)];
                    paged.copy_into_lane(&mut dst_k, &mut dst_v, 0, c);
                }
            }
        }
        // final sync + compare the live region of every layer
        let c = caps[rng.below(2)];
        paged.copy_into_lane(&mut dst_k, &mut dst_v, 0, c);
        let (ref_k, ref_v) = reference.gather(m.n_layers, row, c);
        let len = paged.len();
        assert_eq!(len, reference.meta.len());
        for l in 0..m.n_layers {
            let o = l * c * row;
            let n = len * row;
            assert_eq!(
                &dst_k[o..o + n],
                &ref_k[o..o + n],
                "layer {} K live region", l
            );
            assert_eq!(
                &dst_v[o..o + n],
                &ref_v[o..o + n],
                "layer {} V live region", l
            );
        }
        for (i, (a, b)) in paged.meta().iter().zip(reference.meta.iter()).enumerate() {
            assert_meta_eq(a, b, &format!("slot {}", i));
        }
    });
}

/// Page-leak invariant over full request lifecycles: at every point,
/// `allocated − freed == live pages == Σ slab page tables`, and a fully
/// drained pool is back to zero pages in use.
#[test]
fn prop_page_pool_never_leaks_across_lifecycles() {
    let m = tiny_meta();
    let row = m.n_heads * m.d_head;
    let token_row = m.n_layers * row;
    run_prop("page-leak", PropConfig { cases: 48, seed: 13 }, |rng, _| {
        let pool = PagePool::new_shared(m.n_layers, row, 64, 4);
        let mut live: Vec<KvSlab> = Vec::new();
        let check = |pool: &hae_serve::cache::SharedPagePool, live: &[KvSlab]| {
            let p = pool.lock().unwrap();
            let s = p.stats();
            let held: usize = live.iter().map(|sl| sl.allocated_pages()).sum();
            assert_eq!(s.in_use, held, "pool in_use == Σ live page tables");
            assert_eq!(
                s.allocs - s.frees,
                s.in_use as u64,
                "allocated − freed == live pages"
            );
        };
        for _ in 0..40 {
            match rng.below(4) {
                // birth: admit a new request
                0 => {
                    if live.len() < 4 {
                        live.push(KvSlab::in_pool(&pool, 48));
                    }
                }
                // growth: decode appends
                1 => {
                    if let Some(sl) = live.last_mut() {
                        let budget = pool.lock().unwrap().free_pages() * 4;
                        let n = rng.below(6).min(budget);
                        for _ in 0..n {
                            if sl.len() < sl.capacity() {
                                let k: Vec<f32> =
                                    (0..token_row).map(|_| rng.f32()).collect();
                                sl.append(&k, &k, sl.len() as i32, Modality::Text, 0.0);
                            }
                        }
                    }
                }
                // eviction
                2 => {
                    if !live.is_empty() {
                        let i = rng.below(live.len());
                        let n = live[i].len();
                        if n > 0 {
                            let victims = rng.choose_k(n, rng.below(n.min(8)));
                            live[i].evict(&victims);
                        }
                    }
                }
                // death: retire (release) or abandon (drop)
                _ => {
                    if !live.is_empty() {
                        let i = rng.below(live.len());
                        let mut sl = live.remove(i);
                        if rng.bool(0.5) {
                            sl.release_pages();
                            assert_eq!(sl.allocated_pages(), 0);
                        }
                        drop(sl);
                    }
                }
            }
            check(&pool, &live);
        }
        live.clear();
        assert_eq!(pool.lock().unwrap().in_use_pages(), 0, "drained pool holds nothing");
    });
}

/// Copy-on-write correctness over shared prefixes: several slabs adopt
/// one donor's pages, then mutate independently (appends, evictions).
/// After every operation, each slab — and the pristine pinned image the
/// "cache" holds — reads exactly its own model's bytes: a write through
/// one page table never changes bytes read through a sibling table. At
/// teardown, dropping every sharer and unpinning returns every page
/// (the no-leak invariant extended to shared pages).
#[test]
fn prop_cow_writes_never_leak_across_sharers() {
    let m = tiny_meta();
    let row = m.n_heads * m.d_head;
    let token_row = m.n_layers * row;
    run_prop("cow-isolation", PropConfig { cases: 48, seed: 17 }, |rng, _| {
        let pool = PagePool::new_shared(m.n_layers, row, 64, 4);
        // donor: the "cold prefill" whose pages get pinned + shared
        let n0 = 4 + rng.below(16);
        let mut donor = KvSlab::in_pool(&pool, 48);
        let mut next_val = 1.0f32;
        let val_row = |v: f32| vec![v; token_row];
        for i in 0..n0 {
            donor.append(&val_row(next_val), &val_row(next_val), i as i32,
                         Modality::Text, 0.0);
            next_val += 1.0;
        }
        let pages = donor.mark_all_shared();
        let meta = donor.meta().to_vec();
        // the simulated prefix-cache pin: one extra reference per page
        {
            let mut p = pool.lock().unwrap();
            for &pg in &pages {
                assert!(p.retain_page(pg));
            }
        }
        // the pristine image the cache must preserve: (position, value)
        let frozen: Vec<(i32, f32)> =
            (0..n0).map(|i| (i as i32, donor.k_row(0, i)[0])).collect();

        // sharers adopt; every slab (donor included) mutates independently
        let mut slabs = vec![donor];
        let mut models: Vec<Vec<(i32, f32)>> = vec![frozen.clone()];
        for _ in 0..1 + rng.below(3) {
            let mut s = KvSlab::in_pool(&pool, 48);
            assert!(s.adopt_shared(&pages, meta.clone()));
            slabs.push(s);
            models.push(frozen.clone());
        }
        let mut pos = n0 as i32;
        for _ in 0..30 {
            let who = rng.below(slabs.len());
            if rng.bool(0.6) {
                if slabs[who].len() < slabs[who].capacity() {
                    slabs[who].append(&val_row(next_val), &val_row(next_val), pos,
                                      Modality::Text, 0.0);
                    models[who].push((pos, next_val));
                    next_val += 1.0;
                    pos += 1;
                }
            } else if slabs[who].len() > 1 {
                let k = rng.below(slabs[who].len().min(5));
                let victims = rng.choose_k(slabs[who].len(), k);
                slabs[who].evict(&victims);
                let mut sorted = victims.clone();
                sorted.sort_unstable();
                sorted.dedup();
                for &e in sorted.iter().rev() {
                    models[who].remove(e);
                }
            }
            // every slab still reads exactly its own bytes
            for (s, model) in slabs.iter().zip(&models) {
                assert_eq!(s.len(), model.len());
                for (slot, &(p, v)) in model.iter().enumerate() {
                    assert_eq!(s.meta()[slot].position, p, "position follows slot");
                    assert_eq!(s.k_row(0, slot)[0], v, "K row isolated");
                    assert_eq!(s.v_row(m.n_layers - 1, slot)[0], v, "V row isolated");
                }
            }
            // ...and the pinned image is untouched by any of them
            {
                let p = pool.lock().unwrap();
                for (i, &(_, v)) in frozen.iter().enumerate() {
                    let (pg, off) = (pages[i / 4], i % 4);
                    assert_eq!(
                        p.read_row(pg, off, 0, false)[0],
                        v,
                        "cache-pinned page mutated through a sharer"
                    );
                }
            }
        }
        // teardown: all sharers gone + cache unpinned → zero pages held
        drop(slabs);
        {
            let mut p = pool.lock().unwrap();
            for &pg in &pages {
                assert!(p.release(pg));
            }
        }
        let s = pool.lock().unwrap().stats();
        assert_eq!(pool.lock().unwrap().in_use_pages(), 0, "no page leaked");
        assert_eq!(s.refcount_errors, 0, "no refcount violation under CoW");
        assert_eq!(s.allocs - s.frees, 0);
    });
}

/// Every decode policy keeps the cache within the hard capacity limit and
/// only ever evicts/marks valid slots.
#[test]
fn prop_policies_respect_capacity_and_validity() {
    let m = tiny_meta();
    let specs = [
        "full", "hae:rc=6", "h2o:budget=24", "snapkv:budget=24,window=4",
        "adakv:budget=24", "mustdrop", "window:sinks=2,window=16", "random:budget=24",
    ];
    run_prop("policy-capacity", PropConfig { cases: 48, seed: 3 }, |rng, case| {
        let spec = specs[case % specs.len()];
        let mut policy = PolicyKind::parse(spec).unwrap().build();
        let cap_limit = 40;
        let prefill_len = 8 + rng.below(8);
        let mut slab = fill_slab(rng, &m, prefill_len, cap_limit + 1);
        let row = m.n_layers * m.n_heads * m.d_head;
        for step in 0..80 {
            // append one generated token
            if slab.len() >= cap_limit {
                let ctx = DecodeCtx { slab: &slab, step, prefill_len, capacity_limit: cap_limit };
                let forced = policy.capacity_fallback(&ctx, slab.len() + 1 - cap_limit);
                assert!(!forced.is_empty(), "{}: fallback must free space", spec);
                slab.evict(&forced);
            }
            let k: Vec<f32> = (0..row).map(|_| rng.f32()).collect();
            slab.append(&k, &k, (100 + step) as i32, Modality::Text, rng.f32());
            let scores: Vec<f32> = (0..slab.len()).map(|_| rng.f32() * 0.1).collect();
            slab.add_scores(&scores, &scores);
            let ctx = DecodeCtx { slab: &slab, step, prefill_len, capacity_limit: cap_limit };
            let d = policy.post_step(&ctx);
            for &s in d.mark.iter().chain(d.evict.iter()) {
                assert!(s < slab.len(), "{}: slot index in range", spec);
            }
            for &s in &d.mark {
                slab.meta_mut()[s].marked = true;
            }
            slab.evict(&d.evict);
            assert!(
                slab.len() <= cap_limit,
                "{}: len {} > capacity {}",
                spec,
                slab.len(),
                cap_limit
            );
        }
    });
}

/// DDES semantics: the number of marked slots never exceeds rc_size, and a
/// flush always clears every mark.
#[test]
fn prop_ddes_bin_bounded_and_flushed() {
    let m = tiny_meta();
    run_prop("ddes-bin", PropConfig { cases: 64, seed: 5 }, |rng, _| {
        let rc = 2 + rng.below(10);
        let mut policy =
            PolicyKind::parse(&format!("hae:rc={},stage=decode", rc)).unwrap().build();
        let prefill_len = 6;
        let mut slab = fill_slab(rng, &m, prefill_len, 128);
        let row = m.n_layers * m.n_heads * m.d_head;
        for step in 0..60 {
            let k: Vec<f32> = (0..row).map(|_| rng.f32()).collect();
            slab.append(&k, &k, (100 + step) as i32, Modality::Text, rng.f32());
            let scores: Vec<f32> = (0..slab.len()).map(|_| rng.f32() * 0.1).collect();
            slab.add_scores(&scores, &scores);
            let ctx = DecodeCtx { slab: &slab, step, prefill_len, capacity_limit: 127 };
            let d = policy.post_step(&ctx);
            for &s in &d.mark {
                slab.meta_mut()[s].marked = true;
            }
            if !d.evict.is_empty() {
                // flush evicts at least the bin and resets all marks
                slab.evict(&d.evict);
                assert_eq!(slab.marked_count(), 0, "flush clears the bin");
            }
            assert!(slab.marked_count() < rc, "bin bounded by rc_size");
        }
    });
}

/// DAP prefill: evicted slots are always vision; retention is adaptive
/// (both criteria must hold — planting one strong link rescues a token).
#[test]
fn prop_dap_only_evicts_weak_vision() {
    let m = tiny_meta();
    run_prop("dap-vision-only", PropConfig { cases: 64, seed: 7 }, |rng, _| {
        let n = 8 + rng.below(24);
        let is_vision = gen_modality(rng, n);
        let dap_sum: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let mut dap_max: Vec<f32> = (0..n).map(|_| rng.f32() * 0.2).collect();
        // rescue one random vision token with a strong individual link
        if let Some(vi) = (0..n).find(|&i| is_vision[i]) {
            dap_max[vi] = 0.9;
        }
        let mut policy = PolicyKind::parse("hae:stage=prefill").unwrap().build();
        let k = vec![0.0f32; m.n_layers * n * m.n_heads * m.d_head];
        let ctx = PrefillCtx {
            dap_sum: &dap_sum,
            dap_max: &dap_max,
            is_vision: &is_vision,
            n_tokens: n,
            k: &k,
            v: &k,
            bucket: n,
            meta: &m,
        };
        let d = policy.prefill(&ctx);
        let retained: std::collections::BTreeSet<usize> = d.retain.iter().copied().collect();
        for i in 0..n {
            if !is_vision[i] {
                assert!(retained.contains(&i), "text never evicted");
            }
            if dap_max[i] >= 0.9 {
                assert!(retained.contains(&i), "strong-link token rescued (Eq. 3)");
            }
        }
    });
}

/// Partial-prefix DAP replay (PR 4): reconstructing a request's
/// statistics from the cached prefix-row contributions plus its own
/// recomputed suffix rows is *bit-exact* — prefix rows and suffix rows
/// accumulate in exactly the order the whole-prompt reduction adds them
/// — and every partial_safe policy's prefill is a pure function of
/// those statistics (it never reads the prompt KV). Together these are
/// the two halves of the warm-start guarantee: the replayed retention
/// decision equals the request's own cold decision.
#[test]
fn prop_partial_replay_reconstructs_cold_decision() {
    let m = tiny_meta();
    run_prop("partial-replay", PropConfig::default(), |rng, _| {
        // prompt layout mirrors the QA shape: [BOS][vision run][text…]
        let n_vis = 2 + rng.below(8);
        let n_suffix = 1 + rng.below(6);
        let n = 1 + n_vis + n_suffix;
        let p = 1 + n_vis; // boundary: one past the last vision token
        let is_vision: Vec<bool> = (0..n).map(|i| i >= 1 && i < p).collect();
        // per-text-row head-mean attention contributions, causal: row i
        // covers columns 0..=i (vision rows carry no DAP weight)
        let rows: Vec<Option<Vec<f32>>> = (0..n)
            .map(|i| (!is_vision[i]).then(|| (0..=i).map(|_| rng.f32()).collect()))
            .collect();
        // cold: one pass over every text row, in row order
        let mut cold_sum = vec![0.0f32; n];
        let mut cold_max = vec![0.0f32; n];
        for r in rows.iter().flatten() {
            for (j, &x) in r.iter().enumerate() {
                cold_sum[j] += x;
                cold_max[j] = cold_max[j].max(x);
            }
        }
        // replay: the cached prefix-row contribution first, then the
        // suffix rows — the exact accumulation the warm path performs
        let mut re_sum = vec![0.0f32; n];
        let mut re_max = vec![0.0f32; n];
        for r in rows[..p].iter().flatten() {
            for (j, &x) in r.iter().enumerate() {
                re_sum[j] += x;
                re_max[j] = re_max[j].max(x);
            }
        }
        for r in rows[p..].iter().flatten() {
            for (j, &x) in r.iter().enumerate() {
                re_sum[j] += x;
                re_max[j] = re_max[j].max(x);
            }
        }
        assert_eq!(cold_sum, re_sum, "column sums must be bit-exact");
        assert_eq!(cold_max, re_max, "column maxes must be bit-exact");
        // identical stats → identical decision, for every partial_safe
        // policy — and independence from the prompt KV (junk vs empty):
        // the purity partial_safe certifies
        for spec in ["full", "hae", "h2o", "snapkv", "adakv", "fastv", "window"] {
            let kind = PolicyKind::parse(spec).unwrap();
            assert!(kind.partial_safe(), "{}", spec);
            let junk = vec![0.25f32; m.n_layers * n * m.n_heads * m.d_head];
            let ctx_cold = PrefillCtx {
                dap_sum: &cold_sum,
                dap_max: &cold_max,
                is_vision: &is_vision,
                n_tokens: n,
                k: &junk,
                v: &junk,
                bucket: n,
                meta: &m,
            };
            let ctx_replay = PrefillCtx {
                dap_sum: &re_sum,
                dap_max: &re_max,
                is_vision: &is_vision,
                n_tokens: n,
                k: &[],
                v: &[],
                bucket: n,
                meta: &m,
            };
            let dc = kind.build().prefill(&ctx_cold);
            let dr = kind.build().prefill(&ctx_replay);
            assert_eq!(
                dc.retain, dr.retain,
                "{}: replayed retention decision differs from cold",
                spec
            );
            assert!(dr.kv_override.is_none(), "{}: partial_safe rewrote KV", spec);
        }
    });
}

/// Chunked DAP accumulation is ORDER-IDENTICAL to per-token accumulation:
/// grouping suffix rows into extend chunks of any size changes only how
/// rows arrive at the accumulator (a chunk row's contributions split at
/// the chunk-start cache boundary instead of at its own column), never
/// the per-column sequence of float additions — so the reconstructed
/// Eq. 1 / Eq. 3 statistics are bit-for-bit the same for every
/// `--extend-chunk`, which is what lets the chunked warm start inherit
/// `prop_partial_replay_reconstructs_cold_decision`'s guarantee
/// unchanged.
#[test]
fn prop_chunked_dap_accumulation_is_order_identical() {
    run_prop("chunked-dap", PropConfig::default(), |rng, _| {
        let p = 1 + rng.below(8); // cached prefix rows (accumulator seed)
        let n_suffix = 1 + rng.below(14);
        let n = p + n_suffix;
        // seed metadata: the prefix entry's cached per-column stats
        let seed: Vec<SlotMeta> = (0..p)
            .map(|i| SlotMeta {
                position: i as i32,
                modality: Modality::Vision,
                cum_score: rng.f32(),
                cum_peak: rng.f32(),
                last_score: 0.0,
                marked: false,
                age: 0,
            })
            .collect();
        // suffix row r at position p+idx covers columns 0..=p+idx
        let rows: Vec<Vec<f32>> = (p..n)
            .map(|i| (0..=i).map(|_| rng.f32()).collect())
            .collect();

        // per-token: each row splits cache-columns | own column — exactly
        // the decode-loop path (dap_row[..len] + self mass)
        let mut per_tok = DapAccumulator::seeded(&seed, n);
        for (idx, r) in rows.iter().enumerate() {
            let len = p + idx;
            per_tok.push_row(&[&r[..len], &r[len..]]);
        }

        // chunked: rows grouped into chunks; a chunk row splits at the
        // CHUNK-START cache length instead (cache part | intra part) —
        // exactly the extend path (cache_cols[..len0] + chunk_cols[..=i])
        for chunk in [1usize, 2, 3, 5, 8, n_suffix] {
            let mut acc = DapAccumulator::seeded(&seed, n);
            let mut t = 0usize;
            while t < n_suffix {
                let step = chunk.min(n_suffix - t);
                let len0 = p + t;
                for i in 0..step {
                    let r = &rows[t + i];
                    acc.push_row(&[&r[..len0], &r[len0..len0 + i + 1]]);
                }
                t += step;
            }
            assert_eq!(
                per_tok.colsum(),
                acc.colsum(),
                "chunk {}: column sums must be bit-exact",
                chunk
            );
            assert_eq!(
                per_tok.colmax(),
                acc.colmax(),
                "chunk {}: column maxes must be bit-exact",
                chunk
            );
        }
    });
}
