//! Property-based tests over the cache substrate and eviction policies
//! (DESIGN.md §6 invariants). No PJRT required.

use hae_serve::cache::policy::{DecodeCtx, EvictionPolicy, PrefillCtx};
use hae_serve::cache::{KvSlab, Modality, PolicyKind};
use hae_serve::model::ModelMeta;
use hae_serve::util::prop::{gen_modality, run_prop, PropConfig};
use hae_serve::util::rng::Rng;

fn tiny_meta() -> ModelMeta {
    ModelMeta {
        vocab: 32,
        d_model: 8,
        n_layers: 2,
        n_heads: 2,
        d_head: 4,
        d_mlp: 8,
        patch_dim: 4,
        n_patches: 4,
        max_pos: 256,
        dap_layer: 1,
    }
}

fn fill_slab(rng: &mut Rng, m: &ModelMeta, n: usize, cap: usize) -> KvSlab {
    let mut slab = KvSlab::new(m, cap);
    let row = m.n_layers * m.n_heads * m.d_head;
    for i in 0..n {
        let k: Vec<f32> = (0..row).map(|_| rng.f32()).collect();
        let v: Vec<f32> = (0..row).map(|_| rng.f32()).collect();
        let modality = if rng.bool(0.4) { Modality::Vision } else { Modality::Text };
        slab.append(&k, &v, i as i32, modality, rng.f32());
    }
    slab
}

/// Slab integrity: any eviction sequence leaves live slots equal to the
/// inserted-and-not-evicted tokens, in original order, with KV intact.
#[test]
fn prop_slab_integrity_under_random_evictions() {
    let m = tiny_meta();
    run_prop("slab-integrity", PropConfig::default(), |rng, _| {
        let n = 4 + rng.below(40);
        let cap = n + 8;
        let mut slab = fill_slab(rng, &m, n, cap);
        // tag each slot's first K element so we can track identity
        let tags: Vec<(i32, f32)> = (0..slab.len())
            .map(|i| (slab.meta()[i].position, slab.k_row(0, i)[0]))
            .collect();
        let mut alive: Vec<usize> = (0..n).collect();
        for _ in 0..3 {
            if alive.len() <= 1 {
                break;
            }
            let k = rng.below(alive.len().min(5));
            let evict_now: Vec<usize> = rng.choose_k(slab.len(), k);
            slab.evict(&evict_now);
            // mirror on the model
            let mut sorted = evict_now.clone();
            sorted.sort_unstable();
            sorted.dedup();
            for &e in sorted.iter().rev() {
                alive.remove(e);
            }
            assert_eq!(slab.len(), alive.len());
        }
        for (slot, &orig) in alive.iter().enumerate() {
            assert_eq!(slab.meta()[slot].position, tags[orig].0, "position preserved");
            assert_eq!(slab.k_row(0, slot)[0], tags[orig].1, "KV row follows slot");
        }
        // positions strictly increasing (order preserved)
        for w in slab.meta().windows(2) {
            assert!(w[0].position < w[1].position);
        }
    });
}

/// Every decode policy keeps the cache within the hard capacity limit and
/// only ever evicts/marks valid slots.
#[test]
fn prop_policies_respect_capacity_and_validity() {
    let m = tiny_meta();
    let specs = [
        "full", "hae:rc=6", "h2o:budget=24", "snapkv:budget=24,window=4",
        "adakv:budget=24", "mustdrop", "window:sinks=2,window=16", "random:budget=24",
    ];
    run_prop("policy-capacity", PropConfig { cases: 48, seed: 3 }, |rng, case| {
        let spec = specs[case % specs.len()];
        let mut policy = PolicyKind::parse(spec).unwrap().build();
        let cap_limit = 40;
        let prefill_len = 8 + rng.below(8);
        let mut slab = fill_slab(rng, &m, prefill_len, cap_limit + 1);
        let row = m.n_layers * m.n_heads * m.d_head;
        for step in 0..80 {
            // append one generated token
            if slab.len() >= cap_limit {
                let ctx = DecodeCtx { slab: &slab, step, prefill_len, capacity_limit: cap_limit };
                let forced = policy.capacity_fallback(&ctx, slab.len() + 1 - cap_limit);
                assert!(!forced.is_empty(), "{}: fallback must free space", spec);
                slab.evict(&forced);
            }
            let k: Vec<f32> = (0..row).map(|_| rng.f32()).collect();
            slab.append(&k, &k, (100 + step) as i32, Modality::Text, rng.f32());
            let scores: Vec<f32> = (0..slab.len()).map(|_| rng.f32() * 0.1).collect();
            slab.add_scores(&scores, &scores);
            let ctx = DecodeCtx { slab: &slab, step, prefill_len, capacity_limit: cap_limit };
            let d = policy.post_step(&ctx);
            for &s in d.mark.iter().chain(d.evict.iter()) {
                assert!(s < slab.len(), "{}: slot index in range", spec);
            }
            for &s in &d.mark {
                slab.meta_mut()[s].marked = true;
            }
            slab.evict(&d.evict);
            assert!(
                slab.len() <= cap_limit,
                "{}: len {} > capacity {}",
                spec,
                slab.len(),
                cap_limit
            );
        }
    });
}

/// DDES semantics: the number of marked slots never exceeds rc_size, and a
/// flush always clears every mark.
#[test]
fn prop_ddes_bin_bounded_and_flushed() {
    let m = tiny_meta();
    run_prop("ddes-bin", PropConfig { cases: 64, seed: 5 }, |rng, _| {
        let rc = 2 + rng.below(10);
        let mut policy =
            PolicyKind::parse(&format!("hae:rc={},stage=decode", rc)).unwrap().build();
        let prefill_len = 6;
        let mut slab = fill_slab(rng, &m, prefill_len, 128);
        let row = m.n_layers * m.n_heads * m.d_head;
        for step in 0..60 {
            let k: Vec<f32> = (0..row).map(|_| rng.f32()).collect();
            slab.append(&k, &k, (100 + step) as i32, Modality::Text, rng.f32());
            let scores: Vec<f32> = (0..slab.len()).map(|_| rng.f32() * 0.1).collect();
            slab.add_scores(&scores, &scores);
            let ctx = DecodeCtx { slab: &slab, step, prefill_len, capacity_limit: 127 };
            let d = policy.post_step(&ctx);
            for &s in &d.mark {
                slab.meta_mut()[s].marked = true;
            }
            if !d.evict.is_empty() {
                // flush evicts at least the bin and resets all marks
                slab.evict(&d.evict);
                assert_eq!(slab.marked_count(), 0, "flush clears the bin");
            }
            assert!(slab.marked_count() < rc, "bin bounded by rc_size");
        }
    });
}

/// DAP prefill: evicted slots are always vision; retention is adaptive
/// (both criteria must hold — planting one strong link rescues a token).
#[test]
fn prop_dap_only_evicts_weak_vision() {
    let m = tiny_meta();
    run_prop("dap-vision-only", PropConfig { cases: 64, seed: 7 }, |rng, _| {
        let n = 8 + rng.below(24);
        let is_vision = gen_modality(rng, n);
        let dap_sum: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let mut dap_max: Vec<f32> = (0..n).map(|_| rng.f32() * 0.2).collect();
        // rescue one random vision token with a strong individual link
        if let Some(vi) = (0..n).find(|&i| is_vision[i]) {
            dap_max[vi] = 0.9;
        }
        let mut policy = PolicyKind::parse("hae:stage=prefill").unwrap().build();
        let k = vec![0.0f32; m.n_layers * n * m.n_heads * m.d_head];
        let ctx = PrefillCtx {
            dap_sum: &dap_sum,
            dap_max: &dap_max,
            is_vision: &is_vision,
            n_tokens: n,
            k: &k,
            v: &k,
            bucket: n,
            meta: &m,
        };
        let d = policy.prefill(&ctx);
        let retained: std::collections::BTreeSet<usize> = d.retain.iter().copied().collect();
        for i in 0..n {
            if !is_vision[i] {
                assert!(retained.contains(&i), "text never evicted");
            }
            if dap_max[i] >= 0.9 {
                assert!(retained.contains(&i), "strong-link token rescued (Eq. 3)");
            }
        }
    });
}
