//! Server integration: real TCP round trips against the engine thread.
//! Skipped when artifacts are absent.

use hae_serve::cache::PolicyKind;
use hae_serve::coordinator::{Engine, EngineConfig};
use hae_serve::runtime::Runtime;
use hae_serve::server::{client_request, serve, ServerConfig};
use hae_serve::util::json::Json;
use hae_serve::workload::StoryGrammar;

fn artifact_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn server_round_trip_and_shutdown() {
    if Runtime::load(&artifact_dir()).is_err() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    const ADDR: &str = "127.0.0.1:8493";
    let handle = std::thread::spawn(|| {
        let rt = Runtime::load(&artifact_dir()).unwrap();
        let engine = Engine::new(
            rt,
            EngineConfig {
                policy: PolicyKind::hae_default(),
                ..EngineConfig::default()
            },
        )
        .unwrap();
        let grammar = StoryGrammar::load(&artifact_dir()).unwrap();
        serve(engine, ServerConfig { addr: ADDR.into(), queue_depth: 8 }, grammar).unwrap();
    });
    // wait for listener
    let mut up = false;
    for _ in 0..200 {
        if std::net::TcpStream::connect(ADDR).is_ok() {
            up = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
    assert!(up, "server came up");

    // valid request
    let resp = client_request(ADDR, r#"{"id": 3, "kind": "qa"}"#).unwrap();
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.get("id").and_then(|v| v.as_i64()), Some(3));
    assert!(j.get("tokens").and_then(|v| v.as_arr()).map_or(0, |a| a.len()) > 0);
    assert!(j.get("error").is_none(), "{}", resp);

    // max_new honoured
    let resp = client_request(ADDR, r#"{"id": 4, "kind": "story", "max_new": 5}"#).unwrap();
    let j = Json::parse(&resp).unwrap();
    assert!(j.get("tokens").unwrap().as_arr().unwrap().len() <= 5);

    // malformed requests produce error objects, not crashes
    let resp = client_request(ADDR, r#"{"id": 5, "kind": "nope"}"#).unwrap();
    assert!(Json::parse(&resp).unwrap().get("error").is_some());
    let resp = client_request(ADDR, "garbage").unwrap();
    assert!(Json::parse(&resp).unwrap().get("error").is_some());

    // clean shutdown
    let resp = client_request(ADDR, "shutdown").unwrap();
    assert!(resp.contains("shutdown"));
    handle.join().unwrap();
}
