//! Server integration: real TCP round trips against the engine thread.
//! Skipped when artifacts are absent.

use hae_serve::cache::PolicyKind;
use hae_serve::harness::{artifact_dir, skip_or_fail, spawn_server, wait_listening};
use hae_serve::runtime::Runtime;
use hae_serve::scheduler::SchedPolicy;
use hae_serve::server::client_request;
use hae_serve::util::json::Json;

#[test]
fn server_round_trip_and_shutdown() {
    if Runtime::load(&artifact_dir()).is_err() {
        skip_or_fail("artifacts not built");
        return;
    }
    let (handle, addr) = spawn_server(
        PolicyKind::hae_default(),
        1,
        None,
        SchedPolicy::Fifo,
        true,
        2,
    );
    assert!(wait_listening(&addr), "server came up");

    // valid request
    let resp = client_request(&addr, r#"{"id": 3, "kind": "qa"}"#).unwrap();
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.get("id").and_then(|v| v.as_i64()), Some(3));
    assert!(j.get("tokens").and_then(|v| v.as_arr()).map_or(0, |a| a.len()) > 0);
    assert!(j.get("error").is_none(), "{}", resp);

    // max_new honoured
    let resp = client_request(&addr, r#"{"id": 4, "kind": "story", "max_new": 5}"#).unwrap();
    let j = Json::parse(&resp).unwrap();
    assert!(j.get("tokens").unwrap().as_arr().unwrap().len() <= 5);

    // malformed requests produce error objects (echoing the id when the
    // line parsed), not crashes
    let resp = client_request(&addr, r#"{"id": 5, "kind": "nope"}"#).unwrap();
    let j = Json::parse(&resp).unwrap();
    assert!(j.get("error").is_some());
    assert_eq!(j.get("id").and_then(|v| v.as_i64()), Some(5));
    let resp = client_request(&addr, "garbage").unwrap();
    assert!(Json::parse(&resp).unwrap().get("error").is_some());

    // clean shutdown
    let resp = client_request(&addr, "shutdown").unwrap();
    assert!(resp.contains("shutdown"));
    handle.join().unwrap();
}

/// Shutdown is a full drain: `serve_on` returns only after the acceptor
/// has joined every connection thread, so once the serve thread joins,
/// the listener is gone — even with an idle client connected that never
/// sends a byte (its reader exits via the read timeout + shutdown flag).
#[test]
fn shutdown_terminates_listener_and_connection_threads() {
    if Runtime::load(&artifact_dir()).is_err() {
        skip_or_fail("artifacts not built");
        return;
    }
    let (handle, addr) = spawn_server(
        PolicyKind::hae_default(),
        1,
        None,
        SchedPolicy::Fifo,
        true,
        1, // sequential mode must drain identically to pipelined
    );
    assert!(wait_listening(&addr), "server came up");

    // an idle connection that never sends anything must not pin the
    // server past shutdown
    let idle = std::net::TcpStream::connect(&addr).unwrap();

    let resp = client_request(&addr, "shutdown").unwrap();
    assert!(resp.contains("shutdown"));
    // joins acceptor + every connection thread inside serve_on; a hang
    // here (the old detached-thread leak) fails via the test timeout
    handle.join().unwrap();
    drop(idle);

    // the listener socket is closed once serve_on returns: new
    // connections are refused (or reset immediately, never serviced)
    match std::net::TcpStream::connect(&addr) {
        Err(_) => {}
        Ok(mut stream) => {
            use std::io::{Read, Write};
            let _ = stream.write_all(b"{\"id\": 9, \"kind\": \"qa\"}\n");
            let mut buf = Vec::new();
            let n = stream.read_to_end(&mut buf).unwrap_or(0);
            assert_eq!(n, 0, "dead server answered: {:?}", String::from_utf8_lossy(&buf));
        }
    }
}

/// Contention e2e: many client threads hammer one server; the final
/// stats must be consistent — the per-request `extend_calls` fields sum
/// to the engine total the snapshot reports, zero refcount errors, and
/// every request is accounted as completed.
#[test]
fn concurrent_clients_leave_consistent_stats() {
    if Runtime::load(&artifact_dir()).is_err() {
        skip_or_fail("artifacts not built");
        return;
    }
    let (handle, addr) = spawn_server(
        PolicyKind::hae_default(),
        hae_serve::harness::widest_batch(),
        None,
        SchedPolicy::Fifo,
        true,
        2,
    );
    assert!(wait_listening(&addr), "server came up");

    // dialog turns share an image ⇒ partial warm starts ⇒ nonzero
    // extend_calls to reconcile; distinct seeds also mix in cold misses
    let n_clients: i64 = 4;
    let per_client: i64 = 3;
    let mut workers = Vec::new();
    for c in 0..n_clients {
        let addr = addr.clone();
        workers.push(std::thread::spawn(move || {
            let mut extend_calls = 0u64;
            for i in 0..per_client {
                let id = c * 100 + i;
                let payload = format!(
                    r#"{{"id": {}, "kind": "qa", "image_seed": 9, "turn": {}, "max_new": 8}}"#,
                    id, i
                );
                let resp = client_request(&addr, &payload).unwrap();
                let j = Json::parse(&resp).unwrap();
                assert!(j.get("error").is_none(), "unexpected error: {}", resp);
                assert_eq!(j.get("id").and_then(|v| v.as_i64()), Some(id));
                extend_calls +=
                    j.get("extend_calls").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
            }
            extend_calls
        }));
    }
    let client_extends: u64 = workers.into_iter().map(|w| w.join().unwrap()).sum();

    let stats =
        Json::parse(&client_request(&addr, r#"{"kind": "stats"}"#).unwrap()).unwrap();
    let _ = client_request(&addr, "shutdown");
    handle.join().unwrap();

    let get = |k: &str| stats.get(k).and_then(|v| v.as_f64()).unwrap_or(-1.0);
    assert_eq!(
        get("completed") as i64,
        n_clients * per_client,
        "stats: {}",
        stats.to_string_compact()
    );
    assert_eq!(
        get("extend_calls") as u64,
        client_extends,
        "per-request extend_calls don't sum to the engine total: {}",
        stats.to_string_compact()
    );
    assert_eq!(get("refcount_errors") as i64, 0);
    assert_eq!(get("failed") as i64, 0);
}
