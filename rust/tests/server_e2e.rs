//! Server integration: real TCP round trips against the engine thread.
//! Skipped when artifacts are absent.

use hae_serve::cache::PolicyKind;
use hae_serve::harness::{artifact_dir, skip_or_fail, spawn_server, wait_listening};
use hae_serve::runtime::Runtime;
use hae_serve::scheduler::SchedPolicy;
use hae_serve::server::client_request;
use hae_serve::util::json::Json;

#[test]
fn server_round_trip_and_shutdown() {
    if Runtime::load(&artifact_dir()).is_err() {
        skip_or_fail("artifacts not built");
        return;
    }
    let (handle, addr) = spawn_server(
        PolicyKind::hae_default(),
        1,
        None,
        SchedPolicy::Fifo,
        true,
    );
    assert!(wait_listening(&addr), "server came up");

    // valid request
    let resp = client_request(&addr, r#"{"id": 3, "kind": "qa"}"#).unwrap();
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.get("id").and_then(|v| v.as_i64()), Some(3));
    assert!(j.get("tokens").and_then(|v| v.as_arr()).map_or(0, |a| a.len()) > 0);
    assert!(j.get("error").is_none(), "{}", resp);

    // max_new honoured
    let resp = client_request(&addr, r#"{"id": 4, "kind": "story", "max_new": 5}"#).unwrap();
    let j = Json::parse(&resp).unwrap();
    assert!(j.get("tokens").unwrap().as_arr().unwrap().len() <= 5);

    // malformed requests produce error objects (echoing the id when the
    // line parsed), not crashes
    let resp = client_request(&addr, r#"{"id": 5, "kind": "nope"}"#).unwrap();
    let j = Json::parse(&resp).unwrap();
    assert!(j.get("error").is_some());
    assert_eq!(j.get("id").and_then(|v| v.as_i64()), Some(5));
    let resp = client_request(&addr, "garbage").unwrap();
    assert!(Json::parse(&resp).unwrap().get("error").is_some());

    // clean shutdown
    let resp = client_request(&addr, "shutdown").unwrap();
    assert!(resp.contains("shutdown"));
    handle.join().unwrap();
}
