//! Router integration: real TCP round trips against the N-replica tier —
//! merged stats, typed load shedding, occupancy spill, and the replicas=2
//! extension of the shutdown-drain guarantee. Skipped when artifacts are
//! absent. Unit coverage of placement/merging lives in `router::tests`;
//! these suites prove the wire behavior end to end.

use hae_serve::harness::{
    artifact_dir, skip_or_fail, spawn_server_replicas, wait_listening, widest_batch,
    ServerRig,
};
use hae_serve::router::RouterPolicy;
use hae_serve::runtime::Runtime;
use hae_serve::server::client_request;
use hae_serve::util::json::Json;

fn rig(replicas: usize) -> ServerRig {
    ServerRig { batch: widest_batch(), replicas, ..ServerRig::default() }
}

/// Two replicas behind one listener: a shared-image mix round-trips, and
/// the `{"kind":"stats"}` reply is the MERGED view — per-replica counts
/// sum to the aggregate, both replicas appear, zero refcount errors.
#[test]
fn two_replica_round_trip_and_merged_stats() {
    if Runtime::load(&artifact_dir()).is_err() {
        skip_or_fail("artifacts not built");
        return;
    }
    let (handle, addr) = spawn_server_replicas(rig(2));
    assert!(wait_listening(&addr), "server came up");

    // two distinct images (likely distinct ring owners) + a text story
    let mut sent = 0i64;
    for (i, line) in [
        r#"{"id": 1, "kind": "qa", "image_seed": 7, "q": "color"}"#,
        r#"{"id": 2, "kind": "qa", "image_seed": 7, "q": "shape"}"#,
        r#"{"id": 3, "kind": "qa", "image_seed": 11, "q": "color"}"#,
        r#"{"id": 4, "kind": "qa", "image_seed": 11, "q": "shape"}"#,
        r#"{"id": 5, "kind": "story", "max_new": 8}"#,
    ]
    .iter()
    .enumerate()
    {
        let resp = client_request(&addr, line).unwrap();
        let j = Json::parse(&resp).unwrap();
        assert!(j.get("error").is_none(), "unexpected error: {}", resp);
        assert_eq!(j.get("id").and_then(|v| v.as_i64()), Some(i as i64 + 1));
        assert!(j.get("tokens").and_then(|v| v.as_arr()).map_or(0, |a| a.len()) > 0);
        sent += 1;
    }

    let stats =
        Json::parse(&client_request(&addr, r#"{"kind": "stats"}"#).unwrap()).unwrap();
    let _ = client_request(&addr, "shutdown");
    handle.join().unwrap();

    let get = |k: &str| stats.get(k).and_then(|v| v.as_f64()).unwrap_or(-1.0);
    assert_eq!(get("replicas") as i64, 2, "stats: {}", stats.to_string_compact());
    assert_eq!(get("completed") as i64, sent);
    assert_eq!(get("refcount_errors") as i64, 0);
    assert_eq!(get("failed") as i64, 0);
    let per = stats
        .get("per_replica")
        .and_then(|v| v.as_arr())
        .expect("merged stats carry per_replica");
    assert_eq!(per.len(), 2);
    let per_sum: f64 = per
        .iter()
        .map(|r| r.get("completed").and_then(|v| v.as_f64()).unwrap_or(0.0))
        .sum();
    assert_eq!(per_sum as i64, sent, "replica counts must sum to the aggregate");
    // the router block is present even when nothing shed or spilled
    assert_eq!(
        stats.path(&["router", "shed_total"]).and_then(|v| v.as_f64()),
        Some(0.0)
    );
    // the affinity router actually routed by content hash
    assert!(
        stats
            .path(&["router", "routed_affinity"])
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0)
            >= 4.0,
        "stats: {}",
        stats.to_string_compact()
    );
}

/// The merged Prometheus exposition at replicas=2: router series present,
/// canonical engine series aggregated (present exactly once).
#[test]
fn two_replica_prometheus_is_merged() {
    if Runtime::load(&artifact_dir()).is_err() {
        skip_or_fail("artifacts not built");
        return;
    }
    let (handle, addr) = spawn_server_replicas(rig(2));
    assert!(wait_listening(&addr), "server came up");
    let resp = client_request(&addr, r#"{"id": 1, "kind": "qa", "image_seed": 3}"#).unwrap();
    assert!(Json::parse(&resp).unwrap().get("error").is_none(), "{}", resp);
    let prom =
        Json::parse(&client_request(&addr, r#"{"kind": "stats", "format": "prometheus"}"#).unwrap())
            .unwrap();
    let _ = client_request(&addr, "shutdown");
    handle.join().unwrap();

    let body = prom.get("body").and_then(|v| v.as_str()).expect("prometheus body").to_string();
    assert!(body.contains("hae_router_replicas 2"), "{}", body);
    assert!(body.contains("hae_router_shed_total 0"), "{}", body);
    assert!(body.contains("hae_requests_submitted_total"), "{}", body);
    // one aggregated sample per canonical series, not one per replica
    assert_eq!(
        body.lines().filter(|l| l.starts_with("hae_requests_submitted_total")).count(),
        1,
        "{}",
        body
    );
}

/// A zero admission bound sheds every workload line with the typed reply
/// — `{"kind":"error","reason":"shed"}`, id echoed — while control verbs
/// (stats, shutdown) still pass, and shed traffic never touches a
/// replica's pool (zero refcount errors, nothing submitted).
#[test]
fn bounded_queue_sheds_with_typed_reply() {
    if Runtime::load(&artifact_dir()).is_err() {
        skip_or_fail("artifacts not built");
        return;
    }
    let (handle, addr) =
        spawn_server_replicas(ServerRig { shed_queue: Some(0), ..rig(2) });
    assert!(wait_listening(&addr), "server came up");

    let burst = 6i64;
    for i in 0..burst {
        let line = format!(r#"{{"id": {}, "kind": "qa", "image_seed": 5}}"#, 100 + i);
        let j = Json::parse(&client_request(&addr, &line).unwrap()).unwrap();
        assert_eq!(j.path(&["kind"]).and_then(|v| v.as_str()), Some("error"));
        assert_eq!(j.get("reason").and_then(|v| v.as_str()), Some("shed"));
        assert_eq!(j.get("id").and_then(|v| v.as_i64()), Some(100 + i));
    }

    let stats =
        Json::parse(&client_request(&addr, r#"{"kind": "stats"}"#).unwrap()).unwrap();
    let _ = client_request(&addr, "shutdown");
    handle.join().unwrap();

    let shed = stats.path(&["router", "shed_total"]).and_then(|v| v.as_f64());
    assert_eq!(shed, Some(burst as f64), "stats: {}", stats.to_string_compact());
    let get = |k: &str| stats.get(k).and_then(|v| v.as_f64()).unwrap_or(-1.0);
    assert_eq!(get("submitted") as i64, 0, "shed lines must never reach a scheduler");
    assert_eq!(get("refcount_errors") as i64, 0);
}

/// A zero spill threshold marks every primary hot, so affinity traffic
/// lands on the ring's second choice — counted by the router, still
/// served correctly (same reply as un-spilled traffic).
#[test]
fn hot_pool_spills_to_second_choice() {
    if Runtime::load(&artifact_dir()).is_err() {
        skip_or_fail("artifacts not built");
        return;
    }
    let (handle, addr) =
        spawn_server_replicas(ServerRig { spill_occupancy: Some(0.0), ..rig(2) });
    assert!(wait_listening(&addr), "server came up");

    let n = 4i64;
    for i in 0..n {
        let line = format!(r#"{{"id": {}, "kind": "qa", "image_seed": 7, "q": "color"}}"#, i);
        let j = Json::parse(&client_request(&addr, &line).unwrap()).unwrap();
        assert!(j.get("error").is_none());
        assert!(j.get("tokens").and_then(|v| v.as_arr()).map_or(0, |a| a.len()) > 0);
    }

    let stats =
        Json::parse(&client_request(&addr, r#"{"kind": "stats"}"#).unwrap()).unwrap();
    let _ = client_request(&addr, "shutdown");
    handle.join().unwrap();

    assert_eq!(
        stats.path(&["router", "spill_total"]).and_then(|v| v.as_f64()),
        Some(n as f64),
        "stats: {}",
        stats.to_string_compact()
    );
    let get = |k: &str| stats.get(k).and_then(|v| v.as_f64()).unwrap_or(-1.0);
    assert_eq!(get("completed") as i64, n);
    assert_eq!(get("refcount_errors") as i64, 0);
    assert_eq!(get("failed") as i64, 0);
}

/// The PR 7 shutdown-drain guarantee at `--replicas 2`: `serve_replicas_on`
/// returns only after the acceptor has joined every connection thread AND
/// both replica scheduler threads have drained — even with an idle client
/// connected that never sends a byte.
#[test]
fn shutdown_terminates_listener_and_replica_threads() {
    if Runtime::load(&artifact_dir()).is_err() {
        skip_or_fail("artifacts not built");
        return;
    }
    let (handle, addr) = spawn_server_replicas(ServerRig {
        // sequential mode must drain identically to pipelined
        engine_threads: 1,
        ..rig(2)
    });
    assert!(wait_listening(&addr), "server came up");

    // an idle connection that never sends anything must not pin the
    // server past shutdown
    let idle = std::net::TcpStream::connect(&addr).unwrap();

    // one request per likely owner so both replicas have seen work
    for line in [
        r#"{"id": 1, "kind": "qa", "image_seed": 7, "max_new": 4}"#,
        r#"{"id": 2, "kind": "qa", "image_seed": 11, "max_new": 4}"#,
    ] {
        let j = Json::parse(&client_request(&addr, line).unwrap()).unwrap();
        assert!(j.get("error").is_none());
    }

    let resp = client_request(&addr, "shutdown").unwrap();
    assert!(resp.contains("shutdown"));
    // joins acceptor + connection threads + BOTH replica threads inside
    // serve_replicas_on; a hang here fails via the test timeout
    handle.join().unwrap();
    drop(idle);

    // the listener socket is closed once serve_replicas_on returns: new
    // connections are refused (or reset immediately, never serviced)
    match std::net::TcpStream::connect(&addr) {
        Err(_) => {}
        Ok(mut stream) => {
            use std::io::{Read, Write};
            let _ = stream.write_all(b"{\"id\": 9, \"kind\": \"qa\"}\n");
            let mut buf = Vec::new();
            let n = stream.read_to_end(&mut buf).unwrap_or(0);
            assert_eq!(n, 0, "dead server answered: {:?}", String::from_utf8_lossy(&buf));
        }
    }
}

/// Round-robin control arm round-trips too (the bench's comparison arm
/// must not only work under the affinity policy).
#[test]
fn round_robin_policy_serves() {
    if Runtime::load(&artifact_dir()).is_err() {
        skip_or_fail("artifacts not built");
        return;
    }
    let (handle, addr) = spawn_server_replicas(ServerRig {
        router_policy: RouterPolicy::RoundRobin,
        ..rig(2)
    });
    assert!(wait_listening(&addr), "server came up");
    for i in 0..4i64 {
        let line = format!(r#"{{"id": {}, "kind": "qa", "image_seed": 2}}"#, i);
        let j = Json::parse(&client_request(&addr, &line).unwrap()).unwrap();
        assert!(j.get("error").is_none());
    }
    let stats =
        Json::parse(&client_request(&addr, r#"{"kind": "stats"}"#).unwrap()).unwrap();
    let _ = client_request(&addr, "shutdown");
    handle.join().unwrap();
    assert_eq!(
        stats.path(&["router", "routed_round_robin"]).and_then(|v| v.as_f64()),
        Some(4.0),
        "stats: {}",
        stats.to_string_compact()
    );
    assert_eq!(
        stats.get("completed").and_then(|v| v.as_f64()),
        Some(4.0)
    );
}
