//! Theorem 2.1 / Corollary 2.1 checked on real decode traces (artifacts
//! required; skipped otherwise).

use hae_serve::attention::decay_rate_fit;
use hae_serve::cache::PolicyKind;
use hae_serve::coordinator::{Engine, EngineConfig};
use hae_serve::runtime::Runtime;
use hae_serve::theory;
use hae_serve::workload::{RequestBuilder, StoryGrammar};

fn artifact_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine(policy: &str) -> Option<Engine> {
    Runtime::load(&artifact_dir()).ok()?;
    Some(
        Engine::from_artifact_dir(
            &artifact_dir(),
            EngineConfig {
                policy: PolicyKind::parse(policy).unwrap(),
                ..EngineConfig::default()
            },
        )
        .unwrap(),
    )
}

#[test]
fn decay_model_fits_measured_scores() {
    let Some(mut eng) = engine("full") else { return };
    let meta = eng.meta().clone();
    let grammar = StoryGrammar::load(&artifact_dir()).unwrap();
    let mut b = RequestBuilder::new(&meta, &grammar, 41);
    let mut req = b.story(3, 12, 100);
    req.min_new_tokens = 80;
    let mut ar = eng.prefill(req).unwrap();
    let mut series = Vec::new();
    while !ar.done {
        let mean: f64 = ar
            .slab
            .meta()
            .iter()
            .map(|m| m.last_score as f64)
            .sum::<f64>()
            / ar.slab.len().max(1) as f64;
        if ar.stats.steps > 0 {
            series.push(mean);
        }
        let mut lanes = [&mut ar];
        eng.decode_step(&mut lanes).unwrap();
    }
    // per-slot mean mass dilutes as the cache grows → positive decay rate
    let lambda = decay_rate_fit(&series);
    assert!(lambda > 0.0, "fitted λ = {}", lambda);
    assert!(lambda < 0.5, "λ implausibly large: {}", lambda);
    // Thm 2.1 internal consistency on the fitted model
    let attn_max = series.iter().cloned().fold(0.0f64, f64::max);
    let eps = attn_max / 10.0;
    let k = theory::integrity_bound(eps, attn_max, lambda).expect("non-vacuous");
    assert!(k > 0.0);
    let loss = theory::worst_case_loss(attn_max, lambda, k);
    assert!((loss - eps).abs() < 1e-9);
}

#[test]
fn corollary_ddes_loss_le_greedy_on_traces() {
    // teacher-forced identical scripts; compare per-eviction realized loss
    let Some(mut reference) = engine("full") else { return };
    let meta = reference.meta().clone();
    let grammar = StoryGrammar::load(&artifact_dir()).unwrap();
    let mut b = RequestBuilder::new(&meta, &grammar, 43);
    let mut holds = 0;
    let total = 3;
    for _ in 0..total {
        let mut req = b.story(3, 12, 100);
        req.min_new_tokens = 90;
        let script = reference.generate(req.clone()).unwrap().generated;

        let mut ddes = engine("hae:stage=decode,rc=16").unwrap();
        let a = ddes.generate_forced(req.clone(), &script).unwrap();
        let mut greedy = engine("h2o").unwrap();
        let c = greedy.generate_forced(req, &script).unwrap();

        let dn: usize = a.evictions.iter().map(|e| e.victims.len()).sum();
        let gn: usize = c.evictions.iter().map(|e| e.victims.len()).sum();
        if dn == 0 || gn == 0 {
            continue;
        }
        let (dl, gl) = theory::corollary_check(&a.evictions, &c.evictions);
        if dl / dn as f64 <= gl / gn as f64 + 1e-9 {
            holds += 1;
        }
    }
    assert!(
        holds * 3 >= total * 2,
        "Corollary 2.1 held on only {}/{} traces",
        holds,
        total
    );
}
