//! Integration tests over the real runtime + artifacts: every policy runs
//! end-to-end; the engine honours its contracts. Skipped (with a notice)
//! when `make artifacts` hasn't been run.

use hae_serve::cache::PolicyKind;
use hae_serve::coordinator::{Engine, EngineConfig};
use hae_serve::runtime::Runtime;
use hae_serve::workload::{RequestBuilder, StoryGrammar, WorkloadKind};

fn artifact_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine(policy: &str) -> Option<Engine> {
    if Runtime::load(&artifact_dir()).is_err() {
        hae_serve::harness::skip_or_fail("artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(
        Engine::from_artifact_dir(
            &artifact_dir(),
            EngineConfig {
                policy: PolicyKind::parse(policy).unwrap(),
                ..EngineConfig::default()
            },
        )
        .unwrap(),
    )
}

#[test]
fn every_policy_completes_mixed_requests() {
    for spec in [
        "full", "hae", "hae:stage=prefill", "hae:stage=decode", "h2o", "snapkv",
        "adakv", "mustdrop", "fastv", "sparsevlm", "tome", "window", "random",
    ] {
        let Some(mut eng) = engine(spec) else { return };
        let meta = eng.meta().clone();
        let grammar = StoryGrammar::load(&artifact_dir()).unwrap();
        let mut b = RequestBuilder::new(&meta, &grammar, 11);
        for kind in [WorkloadKind::Understanding, WorkloadKind::Story] {
            let mut req = b.make(kind);
            req.max_new_tokens = req.max_new_tokens.min(40);
            req.min_new_tokens = req.min_new_tokens.min(30);
            let ar = eng.generate(req).unwrap_or_else(|e| panic!("{}: {}", spec, e));
            assert!(ar.done, "{}: finished", spec);
            assert!(!ar.generated.is_empty(), "{}: produced tokens", spec);
            assert!(
                ar.slab.len() < eng.manifest().shapes.cache_capacity,
                "{}: capacity respected",
                spec
            );
            // positions strictly increasing in the live cache
            for w in ar.slab.meta().windows(2) {
                assert!(w[0].position < w[1].position, "{}: slot order", spec);
            }
        }
    }
}

#[test]
fn greedy_determinism_across_runs() {
    let Some(mut e1) = engine("hae") else { return };
    let Some(mut e2) = engine("hae") else { return };
    let meta = e1.meta().clone();
    let grammar = StoryGrammar::load(&artifact_dir()).unwrap();
    let req1 = RequestBuilder::new(&meta, &grammar, 99).make(WorkloadKind::Story);
    let req2 = RequestBuilder::new(&meta, &grammar, 99).make(WorkloadKind::Story);
    let a = e1.generate(req1).unwrap();
    let b = e2.generate(req2).unwrap();
    assert_eq!(a.generated, b.generated, "greedy decode must be reproducible");
    assert_eq!(a.stats.pruned_at_prefill, b.stats.pruned_at_prefill);
    assert_eq!(a.stats.evicted_at_decode, b.stats.evicted_at_decode);
}

#[test]
fn full_cache_teacher_forcing_is_exact() {
    // replaying the full-cache script under the full-cache policy must
    // reproduce identical logits — validates the fidelity protocol itself
    let Some(mut reference) = engine("full") else { return };
    reference.cfg.capture_logits = true;
    let meta = reference.meta().clone();
    let grammar = StoryGrammar::load(&artifact_dir()).unwrap();
    let mut b = RequestBuilder::new(&meta, &grammar, 5);
    let mut req = b.make(WorkloadKind::Story);
    req.max_new_tokens = 24;
    req.min_new_tokens = 0;
    let ar = reference.generate(req.clone()).unwrap();

    let Some(mut replay) = engine("full") else { return };
    replay.cfg.capture_logits = true;
    let ar2 = replay.generate_forced(req, &ar.generated).unwrap();
    assert_eq!(ar.generated, ar2.generated);
    let f = hae_serve::eval::fidelity(&ar.logits_trace, &ar2.logits_trace);
    assert_eq!(f.top1_agreement, 1.0);
    assert!(f.mean_kl < 1e-6, "kl {}", f.mean_kl);
}

#[test]
fn batched_equals_sequential_for_greedy_decode() {
    // batch width must not change results: run the same two requests at
    // batch 1 and batch 4 and compare token streams
    let Some(mut e1) = engine("hae") else { return };
    let meta = e1.meta().clone();
    let grammar = StoryGrammar::load(&artifact_dir()).unwrap();
    let reqs = |seed| {
        let mut b = RequestBuilder::new(&meta, &grammar, seed);
        vec![b.make(WorkloadKind::Understanding), b.make(WorkloadKind::Understanding)]
    };
    let (seq, _) = e1.run_batched(reqs(17)).unwrap();

    let mut e4 = Engine::from_artifact_dir(
        &artifact_dir(),
        EngineConfig {
            policy: PolicyKind::parse("hae").unwrap(),
            batch: 4,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    let (bat, _) = e4.run_batched(reqs(17)).unwrap();
    let mut seq_tokens: Vec<_> = seq.iter().map(|a| (a.req.id, a.generated.clone())).collect();
    let mut bat_tokens: Vec<_> = bat.iter().map(|a| (a.req.id, a.generated.clone())).collect();
    seq_tokens.sort();
    bat_tokens.sort();
    assert_eq!(seq_tokens, bat_tokens, "batching must not change greedy output");
}

#[test]
fn capacity_bucketing_shrinks_with_eviction() {
    // a long story under HAE must run most decode steps in a smaller
    // capacity bucket than the full-cache run
    let Some(mut hae) = engine("hae:rc=8") else { return };
    let meta = hae.meta().clone();
    let grammar = StoryGrammar::load(&artifact_dir()).unwrap();
    let mut b = RequestBuilder::new(&meta, &grammar, 23);
    let mut req = b.story(4, 14, 140);
    req.min_new_tokens = 120;
    let mut ar = hae.prefill(req.clone()).unwrap();
    let mut hae_caps = Vec::new();
    while !ar.done {
        let mut lanes = [&mut ar];
        let rep = hae.decode_step(&mut lanes).unwrap();
        hae_caps.push(rep.capacity);
    }

    let Some(mut full) = engine("full") else { return };
    let mut ar2 = full.prefill(req).unwrap();
    let mut full_caps = Vec::new();
    while !ar2.done {
        let mut lanes = [&mut ar2];
        let rep = full.decode_step(&mut lanes).unwrap();
        full_caps.push(rep.capacity);
    }
    let mean = |v: &[usize]| v.iter().sum::<usize>() as f64 / v.len() as f64;
    assert!(
        mean(&hae_caps) < mean(&full_caps),
        "hae mean capacity {} !< full {}",
        mean(&hae_caps),
        mean(&full_caps)
    );
}

#[test]
fn h2o_does_more_decisions_than_ddes() {
    // the Table 3 mechanism: greedy sorts every over-budget step, the
    // recycle bin amortises
    let Some(mut ddes) = engine("hae:stage=decode,rc=16") else { return };
    let meta = ddes.meta().clone();
    let grammar = StoryGrammar::load(&artifact_dir()).unwrap();
    let mut b = RequestBuilder::new(&meta, &grammar, 31);
    let mut req = b.story(3, 12, 120);
    req.min_new_tokens = 100;
    let a = ddes.generate(req.clone()).unwrap();

    let Some(mut h2o) = engine("h2o") else { return };
    let c = h2o.generate(req).unwrap();
    assert!(
        c.stats.decisions > 2 * a.stats.decisions,
        "h2o {} decisions vs ddes {}",
        c.stats.decisions,
        a.stats.decisions
    );
}
