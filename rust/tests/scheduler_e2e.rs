//! Scheduler integration: N concurrent clients against the
//! continuous-batching server — responses match their request ids, lanes
//! are actually shared, and the KV-budget admission invariant holds.
//! Skipped when artifacts are absent.

use hae_serve::cache::{PolicyKind, DEFAULT_PAGE_SLOTS};
use hae_serve::coordinator::{Engine, EngineConfig};
use hae_serve::harness::{
    artifact_dir, load_grammar, skip_or_fail, spawn_server, wait_listening,
    widest_batch,
};
use hae_serve::model::Manifest;
use hae_serve::obs::{RetireReason, TraceEvent};
use hae_serve::runtime::Runtime;
use hae_serve::scheduler::{
    AdmissionController, SchedOutcome, SchedPolicy, Scheduler, SchedulerConfig,
};
use hae_serve::server::client_request;
use hae_serve::util::json::Json;
use hae_serve::workload::{Request, RequestBuilder};

fn artifacts_present() -> bool {
    if Runtime::load(&artifact_dir()).is_err() {
        skip_or_fail("artifacts not built");
        return false;
    }
    true
}

fn get_num(j: &Json, key: &str) -> f64 {
    j.get(key).and_then(|v| v.as_f64()).unwrap_or(-1.0)
}

#[test]
fn concurrent_clients_share_lanes_under_budget() {
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load(&artifact_dir()).unwrap();
    let batch = widest_batch();
    // explicit budget = the physical ceiling: tight enough that the
    // invariant check is real, loose enough that all lanes can fill
    let budget = batch
        * (manifest.shapes.cache_capacity - 1)
        * manifest.model.kv_bytes_per_token();
    let (server, addr) = spawn_server(
        PolicyKind::hae_default(),
        batch,
        Some(budget),
        SchedPolicy::Priority,
        true,
        2,
    );
    assert!(wait_listening(&addr), "server came up");

    // 6 concurrent clients × 2 requests, every id unique
    let n_clients: i64 = 6;
    let per_client: i64 = 2;
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..per_client {
                let id = c * 100 + i;
                let kind = if (c + i) % 2 == 0 { "story" } else { "mixed" };
                let payload = format!(
                    r#"{{"id": {}, "kind": "{}", "max_new": 24}}"#,
                    id, kind
                );
                let resp = client_request(&addr, &payload).unwrap();
                let j = Json::parse(&resp).unwrap();
                // (a) the response carries this request's id
                assert_eq!(
                    j.get("id").and_then(|v| v.as_i64()),
                    Some(id),
                    "response/request id mismatch: {}",
                    resp
                );
                assert!(j.get("error").is_none(), "unexpected error: {}", resp);
                assert!(
                    j.get("tokens").and_then(|v| v.as_arr()).map_or(0, |a| a.len()) > 0
                );
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    let stats =
        Json::parse(&client_request(&addr, r#"{"kind": "stats"}"#).unwrap()).unwrap();
    let _ = client_request(&addr, "shutdown");
    let _ = server.join();

    assert_eq!(
        get_num(&stats, "completed"),
        (n_clients * per_client) as f64,
        "stats: {}",
        stats.to_string_compact()
    );
    // (b) at least one decode step ran more than one lane
    if batch > 1 {
        assert!(
            get_num(&stats, "max_lanes_step") >= 2.0,
            "continuous batching never shared a step: {}",
            stats.to_string_compact()
        );
    }
    // (c) the admission invariant: aggregate live KV never passed the
    // budget at any decode step
    let peak = get_num(&stats, "peak_live_kv_bytes");
    assert!(peak > 0.0, "no KV accounted: {}", stats.to_string_compact());
    assert!(
        peak <= budget as f64,
        "budget invariant violated: peak {} > budget {}",
        peak,
        budget
    );
}

/// Chunked-prefill admission: a request whose worst case exceeds the
/// free page budget at arrival is not rejected and not starved — it
/// accumulates page reservations as the live lane evicts and retires,
/// prefills once covered, and completes. The page-accounting invariant
/// (live pages ≤ pool capacity) and the byte-budget invariant hold at
/// every step.
#[test]
fn chunked_prefill_admits_oversized_prompt_incrementally() {
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load(&artifact_dir()).unwrap();
    let batch = widest_batch();
    if batch < 2 {
        skip_or_fail("needs a compiled decode batch ≥ 2");
        return;
    }
    let meta = manifest.model.clone();
    let grammar = load_grammar(&artifact_dir());
    let mut b = RequestBuilder::new(&meta, &grammar, 77);
    let mut req_a = b.story(3, 12, 60);
    req_a.min_new_tokens = 40;
    let mut req_b = b.story(3, 12, 60);
    req_b.min_new_tokens = 40;

    // budget: the bigger request fits alone with one page to spare, so
    // while A is live, B's worst case can never fit in one piece
    let ps = DEFAULT_PAGE_SLOTS;
    let cap_limit = manifest.shapes.cache_capacity - 1;
    let worst_pages = |r: &Request| {
        (r.prompt_len() + r.max_new_tokens).min(cap_limit).div_ceil(ps)
    };
    let budget_pages = worst_pages(&req_a).max(worst_pages(&req_b)) + 1;
    let budget = budget_pages * ps * meta.kv_bytes_per_token();
    assert!(worst_pages(&req_a) + worst_pages(&req_b) > budget_pages);

    let mut engine = Engine::from_artifact_dir(
        &artifact_dir(),
        EngineConfig {
            policy: PolicyKind::hae_default(),
            batch,
            kv_budget: Some(budget),
            ..EngineConfig::default()
        },
    )
    .unwrap();
    let sched_cfg = SchedulerConfig { kv_budget: budget, ..SchedulerConfig::default() };
    let mut sched: Scheduler<u32> = Scheduler::for_engine(sched_cfg, &engine);

    // with A admitted, the unreserved budget is exactly the one spare
    // page — smaller than even B's prompt, let alone its worst case
    let b_target_pages = worst_pages(&req_b);
    assert!(req_b.prompt_len().div_ceil(ps) > 1, "B's prompt exceeds the spare page");
    sched.submit(1, req_a).expect("A fits alone");
    sched.submit(2, req_b).expect("B fits alone (but not beside A)");

    let pool_pages = engine.pool_pages();
    let mut done_tags = Vec::new();
    let mut saw_partial_reservation = false;
    for _ in 0..5000 {
        if !sched.has_work() {
            break;
        }
        sched.tick(&mut engine).unwrap();
        // B holds a partial reservation: admitted chunk-by-chunk, not in
        // one piece
        if sched.metrics.reserved_pages > 0
            && sched.metrics.reserved_pages < b_target_pages
        {
            saw_partial_reservation = true;
        }
        // page-accounting invariant, every step
        let pool = engine.pool_stats();
        assert!(
            pool.in_use <= pool_pages,
            "live pages {} > pool {}",
            pool.in_use,
            pool_pages
        );
        // byte-budget invariant, every step
        assert!(
            sched.metrics.peak_live_kv_bytes <= budget,
            "peak {} > budget {}",
            sched.metrics.peak_live_kv_bytes,
            budget
        );
        for outcome in sched.take_outcomes() {
            match outcome {
                SchedOutcome::Done { tag, ar } => {
                    assert!(!ar.generated.is_empty());
                    done_tags.push(tag);
                }
                SchedOutcome::Failed { tag, error } => {
                    panic!("request {} failed: {}", tag, error);
                }
            }
        }
    }
    assert!(
        saw_partial_reservation,
        "B never held a partial reservation — it was admitted in one piece"
    );
    done_tags.sort_unstable();
    assert_eq!(done_tags, vec![1, 2], "both requests completed");
    assert!(
        sched.metrics.chunked_admits >= 1,
        "B must have been admitted through the chunked-prefill path"
    );
    assert!(sched.metrics.chunk_reserved_pages >= b_target_pages as u64);
    // after the drain, only prefix-cache pins may remain (retired lanes
    // returned everything else); evicting the cache empties the arena
    assert_eq!(
        engine.pool_stats().in_use,
        engine.prefix_pinned_pages(),
        "drained arena holds only prefix-cache pins"
    );
    while engine.prefix_evict_one() {}
    assert_eq!(engine.pool_stats().in_use, 0, "reclaimed arena holds no pages");
    assert_eq!(engine.pool_stats().refcount_errors, 0);
}

/// Prefix sharing end-to-end: 8 questions against one image. Serially
/// (where decode numerics are identical), warm outputs are
/// byte-identical to a prefix-cache-off engine; through the scheduler,
/// warm admissions skip prefill (≥6 of 8 hits at 2 distinct prompts),
/// shared pages are charged once and surfaced in the metrics, and the
/// every-step invariants — live pages ≤ pool, zero refcount errors, no
/// page leaks beyond the cache's own pins — hold with sharing enabled.
#[test]
fn prefix_sharing_serves_shared_image_qa() {
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load(&artifact_dir()).unwrap();
    let meta = manifest.model.clone();
    let grammar = load_grammar(&artifact_dir());

    // (a) serial byte-identity: cache off vs on, batch 1, same requests
    let mut b = RequestBuilder::new(&meta, &grammar, 5);
    let reqs = b.shared_image_qa(11, 8);
    let mut cold = Engine::from_artifact_dir(
        &artifact_dir(),
        EngineConfig {
            policy: PolicyKind::hae_default(),
            prefix_cache: false,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    cold.warmup().unwrap();
    let mut warm = Engine::from_artifact_dir(
        &artifact_dir(),
        EngineConfig { policy: PolicyKind::hae_default(), ..EngineConfig::default() },
    )
    .unwrap();
    warm.warmup().unwrap();
    for r in &reqs {
        let c = cold.generate(r.clone()).unwrap();
        let w = warm.generate(r.clone()).unwrap();
        assert_eq!(
            w.generated, c.generated,
            "warm output differs from cold for request {}",
            r.id
        );
    }
    let ps = warm.prefix_stats();
    assert!(ps.hits >= 6, "2 distinct prompts over 8 requests: {:?}", ps);
    assert!(ps.prefill_tokens_skipped >= (6 * reqs[0].prompt_len()) as u64);
    assert_eq!(warm.pool_stats().refcount_errors, 0);

    // (b) the scheduler path: invariants every tick with sharing on
    let batch = widest_batch();
    let mut engine = Engine::from_artifact_dir(
        &artifact_dir(),
        EngineConfig {
            policy: PolicyKind::hae_default(),
            batch,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    engine.warmup().unwrap();
    let mut sched: Scheduler<u64> =
        Scheduler::for_engine(SchedulerConfig::default(), &engine);
    let mut b = RequestBuilder::new(&meta, &grammar, 6);
    for r in b.shared_image_qa(12, 8) {
        sched.submit(r.id, r).unwrap();
    }
    let pool_pages = engine.pool_pages();
    let mut done = 0usize;
    let mut max_shared = 0usize;
    for _ in 0..5000 {
        if !sched.has_work() {
            break;
        }
        sched.tick(&mut engine).unwrap();
        let pool = engine.pool_stats();
        assert!(
            pool.in_use <= pool_pages,
            "live pages {} > pool {}",
            pool.in_use,
            pool_pages
        );
        assert_eq!(pool.refcount_errors, 0, "refcount violation under sharing");
        max_shared = max_shared.max(sched.metrics.pages_shared);
        for outcome in sched.take_outcomes() {
            match outcome {
                SchedOutcome::Done { ar, .. } => {
                    assert!(!ar.generated.is_empty());
                    done += 1;
                }
                SchedOutcome::Failed { tag, error } => {
                    panic!("request {} failed: {}", tag, error);
                }
            }
        }
    }
    assert_eq!(done, 8, "all shared-image questions completed");
    let ps = engine.prefix_stats();
    assert!(ps.hits >= 6, "sharing engaged under the scheduler: {:?}", ps);
    assert!(max_shared >= 1, "charged-once shared pages surfaced in metrics");
    // zero page leaks beyond the cache's own pins
    assert_eq!(engine.pool_stats().in_use, engine.prefix_pinned_pages());
    while engine.prefix_evict_one() {}
    assert_eq!(engine.pool_stats().in_use, 0, "reclaimed arena holds nothing");
}

/// The fork-storm corner that used to panic (PR-3 known residual): a
/// budget-sized pool admitted to the brim, with six sharers of ONE
/// visual prefix diverging simultaneously — an H2O budget below the
/// prompt length forces eviction *inside* the shared prefix from the
/// first decode step on every lane, so CoW forks fire concurrently
/// under maximum page pressure. The fixed accounting (shared partial
/// tails charged once globally AND kept in the lane bound as the fork
/// allowance) plus recoverable deferral (`try_evict` + the CoW
/// affordability gate) must turn that into back-pressure: zero panics,
/// zero refcount errors, live pages ≤ pool at every tick, and every
/// request eventually completes.
#[test]
fn fork_storm_defers_instead_of_panicking() {
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load(&artifact_dir()).unwrap();
    let batch = widest_batch();
    if batch < 2 {
        skip_or_fail("needs a compiled decode batch ≥ 2");
        return;
    }
    let meta = manifest.model.clone();
    let grammar = load_grammar(&artifact_dir());
    let mut b = RequestBuilder::new(&meta, &grammar, 21);
    // six questions, one image: every admission shares the visual prefix
    let mut reqs = b.shared_image_qa(31, 6);
    for r in &mut reqs {
        r.max_new_tokens = 12; // enough steps for repeated divergence
    }

    // budget-sized: exactly the admission bound of `batch` such lanes
    // plus the cache's prefix pins — admitted to the brim, nothing spare
    let ps = DEFAULT_PAGE_SLOTS;
    let cap_limit = manifest.shapes.cache_capacity - 1;
    let worst = |r: &Request| {
        (r.prompt_len() + r.max_new_tokens).min(cap_limit).div_ceil(ps)
    };
    let prompt_pages = reqs[0].prompt_len().div_ceil(ps);
    let budget_pages = batch * worst(&reqs[0]) + 2 * prompt_pages + 1;
    let budget = budget_pages * ps * meta.kv_bytes_per_token();

    // H2O with a budget below the prompt: the very first post-step
    // decision compacts deep inside the adopted prefix
    let policy = PolicyKind::parse("h2o:budget=12,recent=2").unwrap();
    let mut engine = Engine::from_artifact_dir(
        &artifact_dir(),
        EngineConfig {
            policy,
            batch,
            kv_budget: Some(budget),
            ..EngineConfig::default()
        },
    )
    .unwrap();
    engine.warmup().unwrap();
    let sched_cfg = SchedulerConfig { kv_budget: budget, ..SchedulerConfig::default() };
    let mut sched: Scheduler<u64> = Scheduler::for_engine(sched_cfg, &engine);
    for r in reqs {
        sched.submit(r.id, r).expect("fits alone under the storm budget");
    }

    let pool_pages = engine.pool_pages();
    let mut done = 0usize;
    for _ in 0..5000 {
        if !sched.has_work() {
            break;
        }
        // a panic anywhere in here IS the regression this test guards
        sched.tick(&mut engine).unwrap();
        let pool = engine.pool_stats();
        assert!(
            pool.in_use <= pool_pages,
            "fork allowance failed: {} live pages > {} pool",
            pool.in_use,
            pool_pages
        );
        assert_eq!(pool.refcount_errors, 0, "refcount violation under divergence");
        for outcome in sched.take_outcomes() {
            match outcome {
                SchedOutcome::Done { ar, .. } => {
                    assert!(!ar.generated.is_empty());
                    done += 1;
                }
                SchedOutcome::Failed { tag, error } => {
                    panic!("request {} failed: {}", tag, error);
                }
            }
        }
    }
    assert_eq!(done, 6, "every sharer completed despite the storm");
    let pool = engine.pool_stats();
    assert!(pool.forks > 0, "the storm actually diverged (CoW forks fired)");
    assert_eq!(pool.refcount_errors, 0);
    assert_eq!(
        engine.emergency_tail_drops(),
        0,
        "no lane should have reached the capacity wall in this workload"
    );
    // drained arena: only cache pins remain, and they reclaim fully
    while engine.prefix_evict_one() {}
    assert_eq!(engine.pool_stats().in_use, 0, "no page leaked through the storm");
}

/// Partial-prefix warm starts end to end through the scheduler: a
/// multi-turn dialog (8 distinct prompts, one image) admits every turn
/// after the first via `RadixTree::longest_match` + suffix recompute +
/// per-request DAP replay. Serially (batch-1 engines, identical decode
/// numerics) every warm turn must be byte-identical to its own cold
/// run — including the retained-index set the replayed decision
/// produces — and through the scheduler the page/refcount invariants
/// must hold every tick while the skip-rate reaches the shared-prefix
/// fraction.
#[test]
fn partial_warm_starts_serve_multi_turn_dialog() {
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load(&artifact_dir()).unwrap();
    let meta = manifest.model.clone();
    let grammar = load_grammar(&artifact_dir());

    // (a) serial byte-identity + retained-set equality, cold vs warm
    let mut b = RequestBuilder::new(&meta, &grammar, 5);
    let turns = b.shared_image_dialog(17, 8);
    let prefix_len = 1 + meta.n_patches;
    let mut cold = Engine::from_artifact_dir(
        &artifact_dir(),
        EngineConfig {
            policy: PolicyKind::hae_default(),
            prefix_cache: false,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    cold.warmup().unwrap();
    let mut warm = Engine::from_artifact_dir(
        &artifact_dir(),
        EngineConfig { policy: PolicyKind::hae_default(), ..EngineConfig::default() },
    )
    .unwrap();
    warm.warmup().unwrap();
    for (t, r) in turns.iter().enumerate() {
        let c = cold.generate(r.clone()).unwrap();
        let w = warm.generate(r.clone()).unwrap();
        assert_eq!(
            w.generated, c.generated,
            "turn {} diverged between cold and warm",
            t
        );
        // the replayed DAP decision is the request's own: same retained
        // count, positions and score seeds as the cold prefill
        assert_eq!(
            w.stats.pruned_at_prefill, c.stats.pruned_at_prefill,
            "turn {}: replayed retention decision differs from cold",
            t
        );
    }
    // retained-index sets, observed right after admission (before decode
    // mutates the slab): the replayed decision must pick the same slots
    let mut cold2 = Engine::from_artifact_dir(
        &artifact_dir(),
        EngineConfig {
            policy: PolicyKind::hae_default(),
            prefix_cache: false,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    cold2.warmup().unwrap();
    let mut warm2 = Engine::from_artifact_dir(
        &artifact_dir(),
        EngineConfig { policy: PolicyKind::hae_default(), ..EngineConfig::default() },
    )
    .unwrap();
    warm2.warmup().unwrap();
    for (t, r) in turns.iter().enumerate() {
        let c = cold2.prefill(r.clone()).unwrap();
        let w = warm2.prefill(r.clone()).unwrap();
        let cp: Vec<i32> = c.slab.meta().iter().map(|m| m.position).collect();
        let wp: Vec<i32> = w.slab.meta().iter().map(|m| m.position).collect();
        assert_eq!(wp, cp, "turn {}: retained-index set differs from cold", t);
        assert_eq!(
            w.pending_token, c.pending_token,
            "turn {}: first token differs from cold",
            t
        );
    }
    assert!(
        warm2.prefix_stats().partial_hits >= 7,
        "prefill-level replay exercised the partial path"
    );
    let ps = warm.prefix_stats();
    assert_eq!(ps.hits, 0, "every turn is a distinct prompt — no exact hits");
    assert!(
        ps.partial_hits >= 7,
        "turns 1..8 must warm-start from the shared image: {:?}",
        ps
    );
    // skip rate ≥ the shared-prefix fraction: each warm turn skips its
    // whole [BOS][img] prefix
    assert!(
        ps.prefill_tokens_skipped >= (7 * prefix_len) as u64,
        "skipped {} < {} (7 turns × {}-token prefix)",
        ps.prefill_tokens_skipped,
        7 * prefix_len,
        prefix_len
    );
    assert_eq!(warm.pool_stats().refcount_errors, 0);

    // (b) through the scheduler: invariants every tick under divergence
    let batch = widest_batch();
    let mut engine = Engine::from_artifact_dir(
        &artifact_dir(),
        EngineConfig {
            policy: PolicyKind::hae_default(),
            batch,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    engine.warmup().unwrap();
    let mut sched: Scheduler<u64> =
        Scheduler::for_engine(SchedulerConfig::default(), &engine);
    let mut b = RequestBuilder::new(&meta, &grammar, 6);
    for r in b.shared_image_dialog(18, 8) {
        sched.submit(r.id, r).unwrap();
    }
    let pool_pages = engine.pool_pages();
    let mut done = 0usize;
    for _ in 0..5000 {
        if !sched.has_work() {
            break;
        }
        sched.tick(&mut engine).unwrap();
        let pool = engine.pool_stats();
        assert!(pool.in_use <= pool_pages, "live pages exceed the pool");
        assert_eq!(pool.refcount_errors, 0);
        for outcome in sched.take_outcomes() {
            match outcome {
                SchedOutcome::Done { ar, .. } => {
                    assert!(!ar.generated.is_empty());
                    done += 1;
                }
                SchedOutcome::Failed { tag, error } => {
                    panic!("turn {} failed: {}", tag, error);
                }
            }
        }
    }
    assert_eq!(done, 8, "all dialog turns completed");
    let ps = engine.prefix_stats();
    assert!(ps.partial_hits >= 1, "scheduler path produced partial hits: {:?}", ps);
    assert_eq!(
        sched.metrics.prefix_partial_hits, ps.partial_hits,
        "partial hits surfaced in the stats snapshot"
    );
    assert_eq!(
        sched.metrics.extend_calls,
        engine.extend_calls(),
        "suffix-recompute device calls surfaced in the stats snapshot"
    );
    assert!(
        engine.extend_calls() > 0,
        "partial hits recomputed their suffixes through extend calls"
    );
}

/// Chunked-extend equivalence at every `--extend-chunk`: partial warm
/// starts must reproduce the request's own cold results — generated
/// tokens byte-identical AND the replayed retention decision's
/// retained-index set equal — at chunk sizes 1 (the one-token decode
/// loop, reproduced exactly: one device call per suffix token), 4
/// (padded chunks through the extend executables) and full (one call
/// per suffix where a bucket fits), while issuing at most
/// ⌈suffix/chunk⌉ suffix-recompute device calls (`extend_calls`).
#[test]
fn chunked_extend_matches_cold_at_every_chunk_size() {
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load(&artifact_dir()).unwrap();
    let meta = manifest.model.clone();
    let grammar = load_grammar(&artifact_dir());
    let prefix_len = 1 + meta.n_patches;
    let n_turns = 6usize;
    let turns =
        RequestBuilder::new(&meta, &grammar, 5).shared_image_dialog(29, n_turns);

    /// One dialog pass: per turn, the retained-index set and first token
    /// observed right after admission, the suffix-recompute call count,
    /// whether the turn was a *partial* warm start, then the full
    /// generation.
    fn run_dialog(
        engine: &mut Engine,
        turns: &[Request],
        prefix_len: usize,
    ) -> Vec<(Vec<i32>, i32, usize, bool, Vec<i32>)> {
        let mut out = Vec::new();
        for r in turns {
            let mut ar = engine.prefill(r.clone()).unwrap();
            let retained: Vec<i32> = ar.slab.meta().iter().map(|m| m.position).collect();
            let first = ar.pending_token;
            let calls = ar.stats.extend_calls;
            let partial =
                ar.stats.prefix_hit && ar.stats.prefill_tokens_skipped == prefix_len;
            while !ar.done {
                let mut lanes = [&mut ar];
                engine.decode_step(&mut lanes).unwrap();
            }
            ar.slab.release_pages();
            out.push((retained, first, calls, partial, ar.generated.clone()));
        }
        out
    }

    // cold oracle (prefix cache off — chunking never runs)
    let mut cold = Engine::from_artifact_dir(
        &artifact_dir(),
        EngineConfig {
            policy: PolicyKind::hae_default(),
            prefix_cache: false,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    cold.warmup().unwrap();
    let cold_runs = run_dialog(&mut cold, &turns, prefix_len);
    for (_, _, calls, partial, _) in &cold_runs {
        assert_eq!(*calls, 0, "cold runs never extend");
        assert!(!partial);
    }

    for &chunk in &[1usize, 4, usize::MAX] {
        let mut warm = Engine::from_artifact_dir(
            &artifact_dir(),
            EngineConfig {
                policy: PolicyKind::hae_default(),
                extend_chunk: chunk,
                ..EngineConfig::default()
            },
        )
        .unwrap();
        warm.warmup().unwrap();
        let eff = warm.effective_extend_chunk();
        if chunk == 1 {
            assert_eq!(eff, 1, "chunk 1 is never widened");
        } else if chunk == usize::MAX {
            assert_eq!(
                eff,
                manifest.max_extend_chunk(1).max(1),
                "'full' clamps to the largest compiled bucket"
            );
        }
        let warm_runs = run_dialog(&mut warm, &turns, prefix_len);
        let mut partial_turns = 0usize;
        for (t, (w, c)) in warm_runs.iter().zip(&cold_runs).enumerate() {
            assert_eq!(
                w.4, c.4,
                "chunk {}: turn {} output diverged from cold",
                eff, t
            );
            assert_eq!(
                w.0, c.0,
                "chunk {}: turn {} retained-index set differs from cold",
                eff, t
            );
            assert_eq!(w.1, c.1, "chunk {}: turn {} first token differs", eff, t);
            if w.3 {
                partial_turns += 1;
                let suffix = turns[t].prompt_len() - prefix_len;
                let bound = AdmissionController::extend_chunk_calls(suffix, eff);
                assert!(
                    w.2 <= bound,
                    "chunk {}: turn {} issued {} calls > ⌈{}/{}⌉ = {}",
                    eff,
                    t,
                    w.2,
                    suffix,
                    eff,
                    bound
                );
                if eff == 1 {
                    // the decode-loop path, reproduced exactly: one
                    // device call per suffix token
                    assert_eq!(w.2, suffix, "turn {}: decode loop calls", t);
                } else if suffix >= 2 {
                    assert!(
                        w.2 < suffix,
                        "chunk {}: turn {} saved no device calls ({} for {} tokens)",
                        eff,
                        t,
                        w.2,
                        suffix
                    );
                }
            } else {
                assert_eq!(w.2, 0, "non-partial admissions never extend");
            }
        }
        assert!(
            partial_turns >= n_turns - 1,
            "chunk {}: only {} of {} turns warm-started partially",
            eff,
            partial_turns,
            n_turns - 1
        );
        let ps = warm.prefix_stats();
        assert_eq!(ps.hits, 0, "distinct prompts: no exact hits");
        assert_eq!(ps.partial_hits as usize, partial_turns);
        assert_eq!(
            warm.extend_calls(),
            warm_runs.iter().map(|r| r.2 as u64).sum::<u64>(),
            "engine total matches the per-request counts"
        );
        assert_eq!(warm.pool_stats().refcount_errors, 0);
    }
}

/// Request-lifecycle tracing end to end: every request served through
/// the scheduler leaves a complete, ordered lifecycle in the shared
/// trace journal (Enqueued → Admitted → PrefillStart → … → Retired with
/// monotone timestamps), warm dialog turns journal their PartialAdopt
/// and ExtendChunk events between the prefill markers, and the
/// ExtendChunk event count reconciles exactly with the extend-call
/// metric the stats snapshot reports.
#[test]
fn trace_journal_records_complete_lifecycles() {
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load(&artifact_dir()).unwrap();
    let meta = manifest.model.clone();
    let grammar = load_grammar(&artifact_dir());
    let batch = widest_batch();
    let mut engine = Engine::from_artifact_dir(
        &artifact_dir(),
        EngineConfig {
            policy: PolicyKind::hae_default(),
            batch,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    engine.warmup().unwrap();
    let mut sched: Scheduler<u64> =
        Scheduler::for_engine(SchedulerConfig::default(), &engine);
    let mut b = RequestBuilder::new(&meta, &grammar, 6);
    let turns = b.shared_image_dialog(44, 6);
    let ids: Vec<u64> = turns.iter().map(|r| r.id).collect();
    for r in turns {
        sched.submit(r.id, r).unwrap();
    }
    for _ in 0..5000 {
        if !sched.has_work() {
            break;
        }
        sched.tick(&mut engine).unwrap();
        sched.take_outcomes();
    }

    let obs = engine.obs();
    let o = obs.inner();
    let mut extend_events = 0u64;
    let mut partial_turns = 0usize;
    for &rid in &ids {
        let ev = o.trace.for_request(rid);
        assert!(!ev.is_empty(), "request {} left no trace", rid);
        for w in ev.windows(2) {
            assert!(
                w[0].at_us <= w[1].at_us,
                "request {}: timestamps regress in journal order",
                rid
            );
        }
        let names: Vec<&str> = ev.iter().map(|r| r.event.name()).collect();
        let pos = |name: &str| names.iter().position(|n| *n == name);
        let enq = pos("enqueued").unwrap_or_else(|| panic!("{}: {:?}", rid, names));
        let adm = pos("admitted").expect("admitted");
        let pstart = pos("prefill_start").expect("prefill_start");
        let pend = pos("prefill_end").expect("prefill_end");
        let ret = pos("retired").expect("retired");
        assert!(
            enq < adm && adm < pstart && pstart < pend && pend < ret,
            "request {}: lifecycle out of order: {:?}",
            rid,
            names
        );
        assert_eq!(ret, ev.len() - 1, "request {}: retired is terminal", rid);
        assert!(
            matches!(
                ev[ret].event,
                TraceEvent::Retired { reason: RetireReason::Completed }
            ),
            "request {}: retired as {:?}",
            rid,
            ev[ret].event
        );
        if let Some(pa) = pos("partial_adopt") {
            partial_turns += 1;
            assert!(
                pstart < pa && pa < pend,
                "request {}: partial adopt outside the prefill window: {:?}",
                rid,
                names
            );
        }
        extend_events +=
            names.iter().filter(|n| **n == "extend_chunk").count() as u64;
    }
    // turns are submitted up-front so concurrent admission keeps some
    // from seeing the earlier turn's pages; at least one must warm-start
    assert!(
        partial_turns >= 1,
        "no dialog turn warm-started partially"
    );
    assert_eq!(
        extend_events,
        sched.metrics.extend_calls,
        "ExtendChunk events disagree with the extend-call metric"
    );
    assert_eq!(sched.metrics.extend_calls, engine.extend_calls());
    assert!(extend_events > 0, "warm turns recompute suffixes in chunks");
    assert!(
        o.trace.iter().any(|r| r.event.name() == "decode_step"),
        "decode steps were journaled"
    );
    // the phase histograms saw the run: one cold prefill, warm replays,
    // and per-step decode samples
    assert!(o.prefill_ms.count() >= 1);
    assert!(o.partial_replay_ms.count() >= 1);
    assert!(o.decode_step_ms.count() > 0);
}

#[test]
fn tiny_budget_rejects_gracefully() {
    if !artifacts_present() {
        return;
    }
    // 1 KiB cannot hold a single token's KV → every request is rejected
    let (server, addr) = spawn_server(
        PolicyKind::hae_default(),
        1,
        Some(1024),
        SchedPolicy::Fifo,
        true,
        2,
    );
    assert!(wait_listening(&addr), "server came up");

    for id in 0..4 {
        let payload = format!(r#"{{"id": {}, "kind": "qa"}}"#, id);
        let resp = client_request(&addr, &payload).unwrap();
        let j = Json::parse(&resp).unwrap();
        let err = j.get("error").and_then(|v| v.as_str()).unwrap_or("");
        assert!(err.contains("kv budget"), "expected budget rejection: {}", resp);
        // rejections still echo the request id
        assert_eq!(j.get("id").and_then(|v| v.as_i64()), Some(id));
    }

    // the server stays alive and accounts the rejections
    let stats =
        Json::parse(&client_request(&addr, r#"{"kind": "stats"}"#).unwrap()).unwrap();
    assert_eq!(get_num(&stats, "rejected_kv_budget") as usize, 4);
    assert_eq!(get_num(&stats, "completed") as usize, 0);

    let _ = client_request(&addr, "shutdown");
    let _ = server.join();
}
