//! Scheduler integration: N concurrent clients against the
//! continuous-batching server — responses match their request ids, lanes
//! are actually shared, and the KV-budget admission invariant holds.
//! Skipped when artifacts are absent.

use hae_serve::cache::PolicyKind;
use hae_serve::harness::{artifact_dir, spawn_server, wait_listening, widest_batch};
use hae_serve::model::Manifest;
use hae_serve::runtime::Runtime;
use hae_serve::scheduler::SchedPolicy;
use hae_serve::server::client_request;
use hae_serve::util::json::Json;

fn artifacts_present() -> bool {
    if Runtime::load(&artifact_dir()).is_err() {
        eprintln!("skipping: artifacts not built");
        return false;
    }
    true
}

fn get_num(j: &Json, key: &str) -> f64 {
    j.get(key).and_then(|v| v.as_f64()).unwrap_or(-1.0)
}

#[test]
fn concurrent_clients_share_lanes_under_budget() {
    if !artifacts_present() {
        return;
    }
    const ADDR: &str = "127.0.0.1:8495";
    let manifest = Manifest::load(&artifact_dir()).unwrap();
    let batch = widest_batch();
    // explicit budget = the physical ceiling: tight enough that the
    // invariant check is real, loose enough that all lanes can fill
    let budget = batch
        * (manifest.shapes.cache_capacity - 1)
        * manifest.model.kv_bytes_per_token();
    let server = spawn_server(
        ADDR.into(),
        PolicyKind::hae_default(),
        batch,
        Some(budget),
        SchedPolicy::Priority,
    );
    assert!(wait_listening(ADDR), "server came up");

    // 6 concurrent clients × 2 requests, every id unique
    let n_clients: i64 = 6;
    let per_client: i64 = 2;
    let mut handles = Vec::new();
    for c in 0..n_clients {
        handles.push(std::thread::spawn(move || {
            for i in 0..per_client {
                let id = c * 100 + i;
                let kind = if (c + i) % 2 == 0 { "story" } else { "mixed" };
                let payload = format!(
                    r#"{{"id": {}, "kind": "{}", "max_new": 24}}"#,
                    id, kind
                );
                let resp = client_request(ADDR, &payload).unwrap();
                let j = Json::parse(&resp).unwrap();
                // (a) the response carries this request's id
                assert_eq!(
                    j.get("id").and_then(|v| v.as_i64()),
                    Some(id),
                    "response/request id mismatch: {}",
                    resp
                );
                assert!(j.get("error").is_none(), "unexpected error: {}", resp);
                assert!(
                    j.get("tokens").and_then(|v| v.as_arr()).map_or(0, |a| a.len()) > 0
                );
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    let stats = Json::parse(&client_request(ADDR, r#"{"kind": "stats"}"#).unwrap()).unwrap();
    let _ = client_request(ADDR, "shutdown");
    let _ = server.join();

    assert_eq!(
        get_num(&stats, "completed"),
        (n_clients * per_client) as f64,
        "stats: {}",
        stats.to_string_compact()
    );
    // (b) at least one decode step ran more than one lane
    if batch > 1 {
        assert!(
            get_num(&stats, "max_lanes_step") >= 2.0,
            "continuous batching never shared a step: {}",
            stats.to_string_compact()
        );
    }
    // (c) the admission invariant: aggregate live KV never passed the
    // budget at any decode step
    let peak = get_num(&stats, "peak_live_kv_bytes");
    assert!(peak > 0.0, "no KV accounted: {}", stats.to_string_compact());
    assert!(
        peak <= budget as f64,
        "budget invariant violated: peak {} > budget {}",
        peak,
        budget
    );
}

#[test]
fn tiny_budget_rejects_gracefully() {
    if !artifacts_present() {
        return;
    }
    const ADDR: &str = "127.0.0.1:8496";
    // 1 KiB cannot hold a single token's KV → every request is rejected
    let server = spawn_server(
        ADDR.into(),
        PolicyKind::hae_default(),
        1,
        Some(1024),
        SchedPolicy::Fifo,
    );
    assert!(wait_listening(ADDR), "server came up");

    for id in 0..4 {
        let payload = format!(r#"{{"id": {}, "kind": "qa"}}"#, id);
        let resp = client_request(ADDR, &payload).unwrap();
        let j = Json::parse(&resp).unwrap();
        let err = j.get("error").and_then(|v| v.as_str()).unwrap_or("");
        assert!(err.contains("kv budget"), "expected budget rejection: {}", resp);
        // rejections still echo the request id
        assert_eq!(j.get("id").and_then(|v| v.as_i64()), Some(id));
    }

    // the server stays alive and accounts the rejections
    let stats = Json::parse(&client_request(ADDR, r#"{"kind": "stats"}"#).unwrap()).unwrap();
    assert_eq!(get_num(&stats, "rejected_kv_budget") as usize, 4);
    assert_eq!(get_num(&stats, "completed") as usize, 0);

    let _ = client_request(ADDR, "shutdown");
    let _ = server.join();
}
