//! KV-budget admission control.
//!
//! The controller guards one invariant (checked every decode step by
//! tests/scheduler_e2e.rs): **the sum of live slab `kv_bytes` across all
//! decode lanes never exceeds the configured budget.**
//!
//! A lane's live KV can only grow by one slot per decode step (the token
//! just processed) and the engine hard-caps it at `capacity_limit`, so a
//! lane admitted with `g` tokens already generated out of `max_new` can
//! never exceed
//!
//! ```text
//! bound(lane) = min(live_slots + (max_new - g), capacity_limit) * kv_bytes_per_token
//! ```
//!
//! Admitting a candidate only when `Σ bound(live lanes) + worst_case(candidate)`
//! fits the budget therefore guarantees the invariant without ever
//! re-checking mid-flight. Crucially `bound` is computed from the lane's
//! *live* slot count: every slot an eviction policy reclaims lowers the
//! aggregate bound immediately, which is exactly how HAE's eviction
//! converts into admission headroom — a budget that fits N full-cache
//! requests fits strictly more HAE requests.

use crate::coordinator::ActiveRequest;
use crate::workload::Request;

#[derive(Debug, Clone, Copy)]
pub struct AdmissionController {
    /// aggregate live-KV budget in bytes
    pub kv_budget: usize,
    /// bytes of one cache slot (K+V for one token across all layers)
    pub kv_bytes_per_token: usize,
    /// hard per-lane slot limit (cache_capacity - 1)
    pub capacity_limit: usize,
}

impl AdmissionController {
    /// Worst-case live KV of a not-yet-admitted request: the whole prompt
    /// is retained at prefill, then one slot per generated token, capped
    /// by the physical lane limit.
    pub fn worst_case_bytes(&self, req: &Request) -> usize {
        (req.prompt_len() + req.max_new_tokens).min(self.capacity_limit)
            * self.kv_bytes_per_token
    }

    /// Upper bound on a live lane's KV at any future step (see module
    /// docs). Non-increasing over the lane's lifetime; eviction lowers it.
    pub fn lane_bound_bytes(&self, ar: &ActiveRequest) -> usize {
        let remaining = ar.req.max_new_tokens.saturating_sub(ar.generated.len());
        (ar.slab.len() + remaining).min(self.capacity_limit) * self.kv_bytes_per_token
    }

    /// Could this request ever be admitted on an idle system? Submissions
    /// failing this are rejected immediately (they would wait forever).
    pub fn fits_alone(&self, req: &Request) -> bool {
        self.worst_case_bytes(req) <= self.kv_budget
    }

    /// Admission test given the summed bound of the currently-live lanes.
    pub fn admits(&self, live_bound_bytes: usize, req: &Request) -> bool {
        live_bound_bytes.saturating_add(self.worst_case_bytes(req)) <= self.kv_budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{KvSlab, PolicyKind};
    use crate::coordinator::RequestStats;
    use crate::model::ModelMeta;
    use crate::workload::WorkloadKind;

    fn tiny_meta() -> ModelMeta {
        ModelMeta {
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 2,
            d_mlp: 8,
            patch_dim: 4,
            n_patches: 4,
            max_pos: 64,
            dap_layer: 1,
        }
    }

    fn req(prompt: usize, max_new: usize) -> Request {
        Request {
            id: 0,
            kind: WorkloadKind::Story,
            ids: vec![1; prompt],
            patches: Vec::new(),
            is_vision: vec![false; prompt],
            max_new_tokens: max_new,
            min_new_tokens: 0,
            expected_answer: None,
            images: Vec::new(),
        }
    }

    fn ctl(budget_slots: usize) -> AdmissionController {
        let per_tok = tiny_meta().kv_bytes_per_token();
        AdmissionController {
            kv_budget: budget_slots * per_tok,
            kv_bytes_per_token: per_tok,
            capacity_limit: 15,
        }
    }

    #[test]
    fn worst_case_clamps_at_capacity() {
        let c = ctl(100);
        assert_eq!(c.worst_case_bytes(&req(4, 4)), 8 * c.kv_bytes_per_token);
        // 30 + 30 tokens can never exceed the 15-slot lane limit
        assert_eq!(c.worst_case_bytes(&req(30, 30)), 15 * c.kv_bytes_per_token);
    }

    #[test]
    fn admits_at_boundary_only() {
        let c = ctl(10);
        assert!(c.fits_alone(&req(6, 4)));
        assert!(!c.fits_alone(&req(7, 4)));
        // two slots of live bound already spoken for
        assert!(c.admits(2 * c.kv_bytes_per_token, &req(4, 4)));
        assert!(!c.admits(3 * c.kv_bytes_per_token, &req(4, 4)));
    }

    #[test]
    fn lane_bound_shrinks_with_eviction_and_progress() {
        let m = tiny_meta();
        let c = ctl(100);
        let mut slab = KvSlab::new(&m, 16);
        let row = vec![0.0f32; m.n_layers * m.n_heads * m.d_head];
        for i in 0..6 {
            slab.append(&row, &row, i, crate::cache::Modality::Text, 0.0);
        }
        let mut ar = ActiveRequest {
            req: req(6, 10),
            slab,
            policy: PolicyKind::Full.build(),
            generated: vec![1, 2],
            pos: 8,
            prefill_len: 6,
            pending_token: 2,
            done: false,
            forced: None,
            logits_trace: Vec::new(),
            score_trace: Vec::new(),
            evictions: Vec::new(),
            stats: RequestStats::default(),
        };
        // 6 live + 8 remaining of 10
        assert_eq!(c.lane_bound_bytes(&ar), 14 * c.kv_bytes_per_token);
        // eviction frees admission headroom immediately
        ar.slab.evict(&[0, 1, 2]);
        assert_eq!(c.lane_bound_bytes(&ar), 11 * c.kv_bytes_per_token);
        // progress shrinks the bound too
        ar.generated.extend([3, 4]);
        assert_eq!(c.lane_bound_bytes(&ar), 9 * c.kv_bytes_per_token);
    }
}
