//! Page-granular KV admission control.
//!
//! The controller guards one invariant (checked every decode step by
//! tests/scheduler_e2e.rs): **the pages held by live lanes in the shared
//! arena never exceed the page budget** — and therefore aggregate live
//! KV bytes never exceed `--kv-budget`.
//!
//! A lane's live KV can only grow by one slot per decode step (the token
//! just processed) and the engine hard-caps it at `capacity_limit`, so a
//! lane admitted with `g` tokens already generated out of `max_new` can
//! never hold more than
//!
//! ```text
//! bound(lane) = pages(min(live_slots + (max_new - g), capacity_limit))
//! ```
//!
//! arena pages, where `pages(n) = ⌈n / page_slots⌉`. Admitting a
//! candidate only when `Σ bound(live lanes) + reserved + pages(candidate
//! worst case)` fits the page budget guarantees the invariant without
//! ever re-checking mid-flight. `bound` is computed from the lane's
//! *live* slot count: every page an eviction policy frees lowers the
//! aggregate bound immediately, which is exactly how HAE's eviction
//! converts into admission headroom — a budget that fits N full-cache
//! requests fits strictly more HAE requests.
//!
//! Reserving **pages, not worst-case bytes**, is also what enables
//! chunked-prefill admission (scheduler/mod.rs): a prompt larger than
//! the currently-free pool is not head-of-line blocked until its whole
//! worst case fits at once — it accumulates page reservations chunk by
//! chunk as lanes evict and retire (`reserved` above), and prefill runs
//! once the reservation covers the target.

use crate::cache::pages_for_slots;
use crate::coordinator::ActiveRequest;
use crate::workload::Request;

#[derive(Debug, Clone, Copy)]
pub struct AdmissionController {
    /// aggregate budget in arena pages
    pub budget_pages: usize,
    /// token slots per arena page
    pub page_slots: usize,
    /// hard per-lane slot limit (cache_capacity - 1)
    pub capacity_limit: usize,
    /// bytes of one cache slot (metrics/reporting only — admission math
    /// is in pages)
    pub kv_bytes_per_token: usize,
}

impl AdmissionController {
    /// Derive the page budget from a byte budget and an arena geometry.
    /// Conservative: a partial page of budget is no page at all, so the
    /// byte invariant `live kv_bytes ≤ kv_budget` follows from the page
    /// invariant.
    pub fn from_bytes(
        kv_budget: usize,
        pool_pages: usize,
        page_slots: usize,
        capacity_limit: usize,
        kv_bytes_per_token: usize,
    ) -> Self {
        let page_bytes = page_slots.max(1) * kv_bytes_per_token.max(1);
        AdmissionController {
            budget_pages: (kv_budget / page_bytes).min(pool_pages),
            page_slots: page_slots.max(1),
            capacity_limit,
            kv_bytes_per_token,
        }
    }

    /// Pages needed for `slots` live token slots.
    pub fn pages_for(&self, slots: usize) -> usize {
        pages_for_slots(slots, self.page_slots)
    }

    /// Worst-case live slots of a not-yet-admitted request: the whole
    /// prompt is retained at prefill, then one slot per generated token,
    /// capped by the physical lane limit.
    pub fn worst_case_slots(&self, req: &Request) -> usize {
        (req.prompt_len() + req.max_new_tokens).min(self.capacity_limit)
    }

    /// Worst-case arena pages of a not-yet-admitted request — the
    /// chunked-prefill reservation target.
    pub fn worst_case_pages(&self, req: &Request) -> usize {
        self.pages_for(self.worst_case_slots(req))
    }

    /// Upper bound on a live lane's *privately charged* arena pages at
    /// any future step (see module docs). Pages the lane maps shared
    /// (prefix-cache adoption, CoW) are excluded here and charged once
    /// globally by the scheduler's shared-charge term — except the
    /// **fork allowance**: the shared partial tail page, which the
    /// lane's first append forks into a fresh allocation. The tail stays
    /// in this private bound AND in the global shared charge; the double
    /// charge is deliberate — it reserves the fork's fresh page while
    /// the forked-off original keeps living under the cache pin, so
    /// `ensure_private` is never the first allocation to see an empty
    /// pool on the append path. (PR 3 excluded the tail from the global
    /// charge as a "double-charge" — leaving the forked-off original
    /// uncharged was the arithmetic hole behind the fork-exhaustion
    /// panic.)
    ///
    /// Eviction and generation progress lower the bound. A CoW fork of a
    /// *stable* shared page (the policy evicting inside the shared
    /// prefix) still moves that page from the global charge into this
    /// bound, so the aggregate can transiently exceed what admission
    /// reserved by the forked count — but that path is no longer a
    /// panic: `KvSlab::try_evict` defers the eviction until pages free
    /// (retirements, cache reclaim), the scheduler re-evaluates every
    /// tick, and the capacity wall has a fork-free fallback. The
    /// remaining optimism is a latency trade, not a crash.
    pub fn lane_bound_pages(&self, ar: &ActiveRequest) -> usize {
        let remaining = ar.req.max_new_tokens.saturating_sub(ar.generated.len());
        let nominal =
            self.pages_for((ar.slab.len() + remaining).min(self.capacity_limit));
        let shared = ar.slab.shared_pages();
        let fork_allowance = ar.slab.fork_allowance_pages();
        nominal.saturating_sub(shared) + fork_allowance
    }

    /// Candidate charge for a *partial* prefix-cache hit: the suffix's
    /// new pages plus a fork allowance covering every adopted prefix
    /// page — the replayed retention decision may compact inside the
    /// shared prefix and fork any of them, and the suffix extension
    /// forks the partial tail. The two terms sum to the full worst case
    /// (which is why partial candidates simply take `worst_case_pages`,
    /// discount 0): the latency win of a partial hit is the skipped
    /// prefill, not admission width.
    ///
    /// NOT a hot-path knob: the charge materializes in serving as
    /// `PrefixCache::peek_discount` returning 0 for prefix entries, so
    /// admission falls through to the undiscounted worst case. This
    /// function states that identity explicitly (and the test below
    /// pins it) — change the discount there, not here.
    pub fn partial_candidate_pages(&self, req: &Request, prefix_tokens: usize) -> usize {
        let total = self.worst_case_pages(req);
        let adopted = self.pages_for(prefix_tokens.min(self.worst_case_slots(req)));
        let suffix_pages = total - adopted;
        let fork_allowance = adopted;
        suffix_pages + fork_allowance
    }

    /// Pages one suffix-recompute chunk of `chunk_tokens` rows may claim
    /// from the pool when it lands: the appended slots' new pages (at
    /// most ⌈chunk/page_slots⌉ — appends are contiguous) plus the
    /// partial-tail fork the chunk's first append can trigger. A partial
    /// warm start's reservation is charged whole at admission
    /// (`partial_candidate_pages`) but *claimed* in these increments as
    /// the chunk loop runs — the same claim-as-you-go shape as a
    /// chunked-prefill reservation, so cache pins convert to free pages
    /// only when the chunk that needs them arrives.
    pub fn extend_chunk_claim(&self, chunk_tokens: usize) -> usize {
        self.pages_for(chunk_tokens.max(1)) + 1
    }

    /// Device calls a chunked suffix recompute issues: ⌈suffix/chunk⌉.
    /// The acceptance bound `RequestStats::extend_calls` is tested
    /// against (chunk 1 degenerates to the one-call-per-token loop).
    pub fn extend_chunk_calls(suffix_tokens: usize, chunk: usize) -> usize {
        suffix_tokens.div_ceil(chunk.max(1))
    }

    /// Could this request ever be admitted on an idle system? Submissions
    /// failing this are rejected immediately (they would wait forever).
    pub fn fits_alone(&self, req: &Request) -> bool {
        self.worst_case_pages(req) <= self.budget_pages
    }

    /// Admission test given the summed bound of the currently-live lanes
    /// and the pages pinned by a chunked-prefill reservation.
    pub fn admits(&self, live_bound_pages: usize, reserved_pages: usize, req: &Request) -> bool {
        self.admits_pages(live_bound_pages, reserved_pages, self.worst_case_pages(req))
    }

    /// Page-level admission test: `reserved_pages` carries everything
    /// charged besides the live bounds (chunked-prefill reservations and
    /// the charged-once shared pages of the prefix cache), and
    /// `candidate_pages` is the candidate's worst case minus any
    /// prefix-cache discount the caller established.
    pub fn admits_pages(
        &self,
        live_bound_pages: usize,
        reserved_pages: usize,
        candidate_pages: usize,
    ) -> bool {
        self.shortfall_pages(live_bound_pages, reserved_pages, candidate_pages) == 0
    }

    /// Pages the candidate is short of admission by (0 = admitted). The
    /// admission loops compare this against the prefix cache's
    /// reclaimable pins before evicting anything: flushing warm entries
    /// for a candidate that cannot be admitted anyway would destroy hit
    /// state for no gain.
    pub fn shortfall_pages(
        &self,
        live_bound_pages: usize,
        reserved_pages: usize,
        candidate_pages: usize,
    ) -> usize {
        live_bound_pages
            .saturating_add(reserved_pages)
            .saturating_add(candidate_pages)
            .saturating_sub(self.budget_pages)
    }

    /// Pages a chunked-prefill reservation may grab right now: free
    /// budget not spoken for by live bounds or the existing reservation,
    /// capped at what the target still needs.
    pub fn reservation_grab(
        &self,
        live_bound_pages: usize,
        reserved_pages: usize,
        target_pages: usize,
    ) -> usize {
        let headroom = self
            .budget_pages
            .saturating_sub(live_bound_pages)
            .saturating_sub(reserved_pages);
        target_pages.saturating_sub(reserved_pages).min(headroom)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::cache::{KvSlab, PolicyKind};
    use crate::coordinator::RequestStats;
    use crate::model::ModelMeta;
    use crate::workload::WorkloadKind;

    fn tiny_meta() -> ModelMeta {
        ModelMeta {
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 2,
            d_mlp: 8,
            patch_dim: 4,
            n_patches: 4,
            max_pos: 64,
            dap_layer: 1,
        }
    }

    fn req(prompt: usize, max_new: usize) -> Request {
        Request {
            id: 0,
            kind: WorkloadKind::Story,
            ids: vec![1; prompt],
            patches: Vec::new(),
            is_vision: vec![false; prompt],
            max_new_tokens: max_new,
            min_new_tokens: 0,
            expected_answer: None,
            images: Vec::new(),
        }
    }

    /// 4-slot pages, page budget given directly.
    fn ctl(budget_pages: usize) -> AdmissionController {
        AdmissionController {
            budget_pages,
            page_slots: 4,
            capacity_limit: 15,
            kv_bytes_per_token: tiny_meta().kv_bytes_per_token(),
        }
    }

    #[test]
    fn worst_case_rounds_to_pages_and_clamps_at_capacity() {
        let c = ctl(100);
        // 4 + 4 = 8 slots → 2 pages; 4 + 5 = 9 slots → 3 pages
        assert_eq!(c.worst_case_pages(&req(4, 4)), 2);
        assert_eq!(c.worst_case_pages(&req(4, 5)), 3);
        // 30 + 30 tokens can never exceed the 15-slot lane limit
        assert_eq!(c.worst_case_pages(&req(30, 30)), 4);
    }

    #[test]
    fn admits_at_boundary_only() {
        let c = ctl(3);
        assert!(c.fits_alone(&req(6, 4))); // 10 slots → 3 pages
        assert!(!c.fits_alone(&req(9, 4))); // 13 slots → 4 pages
        // one page of live bound already spoken for
        assert!(c.admits(1, 0, &req(4, 4)));
        assert!(!c.admits(2, 0, &req(4, 4)));
        // a chunked reservation counts against headroom too
        assert!(!c.admits(1, 1, &req(4, 4)));
    }

    #[test]
    fn from_bytes_is_conservative() {
        let per_tok = tiny_meta().kv_bytes_per_token();
        // 9.5 pages of bytes → 9-page budget, clamped by the pool
        let c = AdmissionController::from_bytes(
            per_tok * 4 * 9 + per_tok * 2,
            8,
            4,
            100,
            per_tok,
        );
        assert_eq!(c.budget_pages, 8);
        let c = AdmissionController::from_bytes(per_tok * 4 * 9, 100, 4, 100, per_tok);
        assert_eq!(c.budget_pages, 9);
        // unbounded byte budget saturates at the pool size
        let c = AdmissionController::from_bytes(usize::MAX, 17, 4, 100, per_tok);
        assert_eq!(c.budget_pages, 17);
    }

    #[test]
    fn lane_bound_shrinks_with_eviction_and_progress() {
        let m = tiny_meta();
        let c = ctl(100);
        let mut slab = KvSlab::new(&m, 16);
        let row = vec![0.0f32; m.n_layers * m.n_heads * m.d_head];
        for i in 0..6 {
            slab.append(&row, &row, i, crate::cache::Modality::Text, 0.0);
        }
        let mut ar = ActiveRequest {
            req: req(6, 10),
            slab,
            policy: PolicyKind::Full.build(),
            generated: vec![1, 2],
            pos: 8,
            prefill_len: 6,
            pending_token: 2,
            done: false,
            forced: None,
            logits_trace: Vec::new(),
            score_trace: Vec::new(),
            evictions: Vec::new(),
            stats: RequestStats::default(),
        };
        // 6 live + 8 remaining of 10 = 14 slots → 4 pages
        assert_eq!(c.lane_bound_pages(&ar), 4);
        // eviction frees admission headroom immediately: 11 slots → 3 pages
        ar.slab.evict(&[0, 1, 2]);
        assert_eq!(c.lane_bound_pages(&ar), 3);
        // progress shrinks the bound too: 3 live + 6 remaining = 9 → 3 pages,
        // then two more generated → 7 slots → 2 pages
        ar.generated.extend([3, 4]);
        assert_eq!(c.lane_bound_pages(&ar), 3);
        ar.generated.extend([5, 6]);
        assert_eq!(c.lane_bound_pages(&ar), 2);
    }

    #[test]
    fn admits_pages_charges_shared_once() {
        let c = ctl(10);
        // live bounds 4 + (reservation + shared charge) 3 + candidate 3
        assert!(c.admits_pages(4, 3, 3));
        assert!(!c.admits_pages(4, 4, 3));
        // the method backing `admits` is the same arithmetic
        assert_eq!(c.admits(4, 3, &req(8, 4)), c.admits_pages(4, 3, 3));
    }

    #[test]
    fn lane_bound_discounts_stable_shared_pages() {
        let m = tiny_meta();
        let c = ctl(100);
        // 4-slot pages to match the controller's geometry
        let pool = crate::cache::PagePool::new_shared(
            m.n_layers,
            m.n_heads * m.d_head,
            8,
            4,
        );
        let row = vec![0.0f32; m.n_layers * m.n_heads * m.d_head];
        let mut donor = KvSlab::in_pool(&pool, 16);
        for i in 0..6 {
            donor.append(&row, &row, i, crate::cache::Modality::Text, 0.0);
        }
        let pages = donor.mark_all_shared();
        let meta = donor.meta().to_vec();
        // simulate the prefix cache pinning the pages
        {
            let mut p = pool.lock().unwrap();
            for &pg in &pages {
                p.retain_page(pg);
            }
        }
        let mut slab = KvSlab::in_pool(&pool, 16);
        assert!(slab.adopt_shared(&pages, meta));
        let ar = ActiveRequest {
            req: req(6, 10),
            slab,
            policy: PolicyKind::Full.build(),
            generated: Vec::new(),
            pos: 6,
            prefill_len: 6,
            pending_token: 0,
            done: false,
            forced: None,
            logits_trace: Vec::new(),
            score_trace: Vec::new(),
            evictions: Vec::new(),
            stats: RequestStats::default(),
        };
        // 6 live + 10 remaining = 16 slots, clamped to the 15-slot lane
        // limit → 4 pages; minus the one *stable* shared page (the full
        // page 0 — the partial tail page forks on the first append, so
        // it stays in the private bound)
        assert_eq!(ar.slab.shared_pages(), 2);
        assert_eq!(ar.slab.shared_pages_stable(), 1);
        assert_eq!(c.lane_bound_pages(&ar), 3);
    }

    #[test]
    fn partial_candidates_are_charged_suffix_plus_fork_allowance() {
        let c = ctl(100);
        // prompt 10 + max_new 4 = 14 slots → 4 pages; prefix 8 tokens →
        // 2 adopted pages. suffix pages = 2, fork allowance = 2 → the
        // full worst case: partial hits earn no admission discount
        let r = req(10, 4);
        assert_eq!(c.partial_candidate_pages(&r, 8), 4);
        assert_eq!(c.partial_candidate_pages(&r, 8), c.worst_case_pages(&r));
        // degenerate boundaries stay within the worst case
        assert_eq!(c.partial_candidate_pages(&r, 0), c.worst_case_pages(&r));
        assert_eq!(c.partial_candidate_pages(&r, 1000), c.worst_case_pages(&r));
    }

    #[test]
    fn lane_bound_keeps_the_tail_fork_allowance() {
        // a lane bound = nominal − shared + fork allowance: with a shared
        // partial tail, the allowance keeps exactly that page charged
        // privately even though the tail is also in the global shared
        // charge — the double charge IS the fork reservation
        let m = tiny_meta();
        let c = ctl(100);
        let pool = crate::cache::PagePool::new_shared(
            m.n_layers,
            m.n_heads * m.d_head,
            8,
            4,
        );
        let row = vec![0.0f32; m.n_layers * m.n_heads * m.d_head];
        let mut donor = KvSlab::in_pool(&pool, 16);
        for i in 0..6 {
            donor.append(&row, &row, i, crate::cache::Modality::Text, 0.0);
        }
        let pages = donor.mark_all_shared();
        {
            let mut p = pool.lock().unwrap();
            for &pg in &pages {
                p.retain_page(pg);
            }
        }
        let mut slab = KvSlab::in_pool(&pool, 16);
        assert!(slab.adopt_shared(&pages, donor.meta().to_vec()));
        assert_eq!(slab.fork_allowance_pages(), 1, "partial tail");
        let ar = ActiveRequest {
            req: req(6, 10),
            slab,
            policy: PolicyKind::Full.build(),
            generated: Vec::new(),
            pos: 6,
            prefill_len: 6,
            pending_token: 0,
            done: false,
            forced: None,
            logits_trace: Vec::new(),
            score_trace: Vec::new(),
            evictions: Vec::new(),
            stats: RequestStats::default(),
        };
        // nominal 4 (15-slot clamp) − 2 shared + 1 tail allowance = 3
        assert_eq!(c.lane_bound_pages(&ar), 3);
    }

    #[test]
    fn extend_chunk_claims_and_call_counts() {
        let c = ctl(100); // 4-slot pages
        // a chunk's claim: its own pages + the possible tail fork
        assert_eq!(c.extend_chunk_claim(1), 2);
        assert_eq!(c.extend_chunk_claim(4), 2);
        assert_eq!(c.extend_chunk_claim(8), 3);
        assert_eq!(c.extend_chunk_claim(0), 2, "clamped to one token");
        // the chunk-wise claims cover the suffix's total append bound
        let suffix = 22usize;
        let chunk = 8usize;
        let calls = AdmissionController::extend_chunk_calls(suffix, chunk);
        assert_eq!(calls, 3);
        let claimed: usize = (0..calls)
            .map(|i| c.extend_chunk_claim(chunk.min(suffix - i * chunk)))
            .sum();
        assert!(claimed >= c.pages_for(suffix) + 1);
        // chunk 1 degenerates to one call per token (the decode loop)
        assert_eq!(AdmissionController::extend_chunk_calls(suffix, 1), suffix);
        assert_eq!(AdmissionController::extend_chunk_calls(suffix, 0), suffix);
        assert_eq!(AdmissionController::extend_chunk_calls(0, 8), 0);
        assert_eq!(AdmissionController::extend_chunk_calls(suffix, 1000), 1);
    }

    #[test]
    fn chunked_reservation_accumulates_to_target() {
        // simulate the scheduler's reservation loop: a 4-page candidate
        // against a 5-page budget while a live lane's bound shrinks from
        // 4 pages to 0 — the candidate must reach its target in chunks
        // and never let (bound + reserved) pass the budget
        let c = ctl(5);
        let target = 4usize;
        let mut reserved = 0usize;
        let mut grabs = Vec::new();
        for live_bound in [4usize, 3, 2, 0] {
            let grab = c.reservation_grab(live_bound, reserved, target);
            assert!(live_bound + reserved + grab <= c.budget_pages);
            reserved += grab;
            grabs.push(grab);
        }
        assert_eq!(reserved, target);
        assert!(grabs.len() > 2, "accumulated across several rounds: {:?}", grabs);
        // once reserved, nothing more is grabbed
        assert_eq!(c.reservation_grab(0, reserved, target), 0);
    }
}
