//! Continuous-batching scheduler with KV-budget admission control — the
//! serving-scale layer between the TCP front end and the engine.
//!
//! # Architecture
//!
//! The scheduler owns the engine's decode lanes (one slot per compiled
//! batch position) plus an admission queue. Each `tick`:
//!
//! 1. **Backfill** — free lanes are filled from the queue, best candidate
//!    first (`queue::SchedPolicy`: FIFO, or priority classes with
//!    starvation-free aging), but only if the KV-budget admission test
//!    passes (`admission::AdmissionController`). Admission runs prefill,
//!    so a request joins the batch *mid-flight* — nobody waits for the
//!    current batch to drain.
//! 2. **Decode** — one batched `Engine::decode_step` over every live lane
//!    (capacity-bucketed as before).
//! 3. **Retire** — finished lanes become buffered outcomes (collected
//!    with `take_outcomes`; the server replies per-connection) and their
//!    slots become backfill targets on the next tick.
//!
//! # The pipelined tick
//!
//! `tick` is also available split in two — [`Scheduler::begin_step`]
//! (backfill + submit the decode batch to the device thread) and
//! [`Scheduler::finish_step`] (collect, account, retire) — so a serving
//! loop can do host work *inside* the device window: deliver outcomes,
//! drain the ingest channel, and run [`Scheduler::overlap_backfill`] to
//! admit/prefill the next candidates into free lanes while the submitted
//! lanes compute. Backfill only ever writes `None` slots, and the
//! in-flight [`crate::coordinator::PendingStep`] addresses its lanes by
//! slot index, so overlap work never touches a submitted lane. The
//! realized overlap is aggregated into the `host_device_overlap_frac`
//! stats key (see `metrics::MetricsRegistry::record_overlap`).
//!
//! # The admission invariant
//!
//! Admission is **page-granular** over the engine's shared KV arena
//! (cache/paged.rs): at every decode step, the pages held by live lanes
//! never exceed the page budget — and therefore `Σ live slab kv_bytes ≤
//! kv_budget`. The controller admits a request only when the summed
//! *future page bound* of the live lanes plus any chunked-prefill
//! reservation plus the candidate's worst case fits the budget (see
//! admission.rs for the bound derivation). Because the bound is computed
//! from live slot counts, **every page the eviction policy reclaims is
//! admission headroom**: under HAE the same budget admits more concurrent
//! requests than Full Cache, which is how the paper's 41% per-request KV
//! reduction compounds into serving throughput
//! (benches/perf_serve_batch.rs measures exactly this).
//!
//! # Chunked-prefill admission
//!
//! A request whose worst case exceeds the *currently free* budget is no
//! longer head-of-line blocked until everything fits at once. The
//! scheduler pulls it into a pending slot and accumulates page
//! **reservations** chunk by chunk as live lanes evict and retire; freed
//! pages go to the pending request first (so a sustained stream of small
//! requests can never starve a large prompt), and any surplus still
//! admits smaller requests around it. Once the reservation covers the
//! worst case, prefill runs and the reservation converts into the lane's
//! live bound. `fits_alone` at submit time guarantees the target is
//! reachable, so the pending request always eventually runs.
//!
//! Metrics (queue depth, TTFT, lanes-occupied histogram, rejections,
//! aggregate KV bytes, pool occupancy/fragmentation/reuse) live in
//! `metrics::MetricsRegistry` and are served by the `{"kind": "stats"}`
//! request.

// hot-path panic discipline (hae-lint R3): violations need an inline
// #[allow] plus a reasoned suppression — see docs/STATIC_ANALYSIS.md
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod admission;
pub mod metrics;
pub mod queue;

pub use admission::AdmissionController;
pub use metrics::{MetricsRegistry, SloTable};
pub use queue::{class_of, AdmissionQueue, QueuedJob, SchedPolicy};

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::{ActiveRequest, Engine, PendingStep, StepReport};
use crate::obs::{Obs, RetireReason, SharedObs, TraceEvent};
use crate::util::json::Json;
use crate::workload::Request;

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// aggregate live-KV budget in bytes
    pub kv_budget: usize,
    pub policy: SchedPolicy,
    /// max jobs waiting for admission before rejection
    pub queue_depth: usize,
    /// scheduler ticks per priority-class promotion (queue aging)
    pub aging_ticks: u64,
    /// per-class latency SLO targets (`--slo`); empty = no attainment
    /// accounting
    pub slo: SloTable,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            kv_budget: usize::MAX,
            policy: SchedPolicy::Fifo,
            queue_depth: 64,
            aging_ticks: 256,
            slo: SloTable::default(),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    QueueFull,
    KvBudget,
}

impl RejectReason {
    pub fn message(&self) -> &'static str {
        match self {
            RejectReason::QueueFull => "admission queue full",
            RejectReason::KvBudget => "kv budget exceeded: request can never fit",
        }
    }
}

/// A request leaving the scheduler, tagged with the caller's context.
pub enum SchedOutcome<T> {
    Done { tag: T, ar: Box<ActiveRequest> },
    Failed { tag: T, error: String },
}

struct LaneTag<T> {
    tag: T,
    enqueued_at: Instant,
}

/// A request pulled out of the queue for chunked-prefill admission: it
/// accumulates page reservations across ticks until `reserved` covers
/// `target`, then prefills into the next free lane.
struct PendingPrefill<T> {
    job: QueuedJob<T>,
    reserved: usize,
    target: usize,
}

pub struct Scheduler<T> {
    cfg: SchedulerConfig,
    admission: AdmissionController,
    queue: AdmissionQueue<T>,
    /// decode lanes, indexed to match `tags` (None = free slot)
    lanes: Vec<Option<ActiveRequest>>,
    tags: Vec<Option<LaneTag<T>>>,
    /// at most one chunked-prefill reservation at a time (head-of-line
    /// by admission order; freed pages top it up before anything else)
    pending: Option<PendingPrefill<T>>,
    /// outcomes produced but not yet collected via `take_outcomes` —
    /// buffered on self so a fatal tick error cannot drop replies that
    /// backfill already finished
    ready: Vec<SchedOutcome<T>>,
    pub metrics: MetricsRegistry,
    /// shared with the engine (`for_engine`) so the scheduler's lifecycle
    /// events and the engine's phase events land in one journal
    pub obs: SharedObs,
    tick_no: u64,
}

impl<T> Scheduler<T> {
    pub fn new(
        cfg: SchedulerConfig,
        batch: usize,
        kv_bytes_per_token: usize,
        capacity_limit: usize,
        page_slots: usize,
        pool_pages: usize,
    ) -> Self {
        let admission = AdmissionController::from_bytes(
            cfg.kv_budget,
            pool_pages,
            page_slots,
            capacity_limit,
            kv_bytes_per_token,
        );
        let queue = AdmissionQueue::new(cfg.policy, cfg.queue_depth, cfg.aging_ticks);
        let mut metrics =
            MetricsRegistry::new(batch, cfg.kv_budget, pool_pages, page_slots);
        metrics.set_slo(cfg.slo.clone());
        Scheduler {
            cfg,
            admission,
            queue,
            lanes: (0..batch).map(|_| None).collect(),
            tags: (0..batch).map(|_| None).collect(),
            pending: None,
            ready: Vec::new(),
            metrics,
            obs: Obs::shared(true),
            tick_no: 0,
        }
    }

    /// Derive lane count, arena geometry and admission constants from a
    /// built engine, and adopt its observability handle so scheduler and
    /// engine events interleave in one trace journal.
    pub fn for_engine(cfg: SchedulerConfig, engine: &Engine) -> Self {
        let mut sc = Self::new(
            cfg,
            engine.cfg.batch,
            engine.meta().kv_bytes_per_token(),
            engine.capacity_limit(),
            engine.page_slots(),
            engine.pool_pages(),
        );
        sc.obs = engine.obs();
        sc
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn lanes_occupied(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    /// Anything queued, reserving pages, or mid-flight?
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty()
            || self.pending.is_some()
            || self.lanes.iter().any(|l| l.is_some())
    }

    pub fn stats_json(&self) -> Json {
        let mut snap = self.metrics.snapshot(self.queue.len(), self.lanes_occupied());
        if let Json::Obj(map) = &mut snap {
            // additive nested block: engine-phase histogram summaries.
            // The flat legacy keys above it are frozen (snapshot test in
            // metrics.rs) — existing dashboards keep parsing unchanged.
            map.insert("phases".to_string(), self.obs.phases_json());
        }
        snap
    }

    /// Answer `{"kind":"trace", ...}`: a request's lifecycle by `id`, or
    /// the newest `last` events journal-wide.
    pub fn trace_json(&self, id: Option<u64>, last: Option<usize>) -> Json {
        self.obs.trace_json(id, last)
    }

    /// Full Prometheus exposition body: scheduler registry series followed
    /// by the engine-phase histograms.
    pub fn stats_prometheus(&self) -> String {
        let mut out = String::new();
        self.metrics
            .prometheus_into(&mut out, self.queue.len(), self.lanes_occupied());
        self.obs.prometheus_body(&mut out);
        out
    }

    /// Answer `{"kind":"profile"}`: the serving profiler's contention and
    /// queue spans (gated histograms — all zero-count with tracing off)
    /// plus the always-on device-thread totals folded each step.
    pub fn profile_json(&self) -> Json {
        use crate::util::json::{num, obj, s};
        obj(vec![
            ("kind", s("profile")),
            ("tracing", Json::Bool(self.obs.enabled())),
            ("spans", self.obs.profile_json()),
            (
                "device",
                obj(vec![
                    ("busy_us", num(self.metrics.device_busy_us as f64)),
                    ("send_wait_us", num(self.metrics.device_send_wait_us as f64)),
                    ("calls", num(self.metrics.device_calls as f64)),
                    ("queue_depth", num(self.metrics.device_queue_depth as f64)),
                    (
                        "peak_queue_depth",
                        num(self.metrics.peak_device_queue_depth as f64),
                    ),
                ]),
            ),
        ])
    }

    /// Enqueue a request. `Err` hands the tag back with the reject reason
    /// so the caller can reply immediately; rejection (rather than
    /// blocking) keeps the engine thread responsive under overload.
    pub fn submit(&mut self, tag: T, req: Request) -> Result<(), (T, RejectReason)> {
        self.metrics.submitted += 1;
        let rid = req.id;
        self.obs.event(rid, TraceEvent::Enqueued);
        if !self.admission.fits_alone(&req) {
            self.metrics.rejected_kv_budget += 1;
            self.obs
                .event(rid, TraceEvent::Retired { reason: RetireReason::Rejected });
            return Err((tag, RejectReason::KvBudget));
        }
        match self.queue.push(tag, req, self.tick_no) {
            Ok(()) => {
                self.metrics.record_queue_depth(self.queue.len());
                Ok(())
            }
            Err(tag) => {
                self.metrics.rejected_queue_full += 1;
                self.obs
                    .event(rid, TraceEvent::Retired { reason: RetireReason::Rejected });
                Err((tag, RejectReason::QueueFull))
            }
        }
    }

    /// Summed future page bound of the live lanes (admission.rs math).
    fn live_bound_pages(&self) -> usize {
        self.lanes
            .iter()
            .flatten()
            .map(|ar| self.admission.lane_bound_pages(ar))
            .sum()
    }

    /// Run prefill for an admitted job, landing it in `lane` (or straight
    /// into the outcome buffer when it finishes at prefill, or fails).
    fn admit_job(&mut self, engine: &mut Engine, lane: usize, job: QueuedJob<T>) {
        let QueuedJob { tag, req, enqueued_at, .. } = job;
        let rid = req.id;
        let kind = req.kind;
        let waited = enqueued_at.elapsed().as_secs_f64();
        self.metrics.record_queue_wait(kind, waited);
        let pages = self.admission.worst_case_pages(&req) as u32;
        self.obs.event(rid, TraceEvent::Admitted { pages });
        match engine.prefill(req) {
            Ok(mut ar) => {
                ar.stats.queue_s = waited;
                self.metrics.record_ttft(kind, enqueued_at.elapsed().as_secs_f64());
                if ar.done {
                    ar.slab.release_pages();
                    self.metrics.completed += 1;
                    self.metrics.record_e2e(kind, enqueued_at.elapsed().as_secs_f64());
                    self.obs
                        .event(rid, TraceEvent::Retired { reason: RetireReason::Completed });
                    self.ready.push(SchedOutcome::Done { tag, ar: Box::new(ar) });
                } else {
                    self.lanes[lane] = Some(ar);
                    self.tags[lane] = Some(LaneTag { tag, enqueued_at });
                }
            }
            Err(e) => {
                // e.g. prompt exceeds the largest prefill bucket
                self.metrics.failed += 1;
                self.obs
                    .event(rid, TraceEvent::Retired { reason: RetireReason::Failed });
                self.ready.push(SchedOutcome::Failed { tag, error: e.to_string() });
            }
        }
    }

    /// Fill free lanes from the queue while the page-granular admission
    /// test passes; oversized candidates accumulate chunked-prefill
    /// reservations instead of head-of-line blocking. Prefix-cache pins
    /// are charged once (`Engine::shared_charge_pages`) and are
    /// reclaimable: LRU entries are evicted whenever they are what
    /// stands between a candidate (or a starving reservation) and its
    /// pages. Per-request failures become buffered `Failed` outcomes,
    /// never errors — the serving loop must survive them.
    fn backfill(&mut self, engine: &mut Engine) {
        // 1. top up the chunked-prefill reservation first: pages freed by
        // eviction/retirement go to the oldest oversized request before
        // anything else can claim them (starvation-freedom). Reclaimable
        // cache pins (pages no live lane maps) yield to the reservation
        // too — otherwise a cache full of cold prefixes could starve it;
        // entries kept alive by live lanes are skipped, since evicting
        // them frees nothing
        if self.pending.is_some() {
            loop {
                let live = self.live_bound_pages();
                let shared = engine.shared_charge_pages(&self.lanes);
                let Some(p) = self.pending.as_mut() else { break };
                let grab =
                    self.admission.reservation_grab(live + shared, p.reserved, p.target);
                if grab >= p.target - p.reserved || !engine.prefix_reclaim_one() {
                    if grab > 0 {
                        p.reserved += grab;
                        self.metrics.chunk_reserved_pages += grab as u64;
                    }
                    break;
                }
                // an entry was evicted: recompute the grab with its pins gone
            }
        }
        // 2. launch the pending prefill once fully reserved and a lane is
        // free — the reservation converts into the lane's live bound
        if self.pending.as_ref().is_some_and(|p| p.reserved >= p.target) {
            if let Some(free) = self.lanes.iter().position(|l| l.is_none()) {
                if let Some(p) = self.pending.take() {
                    self.metrics.chunked_admits += 1;
                    self.admit_job(engine, free, p.job);
                }
            }
        }
        // 3. regular admission against the surplus the reservation leaves
        loop {
            let cand = match self.queue.select(self.tick_no) {
                Some(i) => i,
                None => return,
            };
            // the candidate's worst case is discounted by the pages a
            // prefix-cache hit would share (those are already in the
            // charged-once shared term); recomputed after each eviction,
            // since evicting could remove the very entry it would hit.
            // Reclaimable pins are evicted only while they can actually
            // close the shortfall AND a lane is free to take the
            // admission — a candidate that cannot land this tick must
            // not flush the cache for nothing
            let lane_free = self.lanes.iter().any(|l| l.is_none());
            let admitted = loop {
                let live = self.live_bound_pages();
                let shared = engine.shared_charge_pages(&self.lanes);
                let reserved = self.pending.as_ref().map_or(0, |p| p.reserved);
                let job = self.queue.peek(cand);
                let (probe_key, probe_fp) = &job.prefix_probe;
                let cand_pages = self
                    .admission
                    .worst_case_pages(&job.req)
                    .saturating_sub(engine.prefix_discount_probed(probe_key, *probe_fp));
                let shortfall =
                    self.admission.shortfall_pages(live, reserved + shared, cand_pages);
                if shortfall == 0 {
                    break true;
                }
                if !lane_free
                    || engine.prefix_reclaimable_pages() < shortfall
                    || !engine.prefix_reclaim_one()
                {
                    break false;
                }
            };
            if !admitted {
                if self.pending.is_none() {
                    // doesn't fit in one piece: start reserving for it.
                    // The target is the worst case whether or not a
                    // partial warm start ends up serving the candidate —
                    // `partial_candidate_pages` (suffix pages + fork
                    // allowance) sums to exactly this, so chunked prefill
                    // and chunked extension share one reservation path:
                    // pages accumulate here chunk-by-chunk, and the
                    // engine's extend loop later CLAIMS them
                    // chunk-by-chunk (`extend_chunk_claim`)
                    let job = self.queue.remove(cand);
                    let target = self.admission.worst_case_pages(&job.req);
                    let live = self.live_bound_pages();
                    let shared = engine.shared_charge_pages(&self.lanes);
                    let reserved =
                        self.admission.reservation_grab(live + shared, 0, target);
                    self.metrics.chunk_reserved_pages += reserved as u64;
                    self.pending = Some(PendingPrefill { job, reserved, target });
                    continue; // smaller jobs may still fit the surplus
                }
                // the pending reservation owns the freed pages — wait
                return;
            }
            let free = match self.lanes.iter().position(|l| l.is_none()) {
                Some(i) => i,
                None => return,
            };
            let job = self.queue.remove(cand);
            self.admit_job(engine, free, job);
        }
    }

    /// One scheduling round: backfill, one batched decode step, retire.
    /// Outcomes are buffered — collect them with `take_outcomes` after
    /// every tick, *including* a failed one: a decode error must not
    /// swallow replies that backfill already finished this round.
    ///
    /// This is the sequential composition of [`Self::begin_step`] and
    /// [`Self::finish_step`]; a pipelined serving loop calls those
    /// directly and does host work between them.
    pub fn tick(&mut self, engine: &mut Engine) -> Result<StepReport> {
        let pending = self.begin_step(engine)?;
        self.finish_step(engine, pending)
    }

    /// First half of a tick: backfill free lanes, then submit the decode
    /// batch to the device thread without waiting for it. `None` when no
    /// lane is live after backfill (queue empty or everything finished at
    /// prefill — collect outcomes and call [`Self::finish_step`] anyway
    /// to advance accounting).
    pub fn begin_step(&mut self, engine: &mut Engine) -> Result<Option<PendingStep>> {
        let t0 = self.obs.enabled().then(Instant::now);
        let pending = {
            self.backfill(engine);
            engine.step_submit(&mut self.lanes)
        };
        if let Some(t0) = t0 {
            self.obs
                .record(|o| o.profile.step_begin_ms.record(t0.elapsed().as_secs_f64() * 1e3));
        }
        pending
    }

    /// Overlap-window work: run another backfill round while a submitted
    /// step computes on the device thread. Safe by construction — the
    /// backfill only writes `None` lane slots and the in-flight step
    /// addresses its lanes by slot index, so submitted lanes are never
    /// touched. Admission, prefix probes, prefill and chunked extends of
    /// the next candidates all run here, inside the device window.
    pub fn overlap_backfill(&mut self, engine: &mut Engine) {
        self.backfill(engine);
    }

    /// Second half of a tick: collect the submitted step (blocking until
    /// the device reply arrives), fold the accounting, retire finished
    /// lanes into buffered outcomes.
    pub fn finish_step(
        &mut self,
        engine: &mut Engine,
        pending: Option<PendingStep>,
    ) -> Result<StepReport> {
        let t0 = self.obs.enabled().then(Instant::now);
        self.tick_no += 1;
        let (report, done) = match pending {
            Some(p) => engine.step_complete(p, &mut self.lanes)?,
            None => (StepReport::default(), Vec::new()),
        };
        if report.lanes > 0 {
            // aggregate *physical* live KV at this step, counting lanes
            // that finished during it: private pages by live slots, each
            // distinct shared page once (full-page granularity) — the
            // quantity the charged-once admission invariant bounds
            let page_bytes = self.admission.page_slots * self.admission.kv_bytes_per_token;
            let mut seen = std::collections::BTreeSet::new();
            let mut live = 0usize;
            for ar in self.lanes.iter().flatten().chain(done.iter().map(|(_, ar)| ar)) {
                live += ar.slab.kv_bytes_private();
                for p in ar.slab.shared_page_ids() {
                    if seen.insert(p) {
                        live += page_bytes;
                    }
                }
            }
            // The byte invariant is enforced through pool sizing: with a
            // budget of at least one full lane, the arena itself is
            // capped at ≤ kv_budget bytes, so physical live KV can never
            // exceed it — CoW fork divergence included, because forks
            // draw from the same capped pool and exhaustion DEFERS the
            // eviction instead of overcommitting. Only the clamped-up
            // floor (budget below one lane, where `fits_alone` rejects
            // every request anyway) leaves the pool larger than the
            // budget; there the documented transient fork overshoot is
            // bounded by the pool, which the page assert below covers.
            if engine.pool_pages() * page_bytes <= self.cfg.kv_budget {
                debug_assert!(
                    live <= self.cfg.kv_budget,
                    "admission invariant violated: {} live > {} budget",
                    live,
                    self.cfg.kv_budget
                );
            }
            self.metrics.record_step(report.lanes, live);
            self.metrics.pages_copied += report.pages_copied as u64;
            // realized host/device overlap: ~0 through the sequential
            // `tick` path (submit and collect are back-to-back), the
            // pipelined loop's overlap-window work otherwise
            self.metrics.record_overlap(report.overlap_host_s, report.pjrt_s);
        }
        // page accounting: arena occupancy, fragmentation, reuse. The
        // page invariant — live pages never exceed the pool — holds by
        // construction (alloc fails rather than overcommit) and the
        // admission bound keeps alloc from ever failing; surface both.
        let pool = engine.pool_stats();
        debug_assert!(
            pool.in_use <= pool.pages,
            "page accounting broken: {} in use > {} pool pages",
            pool.in_use,
            pool.pages
        );
        let live_slots: usize =
            self.lanes.iter().flatten().map(|ar| ar.slab.len()).sum();
        let reserved = self.pending.as_ref().map_or(0, |p| p.reserved);
        self.metrics.record_pool(pool, live_slots, reserved);
        self.metrics.record_prefix(
            engine.prefix_stats(),
            engine.shared_charge_pages(&self.lanes),
            engine.fork_deferrals(),
            engine.emergency_tail_drops(),
            engine.extend_calls(),
        );
        for (idx, ar) in done {
            #[allow(clippy::expect_used)]
            // hae-lint: allow(R3-forbidden-api) a finished lane without a tag is scheduler-state corruption; fail loud
            let lt = self.tags[idx].take().expect("finished lane carries a tag");
            self.metrics.completed += 1;
            self.metrics
                .record_e2e(ar.req.kind, lt.enqueued_at.elapsed().as_secs_f64());
            self.obs
                .event(ar.req.id, TraceEvent::Retired { reason: RetireReason::Completed });
            self.ready.push(SchedOutcome::Done { tag: lt.tag, ar: Box::new(ar) });
        }
        // device-thread health: fold the handle's always-on channel
        // counters into the registry (visible with tracing off), and
        // sample the channel depth into the gated profiler histogram
        let dev = engine.device();
        let depth = dev.queue_depth();
        self.metrics
            .record_device(dev.busy_us(), dev.send_wait_us(), dev.calls(), depth);
        if let Some(t0) = t0 {
            self.obs.record(|o| {
                o.profile.device_queue_depth.record(depth as f64);
                o.profile.step_finish_ms.record(t0.elapsed().as_secs_f64() * 1e3);
            });
        }
        Ok(report)
    }

    /// Drain the buffered outcomes of prior `tick` calls.
    pub fn take_outcomes(&mut self) -> Vec<SchedOutcome<T>> {
        std::mem::take(&mut self.ready)
    }

    /// Abandon everything queued, reserving, or mid-flight, returning the
    /// tags so the caller can notify clients (shutdown path).
    pub fn drain_tags(&mut self) -> Vec<T> {
        let mut tags: Vec<T> = self.queue.drain().into_iter().map(|j| j.tag).collect();
        if let Some(p) = self.pending.take() {
            tags.push(p.job.tag);
        }
        for (lane, tag) in self.lanes.iter_mut().zip(self.tags.iter_mut()) {
            *lane = None;
            if let Some(lt) = tag.take() {
                tags.push(lt.tag);
            }
        }
        tags
    }
}

/// Parse a `--kv-budget` spec: plain bytes, or an integer with a
/// k/m/g (KiB/MiB/GiB) suffix, e.g. `512k`, `4m`.
pub fn parse_kv_budget(spec: &str) -> Option<usize> {
    let sp = spec.trim().to_ascii_lowercase();
    let (digits, mult) = if let Some(d) = sp.strip_suffix('k') {
        (d, 1usize << 10)
    } else if let Some(d) = sp.strip_suffix('m') {
        (d, 1usize << 20)
    } else if let Some(d) = sp.strip_suffix('g') {
        (d, 1usize << 30)
    } else {
        (sp.as_str(), 1usize)
    };
    digits.parse::<usize>().ok().and_then(|n| n.checked_mul(mult))
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::workload::WorkloadKind;

    fn req(prompt: usize, max_new: usize) -> Request {
        Request {
            id: 0,
            kind: WorkloadKind::Understanding,
            ids: vec![1; prompt],
            patches: Vec::new(),
            is_vision: vec![false; prompt],
            max_new_tokens: max_new,
            min_new_tokens: 0,
            expected_answer: None,
            images: Vec::new(),
        }
    }

    fn sched(budget_slots: usize, queue_depth: usize) -> Scheduler<u32> {
        let cfg = SchedulerConfig {
            kv_budget: budget_slots * 64,
            queue_depth,
            ..SchedulerConfig::default()
        };
        // 1-slot pages keep this test's arithmetic in whole slots
        Scheduler::new(cfg, 4, 64, 100, 1, 1024)
    }

    #[test]
    fn submit_rejects_oversized_requests() {
        let mut sc = sched(8, 16);
        assert!(sc.submit(1, req(4, 4)).is_ok());
        match sc.submit(2, req(8, 8)) {
            Err((tag, RejectReason::KvBudget)) => assert_eq!(tag, 2),
            _ => panic!("16-slot worst case must not fit an 8-slot budget"),
        }
        assert_eq!(sc.metrics.rejected_kv_budget, 1);
        assert_eq!(sc.metrics.submitted, 2);
        assert_eq!(sc.queue_len(), 1);
    }

    #[test]
    fn submit_rejects_when_queue_full() {
        let mut sc = sched(100, 1);
        assert!(sc.submit(1, req(2, 2)).is_ok());
        match sc.submit(2, req(2, 2)) {
            Err((tag, RejectReason::QueueFull)) => assert_eq!(tag, 2),
            _ => panic!("second submit must hit the depth-1 queue"),
        }
        assert_eq!(sc.metrics.rejected_queue_full, 1);
    }

    #[test]
    fn drain_returns_queued_tags() {
        let mut sc = sched(100, 8);
        sc.submit(7, req(2, 2)).unwrap();
        sc.submit(9, req(2, 2)).unwrap();
        let tags = sc.drain_tags();
        assert_eq!(tags, vec![7, 9]);
        assert!(!sc.has_work());
    }

    #[test]
    fn submit_and_reject_trace_lifecycle_events() {
        let mut sc = sched(8, 1);
        let mut ok = req(4, 4);
        ok.id = 11;
        sc.submit(1, ok).unwrap();
        let mut oversized = req(8, 8);
        oversized.id = 12;
        assert!(sc.submit(2, oversized).is_err(), "kv-budget reject");
        let mut overflow = req(2, 2);
        overflow.id = 13;
        assert!(sc.submit(3, overflow).is_err(), "queue-full reject");

        let o = sc.obs.inner();
        // admitted-to-queue request: Enqueued only (no engine ran)
        let ev11 = o.trace.for_request(11);
        assert_eq!(ev11.len(), 1);
        assert!(matches!(ev11[0].event, TraceEvent::Enqueued));
        // both reject paths: Enqueued then Retired{Rejected}
        for rid in [12u64, 13] {
            let ev = o.trace.for_request(rid);
            assert_eq!(ev.len(), 2, "request {}", rid);
            assert!(matches!(ev[0].event, TraceEvent::Enqueued));
            assert!(matches!(
                ev[1].event,
                TraceEvent::Retired { reason: RetireReason::Rejected }
            ));
            assert!(ev[0].at_us <= ev[1].at_us, "timestamps monotone per request");
        }
        drop(o);

        // the stats snapshot gains the additive `phases` block without
        // disturbing the frozen flat keys
        let snap = sc.stats_json();
        assert!(snap.get("phases").is_some());
        assert!(snap.get("submitted").is_some());
        // trace query over the wire shape
        let tr = sc.trace_json(Some(12), None);
        assert_eq!(tr.get("count").and_then(|v| v.as_i64()), Some(2));
        // prometheus body covers registry + engine-phase series
        let body = sc.stats_prometheus();
        assert!(crate::obs::prometheus::parses_as_exposition(&body), "{}", body);
        assert!(body.contains("hae_requests_submitted_total"));
        assert!(body.contains("hae_prefill_ms_bucket"));
    }

    #[test]
    fn profile_json_has_spans_and_device_block() {
        let sc = sched(100, 8);
        let j = sc.profile_json();
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("profile"));
        assert_eq!(j.get("tracing").and_then(|v| v.as_bool()), Some(true));
        for span in [
            "pool_lock_wait_ms",
            "device_send_wait_ms",
            "step_begin_ms",
            "step_overlap_ms",
            "step_finish_ms",
            "device_queue_depth",
        ] {
            assert!(j.path(&["spans", span, "count"]).is_some(), "missing span {}", span);
        }
        for key in ["busy_us", "send_wait_us", "calls", "queue_depth", "peak_queue_depth"] {
            assert!(j.path(&["device", key]).is_some(), "missing device key {}", key);
        }
    }

    #[test]
    fn scheduler_config_slo_reaches_registry() {
        let cfg = SchedulerConfig {
            slo: SloTable::parse("qa=200:2000").unwrap(),
            ..SchedulerConfig::default()
        };
        let sc: Scheduler<u32> = Scheduler::new(cfg, 4, 64, 100, 1, 1024);
        assert_eq!(
            sc.metrics.slo().target(WorkloadKind::Understanding),
            Some((200.0, 2000.0))
        );
    }

    #[test]
    fn kv_budget_parsing() {
        assert_eq!(parse_kv_budget("4096"), Some(4096));
        assert_eq!(parse_kv_budget("512k"), Some(512 << 10));
        assert_eq!(parse_kv_budget("4M"), Some(4 << 20));
        assert_eq!(parse_kv_budget("1g"), Some(1 << 30));
        assert_eq!(parse_kv_budget("bogus"), None);
        assert_eq!(parse_kv_budget(""), None);
    }
}
