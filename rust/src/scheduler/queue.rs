//! Admission queue: per-workload-kind priority classes with
//! starvation-free aging.
//!
//! Requests wait here until a decode lane AND enough KV budget are free
//! (scheduler/admission.rs decides the latter). Two selection policies:
//!
//! * `Fifo` — strict arrival order regardless of workload kind.
//! * `Priority` — interactive kinds (QA) outrank long-generation kinds
//!   (story), with aging: every `aging_ticks` scheduler ticks spent
//!   waiting promotes a job one class, so sustained high-priority traffic
//!   can never starve the low classes — a class-`c` job waits at most
//!   `c * aging_ticks` ticks before it competes at class 0, where ties
//!   break by arrival order.

use std::collections::VecDeque;
use std::time::Instant;

use crate::prefix::{request_fingerprint, request_key, KeySym};
use crate::workload::{Request, WorkloadKind};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    Fifo,
    Priority,
}

impl SchedPolicy {
    pub fn parse(s: &str) -> Option<SchedPolicy> {
        match s {
            "fifo" => Some(SchedPolicy::Fifo),
            "priority" | "prio" => Some(SchedPolicy::Priority),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SchedPolicy::Fifo => "fifo",
            SchedPolicy::Priority => "priority",
        }
    }
}

/// Priority class of a workload kind (lower = served first). QA turns are
/// interactive; story generations are long batch jobs that would
/// otherwise head-of-line-block everyone behind them.
pub fn class_of(kind: WorkloadKind) -> u8 {
    match kind {
        WorkloadKind::Understanding => 0,
        WorkloadKind::Video => 1,
        WorkloadKind::Mixed => 1,
        WorkloadKind::Story => 2,
    }
}

pub struct QueuedJob<T> {
    pub tag: T,
    pub req: Request,
    pub class: u8,
    pub enqueued_tick: u64,
    pub enqueued_at: Instant,
    /// prefix-cache probe (radix key + whole-prompt fingerprint),
    /// hashed ONCE at enqueue: the admission loop consults the cache
    /// every tick the job waits, and re-hashing a multi-KB vision
    /// prompt per tick would dwarf the lookup itself
    pub prefix_probe: (Vec<KeySym>, u64),
}

pub struct AdmissionQueue<T> {
    jobs: VecDeque<QueuedJob<T>>,
    policy: SchedPolicy,
    aging_ticks: u64,
    capacity: usize,
}

impl<T> AdmissionQueue<T> {
    pub fn new(policy: SchedPolicy, capacity: usize, aging_ticks: u64) -> Self {
        AdmissionQueue {
            jobs: VecDeque::new(),
            policy,
            aging_ticks: aging_ticks.max(1),
            capacity,
        }
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Enqueue, or hand the tag back when the queue is full so the caller
    /// can reject gracefully.
    pub fn push(&mut self, tag: T, req: Request, tick: u64) -> Result<(), T> {
        if self.jobs.len() >= self.capacity {
            return Err(tag);
        }
        let class = class_of(req.kind);
        let prefix_probe = (request_key(&req), request_fingerprint(&req));
        self.jobs.push_back(QueuedJob {
            tag,
            req,
            class,
            enqueued_tick: tick,
            enqueued_at: Instant::now(),
            prefix_probe,
        });
        Ok(())
    }

    fn effective_class(&self, job: &QueuedJob<T>, tick: u64) -> u8 {
        let waited = tick.saturating_sub(job.enqueued_tick);
        let promoted = (waited / self.aging_ticks).min(u8::MAX as u64) as u8;
        job.class.saturating_sub(promoted)
    }

    /// Index of the job the policy would admit next (None when empty).
    pub fn select(&self, tick: u64) -> Option<usize> {
        if self.jobs.is_empty() {
            return None;
        }
        match self.policy {
            SchedPolicy::Fifo => Some(0),
            SchedPolicy::Priority => (0..self.jobs.len()).min_by_key(|&i| {
                let j = &self.jobs[i];
                (self.effective_class(j, tick), j.enqueued_tick, i)
            }),
        }
    }

    pub fn peek(&self, idx: usize) -> &QueuedJob<T> {
        &self.jobs[idx]
    }

    #[allow(clippy::expect_used)]
    pub fn remove(&mut self, idx: usize) -> QueuedJob<T> {
        // hae-lint: allow(R3-forbidden-api) idx comes from select() on this same queue state; out-of-range is caller corruption
        self.jobs.remove(idx).expect("queue index in range")
    }

    /// Take everything still waiting (shutdown drain).
    pub fn drain(&mut self) -> Vec<QueuedJob<T>> {
        self.jobs.drain(..).collect()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn req(kind: WorkloadKind) -> Request {
        Request {
            id: 0,
            kind,
            ids: vec![1],
            patches: Vec::new(),
            is_vision: vec![false],
            max_new_tokens: 4,
            min_new_tokens: 0,
            expected_answer: None,
            images: Vec::new(),
        }
    }

    #[test]
    fn fifo_ignores_class() {
        let mut q: AdmissionQueue<u32> = AdmissionQueue::new(SchedPolicy::Fifo, 8, 16);
        q.push(0, req(WorkloadKind::Story), 0).unwrap();
        q.push(1, req(WorkloadKind::Understanding), 1).unwrap();
        assert_eq!(q.select(2), Some(0));
        assert_eq!(q.remove(0).tag, 0);
        assert_eq!(q.remove(0).tag, 1);
    }

    #[test]
    fn priority_prefers_interactive() {
        let mut q: AdmissionQueue<u32> = AdmissionQueue::new(SchedPolicy::Priority, 8, 16);
        q.push(0, req(WorkloadKind::Story), 0).unwrap();
        q.push(1, req(WorkloadKind::Understanding), 1).unwrap();
        // QA (class 0) beats the earlier-arrived story (class 2)
        let i = q.select(2).unwrap();
        assert_eq!(q.peek(i).tag, 1);
    }

    #[test]
    fn aging_prevents_starvation() {
        let mut q: AdmissionQueue<u32> = AdmissionQueue::new(SchedPolicy::Priority, 8, 4);
        q.push(0, req(WorkloadKind::Story), 0).unwrap();
        q.push(1, req(WorkloadKind::Understanding), 7).unwrap();
        // at tick 8 the story has waited 8 ticks = 2 promotions → class 0,
        // and its earlier enqueue tick wins the tie
        let i = q.select(8).unwrap();
        assert_eq!(q.peek(i).tag, 0);
    }

    #[test]
    fn full_queue_returns_tag() {
        let mut q: AdmissionQueue<u32> = AdmissionQueue::new(SchedPolicy::Fifo, 1, 16);
        q.push(7, req(WorkloadKind::Mixed), 0).unwrap();
        assert_eq!(q.push(8, req(WorkloadKind::Mixed), 0), Err(8));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn drain_empties() {
        let mut q: AdmissionQueue<u32> = AdmissionQueue::new(SchedPolicy::Fifo, 8, 16);
        q.push(1, req(WorkloadKind::Story), 0).unwrap();
        q.push(2, req(WorkloadKind::Video), 0).unwrap();
        let drained = q.drain();
        assert_eq!(drained.len(), 2);
        assert!(q.is_empty());
    }
}
