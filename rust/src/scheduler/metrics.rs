//! Serving metrics registry, exposed over the wire via the
//! `{"kind": "stats"}` server request (JSON snapshot or Prometheus text
//! exposition with `"format": "prometheus"`).
//!
//! Counters (submissions, completions, rejections), gauges (queue depth,
//! live KV bytes, page-pool occupancy) and fixed-bucket log-scale latency
//! histograms (queue wait, TTFT and end-to-end). The histograms replaced a
//! raw-sample ring that silently dropped the oldest samples — a long-run
//! p99 computed from survivors is wrong exactly when tails matter; a
//! histogram keeps every observation in bounded memory (see `obs::hist`).
//! The lanes-occupied histogram is the direct evidence of continuous
//! batching: `lanes_hist[k]` counts decode steps that ran with exactly
//! `k` live lanes. The pool gauges (live/free pages, fragmentation,
//! reuse) are the paged-arena counterpart: they show eviction turning
//! into free pages, and free pages turning into admissions
//! (`chunked_admits`).

use crate::cache::PoolStats;
use crate::obs::{prometheus, Histogram};
use crate::prefix::PrefixStats;
use crate::util::json::{num, obj, s, Json};
use crate::workload::WorkloadKind;

/// Per-class latency SLO targets: `(ttft_ms, e2e_ms)` per
/// [`WorkloadKind`], both optional. Attainment is counted at record
/// time (a histogram cannot answer an arbitrary threshold after the
/// fact): a TTFT/e2e sample within its class target counts as met.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SloTable {
    targets: [Option<(f64, f64)>; 4],
}

impl SloTable {
    /// Parse the CLI form `class=ttft_ms:e2e_ms[,class=...]`, e.g.
    /// `qa=200:2000,story=500:30000`. Classes are the
    /// [`WorkloadKind::wire_name`] strings (parse aliases accepted).
    pub fn parse(spec: &str) -> Result<SloTable, String> {
        let mut t = SloTable::default();
        for part in spec.split(',').filter(|p| !p.is_empty()) {
            let (class, rest) = part
                .split_once('=')
                .ok_or_else(|| format!("--slo entry '{}' is not class=ttft_ms:e2e_ms", part))?;
            let kind = WorkloadKind::parse(class).ok_or_else(|| {
                format!("--slo class '{}' unknown; accepted: {}", class, WorkloadKind::accepted())
            })?;
            let (ttft, e2e) = rest
                .split_once(':')
                .ok_or_else(|| format!("--slo entry '{}' is not class=ttft_ms:e2e_ms", part))?;
            let ttft_ms: f64 = ttft
                .parse()
                .map_err(|_| format!("--slo ttft_ms '{}' is not a number", ttft))?;
            let e2e_ms: f64 = e2e
                .parse()
                .map_err(|_| format!("--slo e2e_ms '{}' is not a number", e2e))?;
            if ttft_ms <= 0.0 || e2e_ms <= 0.0 {
                return Err(format!("--slo targets must be positive in '{}'", part));
            }
            t.targets[kind.index()] = Some((ttft_ms, e2e_ms));
        }
        Ok(t)
    }

    pub fn set(&mut self, kind: WorkloadKind, ttft_ms: f64, e2e_ms: f64) {
        self.targets[kind.index()] = Some((ttft_ms, e2e_ms));
    }

    pub fn target(&self, kind: WorkloadKind) -> Option<(f64, f64)> {
        self.targets[kind.index()]
    }

    pub fn is_empty(&self) -> bool {
        self.targets.iter().all(|t| t.is_none())
    }
}

#[derive(Debug, Clone)]
pub struct MetricsRegistry {
    pub kv_budget: usize,
    pub submitted: u64,
    pub completed: u64,
    /// requests that failed inside the engine (e.g. prompt too long)
    pub failed: u64,
    pub rejected_queue_full: u64,
    pub rejected_kv_budget: u64,
    pub decode_steps: u64,
    /// live KV bytes at the most recent decode step (gauge)
    pub live_kv_bytes: usize,
    /// max aggregate live KV observed at any decode step — the budget
    /// invariant says this never exceeds `kv_budget`
    pub peak_live_kv_bytes: usize,
    pub peak_queue_depth: usize,
    // --- paged-arena accounting -------------------------------------
    /// total pages in the engine's shared arena
    pub pool_pages: usize,
    /// token slots per page
    pub page_slots: usize,
    /// pages held by live lanes at the most recent tick (gauge)
    pub live_pages: usize,
    /// most pages ever held at once — the page invariant says this never
    /// exceeds `pool_pages`
    pub peak_live_pages: usize,
    /// free pages at the most recent tick (gauge)
    pub free_pages: usize,
    /// lifetime page allocations / frees / recycled allocations
    pub page_allocs: u64,
    pub page_frees: u64,
    pub page_reuse: u64,
    /// allocated-but-dead slots at the most recent tick (tail-page
    /// internal fragmentation, gauge)
    pub frag_slots: usize,
    /// pages currently pinned by a chunked-prefill reservation (gauge)
    pub reserved_pages: usize,
    /// pages ever granted to chunked-prefill reservations
    pub chunk_reserved_pages: u64,
    /// admissions that went through the chunked-prefill path
    pub chunked_admits: u64,
    /// arena pages gathered into batch buffers across all decode steps —
    /// with the incremental lane sync this grows O(dirty pages/step)
    pub pages_copied: u64,
    /// copy-on-write forks: a sharer diverging from a shared prefix page
    pub cow_forks: u64,
    /// policy evictions deferred because a CoW fork found the pool empty
    /// (retried later — the recoverable form of the fork-exhaustion
    /// panic; a sustained nonzero rate means the budget is too tight for
    /// the divergence pattern)
    pub cow_fork_deferrals: u64,
    /// capacity-wall emergencies resolved by the fork-free aligned tail
    /// drop (recent context sacrificed; healthy systems: always 0)
    pub emergency_tail_drops: u64,
    /// refcount violations the pool refused (healthy systems: always 0)
    pub refcount_errors: u64,
    // --- prefix cache ------------------------------------------------
    /// exact warm admissions served from the radix-tree prefix cache
    pub prefix_hits: u64,
    /// partial-prefix warm admissions (prefix adopted CoW, suffix
    /// recomputed, retention decision replayed per request)
    pub prefix_partial_hits: u64,
    /// cold prefills that consulted the cache and missed
    pub prefix_misses: u64,
    /// live cache entries (gauge)
    pub prefix_entries: usize,
    /// distinct arena pages charged once against the budget — cache pins
    /// ∪ lanes' shared pages (gauge): the sharing multiplier made visible
    pub pages_shared: usize,
    /// entries LRU-evicted (cap or pool pressure)
    pub prefix_lru_evictions: u64,
    /// prompt tokens never recomputed thanks to warm hits
    pub prefill_tokens_skipped: u64,
    /// suffix-recompute device calls issued by partial warm starts:
    /// ≈ Σ ⌈suffix/extend-chunk⌉ with the chunked extend executables,
    /// Σ suffix at --extend-chunk 1 (the one-token decode loop)
    pub extend_calls: u64,
    /// pages deduplicated at prefix-cache registration: an entry pinned
    /// an existing bit-identical page instead of a second copy
    pub prefix_dedup_pages: u64,
    /// Σ per-step host/device overlap fractions over `overlap_steps`
    /// pipelined decode steps: each step contributes
    /// `min(host_overlap_s, pjrt_s) / pjrt_s` — 0 when the scheduler did
    /// no host work during the device window, 1 when it filled it
    overlap_frac_sum: f64,
    /// pipelined decode steps that reported an overlap window (the
    /// sequential engine contributes none)
    overlap_steps: u64,
    lanes_hist: Vec<u64>,
    /// enqueue → admission (scheduler clock)
    queue_wait_ms: Histogram,
    /// enqueue → prefill done (first token exists)
    ttft_ms: Histogram,
    /// enqueue → retirement
    e2e_ms: Histogram,
    // --- per-class latency + SLO attainment --------------------------
    /// per-[`WorkloadKind`] latency histograms, indexed by
    /// `WorkloadKind::index()` (the aggregate histograms above stay the
    /// wire-frozen legacy surface; these are additive)
    class_queue_wait_ms: [Histogram; 4],
    class_ttft_ms: [Histogram; 4],
    class_e2e_ms: [Histogram; 4],
    /// SLO targets; empty table = no attainment accounting (gauges read 1)
    slo: SloTable,
    /// per-class TTFT samples recorded / within the class TTFT target
    class_ttft_total: [u64; 4],
    class_ttft_ok: [u64; 4],
    /// per-class e2e samples recorded / within the class e2e target
    class_e2e_total: [u64; 4],
    class_e2e_ok: [u64; 4],
    // --- device-thread health (folded each finish_step, always on) ---
    /// cumulative device-thread busy time (µs)
    pub device_busy_us: u64,
    /// cumulative host time blocked in the device-channel send (µs) —
    /// the backpressure counter
    pub device_send_wait_us: u64,
    /// total calls sent to the device thread
    pub device_calls: u64,
    /// device-channel depth at the last fold (queued + executing)
    pub device_queue_depth: u64,
    /// high-water mark of the channel depth
    pub peak_device_queue_depth: u64,
}

impl MetricsRegistry {
    pub fn new(batch: usize, kv_budget: usize, pool_pages: usize, page_slots: usize) -> Self {
        MetricsRegistry {
            kv_budget,
            submitted: 0,
            completed: 0,
            failed: 0,
            rejected_queue_full: 0,
            rejected_kv_budget: 0,
            decode_steps: 0,
            live_kv_bytes: 0,
            peak_live_kv_bytes: 0,
            peak_queue_depth: 0,
            pool_pages,
            page_slots,
            live_pages: 0,
            peak_live_pages: 0,
            free_pages: pool_pages,
            page_allocs: 0,
            page_frees: 0,
            page_reuse: 0,
            frag_slots: 0,
            reserved_pages: 0,
            chunk_reserved_pages: 0,
            chunked_admits: 0,
            pages_copied: 0,
            cow_forks: 0,
            cow_fork_deferrals: 0,
            emergency_tail_drops: 0,
            refcount_errors: 0,
            prefix_hits: 0,
            prefix_partial_hits: 0,
            prefix_misses: 0,
            prefix_entries: 0,
            pages_shared: 0,
            prefix_lru_evictions: 0,
            prefill_tokens_skipped: 0,
            extend_calls: 0,
            prefix_dedup_pages: 0,
            overlap_frac_sum: 0.0,
            overlap_steps: 0,
            lanes_hist: vec![0; batch + 1],
            queue_wait_ms: Histogram::latency_ms(),
            ttft_ms: Histogram::latency_ms(),
            e2e_ms: Histogram::latency_ms(),
            class_queue_wait_ms: std::array::from_fn(|_| Histogram::latency_ms()),
            class_ttft_ms: std::array::from_fn(|_| Histogram::latency_ms()),
            class_e2e_ms: std::array::from_fn(|_| Histogram::latency_ms()),
            slo: SloTable::default(),
            class_ttft_total: [0; 4],
            class_ttft_ok: [0; 4],
            class_e2e_total: [0; 4],
            class_e2e_ok: [0; 4],
            device_busy_us: 0,
            device_send_wait_us: 0,
            device_calls: 0,
            device_queue_depth: 0,
            peak_device_queue_depth: 0,
        }
    }

    /// Install the per-class SLO target table (`SchedulerConfig::slo`,
    /// CLI `--slo`). Attainment counting starts from the next sample.
    pub fn set_slo(&mut self, slo: SloTable) {
        self.slo = slo;
    }

    pub fn slo(&self) -> &SloTable {
        &self.slo
    }

    /// Fold the device handle's always-on channel counters
    /// (`device::ChannelStats`) into the registry; called once per
    /// `finish_step` so device health is visible with tracing off.
    pub fn record_device(&mut self, busy_us: u64, send_wait_us: u64, calls: u64, depth: u64) {
        self.device_busy_us = busy_us;
        self.device_send_wait_us = send_wait_us;
        self.device_calls = calls;
        self.device_queue_depth = depth;
        self.peak_device_queue_depth = self.peak_device_queue_depth.max(depth);
    }

    /// Fold one tick's arena snapshot into the gauges. `live_slots` is
    /// the summed live length of the lanes (fragmentation = allocated
    /// slots − live slots); `reserved` the chunked-prefill reservation.
    pub fn record_pool(&mut self, pool: PoolStats, live_slots: usize, reserved: usize) {
        self.live_pages = pool.in_use;
        self.peak_live_pages = self.peak_live_pages.max(pool.peak_in_use);
        self.free_pages = pool.free;
        self.page_allocs = pool.allocs;
        self.page_frees = pool.frees;
        self.page_reuse = pool.reused;
        self.frag_slots = (pool.in_use * pool.page_slots).saturating_sub(live_slots);
        self.reserved_pages = reserved;
        self.cow_forks = pool.forks;
        self.refcount_errors = pool.refcount_errors;
    }

    /// Fold one tick's prefix-cache snapshot into the gauges.
    /// `shared_charge` is the distinct charged-once page count
    /// (`Engine::shared_charge_pages`); `fork_deferrals` and
    /// `tail_drops` the engine's CoW back-pressure counters;
    /// `extend_calls` its suffix-recompute device-call counter.
    pub fn record_prefix(
        &mut self,
        ps: PrefixStats,
        shared_charge: usize,
        fork_deferrals: u64,
        tail_drops: u64,
        extend_calls: u64,
    ) {
        self.prefix_hits = ps.hits;
        self.prefix_partial_hits = ps.partial_hits;
        self.prefix_misses = ps.misses;
        self.prefix_entries = ps.entries;
        self.prefix_lru_evictions = ps.lru_evictions;
        self.prefill_tokens_skipped = ps.prefill_tokens_skipped;
        self.prefix_dedup_pages = ps.dedup_pages;
        self.pages_shared = shared_charge;
        self.cow_fork_deferrals = fork_deferrals;
        self.emergency_tail_drops = tail_drops;
        self.extend_calls = extend_calls;
    }

    /// Fold one pipelined decode step's realized host/device overlap:
    /// `host_overlap_s` is the host time spent between submit and
    /// collect ([`crate::coordinator::StepReport::overlap_host_s`]),
    /// `pjrt_s` the device time of the step.
    pub fn record_overlap(&mut self, host_overlap_s: f64, pjrt_s: f64) {
        if pjrt_s > 0.0 {
            self.overlap_frac_sum += (host_overlap_s / pjrt_s).clamp(0.0, 1.0);
            self.overlap_steps += 1;
        }
    }

    /// Mean fraction of the device window covered by host work across
    /// pipelined decode steps; 0.0 with no pipelined steps (sequential
    /// engine or no decode traffic).
    pub fn host_device_overlap_frac(&self) -> f64 {
        if self.overlap_steps == 0 {
            0.0
        } else {
            self.overlap_frac_sum / self.overlap_steps as f64
        }
    }

    /// Fraction of cache-consulting admissions served warm (exact or
    /// partial).
    pub fn prefix_hit_rate(&self) -> f64 {
        let warm = self.prefix_hits + self.prefix_partial_hits;
        let total = warm + self.prefix_misses;
        if total == 0 {
            0.0
        } else {
            warm as f64 / total as f64
        }
    }

    pub fn record_step(&mut self, lanes: usize, live_kv_bytes: usize) {
        self.decode_steps += 1;
        let k = lanes.min(self.lanes_hist.len().saturating_sub(1));
        self.lanes_hist[k] += 1;
        self.live_kv_bytes = live_kv_bytes;
        self.peak_live_kv_bytes = self.peak_live_kv_bytes.max(live_kv_bytes);
    }

    pub fn record_queue_depth(&mut self, depth: usize) {
        self.peak_queue_depth = self.peak_queue_depth.max(depth);
    }

    /// Queue wait: enqueue → the moment admission hands the request to
    /// the engine. Recorded into the aggregate histogram and the
    /// request's class histogram.
    pub fn record_queue_wait(&mut self, kind: WorkloadKind, seconds: f64) {
        let ms = seconds * 1000.0;
        self.queue_wait_ms.record(ms);
        self.class_queue_wait_ms[kind.index()].record(ms);
    }

    /// Time-to-first-token: enqueue → prefill done (the first token
    /// exists as soon as prefill logits are sampled). Counts the class's
    /// SLO attainment when a target is set.
    pub fn record_ttft(&mut self, kind: WorkloadKind, seconds: f64) {
        let ms = seconds * 1000.0;
        self.ttft_ms.record(ms);
        let i = kind.index();
        self.class_ttft_ms[i].record(ms);
        self.class_ttft_total[i] += 1;
        match self.slo.target(kind) {
            Some((ttft_target, _)) if ms > ttft_target => {}
            _ => self.class_ttft_ok[i] += 1,
        }
    }

    pub fn record_e2e(&mut self, kind: WorkloadKind, seconds: f64) {
        let ms = seconds * 1000.0;
        self.e2e_ms.record(ms);
        let i = kind.index();
        self.class_e2e_ms[i].record(ms);
        self.class_e2e_total[i] += 1;
        match self.slo.target(kind) {
            Some((_, e2e_target)) if ms > e2e_target => {}
            _ => self.class_e2e_ok[i] += 1,
        }
    }

    /// Fraction of a class's TTFT samples inside its target; 1.0 with no
    /// samples (nothing violated) or no target (vacuously attained).
    pub fn slo_ttft_attainment(&self, kind: WorkloadKind) -> f64 {
        let i = kind.index();
        if self.class_ttft_total[i] == 0 {
            1.0
        } else {
            self.class_ttft_ok[i] as f64 / self.class_ttft_total[i] as f64
        }
    }

    /// Fraction of a class's e2e samples inside its target; 1.0 with no
    /// samples or no target.
    pub fn slo_e2e_attainment(&self, kind: WorkloadKind) -> f64 {
        let i = kind.index();
        if self.class_e2e_total[i] == 0 {
            1.0
        } else {
            self.class_e2e_ok[i] as f64 / self.class_e2e_total[i] as f64
        }
    }

    /// Worst per-class per-phase attainment across classes that have a
    /// target — the single "are we meeting our SLOs" gauge. 1.0 when no
    /// targets are configured.
    pub fn slo_attainment(&self) -> f64 {
        let mut worst = 1.0f64;
        for kind in WorkloadKind::ALL {
            if self.slo.target(kind).is_some() {
                worst = worst
                    .min(self.slo_ttft_attainment(kind))
                    .min(self.slo_e2e_attainment(kind));
            }
        }
        worst
    }

    /// Widest batch any decode step actually ran at.
    pub fn max_lanes_step(&self) -> usize {
        self.lanes_hist
            .iter()
            .enumerate()
            .filter(|(_, &n)| n > 0)
            .map(|(k, _)| k)
            .max()
            .unwrap_or(0)
    }

    pub fn snapshot(&self, queue_depth: usize, lanes_occupied: usize) -> Json {
        obj(vec![
            ("kind", s("stats")),
            ("queue_depth", num(queue_depth as f64)),
            ("peak_queue_depth", num(self.peak_queue_depth as f64)),
            ("lanes_occupied", num(lanes_occupied as f64)),
            ("max_lanes_step", num(self.max_lanes_step() as f64)),
            (
                "lanes_hist",
                Json::Arr(self.lanes_hist.iter().map(|&n| num(n as f64)).collect()),
            ),
            ("submitted", num(self.submitted as f64)),
            ("completed", num(self.completed as f64)),
            ("failed", num(self.failed as f64)),
            ("rejected_queue_full", num(self.rejected_queue_full as f64)),
            ("rejected_kv_budget", num(self.rejected_kv_budget as f64)),
            ("decode_steps", num(self.decode_steps as f64)),
            ("kv_budget", num(self.kv_budget as f64)),
            ("live_kv_bytes", num(self.live_kv_bytes as f64)),
            ("peak_live_kv_bytes", num(self.peak_live_kv_bytes as f64)),
            ("pool_pages", num(self.pool_pages as f64)),
            ("page_slots", num(self.page_slots as f64)),
            ("live_pages", num(self.live_pages as f64)),
            ("peak_live_pages", num(self.peak_live_pages as f64)),
            ("free_pages", num(self.free_pages as f64)),
            ("page_allocs", num(self.page_allocs as f64)),
            ("page_frees", num(self.page_frees as f64)),
            ("page_reuse", num(self.page_reuse as f64)),
            ("frag_slots", num(self.frag_slots as f64)),
            ("reserved_pages", num(self.reserved_pages as f64)),
            ("chunk_reserved_pages", num(self.chunk_reserved_pages as f64)),
            ("chunked_admits", num(self.chunked_admits as f64)),
            ("pages_copied", num(self.pages_copied as f64)),
            ("cow_forks", num(self.cow_forks as f64)),
            ("cow_fork_deferrals", num(self.cow_fork_deferrals as f64)),
            ("emergency_tail_drops", num(self.emergency_tail_drops as f64)),
            ("refcount_errors", num(self.refcount_errors as f64)),
            ("prefix_hits", num(self.prefix_hits as f64)),
            ("prefix_partial_hits", num(self.prefix_partial_hits as f64)),
            ("prefix_misses", num(self.prefix_misses as f64)),
            ("prefix_hit_rate", num(self.prefix_hit_rate())),
            ("prefix_entries", num(self.prefix_entries as f64)),
            ("pages_shared", num(self.pages_shared as f64)),
            ("prefix_lru_evictions", num(self.prefix_lru_evictions as f64)),
            ("prefill_tokens_skipped", num(self.prefill_tokens_skipped as f64)),
            ("extend_calls", num(self.extend_calls as f64)),
            ("ttft_p50_ms", num(self.ttft_ms.percentile(0.5))),
            ("ttft_p95_ms", num(self.ttft_ms.percentile(0.95))),
            ("e2e_p50_ms", num(self.e2e_ms.percentile(0.5))),
            ("e2e_p95_ms", num(self.e2e_ms.percentile(0.95))),
            // additive keys (the block above is schema-frozen — see
            // `snapshot_keys_are_stable`); whole-run tails the old sample
            // ring could not provide, plus the queue-wait phase
            ("ttft_p99_ms", num(self.ttft_ms.percentile(0.99))),
            ("e2e_p99_ms", num(self.e2e_ms.percentile(0.99))),
            ("queue_wait_p50_ms", num(self.queue_wait_ms.percentile(0.5))),
            ("queue_wait_p95_ms", num(self.queue_wait_ms.percentile(0.95))),
            ("queue_wait_p99_ms", num(self.queue_wait_ms.percentile(0.99))),
            // thread-parallel engine core (additive)
            ("prefix_dedup_pages", num(self.prefix_dedup_pages as f64)),
            ("host_device_overlap_frac", num(self.host_device_overlap_frac())),
            // serving profiler (additive): device-thread health folded
            // each finish_step, plus per-class latency + SLO attainment
            ("device_busy_us", num(self.device_busy_us as f64)),
            ("device_send_wait_us", num(self.device_send_wait_us as f64)),
            ("device_calls", num(self.device_calls as f64)),
            ("device_queue_depth", num(self.device_queue_depth as f64)),
            ("peak_device_queue_depth", num(self.peak_device_queue_depth as f64)),
            ("slo_attainment", num(self.slo_attainment())),
            ("classes", self.classes_json()),
        ])
    }

    /// The nested per-class block of the stats snapshot: latency
    /// percentiles, sample counts, the configured targets (absent when
    /// none) and attainment per phase, keyed by
    /// [`WorkloadKind::wire_name`].
    fn classes_json(&self) -> Json {
        let mut classes = Vec::new();
        for kind in WorkloadKind::ALL {
            let i = kind.index();
            let mut pairs = vec![
                ("queue_wait_p50_ms", num(self.class_queue_wait_ms[i].percentile(0.5))),
                ("queue_wait_p95_ms", num(self.class_queue_wait_ms[i].percentile(0.95))),
                ("ttft_p50_ms", num(self.class_ttft_ms[i].percentile(0.5))),
                ("ttft_p95_ms", num(self.class_ttft_ms[i].percentile(0.95))),
                ("e2e_p50_ms", num(self.class_e2e_ms[i].percentile(0.5))),
                ("e2e_p95_ms", num(self.class_e2e_ms[i].percentile(0.95))),
                ("ttft_count", num(self.class_ttft_total[i] as f64)),
                ("e2e_count", num(self.class_e2e_total[i] as f64)),
                ("slo_ttft_attainment", num(self.slo_ttft_attainment(kind))),
                ("slo_e2e_attainment", num(self.slo_e2e_attainment(kind))),
            ];
            if let Some((ttft_target, e2e_target)) = self.slo.target(kind) {
                pairs.push(("slo_ttft_ms", num(ttft_target)));
                pairs.push(("slo_e2e_ms", num(e2e_target)));
            }
            classes.push((kind.wire_name(), obj(pairs)));
        }
        obj(classes)
    }

    /// Render every counter, gauge and latency histogram in Prometheus
    /// text exposition format. Engine-phase histograms are appended by the
    /// caller (`Scheduler::stats_prometheus`) from the shared `Obs`.
    pub fn prometheus_into(&self, out: &mut String, queue_depth: usize, lanes_occupied: usize) {
        use prometheus::{counter, gauge, histogram};
        gauge(out, "hae_queue_depth", "requests waiting for admission", queue_depth as f64);
        gauge(out, "hae_peak_queue_depth", "deepest queue observed", self.peak_queue_depth as f64);
        gauge(out, "hae_lanes_occupied", "decode lanes currently live", lanes_occupied as f64);
        gauge(out, "hae_max_lanes_step", "widest batch any decode step ran at", self.max_lanes_step() as f64);
        counter(out, "hae_requests_submitted_total", "requests submitted", self.submitted as f64);
        counter(out, "hae_requests_completed_total", "requests completed", self.completed as f64);
        counter(out, "hae_requests_failed_total", "requests failed in the engine", self.failed as f64);
        counter(out, "hae_rejected_queue_full_total", "rejections: queue full", self.rejected_queue_full as f64);
        counter(out, "hae_rejected_kv_budget_total", "rejections: cannot fit KV budget alone", self.rejected_kv_budget as f64);
        counter(out, "hae_decode_steps_total", "decode steps executed", self.decode_steps as f64);
        gauge(out, "hae_kv_budget_bytes", "aggregate KV budget", self.kv_budget as f64);
        gauge(out, "hae_live_kv_bytes", "live KV bytes at last step", self.live_kv_bytes as f64);
        gauge(out, "hae_peak_live_kv_bytes", "max live KV bytes observed", self.peak_live_kv_bytes as f64);
        gauge(out, "hae_pool_pages", "total arena pages", self.pool_pages as f64);
        gauge(out, "hae_page_slots", "token slots per page", self.page_slots as f64);
        gauge(out, "hae_live_pages", "pages held by live lanes", self.live_pages as f64);
        gauge(out, "hae_peak_live_pages", "max pages held at once", self.peak_live_pages as f64);
        gauge(out, "hae_free_pages", "free arena pages", self.free_pages as f64);
        counter(out, "hae_page_allocs_total", "lifetime page allocations", self.page_allocs as f64);
        counter(out, "hae_page_frees_total", "lifetime page frees", self.page_frees as f64);
        counter(out, "hae_page_reuse_total", "recycled page allocations", self.page_reuse as f64);
        gauge(out, "hae_frag_slots", "allocated-but-dead slots (tail fragmentation)", self.frag_slots as f64);
        gauge(out, "hae_reserved_pages", "pages pinned by chunked-prefill reservations", self.reserved_pages as f64);
        counter(out, "hae_chunk_reserved_pages_total", "pages ever granted to chunked reservations", self.chunk_reserved_pages as f64);
        counter(out, "hae_chunked_admits_total", "admissions via chunked prefill", self.chunked_admits as f64);
        counter(out, "hae_pages_copied_total", "arena pages gathered into batch buffers", self.pages_copied as f64);
        counter(out, "hae_cow_forks_total", "copy-on-write page forks", self.cow_forks as f64);
        counter(out, "hae_cow_fork_deferrals_total", "policy evictions deferred by fork pressure", self.cow_fork_deferrals as f64);
        counter(out, "hae_emergency_tail_drops_total", "capacity emergencies resolved by aligned tail drop", self.emergency_tail_drops as f64);
        counter(out, "hae_refcount_errors_total", "refcount violations refused by the pool", self.refcount_errors as f64);
        counter(out, "hae_prefix_hits_total", "exact warm admissions", self.prefix_hits as f64);
        counter(out, "hae_prefix_partial_hits_total", "partial-prefix warm admissions", self.prefix_partial_hits as f64);
        counter(out, "hae_prefix_misses_total", "cold prefills that consulted the cache", self.prefix_misses as f64);
        gauge(out, "hae_prefix_hit_rate", "warm fraction of cache-consulting admissions", self.prefix_hit_rate());
        gauge(out, "hae_prefix_entries", "live prefix-cache entries", self.prefix_entries as f64);
        gauge(out, "hae_pages_shared", "distinct pages charged once against the budget", self.pages_shared as f64);
        counter(out, "hae_prefix_lru_evictions_total", "prefix entries LRU-evicted", self.prefix_lru_evictions as f64);
        counter(out, "hae_prefill_tokens_skipped_total", "prompt tokens never recomputed", self.prefill_tokens_skipped as f64);
        counter(out, "hae_extend_calls_total", "suffix-recompute device calls", self.extend_calls as f64);
        counter(out, "hae_prefix_dedup_pages_total", "pages deduplicated at prefix-cache registration", self.prefix_dedup_pages as f64);
        gauge(out, "hae_host_device_overlap_frac", "mean fraction of the decode device window covered by host work", self.host_device_overlap_frac());
        histogram(out, "hae_queue_wait_ms", "enqueue to admission (ms)", &self.queue_wait_ms);
        histogram(out, "hae_ttft_ms", "enqueue to first token (ms)", &self.ttft_ms);
        histogram(out, "hae_e2e_ms", "enqueue to retirement (ms)", &self.e2e_ms);
        // device-thread health (always on — folded from the handle's
        // channel counters each finish_step)
        counter(out, "hae_device_busy_us_total", "cumulative device-thread busy time (us)", self.device_busy_us as f64);
        counter(out, "hae_device_send_wait_us_total", "cumulative device-channel send wait (us)", self.device_send_wait_us as f64);
        counter(out, "hae_device_calls_total", "device calls sent", self.device_calls as f64);
        gauge(out, "hae_device_queue_depth", "device-channel depth at last step (calls in flight)", self.device_queue_depth as f64);
        gauge(out, "hae_device_peak_queue_depth", "peak observed device-channel depth", self.peak_device_queue_depth as f64);
        // per-class latency + SLO attainment
        self.prometheus_classes(out);
        gauge(out, "hae_slo_attainment", "worst per-class SLO attainment (1 = all met / no targets)", self.slo_attainment());
    }

    /// The per-class labeled series: one gauge family per statistic,
    /// labeled `class="qa|story|video|mixed"`.
    fn prometheus_classes(&self, out: &mut String) {
        use prometheus::labeled_gauge;
        let rows = |f: &dyn Fn(WorkloadKind) -> f64| -> Vec<(&'static str, f64)> {
            WorkloadKind::ALL.iter().map(|&k| (k.wire_name(), f(k))).collect()
        };
        let p = |h: &[Histogram; 4], k: WorkloadKind, q: f64| h[k.index()].percentile(q);
        labeled_gauge(out, "hae_class_queue_wait_p95_ms", "per-class enqueue to admission p95 (ms)", "class",
            &rows(&|k| p(&self.class_queue_wait_ms, k, 0.95)));
        labeled_gauge(out, "hae_class_ttft_p50_ms", "per-class enqueue to first token p50 (ms)", "class",
            &rows(&|k| p(&self.class_ttft_ms, k, 0.5)));
        labeled_gauge(out, "hae_class_ttft_p95_ms", "per-class enqueue to first token p95 (ms)", "class",
            &rows(&|k| p(&self.class_ttft_ms, k, 0.95)));
        labeled_gauge(out, "hae_class_e2e_p50_ms", "per-class enqueue to retirement p50 (ms)", "class",
            &rows(&|k| p(&self.class_e2e_ms, k, 0.5)));
        labeled_gauge(out, "hae_class_e2e_p95_ms", "per-class enqueue to retirement p95 (ms)", "class",
            &rows(&|k| p(&self.class_e2e_ms, k, 0.95)));
        labeled_gauge(out, "hae_slo_ttft_attainment", "fraction of TTFT samples inside the class target", "class",
            &rows(&|k| self.slo_ttft_attainment(k)));
        labeled_gauge(out, "hae_slo_e2e_attainment", "fraction of e2e samples inside the class target", "class",
            &rows(&|k| self.slo_e2e_attainment(k)));
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn histogram_and_peaks() {
        let mut m = MetricsRegistry::new(4, 1000, 16, 8);
        m.record_step(1, 100);
        m.record_step(3, 700);
        m.record_step(3, 400);
        assert_eq!(m.decode_steps, 3);
        assert_eq!(m.max_lanes_step(), 3);
        assert_eq!(m.peak_live_kv_bytes, 700);
        assert_eq!(m.live_kv_bytes, 400);
    }

    #[test]
    fn pool_gauges_track_occupancy_and_fragmentation() {
        let mut m = MetricsRegistry::new(4, 1000, 16, 8);
        assert_eq!(m.free_pages, 16);
        let snap = PoolStats {
            pages: 16,
            page_slots: 8,
            in_use: 5,
            free: 11,
            peak_in_use: 7,
            allocs: 20,
            frees: 15,
            reused: 12,
            forks: 3,
            refcount_errors: 0,
        };
        // 5 pages × 8 slots = 40 allocated, 33 live → 7 dead slots
        m.record_pool(snap, 33, 2);
        assert_eq!(m.live_pages, 5);
        assert_eq!(m.peak_live_pages, 7);
        assert_eq!(m.free_pages, 11);
        assert_eq!(m.frag_slots, 7);
        assert_eq!(m.reserved_pages, 2);
        assert_eq!(m.page_reuse, 12);
        assert_eq!(m.cow_forks, 3);
        assert_eq!(m.refcount_errors, 0);
        assert!(m.peak_live_pages <= m.pool_pages, "page invariant");
    }

    #[test]
    fn prefix_gauges_and_hit_rate() {
        let mut m = MetricsRegistry::new(4, 1000, 16, 8);
        assert_eq!(m.prefix_hit_rate(), 0.0, "no lookups yet");
        let ps = PrefixStats {
            hits: 6,
            partial_hits: 2,
            misses: 2,
            entries: 2,
            pinned_pages: 3,
            lru_evictions: 1,
            insertions: 3,
            prefill_tokens_skipped: 108,
            dedup_pages: 7,
        };
        m.record_prefix(ps, 5, 4, 1, 9);
        assert_eq!(m.prefix_hits, 6);
        assert_eq!(m.prefix_partial_hits, 2);
        assert_eq!(m.prefix_misses, 2);
        assert_eq!(m.prefix_entries, 2);
        assert_eq!(m.pages_shared, 5);
        assert_eq!(m.prefill_tokens_skipped, 108);
        assert_eq!(m.cow_fork_deferrals, 4);
        assert_eq!(m.emergency_tail_drops, 1);
        assert_eq!(m.extend_calls, 9);
        // (6 exact + 2 partial) of 10 consulting admissions
        assert!((m.prefix_hit_rate() - 0.8).abs() < 1e-9);
        let j = m.snapshot(0, 0);
        let parsed = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed.get("prefix_hits").and_then(|v| v.as_usize()), Some(6));
        assert_eq!(
            parsed.get("prefix_partial_hits").and_then(|v| v.as_usize()),
            Some(2)
        );
        assert_eq!(
            parsed.get("cow_fork_deferrals").and_then(|v| v.as_usize()),
            Some(4)
        );
        assert_eq!(
            parsed.get("emergency_tail_drops").and_then(|v| v.as_usize()),
            Some(1)
        );
        assert_eq!(parsed.get("pages_shared").and_then(|v| v.as_usize()), Some(5));
        assert_eq!(
            parsed.get("prefill_tokens_skipped").and_then(|v| v.as_usize()),
            Some(108)
        );
        assert_eq!(parsed.get("extend_calls").and_then(|v| v.as_usize()), Some(9));
        assert_eq!(
            parsed.get("refcount_errors").and_then(|v| v.as_usize()),
            Some(0)
        );
        assert_eq!(
            parsed.get("prefix_dedup_pages").and_then(|v| v.as_usize()),
            Some(7)
        );
    }

    #[test]
    fn overlap_fraction_aggregates_pipelined_steps() {
        let mut m = MetricsRegistry::new(2, 4096, 8, 16);
        assert_eq!(m.host_device_overlap_frac(), 0.0, "no pipelined steps yet");
        m.record_overlap(0.004, 0.010); // 40% of the window covered
        m.record_overlap(0.050, 0.010); // host overran the window: capped at 1
        m.record_overlap(0.0, 0.010); // no host work overlapped
        let f = m.host_device_overlap_frac();
        assert!((f - (0.4 + 1.0) / 3.0).abs() < 1e-9, "mean of capped fractions: {}", f);
        m.record_overlap(1.0, 0.0); // zero device time never divides
        let j = m.snapshot(0, 0);
        let parsed = Json::parse(&j.to_string_compact()).unwrap();
        let got = parsed
            .get("host_device_overlap_frac")
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!((got - f).abs() < 1e-9);
    }

    #[test]
    fn snapshot_round_trips_as_json() {
        let mut m = MetricsRegistry::new(2, 4096, 8, 16);
        m.submitted = 5;
        m.completed = 4;
        m.record_step(2, 2048);
        m.record_ttft(WorkloadKind::Understanding, 0.010);
        m.record_e2e(WorkloadKind::Understanding, 0.100);
        m.chunked_admits = 1;
        let j = m.snapshot(3, 1);
        let parsed = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed.get("kind").and_then(|v| v.as_str()), Some("stats"));
        assert_eq!(parsed.get("queue_depth").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(parsed.get("max_lanes_step").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(
            parsed.get("peak_live_kv_bytes").and_then(|v| v.as_usize()),
            Some(2048)
        );
        assert_eq!(parsed.get("pool_pages").and_then(|v| v.as_usize()), Some(8));
        assert_eq!(parsed.get("page_slots").and_then(|v| v.as_usize()), Some(16));
        assert_eq!(parsed.get("chunked_admits").and_then(|v| v.as_usize()), Some(1));
        assert!(parsed.get("ttft_p50_ms").and_then(|v| v.as_f64()).unwrap() > 0.0);
    }

    #[test]
    fn latency_tails_cover_the_whole_run() {
        // the old sample ring dropped the first samples of a long run;
        // the histogram must keep every one: record far more samples than
        // the old ring capacity (4096) with the slow tail *early*, then
        // check the tail is still visible
        let mut m = MetricsRegistry::new(2, 4096, 8, 16);
        for _ in 0..100 {
            // 5s outliers, all in the first 100 samples
            m.record_e2e(WorkloadKind::Story, 5.0);
        }
        for _ in 0..20_000 {
            m.record_e2e(WorkloadKind::Story, 0.010);
        }
        let j = m.snapshot(0, 0);
        let p99 = j.get("e2e_p99_ms").and_then(|v| v.as_f64()).unwrap();
        let p995 = m.e2e_ms.percentile(0.9995);
        assert!(p99 < 100.0, "bulk at 10ms dominates p99: {}", p99);
        assert!(p995 > 1000.0, "early 5s outliers still visible at p99.95: {}", p995);
        assert_eq!(m.e2e_ms.count(), 20_100, "no sample dropped");
    }

    #[test]
    fn snapshot_keys_are_stable() {
        // wire-compatibility contract: every key below existed before the
        // histogram refactor and must keep existing — external scrapers
        // depend on them. New keys may be added; these may not vanish.
        let m = MetricsRegistry::new(2, 4096, 8, 16);
        let j = m.snapshot(0, 0);
        let parsed = Json::parse(&j.to_string_compact()).unwrap();
        const FROZEN: &[&str] = &[
            "kind", "queue_depth", "peak_queue_depth", "lanes_occupied",
            "max_lanes_step", "lanes_hist", "submitted", "completed",
            "failed", "rejected_queue_full", "rejected_kv_budget",
            "decode_steps", "kv_budget", "live_kv_bytes",
            "peak_live_kv_bytes", "pool_pages", "page_slots", "live_pages",
            "peak_live_pages", "free_pages", "page_allocs", "page_frees",
            "page_reuse", "frag_slots", "reserved_pages",
            "chunk_reserved_pages", "chunked_admits", "pages_copied",
            "cow_forks", "cow_fork_deferrals", "emergency_tail_drops",
            "refcount_errors", "prefix_hits", "prefix_partial_hits",
            "prefix_misses", "prefix_hit_rate", "prefix_entries",
            "pages_shared", "prefix_lru_evictions",
            "prefill_tokens_skipped", "extend_calls", "ttft_p50_ms",
            "ttft_p95_ms", "e2e_p50_ms", "e2e_p95_ms",
        ];
        for key in FROZEN {
            assert!(parsed.get(key).is_some(), "snapshot lost frozen key '{}'", key);
        }
        // additive keys frozen since: PR 6/7 tails + overlap, PR 8 device
        // health, SLO attainment and the nested per-class block
        const ADDITIVE: &[&str] = &[
            "ttft_p99_ms", "e2e_p99_ms", "queue_wait_p50_ms",
            "queue_wait_p95_ms", "queue_wait_p99_ms", "prefix_dedup_pages",
            "host_device_overlap_frac", "device_busy_us",
            "device_send_wait_us", "device_calls", "device_queue_depth",
            "peak_device_queue_depth", "slo_attainment", "classes",
        ];
        for key in ADDITIVE {
            assert!(parsed.get(key).is_some(), "snapshot lost additive key '{}'", key);
        }
        for class in ["qa", "story", "video", "mixed"] {
            assert!(
                parsed.path(&["classes", class, "ttft_p50_ms"]).is_some(),
                "classes block lost '{}'",
                class
            );
        }
        assert_eq!(parsed.get("kind").and_then(|v| v.as_str()), Some("stats"));
    }

    #[test]
    fn prometheus_rendering_is_valid_exposition() {
        let mut m = MetricsRegistry::new(2, 4096, 8, 16);
        m.submitted = 3;
        m.record_queue_wait(WorkloadKind::Understanding, 0.002);
        m.record_ttft(WorkloadKind::Understanding, 0.010);
        m.record_e2e(WorkloadKind::Understanding, 0.100);
        m.record_device(1234, 56, 7, 2);
        let mut out = String::new();
        m.prometheus_into(&mut out, 1, 2);
        assert!(prometheus::parses_as_exposition(&out), "{}", out);
        assert!(out.contains("# TYPE hae_requests_submitted_total counter"));
        assert!(out.contains("hae_queue_depth 1"));
        assert!(out.contains("hae_ttft_ms_bucket"));
        assert!(out.contains("hae_e2e_ms_count 1"));
        // device-thread health + per-class SLO series are part of the
        // exposition contract (docs/OBSERVABILITY.md)
        assert!(out.contains("hae_device_busy_us_total 1234"));
        assert!(out.contains("hae_device_queue_depth 2"));
        assert!(out.contains("hae_class_ttft_p50_ms{class=\"qa\"}"));
        assert!(out.contains("hae_slo_ttft_attainment{class=\"story\"} 1"));
        assert!(out.contains("hae_slo_attainment 1"));
    }

    #[test]
    fn slo_table_parses_and_rejects() {
        let t = SloTable::parse("qa=200:2000,story=500.5:30000").unwrap();
        assert_eq!(t.target(WorkloadKind::Understanding), Some((200.0, 2000.0)));
        assert_eq!(t.target(WorkloadKind::Story), Some((500.5, 30000.0)));
        assert_eq!(t.target(WorkloadKind::Video), None);
        assert!(!t.is_empty());
        assert!(SloTable::parse("").unwrap().is_empty());
        // parse aliases work; malformed entries name the accepted classes
        assert!(SloTable::parse("understanding=1:2").is_ok());
        assert!(SloTable::parse("qa=200").unwrap_err().contains("class=ttft_ms:e2e_ms"));
        assert!(SloTable::parse("nosuch=1:2").unwrap_err().contains("accepted"));
        assert!(SloTable::parse("qa=0:5").unwrap_err().contains("positive"));
        assert!(SloTable::parse("qa=a:5").unwrap_err().contains("not a number"));
    }

    #[test]
    fn per_class_attainment_counts_against_targets() {
        let mut m = MetricsRegistry::new(2, 4096, 8, 16);
        let mut slo = SloTable::default();
        slo.set(WorkloadKind::Understanding, 50.0, 500.0);
        m.set_slo(slo);
        // qa: 3 TTFT samples, one over the 50ms target
        m.record_ttft(WorkloadKind::Understanding, 0.010);
        m.record_ttft(WorkloadKind::Understanding, 0.020);
        m.record_ttft(WorkloadKind::Understanding, 0.120);
        // qa: 2 e2e samples, both inside 500ms
        m.record_e2e(WorkloadKind::Understanding, 0.100);
        m.record_e2e(WorkloadKind::Understanding, 0.400);
        // story has no target: every sample vacuously attains
        m.record_ttft(WorkloadKind::Story, 9.0);
        let qa_ttft = m.slo_ttft_attainment(WorkloadKind::Understanding);
        assert!((qa_ttft - 2.0 / 3.0).abs() < 1e-9, "{}", qa_ttft);
        assert_eq!(m.slo_e2e_attainment(WorkloadKind::Understanding), 1.0);
        assert_eq!(m.slo_ttft_attainment(WorkloadKind::Story), 1.0);
        // the headline gauge is the worst targeted attainment
        assert!((m.slo_attainment() - 2.0 / 3.0).abs() < 1e-9);
        // classes block carries percentiles, counts, targets, attainment
        let j = m.snapshot(0, 0);
        let parsed = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed.path(&["classes", "qa", "ttft_count"]).and_then(|v| v.as_usize()), Some(3));
        assert_eq!(parsed.path(&["classes", "qa", "slo_ttft_ms"]).and_then(|v| v.as_f64()), Some(50.0));
        let att = parsed
            .path(&["classes", "qa", "slo_ttft_attainment"])
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!((att - 2.0 / 3.0).abs() < 1e-9);
        assert!(parsed.path(&["classes", "story", "slo_ttft_ms"]).is_none(), "no target set");
        assert!(parsed.path(&["classes", "video", "ttft_p50_ms"]).is_some());
        let overall = parsed.get("slo_attainment").and_then(|v| v.as_f64()).unwrap();
        assert!((overall - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn device_fold_tracks_peak_depth() {
        let mut m = MetricsRegistry::new(2, 4096, 8, 16);
        m.record_device(100, 3, 2, 2);
        m.record_device(900, 8, 9, 4);
        m.record_device(950, 8, 10, 1);
        assert_eq!(m.device_busy_us, 950);
        assert_eq!(m.device_queue_depth, 1);
        assert_eq!(m.peak_device_queue_depth, 4);
        let j = m.snapshot(0, 0);
        let parsed = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed.get("device_busy_us").and_then(|v| v.as_usize()), Some(950));
        assert_eq!(parsed.get("peak_device_queue_depth").and_then(|v| v.as_usize()), Some(4));
    }
}
