//! Fidelity of a policy run against the full-cache reference.
//!
//! Protocol: generate greedily under the full cache to obtain the reference
//! token script and logits trace; replay the same request under the policy
//! with teacher forcing (engine.generate_forced), so both runs see the same
//! token stream and differences are attributable purely to the cache
//! contents. Compare per-step logits.

use crate::util::stats::{argmax, kl_from_logits, mean};

#[derive(Debug, Clone, Default)]
pub struct Fidelity {
    /// fraction of steps where both runs argmax to the same token
    pub top1_agreement: f64,
    /// mean KL(reference ‖ policy) over steps
    pub mean_kl: f64,
    /// p95 KL
    pub p95_kl: f64,
    /// steps compared
    pub steps: usize,
}

/// Compare two logits traces (same length; both from teacher-forced runs
/// over the same token script).
pub fn fidelity(reference: &[Vec<f32>], policy: &[Vec<f32>]) -> Fidelity {
    let steps = reference.len().min(policy.len());
    if steps == 0 {
        return Fidelity::default();
    }
    let mut agree = 0usize;
    let mut kls = Vec::with_capacity(steps);
    for i in 0..steps {
        let r = &reference[i];
        let p = &policy[i];
        if argmax(r) == argmax(p) {
            agree += 1;
        }
        kls.push(kl_from_logits(r, p));
    }
    Fidelity {
        top1_agreement: agree as f64 / steps as f64,
        mean_kl: mean(&kls),
        p95_kl: crate::util::stats::percentile(&kls, 0.95),
        steps,
    }
}

/// Map a fidelity score onto a Table-1-style benchmark column: the paper
/// reports task scores where the full-cache model defines the ceiling; we
/// report the policy's score as `ceiling × top1_agreement` so rows are
/// directly comparable to the paper's relative degradation.
pub fn scaled_score(ceiling: f64, f: &Fidelity) -> f64 {
    ceiling * f.top1_agreement
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_traces_are_perfect() {
        let trace = vec![vec![0.1, 0.9, 0.0], vec![2.0, -1.0, 0.5]];
        let f = fidelity(&trace, &trace);
        assert_eq!(f.top1_agreement, 1.0);
        assert!(f.mean_kl < 1e-9);
        assert_eq!(f.steps, 2);
    }

    #[test]
    fn divergent_traces_detected() {
        let a = vec![vec![0.0, 1.0], vec![1.0, 0.0]];
        let b = vec![vec![0.0, 1.0], vec![0.0, 1.0]];
        let f = fidelity(&a, &b);
        assert_eq!(f.top1_agreement, 0.5);
        assert!(f.mean_kl > 0.0);
    }

    #[test]
    fn empty_is_default() {
        let f = fidelity(&[], &[]);
        assert_eq!(f.steps, 0);
    }

    #[test]
    fn scaled_score_matches_paper_convention() {
        let f = Fidelity { top1_agreement: 0.97, ..Default::default() };
        let s = scaled_score(61.9, &f);
        assert!((s - 60.043).abs() < 1e-9);
    }
}
