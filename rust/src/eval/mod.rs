//! Evaluation metrics — the measurable stand-ins for the paper's benchmark
//! scores (DESIGN.md §3).
//!
//! Eviction papers hold the model fixed and ask how much output quality a
//! smaller cache costs, so the primary metrics are *fidelity to the
//! full-cache model* under teacher forcing (top-1 agreement, logit KL) plus
//! task accuracy on the QA families and degeneration statistics for long
//! generation.

pub mod fidelity;
pub mod quality;

pub use fidelity::{fidelity, Fidelity};
pub use quality::{degeneration, Degeneration};

/// KV-cache accounting in the units the paper's tables use.
pub fn kv_mib(bytes: f64) -> f64 {
    bytes / (1024.0 * 1024.0)
}

/// Scale a per-sample measurement the way Table 3 reports "KV Cache (MB)"
/// (per-sample peak KV, averaged over samples).
pub fn mean_peak_kv_mib(peaks: &[usize]) -> f64 {
    if peaks.is_empty() {
        return 0.0;
    }
    kv_mib(peaks.iter().map(|&b| b as f64).sum::<f64>() / peaks.len() as f64)
}
