//! Long-generation quality proxies (the Table 2 judge-score stand-ins).
//!
//! The paper scores story generations with an LLM judge on style /
//! engagement / coherence. Offline, the measurable core of those judgments
//! is (a) whether eviction made the text degenerate — repetition loops,
//! collapsed vocabulary — and (b) whether the story still references its
//! images. These map to:
//!
//! * `distinct_2` — bigram diversity (style/engagement proxy; higher = better)
//! * `repetition_rate` — fraction of 4-token windows repeating an earlier
//!   window (lower = better)
//! * `grounding` — fraction of story segments mentioning their image's
//!   color/shape words (coherence proxy)

use std::collections::BTreeSet;

use crate::model::vocab;
use crate::workload::ImageClass;

#[derive(Debug, Clone, Default)]
pub struct Degeneration {
    pub distinct_2: f64,
    pub repetition_rate: f64,
    pub grounding: f64,
    pub tokens: usize,
}

/// Compute degeneration metrics over generated tokens. `images` are the
/// prompt's image classes for the grounding check (may be empty).
pub fn degeneration(tokens: &[i32], images: &[ImageClass]) -> Degeneration {
    let n = tokens.len();
    if n == 0 {
        return Degeneration::default();
    }

    // distinct-2
    let mut bigrams = BTreeSet::new();
    let mut total_bi = 0usize;
    for w in tokens.windows(2) {
        bigrams.insert((w[0], w[1]));
        total_bi += 1;
    }
    let distinct_2 = if total_bi == 0 {
        1.0
    } else {
        bigrams.len() as f64 / total_bi as f64
    };

    // repetition: 4-gram windows seen before
    let mut seen = BTreeSet::new();
    let mut repeats = 0usize;
    let mut windows = 0usize;
    for w in tokens.windows(4) {
        let key = (w[0], w[1], w[2], w[3]);
        if !seen.insert(key) {
            repeats += 1;
        }
        windows += 1;
    }
    let repetition_rate = if windows == 0 {
        0.0
    } else {
        repeats as f64 / windows as f64
    };

    // grounding: does the text mention any prompt image's class words?
    let grounding = if images.is_empty() {
        0.0
    } else {
        let mentioned = images
            .iter()
            .filter(|img| {
                tokens.iter().any(|&t| {
                    t == vocab::color_token(img.color) || t == vocab::shape_token(img.shape)
                })
            })
            .count();
        mentioned as f64 / images.len() as f64
    };

    Degeneration { distinct_2, repetition_rate, grounding, tokens: n }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varied_text_scores_high_diversity() {
        let toks: Vec<i32> = (64..128).collect();
        let d = degeneration(&toks, &[]);
        assert!((d.distinct_2 - 1.0).abs() < 1e-9);
        assert_eq!(d.repetition_rate, 0.0);
    }

    #[test]
    fn loops_detected() {
        let toks: Vec<i32> = std::iter::repeat([64, 65, 66, 67])
            .take(10)
            .flatten()
            .collect();
        let d = degeneration(&toks, &[]);
        assert!(d.repetition_rate > 0.7, "rate {}", d.repetition_rate);
        assert!(d.distinct_2 < 0.2);
    }

    #[test]
    fn grounding_counts_mentions() {
        let imgs = [
            ImageClass { color: 1, shape: 2 },
            ImageClass { color: 3, shape: 4 },
        ];
        // mentions color 1 only
        let toks = [vocab::color_token(1), 70, 71];
        let d = degeneration(&toks, &imgs);
        assert!((d.grounding - 0.5).abs() < 1e-9);
    }

    #[test]
    fn empty_tokens() {
        let d = degeneration(&[], &[]);
        assert_eq!(d.tokens, 0);
    }
}
