//! Baseline eviction/compression policies the paper compares against.
//!
//! Each is a faithful *mechanism* reproduction at the granularity this
//! runtime supports (whole-token slots shared across layers — the same
//! granularity HAE itself uses). Where the original method needs machinery
//! this substrate cannot express (per-layer ratios, per-head cache masks,
//! trained gates), the closest behaviour-preserving approximation is used
//! and noted on the struct — these are the substitutions DESIGN.md §3
//! documents.

use crate::cache::slab::Modality;

use super::policy::{
    lowest_score_slots, DecodeCtx, EvictionPolicy, PrefillCtx, PrefillDecision,
    StepDecision, DEFAULT_RECENT_PROTECT,
};

// ---------------------------------------------------------------------------
// Full cache (no eviction)
// ---------------------------------------------------------------------------

/// Upper-bound reference: keeps everything; only the engine's hard
/// capacity fallback can ever evict (sliding-window oldest-first).
pub struct FullCache;

impl EvictionPolicy for FullCache {
    fn name(&self) -> &'static str {
        "full"
    }

    fn prefill(&mut self, ctx: &PrefillCtx) -> PrefillDecision {
        PrefillDecision::retain_all(ctx.n_tokens)
    }

    fn post_step(&mut self, _ctx: &DecodeCtx) -> StepDecision {
        StepDecision::keep()
    }

    fn capacity_fallback(&mut self, ctx: &DecodeCtx, need: usize) -> Vec<usize> {
        // sliding window: drop the oldest slots
        (0..need.min(ctx.slab.len())).collect()
    }
}

// ---------------------------------------------------------------------------
// FastV (Chen et al. 2024a)
// ---------------------------------------------------------------------------

/// FastV prunes a fixed fraction of visual tokens after the early layers,
/// ranked by attention received. Here the rank signal is the layer-0
/// text→vision mass (same signal the real method reads at its pruning
/// layer) and the prune is applied at prefill hand-off. No decode-stage
/// eviction.
pub struct FastV {
    /// fraction of visual tokens to retain (paper Table 1 uses 192/576 = ⅓)
    pub retain_ratio: f32,
}

impl EvictionPolicy for FastV {
    fn name(&self) -> &'static str {
        "fastv"
    }

    fn prefill(&mut self, ctx: &PrefillCtx) -> PrefillDecision {
        let vision = ctx.vision_slots();
        let keep_n = ((vision.len() as f32 * self.retain_ratio).round() as usize)
            .clamp(1, vision.len());
        let mut ranked = vision.clone();
        ranked.sort_by(|&a, &b| ctx.dap_sum[b].total_cmp(&ctx.dap_sum[a]));
        let kept: std::collections::BTreeSet<usize> =
            ranked.into_iter().take(keep_n).collect();
        PrefillDecision::retain(
            (0..ctx.n_tokens)
                .filter(|i| !ctx.is_vision[*i] || kept.contains(i))
                .collect(),
        )
    }

    fn post_step(&mut self, _ctx: &DecodeCtx) -> StepDecision {
        StepDecision::keep()
    }
}

// ---------------------------------------------------------------------------
// SparseVLM (Zhang et al. 2024)
// ---------------------------------------------------------------------------

/// Text-guided visual sparsification with token recycling: retain the
/// top-k visual tokens by text relevance and *recycle* the pruned ones by
/// merging their KV (mean) into the lowest-ranked retained token instead of
/// discarding the mass outright. (The original applies rank-based per-layer
/// ratios; the broadcast substrate applies one global ratio.)
pub struct SparseVlm {
    pub retain_ratio: f32,
}

impl EvictionPolicy for SparseVlm {
    fn name(&self) -> &'static str {
        "sparsevlm"
    }

    fn prefill(&mut self, ctx: &PrefillCtx) -> PrefillDecision {
        let vision = ctx.vision_slots();
        let keep_n = ((vision.len() as f32 * self.retain_ratio).round() as usize)
            .clamp(1, vision.len());
        let mut ranked = vision.clone();
        ranked.sort_by(|&a, &b| ctx.dap_sum[b].total_cmp(&ctx.dap_sum[a]));
        let kept: Vec<usize> = ranked[..keep_n].to_vec();
        let dropped: Vec<usize> = ranked[keep_n..].to_vec();

        let mut k = ctx.k.to_vec();
        let mut v = ctx.v.to_vec();
        if !dropped.is_empty() {
            // recycle: average the dropped tokens' KV into the weakest kept
            // token (rank keep_n-1)
            let Some(&sink) = kept.last() else {
                // keep_n is clamped to ≥ 1, so kept is never empty
                return PrefillDecision::retain_all(ctx.n_tokens);
            };
            let row = ctx.meta.n_heads * ctx.meta.d_head;
            let w_old = 1.0 / (dropped.len() + 1) as f32;
            for l in 0..ctx.meta.n_layers {
                let sink_off = (l * ctx.bucket + sink) * row;
                for d in 0..row {
                    let mut acc_k = k[sink_off + d];
                    let mut acc_v = v[sink_off + d];
                    for &j in &dropped {
                        let off = (l * ctx.bucket + j) * row;
                        acc_k += ctx.k[off + d];
                        acc_v += ctx.v[off + d];
                    }
                    k[sink_off + d] = acc_k * w_old;
                    v[sink_off + d] = acc_v * w_old;
                }
            }
        }

        let kept_set: std::collections::BTreeSet<usize> = kept.into_iter().collect();
        let retain: Vec<usize> = (0..ctx.n_tokens)
            .filter(|i| !ctx.is_vision[*i] || kept_set.contains(i))
            .collect();
        PrefillDecision { retain, kv_override: Some((k, v)) }
    }

    fn post_step(&mut self, _ctx: &DecodeCtx) -> StepDecision {
        StepDecision::keep()
    }
}

// ---------------------------------------------------------------------------
// ToMe (Bolya et al. 2023)
// ---------------------------------------------------------------------------

/// Token Merging: repeatedly merge the most similar pair of visual tokens
/// (cosine similarity of their layer-0 keys) until only
/// `retain_ratio · |V|` remain. Merged KV rows are averaged — information
/// is pooled rather than discarded, which is why ToMe degrades differently
/// from pruning baselines.
pub struct ToMe {
    pub retain_ratio: f32,
}

impl ToMe {
    fn key_vec<'a>(ctx: &'a PrefillCtx, slot: usize) -> &'a [f32] {
        let row = ctx.meta.n_heads * ctx.meta.d_head;
        let off = slot * row; // layer 0
        &ctx.k[off..off + row]
    }

    fn cosine(a: &[f32], b: &[f32]) -> f32 {
        let mut dot = 0.0;
        let mut na = 0.0;
        let mut nb = 0.0;
        for (x, y) in a.iter().zip(b) {
            dot += x * y;
            na += x * x;
            nb += y * y;
        }
        dot / (na.sqrt() * nb.sqrt() + 1e-9)
    }
}

impl EvictionPolicy for ToMe {
    fn name(&self) -> &'static str {
        "tome"
    }

    fn prefill(&mut self, ctx: &PrefillCtx) -> PrefillDecision {
        let vision = ctx.vision_slots();
        let target = ((vision.len() as f32 * self.retain_ratio).round() as usize)
            .clamp(1, vision.len());
        // groups[i] = members merged into representative vision[i]
        let mut alive: Vec<usize> = vision.clone();
        let mut members: std::collections::BTreeMap<usize, Vec<usize>> =
            vision.iter().map(|&s| (s, vec![s])).collect();
        while alive.len() > target {
            // greedy closest pair on layer-0 keys (O(n²) — |V| is small)
            let mut best = (0usize, 1usize, f32::NEG_INFINITY);
            for i in 0..alive.len() {
                for j in (i + 1)..alive.len() {
                    let sim = Self::cosine(
                        Self::key_vec(ctx, alive[i]),
                        Self::key_vec(ctx, alive[j]),
                    );
                    if sim > best.2 {
                        best = (i, j, sim);
                    }
                }
            }
            let (i, j, _) = best;
            let (keep_slot, drop_slot) = (alive[i], alive[j]);
            if let Some(moved) = members.remove(&drop_slot) {
                members.entry(keep_slot).or_default().extend(moved);
            }
            alive.remove(j);
        }

        // average each group's KV rows into the representative slot
        let mut k = ctx.k.to_vec();
        let mut v = ctx.v.to_vec();
        let row = ctx.meta.n_heads * ctx.meta.d_head;
        for (&rep, group) in &members {
            if group.len() == 1 {
                continue;
            }
            let w = 1.0 / group.len() as f32;
            for l in 0..ctx.meta.n_layers {
                let rep_off = (l * ctx.bucket + rep) * row;
                for d in 0..row {
                    let mut acc_k = 0.0;
                    let mut acc_v = 0.0;
                    for &g in group {
                        let off = (l * ctx.bucket + g) * row;
                        acc_k += ctx.k[off + d];
                        acc_v += ctx.v[off + d];
                    }
                    k[rep_off + d] = acc_k * w;
                    v[rep_off + d] = acc_v * w;
                }
            }
        }

        let alive_set: std::collections::BTreeSet<usize> = alive.into_iter().collect();
        let retain: Vec<usize> = (0..ctx.n_tokens)
            .filter(|i| !ctx.is_vision[*i] || alive_set.contains(i))
            .collect();
        PrefillDecision { retain, kv_override: Some((k, v)) }
    }

    fn post_step(&mut self, _ctx: &DecodeCtx) -> StepDecision {
        StepDecision::keep()
    }
}

// ---------------------------------------------------------------------------
// MustDrop (Liu et al. 2024b)
// ---------------------------------------------------------------------------

/// Multi-stage vision-token dropping: (1) merge near-duplicate *adjacent*
/// visual tokens (the vision-encoding spatial-merge stage), (2) drop
/// low-text-relevance visual tokens by global threshold — crucially
/// *without* HAE's Eq. 3 individual-max rescue, the gap Table 1 exposes —
/// and (3) an output-aware decode stage that evicts only visual tokens.
pub struct MustDrop {
    /// global relevance threshold as an absolute fraction of the total
    /// visual mass; values < 0 mean "uniform share 1/|V|" (scale-invariant)
    pub r: f32,
    /// cosine similarity above which adjacent visual tokens merge
    pub merge_sim: f32,
    /// decode-stage budget (None = post-prefill length)
    pub budget: Option<usize>,
    decisions: u64,
}

impl MustDrop {
    pub fn new(r: f32, merge_sim: f32, budget: Option<usize>) -> Self {
        MustDrop { r, merge_sim, budget, decisions: 0 }
    }
}

impl EvictionPolicy for MustDrop {
    fn name(&self) -> &'static str {
        "mustdrop"
    }

    fn prefill(&mut self, ctx: &PrefillCtx) -> PrefillDecision {
        let vision = ctx.vision_slots();
        let row = ctx.meta.n_heads * ctx.meta.d_head;

        // stage 1: merge adjacent near-duplicates (drop the later twin)
        let mut merged_away: std::collections::BTreeSet<usize> =
            std::collections::BTreeSet::new();
        let mut k = ctx.k.to_vec();
        let mut v = ctx.v.to_vec();
        for w in vision.windows(2) {
            let (a, b) = (w[0], w[1]);
            if merged_away.contains(&a) {
                continue;
            }
            let sim = ToMe::cosine(
                &ctx.k[a * row..a * row + row],
                &ctx.k[b * row..b * row + row],
            );
            if sim > self.merge_sim {
                merged_away.insert(b);
                for l in 0..ctx.meta.n_layers {
                    let ao = (l * ctx.bucket + a) * row;
                    let bo = (l * ctx.bucket + b) * row;
                    for d in 0..row {
                        k[ao + d] = 0.5 * (ctx.k[ao + d] + ctx.k[bo + d]);
                        v[ao + d] = 0.5 * (ctx.v[ao + d] + ctx.v[bo + d]);
                    }
                }
            }
        }

        // stage 2: global-threshold drop (no individual-max rescue)
        let total: f32 = vision.iter().map(|&i| ctx.dap_sum[i]).sum();
        let r_abs =
            if self.r < 0.0 { 1.0 / vision.len().max(1) as f32 } else { self.r };
        let threshold = r_abs * total;
        let retain: Vec<usize> = (0..ctx.n_tokens)
            .filter(|&i| {
                if !ctx.is_vision[i] {
                    return true;
                }
                if merged_away.contains(&i) {
                    return false;
                }
                ctx.dap_sum[i] >= threshold
            })
            .collect();
        PrefillDecision { retain, kv_override: Some((k, v)) }
    }

    fn post_step(&mut self, ctx: &DecodeCtx) -> StepDecision {
        // stage 3: output-aware — evict lowest-scored *visual* tokens when
        // over budget (greedy, per step)
        let budget = self.budget.unwrap_or(ctx.prefill_len).min(ctx.capacity_limit - 1);
        let len = ctx.slab.len();
        if len <= budget {
            return StepDecision::keep();
        }
        self.decisions += 1;
        let mut vis: Vec<usize> = (0..len)
            .filter(|&i| ctx.slab.meta()[i].modality == Modality::Vision)
            .collect();
        vis.sort_by(|&a, &b| {
            ctx.slab.meta()[a]
                .cum_score
                .total_cmp(&ctx.slab.meta()[b].cum_score)
        });
        let mut evict: Vec<usize> = vis.into_iter().take(len - budget).collect();
        if evict.is_empty() {
            // no visual tokens left — fall back to global lowest
            evict = lowest_score_slots(ctx.slab, len - budget, DEFAULT_RECENT_PROTECT);
        }
        evict.sort_unstable();
        StepDecision { mark: Vec::new(), evict }
    }

    fn decision_count(&self) -> u64 {
        self.decisions
    }
}

// ---------------------------------------------------------------------------
// SnapKV (Li et al. 2024c)
// ---------------------------------------------------------------------------

/// SnapKV compresses the prompt cache once at the end of prefill: an
/// observation window (the last `window` prompt tokens) votes for the
/// important prefix positions; top-k voted positions plus the window are
/// kept. The vote signal here is the layer-0 attention mass (dap_sum
/// includes exactly the text-query votes). Decode-stage: H2O-style budget
/// maintenance.
pub struct SnapKv {
    pub budget: usize,
    pub window: usize,
    decisions: u64,
}

impl SnapKv {
    pub fn new(budget: usize, window: usize) -> Self {
        SnapKv { budget, window, decisions: 0 }
    }
}

impl EvictionPolicy for SnapKv {
    fn name(&self) -> &'static str {
        "snapkv"
    }

    fn prefill(&mut self, ctx: &PrefillCtx) -> PrefillDecision {
        let n = ctx.n_tokens;
        if n <= self.budget {
            return PrefillDecision::retain_all(n);
        }
        self.decisions += 1;
        let window_start = n.saturating_sub(self.window);
        let mut prefix: Vec<usize> = (0..window_start).collect();
        prefix.sort_by(|&a, &b| ctx.dap_sum[b].total_cmp(&ctx.dap_sum[a]));
        let keep_prefix = self.budget.saturating_sub(n - window_start);
        let mut retain: Vec<usize> = prefix.into_iter().take(keep_prefix).collect();
        retain.extend(window_start..n);
        PrefillDecision::retain(retain)
    }

    fn post_step(&mut self, ctx: &DecodeCtx) -> StepDecision {
        let len = ctx.slab.len();
        let budget = self.budget.min(ctx.capacity_limit - 1);
        if len <= budget {
            return StepDecision::keep();
        }
        self.decisions += 1;
        StepDecision {
            mark: Vec::new(),
            evict: lowest_score_slots(ctx.slab, len - budget, self.window.min(len / 2)),
        }
    }

    fn decision_count(&self) -> u64 {
        self.decisions
    }
}

// ---------------------------------------------------------------------------
// AdaKV (Feng et al. 2024)
// ---------------------------------------------------------------------------

/// AdaKV allocates the eviction budget adaptively across heads. This
/// runtime's slots span all heads, so the *allocation* is expressed in the
/// scoring instead: a slot survives on its best-head evidence
/// (`cum_peak`), blended with the mean — heads that concentrate attention
/// protect their tokens, which is the budget-shifting effect AdaKV's
/// per-head allocation produces. Noted substitution (DESIGN.md §3).
pub struct AdaKv {
    pub budget: Option<usize>,
    pub recent: usize,
    /// blend factor: 0 = pure mean (H2O), 1 = pure peak
    pub peak_weight: f32,
    decisions: u64,
}

impl AdaKv {
    pub fn new(budget: Option<usize>, recent: usize, peak_weight: f32) -> Self {
        AdaKv { budget, recent, peak_weight, decisions: 0 }
    }
}

impl EvictionPolicy for AdaKv {
    fn name(&self) -> &'static str {
        "adakv"
    }

    fn prefill(&mut self, ctx: &PrefillCtx) -> PrefillDecision {
        PrefillDecision::retain_all(ctx.n_tokens)
    }

    fn post_step(&mut self, ctx: &DecodeCtx) -> StepDecision {
        let budget = self.budget.unwrap_or(ctx.prefill_len).min(ctx.capacity_limit - 1);
        let len = ctx.slab.len();
        if len <= budget {
            return StepDecision::keep();
        }
        self.decisions += 1;
        let evictable = len.saturating_sub(self.recent);
        let w = self.peak_weight;
        let mut idx: Vec<usize> = (0..evictable).collect();
        let score = |i: usize| {
            let m = &ctx.slab.meta()[i];
            (1.0 - w) * m.cum_score + w * m.cum_peak
        };
        idx.sort_by(|&a, &b| score(a).total_cmp(&score(b)).then(a.cmp(&b)));
        let mut evict: Vec<usize> = idx.into_iter().take(len - budget).collect();
        evict.sort_unstable();
        StepDecision { mark: Vec::new(), evict }
    }

    fn decision_count(&self) -> u64 {
        self.decisions
    }
}

// ---------------------------------------------------------------------------
// StreamingLLM-style sliding window (ablation extra)
// ---------------------------------------------------------------------------

/// Attention-sink sliding window: keep the first `sinks` slots and the
/// most recent `window` slots; evict everything in between. Not a paper
/// baseline, but a useful lower-anchor ablation for the benches.
pub struct SlidingWindow {
    pub sinks: usize,
    pub window: usize,
}

impl EvictionPolicy for SlidingWindow {
    fn name(&self) -> &'static str {
        "window"
    }

    fn prefill(&mut self, ctx: &PrefillCtx) -> PrefillDecision {
        PrefillDecision::retain_all(ctx.n_tokens)
    }

    fn post_step(&mut self, ctx: &DecodeCtx) -> StepDecision {
        let len = ctx.slab.len();
        let keep = self.sinks + self.window;
        if len <= keep {
            return StepDecision::keep();
        }
        let evict: Vec<usize> = (self.sinks..len - self.window).collect();
        StepDecision { mark: Vec::new(), evict }
    }
}

// ---------------------------------------------------------------------------
// Random eviction (sanity anchor)
// ---------------------------------------------------------------------------

/// Evicts uniformly random unprotected slots when over budget. Any
/// score-guided policy must beat this.
pub struct RandomEvict {
    pub budget: Option<usize>,
    pub rng: crate::util::rng::Rng,
}

impl EvictionPolicy for RandomEvict {
    fn name(&self) -> &'static str {
        "random"
    }

    fn prefill(&mut self, ctx: &PrefillCtx) -> PrefillDecision {
        PrefillDecision::retain_all(ctx.n_tokens)
    }

    fn post_step(&mut self, ctx: &DecodeCtx) -> StepDecision {
        let budget = self.budget.unwrap_or(ctx.prefill_len).min(ctx.capacity_limit - 1);
        let len = ctx.slab.len();
        if len <= budget {
            return StepDecision::keep();
        }
        let need = len - budget;
        let evictable = len.saturating_sub(DEFAULT_RECENT_PROTECT);
        let mut evict = self.rng.choose_k(evictable, need);
        evict.sort_unstable();
        evict.dedup();
        StepDecision { mark: Vec::new(), evict }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::cache::slab::{KvSlab, Modality};
    use crate::model::ModelMeta;

    fn tiny_meta() -> ModelMeta {
        ModelMeta {
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 2,
            d_mlp: 8,
            patch_dim: 4,
            n_patches: 4,
            max_pos: 64,
            dap_layer: 1,
        }
    }

    fn prefill_ctx_fixture<'a>(
        m: &'a ModelMeta,
        dap_sum: &'a [f32],
        dap_max: &'a [f32],
        is_vision: &'a [bool],
        k: &'a [f32],
        v: &'a [f32],
        bucket: usize,
    ) -> PrefillCtx<'a> {
        PrefillCtx {
            dap_sum,
            dap_max,
            is_vision,
            n_tokens: is_vision.len(),
            k,
            v,
            bucket,
            meta: m,
        }
    }

    #[test]
    fn fastv_keeps_top_ratio() {
        let m = tiny_meta();
        let bucket = 6;
        let row = m.n_heads * m.d_head;
        let k = vec![0.0f32; m.n_layers * bucket * row];
        let v = k.clone();
        let is_vision = [true, true, true, true, false, false];
        let dap_sum = [0.4, 0.1, 0.3, 0.2, 0.0, 0.0];
        let dap_max = [0.0; 6];
        let ctx = prefill_ctx_fixture(&m, &dap_sum, &dap_max, &is_vision, &k, &v, bucket);
        let mut p = FastV { retain_ratio: 0.5 };
        let d = p.prefill(&ctx);
        // top-2 vision by dap_sum = slots 0, 2; all text kept
        assert_eq!(d.retain, vec![0, 2, 4, 5]);
    }

    #[test]
    fn sparsevlm_recycles_mass() {
        let m = tiny_meta();
        let bucket = 4;
        let row = m.n_heads * m.d_head;
        let mut k = vec![0.0f32; m.n_layers * bucket * row];
        // distinct values per slot in layer 0
        for slot in 0..bucket {
            for d in 0..row {
                k[slot * row + d] = slot as f32 + 1.0;
            }
        }
        let v = k.clone();
        let is_vision = [true, true, true, false];
        let dap_sum = [0.5, 0.3, 0.1, 0.0];
        let dap_max = [0.0; 4];
        let ctx = prefill_ctx_fixture(&m, &dap_sum, &dap_max, &is_vision, &k, &v, bucket);
        let mut p = SparseVlm { retain_ratio: 0.67 };
        let d = p.prefill(&ctx);
        assert_eq!(d.retain, vec![0, 1, 3]);
        let (nk, _) = d.kv_override.unwrap();
        // sink = slot 1 (weakest kept); merged with dropped slot 2:
        // (2 + 3) / 2 = 2.5 in layer 0
        assert!((nk[1 * row] - 2.5).abs() < 1e-6);
        // untouched slot keeps its value
        assert!((nk[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn tome_merges_most_similar() {
        let m = tiny_meta();
        let bucket = 4;
        let row = m.n_heads * m.d_head;
        let mut k = vec![0.0f32; m.n_layers * bucket * row];
        // slots 0,1 identical keys; slot 2 orthogonal
        for d in 0..row {
            k[d] = 1.0;
            k[row + d] = 1.0;
        }
        k[2 * row] = -1.0;
        let v = k.clone();
        let is_vision = [true, true, true, false];
        let dap = [0.0f32; 4];
        let ctx = prefill_ctx_fixture(&m, &dap, &dap, &is_vision, &k, &v, bucket);
        let mut p = ToMe { retain_ratio: 0.67 };
        let d = p.prefill(&ctx);
        // 3 vision → 2: slots 0 and 1 merge; retained vision = {0, 2}
        assert_eq!(d.retain, vec![0, 2, 3]);
    }

    #[test]
    fn snapkv_keeps_window_and_heavy() {
        let m = tiny_meta();
        let bucket = 8;
        let k = vec![0.0f32; m.n_layers * bucket * (m.n_heads * m.d_head)];
        let v = k.clone();
        let is_vision = [false; 8];
        let dap_sum = [0.9, 0.1, 0.8, 0.2, 0.1, 0.1, 0.1, 0.1];
        let dap_max = [0.0; 8];
        let ctx = prefill_ctx_fixture(&m, &dap_sum, &dap_max, &is_vision, &k, &v, bucket);
        let mut p = SnapKv::new(4, 2);
        let d = p.prefill(&ctx);
        // window = {6, 7}; top-2 voted prefix = {0, 2}
        assert_eq!(d.retain, vec![0, 2, 6, 7]);
    }

    #[test]
    fn mustdrop_decode_evicts_vision_first() {
        let m = tiny_meta();
        let mut slab = KvSlab::new(&m, 32);
        let row = vec![0.0f32; m.n_layers * m.n_heads * m.d_head];
        slab.append(&row, &row, 0, Modality::Text, 0.01);
        slab.append(&row, &row, 1, Modality::Vision, 0.02);
        slab.append(&row, &row, 2, Modality::Vision, 0.5);
        slab.append(&row, &row, 3, Modality::Text, 0.9);
        let mut p = MustDrop::new(0.0, 2.0, Some(3));
        let ctx = DecodeCtx { slab: &slab, step: 0, prefill_len: 3, capacity_limit: 31 };
        let d = p.post_step(&ctx);
        // over budget by 1 → evict lowest-scored VISION slot (1), even
        // though text slot 0 has a lower score
        assert_eq!(d.evict, vec![1]);
    }

    #[test]
    fn sliding_window_keeps_sinks() {
        let m = tiny_meta();
        let mut slab = KvSlab::new(&m, 32);
        let row = vec![0.0f32; m.n_layers * m.n_heads * m.d_head];
        for i in 0..10 {
            slab.append(&row, &row, i, Modality::Text, 0.0);
        }
        let mut p = SlidingWindow { sinks: 2, window: 3 };
        let ctx = DecodeCtx { slab: &slab, step: 0, prefill_len: 5, capacity_limit: 31 };
        let d = p.post_step(&ctx);
        assert_eq!(d.evict, vec![2, 3, 4, 5, 6]);
    }

    #[test]
    fn adakv_peak_protects() {
        let m = tiny_meta();
        let mut slab = KvSlab::new(&m, 32);
        let row = vec![0.0f32; m.n_layers * m.n_heads * m.d_head];
        for i in 0..6 {
            slab.append(&row, &row, i, Modality::Text, 0.0);
        }
        // slot 0: low mean, HIGH peak (one head loves it)
        // slot 1: low mean, low peak
        slab.meta_mut()[0].cum_score = 0.1;
        slab.meta_mut()[0].cum_peak = 0.9;
        slab.meta_mut()[1].cum_score = 0.1;
        slab.meta_mut()[1].cum_peak = 0.1;
        for i in 2..6 {
            slab.meta_mut()[i].cum_score = 0.8;
            slab.meta_mut()[i].cum_peak = 0.8;
        }
        let mut p = AdaKv::new(Some(5), 0, 0.5);
        let ctx = DecodeCtx { slab: &slab, step: 0, prefill_len: 5, capacity_limit: 31 };
        let d = p.post_step(&ctx);
        assert_eq!(d.evict, vec![1], "peak evidence must protect slot 0");
    }
}
