//! Eviction-policy interface shared by HAE and every baseline.
//!
//! A policy participates at two points of a request's lifetime, mirroring
//! the paper's two stages:
//!
//! * **prefill** — after the prompt's KV and layer-0 DAP statistics are
//!   available, the policy decides which prompt slots enter the cache
//!   (and may rewrite KV rows, e.g. ToMe-style merging);
//! * **post_step** — after every decode step (scores already accumulated
//!   into the slab), the policy may *mark* slots (DDES recycle bin —
//!   marked slots stay attendable) and/or *evict* slots immediately.
//!
//! The engine enforces the hard capacity limit: if a step would overflow
//! the largest bucket it calls `capacity_fallback`, whose default evicts
//! the lowest-cumulative-score unprotected slot (never the last
//! `recent_protect` slots).

use crate::model::ModelMeta;

use super::slab::KvSlab;

/// Inputs available to a prefill-stage decision.
pub struct PrefillCtx<'a> {
    /// Eq. 1 — layer-0 text→key attention mass per prompt slot
    pub dap_sum: &'a [f32],
    /// Eq. 3 — layer-0 max text→key attention per prompt slot
    pub dap_max: &'a [f32],
    pub is_vision: &'a [bool],
    /// valid prompt length (≤ bucket)
    pub n_tokens: usize,
    /// `[L, S, H, Dh]` prompt KV (read-only; baselines may derive merges)
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub bucket: usize,
    pub meta: &'a ModelMeta,
}

impl<'a> PrefillCtx<'a> {
    /// Indices of valid vision slots.
    pub fn vision_slots(&self) -> Vec<usize> {
        (0..self.n_tokens).filter(|&i| self.is_vision[i]).collect()
    }

    /// Total Eq. 1 mass over vision slots (the denominator of Eq. 2).
    pub fn vision_mass(&self) -> f32 {
        self.vision_slots().iter().map(|&i| self.dap_sum[i]).sum()
    }
}

/// Result of a prefill-stage decision.
pub struct PrefillDecision {
    /// prompt slot indices to retain, ascending
    pub retain: Vec<usize>,
    /// optional rewritten KV slabs `[L, S, H, Dh]` (token-merging baselines)
    pub kv_override: Option<(Vec<f32>, Vec<f32>)>,
}

impl PrefillDecision {
    pub fn retain_all(n: usize) -> Self {
        PrefillDecision { retain: (0..n).collect(), kv_override: None }
    }

    pub fn retain(mut idx: Vec<usize>) -> Self {
        idx.sort_unstable();
        idx.dedup();
        PrefillDecision { retain: idx, kv_override: None }
    }
}

/// Inputs available after each decode step.
pub struct DecodeCtx<'a> {
    pub slab: &'a KvSlab,
    /// decode step index within this request (0 = first generated token)
    pub step: usize,
    /// live length right after prefill injection (the paper's `l`)
    pub prefill_len: usize,
    /// hard limit on live length (largest capacity bucket − 1)
    pub capacity_limit: usize,
}

/// What to do after a step.
#[derive(Debug, Default, Clone)]
pub struct StepDecision {
    /// slots to mark into the recycle bin (stay attendable)
    pub mark: Vec<usize>,
    /// slots to evict right now
    pub evict: Vec<usize>,
}

impl StepDecision {
    pub fn keep() -> Self {
        StepDecision::default()
    }
}

pub trait EvictionPolicy {
    fn name(&self) -> &'static str;

    fn prefill(&mut self, ctx: &PrefillCtx) -> PrefillDecision;

    fn post_step(&mut self, ctx: &DecodeCtx) -> StepDecision;

    /// Emergency eviction when the live length hits the hard capacity
    /// limit and `post_step` freed nothing. Must return ≥ `need` slots.
    fn capacity_fallback(&mut self, ctx: &DecodeCtx, need: usize) -> Vec<usize> {
        lowest_score_slots(ctx.slab, need, DEFAULT_RECENT_PROTECT)
    }

    /// Number of decode-eviction decision computations performed so far
    /// (the paper's Table 3 argument: H2O sorts every step, DDES amortises).
    fn decision_count(&self) -> u64 {
        0
    }
}

/// Protect this many most-recent slots from eviction by default (all
/// policies keep a small recency window, following H2O's recent-token half).
pub const DEFAULT_RECENT_PROTECT: usize = 8;

/// Indices of the `n` lowest-cumulative-score slots, excluding the last
/// `protect` slots. Ascending index order.
pub fn lowest_score_slots(slab: &KvSlab, n: usize, protect: usize) -> Vec<usize> {
    let len = slab.len();
    let evictable = len.saturating_sub(protect);
    let mut idx: Vec<usize> = (0..evictable).collect();
    idx.sort_by(|&a, &b| {
        // total_cmp: a NaN score (poisoned logits upstream) must rank a
        // slot, not panic the serving loop mid-batch
        slab.meta()[a]
            .cum_score
            .total_cmp(&slab.meta()[b].cum_score)
            .then(a.cmp(&b))
    });
    idx.truncate(n);
    idx.sort_unstable();
    idx
}

/// Same, but restricted to unmarked slots (DDES marking pass).
pub fn lowest_unmarked_slots(slab: &KvSlab, n: usize, protect: usize) -> Vec<usize> {
    let len = slab.len();
    let evictable = len.saturating_sub(protect);
    let mut idx: Vec<usize> = (0..evictable)
        .filter(|&i| !slab.meta()[i].marked)
        .collect();
    idx.sort_by(|&a, &b| {
        // total_cmp: a NaN score (poisoned logits upstream) must rank a
        // slot, not panic the serving loop mid-batch
        slab.meta()[a]
            .cum_score
            .total_cmp(&slab.meta()[b].cum_score)
            .then(a.cmp(&b))
    });
    idx.truncate(n);
    idx.sort_unstable();
    idx
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::cache::slab::Modality;
    use crate::model::ModelMeta;

    fn tiny_meta() -> ModelMeta {
        ModelMeta {
            vocab: 16,
            d_model: 8,
            n_layers: 1,
            n_heads: 1,
            d_head: 2,
            d_mlp: 8,
            patch_dim: 4,
            n_patches: 4,
            max_pos: 64,
            dap_layer: 1,
        }
    }

    fn slab_with_scores(scores: &[f32]) -> KvSlab {
        let m = tiny_meta();
        let mut s = KvSlab::new(&m, 32);
        for (i, &sc) in scores.iter().enumerate() {
            s.append(&[0.0, 0.0], &[0.0, 0.0], i as i32, Modality::Text, sc);
        }
        s
    }

    #[test]
    fn lowest_scores_respect_protection() {
        let s = slab_with_scores(&[0.5, 0.1, 0.9, 0.05, 0.3]);
        // protect last 2 slots (indices 3, 4) — lowest among 0..3 is idx 1
        let picks = lowest_score_slots(&s, 1, 2);
        assert_eq!(picks, vec![1]);
        // without protection the global lowest (idx 3) wins
        let picks = lowest_score_slots(&s, 1, 0);
        assert_eq!(picks, vec![3]);
    }

    #[test]
    fn lowest_returns_ascending() {
        let s = slab_with_scores(&[0.9, 0.1, 0.8, 0.2, 0.7, 0.3]);
        let picks = lowest_score_slots(&s, 3, 0);
        assert_eq!(picks, vec![1, 3, 5]);
    }

    #[test]
    fn unmarked_filter() {
        let mut s = slab_with_scores(&[0.1, 0.2, 0.3, 0.4]);
        s.meta_mut()[0].marked = true;
        let picks = lowest_unmarked_slots(&s, 1, 0);
        assert_eq!(picks, vec![1]);
    }

    #[test]
    fn prefill_decision_sorts() {
        let d = PrefillDecision::retain(vec![5, 1, 3, 1]);
        assert_eq!(d.retain, vec![1, 3, 5]);
    }
}
