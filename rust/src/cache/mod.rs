//! KV-cache management: the host-owned slab store, the eviction-policy
//! interface, HAE (the paper's contribution) and every baseline policy the
//! evaluation compares against.

// hot-path panic discipline (hae-lint R3): violations need an inline
// #[allow] plus a reasoned suppression — see docs/STATIC_ANALYSIS.md
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod baselines;
pub mod h2o;
pub mod hae;
pub mod paged;
pub mod policy;
pub mod slab;

pub use hae::{Hae, HaeConfig};
pub use paged::{
    lock_pool, lock_profiled, pages_for_slots, PagePool, PoolStats,
    SharedPagePool, DEFAULT_PAGE_SLOTS,
};
pub use policy::{
    DecodeCtx, EvictionPolicy, PrefillCtx, PrefillDecision, StepDecision,
};
pub use slab::{KvSlab, Modality, SlotMeta};

use crate::util::rng::Rng;

/// Retain ratio corresponding to the paper's headline setting
/// (192 of 576 visual tokens).
pub const PAPER_RETAIN_RATIO: f32 = 192.0 / 576.0;

/// Which eviction policy to run — the engine-facing configuration surface.
#[derive(Debug, Clone, PartialEq)]
pub enum PolicyKind {
    Full,
    Hae(HaeParams),
    H2o { budget: Option<usize>, recent: usize },
    SnapKv { budget: usize, window: usize },
    AdaKv { budget: Option<usize>, recent: usize, peak_weight: f32 },
    MustDrop { r: f32, merge_sim: f32, budget: Option<usize> },
    FastV { retain_ratio: f32 },
    SparseVlm { retain_ratio: f32 },
    ToMe { retain_ratio: f32 },
    Window { sinks: usize, window: usize },
    Random { budget: Option<usize>, seed: u64 },
}

/// HAE hyper-parameters (paper Appendix Table 5).
#[derive(Debug, Clone, PartialEq)]
pub struct HaeParams {
    /// absolute Eq. 2 threshold (None → use r_rel)
    pub r: Option<f32>,
    /// threshold as a multiple of the uniform share 1/|V|
    pub r_rel: f32,
    pub alpha: f32,
    pub rc_size: usize,
    pub prefill_stage: bool,
    pub decode_stage: bool,
}

impl Default for HaeParams {
    fn default() -> Self {
        // Paper Table 5 uses r = α = 0.0015 with 576 visual tokens — r sits
        // at ≈0.9× the uniform share 1/|V|. TinyMM images have 16 visual
        // tokens, so the scale-equivalent defaults are r = 1/16 = 0.0625
        // and α = 0.1 (calibrated to reproduce the paper's ~2/3 visual
        // eviction rate; see DESIGN.md §3 and benches/fig5_broadcast.rs).
        // Calibrated knee of the accuracy/KV trade-off at TinyMM scale
        // (benches/table1 sweeps the curve; rrel=1.0/α=0.1 reproduces the
        // paper's ~2/3 visual eviction rate at higher fidelity cost).
        HaeParams {
            r: None,
            r_rel: 0.6,
            alpha: 0.05,
            rc_size: 24,
            prefill_stage: true,
            decode_stage: true,
        }
    }
}

/// Accepted policy names — parse-failure messages list these instead of
/// a bare rejection (CLI and any JSON error reply that carries them).
pub const POLICY_NAMES: &str =
    "full, hae, h2o, snapkv, adakv, mustdrop, fastv, sparsevlm, tome, window, random";

impl PolicyKind {
    pub fn hae_default() -> Self {
        PolicyKind::Hae(HaeParams::default())
    }

    /// Whether warm prefix-cache hits preserve this policy's cold-path
    /// behaviour byte-for-byte. A hit skips `EvictionPolicy::prefill`,
    /// so any policy that consumes internal state there would desync:
    /// `random` draws from its seeded RNG at prefill, so the engine
    /// keeps the prefix cache off for it.
    pub fn prefix_safe(&self) -> bool {
        !matches!(self, PolicyKind::Random { .. })
    }

    /// Whether *partial*-prefix warm starts preserve this policy's cold
    /// behaviour. The partial path replays the retention decision from
    /// reconstructed DAP statistics (cached prefix-row contributions +
    /// this request's own suffix rows) — sound only when the policy's
    /// `prefill` is a pure function of those statistics. Policies that
    /// read the raw prompt KV or rewrite it (`kv_override`: ToMe,
    /// SparseVLM, MustDrop merge KV rows the replay cannot reproduce
    /// without the full bucket-major prefill output) go cold on a
    /// partial match instead; exact hits still serve them.
    pub fn partial_safe(&self) -> bool {
        matches!(
            self,
            PolicyKind::Full
                | PolicyKind::Hae(_)
                | PolicyKind::H2o { .. }
                | PolicyKind::SnapKv { .. }
                | PolicyKind::AdaKv { .. }
                | PolicyKind::FastV { .. }
                | PolicyKind::Window { .. }
        )
    }

    /// Parse a policy spec string, e.g. `hae`, `hae:r=0.002,rc=64`,
    /// `h2o:budget=200`, `fastv:ratio=0.33`. Used by the CLI and the bench
    /// harnesses.
    pub fn parse(spec: &str) -> Result<PolicyKind, String> {
        let (name, rest) = match spec.split_once(':') {
            Some((n, r)) => (n, r),
            None => (spec, ""),
        };
        let mut kv = std::collections::BTreeMap::new();
        for pair in rest.split(',').filter(|s| !s.is_empty()) {
            let (k, v) = pair
                .split_once('=')
                .ok_or_else(|| format!("bad param '{}' in '{}'", pair, spec))?;
            kv.insert(k.to_string(), v.to_string());
        }
        // a typo'd key (e.g. `hae:rcsize=64`) must fail loudly, not parse
        // as the defaults
        let accepted: &[&str] = match name {
            "full" => &[],
            "hae" => &["r", "rrel", "alpha", "rc", "stage"],
            "h2o" => &["budget", "recent"],
            "snapkv" => &["budget", "window"],
            "adakv" => &["budget", "recent", "peak"],
            "mustdrop" => &["r", "sim", "budget"],
            "fastv" | "sparsevlm" | "tome" => &["ratio"],
            "window" => &["sinks", "window"],
            "random" => &["budget", "seed"],
            other => {
                return Err(format!(
                    "unknown policy '{}' (accepted: {})",
                    other, POLICY_NAMES
                ))
            }
        };
        if let Some(bad) = kv.keys().find(|k| !accepted.contains(&k.as_str())) {
            return Err(format!(
                "unknown parameter '{}' for policy '{}' (accepted: {})",
                bad,
                name,
                if accepted.is_empty() { "none".to_string() } else { accepted.join(", ") }
            ));
        }
        // values must parse too — `hae:rc=64x` silently running with the
        // default rc is the same misconfiguration class as a typo'd key
        fn val<T: std::str::FromStr>(
            kv: &std::collections::BTreeMap<String, String>,
            k: &str,
            d: T,
        ) -> Result<T, String> {
            match kv.get(k) {
                None => Ok(d),
                Some(v) => v
                    .parse()
                    .map_err(|_| format!("bad value '{}' for parameter '{}'", v, k)),
            }
        }
        fn opt<T: std::str::FromStr>(
            kv: &std::collections::BTreeMap<String, String>,
            k: &str,
        ) -> Result<Option<T>, String> {
            match kv.get(k) {
                None => Ok(None),
                Some(v) => v
                    .parse()
                    .map(Some)
                    .map_err(|_| format!("bad value '{}' for parameter '{}'", v, k)),
            }
        }
        let f = |k: &str, d: f32| val::<f32>(&kv, k, d);
        let u = |k: &str, d: usize| val::<usize>(&kv, k, d);
        let opt_u = |k: &str| opt::<usize>(&kv, k);
        Ok(match name {
            "full" => PolicyKind::Full,
            "hae" => {
                let (prefill_stage, decode_stage) = match kv.get("stage").map(|s| s.as_str())
                {
                    None | Some("all") => (true, true),
                    Some("prefill") => (true, false),
                    Some("decode") => (false, true),
                    Some(other) => {
                        return Err(format!(
                            "bad value '{}' for parameter 'stage' (prefill|decode|all)",
                            other
                        ))
                    }
                };
                PolicyKind::Hae(HaeParams {
                    r: opt::<f32>(&kv, "r")?,
                    r_rel: f("rrel", 0.6)?,
                    alpha: f("alpha", 0.05)?,
                    rc_size: u("rc", 24)?,
                    prefill_stage,
                    decode_stage,
                })
            }
            "h2o" => PolicyKind::H2o { budget: opt_u("budget")?, recent: u("recent", 16)? },
            "snapkv" => {
                PolicyKind::SnapKv { budget: u("budget", 192)?, window: u("window", 16)? }
            }
            "adakv" => PolicyKind::AdaKv {
                budget: opt_u("budget")?,
                recent: u("recent", 16)?,
                peak_weight: f("peak", 0.5)?,
            },
            "mustdrop" => PolicyKind::MustDrop {
                r: f("r", -1.0)?, // <0 → relative uniform-share threshold
                merge_sim: f("sim", 0.95)?,
                budget: opt_u("budget")?,
            },
            "fastv" => PolicyKind::FastV { retain_ratio: f("ratio", PAPER_RETAIN_RATIO)? },
            "sparsevlm" => {
                PolicyKind::SparseVlm { retain_ratio: f("ratio", PAPER_RETAIN_RATIO)? }
            }
            "tome" => PolicyKind::ToMe { retain_ratio: f("ratio", PAPER_RETAIN_RATIO)? },
            "window" => {
                PolicyKind::Window { sinks: u("sinks", 4)?, window: u("window", 64)? }
            }
            "random" => PolicyKind::Random {
                budget: opt_u("budget")?,
                seed: u("seed", 17)? as u64,
            },
            other => {
                return Err(format!(
                    "unknown policy '{}' (accepted: {})",
                    other, POLICY_NAMES
                ))
            }
        })
    }

    pub fn build(&self) -> Box<dyn EvictionPolicy> {
        match self.clone() {
            PolicyKind::Full => Box::new(baselines::FullCache),
            PolicyKind::Hae(p) => Box::new(Hae::new(HaeConfig {
                r: p.r,
                r_rel: p.r_rel,
                alpha: p.alpha,
                rc_size: p.rc_size,
                prefill_stage: p.prefill_stage,
                decode_stage: p.decode_stage,
                ..HaeConfig::default()
            })),
            PolicyKind::H2o { budget, recent } => {
                Box::new(h2o::H2o::new(h2o::H2oConfig { budget, recent }))
            }
            PolicyKind::SnapKv { budget, window } => {
                Box::new(baselines::SnapKv::new(budget, window))
            }
            PolicyKind::AdaKv { budget, recent, peak_weight } => {
                Box::new(baselines::AdaKv::new(budget, recent, peak_weight))
            }
            PolicyKind::MustDrop { r, merge_sim, budget } => {
                Box::new(baselines::MustDrop::new(r, merge_sim, budget))
            }
            PolicyKind::FastV { retain_ratio } => {
                Box::new(baselines::FastV { retain_ratio })
            }
            PolicyKind::SparseVlm { retain_ratio } => {
                Box::new(baselines::SparseVlm { retain_ratio })
            }
            PolicyKind::ToMe { retain_ratio } => Box::new(baselines::ToMe { retain_ratio }),
            PolicyKind::Window { sinks, window } => {
                Box::new(baselines::SlidingWindow { sinks, window })
            }
            PolicyKind::Random { budget, seed } => {
                Box::new(baselines::RandomEvict { budget, rng: Rng::new(seed) })
            }
        }
    }

    pub fn label(&self) -> String {
        match self {
            PolicyKind::Full => "Full Cache".into(),
            PolicyKind::Hae(p) => {
                match (p.prefill_stage, p.decode_stage) {
                    (true, true) => "HAE (All Stage)".into(),
                    (true, false) => "HAE (Pre-filling)".into(),
                    (false, true) => "HAE (Decoding)".into(),
                    (false, false) => "HAE (disabled)".into(),
                }
            }
            PolicyKind::H2o { .. } => "H2O".into(),
            PolicyKind::SnapKv { .. } => "SnapKV".into(),
            PolicyKind::AdaKv { .. } => "AdaKV".into(),
            PolicyKind::MustDrop { .. } => "MustDrop".into(),
            PolicyKind::FastV { .. } => "FastV".into(),
            PolicyKind::SparseVlm { .. } => "SparseVLM".into(),
            PolicyKind::ToMe { .. } => "ToMe".into(),
            PolicyKind::Window { .. } => "SlidingWindow".into(),
            PolicyKind::Random { .. } => "Random".into(),
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn parse_specs() {
        assert_eq!(PolicyKind::parse("full").unwrap(), PolicyKind::Full);
        match PolicyKind::parse("hae:r=0.002,rc=64").unwrap() {
            PolicyKind::Hae(p) => {
                assert_eq!(p.r, Some(0.002));
                assert_eq!(p.rc_size, 64);
                assert!(p.prefill_stage && p.decode_stage);
            }
            other => panic!("{:?}", other),
        }
        match PolicyKind::parse("hae:stage=prefill").unwrap() {
            PolicyKind::Hae(p) => {
                assert!(p.prefill_stage && !p.decode_stage);
            }
            other => panic!("{:?}", other),
        }
        match PolicyKind::parse("h2o:budget=200").unwrap() {
            PolicyKind::H2o { budget, .. } => assert_eq!(budget, Some(200)),
            other => panic!("{:?}", other),
        }
        assert!(PolicyKind::parse("bogus").is_err());
        assert!(PolicyKind::parse("hae:r0.002").is_err());
    }

    #[test]
    fn unknown_policy_error_lists_accepted_names() {
        let err = PolicyKind::parse("bogus").unwrap_err();
        assert!(err.contains("bogus"), "names the bad policy: {}", err);
        assert!(err.contains("hae") && err.contains("snapkv"), "lists accepted: {}", err);
        let err = PolicyKind::parse("bogus:budget=4").unwrap_err();
        assert!(err.contains("accepted"), "{}", err);
    }

    #[test]
    fn prefix_safety_gates_stateful_prefill() {
        for spec in ["full", "hae", "h2o", "snapkv", "adakv", "mustdrop", "fastv",
                     "sparsevlm", "tome", "window"] {
            assert!(PolicyKind::parse(spec).unwrap().prefix_safe(), "{}", spec);
        }
        // random consumes its RNG at prefill: a warm hit would desync it
        assert!(!PolicyKind::parse("random").unwrap().prefix_safe());
    }

    #[test]
    fn partial_safety_excludes_kv_rewriting_policies() {
        for spec in ["full", "hae", "h2o", "snapkv", "adakv", "fastv", "window"] {
            let k = PolicyKind::parse(spec).unwrap();
            assert!(k.partial_safe(), "{} decides from stats alone", spec);
            assert!(k.prefix_safe(), "partial_safe must imply prefix_safe");
        }
        // kv_override policies merge prompt KV rows the replay cannot
        // reproduce; random is unsafe for any warm start
        for spec in ["mustdrop", "sparsevlm", "tome", "random"] {
            assert!(!PolicyKind::parse(spec).unwrap().partial_safe(), "{}", spec);
        }
    }

    #[test]
    fn parse_rejects_unknown_parameter_keys() {
        // a typo'd key must not silently parse as the defaults
        let err = PolicyKind::parse("hae:rcsize=64").unwrap_err();
        assert!(err.contains("rcsize"), "names the bad key: {}", err);
        assert!(err.contains("rc"), "lists accepted keys: {}", err);
        let err = PolicyKind::parse("h2o:window=4").unwrap_err();
        assert!(err.contains("window") && err.contains("recent"), "{}", err);
        assert!(PolicyKind::parse("full:budget=4").is_err());
        // known keys still parse
        assert!(PolicyKind::parse("hae:rc=64,stage=decode").is_ok());
        assert!(PolicyKind::parse("random:seed=3,budget=8").is_ok());
    }

    #[test]
    fn parse_rejects_unparseable_values() {
        // an accepted key with a bad value must not fall back to defaults
        let err = PolicyKind::parse("hae:rc=64x").unwrap_err();
        assert!(err.contains("64x"), "names the bad value: {}", err);
        assert!(PolicyKind::parse("fastv:ratio=abc").is_err());
        assert!(PolicyKind::parse("h2o:budget=").is_err());
        let err = PolicyKind::parse("hae:stage=bogus").unwrap_err();
        assert!(err.contains("prefill|decode|all"), "{}", err);
        assert!(PolicyKind::parse("hae:stage=all").is_ok());
    }

    #[test]
    fn build_all() {
        for spec in [
            "full", "hae", "h2o", "snapkv", "adakv", "mustdrop", "fastv",
            "sparsevlm", "tome", "window", "random",
        ] {
            let kind = PolicyKind::parse(spec).unwrap();
            let p = kind.build();
            assert!(!p.name().is_empty());
            assert!(!kind.label().is_empty());
        }
    }
}
