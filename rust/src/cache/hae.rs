//! Hierarchical Adaptive Eviction — the paper's contribution.
//!
//! **DAP (Dual-Attention Pruning, §2.2.1)** runs at prefill: a vision slot
//! j is evicted iff BOTH
//!   * its global text→vision mass is below the adaptive threshold:
//!     `A_j < r · Σ_{j∈V} A_j`           (Eq. 2, complement), and
//!   * its strongest individual text link is weak:
//!     `max_i A_{i,j} < α`               (Eq. 3).
//!
//! The decision is computed once from layer-0 statistics and broadcast to
//! all layers — in this runtime the slab physically shares slots across
//! layers, so the broadcast is structural (a slot eviction removes the
//! token's KV in every layer at once), exactly the storage-uniformity
//! advantage claimed in §1. The per-layer coverage the broadcast relies on
//! (paper Fig. 5) is reproduced by `benches/fig5_broadcast.rs`.
//!
//! **DDES (Dynamic Decoding Eviction Strategy, §2.2.2)** runs at decode:
//! instead of H2O's greedy per-step eviction, the lowest-cumulative-score
//! slot is *marked* into a recycle bin each step once the cache exceeds its
//! post-prefill length `l`; when the bin holds `rc_size` entries they are
//! flushed all at once (Definition 2: `l ≤ |S2| < l + D`). Marked slots
//! remain attendable until flushed — the property behind Corollary 2.1's
//! tighter error bound, tested in rust/tests/theory.rs.

use super::policy::{
    lowest_unmarked_slots, DecodeCtx, EvictionPolicy, PrefillCtx, PrefillDecision,
    StepDecision, DEFAULT_RECENT_PROTECT,
};

#[derive(Debug, Clone)]
pub struct HaeConfig {
    /// Eq. 2 threshold r on the global attention mass, as an *absolute*
    /// fraction of the total visual mass (the paper's formulation, tuned
    /// for a fixed |V| = 576). None = use `r_rel` instead.
    pub r: Option<f32>,
    /// Eq. 2 threshold as a multiple of the uniform share 1/|V| — the
    /// |V|-invariant generalization this repo defaults to (1.0 reproduces
    /// the paper's operating point at every image count; DESIGN.md §3).
    pub r_rel: f32,
    /// Eq. 3 absolute threshold α on the max individual text link
    pub alpha: f32,
    /// recycle-bin size D (paper Table 5 "RC_size")
    pub rc_size: usize,
    /// never evict the most recent N slots
    pub recent_protect: usize,
    /// Definition 1: at most this many vision tokens may be evicted
    /// (None = no cap, the common configuration)
    pub max_evict: Option<usize>,
    /// enable the prefill stage (ablation: HAE-Decoding only)
    pub prefill_stage: bool,
    /// enable the decode stage (ablation: HAE-Pre-filling only)
    pub decode_stage: bool,
}

impl Default for HaeConfig {
    fn default() -> Self {
        // Scale-equivalent of paper Appendix Table 5 (r = α = 0.0015,
        // RC_size = 56 at 576 visual tokens / 512 max-new): r tracks the
        // uniform share 1/|V|, see cache/mod.rs HaeParams::default.
        HaeConfig {
            r: None,
            r_rel: 0.6,
            alpha: 0.05,
            rc_size: 24,
            recent_protect: DEFAULT_RECENT_PROTECT,
            max_evict: None,
            prefill_stage: true,
            decode_stage: true,
        }
    }
}

pub struct Hae {
    cfg: HaeConfig,
    decisions: u64,
}

impl Hae {
    pub fn new(cfg: HaeConfig) -> Self {
        Hae { cfg, decisions: 0 }
    }

    /// Pure DAP decision from layer statistics — exposed separately so the
    /// Fig. 5 broadcast-coverage bench can evaluate it per layer.
    ///
    /// Returns the *evicted* vision slot indices.
    pub fn dap_evict_set(
        colsum: &[f32],
        colmax: &[f32],
        is_vision: &[bool],
        n_tokens: usize,
        r: f32,
        alpha: f32,
        max_evict: Option<usize>,
    ) -> Vec<usize> {
        let vision: Vec<usize> = (0..n_tokens).filter(|&i| is_vision[i]).collect();
        let total: f32 = vision.iter().map(|&i| colsum[i]).sum();
        let threshold = r * total;
        // Text evidence is causal: only text queries *after* column j can
        // have scored it. A vision token with no posterior text rows has
        // zero evidence either way — abstain rather than evict (this keeps
        // trailing images, e.g. the final frame a continuation must
        // caption, out of DAP's reach).
        let mut text_after = vec![0usize; n_tokens + 1];
        for i in (0..n_tokens).rev() {
            text_after[i] = text_after[i + 1] + usize::from(!is_vision[i]);
        }
        let mut evict: Vec<usize> = vision
            .into_iter()
            .filter(|&j| {
                text_after[j + 1] > 0 && colsum[j] < threshold && colmax[j] < alpha
            })
            .collect();
        if let Some(cap) = max_evict {
            if evict.len() > cap {
                // keep the weakest `cap` evictions (lowest global mass)
                evict.sort_by(|&a, &b| colsum[a].total_cmp(&colsum[b]));
                evict.truncate(cap);
                evict.sort_unstable();
            }
        }
        evict
    }
}

impl EvictionPolicy for Hae {
    fn name(&self) -> &'static str {
        "hae"
    }

    fn prefill(&mut self, ctx: &PrefillCtx) -> PrefillDecision {
        if !self.cfg.prefill_stage {
            return PrefillDecision::retain_all(ctx.n_tokens);
        }
        self.decisions += 1; // one DAP decision, broadcast to all layers
        let n_vision = ctx.vision_slots().len().max(1);
        let r_abs = self.cfg.r.unwrap_or(self.cfg.r_rel / n_vision as f32);
        let evict = Self::dap_evict_set(
            ctx.dap_sum,
            ctx.dap_max,
            ctx.is_vision,
            ctx.n_tokens,
            r_abs,
            self.cfg.alpha,
            self.cfg.max_evict,
        );
        let mut drop = vec![false; ctx.n_tokens];
        for &j in &evict {
            drop[j] = true;
        }
        PrefillDecision::retain((0..ctx.n_tokens).filter(|&i| !drop[i]).collect())
    }

    fn post_step(&mut self, ctx: &DecodeCtx) -> StepDecision {
        if !self.cfg.decode_stage {
            return StepDecision::keep();
        }
        let mut d = StepDecision::keep();
        let len = ctx.slab.len();
        // Definition 2(2): once the cache has grown past `l`, mark the
        // lowest-cumulative-score unmarked slot (Eq. 4/5 criterion — the
        // slab's cum_score *is* Sc: per-step softmax mass plus the β
        // history accumulated since entry).
        if len > ctx.prefill_len {
            d.mark = lowest_unmarked_slots(ctx.slab, 1, self.cfg.recent_protect);
        }
        // Recycle-bin flush: bin full (or the hard capacity wall forces an
        // early flush). Eviction happens all at once — the single sort per
        // flush, vs H2O's sort per step.
        let marked_now = ctx.slab.marked_count() + d.mark.len();
        if marked_now >= self.cfg.rc_size || len + 1 >= ctx.capacity_limit {
            self.decisions += 1;
            let mut evict = ctx.slab.marked_slots();
            evict.extend(d.mark.iter().copied());
            evict.sort_unstable();
            evict.dedup();
            d.mark.clear();
            d.evict = evict;
        }
        d
    }

    fn decision_count(&self) -> u64 {
        self.decisions
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::cache::slab::{KvSlab, Modality};
    use crate::model::ModelMeta;

    fn tiny_meta() -> ModelMeta {
        ModelMeta {
            vocab: 16,
            d_model: 8,
            n_layers: 1,
            n_heads: 1,
            d_head: 2,
            d_mlp: 8,
            patch_dim: 4,
            n_patches: 4,
            max_pos: 64,
            dap_layer: 1,
        }
    }

    #[test]
    fn dap_requires_both_criteria() {
        let is_vision = vec![true, true, true, false];
        // slot 0: low sum, low max  -> evict
        // slot 1: low sum, HIGH max -> keep (Eq. 3 rescue)
        // slot 2: high sum, low max -> keep (Eq. 2)
        let colsum = vec![0.001, 0.001, 0.9, 0.5];
        let colmax = vec![0.0001, 0.9, 0.0001, 0.5];
        let evict =
            Hae::dap_evict_set(&colsum, &colmax, &is_vision, 4, 0.01, 0.001, None);
        assert_eq!(evict, vec![0]);
    }

    #[test]
    fn dap_never_touches_text() {
        let is_vision = vec![false, false, false];
        let colsum = vec![0.0, 0.0, 0.0];
        let colmax = vec![0.0, 0.0, 0.0];
        let evict = Hae::dap_evict_set(&colsum, &colmax, &is_vision, 3, 0.5, 0.5, None);
        assert!(evict.is_empty());
    }

    #[test]
    fn dap_max_evict_cap() {
        // trailing text token provides the causal evidence rows
        let is_vision = vec![true, true, true, true, true, false];
        let colsum = vec![0.01, 0.02, 0.03, 0.04, 10.0, 0.0];
        let colmax = vec![0.0; 6];
        let evict =
            Hae::dap_evict_set(&colsum, &colmax, &is_vision, 6, 0.05, 1.0, Some(2));
        // weakest two of the four candidates
        assert_eq!(evict, vec![0, 1]);
    }

    #[test]
    fn dap_abstains_without_text_evidence() {
        // no text after the vision tokens → nothing may be evicted
        let is_vision = vec![false, true, true, true];
        let colsum = vec![0.5, 0.0, 0.0, 0.0];
        let colmax = vec![0.0; 4];
        let evict = Hae::dap_evict_set(&colsum, &colmax, &is_vision, 4, 0.9, 0.9, None);
        assert!(evict.is_empty(), "trailing images must be kept");
    }

    #[test]
    fn ddes_marks_then_flushes() {
        let m = tiny_meta();
        let mut slab = KvSlab::new(&m, 32);
        for i in 0..10 {
            slab.append(&[0.0, 0.0], &[0.0, 0.0], i, Modality::Text, i as f32 * 0.1);
        }
        let mut hae = Hae::new(HaeConfig {
            rc_size: 3,
            recent_protect: 2,
            ..HaeConfig::default()
        });
        let prefill_len = 6;
        let mut marked_total = 0;
        for step in 0..3 {
            let ctx = DecodeCtx { slab: &slab, step, prefill_len, capacity_limit: 31 };
            let d = hae.post_step(&ctx);
            if !d.evict.is_empty() {
                // flush happens exactly when the 3rd mark lands
                assert_eq!(step, 2);
                assert_eq!(d.evict.len(), 3);
                assert!(d.mark.is_empty());
                slab.evict(&d.evict);
                marked_total += 3;
            } else {
                assert_eq!(d.mark.len(), 1);
                for &i in &d.mark {
                    slab.meta_mut()[i].marked = true;
                }
            }
        }
        assert_eq!(marked_total, 3);
        assert_eq!(slab.len(), 7);
        assert_eq!(slab.marked_count(), 0);
    }

    #[test]
    fn ddes_idle_below_prefill_len() {
        let m = tiny_meta();
        let mut slab = KvSlab::new(&m, 32);
        for i in 0..5 {
            slab.append(&[0.0, 0.0], &[0.0, 0.0], i, Modality::Text, 0.1);
        }
        let mut hae = Hae::new(HaeConfig::default());
        let ctx = DecodeCtx { slab: &slab, step: 0, prefill_len: 5, capacity_limit: 31 };
        let d = hae.post_step(&ctx);
        assert!(d.mark.is_empty() && d.evict.is_empty());
    }

    #[test]
    fn stage_toggles() {
        let mut pre_only = Hae::new(HaeConfig {
            decode_stage: false,
            ..HaeConfig::default()
        });
        let m = tiny_meta();
        let mut slab = KvSlab::new(&m, 32);
        for i in 0..20 {
            slab.append(&[0.0, 0.0], &[0.0, 0.0], i, Modality::Text, 0.1);
        }
        let ctx = DecodeCtx { slab: &slab, step: 0, prefill_len: 4, capacity_limit: 31 };
        let d = pre_only.post_step(&ctx);
        assert!(d.mark.is_empty() && d.evict.is_empty());

        let mut dec_only = Hae::new(HaeConfig {
            prefill_stage: false,
            ..HaeConfig::default()
        });
        let pctx = PrefillCtx {
            dap_sum: &[0.0; 4],
            dap_max: &[0.0; 4],
            is_vision: &[true, true, false, false],
            n_tokens: 4,
            k: &[],
            v: &[],
            bucket: 4,
            meta: &m,
        };
        let pd = dec_only.prefill(&pctx);
        assert_eq!(pd.retain.len(), 4);
    }
}
