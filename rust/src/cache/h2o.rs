//! H2O (Heavy-Hitter Oracle, Zhang et al. 2023) — greedy decode-time
//! eviction baseline.
//!
//! H2O keeps a budget-sized cache split between the most recent tokens and
//! the "heavy hitters" (highest cumulative attention). It performs no
//! prefill-stage pruning and — the paper's Table 3 point — recomputes the
//! eviction decision (a sort over all cached scores) at *every* decode
//! step, which is why its wall-clock can exceed the full-cache model on
//! short generations.

use super::policy::{
    lowest_score_slots, DecodeCtx, EvictionPolicy, PrefillCtx, PrefillDecision,
    StepDecision,
};

#[derive(Debug, Clone)]
pub struct H2oConfig {
    /// total live-slot budget; None = use the post-prefill length `l`
    pub budget: Option<usize>,
    /// size of the protected recent window (the "recent tokens" half)
    pub recent: usize,
}

impl Default for H2oConfig {
    fn default() -> Self {
        H2oConfig { budget: None, recent: 16 }
    }
}

pub struct H2o {
    cfg: H2oConfig,
    decisions: u64,
}

impl H2o {
    pub fn new(cfg: H2oConfig) -> Self {
        H2o { cfg, decisions: 0 }
    }
}

impl EvictionPolicy for H2o {
    fn name(&self) -> &'static str {
        "h2o"
    }

    fn prefill(&mut self, ctx: &PrefillCtx) -> PrefillDecision {
        PrefillDecision::retain_all(ctx.n_tokens)
    }

    fn post_step(&mut self, ctx: &DecodeCtx) -> StepDecision {
        let budget = self.cfg.budget.unwrap_or(ctx.prefill_len).min(ctx.capacity_limit - 1);
        let len = ctx.slab.len();
        if len <= budget {
            return StepDecision::keep();
        }
        // greedy: evict exactly down to budget, lowest cumulative first —
        // one decision computation per step (the cost Table 3 measures)
        self.decisions += 1;
        let evict = lowest_score_slots(ctx.slab, len - budget, self.cfg.recent);
        StepDecision { mark: Vec::new(), evict }
    }

    fn decision_count(&self) -> u64 {
        self.decisions
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::cache::slab::{KvSlab, Modality};
    use crate::model::ModelMeta;

    fn tiny_meta() -> ModelMeta {
        ModelMeta {
            vocab: 16,
            d_model: 8,
            n_layers: 1,
            n_heads: 1,
            d_head: 2,
            d_mlp: 8,
            patch_dim: 4,
            n_patches: 4,
            max_pos: 64,
            dap_layer: 1,
        }
    }

    #[test]
    fn evicts_down_to_budget_every_step() {
        let m = tiny_meta();
        let mut slab = KvSlab::new(&m, 64);
        for i in 0..12 {
            slab.append(&[0.0, 0.0], &[0.0, 0.0], i, Modality::Text, i as f32);
        }
        let mut h2o = H2o::new(H2oConfig { budget: Some(10), recent: 2 });
        let ctx = DecodeCtx { slab: &slab, step: 0, prefill_len: 10, capacity_limit: 63 };
        let d = h2o.post_step(&ctx);
        assert_eq!(d.evict.len(), 2);
        // lowest cumulative scores are slots 0 and 1
        assert_eq!(d.evict, vec![0, 1]);
        assert_eq!(h2o.decision_count(), 1);
    }

    #[test]
    fn idle_when_under_budget() {
        let m = tiny_meta();
        let mut slab = KvSlab::new(&m, 64);
        for i in 0..5 {
            slab.append(&[0.0, 0.0], &[0.0, 0.0], i, Modality::Text, 0.1);
        }
        let mut h2o = H2o::new(H2oConfig { budget: Some(10), recent: 2 });
        let ctx = DecodeCtx { slab: &slab, step: 0, prefill_len: 5, capacity_limit: 63 };
        let d = h2o.post_step(&ctx);
        assert!(d.evict.is_empty());
        assert_eq!(h2o.decision_count(), 0);
    }
}
