//! Shared paged KV arena.
//!
//! A `PagePool` owns one big K and one big V buffer, carved into
//! fixed-size **pages** of `page_slots` token slots each. Per-request
//! `KvSlab` views (cache/slab.rs) map logical slot index → (page, offset)
//! through an ordered page table, so the pool is shared by every live
//! request of an engine: a slot evicted anywhere becomes a free page —
//! and therefore admission headroom — for everyone, without a single
//! byte of cross-request copying.
//!
//! Layout: page-major, layer-major within a page —
//! `[(page * n_layers + layer) * page_slots + offset] * row` floats,
//! where `row = n_heads * d_head`. One (page, layer) run is contiguous,
//! so a lane gather copies whole `page_slots * row` spans per layer.
//!
//! Allocation is a LIFO free list over recycled pages plus a fresh-page
//! high-water mark; pages carry refcounts so the copy-on-write prefix
//! sharing layer (prefix/cow.rs, prefix/mod.rs) can pin a page under
//! several tables at once. The pool never grows:
//! `alloc` returns `None` at capacity and the scheduler's page-granular
//! admission (scheduler/admission.rs) guarantees that is never hit in
//! serving.

use std::sync::{Arc, Mutex};

use crate::model::ModelMeta;

/// Default token slots per page. Small enough that a retired request's
/// tail fragmentation (< one page per request) is negligible, large
/// enough that lane gathers move long contiguous spans; see ROADMAP
/// "Paged KV arena" for the trade-off.
pub const DEFAULT_PAGE_SLOTS: usize = 16;

/// Snapshot of pool occupancy (scheduler metrics + benches).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// total pages in the arena
    pub pages: usize,
    /// token slots per page
    pub page_slots: usize,
    /// pages currently referenced by at least one page table
    pub in_use: usize,
    /// pages available for allocation
    pub free: usize,
    /// most pages ever in use at once
    pub peak_in_use: usize,
    /// lifetime page allocations
    pub allocs: u64,
    /// lifetime page frees (refcount reached zero)
    pub frees: u64,
    /// allocations served by a recycled page rather than a fresh one —
    /// the page-reuse counter: high reuse under churn is the arena
    /// doing its job
    pub reused: u64,
    /// copy-on-write forks: a shared page cloned so one table could
    /// diverge from the prefix cache / its co-sharers
    pub forks: u64,
    /// refcount protocol violations caught and refused (double release,
    /// retain of a dead page). Always 0 in a healthy system; nonzero
    /// means a caller bug that would previously have corrupted the free
    /// list silently in release builds
    pub refcount_errors: u64,
}

#[derive(Debug)]
pub struct PagePool {
    k: Vec<f32>,
    v: Vec<f32>,
    n_layers: usize,
    /// floats per slot per layer (n_heads * d_head)
    row: usize,
    page_slots: usize,
    n_pages: usize,
    /// recycled pages ready for reuse (LIFO keeps hot pages hot)
    free: Vec<u32>,
    /// pages never handed out yet are `next_fresh..n_pages`
    next_fresh: u32,
    refcount: Vec<u32>,
    allocs: u64,
    frees: u64,
    reused: u64,
    forks: u64,
    refcount_errors: u64,
    peak_in_use: usize,
}

/// The pool handle page tables hold. `Arc<Mutex<...>>` so the engine
/// loop, worker threads and tests can share one arena: only device
/// calls are pinned to the dedicated device thread (the PJRT client is
/// `!Send` — see device/mod.rs); everything touching the pool is Send.
/// The lock is a single coarse Mutex: every critical section is a few
/// index/refcount updates or one page-sized memcpy, and the hot
/// retain/release path is measured by `perf_page_pool` — shard it only
/// if that bench shows contention (docs/CONCURRENCY.md §lock order).
pub type SharedPagePool = Arc<Mutex<PagePool>>;

/// Acquire the pool mutex, recording the acquire wait into the profiler
/// when tracing is on. Every engine pool-lock site goes through this,
/// so `hae_pool_lock_wait_ms` sees exactly the contention the coarse
/// mutex comment above asks about. Gate checked *before* the clock
/// (disabled cost: one relaxed atomic load); the obs lock is taken
/// while holding the pool guard, which follows the documented pool→obs
/// lock order (docs/CONCURRENCY.md) — never the reverse.
pub fn lock_profiled<'a>(
    pool: &'a SharedPagePool,
    obs: &crate::obs::Obs,
) -> std::sync::MutexGuard<'a, PagePool> {
    if obs.enabled() {
        let t0 = std::time::Instant::now();
        let guard = lock_pool(pool);
        let waited_ms = t0.elapsed().as_secs_f64() * 1e3;
        // hae-lint: allow(R1-lock-order) documented pool→obs direction: the profiler records under the pool guard
        obs.record(|o| o.profile.pool_lock_wait_ms.record(waited_ms));
        guard
    } else {
        lock_pool(pool)
    }
}

/// Acquire the pool mutex without profiling — the slab-internal lock
/// site. A free function (not a method) so callers can borrow just the
/// pool field while mutating sibling fields under the guard.
#[allow(clippy::unwrap_used)]
pub fn lock_pool(pool: &SharedPagePool) -> std::sync::MutexGuard<'_, PagePool> {
    // hae-lint: allow(R3-forbidden-api) a poisoned pool mutex is unrecoverable; propagate the panic
    pool.lock().unwrap()
}

impl PagePool {
    pub fn new(n_layers: usize, row: usize, n_pages: usize, page_slots: usize) -> Self {
        assert!(page_slots > 0, "page_slots must be positive");
        assert!(n_pages > 0, "pool needs at least one page");
        let floats = n_pages * n_layers * page_slots * row;
        PagePool {
            k: vec![0.0; floats],
            v: vec![0.0; floats],
            n_layers,
            row,
            page_slots,
            n_pages,
            free: Vec::new(),
            next_fresh: 0,
            refcount: vec![0; n_pages],
            allocs: 0,
            frees: 0,
            reused: 0,
            forks: 0,
            refcount_errors: 0,
            peak_in_use: 0,
        }
    }

    /// Pool sized for a model: `n_pages` pages of `page_slots` slots.
    pub fn for_model(m: &ModelMeta, n_pages: usize, page_slots: usize) -> Self {
        PagePool::new(m.n_layers, m.n_heads * m.d_head, n_pages, page_slots)
    }

    pub fn new_shared(
        n_layers: usize,
        row: usize,
        n_pages: usize,
        page_slots: usize,
    ) -> SharedPagePool {
        Arc::new(Mutex::new(PagePool::new(n_layers, row, n_pages, page_slots)))
    }

    pub fn page_slots(&self) -> usize {
        self.page_slots
    }

    pub fn n_pages(&self) -> usize {
        self.n_pages
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn row(&self) -> usize {
        self.row
    }

    pub fn in_use_pages(&self) -> usize {
        self.next_fresh as usize - self.free.len()
    }

    pub fn free_pages(&self) -> usize {
        self.n_pages - self.in_use_pages()
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            pages: self.n_pages,
            page_slots: self.page_slots,
            in_use: self.in_use_pages(),
            free: self.free_pages(),
            peak_in_use: self.peak_in_use,
            allocs: self.allocs,
            frees: self.frees,
            reused: self.reused,
            forks: self.forks,
            refcount_errors: self.refcount_errors,
        }
    }

    /// Allocate one page (refcount 1). `None` when the arena is full —
    /// callers that can hit this in serving must be guarded by the
    /// page-granular admission controller.
    pub fn alloc(&mut self) -> Option<u32> {
        let page = if let Some(p) = self.free.pop() {
            self.reused += 1;
            p
        } else if (self.next_fresh as usize) < self.n_pages {
            let p = self.next_fresh;
            self.next_fresh += 1;
            p
        } else {
            return None;
        };
        debug_assert_eq!(self.refcount[page as usize], 0, "allocated page must be dead");
        self.refcount[page as usize] = 1;
        self.allocs += 1;
        self.peak_in_use = self.peak_in_use.max(self.in_use_pages());
        Some(page)
    }

    /// Pin a page under one more table (copy-on-write prefix sharing).
    ///
    /// Retaining a dead page is a caller bug: it would hand out an alias
    /// to a page the allocator is free to recycle. The violation used to
    /// be a `debug_assert` — invisible in release builds. It is now a
    /// real error in every build: the retain is refused (`false`) and
    /// counted in `PoolStats::refcount_errors` instead of silently
    /// corrupting the free list.
    pub fn retain_page(&mut self, page: u32) -> bool {
        if self.refcount[page as usize] == 0 {
            self.refcount_errors += 1;
            return false;
        }
        self.refcount[page as usize] += 1;
        true
    }

    /// Drop one reference; the page returns to the free list at zero.
    ///
    /// A double release used to be a `debug_assert` only: in release
    /// builds the underflowing decrement pushed the page onto the free
    /// list a second time, and two later `alloc`s would hand the same
    /// page to two owners. Now the violation is a real error in every
    /// build — refused (`false`) and counted in
    /// `PoolStats::refcount_errors`.
    pub fn release(&mut self, page: u32) -> bool {
        let rc = &mut self.refcount[page as usize];
        if *rc == 0 {
            self.refcount_errors += 1;
            return false;
        }
        *rc -= 1;
        if *rc == 0 {
            self.free.push(page);
            self.frees += 1;
        }
        true
    }

    /// Current reference count of a page (copy-on-write probes: a
    /// "shared" page whose count has dropped back to 1 — e.g. the prefix
    /// cache evicted its entry — can be privatized without a copy).
    pub fn refcount(&self, page: u32) -> u32 {
        self.refcount[page as usize]
    }

    /// Retain every page in `pages`, or none: a refused retain rolls
    /// back the prefix already taken. The all-or-nothing primitive both
    /// the prefix cache (entry registration) and CoW page tables
    /// (adoption) build on.
    pub fn retain_all(&mut self, pages: &[u32]) -> bool {
        for (i, &p) in pages.iter().enumerate() {
            if !self.retain_page(p) {
                for &q in &pages[..i] {
                    self.release(q);
                }
                return false;
            }
        }
        true
    }

    /// Copy-on-write fork: allocate a fresh page and copy `src`'s full
    /// content (every layer run) into it. `None` when the arena is full —
    /// callers evict prefix-cache entries and retry before treating this
    /// as fatal. The fork itself does not touch `src`'s refcount; the
    /// caller swaps its table entry and releases its own reference.
    pub fn fork_page(&mut self, src: u32) -> Option<u32> {
        let dst = self.alloc()?;
        let span = self.page_slots * self.row;
        for l in 0..self.n_layers {
            let s = self.run_offset(src, l);
            let d = self.run_offset(dst, l);
            self.k.copy_within(s..s + span, d);
            self.v.copy_within(s..s + span, d);
        }
        self.forks += 1;
        Some(dst)
    }

    #[inline]
    fn run_offset(&self, page: u32, layer: usize) -> usize {
        (page as usize * self.n_layers + layer) * self.page_slots * self.row
    }

    #[inline]
    fn slot_offset(&self, page: u32, layer: usize, offset: usize) -> usize {
        self.run_offset(page, layer) + offset * self.row
    }

    /// Contiguous K span of one (page, layer): `page_slots * row` floats.
    pub fn k_run(&self, page: u32, layer: usize) -> &[f32] {
        let o = self.run_offset(page, layer);
        &self.k[o..o + self.page_slots * self.row]
    }

    pub fn v_run(&self, page: u32, layer: usize) -> &[f32] {
        let o = self.run_offset(page, layer);
        &self.v[o..o + self.page_slots * self.row]
    }

    /// Write one token's KV. `k_row`/`v_row` are `[L, H, Dh]`
    /// (layer-major, one lane of a decode output).
    pub fn write_slot(&mut self, page: u32, offset: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(offset < self.page_slots);
        debug_assert_eq!(k_row.len(), self.n_layers * self.row);
        for l in 0..self.n_layers {
            let dst = self.slot_offset(page, l, offset);
            let src = l * self.row;
            self.k[dst..dst + self.row].copy_from_slice(&k_row[src..src + self.row]);
            self.v[dst..dst + self.row].copy_from_slice(&v_row[src..src + self.row]);
        }
    }

    /// Write one token's KV for a single layer from a bucket-major
    /// prefill output row (prefill injection gather).
    pub fn write_layer_row(
        &mut self,
        page: u32,
        offset: usize,
        layer: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        let dst = self.slot_offset(page, layer, offset);
        self.k[dst..dst + self.row].copy_from_slice(k_row);
        self.v[dst..dst + self.row].copy_from_slice(v_row);
    }

    /// Move one token's KV (all layers) between arena slots. Used by
    /// in-table compaction; source and destination must differ.
    pub fn copy_slot(&mut self, src: (u32, usize), dst: (u32, usize)) {
        debug_assert!(src != dst, "copy_slot onto itself");
        for l in 0..self.n_layers {
            let s = self.slot_offset(src.0, l, src.1);
            let d = self.slot_offset(dst.0, l, dst.1);
            // row-sized chunks at distinct (page, offset) never overlap
            self.k.copy_within(s..s + self.row, d);
            self.v.copy_within(s..s + self.row, d);
        }
    }

    /// Copy of one slot's K (or V) row for a layer (test/diagnostic use).
    pub fn read_row(&self, page: u32, offset: usize, layer: usize, want_v: bool) -> Vec<f32> {
        let o = self.slot_offset(page, layer, offset);
        let src = if want_v { &self.v } else { &self.k };
        src[o..o + self.row].to_vec()
    }

    /// Bit-exact content equality of two *full* pages (every layer's K
    /// and V run). The prefix cache's cross-entry dedup compares a
    /// freshly registered page against pages already pinned under the
    /// same vision-segment hash — only whole pages are deduped, so tail
    /// slots beyond either entry's live region never alias garbage.
    pub fn pages_equal(&self, a: u32, b: u32) -> bool {
        if a == b {
            return true;
        }
        let span = self.page_slots * self.row;
        for l in 0..self.n_layers {
            let oa = self.run_offset(a, l);
            let ob = self.run_offset(b, l);
            if self.k[oa..oa + span] != self.k[ob..ob + span]
                || self.v[oa..oa + span] != self.v[ob..ob + span]
            {
                return false;
            }
        }
        true
    }
}

/// Pages needed to hold `slots` token slots.
pub fn pages_for_slots(slots: usize, page_slots: usize) -> usize {
    slots.div_ceil(page_slots.max(1))
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn pool() -> PagePool {
        PagePool::new(2, 4, 4, 8)
    }

    #[test]
    fn alloc_free_reuse_accounting() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.in_use_pages(), 2);
        assert_eq!(p.free_pages(), 2);
        p.release(a);
        assert_eq!(p.in_use_pages(), 1);
        let c = p.alloc().unwrap();
        assert_eq!(c, a, "LIFO free list recycles the last freed page");
        let s = p.stats();
        assert_eq!(s.allocs, 3);
        assert_eq!(s.frees, 1);
        assert_eq!(s.reused, 1);
        assert_eq!(s.peak_in_use, 2);
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut p = pool();
        let pages: Vec<u32> = (0..4).map(|_| p.alloc().unwrap()).collect();
        assert!(p.alloc().is_none());
        p.release(pages[2]);
        assert!(p.alloc().is_some());
    }

    #[test]
    fn refcount_pins_pages() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        p.retain_page(a);
        p.release(a);
        assert_eq!(p.in_use_pages(), 1, "still pinned by the second ref");
        p.release(a);
        assert_eq!(p.in_use_pages(), 0);
        assert_eq!(p.stats().frees, 1);
    }

    #[test]
    fn write_and_read_back_slots() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        // [L=2, row=4] layer-major token row
        let k: Vec<f32> = (0..8).map(|x| x as f32).collect();
        let v: Vec<f32> = (0..8).map(|x| -(x as f32)).collect();
        p.write_slot(a, 3, &k, &v);
        assert_eq!(p.read_row(a, 3, 0, false), vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(p.read_row(a, 3, 1, false), vec![4.0, 5.0, 6.0, 7.0]);
        assert_eq!(p.read_row(a, 3, 1, true), vec![-4.0, -5.0, -6.0, -7.0]);
        // the (page, layer) run places offset 3 at floats [12..16)
        assert_eq!(p.k_run(a, 0)[12..16], [0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn copy_slot_moves_all_layers() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        let k: Vec<f32> = (0..8).map(|x| x as f32 + 1.0).collect();
        p.write_slot(a, 7, &k, &k);
        p.copy_slot((a, 7), (b, 0));
        assert_eq!(p.read_row(b, 0, 0, false), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(p.read_row(b, 0, 1, true), vec![5.0, 6.0, 7.0, 8.0]);
    }

    #[test]
    fn double_release_is_refused_and_counted() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        assert!(p.release(a));
        // the page is on the free list exactly once; a second release
        // must not push it again (the old silent free-list corruption)
        assert!(!p.release(a));
        assert_eq!(p.stats().refcount_errors, 1);
        assert_eq!(p.stats().frees, 1);
        let b = p.alloc().unwrap();
        let c = p.alloc().unwrap();
        assert_ne!(b, c, "no aliased handout after a refused double release");
    }

    #[test]
    fn retain_of_dead_page_is_refused_and_counted() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        p.release(a);
        assert!(!p.retain_page(a));
        assert_eq!(p.stats().refcount_errors, 1);
        // the refused retain granted no reference: a release would be a
        // second error, and the page stays allocatable
        assert!(!p.release(a));
        assert_eq!(p.stats().refcount_errors, 2);
        assert_eq!(p.alloc(), Some(a));
    }

    #[test]
    fn fork_page_copies_content_into_a_fresh_page() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        let k: Vec<f32> = (0..8).map(|x| x as f32 + 1.0).collect();
        let v: Vec<f32> = (0..8).map(|x| -(x as f32) - 1.0).collect();
        p.write_slot(a, 2, &k, &v);
        let b = p.fork_page(a).unwrap();
        assert_ne!(a, b);
        assert_eq!(p.refcount(a), 1, "fork leaves the source refcount alone");
        assert_eq!(p.refcount(b), 1);
        assert_eq!(p.read_row(b, 2, 0, false), p.read_row(a, 2, 0, false));
        assert_eq!(p.read_row(b, 2, 1, true), p.read_row(a, 2, 1, true));
        // diverging the fork never touches the source
        p.write_slot(b, 2, &v, &k);
        assert_eq!(p.read_row(a, 2, 0, false), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(p.stats().forks, 1);
    }

    #[test]
    fn fork_page_returns_none_at_capacity() {
        let mut p = pool();
        let pages: Vec<u32> = (0..4).map(|_| p.alloc().unwrap()).collect();
        assert!(p.fork_page(pages[0]).is_none());
        assert_eq!(p.stats().forks, 0);
    }

    #[test]
    fn pages_for_slots_rounds_up() {
        assert_eq!(pages_for_slots(0, 8), 0);
        assert_eq!(pages_for_slots(1, 8), 1);
        assert_eq!(pages_for_slots(8, 8), 1);
        assert_eq!(pages_for_slots(9, 8), 2);
    }

    #[test]
    fn pages_equal_compares_full_content() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        let k: Vec<f32> = (0..8).map(|x| x as f32 + 1.0).collect();
        let v: Vec<f32> = (0..8).map(|x| -(x as f32) - 1.0).collect();
        p.write_slot(a, 1, &k, &v);
        let b = p.fork_page(a).unwrap();
        assert!(p.pages_equal(a, b), "a fork is bit-identical to its source");
        assert!(p.pages_equal(a, a), "reflexive");
        // diverge one slot of one layer's V run: no longer equal
        p.write_slot(b, 3, &k, &k);
        assert!(!p.pages_equal(a, b));
    }

    // ---- satellite: multi-thread stress over the shared pool ----
    //
    // The pool is now `Arc<Mutex<PagePool>>` shared between the engine
    // loop, worker threads and the server's ingest path. These tests
    // hammer the retain/release/fork surface from many threads and then
    // assert the bookkeeping invariants that the single-thread tests
    // above pin: refcounts never underflow, the free list never holds a
    // live page twice, and alloc/free totals balance after every thread
    // joins. On a single-core runner they still interleave at lock
    // granularity, which is exactly the unit under test.

    #[test]
    fn concurrent_retain_release_fork_stress() {
        use std::thread;
        const THREADS: usize = 8;
        const ITERS: usize = 200;
        let pool = PagePool::new_shared(2, 4, 64, 8);
        let mut handles = Vec::new();
        for t in 0..THREADS {
            let pool = Arc::clone(&pool);
            handles.push(thread::spawn(move || {
                for i in 0..ITERS {
                    let page = {
                        let mut p = pool.lock().unwrap();
                        match p.alloc() {
                            Some(pg) => pg,
                            // transient exhaustion under contention is
                            // legal; the invariants are checked at join
                            None => continue,
                        }
                    };
                    {
                        let mut p = pool.lock().unwrap();
                        assert!(p.retain_page(page), "fresh page must be live");
                    }
                    // every third iteration also forks, diverges the
                    // copy, and drops it again
                    if (t + i) % 3 == 0 {
                        let forked = {
                            let mut p = pool.lock().unwrap();
                            p.fork_page(page)
                        };
                        if let Some(f) = forked {
                            let mut p = pool.lock().unwrap();
                            let row: Vec<f32> = vec![t as f32; 8];
                            p.write_slot(f, 0, &row, &row);
                            assert!(p.release(f));
                        }
                    }
                    let mut p = pool.lock().unwrap();
                    assert!(p.release(page), "first release drops the retain");
                    assert!(p.release(page), "second release frees the page");
                }
            }));
        }
        for h in handles {
            h.join().expect("stress worker panicked");
        }
        let p = pool.lock().unwrap();
        let s = p.stats();
        assert_eq!(s.refcount_errors, 0, "no underflow under contention");
        assert_eq!(s.allocs, s.frees, "every page handed out came back");
        assert_eq!(p.in_use_pages(), 0, "all pages returned after join");
        // free-list integrity: every freed page appears exactly once and
        // every entry is a dead page
        let mut seen = std::collections::BTreeSet::new();
        for &pg in &p.free {
            assert!(seen.insert(pg), "page {pg} is on the free list twice");
            assert_eq!(p.refcount(pg), 0, "free-listed page {pg} is live");
        }
        assert_eq!(
            p.next_fresh as usize,
            p.free.len(),
            "every fresh-watermark page is accounted for on the free list"
        );
    }

    #[test]
    fn concurrent_shared_page_pinning_is_exact() {
        use std::thread;
        const THREADS: usize = 8;
        const ROUNDS: usize = 100;
        let pool = PagePool::new_shared(2, 4, 16, 8);
        // one long-lived shared page, as the prefix cache would pin it
        let shared = pool.lock().unwrap().alloc().unwrap();
        let mut handles = Vec::new();
        for _ in 0..THREADS {
            let pool = Arc::clone(&pool);
            handles.push(thread::spawn(move || {
                for _ in 0..ROUNDS {
                    // adopt-then-retire, the CoW warm-start lifecycle
                    assert!(pool.lock().unwrap().retain_page(shared));
                    assert!(pool.lock().unwrap().release(shared));
                }
            }));
        }
        for h in handles {
            h.join().expect("pinning worker panicked");
        }
        let mut p = pool.lock().unwrap();
        assert_eq!(p.refcount(shared), 1, "only the original pin survives");
        assert_eq!(p.stats().refcount_errors, 0);
        assert!(p.release(shared));
        assert_eq!(p.in_use_pages(), 0);
    }
}
