//! Per-request KV view over the shared paged arena.
//!
//! `KvSlab` keeps its original contract — slot index i always addresses
//! the same token across K, V and metadata, the first `len` logical slots
//! are live, eviction compacts retained slots down in order (the
//! slab-integrity property tested in tests/cache_props.rs) — but the
//! storage is no longer an owned contiguous buffer. A copy-on-write page
//! table (prefix/cow.rs) maps logical slot → (page, offset) into a
//! `cache::paged::PagePool`, so:
//!
//! * eviction returns whole emptied tail pages to the shared pool
//!   (immediate admission headroom for other requests) instead of
//!   shrinking a private allocation;
//! * the per-step batch assembly (`copy_into_lane`) is an incremental
//!   page-granular gather: pages untouched since the last sync of the
//!   same (lane, capacity) destination are skipped — steady-state decode
//!   copies O(dirty pages), not O(live slots);
//! * a slab can **adopt** pages pinned by the prefix cache
//!   (prefix/mod.rs) instead of recomputing and re-storing an identical
//!   prompt prefix: adopted pages are mapped shared, every write goes
//!   through the CoW barrier (append into a shared tail, eviction /
//!   compaction inside the shared prefix — each forks the page first),
//!   so each request's eviction policy still acts independently while
//!   reads are zero-copy.
//!
//! Each live slot carries metadata: original sequence position, modality,
//! cumulative attention score (the β(C_j) term of paper Eq. 5) and a
//! recycle-bin mark (DDES). `KvSlab::new` keeps the old standalone
//! behaviour by backing the view with a private single-request pool;
//! `KvSlab::in_pool` attaches it to an engine's shared arena.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::model::ModelMeta;
use crate::prefix::cow::PageTable;

use super::paged::{lock_pool, pages_for_slots, PagePool, SharedPagePool, DEFAULT_PAGE_SLOTS};

/// Process-wide slab identity: the engine tracks which slab last wrote
/// each scratch lane region, and a fresh id per slab (never reused)
/// makes that check airtight across retire/re-admit cycles.
static NEXT_SLAB_ID: AtomicU64 = AtomicU64::new(1);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Modality {
    Vision,
    Text,
}

#[derive(Debug, Clone, Copy)]
pub struct SlotMeta {
    /// original (global) sequence position of this token
    pub position: i32,
    pub modality: Modality,
    /// cumulative attention mass received since entering the cache
    /// (layer/head mean — the β(C_j) term of Eq. 5)
    pub cum_score: f32,
    /// cumulative max-over-heads attention mass (AdaKV-style adaptive
    /// scoring input; see cache/baselines.rs)
    pub cum_peak: f32,
    /// attention mass received in the most recent step
    pub last_score: f32,
    /// DDES recycle-bin mark (still attendable until flushed)
    pub marked: bool,
    /// decode steps survived in the cache
    pub age: u32,
}

/// Destination of the most recent lane sync: the incremental gather is
/// valid only while the slab keeps writing the same (lane, capacity)
/// region of the engine's persistent scratch buffers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct LaneSync {
    lane: usize,
    cap_c: usize,
}

pub struct KvSlab {
    /// unique per slab (engine scratch-ownership checks)
    id: u64,
    pool: SharedPagePool,
    /// copy-on-write page table: logical slot s lives at
    /// (table.page(s / page_slots), s % page_slots)
    table: PageTable,
    meta: Vec<SlotMeta>,
    /// logical capacity in slots
    cap: usize,
    /// floats per slot per layer (H * Dh)
    row: usize,
    n_layers: usize,
    page_slots: usize,
    last_sync: Option<LaneSync>,
    /// pages returned to the pool at retire (`release_pages`); metadata
    /// stays readable but KV is gone
    released: bool,
    /// physical split recorded at `release_pages` — a lane that finishes
    /// mid-step must still be accounted (private bytes + distinct shared
    /// pages) without double-counting pages a surviving lane also maps
    released_private: usize,
    released_shared: Vec<u32>,
}

impl KvSlab {
    /// Standalone slab backed by a private pool sized to `cap` slots —
    /// the seed behaviour, used by policies' unit tests and single-shot
    /// tools. Serving paths share an arena via `in_pool`.
    pub fn new(m: &ModelMeta, cap: usize) -> Self {
        let page_slots = DEFAULT_PAGE_SLOTS.min(cap.max(1));
        let pool = PagePool::new_shared(
            m.n_layers,
            m.n_heads * m.d_head,
            pages_for_slots(cap.max(1), page_slots),
            page_slots,
        );
        KvSlab::in_pool(&pool, cap)
    }

    /// View over a shared arena, holding at most `cap` live slots. Pages
    /// are allocated lazily on append and returned on eviction/drop.
    pub fn in_pool(pool: &SharedPagePool, cap: usize) -> Self {
        let (row, n_layers, page_slots) = {
            let p = lock_pool(pool);
            (p.row(), p.n_layers(), p.page_slots())
        };
        KvSlab {
            id: NEXT_SLAB_ID.fetch_add(1, Ordering::Relaxed),
            pool: pool.clone(),
            table: PageTable::new(),
            meta: Vec::with_capacity(cap),
            cap,
            row,
            n_layers,
            page_slots,
            last_sync: None,
            released: false,
            released_private: 0,
            released_shared: Vec::new(),
        }
    }

    /// Stable identity for engine scratch-ownership tracking.
    pub fn sync_id(&self) -> u64 {
        self.id
    }

    /// Forget the incremental-sync state: the next `copy_into_lane` does
    /// a full gather. The engine calls this whenever a *different* slab
    /// wrote the same scratch region since this slab's last sync — the
    /// slab's own (lane, capacity) check cannot see that.
    pub fn invalidate_sync(&mut self) {
        self.last_sync = None;
        self.table.mark_all_dirty();
    }

    pub fn len(&self) -> usize {
        self.meta.len()
    }

    pub fn is_empty(&self) -> bool {
        self.meta.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn meta(&self) -> &[SlotMeta] {
        &self.meta
    }

    pub fn meta_mut(&mut self) -> &mut [SlotMeta] {
        &mut self.meta
    }

    /// Token slots per arena page.
    pub fn page_slots(&self) -> usize {
        self.page_slots
    }

    /// Pages this slab currently holds in the arena.
    pub fn allocated_pages(&self) -> usize {
        self.table.len()
    }

    /// Pages currently mapped copy-on-write (aliased with the prefix
    /// cache and/or sibling slabs).
    pub fn shared_pages(&self) -> usize {
        self.table.shared_count()
    }

    /// Arena ids of the currently-shared pages (the scheduler counts
    /// each distinct shared page once for physical KV accounting). A
    /// released slab reports the split recorded at release time, so a
    /// lane that finished mid-step dedups against survivors correctly.
    pub fn shared_page_ids(&self) -> Vec<u32> {
        if self.released {
            return self.released_shared.clone();
        }
        self.table.shared_page_ids()
    }

    /// The tail page when it is shared *and* partially filled: the page
    /// this slab's first append will fork. It stays in the lane's
    /// private admission bound, so the scheduler's charged-once term
    /// must not count it again (see `Engine::shared_charge_pages`).
    pub fn unstable_tail_page(&self) -> Option<u32> {
        let n = self.table.len();
        if !self.released
            && n > 0
            && self.table.is_shared(n - 1)
            && self.meta.len() < n * self.page_slots
        {
            Some(self.table.page(n - 1))
        } else {
            None
        }
    }

    /// Shared pages that stay shared under this slab's own appends:
    /// everything except a shared *partial* tail page
    /// (`unstable_tail_page` — the one the first generated token
    /// forks). This is the admission discount — see
    /// scheduler/admission.rs.
    pub fn shared_pages_stable(&self) -> usize {
        self.table.shared_count() - self.fork_allowance_pages()
    }

    /// Pages the admission bound must reserve for this slab's own CoW
    /// forks: the shared *partial tail* page, which the first append
    /// forks into a fresh allocation. Kept inside the lane's private
    /// page bound (`AdmissionController::lane_bound_pages`) while the
    /// original tail stays charged once globally — the double charge IS
    /// the reservation that guarantees `ensure_private` never meets an
    /// empty pool on the append path.
    pub fn fork_allowance_pages(&self) -> usize {
        usize::from(self.unstable_tail_page().is_some())
    }

    /// Bytes of one live slot (K+V for one token across all layers) —
    /// the accounting unit of the scheduler's KV-budget admission.
    pub fn kv_bytes_per_slot(&self) -> usize {
        2 * self.n_layers * self.row * 4
    }

    /// Live KV bytes (the paper's "KV Cache (MB)" accounting). Counts
    /// every live slot, shared or not — the per-request view.
    pub fn kv_bytes(&self) -> usize {
        self.meta.len() * self.kv_bytes_per_slot()
    }

    /// Live KV bytes held in *private* pages only. The scheduler's
    /// physical-occupancy invariant sums this plus each distinct shared
    /// page once, so a prefix shared by N lanes is charged once, not N
    /// times. A released slab reports the private bytes recorded at
    /// release time (its pages were live during the step it finished
    /// in; the shared part dedups via `shared_page_ids`).
    pub fn kv_bytes_private(&self) -> usize {
        if self.released {
            return self.released_private;
        }
        let ps = self.page_slots;
        let mut slots = 0usize;
        for pi in 0..self.table.len() {
            let base = pi * ps;
            if base >= self.meta.len() {
                break;
            }
            if !self.table.is_shared(pi) {
                slots += (self.meta.len() - base).min(ps);
            }
        }
        slots * self.kv_bytes_per_slot()
    }

    /// Bytes of arena actually held (live + tail-page fragmentation).
    pub fn kv_bytes_allocated(&self) -> usize {
        self.table.len() * self.page_slots * self.kv_bytes_per_slot()
    }

    #[inline]
    fn page_of(&self, slot: usize) -> (u32, usize) {
        (self.table.page(slot / self.page_slots), slot % self.page_slots)
    }

    /// Make sure a page backs logical slot `slot` (== current len).
    #[allow(clippy::expect_used)]
    fn ensure_page(&mut self, slot: usize) {
        if slot == self.table.len() * self.page_slots {
            let page = lock_pool(&self.pool)
                .alloc()
                // hae-lint: allow(R3-forbidden-api) pool exhaustion here is an admission-accounting bug; fail loud
                .expect("page pool exhausted (admission must prevent this)");
            self.table.push_private(page);
        }
    }

    /// Append one token's KV. `k_row`/`v_row` are `[L, H, Dh]` (layer-major,
    /// as returned by the decode executable for one lane).
    pub fn append(
        &mut self,
        k_row: &[f32],
        v_row: &[f32],
        position: i32,
        modality: Modality,
        init_score: f32,
    ) -> usize {
        assert!(!self.released, "append to a released slab");
        assert!(self.meta.len() < self.cap, "slab full");
        assert_eq!(k_row.len(), self.n_layers * self.row);
        let slot = self.meta.len();
        self.ensure_page(slot);
        let pi = slot / self.page_slots;
        {
            let mut pool = lock_pool(&self.pool);
            // CoW barrier: appending into a shared (adopted) partial tail
            // page forks it first, so the prefix cache's image — and every
            // co-sharing request — never sees this request's generation.
            // The fork's fresh page is reserved by the admission fork
            // allowance (the shared partial tail stays inside the lane's
            // private page bound while the original is charged once
            // globally), so exhaustion here means broken accounting —
            // the same bug class as the ensure_page expect above.
            #[allow(clippy::expect_used)]
            // hae-lint: allow(R3-forbidden-api) fork-allowance exhaustion is an accounting bug; fail loud
            self.table.ensure_private(&mut pool, pi).expect(
                "page pool exhausted forking the shared tail \
                 (the admission fork allowance must reserve it)",
            );
            let (page, off) = (self.table.page(pi), slot % self.page_slots);
            pool.write_slot(page, off, k_row, v_row);
        }
        self.table.mark_dirty(pi);
        self.meta.push(SlotMeta {
            position,
            modality,
            cum_score: init_score,
            cum_peak: init_score,
            last_score: init_score,
            marked: false,
            age: 0,
        });
        slot
    }

    /// Bulk-load retained prompt tokens from a prefill output.
    ///
    /// `k_src`/`v_src` are `[L, S, H, Dh]` (bucket-major, as emitted by the
    /// prefill executable); `retain` lists prompt slot indices to keep (in
    /// ascending order); `modality[i]`/`scores[i]` describe prompt slot i.
    pub fn inject_prefill(
        &mut self,
        k_src: &[f32],
        v_src: &[f32],
        bucket: usize,
        retain: &[usize],
        modality: &[Modality],
        scores: &[f32],
    ) {
        assert!(!self.released, "inject into a released slab");
        assert!(self.meta.is_empty(), "inject into non-empty slab");
        assert!(retain.len() < self.cap, "prefill larger than slab capacity");
        for (dst_slot, &src_slot) in retain.iter().enumerate() {
            self.ensure_page(dst_slot);
            let (page, off) = self.page_of(dst_slot);
            let mut pool = lock_pool(&self.pool);
            for l in 0..self.n_layers {
                let src = (l * bucket + src_slot) * self.row;
                pool.write_layer_row(
                    page,
                    off,
                    l,
                    &k_src[src..src + self.row],
                    &v_src[src..src + self.row],
                );
            }
            drop(pool);
            self.meta.push(SlotMeta {
                position: src_slot as i32,
                modality: modality[src_slot],
                cum_score: scores[src_slot],
                cum_peak: scores[src_slot],
                last_score: scores[src_slot],
                marked: false,
                age: 0,
            });
        }
    }

    /// Adopt a prefix-cache entry instead of recomputing it: map `pages`
    /// shared (retaining each in the pool) and take the cached slot
    /// metadata verbatim. The slab must be empty; `pages` must cover
    /// exactly `meta.len()` slots. Returns false — leaving the slab
    /// empty — if any page could not be retained (cache/pool accounting
    /// bug surfaced via `PoolStats::refcount_errors`), so the caller can
    /// fall back to a cold prefill.
    pub fn adopt_shared(&mut self, pages: &[u32], meta: Vec<SlotMeta>) -> bool {
        assert!(!self.released, "adopt into a released slab");
        assert!(self.meta.is_empty(), "adopt into non-empty slab");
        assert!(meta.len() < self.cap, "cached prefix larger than slab capacity");
        assert_eq!(
            pages.len(),
            pages_for_slots(meta.len(), self.page_slots),
            "adopted pages must cover exactly the cached slots"
        );
        let mut pool = lock_pool(&self.pool);
        if !self.table.adopt_shared(&mut pool, pages) {
            return false;
        }
        drop(pool);
        self.meta = meta;
        true
    }

    /// Hand this slab's pages to the prefix cache: every page becomes
    /// copy-on-write (the cache retains them separately; this slab's own
    /// writes fork first from now on) and their arena ids are returned
    /// for pinning. If the cache ends up not retaining them, the marks
    /// self-heal: `ensure_private` sees refcount 1 and just clears them.
    pub fn mark_all_shared(&mut self) -> Vec<u32> {
        self.table.mark_all_shared();
        self.table.pages().to_vec()
    }

    /// Accumulate this step's attention mass into slot scores and ages.
    /// `mean[i]` is the layer/head-mean mass on slot i, `peak[i]` the
    /// max-over-heads mass (may be the same slice when peak tracking is
    /// not needed). Both must cover exactly the live slots.
    pub fn add_scores(&mut self, mean: &[f32], peak: &[f32]) {
        debug_assert_eq!(
            mean.len(),
            self.meta.len(),
            "mean score vector length must match the live slot count"
        );
        debug_assert_eq!(
            peak.len(),
            self.meta.len(),
            "peak score vector length must match the live slot count"
        );
        for (i, m) in self.meta.iter_mut().enumerate() {
            let s = mean.get(i).copied().unwrap_or(0.0);
            m.cum_score += s;
            m.cum_peak += peak.get(i).copied().unwrap_or(s);
            m.last_score = s;
            m.age += 1;
        }
    }

    /// Keep exactly the slots in `retain` (strictly ascending, therefore
    /// deduped), dropping the rest. Retained slots slide down in order;
    /// tail pages emptied by the shrink are freed back to the pool.
    /// Slide-down writes into a shared page fork it first (CoW): evicting
    /// inside a shared prefix detaches this slab's copy and leaves the
    /// cached original intact. Returns the number of evicted slots.
    ///
    /// Panics when the pool cannot supply a CoW fork page — the contract
    /// of the standalone/private-pool callers, for whom a fork can never
    /// be needed. Serving paths, where divergence from a shared prefix
    /// under a tight budget is real, use [`Self::try_compact`] and defer.
    #[allow(clippy::expect_used)]
    pub fn compact(&mut self, retain: &[usize]) -> usize {
        // hae-lint: allow(R3-forbidden-api) documented panic contract for private-pool callers
        self.try_compact(retain).expect(
            "page pool exhausted during CoW compaction \
             (serving callers must use try_compact and defer)",
        )
    }

    /// Fallible [`Self::compact`]: `None` — with every slot still live
    /// and in place — when a copy-on-write fork cannot get a page. All
    /// forks run in a pre-pass *before* the first slot moves, so a
    /// mid-compaction exhaustion can never leave the slab half-slid:
    /// pages forked before the failure simply stay private (their
    /// content is byte-identical to the shared original), and the caller
    /// retries after pages free up.
    pub fn try_compact(&mut self, retain: &[usize]) -> Option<usize> {
        debug_assert!(
            retain.windows(2).all(|w| w[0] < w[1]),
            "retain must be strictly ascending (ascending + deduped)"
        );
        debug_assert!(
            retain.last().is_none_or(|&i| i < self.meta.len()),
            "retain indices must be live slots"
        );
        let evicted = self.meta.len() - retain.len();
        if evicted == 0 {
            return Some(0);
        }
        assert!(!self.released, "compact of a released slab");
        let first_moved = retain
            .iter()
            .enumerate()
            .find(|&(dst, &src)| dst != src)
            .map(|(dst, _)| dst);
        if let Some(fm) = first_moved {
            // CoW pre-pass: privatize every page the slide-down will
            // write (first moved slot → last retained slot) before any
            // copy. Forking up front is consistent — fork-time content
            // equals what the not-yet-slid source reads expect — and it
            // makes exhaustion recoverable instead of corrupting state.
            let dst_pages = pages_for_slots(retain.len(), self.page_slots);
            let mut pool = lock_pool(&self.pool);
            for pi in (fm / self.page_slots)..dst_pages {
                self.table.ensure_private(&mut pool, pi)?;
            }
        }
        {
            let mut pool = lock_pool(&self.pool);
            for (dst_slot, &src_slot) in retain.iter().enumerate() {
                if dst_slot == src_slot {
                    // unchanged prefix: no copy, page stays clean/shared
                    continue;
                }
                let src = self.page_of(src_slot);
                let dst = self.page_of(dst_slot);
                pool.copy_slot(src, dst);
                self.meta[dst_slot] = self.meta[src_slot];
            }
        }
        self.meta.truncate(retain.len());
        // every page from the first rewritten slot on now has new content
        if let Some(fm) = first_moved {
            let live_pages = pages_for_slots(self.meta.len(), self.page_slots);
            for pi in (fm / self.page_slots)..live_pages {
                self.table.mark_dirty(pi);
            }
        }
        // free whole tail pages the shrink emptied (a shared tail page
        // just drops this slab's reference; the cache keeps its copy)
        let needed = pages_for_slots(self.meta.len(), self.page_slots);
        if self.table.len() > needed {
            let mut pool = lock_pool(&self.pool);
            self.table.truncate_release(&mut pool, needed);
        }
        Some(evicted)
    }

    /// Evict the given slots (any order, deduped internally). Panics on
    /// CoW-fork exhaustion like [`Self::compact`].
    #[allow(clippy::expect_used)]
    pub fn evict(&mut self, evict: &[usize]) -> usize {
        // hae-lint: allow(R3-forbidden-api) documented panic contract for private-pool callers
        self.try_evict(evict).expect(
            "page pool exhausted during CoW eviction \
             (serving callers must use try_evict and defer)",
        )
    }

    /// Fallible [`Self::evict`]: `None` — nothing evicted, slab intact —
    /// when a copy-on-write fork cannot get a page. The serving engine's
    /// deferral path: the eviction is simply retried on a later step,
    /// once retirements or cache reclaim free pages.
    pub fn try_evict(&mut self, evict: &[usize]) -> Option<usize> {
        if evict.is_empty() {
            return Some(0);
        }
        let mut drop_mask = vec![false; self.meta.len()];
        for &i in evict {
            if i < drop_mask.len() {
                drop_mask[i] = true;
            }
        }
        let retain: Vec<usize> =
            (0..self.meta.len()).filter(|&i| !drop_mask[i]).collect();
        self.try_compact(&retain)
    }

    /// First slot [`Self::drop_tail_aligned`] would remove for `need`:
    /// the largest page-aligned length at most `len - need`. The single
    /// source of the alignment rule — callers snapshotting the victims
    /// before the drop read the same boundary the drop will use.
    pub fn tail_drop_keep(&self, need: usize) -> usize {
        (self.meta.len().saturating_sub(need) / self.page_slots) * self.page_slots
    }

    /// Emergency fork-free eviction: drop the newest slots, down to a
    /// page boundary, covering at least `need` of them. Pure truncation —
    /// no slide-down writes, so no CoW forks and no allocations — and the
    /// page alignment guarantees at least one whole tail page returns to
    /// the pool *and* the next append lands on a fresh page instead of a
    /// shared tail. This is the capacity-wall last resort: when a
    /// CoW-deferred eviction would otherwise leave no slot for the
    /// incoming token, dropping recent context beats panicking the whole
    /// serving loop (coordinator/engine.rs counts every use). Returns
    /// slots dropped.
    pub fn drop_tail_aligned(&mut self, need: usize) -> usize {
        assert!(!self.released, "drop_tail on a released slab");
        let len = self.meta.len();
        if len == 0 || need == 0 {
            return 0;
        }
        let keep = self.tail_drop_keep(need);
        self.meta.truncate(keep);
        let needed = pages_for_slots(keep, self.page_slots);
        if self.table.len() > needed {
            let mut pool = lock_pool(&self.pool);
            self.table.truncate_release(&mut pool, needed);
        }
        len - keep
    }

    /// Gather this slab's live region into a batched decode input at the
    /// given lane. `dst_k`/`dst_v` are `[B, L, C, H, Dh]`; `cap_c` is the
    /// batch buffer's capacity bucket (≥ self.len()).
    ///
    /// Incremental: when the destination (lane, capacity) matches the
    /// previous call — the engine reuses its scratch buffers across
    /// steps — only pages whose KV changed since then are copied (the
    /// paper's index-broadcasting idea applied to the host hot path).
    /// Returns the number of pages copied.
    pub fn copy_into_lane(
        &mut self,
        dst_k: &mut [f32],
        dst_v: &mut [f32],
        lane: usize,
        cap_c: usize,
    ) -> usize {
        let len = self.meta.len();
        assert!(!self.released, "lane sync of a released slab");
        assert!(len <= cap_c, "lane cache {} > bucket {}", len, cap_c);
        let here = LaneSync { lane, cap_c };
        let full = self.last_sync != Some(here);
        let pool = lock_pool(&self.pool);
        let mut copied = 0;
        for pi in 0..self.table.len() {
            let base_slot = pi * self.page_slots;
            if base_slot >= len {
                break;
            }
            if !full && !self.table.is_dirty(pi) {
                continue;
            }
            let page = self.table.page(pi);
            let n = (len - base_slot).min(self.page_slots) * self.row;
            for l in 0..self.n_layers {
                let dst = ((lane * self.n_layers + l) * cap_c + base_slot) * self.row;
                dst_k[dst..dst + n].copy_from_slice(&pool.k_run(page, l)[..n]);
                dst_v[dst..dst + n].copy_from_slice(&pool.v_run(page, l)[..n]);
            }
            copied += 1;
        }
        drop(pool);
        self.table.clear_dirty();
        self.last_sync = Some(here);
        copied
    }

    /// Raw K row of one slot in one layer (test/diagnostic use).
    pub fn k_row(&self, layer: usize, slot: usize) -> Vec<f32> {
        let (page, off) = self.page_of(slot);
        lock_pool(&self.pool).read_row(page, off, layer, false)
    }

    pub fn v_row(&self, layer: usize, slot: usize) -> Vec<f32> {
        let (page, off) = self.page_of(slot);
        lock_pool(&self.pool).read_row(page, off, layer, true)
    }

    /// Retire hook: return every arena page to the pool *now*, instead
    /// of when the caller drops the finished request. Metadata (and so
    /// `len`, `kv_bytes`, eviction stats) stays readable; the KV itself
    /// is gone and the slab must not be appended to or lane-synced again.
    /// Idempotent. Shared pages just drop this slab's reference — the
    /// prefix cache keeps them alive for the next request.
    pub fn release_pages(&mut self) {
        if self.released {
            return;
        }
        // record the physical split first: the scheduler accounts a lane
        // that finished mid-step by these, deduping shared pages against
        // lanes that still map them
        self.released_private = self.kv_bytes_private();
        self.released_shared = self.table.shared_page_ids();
        if !self.table.is_empty() {
            let mut pool = lock_pool(&self.pool);
            self.table.release_all(&mut pool);
        }
        self.last_sync = None;
        self.released = true;
    }

    /// Count of marked (recycle-bin) slots.
    pub fn marked_count(&self) -> usize {
        self.meta.iter().filter(|m| m.marked).count()
    }

    /// Indices of marked slots, ascending.
    pub fn marked_slots(&self) -> Vec<usize> {
        self.meta
            .iter()
            .enumerate()
            .filter(|(_, m)| m.marked)
            .map(|(i, _)| i)
            .collect()
    }
}

impl Drop for KvSlab {
    fn drop(&mut self) {
        let mut pool = lock_pool(&self.pool);
        self.table.release_all(&mut pool);
    }
}

impl Clone for KvSlab {
    /// Deep copy into a fresh private pool: a clone is a snapshot, never
    /// an alias of the shared arena (aliasing pages without retaining
    /// them would double-free on drop).
    fn clone(&self) -> Self {
        let page_slots = self.page_slots;
        let pool = PagePool::new_shared(
            self.n_layers,
            self.row,
            pages_for_slots(self.cap.max(1), page_slots).max(1),
            page_slots,
        );
        let mut out = KvSlab {
            id: NEXT_SLAB_ID.fetch_add(1, Ordering::Relaxed),
            pool,
            table: PageTable::new(),
            meta: self.meta.clone(),
            cap: self.cap,
            row: self.row,
            n_layers: self.n_layers,
            page_slots,
            last_sync: None,
            released: self.released,
            released_private: self.released_private,
            // the clone's private pool shares nothing with the arena
            released_shared: Vec::new(),
        };
        let src = lock_pool(&self.pool);
        let live_kv = if self.released { 0 } else { self.meta.len() };
        for slot in 0..live_kv {
            out.ensure_page(slot);
            let (dpage, doff) = out.page_of(slot);
            let (spage, soff) = self.page_of(slot);
            // hae-lint: allow(R1-lock-order) clone targets its fresh private pool; the two mutexes are disjoint (docs/CONCURRENCY.md)
            let mut dst = lock_pool(&out.pool);
            for l in 0..self.n_layers {
                dst.write_layer_row(
                    dpage,
                    doff,
                    l,
                    &src.read_row(spage, soff, l, false),
                    &src.read_row(spage, soff, l, true),
                );
            }
        }
        out
    }
}

impl std::fmt::Debug for KvSlab {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KvSlab")
            .field("len", &self.meta.len())
            .field("cap", &self.cap)
            .field("pages", &self.table.pages())
            .field("shared", &self.table.shared_count())
            .field("page_slots", &self.page_slots)
            .finish()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::model::ModelMeta;

    fn tiny_meta() -> ModelMeta {
        ModelMeta {
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 2,
            d_mlp: 8,
            patch_dim: 4,
            n_patches: 4,
            max_pos: 64,
            dap_layer: 1,
        }
    }

    fn row_of(val: f32, m: &ModelMeta) -> Vec<f32> {
        vec![val; m.n_layers * m.n_heads * m.d_head]
    }

    /// A shared arena small enough to observe page churn: 4-slot pages.
    fn tiny_pool(m: &ModelMeta, pages: usize) -> SharedPagePool {
        PagePool::new_shared(m.n_layers, m.n_heads * m.d_head, pages, 4)
    }

    #[test]
    fn append_and_read_back() {
        let m = tiny_meta();
        let mut s = KvSlab::new(&m, 8);
        for i in 0..5 {
            s.append(&row_of(i as f32, &m), &row_of(-(i as f32), &m), i as i32,
                     Modality::Text, 0.1);
        }
        assert_eq!(s.len(), 5);
        for i in 0..5 {
            assert_eq!(s.k_row(0, i)[0], i as f32);
            assert_eq!(s.k_row(1, i)[0], i as f32);
            assert_eq!(s.v_row(0, i)[0], -(i as f32));
            assert_eq!(s.meta()[i].position, i as i32);
        }
    }

    #[test]
    fn compact_preserves_order_and_data() {
        let m = tiny_meta();
        let mut s = KvSlab::new(&m, 8);
        for i in 0..6 {
            s.append(&row_of(i as f32, &m), &row_of(i as f32, &m), i as i32,
                     Modality::Text, 0.0);
        }
        let evicted = s.compact(&[0, 2, 5]);
        assert_eq!(evicted, 3);
        assert_eq!(s.len(), 3);
        assert_eq!(s.k_row(0, 0)[0], 0.0);
        assert_eq!(s.k_row(0, 1)[0], 2.0);
        assert_eq!(s.k_row(1, 2)[0], 5.0);
        assert_eq!(s.meta()[1].position, 2);
    }

    #[test]
    fn evict_any_order() {
        let m = tiny_meta();
        let mut s = KvSlab::new(&m, 8);
        for i in 0..6 {
            s.append(&row_of(i as f32, &m), &row_of(i as f32, &m), i as i32,
                     Modality::Vision, 0.0);
        }
        s.evict(&[4, 1, 1]);
        assert_eq!(s.len(), 4);
        let positions: Vec<i32> = s.meta().iter().map(|mm| mm.position).collect();
        assert_eq!(positions, vec![0, 2, 3, 5]);
    }

    #[test]
    fn inject_prefill_gathers() {
        let m = tiny_meta();
        let bucket = 4;
        let row = m.n_heads * m.d_head;
        // k_src [L, S, H*Dh]: value = layer*100 + slot
        let mut k_src = vec![0.0f32; m.n_layers * bucket * row];
        for l in 0..m.n_layers {
            for sslot in 0..bucket {
                let base = (l * bucket + sslot) * row;
                for x in &mut k_src[base..base + row] {
                    *x = (l * 100 + sslot) as f32;
                }
            }
        }
        let v_src = k_src.clone();
        let mut s = KvSlab::new(&m, 8);
        let modality = vec![Modality::Vision, Modality::Vision, Modality::Text, Modality::Text];
        let scores = vec![0.1, 0.2, 0.3, 0.4];
        s.inject_prefill(&k_src, &v_src, bucket, &[1, 3], &modality, &scores);
        assert_eq!(s.len(), 2);
        assert_eq!(s.k_row(0, 0)[0], 1.0);
        assert_eq!(s.k_row(1, 0)[0], 101.0);
        assert_eq!(s.k_row(0, 1)[0], 3.0);
        assert_eq!(s.meta()[0].modality, Modality::Vision);
        assert_eq!(s.meta()[1].position, 3);
        assert!((s.meta()[1].cum_score - 0.4).abs() < 1e-6);
    }

    #[test]
    fn copy_into_lane_layout() {
        let m = tiny_meta();
        let row = m.n_heads * m.d_head;
        let mut s = KvSlab::new(&m, 8);
        for i in 0..3 {
            s.append(&row_of(i as f32 + 1.0, &m), &row_of(0.0, &m), i as i32,
                     Modality::Text, 0.0);
        }
        let (b, c) = (2, 4);
        let mut dst_k = vec![0.0f32; b * m.n_layers * c * row];
        let mut dst_v = dst_k.clone();
        s.copy_into_lane(&mut dst_k, &mut dst_v, 1, c);
        // lane 0 untouched
        assert!(dst_k[..m.n_layers * c * row].iter().all(|&x| x == 0.0));
        // lane 1, layer 0, slot 1 = 2.0
        let off = (m.n_layers * c + 1) * row;
        assert_eq!(dst_k[off], 2.0);
    }

    #[test]
    fn incremental_sync_copies_only_dirty_pages() {
        let m = tiny_meta();
        let row = m.n_heads * m.d_head;
        let pool = tiny_pool(&m, 8); // 4-slot pages
        let mut s = KvSlab::in_pool(&pool, 20);
        for i in 0..9 {
            s.append(&row_of(i as f32, &m), &row_of(i as f32, &m), i as i32,
                     Modality::Text, 0.0);
        }
        let c = 20;
        let mut dst_k = vec![0.0f32; m.n_layers * c * row];
        let mut dst_v = dst_k.clone();
        // first sync: all 3 pages (slots 0..9 over 4-slot pages)
        assert_eq!(s.copy_into_lane(&mut dst_k, &mut dst_v, 0, c), 3);
        // steady-state append touches only the tail page
        s.append(&row_of(9.0, &m), &row_of(9.0, &m), 9, Modality::Text, 0.0);
        assert_eq!(s.copy_into_lane(&mut dst_k, &mut dst_v, 0, c), 1);
        assert_eq!(dst_k[9 * row], 9.0);
        // scores don't touch KV: nothing to copy
        let zeros = vec![0.0f32; s.len()];
        s.add_scores(&zeros, &zeros);
        assert_eq!(s.copy_into_lane(&mut dst_k, &mut dst_v, 0, c), 0);
        // a different destination forces a full resync
        assert_eq!(s.copy_into_lane(&mut dst_k, &mut dst_v, 0, c + 4), 3);
    }

    #[test]
    fn incremental_sync_tracks_evictions() {
        let m = tiny_meta();
        let row = m.n_heads * m.d_head;
        let pool = tiny_pool(&m, 8);
        let mut s = KvSlab::in_pool(&pool, 20);
        for i in 0..12 {
            s.append(&row_of(i as f32, &m), &row_of(i as f32, &m), i as i32,
                     Modality::Text, 0.0);
        }
        let c = 20;
        let mut dst_k = vec![0.0f32; m.n_layers * c * row];
        let mut dst_v = dst_k.clone();
        s.copy_into_lane(&mut dst_k, &mut dst_v, 0, c);
        // evicting slot 2 rewrites everything from slot 2 on → pages 0..3
        // shrink to 11 live slots over 3 pages, all rewritten
        s.evict(&[2]);
        assert_eq!(s.copy_into_lane(&mut dst_k, &mut dst_v, 0, c), 3);
        for (i, expect) in [0.0f32, 1.0, 3.0, 4.0].iter().enumerate() {
            assert_eq!(dst_k[i * row], *expect, "slot {} after eviction", i);
        }
        // pure tail truncation leaves the prefix pages clean
        let keep: Vec<usize> = (0..8).collect();
        s.compact(&keep);
        assert_eq!(s.copy_into_lane(&mut dst_k, &mut dst_v, 0, c), 0);
    }

    #[test]
    fn invalidate_sync_recovers_clobbered_scratch() {
        // Two slabs alternate writes to the same (lane, capacity) region,
        // the aliasing the engine's per-lane ownership tracking detects:
        // without invalidation, slab A would skip its "clean" pages and
        // leave slab B's rows in the buffer.
        let m = tiny_meta();
        let row = m.n_heads * m.d_head;
        let pool = tiny_pool(&m, 8);
        let mut a = KvSlab::in_pool(&pool, 16);
        let mut b = KvSlab::in_pool(&pool, 16);
        assert_ne!(a.sync_id(), b.sync_id());
        for i in 0..6 {
            a.append(&row_of(1.0, &m), &row_of(1.0, &m), i, Modality::Text, 0.0);
            b.append(&row_of(2.0, &m), &row_of(2.0, &m), i, Modality::Text, 0.0);
        }
        let c = 16;
        let mut dst_k = vec![0.0f32; m.n_layers * c * row];
        let mut dst_v = dst_k.clone();
        a.copy_into_lane(&mut dst_k, &mut dst_v, 0, c);
        b.copy_into_lane(&mut dst_k, &mut dst_v, 0, c); // clobbers A's region
        // A's own (lane, capacity) state still matches — without the
        // engine-driven invalidation it would copy 0 pages
        a.invalidate_sync();
        let copied = a.copy_into_lane(&mut dst_k, &mut dst_v, 0, c);
        assert_eq!(copied, 2, "full resync after invalidation");
        for s in 0..6 {
            assert_eq!(dst_k[s * row], 1.0, "slot {} holds A's data again", s);
        }
    }

    #[test]
    fn eviction_frees_tail_pages_to_the_pool() {
        let m = tiny_meta();
        let pool = tiny_pool(&m, 8);
        let mut s = KvSlab::in_pool(&pool, 32);
        for i in 0..12 {
            s.append(&row_of(0.0, &m), &row_of(0.0, &m), i, Modality::Text, 0.0);
        }
        assert_eq!(s.allocated_pages(), 3);
        assert_eq!(pool.lock().unwrap().in_use_pages(), 3);
        // drop 7 of 12 slots: 5 live → 2 pages, one page back to the pool
        s.evict(&[0, 1, 2, 3, 4, 5, 6]);
        assert_eq!(s.allocated_pages(), 2);
        assert_eq!(pool.lock().unwrap().in_use_pages(), 2);
        assert_eq!(pool.lock().unwrap().stats().frees, 1);
        drop(s);
        assert_eq!(pool.lock().unwrap().in_use_pages(), 0, "drop releases every page");
    }

    #[test]
    fn slabs_share_one_arena() {
        let m = tiny_meta();
        let pool = tiny_pool(&m, 4); // 16 slots total
        let mut a = KvSlab::in_pool(&pool, 16);
        let mut b = KvSlab::in_pool(&pool, 16);
        for i in 0..8 {
            a.append(&row_of(1.0, &m), &row_of(1.0, &m), i, Modality::Text, 0.0);
            b.append(&row_of(2.0, &m), &row_of(2.0, &m), i, Modality::Text, 0.0);
        }
        assert_eq!(pool.lock().unwrap().free_pages(), 0);
        // a's eviction is immediately b's headroom
        a.evict(&(0..8).collect::<Vec<_>>());
        assert_eq!(pool.lock().unwrap().free_pages(), 2);
        for i in 8..16 {
            b.append(&row_of(2.0, &m), &row_of(2.0, &m), i, Modality::Text, 0.0);
        }
        assert_eq!(b.len(), 16);
        assert_eq!(b.k_row(0, 15)[0], 2.0);
    }

    #[test]
    fn release_pages_keeps_metadata() {
        let m = tiny_meta();
        let pool = tiny_pool(&m, 4);
        let mut s = KvSlab::in_pool(&pool, 16);
        for i in 0..6 {
            s.append(&row_of(0.0, &m), &row_of(0.0, &m), i, Modality::Text, 0.5);
        }
        s.release_pages();
        assert_eq!(pool.lock().unwrap().in_use_pages(), 0, "pages back at retire");
        assert_eq!(s.len(), 6, "stats stay readable");
        assert!((s.meta()[3].cum_score - 0.5).abs() < 1e-6);
        assert!(s.kv_bytes() > 0);
        s.release_pages(); // idempotent
        drop(s); // the emptied table leaves nothing to double-release
        assert_eq!(pool.lock().unwrap().stats().frees, 2);
        assert_eq!(pool.lock().unwrap().stats().refcount_errors, 0);
    }

    #[test]
    fn clone_detaches_from_the_arena() {
        let m = tiny_meta();
        let pool = tiny_pool(&m, 4);
        let mut s = KvSlab::in_pool(&pool, 16);
        for i in 0..6 {
            s.append(&row_of(i as f32, &m), &row_of(0.0, &m), i, Modality::Text, 0.0);
        }
        let in_use = pool.lock().unwrap().in_use_pages();
        let c = s.clone();
        assert_eq!(pool.lock().unwrap().in_use_pages(), in_use, "clone takes no arena pages");
        drop(s);
        assert_eq!(c.len(), 6);
        assert_eq!(c.k_row(0, 5)[0], 5.0);
    }

    #[test]
    fn kv_bytes_counts_live_only() {
        let m = tiny_meta();
        let mut s = KvSlab::new(&m, 8);
        assert_eq!(s.kv_bytes(), 0);
        s.append(&row_of(0.0, &m), &row_of(0.0, &m), 0, Modality::Text, 0.0);
        assert_eq!(s.kv_bytes(), 2 * m.n_layers * m.n_heads * m.d_head * 4);
        assert_eq!(s.kv_bytes(), s.kv_bytes_per_slot());
        assert_eq!(s.kv_bytes_per_slot(), m.kv_bytes_per_token());
    }

    #[test]
    #[should_panic(expected = "slab full")]
    fn append_past_capacity_panics() {
        let m = tiny_meta();
        let mut s = KvSlab::new(&m, 2);
        for i in 0..3 {
            s.append(&row_of(0.0, &m), &row_of(0.0, &m), i, Modality::Text, 0.0);
        }
    }

    // ------------------------------------------------------------------
    // copy-on-write prefix sharing
    // ------------------------------------------------------------------

    /// Build a donor slab with `n` slots valued by index, and return the
    /// metadata snapshot a prefix-cache entry would hold.
    fn donor(pool: &SharedPagePool, m: &ModelMeta, n: usize) -> (KvSlab, Vec<SlotMeta>) {
        let mut s = KvSlab::in_pool(pool, 16);
        for i in 0..n {
            s.append(&row_of(i as f32, m), &row_of(i as f32, m), i as i32,
                     Modality::Text, 0.0);
        }
        let meta = s.meta().to_vec();
        (s, meta)
    }

    #[test]
    fn adopt_shared_reads_without_copying() {
        let m = tiny_meta();
        let pool = tiny_pool(&m, 8);
        let (d, meta) = donor(&pool, &m, 8); // two full 4-slot pages
        let in_use = pool.lock().unwrap().in_use_pages();
        let mut s = KvSlab::in_pool(&pool, 16);
        assert!(s.adopt_shared(&d.table.pages().to_vec(), meta));
        assert_eq!(pool.lock().unwrap().in_use_pages(), in_use, "adoption allocates nothing");
        assert_eq!(s.len(), 8);
        assert_eq!(s.shared_pages(), 2);
        assert_eq!(s.shared_pages_stable(), 2, "aligned tail stays shared");
        for i in 0..8 {
            assert_eq!(s.k_row(0, i)[0], i as f32);
        }
        drop(s);
        assert_eq!(pool.lock().unwrap().in_use_pages(), in_use, "adopter's refs released");
        assert_eq!(pool.lock().unwrap().stats().refcount_errors, 0);
    }

    #[test]
    fn append_into_shared_partial_tail_forks() {
        let m = tiny_meta();
        let pool = tiny_pool(&m, 8);
        let (d, meta) = donor(&pool, &m, 6); // pages: full + partial (2 slots)
        let mut s = KvSlab::in_pool(&pool, 16);
        assert!(s.adopt_shared(&d.table.pages().to_vec(), meta));
        assert_eq!(s.shared_pages(), 2);
        assert_eq!(s.shared_pages_stable(), 1, "partial tail is fork-bound");
        s.append(&row_of(99.0, &m), &row_of(99.0, &m), 6, Modality::Text, 0.0);
        assert_eq!(pool.lock().unwrap().stats().forks, 1, "first append forked the tail");
        assert_eq!(s.shared_pages(), 1);
        // the write landed in this slab only
        assert_eq!(s.k_row(0, 6)[0], 99.0);
        assert_eq!(d.k_row(0, 5)[0], 5.0, "donor tail untouched");
        let (dp, doff) = d.page_of(5);
        assert_eq!(pool.lock().unwrap().read_row(dp, doff, 0, false)[0], 5.0);
        // further appends reuse the now-private tail: no more forks
        s.append(&row_of(98.0, &m), &row_of(98.0, &m), 7, Modality::Text, 0.0);
        assert_eq!(pool.lock().unwrap().stats().forks, 1);
    }

    #[test]
    fn eviction_inside_shared_prefix_forks_and_leaves_donor_intact() {
        let m = tiny_meta();
        let pool = tiny_pool(&m, 8);
        let (d, meta) = donor(&pool, &m, 8);
        let mut s = KvSlab::in_pool(&pool, 16);
        assert!(s.adopt_shared(&d.table.pages().to_vec(), meta));
        // evicting slot 1 slides everything down: writes hit both pages
        s.evict(&[1]);
        assert!(pool.lock().unwrap().stats().forks >= 1, "CoW forked the written pages");
        assert_eq!(s.shared_pages(), 0, "writer fully diverged");
        let positions: Vec<i32> = s.meta().iter().map(|mm| mm.position).collect();
        assert_eq!(positions, vec![0, 2, 3, 4, 5, 6, 7]);
        assert_eq!(s.k_row(0, 1)[0], 2.0);
        // donor still sees its original 8 slots, byte-for-byte
        for i in 0..8 {
            assert_eq!(d.k_row(0, i)[0], i as f32, "donor slot {}", i);
        }
    }

    #[test]
    fn try_evict_defers_on_exhaustion_and_recovers() {
        // pool sized so the donor + one adopter fill it exactly: the
        // adopter's eviction inside the shared prefix needs CoW forks the
        // pool cannot supply — try_evict must defer (slab untouched, no
        // refcount damage) and succeed once pages free up. This is the
        // PR-3 fork-exhaustion panic scenario, now recoverable.
        let m = tiny_meta();
        let pool = tiny_pool(&m, 4); // donor 2 pages + 2 for the forks
        let (d, meta) = donor(&pool, &m, 8); // donor holds 2 pages
        let mut s = KvSlab::in_pool(&pool, 16);
        assert!(s.adopt_shared(&d.table.pages().to_vec(), meta));
        // burn the free pages so the fork pre-pass finds nothing
        let blockers: Vec<u32> =
            (0..2).map(|_| pool.lock().unwrap().alloc().unwrap()).collect();
        let before: Vec<i32> = s.meta().iter().map(|mm| mm.position).collect();
        assert_eq!(s.try_evict(&[1]), None, "no page for the fork: deferred");
        assert_eq!(s.len(), 8, "nothing evicted");
        let after: Vec<i32> = s.meta().iter().map(|mm| mm.position).collect();
        assert_eq!(after, before, "slot order untouched");
        for i in 0..8 {
            assert_eq!(s.k_row(0, i)[0], i as f32, "KV untouched at slot {}", i);
        }
        assert_eq!(pool.lock().unwrap().stats().refcount_errors, 0);
        // pages free → the retry applies the same eviction cleanly
        for b in blockers {
            pool.lock().unwrap().release(b);
        }
        assert_eq!(s.try_evict(&[1]), Some(1));
        let positions: Vec<i32> = s.meta().iter().map(|mm| mm.position).collect();
        assert_eq!(positions, vec![0, 2, 3, 4, 5, 6, 7]);
        assert_eq!(s.k_row(0, 1)[0], 2.0);
        // donor still byte-identical
        for i in 0..8 {
            assert_eq!(d.k_row(0, i)[0], i as f32, "donor slot {}", i);
        }
    }

    #[test]
    fn partial_prepass_fork_survives_a_deferral() {
        // 4-page pool: donor 2 pages + 1 free. The pre-pass forks page 0,
        // then fails on page 1 → deferral. Page 0 stays private with
        // identical content; the logical view is unchanged, and a retry
        // after a free completes (page 0 needs no second fork).
        let m = tiny_meta();
        let pool = tiny_pool(&m, 3);
        let (d, meta) = donor(&pool, &m, 8);
        let mut s = KvSlab::in_pool(&pool, 16);
        assert!(s.adopt_shared(&d.table.pages().to_vec(), meta));
        assert_eq!(pool.lock().unwrap().free_pages(), 1);
        assert_eq!(s.try_evict(&[0]), None, "second fork has no page");
        assert!(s.shared_pages() <= 1, "first pre-pass fork may persist");
        for i in 0..8 {
            assert_eq!(s.k_row(0, i)[0], i as f32, "content intact at {}", i);
        }
        // dropping the donor frees its reference on the forked-off page;
        // the sole-owner path then privatizes page 1 without a copy
        drop(d);
        assert_eq!(s.try_evict(&[0]), Some(1));
        let positions: Vec<i32> = s.meta().iter().map(|mm| mm.position).collect();
        assert_eq!(positions, (1..8).collect::<Vec<i32>>());
    }

    #[test]
    fn drop_tail_aligned_is_fork_free_and_frees_a_page() {
        let m = tiny_meta();
        let pool = tiny_pool(&m, 8);
        let (d, meta) = donor(&pool, &m, 6); // 2 pages, partial tail
        let mut s = KvSlab::in_pool(&pool, 16);
        assert!(s.adopt_shared(&d.table.pages().to_vec(), meta));
        let forks_before = pool.lock().unwrap().stats().forks;
        let in_use = pool.lock().unwrap().in_use_pages();
        // need 1 → truncate to the 4-slot page boundary: 2 slots dropped
        assert_eq!(s.drop_tail_aligned(1), 2);
        assert_eq!(s.len(), 4);
        assert_eq!(pool.lock().unwrap().stats().forks, forks_before, "no CoW fork");
        assert_eq!(pool.lock().unwrap().in_use_pages(), in_use, "donor keeps the tail page");
        assert_eq!(s.allocated_pages(), 1, "this slab released its tail reference");
        // the next append allocates a fresh page — no shared tail to fork
        assert!(s.unstable_tail_page().is_none());
        s.append(&row_of(9.0, &m), &row_of(9.0, &m), 6, Modality::Text, 0.0);
        assert_eq!(pool.lock().unwrap().stats().forks, forks_before);
        // donor tail untouched
        assert_eq!(d.k_row(0, 5)[0], 5.0);
        // degenerate: need larger than len drops everything
        let mut t = KvSlab::in_pool(&pool, 16);
        t.append(&row_of(0.0, &m), &row_of(0.0, &m), 0, Modality::Text, 0.0);
        assert_eq!(t.drop_tail_aligned(99), 1);
        assert!(t.is_empty());
    }

    #[test]
    fn shared_pages_free_only_after_every_holder_drops() {
        let m = tiny_meta();
        let pool = tiny_pool(&m, 8);
        let (d, meta) = donor(&pool, &m, 8);
        let pages = d.table.pages().to_vec();
        let mut a = KvSlab::in_pool(&pool, 16);
        let mut b = KvSlab::in_pool(&pool, 16);
        assert!(a.adopt_shared(&pages, meta.clone()));
        assert!(b.adopt_shared(&pages, meta));
        drop(d);
        assert_eq!(pool.lock().unwrap().in_use_pages(), 2, "a+b still pin the pages");
        a.release_pages();
        assert_eq!(pool.lock().unwrap().in_use_pages(), 2, "b still pins them");
        drop(b);
        assert_eq!(pool.lock().unwrap().in_use_pages(), 0, "last holder frees");
        assert_eq!(pool.lock().unwrap().stats().refcount_errors, 0);
    }

    #[test]
    fn release_records_physical_split_for_accounting() {
        let m = tiny_meta();
        let pool = tiny_pool(&m, 8);
        let (d, meta) = donor(&pool, &m, 6);
        let mut s = KvSlab::in_pool(&pool, 16);
        assert!(s.adopt_shared(&d.table.pages().to_vec(), meta));
        // fork the tail: 3 private slots, page 0 still shared
        s.append(&row_of(9.0, &m), &row_of(9.0, &m), 6, Modality::Text, 0.0);
        let private_before = s.kv_bytes_private();
        let shared_before = s.shared_page_ids();
        assert_eq!(shared_before, vec![d.table.page(0)]);
        s.release_pages();
        // the split survives release: a lane finishing mid-step is
        // accounted without double-counting the donor's shared page
        assert_eq!(s.kv_bytes_private(), private_before);
        assert_eq!(s.shared_page_ids(), shared_before);
        assert!(s.unstable_tail_page().is_none(), "released: nothing forks");
    }

    #[test]
    fn unstable_tail_is_the_fork_bound_page() {
        let m = tiny_meta();
        let pool = tiny_pool(&m, 8);
        let (d, meta) = donor(&pool, &m, 6); // partial tail (2 of 4 slots)
        let mut s = KvSlab::in_pool(&pool, 16);
        assert!(s.adopt_shared(&d.table.pages().to_vec(), meta));
        assert_eq!(s.unstable_tail_page(), Some(d.table.page(1)));
        // the first append forks it: no unstable tail remains
        s.append(&row_of(1.0, &m), &row_of(1.0, &m), 6, Modality::Text, 0.0);
        assert_eq!(s.unstable_tail_page(), None);

        // an aligned shared tail is stable: nothing to exclude
        let (d2, meta2) = donor(&pool, &m, 4);
        let mut s2 = KvSlab::in_pool(&pool, 16);
        assert!(s2.adopt_shared(&d2.table.pages().to_vec(), meta2));
        assert_eq!(s2.unstable_tail_page(), None);
    }

    #[test]
    fn kv_bytes_private_excludes_shared_pages() {
        let m = tiny_meta();
        let pool = tiny_pool(&m, 8);
        let (d, meta) = donor(&pool, &m, 6);
        let mut s = KvSlab::in_pool(&pool, 16);
        assert!(s.adopt_shared(&d.table.pages().to_vec(), meta));
        assert_eq!(s.kv_bytes_private(), 0, "everything shared");
        assert_eq!(s.kv_bytes(), 6 * s.kv_bytes_per_slot());
        // fork the tail: 2 live slots in the now-private page
        s.append(&row_of(0.0, &m), &row_of(0.0, &m), 6, Modality::Text, 0.0);
        assert_eq!(s.kv_bytes_private(), 3 * s.kv_bytes_per_slot());
        assert_eq!(s.shared_page_ids(), vec![d.table.page(0)]);
    }
}
