//! Host-owned per-request KV slab.
//!
//! Layout is layer-major `[L, CAP, H, Dh]` (matching the decode executable's
//! cache input) with a fixed physical capacity; the first `len` slots of
//! every layer are live. Each live slot carries metadata: original sequence
//! position, modality, cumulative attention score (the β(C_j) term of paper
//! Eq. 5) and a recycle-bin mark (DDES). Eviction = compaction: retained
//! slots are copied down in order, so slot index i always addresses the
//! same token across K, V and metadata — the slab-integrity property
//! tested in tests/cache_props.rs.

use crate::model::ModelMeta;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Modality {
    Vision,
    Text,
}

#[derive(Debug, Clone, Copy)]
pub struct SlotMeta {
    /// original (global) sequence position of this token
    pub position: i32,
    pub modality: Modality,
    /// cumulative attention mass received since entering the cache
    /// (layer/head mean — the β(C_j) term of Eq. 5)
    pub cum_score: f32,
    /// cumulative max-over-heads attention mass (AdaKV-style adaptive
    /// scoring input; see cache/baselines.rs)
    pub cum_peak: f32,
    /// attention mass received in the most recent step
    pub last_score: f32,
    /// DDES recycle-bin mark (still attendable until flushed)
    pub marked: bool,
    /// decode steps survived in the cache
    pub age: u32,
}

#[derive(Debug, Clone)]
pub struct KvSlab {
    k: Vec<f32>,
    v: Vec<f32>,
    meta: Vec<SlotMeta>,
    /// physical slots per layer
    cap: usize,
    /// floats per slot per layer (H * Dh)
    row: usize,
    n_layers: usize,
}

impl KvSlab {
    pub fn new(m: &ModelMeta, cap: usize) -> Self {
        let row = m.n_heads * m.d_head;
        KvSlab {
            k: vec![0.0; m.n_layers * cap * row],
            v: vec![0.0; m.n_layers * cap * row],
            meta: Vec::with_capacity(cap),
            cap,
            row,
            n_layers: m.n_layers,
        }
    }

    pub fn len(&self) -> usize {
        self.meta.len()
    }

    pub fn is_empty(&self) -> bool {
        self.meta.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn meta(&self) -> &[SlotMeta] {
        &self.meta
    }

    pub fn meta_mut(&mut self) -> &mut [SlotMeta] {
        &mut self.meta
    }

    /// Bytes of one live slot (K+V for one token across all layers) —
    /// the accounting unit of the scheduler's KV-budget admission.
    pub fn kv_bytes_per_slot(&self) -> usize {
        2 * self.n_layers * self.row * 4
    }

    /// Live KV bytes (the paper's "KV Cache (MB)" accounting).
    pub fn kv_bytes(&self) -> usize {
        self.meta.len() * self.kv_bytes_per_slot()
    }

    fn slot_offset(&self, layer: usize, slot: usize) -> usize {
        (layer * self.cap + slot) * self.row
    }

    /// Append one token's KV. `k_row`/`v_row` are `[L, H, Dh]` (layer-major,
    /// as returned by the decode executable for one lane).
    pub fn append(
        &mut self,
        k_row: &[f32],
        v_row: &[f32],
        position: i32,
        modality: Modality,
        init_score: f32,
    ) -> usize {
        assert!(self.meta.len() < self.cap, "slab full");
        assert_eq!(k_row.len(), self.n_layers * self.row);
        let slot = self.meta.len();
        for l in 0..self.n_layers {
            let dst = self.slot_offset(l, slot);
            let src = l * self.row;
            self.k[dst..dst + self.row].copy_from_slice(&k_row[src..src + self.row]);
            self.v[dst..dst + self.row].copy_from_slice(&v_row[src..src + self.row]);
        }
        self.meta.push(SlotMeta {
            position,
            modality,
            cum_score: init_score,
            cum_peak: init_score,
            last_score: init_score,
            marked: false,
            age: 0,
        });
        slot
    }

    /// Bulk-load retained prompt tokens from a prefill output.
    ///
    /// `k_src`/`v_src` are `[L, S, H, Dh]` (bucket-major, as emitted by the
    /// prefill executable); `retain` lists prompt slot indices to keep (in
    /// ascending order); `modality[i]`/`scores[i]` describe prompt slot i.
    pub fn inject_prefill(
        &mut self,
        k_src: &[f32],
        v_src: &[f32],
        bucket: usize,
        retain: &[usize],
        modality: &[Modality],
        scores: &[f32],
    ) {
        assert!(self.meta.is_empty(), "inject into non-empty slab");
        assert!(retain.len() < self.cap, "prefill larger than slab capacity");
        for (dst_slot, &src_slot) in retain.iter().enumerate() {
            for l in 0..self.n_layers {
                let src = (l * bucket + src_slot) * self.row;
                let dst = self.slot_offset(l, dst_slot);
                self.k[dst..dst + self.row].copy_from_slice(&k_src[src..src + self.row]);
                self.v[dst..dst + self.row].copy_from_slice(&v_src[src..src + self.row]);
            }
            self.meta.push(SlotMeta {
                position: src_slot as i32,
                modality: modality[src_slot],
                cum_score: scores[src_slot],
                cum_peak: scores[src_slot],
                last_score: scores[src_slot],
                marked: false,
                age: 0,
            });
        }
    }

    /// Accumulate this step's attention mass into slot scores and ages.
    /// `mean[i]` is the layer/head-mean mass on slot i, `peak[i]` the
    /// max-over-heads mass (may be the same slice when peak tracking is
    /// not needed).
    pub fn add_scores(&mut self, mean: &[f32], peak: &[f32]) {
        for (i, m) in self.meta.iter_mut().enumerate() {
            let s = mean.get(i).copied().unwrap_or(0.0);
            m.cum_score += s;
            m.cum_peak += peak.get(i).copied().unwrap_or(s);
            m.last_score = s;
            m.age += 1;
        }
    }

    /// Keep exactly the slots in `retain` (ascending, deduped), dropping
    /// the rest. Returns the number of evicted slots.
    pub fn compact(&mut self, retain: &[usize]) -> usize {
        debug_assert!(retain.windows(2).all(|w| w[0] < w[1]), "retain must be ascending");
        let evicted = self.meta.len() - retain.len();
        if evicted == 0 {
            return 0;
        }
        for (dst_slot, &src_slot) in retain.iter().enumerate() {
            if dst_slot == src_slot {
                continue;
            }
            for l in 0..self.n_layers {
                let src = self.slot_offset(l, src_slot);
                let dst = self.slot_offset(l, dst_slot);
                let (a, b) = if src > dst { (dst, src) } else { (src, dst) };
                // non-overlapping because row-sized chunks at distinct slots
                let _ = (a, b);
                self.k.copy_within(src..src + self.row, dst);
                self.v.copy_within(src..src + self.row, dst);
            }
            self.meta[dst_slot] = self.meta[src_slot];
        }
        self.meta.truncate(retain.len());
        evicted
    }

    /// Evict the given slots (any order, deduped internally).
    pub fn evict(&mut self, evict: &[usize]) -> usize {
        if evict.is_empty() {
            return 0;
        }
        let mut drop_mask = vec![false; self.meta.len()];
        for &i in evict {
            if i < drop_mask.len() {
                drop_mask[i] = true;
            }
        }
        let retain: Vec<usize> =
            (0..self.meta.len()).filter(|&i| !drop_mask[i]).collect();
        self.compact(&retain)
    }

    /// Copy this slab's live region into a batched decode input at the
    /// given lane. `dst_k`/`dst_v` are `[B, L, C, H, Dh]`; `cap_c` is the
    /// batch buffer's capacity bucket (≥ self.len()).
    pub fn copy_into_lane(
        &self,
        dst_k: &mut [f32],
        dst_v: &mut [f32],
        lane: usize,
        cap_c: usize,
    ) {
        let len = self.meta.len();
        assert!(len <= cap_c, "lane cache {} > bucket {}", len, cap_c);
        for l in 0..self.n_layers {
            let src = self.slot_offset(l, 0);
            let dst = ((lane * self.n_layers + l) * cap_c) * self.row;
            let n = len * self.row;
            dst_k[dst..dst + n].copy_from_slice(&self.k[src..src + n]);
            dst_v[dst..dst + n].copy_from_slice(&self.v[src..src + n]);
        }
    }

    /// Raw K row of one slot in one layer (test/diagnostic use).
    pub fn k_row(&self, layer: usize, slot: usize) -> &[f32] {
        let o = self.slot_offset(layer, slot);
        &self.k[o..o + self.row]
    }

    pub fn v_row(&self, layer: usize, slot: usize) -> &[f32] {
        let o = self.slot_offset(layer, slot);
        &self.v[o..o + self.row]
    }

    /// Count of marked (recycle-bin) slots.
    pub fn marked_count(&self) -> usize {
        self.meta.iter().filter(|m| m.marked).count()
    }

    /// Indices of marked slots, ascending.
    pub fn marked_slots(&self) -> Vec<usize> {
        self.meta
            .iter()
            .enumerate()
            .filter(|(_, m)| m.marked)
            .map(|(i, _)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelMeta;

    fn tiny_meta() -> ModelMeta {
        ModelMeta {
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 2,
            d_mlp: 8,
            patch_dim: 4,
            n_patches: 4,
            max_pos: 64,
            dap_layer: 1,
        }
    }

    fn row_of(val: f32, m: &ModelMeta) -> Vec<f32> {
        vec![val; m.n_layers * m.n_heads * m.d_head]
    }

    #[test]
    fn append_and_read_back() {
        let m = tiny_meta();
        let mut s = KvSlab::new(&m, 8);
        for i in 0..5 {
            s.append(&row_of(i as f32, &m), &row_of(-(i as f32), &m), i as i32,
                     Modality::Text, 0.1);
        }
        assert_eq!(s.len(), 5);
        for i in 0..5 {
            assert_eq!(s.k_row(0, i)[0], i as f32);
            assert_eq!(s.k_row(1, i)[0], i as f32);
            assert_eq!(s.v_row(0, i)[0], -(i as f32));
            assert_eq!(s.meta()[i].position, i as i32);
        }
    }

    #[test]
    fn compact_preserves_order_and_data() {
        let m = tiny_meta();
        let mut s = KvSlab::new(&m, 8);
        for i in 0..6 {
            s.append(&row_of(i as f32, &m), &row_of(i as f32, &m), i as i32,
                     Modality::Text, 0.0);
        }
        let evicted = s.compact(&[0, 2, 5]);
        assert_eq!(evicted, 3);
        assert_eq!(s.len(), 3);
        assert_eq!(s.k_row(0, 0)[0], 0.0);
        assert_eq!(s.k_row(0, 1)[0], 2.0);
        assert_eq!(s.k_row(1, 2)[0], 5.0);
        assert_eq!(s.meta()[1].position, 2);
    }

    #[test]
    fn evict_any_order() {
        let m = tiny_meta();
        let mut s = KvSlab::new(&m, 8);
        for i in 0..6 {
            s.append(&row_of(i as f32, &m), &row_of(i as f32, &m), i as i32,
                     Modality::Vision, 0.0);
        }
        s.evict(&[4, 1, 1]);
        assert_eq!(s.len(), 4);
        let positions: Vec<i32> = s.meta().iter().map(|mm| mm.position).collect();
        assert_eq!(positions, vec![0, 2, 3, 5]);
    }

    #[test]
    fn inject_prefill_gathers() {
        let m = tiny_meta();
        let bucket = 4;
        let row = m.n_heads * m.d_head;
        // k_src [L, S, H*Dh]: value = layer*100 + slot
        let mut k_src = vec![0.0f32; m.n_layers * bucket * row];
        for l in 0..m.n_layers {
            for sslot in 0..bucket {
                let base = (l * bucket + sslot) * row;
                for x in &mut k_src[base..base + row] {
                    *x = (l * 100 + sslot) as f32;
                }
            }
        }
        let v_src = k_src.clone();
        let mut s = KvSlab::new(&m, 8);
        let modality = vec![Modality::Vision, Modality::Vision, Modality::Text, Modality::Text];
        let scores = vec![0.1, 0.2, 0.3, 0.4];
        s.inject_prefill(&k_src, &v_src, bucket, &[1, 3], &modality, &scores);
        assert_eq!(s.len(), 2);
        assert_eq!(s.k_row(0, 0)[0], 1.0);
        assert_eq!(s.k_row(1, 0)[0], 101.0);
        assert_eq!(s.k_row(0, 1)[0], 3.0);
        assert_eq!(s.meta()[0].modality, Modality::Vision);
        assert_eq!(s.meta()[1].position, 3);
        assert!((s.meta()[1].cum_score - 0.4).abs() < 1e-6);
    }

    #[test]
    fn copy_into_lane_layout() {
        let m = tiny_meta();
        let row = m.n_heads * m.d_head;
        let mut s = KvSlab::new(&m, 8);
        for i in 0..3 {
            s.append(&row_of(i as f32 + 1.0, &m), &row_of(0.0, &m), i as i32,
                     Modality::Text, 0.0);
        }
        let (b, c) = (2, 4);
        let mut dst_k = vec![0.0f32; b * m.n_layers * c * row];
        let mut dst_v = dst_k.clone();
        s.copy_into_lane(&mut dst_k, &mut dst_v, 1, c);
        // lane 0 untouched
        assert!(dst_k[..m.n_layers * c * row].iter().all(|&x| x == 0.0));
        // lane 1, layer 0, slot 1 = 2.0
        let off = (1 * m.n_layers + 0) * c * row + 1 * row;
        assert_eq!(dst_k[off], 2.0);
    }

    #[test]
    fn kv_bytes_counts_live_only() {
        let m = tiny_meta();
        let mut s = KvSlab::new(&m, 8);
        assert_eq!(s.kv_bytes(), 0);
        s.append(&row_of(0.0, &m), &row_of(0.0, &m), 0, Modality::Text, 0.0);
        assert_eq!(s.kv_bytes(), 2 * m.n_layers * m.n_heads * m.d_head * 4);
        assert_eq!(s.kv_bytes(), s.kv_bytes_per_slot());
        assert_eq!(s.kv_bytes_per_slot(), m.kv_bytes_per_token());
    }

    #[test]
    #[should_panic(expected = "slab full")]
    fn append_past_capacity_panics() {
        let m = tiny_meta();
        let mut s = KvSlab::new(&m, 2);
        for i in 0..3 {
            s.append(&row_of(0.0, &m), &row_of(0.0, &m), i, Modality::Text, 0.0);
        }
    }
}
