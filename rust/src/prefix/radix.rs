//! Compressed radix trie over prompt-prefix symbols.
//!
//! Keys are sequences of [`KeySym`]: one symbol per *text* token id, and
//! one symbol per contiguous *vision segment* (the content hash of the
//! segment's patch features — prefix/mod.rs builds keys from requests).
//! Collapsing an image to a single symbol keeps the trie shallow: the
//! dominant multimodal pattern — many questions against one image —
//! becomes a single shared [BOS][image-hash] spine with one short text
//! branch per distinct question.
//!
//! Edges are label-compressed (a node stores the whole symbol run to its
//! parent), so lookup cost is O(key length), independent of how many
//! entries share a prefix. `longest_match` returns the deepest stored
//! value whose path is a prefix of the query — the page-aligned partial
//! reuse hook — while exact hits are the `matched == key.len()` case the
//! engine's admission fast path uses.

/// One key symbol: a text token id, or a whole vision segment collapsed
/// to its content hash.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KeySym {
    Text(i32),
    Vision(u64),
}

struct Node<V> {
    /// compressed edge label from the parent (empty only at the root)
    edge: Vec<KeySym>,
    val: Option<V>,
    children: Vec<Node<V>>,
}

impl<V> Node<V> {
    fn leaf(edge: Vec<KeySym>, val: V) -> Self {
        Node { edge, val: Some(val), children: Vec::new() }
    }

    fn insert(&mut self, key: &[KeySym], val: V) -> Option<V> {
        if key.is_empty() {
            return self.val.replace(val);
        }
        let idx = match self.children.iter().position(|c| c.edge[0] == key[0]) {
            None => {
                self.children.push(Node::leaf(key.to_vec(), val));
                return None;
            }
            Some(i) => i,
        };
        let child = &mut self.children[idx];
        let common = child
            .edge
            .iter()
            .zip(key)
            .take_while(|(a, b)| a == b)
            .count();
        if common == child.edge.len() {
            return child.insert(&key[common..], val);
        }
        // split the edge: intermediate node carries the common prefix
        let prefix: Vec<KeySym> = child.edge.drain(..common).collect();
        let old = self.children.swap_remove(idx);
        let mut mid = Node { edge: prefix, val: None, children: vec![old] };
        let rest = &key[common..];
        if rest.is_empty() {
            mid.val = Some(val);
        } else {
            mid.children.push(Node::leaf(rest.to_vec(), val));
        }
        self.children.push(mid);
        None
    }

    fn remove(&mut self, key: &[KeySym]) -> Option<V> {
        if key.is_empty() {
            return self.val.take();
        }
        let idx = self.children.iter().position(|c| c.edge[0] == key[0])?;
        {
            let child = &self.children[idx];
            if key.len() < child.edge.len() || key[..child.edge.len()] != child.edge[..] {
                return None;
            }
        }
        let edge_len = self.children[idx].edge.len();
        let out = self.children[idx].remove(&key[edge_len..]);
        if out.is_some() && self.children[idx].val.is_none() {
            if self.children[idx].children.is_empty() {
                self.children.swap_remove(idx);
            } else if self.children[idx].children.len() == 1 {
                // re-compress: merge the lone grandchild into the edge
                let mut only = self.children[idx].children.pop().unwrap();
                let mut edge = std::mem::take(&mut self.children[idx].edge);
                edge.append(&mut only.edge);
                only.edge = edge;
                self.children[idx] = only;
            }
        }
        out
    }
}

pub struct RadixTree<V> {
    root: Node<V>,
    len: usize,
}

impl<V> Default for RadixTree<V> {
    fn default() -> Self {
        RadixTree::new()
    }
}

impl<V> RadixTree<V> {
    pub fn new() -> Self {
        RadixTree {
            root: Node { edge: Vec::new(), val: None, children: Vec::new() },
            len: 0,
        }
    }

    /// Stored values.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Insert, returning the previous value stored at exactly this key.
    pub fn insert(&mut self, key: &[KeySym], val: V) -> Option<V> {
        let old = self.root.insert(key, val);
        if old.is_none() {
            self.len += 1;
        }
        old
    }

    /// Deepest stored value whose key is a prefix of `key`, with the
    /// number of symbols it covers. `matched == key.len()` is an exact
    /// hit.
    pub fn longest_match<'a>(&'a self, key: &[KeySym]) -> Option<(usize, &'a V)> {
        let mut node = &self.root;
        let mut depth = 0usize;
        let mut best = node.val.as_ref().map(|v| (0, v));
        loop {
            let rest = &key[depth..];
            if rest.is_empty() {
                break;
            }
            let Some(child) = node.children.iter().find(|c| c.edge[0] == rest[0]) else {
                break;
            };
            if rest.len() < child.edge.len()
                || rest[..child.edge.len()] != child.edge[..]
            {
                break;
            }
            depth += child.edge.len();
            node = child;
            if let Some(v) = &node.val {
                best = Some((depth, v));
            }
        }
        best
    }

    /// Value stored at exactly `key`.
    pub fn get(&self, key: &[KeySym]) -> Option<&V> {
        match self.longest_match(key) {
            Some((d, v)) if d == key.len() => Some(v),
            _ => None,
        }
    }

    /// Remove the value at exactly `key`, re-compressing the path.
    pub fn remove(&mut self, key: &[KeySym]) -> Option<V> {
        let out = self.root.remove(key);
        if out.is_some() {
            self.len -= 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(id: i32) -> KeySym {
        KeySym::Text(id)
    }

    fn v(h: u64) -> KeySym {
        KeySym::Vision(h)
    }

    #[test]
    fn insert_get_exact() {
        let mut tr = RadixTree::new();
        assert!(tr.insert(&[t(1), v(9), t(2)], "a").is_none());
        assert!(tr.insert(&[t(1), v(9), t(3)], "b").is_none());
        assert_eq!(tr.len(), 2);
        assert_eq!(tr.get(&[t(1), v(9), t(2)]), Some(&"a"));
        assert_eq!(tr.get(&[t(1), v(9), t(3)]), Some(&"b"));
        assert_eq!(tr.get(&[t(1), v(9)]), None, "interior split node holds no value");
        assert_eq!(tr.get(&[t(1), v(8), t(2)]), None, "different image hash");
        // replacing returns the old value and keeps len
        assert_eq!(tr.insert(&[t(1), v(9), t(2)], "a2"), Some("a"));
        assert_eq!(tr.len(), 2);
        assert_eq!(tr.get(&[t(1), v(9), t(2)]), Some(&"a2"));
    }

    #[test]
    fn longest_match_finds_deepest_prefix() {
        let mut tr = RadixTree::new();
        tr.insert(&[t(1), v(9)], "prefix");
        tr.insert(&[t(1), v(9), t(2), t(3)], "deep");
        // full key match wins
        assert_eq!(tr.longest_match(&[t(1), v(9), t(2), t(3)]), Some((4, &"deep")));
        // a longer query falls back to the deepest stored prefix
        assert_eq!(
            tr.longest_match(&[t(1), v(9), t(2), t(3), t(4)]),
            Some((4, &"deep"))
        );
        // diverging after the shared spine matches the shallow entry
        assert_eq!(tr.longest_match(&[t(1), v(9), t(7)]), Some((2, &"prefix")));
        // a query shorter than every stored edge matches nothing
        assert_eq!(tr.longest_match(&[t(1)]), None);
        assert_eq!(tr.longest_match(&[t(5)]), None);
    }

    #[test]
    fn boundary_get_sees_through_deeper_exact_entries() {
        // the partial-hit lookup shape: the cache stores a prefix entry
        // at the vision boundary AND exact entries at deeper whole-prompt
        // keys (earlier dialog turns). Truncating the query at the
        // boundary and using get() (longest_match underneath) must find
        // the boundary entry regardless of what is stored deeper.
        let mut tr = RadixTree::new();
        tr.insert(&[t(1), v(9)], "prefix");
        tr.insert(&[t(1), v(9), t(2)], "exact-turn-0");
        let query = [t(1), v(9), t(2), t(20), t(3)]; // turn 1's key
        assert_eq!(
            tr.longest_match(&query),
            Some((3, &"exact-turn-0")),
            "the raw deepest match is the earlier turn's exact entry"
        );
        assert_eq!(tr.get(&query[..2]), Some(&"prefix"), "boundary get unshadowed");
    }

    #[test]
    fn shared_spine_is_one_edge() {
        // the many-questions-one-image pattern: entries share [BOS][img]
        let mut tr = RadixTree::new();
        for q in 0..6 {
            tr.insert(&[t(1), v(42), t(100 + q)], q);
        }
        assert_eq!(tr.len(), 6);
        // root has a single child (the compressed shared spine)
        assert_eq!(tr.root.children.len(), 1);
        assert_eq!(tr.root.children[0].edge, vec![t(1), v(42)]);
        assert_eq!(tr.root.children[0].children.len(), 6);
        for q in 0..6 {
            assert_eq!(tr.get(&[t(1), v(42), t(100 + q)]), Some(&q));
        }
    }

    #[test]
    fn remove_prunes_and_recompresses() {
        let mut tr = RadixTree::new();
        tr.insert(&[t(1), t(2), t(3)], "a");
        tr.insert(&[t(1), t(2), t(4)], "b");
        assert_eq!(tr.remove(&[t(1), t(2), t(3)]), Some("a"));
        assert_eq!(tr.len(), 1);
        assert_eq!(tr.get(&[t(1), t(2), t(3)]), None);
        assert_eq!(tr.get(&[t(1), t(2), t(4)]), Some(&"b"));
        // the split node re-compressed into a single edge again
        assert_eq!(tr.root.children.len(), 1);
        assert_eq!(tr.root.children[0].edge, vec![t(1), t(2), t(4)]);
        assert_eq!(tr.remove(&[t(1), t(2), t(4)]), Some("b"));
        assert!(tr.is_empty());
        assert!(tr.root.children.is_empty());
        // removing a missing key is a no-op
        assert_eq!(tr.remove(&[t(1), t(2), t(4)]), None);
    }

    #[test]
    fn remove_keeps_interior_values() {
        let mut tr = RadixTree::new();
        tr.insert(&[t(1), t(2)], "mid");
        tr.insert(&[t(1), t(2), t(3)], "leaf");
        assert_eq!(tr.remove(&[t(1), t(2), t(3)]), Some("leaf"));
        assert_eq!(tr.get(&[t(1), t(2)]), Some(&"mid"));
        assert_eq!(tr.len(), 1);
        // removing an unstored interior point of an edge does nothing
        tr.insert(&[t(1), t(2), t(3), t(4)], "leaf2");
        assert_eq!(tr.remove(&[t(1), t(2), t(3)]), None);
        assert_eq!(tr.len(), 2);
    }

    #[test]
    fn empty_key_stores_at_root() {
        let mut tr = RadixTree::new();
        assert!(tr.insert(&[], "root").is_none());
        assert_eq!(tr.longest_match(&[t(1)]), Some((0, &"root")));
        assert_eq!(tr.get(&[]), Some(&"root"));
        assert_eq!(tr.remove(&[]), Some("root"));
        assert!(tr.is_empty());
    }
}
