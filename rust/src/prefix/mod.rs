//! Radix-tree prefix cache with copy-on-write page sharing — the
//! cross-request reuse layer for the dominant multimodal serving
//! pattern: many questions against the same image or video.
//!
//! # What is cached
//!
//! After a cold prefill, the engine registers the request's *retained*
//! KV — the pages left after HAE's Dual-Attention Pruning — under a key
//! built from the prompt: one symbol per leading/trailing text token id,
//! one content-hash symbol per vision segment ([`request_key`]). The
//! entry pins the slab's pages in the shared `PagePool` (`retain_page`)
//! and snapshots the slot metadata (positions = the cached HAE
//! retained-index set, cum-score seeds = the DAP statistics) plus the
//! prefill logits of the last prompt position.
//!
//! # What a hit buys
//!
//! A later request with the same key skips prefill *entirely*: its slab
//! adopts the pinned pages copy-on-write (`KvSlab::adopt_shared`), the
//! cached metadata seeds its scores, and the cached logits produce the
//! first token. Dual-Attention Pruning therefore runs once per distinct
//! image instead of once per request, no prompt position is recomputed,
//! and N concurrent questions hold ONE copy of the visual prefix —
//! which the scheduler charges once against the KV budget
//! (scheduler/admission.rs), turning sharing directly into admission
//! headroom and batch width.
//!
//! Hits come in two granularities:
//!
//! * **Exact** (whole-prompt) matches are byte-identical to the
//!   request's own cold run, because everything the decode trajectory
//!   depends on — retained KV, metadata, first-token logits — is the
//!   cold run's own output for that exact prompt.
//! * **Partial** matches (PR 4): a prompt sharing only the *visual
//!   prefix* (the image symbols + leading tokens, e.g. a new question
//!   about a cached image) adopts a **prefix entry** — the *unpruned*
//!   prefix KV pinned at the last-vision-segment boundary, plus the
//!   prefix text rows' DAP statistic contributions — copy-on-write,
//!   recomputes only the text suffix through the decode executables,
//!   and re-runs the Dual-Attention Pruning decision with the
//!   request's OWN reconstructed statistics (cached prefix rows + its
//!   suffix rows, emitted per step by the decode graph). The pruning
//!   decision is therefore the request's own, never the donor's —
//!   which is what preserves cold/warm equivalence where replaying the
//!   donor's decision under a different question would break it
//!   (MadaKV's modality-aware budgets and TGV-KV's text-grounded
//!   scoring motivate exactly this per-request re-scoring).
//!
//! # Lifecycle
//!
//! Entries share pages with *live* slabs: the donor keeps decoding on
//! the pages it registered, and the first write a sharer (donor
//! included) makes inside the shared region forks the page
//! (prefix/cow.rs), so the cached image stays pristine. Unreferenced
//! entries are LRU-evicted when the pool runs short (the engine calls
//! [`PrefixCache::reclaim`] before allocating) or when the entry cap is
//! hit; eviction drops the cache's page references, freeing exactly the
//! pages no live request still maps.

pub mod cow;
pub mod radix;
pub mod replay;

use crate::cache::paged::PagePool;
use crate::cache::slab::SlotMeta;
use crate::workload::Request;

pub use radix::{KeySym, RadixTree};
pub use replay::DapAccumulator;

/// Default cap on cached entries (LRU beyond this). Entries are cheap on
/// the host (metadata + one logits row) — the real cost is pinned arena
/// pages, which `reclaim` bounds under pool pressure.
pub const DEFAULT_MAX_ENTRIES: usize = 64;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fnv(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Build the trie key of a request's prompt: text tokens symbol-by-symbol,
/// vision segments collapsed to a content hash over their patch features
/// and segment length. The hash is 64-bit FNV-1a, so the key alone is not
/// proof of identity — every entry also stores an independently-seeded
/// [`request_fingerprint`] that a hit must match, making a wrong-prefix
/// hit require a simultaneous collision in two independent 64-bit hashes.
pub fn request_key(req: &Request) -> Vec<KeySym> {
    let n = req.ids.len();
    let pd = if n == 0 { 0 } else { req.patches.len() / n };
    let mut key = Vec::new();
    let mut i = 0;
    while i < n {
        if req.is_vision[i] {
            let start = i;
            let mut h = FNV_OFFSET;
            while i < n && req.is_vision[i] {
                h = fnv(h, &req.ids[i].to_le_bytes());
                for &f in &req.patches[i * pd..(i + 1) * pd] {
                    h = fnv(h, &f.to_bits().to_le_bytes());
                }
                i += 1;
            }
            h = fnv(h, &((i - start) as u64).to_le_bytes());
            key.push(KeySym::Vision(h));
        } else {
            key.push(KeySym::Text(req.ids[i]));
            i += 1;
        }
    }
    key
}

/// The routing tier's affinity key: the content hash of the request's
/// *first* vision segment, extracted through [`request_key`] so "same
/// image" means exactly the same thing to the router as to the prefix
/// cache — a router placement decision and a prefix-cache hit can never
/// disagree about identity. `None` for text-only prompts, which have no
/// stable affinity worth routing on (the router falls back to
/// least-loaded placement).
pub fn vision_affinity_hash(req: &Request) -> Option<u64> {
    request_key(req).into_iter().find_map(|sym| match sym {
        KeySym::Vision(h) => Some(h),
        KeySym::Text(_) => None,
    })
}

/// Seed of the fingerprint stream — distinct from the radix-key hash so
/// a collision must happen in two independent 64-bit hashes at once.
const FP_SEED: u64 = FNV_OFFSET ^ 0x9e37_79b9_7f4a_7c15;

/// Absorb one prompt token (id, modality bit, patch row) into the
/// fingerprint stream. Token-interleaved so a prefix of the stream is a
/// fingerprint of a prompt prefix — which is what lets
/// [`PrefixProbe::of`] compute the whole-prompt and prefix fingerprints
/// in ONE pass over the (patch-dominated) prompt data.
#[inline]
fn fp_absorb(mut h: u64, req: &Request, i: usize, pd: usize) -> u64 {
    h = fnv(h, &req.ids[i].to_le_bytes());
    h = fnv(h, &[u8::from(req.is_vision[i])]);
    for &f in &req.patches[i * pd..(i + 1) * pd] {
        h = fnv(h, &f.to_bits().to_le_bytes());
    }
    h
}

#[inline]
fn patch_dim_of(req: &Request) -> usize {
    let n = req.ids.len();
    if n == 0 {
        0
    } else {
        req.patches.len() / n
    }
}

/// Independently-seeded whole-prompt content hash (ids, modality mask,
/// patch bits). Stored per entry and compared at lookup so a radix-key
/// collision between two different prompts cannot silently serve the
/// wrong cached KV.
pub fn request_fingerprint(req: &Request) -> u64 {
    let pd = patch_dim_of(req);
    let mut h = FP_SEED;
    for i in 0..req.ids.len() {
        h = fp_absorb(h, req, i, pd);
    }
    h
}

/// The fingerprint stream snapshotted at `prefix_tokens`, with the
/// boundary mixed in. The verification hash of *prefix* entries: a warm
/// partial admission must prove its own first `prefix_tokens` tokens
/// are byte-identical to what the entry caches, not merely
/// radix-key-equal.
pub fn prefix_fingerprint(req: &Request, prefix_tokens: usize) -> u64 {
    let pd = patch_dim_of(req);
    let p = prefix_tokens.min(req.ids.len());
    let mut h = FP_SEED;
    for i in 0..p {
        h = fp_absorb(h, req, i, pd);
    }
    fnv(h, &(p as u64).to_le_bytes())
}

/// A reusable-prefix boundary of a prompt: a token position where a
/// prefix entry can be snapshotted and later adopted. Everything after a
/// boundary must be text-only — the suffix recompute (decode or chunked
/// extend executables) can only embed text tokens.
///
/// Today there is exactly one boundary kind: one past the *last* vision
/// segment, where the prefill graph emits the prefix-restricted DAP
/// statistics (`dap_psum`/`dap_pmax`). The boundary discovery is
/// factored here so the planned deeper *text* boundaries (caching shared
/// dialog history, which needs a psum snapshot per boundary) extend
/// [`reusable_boundaries`] instead of re-deriving positions at every
/// call site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixBoundary {
    /// prompt tokens in the reusable prefix (the boundary position)
    pub tokens: usize,
    /// key symbols covering those tokens ([`prefix_symbols`])
    pub syms: usize,
}

/// Every reusable-prefix boundary of a prompt, shallow→deep. Currently
/// at most one (the last-vision-segment boundary); empty when the
/// prompt has no vision (a pure-text prefix is not worth pinning arena
/// pages for) or no text suffix after the last vision token (an empty
/// suffix is the exact-hit case).
pub fn reusable_boundaries(req: &Request) -> Vec<PrefixBoundary> {
    let Some(last_vis) = req.is_vision.iter().rposition(|&v| v) else {
        return Vec::new();
    };
    let p = last_vis + 1;
    if p >= req.ids.len() {
        return Vec::new();
    }
    vec![PrefixBoundary { tokens: p, syms: prefix_symbols(req, p) }]
}

/// Token boundary of the deepest reusable prefix (see
/// [`reusable_boundaries`]); the depth partial lookups probe at.
pub fn partial_boundary(req: &Request) -> Option<usize> {
    reusable_boundaries(req).last().map(|b| b.tokens)
}

/// Key symbols covering the first `prefix_tokens` prompt tokens — the
/// truncation depth of [`request_key`] at a segment boundary
/// ([`partial_boundary`] always is one: it sits one past a vision run).
pub fn prefix_symbols(req: &Request, prefix_tokens: usize) -> usize {
    let mut syms = 0usize;
    let mut i = 0usize;
    while i < prefix_tokens {
        if req.is_vision[i] {
            while i < prefix_tokens && req.is_vision[i] {
                i += 1;
            }
        } else {
            i += 1;
        }
        syms += 1;
    }
    syms
}

/// Everything the engine and scheduler need to consult the cache for one
/// request, hashed once: the full-prompt radix key + fingerprint (exact
/// hits) and, when the prompt has a reusable visual prefix, the
/// partial-hit probe for it.
pub struct PrefixProbe {
    pub key: Vec<KeySym>,
    pub fingerprint: u64,
    pub partial: Option<PartialProbe>,
}

/// Partial-hit probe: the request's own last-vision-segment boundary.
/// This is the only depth a stored prefix entry can match for this
/// request — prefix entries are registered at donor last-vision
/// boundaries (their keys end with a vision symbol), and any shallower
/// stored boundary would leave vision tokens in the suffix, which the
/// decode-path recompute cannot embed.
pub struct PartialProbe {
    /// prompt tokens in the reusable prefix
    pub prefix_tokens: usize,
    /// key symbols covering those tokens
    pub prefix_syms: usize,
    /// independent content hash of the prefix alone
    pub prefix_fp: u64,
}

impl PrefixProbe {
    pub fn of(req: &Request) -> PrefixProbe {
        let key = request_key(req);
        let boundaries = reusable_boundaries(req);
        // one pass over the (patch-dominated) prompt data computes the
        // whole-prompt fingerprint AND a snapshot at every reusable
        // boundary (today at most one; deeper text boundaries will
        // snapshot here too) — the stream is token-interleaved exactly
        // so these prefixes are prefix fingerprints
        let pd = patch_dim_of(req);
        let mut h = FP_SEED;
        let mut snaps: Vec<u64> = Vec::with_capacity(boundaries.len());
        let mut next = boundaries.iter();
        let mut pending = next.next();
        for i in 0..req.ids.len() {
            h = fp_absorb(h, req, i, pd);
            if pending.is_some_and(|b| b.tokens == i + 1) {
                snaps.push(fnv(h, &((i + 1) as u64).to_le_bytes()));
                pending = next.next();
            }
        }
        debug_assert_eq!(snaps.len(), boundaries.len(), "boundaries lie in the prompt");
        let partial = boundaries.last().zip(snaps.last()).map(|(b, &fp)| PartialProbe {
            prefix_tokens: b.tokens,
            prefix_syms: b.syms,
            prefix_fp: fp,
        });
        PrefixProbe { key, fingerprint: h, partial }
    }
}

/// What an entry caches — the two reuse granularities.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EntryKind {
    /// Whole-prompt entry (PR 3): post-DAP retained pages, slot metadata
    /// and last-position prefill logits. A hit replays the cold run's
    /// own outputs — prefill AND the pruning decision are skipped.
    Exact,
    /// Prefix entry at a last-vision-segment boundary: the *unpruned*
    /// prefix KV in cache-owned pages, with the prefix-row DAP
    /// contributions in the slot metadata's score fields (`cum_score` /
    /// `last_score` = Eq. 1 column mass from prefix text rows,
    /// `cum_peak` = Eq. 3 column max). A partial hit adopts the pages
    /// copy-on-write, recomputes only the text suffix through the
    /// decode executables, and re-runs the retention decision with the
    /// request's OWN statistics (cached prefix rows + its suffix rows) —
    /// the donor's pruning decision is never replayed.
    Prefix,
}

/// One cached prefix: pinned pages + everything needed to reconstruct
/// the post-prefill request state without running prefill.
struct PrefixEntry {
    kind: EntryKind,
    key: Vec<KeySym>,
    /// verification hash: `request_fingerprint` for exact entries,
    /// `prefix_fingerprint` for prefix entries
    fingerprint: u64,
    /// arena pages holding the cached KV (one cache reference each)
    pages: Vec<u32>,
    /// slot metadata — see [`EntryKind`] for what the score fields carry
    meta: Vec<SlotMeta>,
    /// prompt tokens this entry replaces (== prefill tokens skipped/hit)
    prompt_len: usize,
    /// prefill logits at the last prompt position (first-token sampling;
    /// empty for prefix entries — the last suffix decode step supplies
    /// the warm first token instead)
    logits: Vec<f32>,
    last_used: u64,
}

/// Owned snapshot an exact hit hands the engine (no borrows into the
/// cache).
pub struct PrefixHit {
    pub pages: Vec<u32>,
    pub meta: Vec<SlotMeta>,
    pub prompt_len: usize,
    pub logits: Vec<f32>,
}

/// Owned snapshot a *partial* hit hands the engine: the unpruned prefix
/// pages to adopt copy-on-write, per-slot metadata (positions are the
/// identity 0..prefix_len, modality real, score fields = the cached
/// prefix-row DAP contributions), and the prefix token count.
pub struct PartialPrefixHit {
    pub pages: Vec<u32>,
    pub meta: Vec<SlotMeta>,
    pub prefix_len: usize,
}

/// Cache observability — surfaced through `{"kind":"stats"}`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixStats {
    /// exact whole-prompt hits (prefill AND the DAP decision skipped)
    pub hits: u64,
    /// partial-prefix hits (prefix prefill skipped; suffix recomputed
    /// and the retention decision re-run for the request)
    pub partial_hits: u64,
    pub misses: u64,
    pub entries: usize,
    /// arena pages currently pinned by cache entries
    pub pinned_pages: usize,
    pub lru_evictions: u64,
    pub insertions: u64,
    /// prompt tokens never recomputed thanks to warm hits (exact hits
    /// contribute the whole prompt, partial hits the shared prefix)
    pub prefill_tokens_skipped: u64,
    /// pages deduplicated at registration: the entry recorded an
    /// already-pinned bit-identical page instead of pinning its own copy
    /// (the duplicate frees with its registering slab)
    pub dedup_pages: u64,
}

pub struct PrefixCache {
    tree: RadixTree<usize>,
    entries: Vec<Option<PrefixEntry>>,
    free_ids: Vec<usize>,
    max_entries: usize,
    tick: u64,
    hits: u64,
    partial_hits: u64,
    misses: u64,
    lru_evictions: u64,
    insertions: u64,
    skipped_tokens: u64,
    dedup_pages: u64,
}

impl PrefixCache {
    pub fn new(max_entries: usize) -> Self {
        PrefixCache {
            tree: RadixTree::new(),
            entries: Vec::new(),
            free_ids: Vec::new(),
            max_entries: max_entries.max(1),
            tick: 0,
            hits: 0,
            partial_hits: 0,
            misses: 0,
            lru_evictions: 0,
            insertions: 0,
            skipped_tokens: 0,
            dedup_pages: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.tree.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tree.is_empty()
    }

    /// Distinct arena pages currently pinned by entries. Entries can
    /// overlap: a partial warm start registers its whole prompt as an
    /// exact entry whose still-shared prefix pages are the prefix
    /// entry's own, so the count dedups.
    pub fn pinned_pages(&self) -> usize {
        self.entries
            .iter()
            .flatten()
            .flat_map(|e| e.pages.iter())
            .collect::<std::collections::BTreeSet<_>>()
            .len()
    }

    /// Ids of every pinned page, possibly repeated across overlapping
    /// entries (the scheduler inserts them into a set together with the
    /// live lanes' shared pages for charged-once accounting).
    pub fn pinned_page_ids(&self) -> Vec<u32> {
        self.entries
            .iter()
            .flatten()
            .flat_map(|e| e.pages.iter().copied())
            .collect()
    }

    /// How many cache entries pin each pinned page — the reference count
    /// the cache itself accounts for. A page whose pool refcount equals
    /// its pin count is held by the cache alone (no live slab maps it).
    fn pin_counts(&self) -> std::collections::BTreeMap<u32, u32> {
        let mut counts = std::collections::BTreeMap::new();
        for e in self.entries.iter().flatten() {
            for &p in &e.pages {
                *counts.entry(p).or_insert(0) += 1;
            }
        }
        counts
    }

    pub fn stats(&self) -> PrefixStats {
        PrefixStats {
            hits: self.hits,
            partial_hits: self.partial_hits,
            misses: self.misses,
            entries: self.tree.len(),
            pinned_pages: self.pinned_pages(),
            lru_evictions: self.lru_evictions,
            insertions: self.insertions,
            prefill_tokens_skipped: self.skipped_tokens,
            dedup_pages: self.dedup_pages,
        }
    }

    /// Exact-match lookup: the radix key AND the whole-prompt
    /// fingerprint must both match (a key-hash collision is treated as
    /// a miss, never served). A hit refreshes the entry's LRU stamp and
    /// returns an owned snapshot; the caller adopts the pages CoW.
    /// Hit/miss accounting is deliberately separate (`note_hit` /
    /// `note_miss`): the engine only counts a hit once adoption actually
    /// succeeded, so the skipped-token metrics never claim work that was
    /// then recomputed on the fallback path.
    pub fn lookup(&mut self, key: &[KeySym], fingerprint: u64) -> Option<PrefixHit> {
        self.tick += 1;
        let id = match self.tree.get(key) {
            Some(&id) => id,
            None => return None,
        };
        let e = self.entries[id].as_mut().expect("tree points at a live entry");
        if e.kind != EntryKind::Exact || e.fingerprint != fingerprint {
            // a prefix entry stored at this key cannot serve an exact
            // hit: its KV is unpruned and it carries no prefill logits
            return None;
        }
        e.last_used = self.tick;
        Some(PrefixHit {
            pages: e.pages.clone(),
            meta: e.meta.clone(),
            prompt_len: e.prompt_len,
            logits: e.logits.clone(),
        })
    }

    /// Partial-hit lookup: the [`RadixTree::longest_match`] walk (via
    /// `get`) over the key truncated at the request's own
    /// last-vision-segment boundary (`probe`) — the only depth a usable
    /// prefix entry can live at, since a shallower stored boundary would
    /// leave vision tokens in the suffix the decode recompute cannot
    /// embed, and deeper stored values are exact entries for earlier
    /// turns' whole prompts (which must not shadow the boundary — hence
    /// the truncation, not a raw deepest-match). Kind, boundary or
    /// fingerprint mismatches are misses. A hit refreshes the LRU stamp
    /// and returns an owned snapshot; the caller adopts the pages CoW
    /// and recomputes the suffix.
    pub fn lookup_partial(
        &mut self,
        key: &[KeySym],
        probe: &PartialProbe,
    ) -> Option<PartialPrefixHit> {
        self.tick += 1;
        if probe.prefix_syms >= key.len() {
            return None;
        }
        let id = match self.tree.get(&key[..probe.prefix_syms]) {
            Some(&id) => id,
            None => return None,
        };
        let e = self.entries[id].as_mut().expect("tree points at a live entry");
        if e.kind != EntryKind::Prefix
            || e.prompt_len != probe.prefix_tokens
            || e.fingerprint != probe.prefix_fp
        {
            return None;
        }
        e.last_used = self.tick;
        Some(PartialPrefixHit {
            pages: e.pages.clone(),
            meta: e.meta.clone(),
            prefix_len: e.prompt_len,
        })
    }

    /// Count a served warm admission that skipped `prompt_len` prefill
    /// tokens (called after page adoption succeeded).
    pub fn note_hit(&mut self, prompt_len: usize) {
        self.hits += 1;
        self.skipped_tokens += prompt_len as u64;
    }

    /// Count a served *partial* warm admission that skipped
    /// `prefix_len` prefill tokens (called once the warm start actually
    /// stuck — adoption, suffix recompute and the replayed retention
    /// decision all succeeded).
    pub fn note_partial_hit(&mut self, prefix_len: usize) {
        self.partial_hits += 1;
        self.skipped_tokens += prefix_len as u64;
    }

    /// Count a cache-consulting admission that went cold (lookup miss,
    /// or a hit whose adoption was refused).
    pub fn note_miss(&mut self) {
        self.misses += 1;
    }

    /// Drop the entry at exactly `key`, releasing its page references.
    /// Used when adoption of its pages was refused: the pins are broken
    /// (surfaced via `refcount_errors`) and retrying forever would count
    /// phantom hits. Releases of already-dead pages are refused-and-
    /// counted by the pool rather than corrupting it.
    pub fn remove(&mut self, key: &[KeySym], pool: &mut PagePool) -> bool {
        let Some(&id) = self.tree.get(key) else {
            return false;
        };
        self.drop_entry(id, pool);
        true
    }

    /// Shared teardown: unlink from the trie, drop the page references,
    /// recycle the entry slot.
    fn drop_entry(&mut self, id: usize, pool: &mut PagePool) {
        let e = self.entries[id].take().expect("live entry");
        self.tree.remove(&e.key);
        for &p in &e.pages {
            pool.release(p);
        }
        self.free_ids.push(id);
    }

    /// Pages an *exact* hit on `key` would adopt that stay shared under
    /// decode appends (the admission discount). Read-only: no counters,
    /// no LRU. Partial hits carry no discount: their replayed retention
    /// decision may fork any adopted page, so admission charges them
    /// their full worst case (the fork allowance — see
    /// scheduler/admission.rs).
    pub fn peek_discount(&self, key: &[KeySym], fingerprint: u64, page_slots: usize) -> usize {
        match self.tree.get(key) {
            Some(&id) => {
                let e = self.entries[id].as_ref().expect("live entry");
                if e.kind != EntryKind::Exact || e.fingerprint != fingerprint {
                    return 0;
                }
                cow::stable_shared_pages(e.meta.len(), page_slots)
            }
            None => 0,
        }
    }

    /// Register a cold prefill's retained pages under `key`. `pages` are
    /// the registering slab's (already marked shared by the caller); the
    /// cache retains each. Returns false without side effects when the
    /// key is already present (refreshes its LRU stamp instead) or a
    /// retain is refused.
    #[allow(clippy::too_many_arguments)]
    pub fn register(
        &mut self,
        pool: &mut PagePool,
        key: Vec<KeySym>,
        fingerprint: u64,
        pages: Vec<u32>,
        meta: Vec<SlotMeta>,
        prompt_len: usize,
        logits: Vec<f32>,
    ) -> bool {
        self.register_kind(
            pool,
            EntryKind::Exact,
            key,
            fingerprint,
            pages,
            meta,
            prompt_len,
            logits,
        )
    }

    /// Register the *unpruned* prefix of a cold prefill as a partial
    /// warm-start donor. `key` is the radix key truncated at the
    /// last-vision-segment boundary, `fingerprint` the
    /// [`prefix_fingerprint`] over those tokens, `meta` the identity
    /// slot metadata carrying the prefix-row DAP contributions in its
    /// score fields (see [`EntryKind::Prefix`]). `pages` are freshly
    /// cache-filled copies (the caller wrote the unpruned prefix KV into
    /// them); the cache retains each, so the caller must release its own
    /// allocation references afterwards.
    pub fn register_prefix(
        &mut self,
        pool: &mut PagePool,
        key: Vec<KeySym>,
        fingerprint: u64,
        pages: Vec<u32>,
        meta: Vec<SlotMeta>,
        prefix_len: usize,
    ) -> bool {
        self.register_kind(
            pool,
            EntryKind::Prefix,
            key,
            fingerprint,
            pages,
            meta,
            prefix_len,
            Vec::new(),
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn register_kind(
        &mut self,
        pool: &mut PagePool,
        kind: EntryKind,
        key: Vec<KeySym>,
        fingerprint: u64,
        pages: Vec<u32>,
        meta: Vec<SlotMeta>,
        prompt_len: usize,
        logits: Vec<f32>,
    ) -> bool {
        self.tick += 1;
        if let Some(&id) = self.tree.get(&key) {
            // first registration wins — a prefix entry and a degenerate
            // whole-prompt entry at the same key are not merged
            self.entries[id].as_mut().expect("live entry").last_used = self.tick;
            return false;
        }
        if self.tree.len() >= self.max_entries && !self.evict_lru(pool) {
            return false;
        }
        // cross-entry page dedup: the same image reaching the cache under
        // a different whole-prompt key (new question, shuffled text) would
        // otherwise pin a second bit-identical copy of its vision pages
        let mut pages = pages;
        let deduped = self.dedup_incoming(pool, &key, &mut pages);
        if !pool.retain_all(&pages) {
            return false;
        }
        self.dedup_pages += deduped;
        let entry = PrefixEntry {
            kind,
            key: key.clone(),
            fingerprint,
            pages,
            meta,
            prompt_len,
            logits,
            last_used: self.tick,
        };
        let id = match self.free_ids.pop() {
            Some(id) => {
                self.entries[id] = Some(entry);
                id
            }
            None => {
                self.entries.push(Some(entry));
                self.entries.len() - 1
            }
        };
        self.tree.insert(&key, id);
        self.insertions += 1;
        true
    }

    /// Cross-entry page dedup at registration: rewrite each incoming
    /// page to an existing entry's bit-identical page where one exists,
    /// so the new entry pins the cached copy and the duplicate frees
    /// with its registering slab (or immediately, for the cache-filled
    /// pages of a prefix registration). Returns the pages swapped; the
    /// caller folds that into the stats counter only once the
    /// registration actually sticks.
    ///
    /// Candidates are restricted to entries sharing a vision-segment
    /// content hash with the incoming key: content-hashed image segments
    /// are the realistic source of cross-key duplicates (MINE-style
    /// cross-request image reuse), and the restriction bounds the
    /// full-page compares to entries already known to carry the same
    /// image. Comparison is [`PagePool::pages_equal`] — whole-page
    /// bit equality, never hash-only — so a hash collision can waste a
    /// compare but can never alias different KV.
    fn dedup_incoming(&self, pool: &PagePool, key: &[KeySym], pages: &mut [u32]) -> u64 {
        let vision: std::collections::BTreeSet<u64> = key
            .iter()
            .filter_map(|s| match s {
                KeySym::Vision(h) => Some(*h),
                _ => None,
            })
            .collect();
        if vision.is_empty() {
            return 0;
        }
        let candidates: Vec<u32> = self
            .entries
            .iter()
            .flatten()
            .filter(|e| {
                e.key
                    .iter()
                    .any(|s| matches!(s, KeySym::Vision(h) if vision.contains(h)))
            })
            .flat_map(|e| e.pages.iter().copied())
            .collect();
        if candidates.is_empty() {
            return 0;
        }
        let mut swapped = 0;
        for p in pages.iter_mut() {
            // a page already pinned by a candidate entry is the shared
            // copy itself (overlapping entries from a partial warm start)
            if candidates.contains(p) {
                continue;
            }
            if let Some(&q) = candidates.iter().find(|&&q| pool.pages_equal(q, *p)) {
                *p = q;
                swapped += 1;
            }
        }
        swapped
    }

    /// Evict the least-recently-used entry, dropping its page references
    /// (pages free only once no live slab maps them). False when empty.
    pub fn evict_lru(&mut self, pool: &mut PagePool) -> bool {
        let victim = self
            .entries
            .iter()
            .enumerate()
            .filter_map(|(i, e)| e.as_ref().map(|e| (e.last_used, i)))
            .min()
            .map(|(_, i)| i);
        let Some(id) = victim else {
            return false;
        };
        self.drop_entry(id, pool);
        self.lru_evictions += 1;
        true
    }

    /// Is this entry's eviction pure win right now? Only when every page
    /// is held by cache entries alone — pool refcount equal to the
    /// cache's own pin count (1 for an unshared entry; 2 where an exact
    /// entry from a partial warm start overlaps the prefix entry).
    /// Evicting all such entries frees the pages. An entry with even one
    /// page still mapped by a live lane is hot — its stable pages are
    /// serving warm state, and a forked-off tail is still needed by the
    /// next adopter — so it is never sacrificed under pressure.
    fn reclaimable(
        e: &PrefixEntry,
        pool: &PagePool,
        pins: &std::collections::BTreeMap<u32, u32>,
    ) -> bool {
        e.pages
            .iter()
            .all(|&p| pool.refcount(p) == *pins.get(&p).unwrap_or(&0))
    }

    /// Evict the least-recently-used *reclaimable* entry (see
    /// [`Self::reclaimable`]). False when none qualifies.
    pub fn evict_lru_reclaimable(&mut self, pool: &mut PagePool) -> bool {
        let pins = self.pin_counts();
        let victim = self
            .entries
            .iter()
            .enumerate()
            .filter_map(|(i, e)| e.as_ref().map(|e| (e, i)))
            .filter(|(e, _)| Self::reclaimable(e, pool, &pins))
            .map(|(e, i)| (e.last_used, i))
            .min()
            .map(|(_, i)| i);
        let Some(id) = victim else {
            return false;
        };
        self.drop_entry(id, pool);
        self.lru_evictions += 1;
        true
    }

    /// Distinct pages that evicting reclaimable entries could free right
    /// now — the exact amount the admission loops can recover without
    /// touching entries live lanes keep alive. They use it to avoid
    /// flushing the cache for a candidate that cannot be admitted anyway.
    pub fn reclaimable_pages(&self, pool: &PagePool) -> usize {
        let pins = self.pin_counts();
        self.entries
            .iter()
            .flatten()
            .filter(|e| Self::reclaimable(e, pool, &pins))
            .flat_map(|e| e.pages.iter())
            .collect::<std::collections::BTreeSet<_>>()
            .len()
    }

    /// Pool-pressure hook: evict reclaimable LRU entries until at least
    /// `need_free` pages are free or none are reclaimable. Returns
    /// entries evicted. Entries pinned alive by lanes stay — their pages
    /// would not free anyway.
    pub fn reclaim(&mut self, pool: &mut PagePool, need_free: usize) -> usize {
        let mut evicted = 0;
        while pool.free_pages() < need_free && self.evict_lru_reclaimable(pool) {
            evicted += 1;
        }
        evicted
    }

    /// Drop every entry (engine shutdown / tests).
    pub fn clear(&mut self, pool: &mut PagePool) {
        while self.evict_lru(pool) {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::slab::Modality;
    use crate::workload::WorkloadKind;

    fn meta_of(n: usize) -> Vec<SlotMeta> {
        (0..n)
            .map(|i| SlotMeta {
                position: i as i32,
                modality: Modality::Text,
                cum_score: 0.1,
                cum_peak: 0.1,
                last_score: 0.1,
                marked: false,
                age: 0,
            })
            .collect()
    }

    fn pool() -> PagePool {
        PagePool::new(2, 4, 16, 4)
    }

    /// Arbitrary whole-prompt fingerprint used across the cache tests.
    const FP: u64 = 0xAB;

    fn req(ids: Vec<i32>, is_vision: Vec<bool>, patches: Vec<f32>) -> Request {
        Request {
            id: 0,
            kind: WorkloadKind::Understanding,
            ids,
            patches,
            is_vision,
            max_new_tokens: 4,
            min_new_tokens: 0,
            expected_answer: None,
            images: Vec::new(),
        }
    }

    #[test]
    fn key_collapses_vision_segments() {
        // [text 1][vision ×2][text 5] with 2 patch dims per token
        let r = req(
            vec![1, 9, 9, 5],
            vec![false, true, true, false],
            vec![0.0; 8],
        );
        let k = request_key(&r);
        assert_eq!(k.len(), 3);
        assert_eq!(k[0], KeySym::Text(1));
        assert!(matches!(k[1], KeySym::Vision(_)));
        assert_eq!(k[2], KeySym::Text(5));
    }

    #[test]
    fn key_is_content_sensitive() {
        let a = req(vec![9, 9], vec![true, true], vec![1.0, 2.0, 3.0, 4.0]);
        let mut b = req(vec![9, 9], vec![true, true], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(request_key(&a), request_key(&b));
        // one patch float differs → different image symbol
        b.patches[3] = 4.5;
        assert_ne!(request_key(&a), request_key(&b));
        // generation params don't affect the key (prefill is independent)
        let mut c = req(vec![9, 9], vec![true, true], vec![1.0, 2.0, 3.0, 4.0]);
        c.max_new_tokens = 99;
        assert_eq!(request_key(&a), request_key(&c));
        // the verification fingerprint tracks the same content
        assert_ne!(request_fingerprint(&a), request_fingerprint(&b));
        assert_eq!(request_fingerprint(&a), request_fingerprint(&c));
    }

    #[test]
    fn register_pins_and_hit_returns_snapshot() {
        let mut p = pool();
        let pages = vec![p.alloc().unwrap(), p.alloc().unwrap()];
        let mut c = PrefixCache::new(8);
        let key = vec![KeySym::Text(1), KeySym::Vision(7)];
        assert!(c.register(
            &mut p,
            key.clone(),
            FP,
            pages.clone(),
            meta_of(8),
            10,
            vec![0.5; 4],
        ));
        assert_eq!(p.refcount(pages[0]), 2, "cache holds a reference");
        assert_eq!(c.pinned_pages(), 2);
        let hit = c.lookup(&key, FP).expect("exact hit");
        c.note_hit(hit.prompt_len);
        assert_eq!(hit.pages, pages);
        assert_eq!(hit.meta.len(), 8);
        assert_eq!(hit.prompt_len, 10);
        assert_eq!(hit.logits, vec![0.5; 4]);
        assert!(c.lookup(&[KeySym::Text(1)], FP).is_none(), "prefix is not exact");
        c.note_miss();
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert_eq!(s.prefill_tokens_skipped, 10);
    }

    #[test]
    fn fingerprint_mismatch_is_a_miss_not_a_wrong_hit() {
        // a radix-key hash collision between two different prompts must
        // never serve the wrong cached KV: the whole-prompt fingerprint
        // is checked at lookup (and peek) and a mismatch is a miss
        let mut p = pool();
        let pg = p.alloc().unwrap();
        let mut c = PrefixCache::new(8);
        let key = vec![KeySym::Vision(42)];
        assert!(c.register(&mut p, key.clone(), FP, vec![pg], meta_of(3), 5, vec![]));
        assert!(c.lookup(&key, FP).is_some());
        assert!(c.lookup(&key, FP ^ 1).is_none(), "colliding key refused");
        assert_eq!(c.peek_discount(&key, FP ^ 1, 4), 0);
        assert_eq!(c.len(), 1, "the entry itself is untouched");
    }

    #[test]
    fn forked_tail_does_not_make_a_hot_entry_reclaimable() {
        // the common shape mid-batch: an adopter forked the partial tail
        // (cache is its sole holder, refcount 1) while the stable pages
        // still serve live lanes (refcount 2). The entry is HOT — the
        // tail is still needed by the next adopter — so pressure reclaim
        // must not sacrifice it for one page
        let mut p = pool();
        let stable = p.alloc().unwrap(); // "lane" keeps its reference
        let tail = p.alloc().unwrap();
        let mut c = PrefixCache::new(8);
        assert!(c.register(
            &mut p,
            vec![KeySym::Vision(1)],
            FP,
            vec![stable, tail],
            meta_of(6),
            8,
            vec![],
        ));
        p.release(tail); // adopters forked it: cache-only now
        assert_eq!(p.refcount(stable), 2);
        assert_eq!(p.refcount(tail), 1);
        assert_eq!(c.reclaimable_pages(&p), 0, "hot entry is not reclaimable");
        assert!(!c.evict_lru_reclaimable(&mut p));
        assert_eq!(c.reclaim(&mut p, 100), 0, "pressure leaves the hot entry");
        // once the last lane retires, the whole entry reclaims at once
        p.release(stable);
        assert_eq!(c.reclaimable_pages(&p), 2);
        assert!(c.evict_lru_reclaimable(&mut p));
        assert!(c.is_empty());
    }

    #[test]
    fn partial_boundary_and_prefix_symbols() {
        // [BOS][vision ×2][q]: boundary one past the vision run
        let r = req(
            vec![1, 9, 9, 8],
            vec![false, true, true, false],
            vec![0.0; 8],
        );
        assert_eq!(partial_boundary(&r), Some(3));
        assert_eq!(prefix_symbols(&r, 3), 2, "[BOS][img-hash]");
        assert_eq!(request_key(&r).len(), 3);
        // the factored boundary metadata carries position + key depth
        assert_eq!(
            reusable_boundaries(&r),
            vec![PrefixBoundary { tokens: 3, syms: 2 }]
        );
        // no vision → no partial boundary
        let t = req(vec![1, 5], vec![false, false], vec![0.0; 4]);
        assert_eq!(partial_boundary(&t), None);
        // vision at the very end → empty suffix → no boundary
        let v = req(vec![1, 9], vec![false, true], vec![0.0; 4]);
        assert_eq!(partial_boundary(&v), None);
        // the prefix fingerprint tracks prefix content only
        let mut r2 = r.clone();
        r2.ids[3] = 9; // different question token, same prefix
        r2.is_vision[3] = false;
        assert_eq!(prefix_fingerprint(&r, 3), prefix_fingerprint(&r2, 3));
        assert_ne!(request_fingerprint(&r), request_fingerprint(&r2));
        let mut r3 = r.clone();
        r3.patches[4] = 7.0; // a prefix patch bit differs
        assert_ne!(prefix_fingerprint(&r, 3), prefix_fingerprint(&r3, 3));
    }

    #[test]
    fn probe_single_pass_matches_standalone_fingerprints() {
        // registration and the scheduler's queue probe hash through the
        // standalone functions; admission lookups hash through the
        // probe's single pass — the streams must agree bit-for-bit
        let r = req(
            vec![1, 9, 9, 8, 5],
            vec![false, true, true, false, false],
            vec![0.5; 10],
        );
        let probe = PrefixProbe::of(&r);
        assert_eq!(probe.fingerprint, request_fingerprint(&r));
        let pp = probe.partial.expect("vision + text suffix → boundary");
        assert_eq!(pp.prefix_tokens, 3);
        assert_eq!(pp.prefix_syms, 2);
        assert_eq!(pp.prefix_fp, prefix_fingerprint(&r, 3));
        // no-vision prompts probe without a partial half
        let t = req(vec![1, 5], vec![false, false], vec![0.0; 4]);
        let probe = PrefixProbe::of(&t);
        assert!(probe.partial.is_none());
        assert_eq!(probe.fingerprint, request_fingerprint(&t));
    }

    #[test]
    fn partial_lookup_matches_prefix_entries_only() {
        let mut p = pool();
        let mut c = PrefixCache::new(8);
        // donor prompt [BOS][img][q1]: prefix entry at [BOS][img]
        let pre_key = vec![KeySym::Text(1), KeySym::Vision(7)];
        let pg = p.alloc().unwrap();
        assert!(c.register_prefix(&mut p, pre_key.clone(), 0xF1, vec![pg], meta_of(3), 3));
        p.release(pg); // caller's allocation reference → cache-owned
        assert_eq!(p.refcount(pg), 1);
        // a second question about the same image probes at the boundary
        let key_b = vec![KeySym::Text(1), KeySym::Vision(7), KeySym::Text(99)];
        let probe = PartialProbe { prefix_tokens: 3, prefix_syms: 2, prefix_fp: 0xF1 };
        let hit = c.lookup_partial(&key_b, &probe).expect("partial hit");
        assert_eq!(hit.prefix_len, 3);
        assert_eq!(hit.pages, vec![pg]);
        assert_eq!(hit.meta.len(), 3);
        c.note_partial_hit(hit.prefix_len);
        // fingerprint mismatch is a miss, never a wrong adoption
        let bad = PartialProbe { prefix_tokens: 3, prefix_syms: 2, prefix_fp: 0xF2 };
        assert!(c.lookup_partial(&key_b, &bad).is_none());
        // boundary mismatch (entry registered at a different token count)
        let off = PartialProbe { prefix_tokens: 4, prefix_syms: 2, prefix_fp: 0xF1 };
        assert!(c.lookup_partial(&key_b, &off).is_none());
        // an EXACT entry whose prompt is our prefix must not serve a
        // partial hit (its KV is pruned), and a prefix entry must not
        // serve an exact lookup (no logits)
        assert!(c.lookup(&pre_key, 0xF1).is_none(), "prefix entry ≠ exact hit");
        let s = c.stats();
        assert_eq!(s.partial_hits, 1);
        assert_eq!(s.prefill_tokens_skipped, 3);
        assert_eq!(s.hits, 0);
    }

    #[test]
    fn exact_entry_for_an_earlier_turn_does_not_shadow_the_prefix_entry() {
        // multi-turn dialogs: turn 1's WHOLE prompt is a proper prefix of
        // turn 2's key, and deeper than the vision boundary. The partial
        // lookup must still find the prefix entry at the boundary.
        let mut p = pool();
        let mut c = PrefixCache::new(8);
        let pre_key = vec![KeySym::Text(1), KeySym::Vision(7)];
        let turn1_key =
            vec![KeySym::Text(1), KeySym::Vision(7), KeySym::Text(8)];
        let turn2_key = vec![
            KeySym::Text(1),
            KeySym::Vision(7),
            KeySym::Text(8),
            KeySym::Text(20),
            KeySym::Text(9),
        ];
        let pg_pre = p.alloc().unwrap();
        assert!(c.register_prefix(&mut p, pre_key, 0xAA, vec![pg_pre], meta_of(3), 3));
        p.release(pg_pre);
        let pg_exact = p.alloc().unwrap();
        assert!(c.register(&mut p, turn1_key, 0xBB, vec![pg_exact], meta_of(3), 4, vec![]));
        let probe = PartialProbe { prefix_tokens: 3, prefix_syms: 2, prefix_fp: 0xAA };
        let hit = c.lookup_partial(&turn2_key, &probe).expect("boundary entry found");
        assert_eq!(hit.pages, vec![pg_pre]);
    }

    #[test]
    fn prefix_entries_participate_in_lru_and_reclaim() {
        let mut p = pool();
        let mut c = PrefixCache::new(8);
        let pg = p.alloc().unwrap();
        assert!(c.register_prefix(&mut p, vec![KeySym::Vision(5)], 0x1, vec![pg], meta_of(4), 4));
        p.release(pg);
        assert_eq!(c.pinned_pages(), 1);
        assert_eq!(c.reclaimable_pages(&p), 1, "cache-owned prefix page reclaims");
        assert!(c.evict_lru_reclaimable(&mut p));
        assert_eq!(p.refcount(pg), 0, "prefix page freed on eviction");
        assert!(c.is_empty());
    }

    #[test]
    fn remove_drops_entry_and_releases_pins() {
        let mut p = pool();
        let pg = p.alloc().unwrap();
        let mut c = PrefixCache::new(8);
        let key = vec![KeySym::Text(9)];
        assert!(c.register(&mut p, key.clone(), FP, vec![pg], meta_of(2), 2, vec![]));
        assert_eq!(p.refcount(pg), 2);
        assert!(c.remove(&key, &mut p));
        assert!(!c.remove(&key, &mut p), "second remove is a no-op");
        assert!(c.lookup(&key, FP).is_none());
        assert_eq!(p.refcount(pg), 1, "cache reference released");
        assert_eq!(c.pinned_pages(), 0);
    }

    #[test]
    fn reclaim_skips_entries_shared_with_lanes() {
        let mut p = pool();
        // entry A's page is also held by a "lane" (refcount 2);
        // entry B is cache-only (the registering request retired)
        let pa = p.alloc().unwrap();
        let mut c = PrefixCache::new(8);
        assert!(c.register(&mut p, vec![KeySym::Text(0)], FP, vec![pa], meta_of(2), 2, vec![]));
        let pb = p.alloc().unwrap();
        assert!(c.register(&mut p, vec![KeySym::Text(1)], FP, vec![pb], meta_of(2), 2, vec![]));
        p.release(pb);
        // A is older (LRU) but evicting it frees nothing: reclaim must
        // take B and then stop instead of draining the cache
        assert_eq!(c.reclaim(&mut p, 100), 1);
        assert_eq!(c.len(), 1);
        assert!(c.lookup(&[KeySym::Text(0)], FP).is_some(), "lane-shared entry kept");
        assert!(!c.evict_lru_reclaimable(&mut p), "nothing reclaimable left");
        // the unconditional LRU eviction (entry-cap path) still works
        assert!(c.evict_lru(&mut p));
        assert!(c.is_empty());
        assert_eq!(p.refcount(pa), 1, "lane still holds its page");
    }

    #[test]
    fn duplicate_register_refreshes_without_repinning() {
        let mut p = pool();
        let pg = vec![p.alloc().unwrap()];
        let mut c = PrefixCache::new(8);
        let key = vec![KeySym::Text(1)];
        assert!(c.register(&mut p, key.clone(), FP, pg.clone(), meta_of(2), 2, vec![]));
        assert!(!c.register(&mut p, key.clone(), FP, pg.clone(), meta_of(2), 2, vec![]));
        assert_eq!(p.refcount(pg[0]), 2, "still one cache reference");
        assert_eq!(c.stats().insertions, 1);
    }

    #[test]
    fn lru_eviction_releases_pages() {
        let mut p = pool();
        let mut c = PrefixCache::new(2);
        let mut page_of = Vec::new();
        for i in 0..2 {
            let pg = p.alloc().unwrap();
            page_of.push(pg);
            assert!(c.register(
                &mut p,
                vec![KeySym::Text(i)],
                FP,
                vec![pg],
                meta_of(2),
                2,
                vec![],
            ));
            // the registering slab retires: only the cache pins the page
            p.release(pg);
        }
        // touch entry 0 so entry 1 is the LRU victim
        assert!(c.lookup(&[KeySym::Text(0)], FP).is_some());
        let pg2 = p.alloc().unwrap();
        assert!(c.register(&mut p, vec![KeySym::Text(2)], FP, vec![pg2], meta_of(2), 2, vec![]));
        p.release(pg2);
        assert_eq!(c.len(), 2, "cap enforced");
        assert!(c.lookup(&[KeySym::Text(1)], FP).is_none(), "LRU entry evicted");
        assert!(c.lookup(&[KeySym::Text(0)], FP).is_some(), "hot entry kept");
        assert_eq!(p.refcount(page_of[1]), 0, "evicted entry's page freed");
        assert_eq!(c.stats().lru_evictions, 1);
    }

    #[test]
    fn reclaim_frees_pages_under_pressure() {
        let mut p = pool(); // 16 pages
        let mut c = PrefixCache::new(32);
        // 3 entries × 4 pages, all cache-only
        for i in 0..3 {
            let pages: Vec<u32> = (0..4).map(|_| p.alloc().unwrap()).collect();
            assert!(c.register(
                &mut p,
                vec![KeySym::Text(i)],
                FP,
                pages.clone(),
                meta_of(4),
                4,
                vec![],
            ));
            for pg in pages {
                p.release(pg);
            }
        }
        assert_eq!(p.free_pages(), 4);
        // ask for 10 free pages: two LRU entries must go
        let evicted = c.reclaim(&mut p, 10);
        assert_eq!(evicted, 2);
        assert_eq!(p.free_pages(), 12);
        assert_eq!(c.len(), 1);
        // already satisfied: no-op
        assert_eq!(c.reclaim(&mut p, 10), 0);
        // impossible targets drain the cache and stop
        assert_eq!(c.reclaim(&mut p, 1000), 1);
        assert!(c.is_empty());
        assert_eq!(p.free_pages(), 16);
    }

    /// Fill every slot of `page` with a value derived from `seed` (the
    /// pool is 2 layers × row 4 × 4 slots in these tests).
    fn fill_page(p: &mut PagePool, page: u32, seed: f32) {
        for s in 0..p.page_slots() {
            let row = vec![seed + s as f32; p.n_layers() * p.row()];
            p.write_slot(page, s, &row, &row);
        }
    }

    #[test]
    fn register_dedups_identical_vision_pages_across_keys() {
        let mut p = pool();
        let mut c = PrefixCache::new(8);
        // donor A: image hash 7, one page of known content
        let pa = p.alloc().unwrap();
        fill_page(&mut p, pa, 1.0);
        let key_a = vec![KeySym::Vision(7), KeySym::Text(1)];
        assert!(c.register(&mut p, key_a, FP, vec![pa], meta_of(4), 5, vec![]));
        assert_eq!(p.refcount(pa), 2);
        // donor B: same image under a different whole-prompt key, its own
        // bit-identical copy of the page
        let pb = p.alloc().unwrap();
        fill_page(&mut p, pb, 1.0);
        let key_b = vec![KeySym::Vision(7), KeySym::Text(2)];
        assert!(c.register(&mut p, key_b.clone(), FP ^ 1, vec![pb], meta_of(4), 5, vec![]));
        // entry B pins A's page, not its own copy
        let hit = c.lookup(&key_b, FP ^ 1).expect("entry B serves");
        assert_eq!(hit.pages, vec![pa], "dedup swapped in the cached copy");
        assert_eq!(p.refcount(pa), 3, "two cache pins + donor A's slab");
        assert_eq!(p.refcount(pb), 1, "duplicate only held by donor B's slab");
        assert_eq!(c.stats().dedup_pages, 1);
        assert_eq!(c.pinned_pages(), 1, "one physical copy for both entries");
        // donor B retires → the duplicate frees; the shared copy lives on
        p.release(pb);
        assert_eq!(p.refcount(pb), 0);
        assert_eq!(p.stats().refcount_errors, 0);
    }

    #[test]
    fn dedup_requires_bit_identical_content_and_a_shared_vision_key() {
        let mut p = pool();
        let mut c = PrefixCache::new(8);
        let pa = p.alloc().unwrap();
        fill_page(&mut p, pa, 1.0);
        assert!(c.register(
            &mut p,
            vec![KeySym::Vision(7), KeySym::Text(1)],
            FP,
            vec![pa],
            meta_of(4),
            5,
            vec![],
        ));
        // same vision hash, different page bits: no dedup (hash alone is
        // never trusted)
        let pb = p.alloc().unwrap();
        fill_page(&mut p, pb, 2.0);
        assert!(c.register(
            &mut p,
            vec![KeySym::Vision(7), KeySym::Text(2)],
            FP ^ 1,
            vec![pb],
            meta_of(4),
            5,
            vec![],
        ));
        assert_eq!(p.refcount(pb), 2, "distinct content keeps its own pin");
        // identical bits but no shared vision symbol: not a candidate
        let pc = p.alloc().unwrap();
        fill_page(&mut p, pc, 1.0);
        assert!(c.register(
            &mut p,
            vec![KeySym::Vision(9), KeySym::Text(3)],
            FP ^ 2,
            vec![pc],
            meta_of(4),
            5,
            vec![],
        ));
        assert_eq!(p.refcount(pc), 2, "different image hash is never scanned");
        assert_eq!(c.stats().dedup_pages, 0);
        assert_eq!(c.pinned_pages(), 3);
    }

    #[test]
    fn dedup_survives_donor_entry_eviction() {
        // entry B deduped onto entry A's page; evicting A must leave B's
        // pin intact (pins are per-entry references, not shared state)
        let mut p = pool();
        let mut c = PrefixCache::new(8);
        let pa = p.alloc().unwrap();
        fill_page(&mut p, pa, 3.0);
        let key_a = vec![KeySym::Vision(5), KeySym::Text(1)];
        assert!(c.register(&mut p, key_a.clone(), FP, vec![pa], meta_of(4), 5, vec![]));
        let pb = p.alloc().unwrap();
        fill_page(&mut p, pb, 3.0);
        let key_b = vec![KeySym::Vision(5), KeySym::Text(2)];
        assert!(c.register(&mut p, key_b.clone(), FP ^ 1, vec![pb], meta_of(4), 5, vec![]));
        p.release(pb); // donor B's slab retires its duplicate
        assert!(c.remove(&key_a, &mut p), "evict the original entry");
        p.release(pa); // donor A's slab retires too
        assert_eq!(p.refcount(pa), 1, "entry B's dedup pin keeps the page");
        let hit = c.lookup(&key_b, FP ^ 1).expect("entry B still serves");
        assert_eq!(hit.pages, vec![pa]);
        assert_eq!(p.stats().refcount_errors, 0);
    }

    #[test]
    fn peek_discount_counts_stable_pages() {
        let mut p = pool(); // 4-slot pages
        let pages = vec![p.alloc().unwrap(), p.alloc().unwrap()];
        let mut c = PrefixCache::new(8);
        let key = vec![KeySym::Vision(3)];
        // 6 retained slots over two 4-slot pages: partial tail unstable
        assert!(c.register(&mut p, key.clone(), FP, pages, meta_of(6), 8, vec![]));
        assert_eq!(c.peek_discount(&key, FP, 4), 1);
        assert_eq!(c.peek_discount(&[KeySym::Vision(4)], FP, 4), 0, "miss: no discount");
        let s = c.stats();
        assert_eq!(s.hits + s.misses, 0, "peek is invisible to hit metrics");
    }
}
