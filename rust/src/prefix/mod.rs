//! Radix-tree prefix cache with copy-on-write page sharing — the
//! cross-request reuse layer for the dominant multimodal serving
//! pattern: many questions against the same image or video.
//!
//! # What is cached
//!
//! After a cold prefill, the engine registers the request's *retained*
//! KV — the pages left after HAE's Dual-Attention Pruning — under a key
//! built from the prompt: one symbol per leading/trailing text token id,
//! one content-hash symbol per vision segment ([`request_key`]). The
//! entry pins the slab's pages in the shared `PagePool` (`retain_page`)
//! and snapshots the slot metadata (positions = the cached HAE
//! retained-index set, cum-score seeds = the DAP statistics) plus the
//! prefill logits of the last prompt position.
//!
//! # What a hit buys
//!
//! A later request with the same key skips prefill *entirely*: its slab
//! adopts the pinned pages copy-on-write (`KvSlab::adopt_shared`), the
//! cached metadata seeds its scores, and the cached logits produce the
//! first token. Dual-Attention Pruning therefore runs once per distinct
//! image instead of once per request, no prompt position is recomputed,
//! and N concurrent questions hold ONE copy of the visual prefix —
//! which the scheduler charges once against the KV budget
//! (scheduler/admission.rs), turning sharing directly into admission
//! headroom and batch width.
//!
//! Hits are **exact** (whole-prompt) matches: a warm request is
//! byte-identical to its own cold run, because everything the decode
//! trajectory depends on — retained KV, metadata, first-token logits —
//! is the cold run's own output for that exact prompt. Partial-prefix
//! reuse (recompute only the suffix through the decode path) is the
//! natural extension of `RadixTree::longest_match`, but it would replay
//! the donor's DAP decision under a different question and so break
//! cold/warm equivalence; see ROADMAP "Prefix cache (PR 3)".
//!
//! # Lifecycle
//!
//! Entries share pages with *live* slabs: the donor keeps decoding on
//! the pages it registered, and the first write a sharer (donor
//! included) makes inside the shared region forks the page
//! (prefix/cow.rs), so the cached image stays pristine. Unreferenced
//! entries are LRU-evicted when the pool runs short (the engine calls
//! [`PrefixCache::reclaim`] before allocating) or when the entry cap is
//! hit; eviction drops the cache's page references, freeing exactly the
//! pages no live request still maps.

pub mod cow;
pub mod radix;

use crate::cache::paged::PagePool;
use crate::cache::slab::SlotMeta;
use crate::workload::Request;

pub use radix::{KeySym, RadixTree};

/// Default cap on cached entries (LRU beyond this). Entries are cheap on
/// the host (metadata + one logits row) — the real cost is pinned arena
/// pages, which `reclaim` bounds under pool pressure.
pub const DEFAULT_MAX_ENTRIES: usize = 64;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fnv(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Build the trie key of a request's prompt: text tokens symbol-by-symbol,
/// vision segments collapsed to a content hash over their patch features
/// and segment length. The hash is 64-bit FNV-1a, so the key alone is not
/// proof of identity — every entry also stores an independently-seeded
/// [`request_fingerprint`] that a hit must match, making a wrong-prefix
/// hit require a simultaneous collision in two independent 64-bit hashes.
pub fn request_key(req: &Request) -> Vec<KeySym> {
    let n = req.ids.len();
    let pd = if n == 0 { 0 } else { req.patches.len() / n };
    let mut key = Vec::new();
    let mut i = 0;
    while i < n {
        if req.is_vision[i] {
            let start = i;
            let mut h = FNV_OFFSET;
            while i < n && req.is_vision[i] {
                h = fnv(h, &req.ids[i].to_le_bytes());
                for &f in &req.patches[i * pd..(i + 1) * pd] {
                    h = fnv(h, &f.to_bits().to_le_bytes());
                }
                i += 1;
            }
            h = fnv(h, &((i - start) as u64).to_le_bytes());
            key.push(KeySym::Vision(h));
        } else {
            key.push(KeySym::Text(req.ids[i]));
            i += 1;
        }
    }
    key
}

/// Independently-seeded whole-prompt content hash (ids, modality mask,
/// patch bits). Stored per entry and compared at lookup so a radix-key
/// collision between two different prompts cannot silently serve the
/// wrong cached KV.
pub fn request_fingerprint(req: &Request) -> u64 {
    let mut h = FNV_OFFSET ^ 0x9e37_79b9_7f4a_7c15;
    for (i, &id) in req.ids.iter().enumerate() {
        h = fnv(h, &id.to_le_bytes());
        h = fnv(h, &[u8::from(req.is_vision[i])]);
    }
    for &f in &req.patches {
        h = fnv(h, &f.to_bits().to_le_bytes());
    }
    h
}

/// One cached prefix: pinned pages + everything needed to reconstruct
/// the post-prefill request state without running prefill.
struct PrefixEntry {
    key: Vec<KeySym>,
    /// whole-prompt verification hash (`request_fingerprint`)
    fingerprint: u64,
    /// arena pages holding the retained KV (one cache reference each)
    pages: Vec<u32>,
    /// retained-slot metadata: positions are the HAE retained-index set,
    /// scores the DAP seeds
    meta: Vec<SlotMeta>,
    /// prompt tokens this entry replaces (== prefill tokens skipped/hit)
    prompt_len: usize,
    /// prefill logits at the last prompt position (first-token sampling)
    logits: Vec<f32>,
    last_used: u64,
}

/// Owned snapshot a hit hands the engine (no borrows into the cache).
pub struct PrefixHit {
    pub pages: Vec<u32>,
    pub meta: Vec<SlotMeta>,
    pub prompt_len: usize,
    pub logits: Vec<f32>,
}

/// Cache observability — surfaced through `{"kind":"stats"}`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    /// arena pages currently pinned by cache entries
    pub pinned_pages: usize,
    pub lru_evictions: u64,
    pub insertions: u64,
    /// prompt tokens never recomputed thanks to warm hits
    pub prefill_tokens_skipped: u64,
}

pub struct PrefixCache {
    tree: RadixTree<usize>,
    entries: Vec<Option<PrefixEntry>>,
    free_ids: Vec<usize>,
    max_entries: usize,
    tick: u64,
    hits: u64,
    misses: u64,
    lru_evictions: u64,
    insertions: u64,
    skipped_tokens: u64,
}

impl PrefixCache {
    pub fn new(max_entries: usize) -> Self {
        PrefixCache {
            tree: RadixTree::new(),
            entries: Vec::new(),
            free_ids: Vec::new(),
            max_entries: max_entries.max(1),
            tick: 0,
            hits: 0,
            misses: 0,
            lru_evictions: 0,
            insertions: 0,
            skipped_tokens: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.tree.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tree.is_empty()
    }

    /// Arena pages currently pinned by entries. Entries pin the pages of
    /// the slab that registered them, and a key is registered at most
    /// once, so the sets are disjoint and the sum is a distinct count.
    pub fn pinned_pages(&self) -> usize {
        self.entries
            .iter()
            .flatten()
            .map(|e| e.pages.len())
            .sum()
    }

    /// Ids of every pinned page (the scheduler unions these with the
    /// live lanes' shared pages for charged-once accounting).
    pub fn pinned_page_ids(&self) -> Vec<u32> {
        self.entries
            .iter()
            .flatten()
            .flat_map(|e| e.pages.iter().copied())
            .collect()
    }

    pub fn stats(&self) -> PrefixStats {
        PrefixStats {
            hits: self.hits,
            misses: self.misses,
            entries: self.tree.len(),
            pinned_pages: self.pinned_pages(),
            lru_evictions: self.lru_evictions,
            insertions: self.insertions,
            prefill_tokens_skipped: self.skipped_tokens,
        }
    }

    /// Exact-match lookup: the radix key AND the whole-prompt
    /// fingerprint must both match (a key-hash collision is treated as
    /// a miss, never served). A hit refreshes the entry's LRU stamp and
    /// returns an owned snapshot; the caller adopts the pages CoW.
    /// Hit/miss accounting is deliberately separate (`note_hit` /
    /// `note_miss`): the engine only counts a hit once adoption actually
    /// succeeded, so the skipped-token metrics never claim work that was
    /// then recomputed on the fallback path.
    pub fn lookup(&mut self, key: &[KeySym], fingerprint: u64) -> Option<PrefixHit> {
        self.tick += 1;
        let id = match self.tree.get(key) {
            Some(&id) => id,
            None => return None,
        };
        let e = self.entries[id].as_mut().expect("tree points at a live entry");
        if e.fingerprint != fingerprint {
            return None;
        }
        e.last_used = self.tick;
        Some(PrefixHit {
            pages: e.pages.clone(),
            meta: e.meta.clone(),
            prompt_len: e.prompt_len,
            logits: e.logits.clone(),
        })
    }

    /// Count a served warm admission that skipped `prompt_len` prefill
    /// tokens (called after page adoption succeeded).
    pub fn note_hit(&mut self, prompt_len: usize) {
        self.hits += 1;
        self.skipped_tokens += prompt_len as u64;
    }

    /// Count a cache-consulting admission that went cold (lookup miss,
    /// or a hit whose adoption was refused).
    pub fn note_miss(&mut self) {
        self.misses += 1;
    }

    /// Drop the entry at exactly `key`, releasing its page references.
    /// Used when adoption of its pages was refused: the pins are broken
    /// (surfaced via `refcount_errors`) and retrying forever would count
    /// phantom hits. Releases of already-dead pages are refused-and-
    /// counted by the pool rather than corrupting it.
    pub fn remove(&mut self, key: &[KeySym], pool: &mut PagePool) -> bool {
        let Some(&id) = self.tree.get(key) else {
            return false;
        };
        self.drop_entry(id, pool);
        true
    }

    /// Shared teardown: unlink from the trie, drop the page references,
    /// recycle the entry slot.
    fn drop_entry(&mut self, id: usize, pool: &mut PagePool) {
        let e = self.entries[id].take().expect("live entry");
        self.tree.remove(&e.key);
        for &p in &e.pages {
            pool.release(p);
        }
        self.free_ids.push(id);
    }

    /// Pages a hit on `key` would adopt that stay shared under decode
    /// appends (the admission discount). Read-only: no counters, no LRU.
    pub fn peek_discount(&self, key: &[KeySym], fingerprint: u64, page_slots: usize) -> usize {
        match self.tree.get(key) {
            Some(&id) => {
                let e = self.entries[id].as_ref().expect("live entry");
                if e.fingerprint != fingerprint {
                    return 0;
                }
                cow::stable_shared_pages(e.meta.len(), page_slots)
            }
            None => 0,
        }
    }

    /// Register a cold prefill's retained pages under `key`. `pages` are
    /// the registering slab's (already marked shared by the caller); the
    /// cache retains each. Returns false without side effects when the
    /// key is already present (refreshes its LRU stamp instead) or a
    /// retain is refused.
    #[allow(clippy::too_many_arguments)]
    pub fn register(
        &mut self,
        pool: &mut PagePool,
        key: Vec<KeySym>,
        fingerprint: u64,
        pages: Vec<u32>,
        meta: Vec<SlotMeta>,
        prompt_len: usize,
        logits: Vec<f32>,
    ) -> bool {
        self.tick += 1;
        if let Some(&id) = self.tree.get(&key) {
            self.entries[id].as_mut().expect("live entry").last_used = self.tick;
            return false;
        }
        if self.tree.len() >= self.max_entries && !self.evict_lru(pool) {
            return false;
        }
        if !pool.retain_all(&pages) {
            return false;
        }
        let entry = PrefixEntry {
            key: key.clone(),
            fingerprint,
            pages,
            meta,
            prompt_len,
            logits,
            last_used: self.tick,
        };
        let id = match self.free_ids.pop() {
            Some(id) => {
                self.entries[id] = Some(entry);
                id
            }
            None => {
                self.entries.push(Some(entry));
                self.entries.len() - 1
            }
        };
        self.tree.insert(&key, id);
        self.insertions += 1;
        true
    }

    /// Evict the least-recently-used entry, dropping its page references
    /// (pages free only once no live slab maps them). False when empty.
    pub fn evict_lru(&mut self, pool: &mut PagePool) -> bool {
        let victim = self
            .entries
            .iter()
            .enumerate()
            .filter_map(|(i, e)| e.as_ref().map(|e| (e.last_used, i)))
            .min()
            .map(|(_, i)| i);
        let Some(id) = victim else {
            return false;
        };
        self.drop_entry(id, pool);
        self.lru_evictions += 1;
        true
    }

    /// Is this entry's eviction pure win right now? Only when *every*
    /// page is referenced by the cache alone (pool refcount 1): evicting
    /// then frees the whole entry. An entry with even one page still
    /// mapped by a live lane is hot — its stable pages are serving warm
    /// state, and a forked-off tail (refcount 1) is still needed by the
    /// next adopter — so it is never sacrificed under pressure.
    fn reclaimable(e: &PrefixEntry, pool: &PagePool) -> bool {
        e.pages.iter().all(|&p| pool.refcount(p) == 1)
    }

    /// Evict the least-recently-used *reclaimable* entry (see
    /// [`Self::reclaimable`]). False when none qualifies.
    pub fn evict_lru_reclaimable(&mut self, pool: &mut PagePool) -> bool {
        let victim = self
            .entries
            .iter()
            .enumerate()
            .filter_map(|(i, e)| e.as_ref().map(|e| (e, i)))
            .filter(|(e, _)| Self::reclaimable(e, pool))
            .map(|(e, i)| (e.last_used, i))
            .min()
            .map(|(_, i)| i);
        let Some(id) = victim else {
            return false;
        };
        self.drop_entry(id, pool);
        self.lru_evictions += 1;
        true
    }

    /// Pages that evicting reclaimable entries could free right now —
    /// the exact amount the admission loops can recover without touching
    /// entries live lanes keep alive. They use it to avoid flushing the
    /// cache for a candidate that cannot be admitted anyway.
    pub fn reclaimable_pages(&self, pool: &PagePool) -> usize {
        self.entries
            .iter()
            .flatten()
            .filter(|e| Self::reclaimable(e, pool))
            .map(|e| e.pages.len())
            .sum()
    }

    /// Pool-pressure hook: evict reclaimable LRU entries until at least
    /// `need_free` pages are free or none are reclaimable. Returns
    /// entries evicted. Entries pinned alive by lanes stay — their pages
    /// would not free anyway.
    pub fn reclaim(&mut self, pool: &mut PagePool, need_free: usize) -> usize {
        let mut evicted = 0;
        while pool.free_pages() < need_free && self.evict_lru_reclaimable(pool) {
            evicted += 1;
        }
        evicted
    }

    /// Drop every entry (engine shutdown / tests).
    pub fn clear(&mut self, pool: &mut PagePool) {
        while self.evict_lru(pool) {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::slab::Modality;
    use crate::workload::WorkloadKind;

    fn meta_of(n: usize) -> Vec<SlotMeta> {
        (0..n)
            .map(|i| SlotMeta {
                position: i as i32,
                modality: Modality::Text,
                cum_score: 0.1,
                cum_peak: 0.1,
                last_score: 0.1,
                marked: false,
                age: 0,
            })
            .collect()
    }

    fn pool() -> PagePool {
        PagePool::new(2, 4, 16, 4)
    }

    /// Arbitrary whole-prompt fingerprint used across the cache tests.
    const FP: u64 = 0xAB;

    fn req(ids: Vec<i32>, is_vision: Vec<bool>, patches: Vec<f32>) -> Request {
        Request {
            id: 0,
            kind: WorkloadKind::Understanding,
            ids,
            patches,
            is_vision,
            max_new_tokens: 4,
            min_new_tokens: 0,
            expected_answer: None,
            images: Vec::new(),
        }
    }

    #[test]
    fn key_collapses_vision_segments() {
        // [text 1][vision ×2][text 5] with 2 patch dims per token
        let r = req(
            vec![1, 9, 9, 5],
            vec![false, true, true, false],
            vec![0.0; 8],
        );
        let k = request_key(&r);
        assert_eq!(k.len(), 3);
        assert_eq!(k[0], KeySym::Text(1));
        assert!(matches!(k[1], KeySym::Vision(_)));
        assert_eq!(k[2], KeySym::Text(5));
    }

    #[test]
    fn key_is_content_sensitive() {
        let a = req(vec![9, 9], vec![true, true], vec![1.0, 2.0, 3.0, 4.0]);
        let mut b = req(vec![9, 9], vec![true, true], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(request_key(&a), request_key(&b));
        // one patch float differs → different image symbol
        b.patches[3] = 4.5;
        assert_ne!(request_key(&a), request_key(&b));
        // generation params don't affect the key (prefill is independent)
        let mut c = req(vec![9, 9], vec![true, true], vec![1.0, 2.0, 3.0, 4.0]);
        c.max_new_tokens = 99;
        assert_eq!(request_key(&a), request_key(&c));
        // the verification fingerprint tracks the same content
        assert_ne!(request_fingerprint(&a), request_fingerprint(&b));
        assert_eq!(request_fingerprint(&a), request_fingerprint(&c));
    }

    #[test]
    fn register_pins_and_hit_returns_snapshot() {
        let mut p = pool();
        let pages = vec![p.alloc().unwrap(), p.alloc().unwrap()];
        let mut c = PrefixCache::new(8);
        let key = vec![KeySym::Text(1), KeySym::Vision(7)];
        assert!(c.register(
            &mut p,
            key.clone(),
            FP,
            pages.clone(),
            meta_of(8),
            10,
            vec![0.5; 4],
        ));
        assert_eq!(p.refcount(pages[0]), 2, "cache holds a reference");
        assert_eq!(c.pinned_pages(), 2);
        let hit = c.lookup(&key, FP).expect("exact hit");
        c.note_hit(hit.prompt_len);
        assert_eq!(hit.pages, pages);
        assert_eq!(hit.meta.len(), 8);
        assert_eq!(hit.prompt_len, 10);
        assert_eq!(hit.logits, vec![0.5; 4]);
        assert!(c.lookup(&[KeySym::Text(1)], FP).is_none(), "prefix is not exact");
        c.note_miss();
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert_eq!(s.prefill_tokens_skipped, 10);
    }

    #[test]
    fn fingerprint_mismatch_is_a_miss_not_a_wrong_hit() {
        // a radix-key hash collision between two different prompts must
        // never serve the wrong cached KV: the whole-prompt fingerprint
        // is checked at lookup (and peek) and a mismatch is a miss
        let mut p = pool();
        let pg = p.alloc().unwrap();
        let mut c = PrefixCache::new(8);
        let key = vec![KeySym::Vision(42)];
        assert!(c.register(&mut p, key.clone(), FP, vec![pg], meta_of(3), 5, vec![]));
        assert!(c.lookup(&key, FP).is_some());
        assert!(c.lookup(&key, FP ^ 1).is_none(), "colliding key refused");
        assert_eq!(c.peek_discount(&key, FP ^ 1, 4), 0);
        assert_eq!(c.len(), 1, "the entry itself is untouched");
    }

    #[test]
    fn forked_tail_does_not_make_a_hot_entry_reclaimable() {
        // the common shape mid-batch: an adopter forked the partial tail
        // (cache is its sole holder, refcount 1) while the stable pages
        // still serve live lanes (refcount 2). The entry is HOT — the
        // tail is still needed by the next adopter — so pressure reclaim
        // must not sacrifice it for one page
        let mut p = pool();
        let stable = p.alloc().unwrap(); // "lane" keeps its reference
        let tail = p.alloc().unwrap();
        let mut c = PrefixCache::new(8);
        assert!(c.register(
            &mut p,
            vec![KeySym::Vision(1)],
            FP,
            vec![stable, tail],
            meta_of(6),
            8,
            vec![],
        ));
        p.release(tail); // adopters forked it: cache-only now
        assert_eq!(p.refcount(stable), 2);
        assert_eq!(p.refcount(tail), 1);
        assert_eq!(c.reclaimable_pages(&p), 0, "hot entry is not reclaimable");
        assert!(!c.evict_lru_reclaimable(&mut p));
        assert_eq!(c.reclaim(&mut p, 100), 0, "pressure leaves the hot entry");
        // once the last lane retires, the whole entry reclaims at once
        p.release(stable);
        assert_eq!(c.reclaimable_pages(&p), 2);
        assert!(c.evict_lru_reclaimable(&mut p));
        assert!(c.is_empty());
    }

    #[test]
    fn remove_drops_entry_and_releases_pins() {
        let mut p = pool();
        let pg = p.alloc().unwrap();
        let mut c = PrefixCache::new(8);
        let key = vec![KeySym::Text(9)];
        assert!(c.register(&mut p, key.clone(), FP, vec![pg], meta_of(2), 2, vec![]));
        assert_eq!(p.refcount(pg), 2);
        assert!(c.remove(&key, &mut p));
        assert!(!c.remove(&key, &mut p), "second remove is a no-op");
        assert!(c.lookup(&key, FP).is_none());
        assert_eq!(p.refcount(pg), 1, "cache reference released");
        assert_eq!(c.pinned_pages(), 0);
    }

    #[test]
    fn reclaim_skips_entries_shared_with_lanes() {
        let mut p = pool();
        // entry A's page is also held by a "lane" (refcount 2);
        // entry B is cache-only (the registering request retired)
        let pa = p.alloc().unwrap();
        let mut c = PrefixCache::new(8);
        assert!(c.register(&mut p, vec![KeySym::Text(0)], FP, vec![pa], meta_of(2), 2, vec![]));
        let pb = p.alloc().unwrap();
        assert!(c.register(&mut p, vec![KeySym::Text(1)], FP, vec![pb], meta_of(2), 2, vec![]));
        p.release(pb);
        // A is older (LRU) but evicting it frees nothing: reclaim must
        // take B and then stop instead of draining the cache
        assert_eq!(c.reclaim(&mut p, 100), 1);
        assert_eq!(c.len(), 1);
        assert!(c.lookup(&[KeySym::Text(0)], FP).is_some(), "lane-shared entry kept");
        assert!(!c.evict_lru_reclaimable(&mut p), "nothing reclaimable left");
        // the unconditional LRU eviction (entry-cap path) still works
        assert!(c.evict_lru(&mut p));
        assert!(c.is_empty());
        assert_eq!(p.refcount(pa), 1, "lane still holds its page");
    }

    #[test]
    fn duplicate_register_refreshes_without_repinning() {
        let mut p = pool();
        let pg = vec![p.alloc().unwrap()];
        let mut c = PrefixCache::new(8);
        let key = vec![KeySym::Text(1)];
        assert!(c.register(&mut p, key.clone(), FP, pg.clone(), meta_of(2), 2, vec![]));
        assert!(!c.register(&mut p, key.clone(), FP, pg.clone(), meta_of(2), 2, vec![]));
        assert_eq!(p.refcount(pg[0]), 2, "still one cache reference");
        assert_eq!(c.stats().insertions, 1);
    }

    #[test]
    fn lru_eviction_releases_pages() {
        let mut p = pool();
        let mut c = PrefixCache::new(2);
        let mut page_of = Vec::new();
        for i in 0..2 {
            let pg = p.alloc().unwrap();
            page_of.push(pg);
            assert!(c.register(
                &mut p,
                vec![KeySym::Text(i)],
                FP,
                vec![pg],
                meta_of(2),
                2,
                vec![],
            ));
            // the registering slab retires: only the cache pins the page
            p.release(pg);
        }
        // touch entry 0 so entry 1 is the LRU victim
        assert!(c.lookup(&[KeySym::Text(0)], FP).is_some());
        let pg2 = p.alloc().unwrap();
        assert!(c.register(&mut p, vec![KeySym::Text(2)], FP, vec![pg2], meta_of(2), 2, vec![]));
        p.release(pg2);
        assert_eq!(c.len(), 2, "cap enforced");
        assert!(c.lookup(&[KeySym::Text(1)], FP).is_none(), "LRU entry evicted");
        assert!(c.lookup(&[KeySym::Text(0)], FP).is_some(), "hot entry kept");
        assert_eq!(p.refcount(page_of[1]), 0, "evicted entry's page freed");
        assert_eq!(c.stats().lru_evictions, 1);
    }

    #[test]
    fn reclaim_frees_pages_under_pressure() {
        let mut p = pool(); // 16 pages
        let mut c = PrefixCache::new(32);
        // 3 entries × 4 pages, all cache-only
        for i in 0..3 {
            let pages: Vec<u32> = (0..4).map(|_| p.alloc().unwrap()).collect();
            assert!(c.register(
                &mut p,
                vec![KeySym::Text(i)],
                FP,
                pages.clone(),
                meta_of(4),
                4,
                vec![],
            ));
            for pg in pages {
                p.release(pg);
            }
        }
        assert_eq!(p.free_pages(), 4);
        // ask for 10 free pages: two LRU entries must go
        let evicted = c.reclaim(&mut p, 10);
        assert_eq!(evicted, 2);
        assert_eq!(p.free_pages(), 12);
        assert_eq!(c.len(), 1);
        // already satisfied: no-op
        assert_eq!(c.reclaim(&mut p, 10), 0);
        // impossible targets drain the cache and stop
        assert_eq!(c.reclaim(&mut p, 1000), 1);
        assert!(c.is_empty());
        assert_eq!(p.free_pages(), 16);
    }

    #[test]
    fn peek_discount_counts_stable_pages() {
        let mut p = pool(); // 4-slot pages
        let pages = vec![p.alloc().unwrap(), p.alloc().unwrap()];
        let mut c = PrefixCache::new(8);
        let key = vec![KeySym::Vision(3)];
        // 6 retained slots over two 4-slot pages: partial tail unstable
        assert!(c.register(&mut p, key.clone(), FP, pages, meta_of(6), 8, vec![]));
        assert_eq!(c.peek_discount(&key, FP, 4), 1);
        assert_eq!(c.peek_discount(&[KeySym::Vision(4)], FP, 4), 0, "miss: no discount");
        let s = c.stats();
        assert_eq!(s.hits + s.misses, 0, "peek is invisible to hit metrics");
    }
}
