//! Host-side DAP statistic replay for partial warm starts.
//!
//! A partial warm start reconstructs the request's OWN Eq. 1 / Eq. 3
//! column statistics from two sources: the cached prefix text rows'
//! contributions (stored in the prefix entry's slot metadata) and the
//! recomputed suffix rows' dap-layer head-mean probabilities, emitted
//! per row by the decode graph (`DecodeOut::dap_row`) or per chunk by
//! the extend graph (`ExtendOut::dap_rows`).
//!
//! The accumulator makes the one invariant both paths must share
//! explicit: **rows are folded in prompt-position order, one addition
//! per column per row** — so chunked accumulation is bit-identical to
//! per-token accumulation (the order of float additions per column is
//! the row order, regardless of how rows were grouped into device
//! calls), and both match the cold prefill's row order. The runtime-free
//! property test in tests/cache_props.rs pins this; the device-side row
//! values themselves are ULP-equal across executables, which is the
//! engine's documented numerical caveat.

use crate::cache::SlotMeta;

/// Accumulates per-row DAP contributions into column statistics, in
/// strict prompt-position order. `filled` is the position of the next
/// row to fold; each pushed row must cover columns `0..=filled`.
#[derive(Debug, Clone)]
pub struct DapAccumulator {
    colsum: Vec<f32>,
    colmax: Vec<f32>,
    filled: usize,
}

impl DapAccumulator {
    /// Start an accumulation over an `n`-column prompt whose first
    /// `meta.len()` rows (the cached prefix) already contributed: the
    /// entry's score fields carry the prefix text rows' Eq. 1 mass /
    /// Eq. 3 max per column.
    pub fn seeded(meta: &[SlotMeta], n: usize) -> Self {
        let mut colsum = vec![0.0f32; n];
        let mut colmax = vec![0.0f32; n];
        for (j, sm) in meta.iter().enumerate().take(n) {
            colsum[j] = sm.cum_score;
            colmax[j] = sm.cum_peak;
        }
        DapAccumulator { colsum, colmax, filled: meta.len().min(n) }
    }

    /// Fresh accumulation with no cached prefix (tests).
    pub fn new(n: usize) -> Self {
        DapAccumulator { colsum: vec![0.0; n], colmax: vec![0.0; n], filled: 0 }
    }

    /// Position of the next row to fold.
    pub fn filled(&self) -> usize {
        self.filled
    }

    /// Fold one row's contributions. `parts`, concatenated, cover
    /// columns `0..=filled` — the decode path passes
    /// `[&dap_row[..len], &[self_mass]]`, the extend path
    /// `[&cache_cols[..len0], &chunk_cols[..=i]]`; either way each
    /// column receives exactly one addition and rows arrive in position
    /// order, so the per-column float-addition sequence is identical
    /// across chunkings.
    pub fn push_row(&mut self, parts: &[&[f32]]) {
        let mut j = 0usize;
        for part in parts {
            for &x in *part {
                self.colsum[j] += x;
                self.colmax[j] = self.colmax[j].max(x);
                j += 1;
            }
        }
        debug_assert_eq!(
            j,
            self.filled + 1,
            "row must cover columns 0..=its own position"
        );
        self.filled += 1;
    }

    pub fn colsum(&self) -> &[f32] {
        &self.colsum
    }

    pub fn colmax(&self) -> &[f32] {
        &self.colmax
    }

    /// Final statistics (every row folded).
    pub fn into_stats(self) -> (Vec<f32>, Vec<f32>) {
        (self.colsum, self.colmax)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::Modality;

    fn meta_row(score: f32, peak: f32) -> SlotMeta {
        SlotMeta {
            position: 0,
            modality: Modality::Text,
            cum_score: score,
            cum_peak: peak,
            last_score: score,
            marked: false,
            age: 0,
        }
    }

    #[test]
    fn seeds_from_prefix_meta_and_accumulates() {
        let meta = vec![meta_row(0.5, 0.4), meta_row(0.25, 0.25)];
        let mut acc = DapAccumulator::seeded(&meta, 4);
        assert_eq!(acc.filled(), 2);
        // row at position 2: cache part covers columns 0..2, self 2
        acc.push_row(&[&[0.1, 0.2], &[0.3]]);
        acc.push_row(&[&[0.05, 0.05, 0.6], &[0.7]]);
        let (sum, max) = acc.into_stats();
        assert_eq!(sum, vec![0.5 + 0.1 + 0.05, 0.25 + 0.2 + 0.05, 0.3 + 0.6, 0.7]);
        assert_eq!(max, vec![0.4, 0.25, 0.6, 0.7]);
    }

    #[test]
    fn chunked_parts_equal_per_token_parts() {
        // the same four rows, folded as 1+1+1+1 vs 2+2 part splits,
        // produce bit-identical statistics — the invariant the engine's
        // chunk loop relies on
        let rows: Vec<Vec<f32>> = vec![
            vec![0.125],
            vec![0.25, 0.5],
            vec![0.1, 0.2, 0.3],
            vec![0.4, 0.3, 0.2, 0.1],
        ];
        let mut per_token = DapAccumulator::new(4);
        for r in &rows {
            let (cache, selfm) = r.split_at(r.len() - 1);
            per_token.push_row(&[cache, selfm]);
        }
        let mut chunked = DapAccumulator::new(4);
        // chunk of 4 starting at a 0-slot cache: cache part empty, intra
        // part covers everything
        for (i, r) in rows.iter().enumerate() {
            chunked.push_row(&[&[], &r[..=i]]);
        }
        assert_eq!(per_token.colsum(), chunked.colsum());
        assert_eq!(per_token.colmax(), chunked.colmax());
    }

    #[test]
    #[should_panic(expected = "row must cover")]
    #[cfg(debug_assertions)]
    fn short_row_is_rejected() {
        let mut acc = DapAccumulator::new(3);
        acc.push_row(&[&[0.1, 0.2]]); // position 0 needs exactly 1 column
    }
}
