//! Copy-on-write page table — the per-request view half of prefix
//! sharing.
//!
//! A `PageTable` is the ordered page list a `KvSlab` (cache/slab.rs)
//! maps logical slots through, extended with two per-page bits:
//!
//! * **shared** — the page is aliased: pinned by the prefix cache
//!   (prefix/mod.rs) and possibly mapped by other slabs. Shared pages
//!   are read-freely, but any write must go through the
//!   [`PageTable::ensure_private`] barrier first, which forks the page
//!   (`PagePool::fork_page`: alloc + whole-page copy) so the writer
//!   diverges without perturbing its co-sharers. A "shared" page whose
//!   pool refcount has meanwhile dropped back to 1 — the cache evicted
//!   its entry and no sibling maps it — is privatized by just clearing
//!   the bit: no copy, no allocation.
//! * **dirty** — the page's KV changed since the last lane sync
//!   (the incremental gather of `KvSlab::copy_into_lane`). Forking and
//!   adoption both dirty the page, so the gather never reads a stale
//!   pre-fork image out of the engine's scratch buffers.
//!
//! The write sites are exactly two: `append` into the (possibly partial)
//! tail page, and `compact`'s slide-down writes — eviction or compaction
//! inside a shared prefix therefore forces a fork, which is the CoW rule
//! the admission discount (scheduler/admission.rs) reasons about.

use crate::cache::paged::{pages_for_slots, PagePool};

#[derive(Debug, Default)]
pub struct PageTable {
    pages: Vec<u32>,
    shared: Vec<bool>,
    dirty: Vec<bool>,
}

impl PageTable {
    pub fn new() -> Self {
        PageTable::default()
    }

    pub fn len(&self) -> usize {
        self.pages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pages.is_empty()
    }

    pub fn page(&self, idx: usize) -> u32 {
        self.pages[idx]
    }

    pub fn pages(&self) -> &[u32] {
        &self.pages
    }

    pub fn is_shared(&self, idx: usize) -> bool {
        self.shared[idx]
    }

    pub fn is_dirty(&self, idx: usize) -> bool {
        self.dirty[idx]
    }

    pub fn mark_dirty(&mut self, idx: usize) {
        self.dirty[idx] = true;
    }

    /// Mark every page dirty (full-resync invalidation).
    pub fn mark_all_dirty(&mut self) {
        self.dirty.fill(true);
    }

    /// Clear every dirty bit (after a lane sync consumed them).
    pub fn clear_dirty(&mut self) {
        self.dirty.fill(false);
    }

    /// Pages currently aliased (shared bit set).
    pub fn shared_count(&self) -> usize {
        self.shared.iter().filter(|&&s| s).count()
    }

    /// Ids of the currently-shared pages (physical-occupancy accounting:
    /// the scheduler counts each distinct shared page once).
    pub fn shared_page_ids(&self) -> Vec<u32> {
        self.pages
            .iter()
            .zip(&self.shared)
            .filter(|(_, &s)| s)
            .map(|(&p, _)| p)
            .collect()
    }

    /// Append a page this table allocated itself (private, dirty).
    pub fn push_private(&mut self, page: u32) {
        self.pages.push(page);
        self.shared.push(false);
        self.dirty.push(true);
    }

    /// Adopt a run of pages from the prefix cache: each is retained in
    /// the pool (one more reference) and mapped shared + dirty. Returns
    /// false — adopting nothing — if any retain is refused (a dead page
    /// would mean a cache/pool accounting bug; refusing keeps this table
    /// consistent and the error observable via `refcount_errors`).
    pub fn adopt_shared(&mut self, pool: &mut PagePool, pages: &[u32]) -> bool {
        if !pool.retain_all(pages) {
            return false;
        }
        for &p in pages {
            self.pages.push(p);
            self.shared.push(true);
            self.dirty.push(true);
        }
        true
    }

    /// Mark every page copy-on-write — called when the prefix cache is
    /// about to retain them, so the owner's own writes fork first from
    /// now on. A page marked shared whose refcount never actually grew
    /// self-heals at the first write (`ensure_private`'s sole-owner
    /// path), so over-marking is safe.
    pub fn mark_all_shared(&mut self) {
        self.shared.fill(true);
    }

    /// Copy-on-write barrier: make page `idx` safe to write. No-op for a
    /// private page (`Some(false)`). For a shared page whose pool
    /// refcount is 1 (sole owner after a cache eviction), just clears
    /// the bit (`Some(false)` — no copy). Otherwise forks: the caller's
    /// mapping moves to a fresh copy and its reference on the shared
    /// original is released (`Some(true)`).
    ///
    /// Returns `None` — touching nothing — when the pool cannot supply
    /// the fork page. This used to be an `expect` (the PR-3
    /// fork-exhaustion panic): a budget-sized pool with several lanes
    /// diverging from one shared prefix at once could make the fork the
    /// first allocation to see an empty pool. Callers now decide:
    /// appends are covered by the admission fork allowance (the shared
    /// partial tail stays in the lane's private page bound, see
    /// scheduler/admission.rs), and compaction-driven forks defer the
    /// eviction to a later step instead of crashing the serving loop
    /// (`KvSlab::try_compact`).
    #[must_use]
    pub fn ensure_private(&mut self, pool: &mut PagePool, idx: usize) -> Option<bool> {
        if !self.shared[idx] {
            return Some(false);
        }
        let page = self.pages[idx];
        if pool.refcount(page) == 1 {
            self.shared[idx] = false;
            return Some(false);
        }
        let fork = pool.fork_page(page)?;
        pool.release(page);
        self.pages[idx] = fork;
        self.shared[idx] = false;
        self.dirty[idx] = true;
        Some(true)
    }

    /// Release the pages beyond the first `keep` back to the pool
    /// (shared or private — the refcount decides whether they free).
    pub fn truncate_release(&mut self, pool: &mut PagePool, keep: usize) {
        for page in self.pages.drain(keep..) {
            pool.release(page);
        }
        self.shared.truncate(keep);
        self.dirty.truncate(keep);
    }

    /// Release every page back to the pool and clear the table.
    pub fn release_all(&mut self, pool: &mut PagePool) {
        for page in self.pages.drain(..) {
            pool.release(page);
        }
        self.shared.clear();
        self.dirty.clear();
    }
}

/// Pages a prefix-cache hit shares that are *stable* under the sharer's
/// own appends: every adopted page except a partial tail. The partial
/// tail page is forked by the first generated token, so the admission
/// discount (shared pages charged once, not per sharer) must not count
/// it — its fork allocation is charged to the lane's own bound instead.
pub fn stable_shared_pages(live_slots: usize, page_slots: usize) -> usize {
    let pages = pages_for_slots(live_slots, page_slots);
    if live_slots % page_slots.max(1) == 0 {
        pages
    } else {
        pages.saturating_sub(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> PagePool {
        // 2 layers × row 4, eight 4-slot pages
        PagePool::new(2, 4, 8, 4)
    }

    #[test]
    fn push_private_is_unshared_and_dirty() {
        let mut p = pool();
        let mut t = PageTable::new();
        t.push_private(p.alloc().unwrap());
        assert_eq!(t.len(), 1);
        assert!(!t.is_shared(0));
        assert!(t.is_dirty(0));
        assert_eq!(t.shared_count(), 0);
    }

    #[test]
    fn adopt_retains_and_marks_shared() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        let mut t = PageTable::new();
        assert!(t.adopt_shared(&mut p, &[a, b]));
        assert_eq!(p.refcount(a), 2);
        assert_eq!(p.refcount(b), 2);
        assert_eq!(t.shared_count(), 2);
        assert_eq!(t.shared_page_ids(), vec![a, b]);
        t.release_all(&mut p);
        assert_eq!(p.refcount(a), 1, "adopter's reference released");
    }

    #[test]
    fn adopt_of_dead_page_rolls_back() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        let dead = p.alloc().unwrap();
        p.release(dead);
        let mut t = PageTable::new();
        assert!(!t.adopt_shared(&mut p, &[a, dead]));
        assert!(t.is_empty());
        assert_eq!(p.refcount(a), 1, "partial retains rolled back");
        assert_eq!(p.stats().refcount_errors, 1);
    }

    #[test]
    fn ensure_private_forks_only_when_truly_shared() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        let k = vec![7.0f32; 8];
        p.write_slot(a, 0, &k, &k);
        let mut t = PageTable::new();
        assert!(t.adopt_shared(&mut p, &[a])); // refcount 2: cache + us
        assert_eq!(t.ensure_private(&mut p, 0), Some(true), "refcount 2 → real fork");
        assert_ne!(t.page(0), a);
        assert!(!t.is_shared(0));
        assert_eq!(p.refcount(a), 1, "our reference moved to the fork");
        assert_eq!(p.read_row(t.page(0), 0, 0, false), vec![7.0; 4]);

        // sole-owner case: shared bit set but nobody else holds the page
        let mut t2 = PageTable::new();
        let sole = p.alloc().unwrap();
        t2.push_private(sole);
        // simulate a cache pin that was later evicted: mark shared by
        // adopting our own page then dropping the original reference
        let mut t3 = PageTable::new();
        assert!(t3.adopt_shared(&mut p, &[sole]));
        t2.release_all(&mut p); // cache-side reference gone, t3 is sole owner
        let forks_before = p.stats().forks;
        assert_eq!(t3.ensure_private(&mut p, 0), Some(false), "sole owner: no copy");
        assert!(!t3.is_shared(0));
        assert_eq!(p.stats().forks, forks_before);
        assert_eq!(t3.page(0), sole);
    }

    #[test]
    fn ensure_private_is_idempotent() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        let mut t = PageTable::new();
        assert!(t.adopt_shared(&mut p, &[a]));
        assert!(t.ensure_private(&mut p, 0).is_some());
        assert_eq!(t.ensure_private(&mut p, 0), Some(false), "already private");
    }

    #[test]
    fn ensure_private_defers_on_exhaustion_without_corruption() {
        // 2-page pool: donor page + one free. Two sharers diverge; the
        // second finds the pool empty — the barrier must report None and
        // leave the table, refcounts and dirty bits exactly as they were
        // (so the caller can retry after pages free up).
        let mut p = PagePool::new(2, 4, 2, 4);
        let a = p.alloc().unwrap();
        let mut t1 = PageTable::new();
        let mut t2 = PageTable::new();
        assert!(t1.adopt_shared(&mut p, &[a]));
        assert!(t2.adopt_shared(&mut p, &[a])); // refcount 3
        assert_eq!(t1.ensure_private(&mut p, 0), Some(true), "last page forks");
        assert_eq!(p.free_pages(), 0);
        t2.clear_dirty();
        assert_eq!(t2.ensure_private(&mut p, 0), None, "exhausted: deferred");
        assert!(t2.is_shared(0), "mapping untouched");
        assert!(!t2.is_dirty(0), "dirty bit untouched");
        assert_eq!(t2.page(0), a);
        assert_eq!(p.refcount(a), 2, "no reference was dropped");
        assert_eq!(p.stats().refcount_errors, 0);
        // a page frees → the retry succeeds
        t1.release_all(&mut p);
        assert_eq!(t2.ensure_private(&mut p, 0), Some(true), "retry after free");
        assert_eq!(p.refcount(a), 1, "cache-side holder remains");
    }

    #[test]
    fn stable_shared_page_math() {
        assert_eq!(stable_shared_pages(0, 4), 0);
        assert_eq!(stable_shared_pages(3, 4), 0, "single partial page is unstable");
        assert_eq!(stable_shared_pages(4, 4), 1, "aligned tail is stable");
        assert_eq!(stable_shared_pages(9, 4), 2, "partial tail excluded");
        assert_eq!(stable_shared_pages(12, 4), 3);
    }
}
