//! Shared infrastructure for the bench harnesses (benches/*.rs).
//!
//! criterion is unavailable offline, so each bench is a `harness = false`
//! binary that uses these helpers: engine construction, the
//! reference-vs-policy fidelity protocol, timing, and fixed-width table
//! printing that mirrors the paper's table layout.

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::Result;

use crate::cache::{KvSlab, Modality, PagePool, PolicyKind};
use crate::coordinator::{ActiveRequest, Engine, EngineConfig};
use crate::eval::{fidelity, Fidelity};
use crate::runtime::Runtime;
use crate::scheduler::SchedPolicy;
use crate::router::RouterPolicy;
use crate::server::{serve_replicas_on, ServerConfig};
use crate::workload::{Request, StoryGrammar};

/// Artifact directory: $HAE_ARTIFACTS or ./artifacts.
pub fn artifact_dir() -> PathBuf {
    std::env::var("HAE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Bench sample-count scale: $HAE_BENCH_N overrides the default.
pub fn bench_n(default: usize) -> usize {
    std::env::var("HAE_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Artifact-gated suites call this instead of silently returning when
/// their precondition (built artifacts, a wide-enough compiled batch) is
/// missing. Under `HAE_REQUIRE_ARTIFACTS=1` — the CI artifacts job,
/// which just built them — a skip is a hard failure, so the gated
/// byte-identity/invariant suites can never silently stop running
/// (libtest captures a passing test's output, so CI could not even grep
/// for the skip message). Without the variable this is the familiar
/// eprintln + return.
pub fn skip_or_fail(reason: &str) {
    // explicit truthy set only, matching config.py's HAE_SMALL_ARTIFACTS
    // semantics — "false"/"off"/"0" never arm the gate by accident
    let required = std::env::var("HAE_REQUIRE_ARTIFACTS")
        .map(|v| {
            matches!(v.trim().to_ascii_lowercase().as_str(), "1" | "true" | "yes" | "on")
        })
        .unwrap_or(false);
    if required {
        panic!(
            "suite would skip ({}) but HAE_REQUIRE_ARTIFACTS is set — \
             the CI artifacts job ran without usable artifacts",
            reason
        );
    }
    eprintln!("skipping: {}", reason);
}

pub fn load_runtime() -> Result<Runtime> {
    Runtime::load(&artifact_dir())
}

pub fn load_grammar(dir: &Path) -> StoryGrammar {
    StoryGrammar::load(dir).unwrap_or_else(|_| StoryGrammar::uniform())
}

/// Build a fresh engine for a policy (each policy gets its own engine —
/// and its own device thread — so executable compile time never leaks
/// into another policy's measurement; call `engine.warmup()` before
/// timing).
pub fn engine_for(policy: PolicyKind, batch: usize, capture: bool) -> Result<Engine> {
    Engine::from_artifact_dir(
        &artifact_dir(),
        EngineConfig {
            policy,
            batch,
            capture_logits: capture,
            ..EngineConfig::default()
        },
    )
}

/// Widest compiled decode batch (cheap manifest read, no PJRT), 1 when
/// artifacts are absent.
pub fn widest_batch() -> usize {
    crate::model::Manifest::load(&artifact_dir())
        .map(|m| m.shapes.decode_batches.iter().copied().max().unwrap_or(1))
        .unwrap_or(1)
}

/// Spawn a serving thread with the given scheduler settings; returns the
/// join handle and the server's actual address. The listener is bound
/// HERE on port 0 — the OS picks a free port, read back via
/// `local_addr` — so parallel test binaries can never collide on a
/// hard-coded port (the old fixed-port scheme was a CI flake).
/// `prefix_cache` toggles the engine's radix-tree prefix cache (warm
/// hits are byte-identical to cold runs, so tests default it on; the
/// serve bench compares on vs off). `engine_threads` selects the serve
/// loop's overlap discipline (1 = sequential rounds, ≥2 = host work
/// overlaps the device window; see `ServerConfig::engine_threads`).
pub fn spawn_server(
    policy: PolicyKind,
    batch: usize,
    kv_budget: Option<usize>,
    sched_policy: SchedPolicy,
    prefix_cache: bool,
    engine_threads: usize,
) -> (std::thread::JoinHandle<()>, String) {
    spawn_server_replicas(ServerRig {
        policy,
        batch,
        kv_budget,
        sched_policy,
        prefix_cache,
        engine_threads,
        ..ServerRig::default()
    })
}

/// Knobs for [`spawn_server_replicas`] — `spawn_server`'s parameter list
/// plus the routing tier's, with defaults matching the single-replica
/// harness so call sites only name what they exercise.
pub struct ServerRig {
    pub policy: PolicyKind,
    pub batch: usize,
    pub kv_budget: Option<usize>,
    pub sched_policy: SchedPolicy,
    pub prefix_cache: bool,
    pub engine_threads: usize,
    pub replicas: usize,
    pub queue_depth: usize,
    pub router_policy: RouterPolicy,
    pub shed_queue: Option<usize>,
    pub spill_occupancy: Option<f64>,
}

impl Default for ServerRig {
    fn default() -> Self {
        ServerRig {
            policy: PolicyKind::hae_default(),
            batch: 1,
            kv_budget: None,
            sched_policy: SchedPolicy::Fifo,
            prefix_cache: true,
            engine_threads: 2,
            replicas: 1,
            queue_depth: 64,
            router_policy: RouterPolicy::Affinity,
            shed_queue: None,
            spill_occupancy: None,
        }
    }
}

/// [`spawn_server`] generalized to N replicas behind one listener — the
/// same ephemeral-port scheme, one engine (and device thread) per
/// replica, all built from the same artifact dir. Shutdown drains every
/// replica scheduler thread before `serve_replicas_on` returns, so a
/// `join()` on the returned handle proves the whole tier exited.
pub fn spawn_server_replicas(rig: ServerRig) -> (std::thread::JoinHandle<()>, String) {
    let listener =
        std::net::TcpListener::bind("127.0.0.1:0").expect("bind an ephemeral port");
    let addr = listener
        .local_addr()
        .expect("bound listener has an address")
        .to_string();
    let cfg_addr = addr.clone();
    let handle = std::thread::spawn(move || {
        // each engine spawns its own device thread; the PJRT client
        // lives there (it is not Send), so construction can happen
        // anywhere
        let engines: Vec<Engine> = (0..rig.replicas.max(1))
            .map(|_| {
                Engine::from_artifact_dir(
                    &artifact_dir(),
                    EngineConfig {
                        policy: rig.policy.clone(),
                        batch: rig.batch,
                        prefix_cache: rig.prefix_cache,
                        ..EngineConfig::default()
                    },
                )
                .expect("engine for compiled batch")
            })
            .collect();
        let grammar = load_grammar(&artifact_dir());
        let cfg = ServerConfig {
            addr: cfg_addr,
            queue_depth: rig.queue_depth,
            kv_budget: rig.kv_budget,
            sched_policy: rig.sched_policy,
            engine_threads: rig.engine_threads,
            router_policy: rig.router_policy,
            shed_queue: rig.shed_queue,
            spill_occupancy: rig.spill_occupancy,
            ..ServerConfig::default()
        };
        // surface engine errors as a thread panic so callers see the
        // root cause on join() instead of a silent dead server
        serve_replicas_on(engines, listener, cfg, grammar)
            .expect("serve exited with error");
    });
    (handle, addr)
}

/// Poll until the server accepts connections (up to ~10 s).
pub fn wait_listening(addr: &str) -> bool {
    for _ in 0..400 {
        if std::net::TcpStream::connect(addr).is_ok() {
            return true;
        }
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
    false
}

/// Result of running one policy over a request set.
pub struct PolicyRun {
    pub label: String,
    pub finished: Vec<ActiveRequest>,
    pub wall_s: f64,
}

/// Run requests to completion (batch width from engine cfg), timed.
pub fn run_policy(engine: &mut Engine, requests: Vec<Request>) -> Result<PolicyRun> {
    engine.warmup()?;
    let label = engine.cfg.policy.label();
    let t0 = Instant::now();
    let (finished, _) = engine.run_batched(requests)?;
    Ok(PolicyRun { label, finished, wall_s: t0.elapsed().as_secs_f64() })
}

/// QA answer accuracy. The answer is the SECOND generated token: prompts
/// end one token before the answer slot, so the first token (ANS_MARK /
/// STORY_MARK) comes from prefill logits and the answer itself is produced
/// through the policy-managed cache (see workload::requests).
pub fn answer_accuracy(finished: &[ActiveRequest]) -> f64 {
    let qa: Vec<&ActiveRequest> =
        finished.iter().filter(|ar| ar.req.expected_answer.is_some()).collect();
    if qa.is_empty() {
        return 0.0;
    }
    let correct = qa
        .iter()
        .filter(|ar| ar.generated.get(1).copied() == ar.req.expected_answer)
        .count();
    correct as f64 / qa.len() as f64
}

/// Fidelity protocol: greedy full-cache reference scripts + teacher-forced
/// policy replay over the same requests. Returns per-request fidelities.
pub fn fidelity_vs_full(
    policy: PolicyKind,
    requests: &[Request],
) -> Result<Vec<Fidelity>> {
    let mut reference = engine_for(PolicyKind::Full, 1, true)?;
    let mut scripts = Vec::new();
    for req in requests {
        let ar = reference.generate(req.clone())?;
        scripts.push((ar.generated.clone(), ar.logits_trace));
    }
    let mut policy_engine = engine_for(policy, 1, true)?;
    let mut out = Vec::new();
    for (req, (script, ref_trace)) in requests.iter().zip(&scripts) {
        let ar = policy_engine.generate_forced(req.clone(), script)?;
        out.push(fidelity(ref_trace, &ar.logits_trace));
    }
    Ok(out)
}

pub fn mean_fidelity(fids: &[Fidelity]) -> Fidelity {
    if fids.is_empty() {
        return Fidelity::default();
    }
    Fidelity {
        top1_agreement: fids.iter().map(|f| f.top1_agreement).sum::<f64>()
            / fids.len() as f64,
        mean_kl: fids.iter().map(|f| f.mean_kl).sum::<f64>() / fids.len() as f64,
        p95_kl: fids.iter().map(|f| f.p95_kl).sum::<f64>() / fids.len() as f64,
        steps: fids.iter().map(|f| f.steps).sum(),
    }
}

// ---------------------------------------------------------------------------
// table printing
// ---------------------------------------------------------------------------

pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n## {}", self.title);
        let line = |cells: &[String]| {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$} | ", c, w = widths[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}-|", "-".repeat(w + 2)));
        }
        println!("{}", sep);
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

// ---------------------------------------------------------------------------
// paged-arena lane-sync measurement (shared by perf_serve_batch and
// perf_page_pool; runtime-free)
// ---------------------------------------------------------------------------

/// One full-vs-incremental lane-gather measurement over a synthetic
/// arena: a slab with `live_slots` tokens is synced into a batch buffer
/// `steps` times, once with the sync cache defeated every step (full
/// resync — the pre-arena O(live slots) behaviour) and once in
/// steady-state decode (one append per step — O(dirty pages)).
pub struct LaneSyncSample {
    pub live_slots: usize,
    pub pages: usize,
    pub full_us_per_step: f64,
    pub incr_us_per_step: f64,
    pub incr_pages_per_step: f64,
    /// K+V bytes of one page (throughput arithmetic)
    pub page_bytes: usize,
}

pub fn measure_lane_sync(live_slots: usize, steps: usize) -> LaneSyncSample {
    let (n_layers, row, ps) = (4usize, 128usize, 16usize);
    let cap = live_slots + steps + 1;
    let pool = PagePool::new_shared(n_layers, row, cap.div_ceil(ps) + 1, ps);
    let token_row = vec![0.5f32; n_layers * row];
    let mut slab = KvSlab::in_pool(&pool, cap);
    for i in 0..live_slots {
        slab.append(&token_row, &token_row, i as i32, Modality::Text, 0.0);
    }
    let c = cap;
    let mut dst_k = vec![0.0f32; 2 * n_layers * c * row];
    let mut dst_v = dst_k.clone();

    // full resync every step: alternating lanes defeat the sync cache
    // (start on lane 1 so the first call already mismatches)
    let pages = slab.allocated_pages();
    let t0 = Instant::now();
    for i in 0..steps {
        slab.copy_into_lane(&mut dst_k, &mut dst_v, (i + 1) % 2, c);
    }
    let full_us_per_step = t0.elapsed().as_secs_f64() * 1e6 / steps as f64;

    // steady-state decode: one append per step, same destination
    slab.copy_into_lane(&mut dst_k, &mut dst_v, 0, c); // prime
    let t0 = Instant::now();
    let mut pages_copied = 0usize;
    for i in 0..steps {
        slab.append(
            &token_row,
            &token_row,
            (live_slots + i) as i32,
            Modality::Text,
            0.0,
        );
        pages_copied += slab.copy_into_lane(&mut dst_k, &mut dst_v, 0, c);
    }
    let incr_us_per_step = t0.elapsed().as_secs_f64() * 1e6 / steps as f64;

    LaneSyncSample {
        live_slots,
        pages,
        full_us_per_step,
        incr_us_per_step,
        incr_pages_per_step: pages_copied as f64 / steps as f64,
        page_bytes: n_layers * ps * row * 4 * 2,
    }
}

pub fn f2(x: f64) -> String {
    format!("{:.2}", x)
}

pub fn f3(x: f64) -> String {
    format!("{:.3}", x)
}

pub fn f4(x: f64) -> String {
    format!("{:.4}", x)
}

pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new("demo", &["a", "bee"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["long cell".into(), "x".into()]);
        t.print();
    }

    #[test]
    fn accuracy_counts_first_token() {
        // empty set → 0
        assert_eq!(answer_accuracy(&[]), 0.0);
    }

    #[test]
    fn formatters() {
        assert_eq!(f2(1.005), "1.00");
        assert_eq!(pct(0.973), "97.3%");
    }
}
