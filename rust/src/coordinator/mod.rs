//! L3 coordinator — the paper's system contribution as a serving runtime:
//! request admission, continuous batching, capacity-bucketed decode
//! scheduling and policy-driven KV management.

pub mod engine;
pub mod request_state;

pub use engine::{Engine, EngineConfig, StepReport};
pub use request_state::{ActiveRequest, EvictionEvent, RequestStats};
