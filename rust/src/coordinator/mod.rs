//! L3 coordinator — the paper's system contribution as a serving runtime:
//! request admission, continuous batching, capacity-bucketed decode
//! scheduling and policy-driven KV management.
//!
//! The engine exposes lane-lifecycle hooks (`Engine::prefill`,
//! `Engine::step_lanes`) consumed by two drivers: the in-process
//! `Engine::run_batched` convenience loop, and the serving-scale
//! `scheduler::Scheduler`, which adds KV-budget admission control and
//! priority queueing in front of the same lanes.

// hot-path panic discipline (hae-lint R3): violations need an inline
// #[allow] plus a reasoned suppression — see docs/STATIC_ANALYSIS.md
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod engine;
pub mod request_state;

pub use engine::{Engine, EngineConfig, StepReport, DEFAULT_EXTEND_CHUNK};
pub use request_state::{ActiveRequest, EvictionEvent, RequestStats};
