//! The serving engine: prefill → continuous-batched decode with
//! policy-driven KV eviction.
//!
//! The engine is the leader loop of the L3 coordinator. It assembles
//! batched decode inputs from per-request host slabs, samples tokens,
//! feeds attention scores back into the policies and applies their
//! eviction decisions. Capacity bucketing (DESIGN.md §2) happens here:
//! each decode step runs on the smallest compiled capacity that fits the
//! longest live cache in the batch — the mechanism by which eviction buys
//! wall-clock speed in a static-shape runtime.
//!
//! Device calls go through a [`DeviceHandle`]: the PJRT runtime lives on
//! its own thread (device/mod.rs) and the engine's decode step is split
//! into [`Engine::step_submit`] / [`Engine::step_complete`] so a caller
//! can overlap host work — admission, prefix probes, backfill prefills —
//! with the device's compute window. The blocking [`Engine::decode_step`]
//! (submit immediately followed by complete) is the sequential
//! single-thread baseline and the compatibility surface for the existing
//! drivers, benches and tests.

use std::path::Path;
use std::sync::mpsc::Receiver;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::cache::{
    lock_profiled, pages_for_slots, DecodeCtx, KvSlab, Modality, PagePool,
    PolicyKind, PoolStats, PrefillCtx, SharedPagePool, SlotMeta,
    DEFAULT_PAGE_SLOTS,
};
use crate::device::{DecodeDone, DeviceHandle};
use crate::model::{vocab, Manifest, ModelMeta};
use crate::obs::{EvictKind, Obs, SharedObs, TraceEvent};
use crate::prefix::{
    request_fingerprint, request_key, DapAccumulator, KeySym, PartialPrefixHit,
    PartialProbe, PrefixCache, PrefixHit, PrefixProbe, PrefixStats,
};
use crate::runtime::{DecodeOut, PrefillOut, Runtime, StepTiming};
use crate::scheduler::AdmissionController;
use crate::util::rng::Rng;
use crate::util::stats::argmax;
use crate::workload::Request;

use super::request_state::{ActiveRequest, EvictionEvent, RequestStats};

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub policy: PolicyKind,
    /// 0.0 = greedy
    pub temperature: f32,
    /// sample from the top-k logits when temperature > 0
    pub top_k: usize,
    pub seed: u64,
    /// keep per-step logits on each request (fidelity eval; memory-heavy)
    pub capture_logits: bool,
    /// keep per-step (position, score) snapshots (theory harness)
    pub capture_scores: bool,
    /// decode batch width (must be one of the compiled batch sizes)
    pub batch: usize,
    /// aggregate live-KV budget in bytes: sizes the shared page arena
    /// (None → physical ceiling, every lane at full capacity)
    pub kv_budget: Option<usize>,
    /// token slots per arena page
    pub page_slots: usize,
    /// radix-tree prefix cache: identical prompts (same text ids, bit-
    /// identical vision segments) skip prefill entirely and share the
    /// retained KV pages copy-on-write. Warm hits are byte-identical to
    /// the cold path, so this is safe to leave on; disabled internally
    /// for policies whose prefill consumes state (PolicyKind::prefix_safe)
    pub prefix_cache: bool,
    /// partial warm starts recompute their text suffix in chunks of up
    /// to this many tokens per device call through the extend
    /// executables (`--extend-chunk`; clamped to the largest compiled
    /// chunk bucket). 1 = the one-token decode loop, reproduced exactly
    pub extend_chunk: usize,
    /// request-lifecycle tracing + per-phase histograms (`obs::Obs`).
    /// Recording is alloc-free (pre-sized ring, `Copy` events) and the
    /// guardrail bench pins its decode overhead under 2%, so this stays
    /// on by default; off switches every `Obs` record into a no-op.
    pub trace: bool,
}

/// Default suffix-recompute chunk: one compiled extend bucket's worth of
/// rows per device call.
pub const DEFAULT_EXTEND_CHUNK: usize = 8;

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: PolicyKind::hae_default(),
            temperature: 0.0,
            top_k: 8,
            seed: 1,
            capture_logits: false,
            capture_scores: false,
            batch: 1,
            kv_budget: None,
            page_slots: DEFAULT_PAGE_SLOTS,
            prefix_cache: true,
            extend_chunk: DEFAULT_EXTEND_CHUNK,
            trace: true,
        }
    }
}

/// Aggregate timing of one batched decode step.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepReport {
    pub capacity: usize,
    pub lanes: usize,
    pub pjrt_s: f64,
    pub coord_s: f64,
    /// arena pages gathered into the batch buffers this step — the
    /// incremental lane sync copies O(dirty pages), so at steady state
    /// this is ≈ lanes, not Σ live slots / page_slots
    pub pages_copied: usize,
    /// host seconds between `step_submit` returning and `step_complete`
    /// starting to wait — the part of the device window the caller
    /// actually spent on other work. 0 on the blocking `decode_step`
    /// path; `min(overlap_host_s, pjrt_s) / pjrt_s` is the per-step
    /// host/device overlap fraction the scheduler aggregates.
    pub overlap_host_s: f64,
}

/// An in-flight decode step: submitted to the device thread, not yet
/// collected. Holds the *slot indices* of the submitted lanes (indices
/// into the caller's `Option`-lane array — stable while the overlap
/// window backfills `None` slots) and the reply channel carrying the
/// result plus the gather scratch on its way back.
pub struct PendingStep {
    slots: Vec<usize>,
    capacity: usize,
    rx: Receiver<DecodeDone>,
    assemble_s: f64,
    pages_copied: usize,
    submitted_at: Instant,
}

impl PendingStep {
    /// Lanes submitted in this step.
    pub fn lanes(&self) -> usize {
        self.slots.len()
    }

    /// Does this pending step include the given lane slot?
    pub fn covers_slot(&self, slot: usize) -> bool {
        self.slots.contains(&slot)
    }
}

pub struct Engine {
    /// handle to the dedicated device thread that owns the PJRT runtime
    dev: DeviceHandle,
    pub cfg: EngineConfig,
    rng: Rng,
    /// shared paged KV arena: one pool for every lane's slab, sized from
    /// `kv_budget` (or the physical ceiling)
    pool: SharedPagePool,
    /// scratch batch buffers, reused across steps (hot-path allocation
    /// avoidance; sized for the largest capacity bucket). Persistence
    /// across steps is what makes the slabs' dirty-page lane sync valid.
    /// `None` while a decode step is in flight: the buffers travel to
    /// the device thread inside the call and come back in the reply
    /// (`DecodeDone`), so they are never aliased across threads.
    scratch_k: Option<Vec<f32>>,
    scratch_v: Option<Vec<f32>>,
    /// separate gather buffers for the partial warm start's suffix
    /// recompute, so a backfill prefill can run its extend calls while
    /// the decode scratch is in flight on the device thread
    ext_k: Vec<f32>,
    ext_v: Vec<f32>,
    /// which slab (`KvSlab::sync_id`) last wrote each scratch lane — a
    /// slab's own (lane, capacity) sync check cannot see another slab
    /// clobbering its region, so ownership changes force a full resync
    /// (0 = never written)
    lane_owner: Vec<u64>,
    /// radix-tree prefix cache over the shared arena (prefix/mod.rs):
    /// cold prefills register their retained pages (exact entries) and
    /// their unpruned visual prefix (prefix entries); identical prompts
    /// adopt the former copy-on-write, prefix-sharing prompts the latter
    prefix: PrefixCache,
    /// policy evictions deferred because a CoW fork found the pool empty
    /// (retried on a later step — the recoverable form of the PR-3
    /// fork-exhaustion panic)
    fork_deferrals: u64,
    /// capacity-wall emergencies: a deferred eviction at the hard limit
    /// resolved by the fork-free aligned tail drop instead
    emergency_tail_drops: u64,
    /// suffix-recompute device calls issued by partial warm starts
    /// (extend executables + decode-loop fallbacks) — chunking makes
    /// this ≈ Σ ⌈suffix/chunk⌉ instead of Σ suffix
    extend_calls: u64,
    /// component timing of the most recent decode step (perf harness)
    last_timing: StepTiming,
    /// lifecycle trace journal + engine-phase histograms, shared with
    /// the scheduler (`Scheduler::for_engine` clones the handle) and
    /// exposed over the wire via `{"kind":"trace"}`
    obs: SharedObs,
}

impl Engine {
    pub fn new(dev: DeviceHandle, cfg: EngineConfig) -> Result<Engine> {
        let manifest = dev.manifest();
        if !manifest.shapes.decode_batches.contains(&cfg.batch) {
            bail!(
                "batch {} not compiled (available: {:?})",
                cfg.batch,
                manifest.shapes.decode_batches
            );
        }
        let m = dev.meta();
        let cap = manifest.shapes.cache_capacity;
        let n = cfg.batch * m.n_layers * cap * m.n_heads * m.d_head;
        let rng = Rng::new(cfg.seed);
        // Pool sizing: by default every lane can hold a full-capacity
        // cache; a --kv-budget shrinks the arena (never below one full
        // lane, so single-request paths always work — the scheduler's
        // page-granular admission enforces the tighter byte budget).
        let page_slots = cfg.page_slots.max(1);
        let pages_per_lane = pages_for_slots(cap, page_slots);
        let default_pages = cfg.batch * pages_per_lane;
        let pool_pages = match cfg.kv_budget {
            None => default_pages,
            Some(bytes) => {
                let page_bytes = page_slots * m.kv_bytes_per_token();
                (bytes / page_bytes.max(1)).clamp(pages_per_lane, default_pages)
            }
        };
        let pool = PagePool::new_shared(
            m.n_layers,
            m.n_heads * m.d_head,
            pool_pages,
            page_slots,
        );
        let lane_owner = vec![0; cfg.batch];
        let cfg_trace = cfg.trace;
        Ok(Engine {
            dev,
            cfg,
            rng,
            pool,
            scratch_k: Some(vec![0.0; n]),
            scratch_v: Some(vec![0.0; n]),
            ext_k: vec![0.0; n],
            ext_v: vec![0.0; n],
            lane_owner,
            prefix: PrefixCache::new(crate::prefix::DEFAULT_MAX_ENTRIES),
            fork_deferrals: 0,
            emergency_tail_drops: 0,
            extend_calls: 0,
            last_timing: StepTiming::default(),
            obs: Obs::shared(cfg_trace),
        })
    }

    /// Spawn a device thread loading artifacts from `dir` and build an
    /// engine on it — the one-liner for drivers, benches and tests that
    /// previously constructed `Engine::new(Runtime::load(dir)?, cfg)`.
    pub fn from_artifact_dir(dir: &Path, cfg: EngineConfig) -> Result<Engine> {
        let dir = dir.to_path_buf();
        Engine::new(crate::device::spawn(move || Runtime::load(&dir))?, cfg)
    }

    /// Model geometry (mirrored off the device thread at spawn).
    pub fn meta(&self) -> &ModelMeta {
        self.dev.meta()
    }

    /// Artifact manifest (shapes, buckets, paths).
    pub fn manifest(&self) -> &Manifest {
        self.dev.manifest()
    }

    /// The device-thread handle (cloneable; standalone probes and the
    /// harness share it rather than spawning a second runtime).
    pub fn device(&self) -> &DeviceHandle {
        &self.dev
    }

    /// Compile this engine's decode batch width ahead of serving.
    pub fn warmup(&self) -> Result<()> {
        self.dev.warmup(&[self.cfg.batch])
    }

    /// Handle to the shared observability state (trace journal + phase
    /// histograms). The scheduler clones this so both sides journal into
    /// one ring.
    pub fn obs(&self) -> SharedObs {
        self.obs.clone()
    }

    /// Start a send-wait span: snapshot the device handle's cumulative
    /// channel send wait before a device call. Returns `u64::MAX` when
    /// tracing is off so the closing bracket costs nothing. The delta is
    /// exact because only this engine's thread sends on its handle.
    fn send_wait_mark(&self) -> u64 {
        if self.obs.enabled() {
            self.dev.send_wait_us()
        } else {
            u64::MAX
        }
    }

    /// Close a send-wait span opened by [`Self::send_wait_mark`]: record
    /// how long the bounded device channel blocked this call's send —
    /// the backpressure histogram `hae_device_send_wait_ms`.
    fn send_wait_record(&self, mark: u64) {
        if mark != u64::MAX {
            let waited_us = self.dev.send_wait_us().saturating_sub(mark);
            self.obs.record(|o| {
                o.profile.device_send_wait_ms.record(waited_us as f64 / 1e3);
            });
        }
    }

    /// Handle to the shared page arena (scheduler metrics, tests).
    pub fn page_pool(&self) -> SharedPagePool {
        self.pool.clone()
    }

    /// Occupancy snapshot of the shared arena.
    pub fn pool_stats(&self) -> PoolStats {
        lock_profiled(&self.pool, &self.obs).stats()
    }

    /// Total pages in the arena.
    pub fn pool_pages(&self) -> usize {
        lock_profiled(&self.pool, &self.obs).n_pages()
    }

    /// Token slots per arena page.
    pub fn page_slots(&self) -> usize {
        lock_profiled(&self.pool, &self.obs).page_slots()
    }

    /// Cumulative CoW fork count — one short pool lock, released before
    /// the caller records anything (docs/CONCURRENCY.md lock order).
    fn pool_forks(&self) -> u64 {
        lock_profiled(&self.pool, &self.obs).stats().forks
    }

    /// Admission controller over the engine's physical arena (budget =
    /// the whole pool): the one page-bound implementation, shared by
    /// engine-direct drivers (`run_batched`) and, with a tighter byte
    /// budget, by the serving scheduler.
    pub fn pool_admission(&self) -> AdmissionController {
        AdmissionController {
            budget_pages: self.pool_pages(),
            page_slots: self.page_slots(),
            capacity_limit: self.capacity_limit(),
            kv_bytes_per_token: self.meta().kv_bytes_per_token(),
        }
    }

    // ------------------------------------------------------------------
    // prefix cache
    // ------------------------------------------------------------------

    /// Is the prefix cache active for this engine's policy?
    pub fn prefix_enabled(&self) -> bool {
        self.cfg.prefix_cache && self.cfg.policy.prefix_safe()
    }

    /// Prefix-cache observability (hits, pinned pages, tokens skipped).
    pub fn prefix_stats(&self) -> PrefixStats {
        self.prefix.stats()
    }

    /// Policy evictions deferred because a CoW fork found the pool empty
    /// (each is retried on a later step — never a panic).
    pub fn fork_deferrals(&self) -> u64 {
        self.fork_deferrals
    }

    /// Capacity-wall emergencies resolved by the fork-free aligned tail
    /// drop. Nonzero only under extreme budget pressure; counted because
    /// the dropped recent context changes that lane's trajectory.
    pub fn emergency_tail_drops(&self) -> u64 {
        self.emergency_tail_drops
    }

    /// Suffix-recompute device calls issued by partial warm starts so
    /// far (chunked extend calls + one-token decode fallbacks).
    pub fn extend_calls(&self) -> u64 {
        self.extend_calls
    }

    /// The suffix-recompute chunk actually in effect: `cfg.extend_chunk`
    /// clamped to the largest extend bucket compiled for single-lane
    /// extension (1 when none exist — the decode-loop path).
    pub fn effective_extend_chunk(&self) -> usize {
        self.cfg
            .extend_chunk
            .max(1)
            .min(self.manifest().max_extend_chunk(1).max(1))
    }

    /// Arena pages currently pinned by prefix-cache entries.
    pub fn prefix_pinned_pages(&self) -> usize {
        self.prefix.pinned_pages()
    }

    /// Ids of every cache-pinned page (the scheduler unions these with
    /// live lanes' shared pages for charged-once budget accounting).
    pub fn prefix_pinned_page_ids(&self) -> Vec<u32> {
        self.prefix.pinned_page_ids()
    }

    /// Admission discount for a candidate: pages a warm hit would adopt
    /// that stay shared under its own decode appends. 0 on a miss or
    /// with the cache off. Hashes the prompt; callers probing every tick
    /// should hash once and use `prefix_discount_probed`.
    pub fn prefix_discount(&self, req: &Request) -> usize {
        if !self.prefix_enabled() {
            return 0;
        }
        self.prefix_discount_probed(&request_key(req), request_fingerprint(req))
    }

    /// `prefix_discount` with the (key, fingerprint) probe already
    /// hashed — the scheduler hashes once at enqueue (`QueuedJob::
    /// prefix_probe`) instead of re-hashing a multi-KB vision prompt on
    /// every admission attempt.
    pub fn prefix_discount_probed(&self, key: &[KeySym], fingerprint: u64) -> usize {
        if !self.prefix_enabled() {
            return 0;
        }
        self.prefix
            .peek_discount(key, fingerprint, self.cfg.page_slots.max(1))
    }

    /// Pages the admission loops could actually recover by evicting
    /// reclaimable cache entries right now. Lets them decline to touch
    /// the cache when reclaiming cannot close a candidate's shortfall.
    pub fn prefix_reclaimable_pages(&self) -> usize {
        let pool = lock_profiled(&self.pool, &self.obs);
        self.prefix.reclaimable_pages(&pool)
    }

    /// Evict the least-recently-used cache entry unconditionally (tests
    /// / shutdown drains). False when the cache is empty.
    pub fn prefix_evict_one(&mut self) -> bool {
        let mut pool = lock_profiled(&self.pool, &self.obs);
        self.prefix.evict_lru(&mut pool)
    }

    /// Evict the LRU *reclaimable* entry — one actually holding pages
    /// nobody else references, so evicting frees budget. The admission
    /// pressure valve: entries still mapped by live lanes are kept,
    /// since evicting them frees nothing and only destroys future hits.
    pub fn prefix_reclaim_one(&mut self) -> bool {
        let mut pool = lock_profiled(&self.pool, &self.obs);
        self.prefix.evict_lru_reclaimable(&mut pool)
    }

    /// Make sure at least `needed` pages are free, LRU-evicting
    /// *reclaimable* prefix entries (cache-only pins) if necessary.
    /// Called before every allocating phase so a cache full of cold
    /// prefixes can never starve live requests.
    fn reclaim_pool_headroom(&mut self, needed: usize) {
        let mut pool = lock_profiled(&self.pool, &self.obs);
        if pool.free_pages() < needed {
            self.prefix.reclaim(&mut pool, needed);
        }
    }

    /// (upload, execute, download) seconds of the most recent decode step.
    pub fn last_timing(&self) -> (f64, f64, f64) {
        (self.last_timing.upload_s, self.last_timing.execute_s, self.last_timing.download_s)
    }

    /// Hard limit on live slots (one below the largest compiled capacity —
    /// the incoming token always needs a free slot).
    pub fn capacity_limit(&self) -> usize {
        self.manifest().shapes.cache_capacity - 1
    }

    /// Most live KV the engine can physically hold: every decode lane at
    /// the hard capacity limit. The scheduler's default (unconstrained)
    /// KV budget; `--kv-budget` tightens it below this.
    pub fn kv_budget_ceiling(&self) -> usize {
        self.cfg.batch * self.capacity_limit() * self.meta().kv_bytes_per_token()
    }

    // ------------------------------------------------------------------
    // prefill
    // ------------------------------------------------------------------

    /// Run prefill for a request and admit it with a fresh policy
    /// instance. With the prefix cache on:
    ///
    /// * a prompt identical to one seen before (same text ids,
    ///   bit-identical vision segments) skips the PJRT prefill *and* the
    ///   DAP decision entirely: the cached retained pages are adopted
    ///   copy-on-write and the cached prefill logits produce the first
    ///   token — byte-identical to the request's own cold run, since
    ///   every input of the decode trajectory is the cold run's output
    ///   for that exact prompt;
    /// * a prompt sharing only the *visual prefix* (a new question about
    ///   a cached image) takes the partial warm start: the unpruned
    ///   prefix pages are adopted copy-on-write, only the text suffix is
    ///   recomputed through the decode executables, and the retention
    ///   decision is re-run with this request's OWN reconstructed DAP
    ///   statistics — never the donor's decision (`prefill_partial`).
    pub fn prefill(&mut self, req: Request) -> Result<ActiveRequest> {
        let rid = req.id;
        self.obs.event(rid, TraceEvent::PrefillStart);
        let out = self.prefill_inner(req);
        if self.obs.enabled() {
            let mut o = self.obs.inner();
            if let Ok(ar) = &out {
                // phase histograms: cold device prefill vs partial-replay
                // suffix recompute. Exact warm hits run no device prefill
                // (prefill_s stays 0) and record in neither.
                if !ar.stats.prefix_hit {
                    o.prefill_ms.record(ar.stats.prefill_s * 1000.0);
                } else if ar.stats.extend_calls > 0 {
                    o.partial_replay_ms.record(ar.stats.prefill_s * 1000.0);
                }
                // retained fraction per modality, recorded where a
                // retention decision actually ran (cold + partial replay;
                // exact hits reuse the donor's decision). Slot eviction
                // spans all layers in KvSlab, so "per-layer" collapses to
                // one fraction — per-modality is the observable axis (see
                // docs/OBSERVABILITY.md).
                if !ar.stats.prefix_hit || ar.stats.extend_calls > 0 {
                    let vis_kept = ar
                        .slab
                        .meta()
                        .iter()
                        .filter(|sm| sm.modality == Modality::Vision)
                        .count();
                    let vis_total = ar.stats.vision_tokens;
                    let txt_total =
                        ar.stats.prompt_tokens.saturating_sub(vis_total);
                    let txt_kept = ar.prefill_len.saturating_sub(vis_kept);
                    if vis_total > 0 {
                        o.retained_frac_vision
                            .record(vis_kept as f64 / vis_total as f64);
                    }
                    if txt_total > 0 {
                        o.retained_frac_text
                            .record((txt_kept.min(txt_total)) as f64 / txt_total as f64);
                    }
                }
            }
            o.trace.record(rid, TraceEvent::PrefillEnd);
        }
        out
    }

    /// Prefill dispatch (see `prefill` for the path semantics).
    fn prefill_inner(&mut self, req: Request) -> Result<ActiveRequest> {
        let probe = self.prefix_enabled().then(|| PrefixProbe::of(&req));
        let req = if let Some(pr) = &probe {
            if let Some(hit) = self.prefix.lookup(&pr.key, pr.fingerprint) {
                let mut slab =
                    KvSlab::in_pool(&self.pool, self.manifest().shapes.cache_capacity);
                let PrefixHit { pages, meta, logits, .. } = hit;
                if slab.adopt_shared(&pages, meta) {
                    // the hit is counted only now, with the pages
                    // actually adopted — the skipped-token metrics never
                    // claim work the fallback path then recomputed
                    self.prefix.note_hit(req.prompt_len());
                    return self.prefill_from_hit(req, slab, logits);
                }
                // adoption refused: the entry's pins are broken (a pool
                // accounting bug, surfaced via refcount_errors). Drop the
                // entry so it is not retried forever, and go cold.
                let mut pool = lock_profiled(&self.pool, &self.obs);
                self.prefix.remove(&pr.key, &mut pool);
            }
            // partial warm start: only for policies whose retention
            // decision is a pure function of the DAP statistics — the
            // replay cannot reproduce kv-rewriting prefills
            let mut fallback = req;
            if self.cfg.policy.partial_safe() {
                if let Some(pp) = &pr.partial {
                    if let Some(hit) = self.prefix.lookup_partial(&pr.key, pp) {
                        match self.prefill_partial(fallback, pr, hit)? {
                            Ok(ar) => return Ok(ar),
                            // the partial path bailed (adoption refused,
                            // pool too tight for the replay forks): the
                            // request comes back and goes cold
                            Err(req) => fallback = req,
                        }
                    }
                }
            }
            self.prefix.note_miss();
            fallback
        } else {
            req
        };
        self.prefill_cold(req, probe)
    }

    /// Prefix-cache fast path: build the post-prefill request state
    /// around an already-adopted slab and the cached prefill logits.
    fn prefill_from_hit(
        &mut self,
        req: Request,
        slab: KvSlab,
        logits: Vec<f32>,
    ) -> Result<ActiveRequest> {
        let t_start = Instant::now();
        let n = req.prompt_len();
        let policy = self.cfg.policy.build();
        let prefill_len = slab.len();
        let first_token = self.sample(&logits);
        let mut stats = RequestStats {
            prompt_tokens: n,
            vision_tokens: req.n_vision(),
            pruned_at_prefill: n - prefill_len,
            peak_kv_bytes: slab.kv_bytes(),
            prefix_hit: true,
            prefill_tokens_skipped: n,
            ..RequestStats::default()
        };
        stats.decisions = policy.decision_count();
        let mut ar = ActiveRequest {
            pos: n as i32,
            pending_token: first_token,
            req,
            slab,
            policy,
            generated: Vec::new(),
            prefill_len,
            done: false,
            forced: None,
            logits_trace: Vec::new(),
            score_trace: Vec::new(),
            evictions: Vec::new(),
            stats,
        };
        if self.cfg.capture_logits {
            ar.logits_trace.push(logits);
        }
        ar.generated.push(first_token);
        self.check_done(&mut ar);
        // no PJRT prefill ran: the whole warm admission is host-side
        // coordination, so it lands in coord_s only — prefill_s stays 0,
        // keeping the timing buckets disjoint (the cold-vs-warm tables
        // then show the device prefill literally disappearing)
        ar.stats.coord_s += t_start.elapsed().as_secs_f64();
        Ok(ar)
    }

    /// Partial-prefix warm start: adopt the entry's *unpruned* prefix
    /// pages copy-on-write, recompute only the text suffix — in chunks
    /// of up to `--extend-chunk` rows per device call through the extend
    /// executables (`Runtime::extend`), the one-token decode loop at
    /// chunk 1 — reconstruct this request's own DAP statistics
    /// (cached prefix-row contributions + the recomputed rows' dap
    /// outputs, folded in prompt order by `prefix::DapAccumulator`),
    /// re-run the retention decision with them, and compact the slab to
    /// the decision — so the pruning decision is the request's own,
    /// never the donor's, and the retained-index set, score seeds and
    /// first token match the request's own cold run. Chunking changes
    /// only how rows are grouped into device calls (⌈suffix/chunk⌉
    /// instead of one per token): every row still attends over the
    /// exact context it saw in a cold prefill, and the host accumulation
    /// order is identical for every chunk size.
    ///
    /// `Err(req)` (the inner result) hands the request back for a cold
    /// prefill when the warm path cannot complete: page adoption refused,
    /// the prompt too long for the decode buckets, or the pool too tight
    /// for the replay's CoW forks. Outer errors are runtime failures and
    /// propagate.
    ///
    /// Numerical caveat: the reconstructed statistics and the recomputed
    /// suffix KV/logits are *mathematically* equal to the cold prefill's
    /// (same weights, same attention support, same aggregation), but the
    /// two executables may reduce in different float orders, so equality
    /// is ULP-level, not provably bitwise. The decision thresholds and
    /// greedy argmax are far from ties on trained attention, and the
    /// equivalence is enforced empirically by hard asserts
    /// (`benches/perf_prefix_cache.rs` dialog table,
    /// `tests/scheduler_e2e.rs`) wherever artifacts exist.
    #[allow(clippy::result_large_err)]
    fn prefill_partial(
        &mut self,
        req: Request,
        probe: &PrefixProbe,
        hit: PartialPrefixHit,
    ) -> Result<std::result::Result<ActiveRequest, Request>> {
        let t_start = Instant::now();
        let m = self.meta().clone();
        let n = req.prompt_len();
        let p = hit.prefix_len;
        debug_assert!(p < n, "partial hit requires a nonempty suffix");
        let ps = self.cfg.page_slots.max(1);

        // the extension runs over the UNPRUNED prefix, so the whole
        // prompt must fit the decode capacity buckets and the slab
        // capacity as-is; a prompt the cold path can still serve (its
        // prefill bucket exists and DAP prunes before decode) goes cold
        // instead of erroring out of the suffix loop
        if n >= self.manifest().shapes.cache_capacity
            || self.manifest().capacity_bucket(n - 1).is_none()
        {
            return Ok(Err(req));
        }

        // adopt FIRST: once the slab maps the entry's pages their pool
        // refcount exceeds the cache's pin count, so the headroom
        // reclaim below can never evict the very entry being served
        // (a cache-only entry is reclaimable until someone maps it)
        let mut slab = KvSlab::in_pool(&self.pool, self.manifest().shapes.cache_capacity);
        if !slab.adopt_shared(&hit.pages, hit.meta.clone()) {
            // broken pins (a pool-accounting bug surfaced via
            // refcount_errors): drop the entry like the exact path does,
            // so it is not retried — and refused — on every later turn
            let mut pool = lock_profiled(&self.pool, &self.obs);
            if let Some(pp) = &probe.partial {
                self.prefix.remove(&probe.key[..pp.prefix_syms], &mut pool);
            }
            return Ok(Err(req));
        }
        self.obs.event(
            req.id,
            TraceEvent::PartialAdopt { shared_pages: hit.pages.len() as u32 },
        );
        // the extension's appends (suffix pages + the tail fork) may not
        // hit the allocator's exhaustion expect: if the pool cannot
        // cover the whole suffix even after reclaiming cache-only pins,
        // go cold BEFORE any device work — the cold path needs no more
        // pages than this and reclaims for itself. Admission already
        // charged the candidate its full worst case (no partial discount
        // — the fork allowance), so this is normally a no-op; the chunk
        // loop below then *claims* its pages chunk-by-chunk (the same
        // claim-as-you-go shape as chunked-prefill reservations,
        // `AdmissionController::extend_chunk_claim`), and the replay
        // compaction reclaims its fork worst case separately — cache
        // pins are only converted when the phase that needs them runs.
        let appends = pages_for_slots(n, ps).saturating_sub(hit.pages.len()) + 1;
        self.reclaim_pool_headroom(appends);
        if lock_profiled(&self.pool, &self.obs).free_pages() < appends {
            return Ok(Err(req));
        }

        // the request's own DAP statistics, rebuilt per column (slot i ==
        // position i: the prefix is unpruned and the suffix appends in
        // order). The accumulator seeds columns from the entry's cached
        // prefix-row contributions, then folds each recomputed suffix
        // row in prompt order — one addition per column per row, so the
        // accumulation is bit-identical for every chunk size
        // (prefix/replay.rs; pinned by tests/cache_props.rs).
        let mut acc = DapAccumulator::seeded(&hit.meta, n);

        // suffix recompute, lane 0 only: up to `effective_extend_chunk`
        // rows per extend call, ⌈suffix/chunk⌉ device calls in place of
        // one per token; chunk 1 (or a pre-extend artifact set) takes
        // the one-token decode path, reproducing it exactly. Positions
        // and lengths are exact, so each suffix row attends to the full
        // unpruned prefix plus the already-recomputed suffix — the same
        // context its row saw in the cold prefill.
        let chunk_eff = self.effective_extend_chunk();
        let ctl = self.pool_admission();
        let b = self.cfg.batch;
        let row = m.n_heads * m.d_head;
        let mut tokens = vec![0i32; b];
        let mut positions = vec![0i32; b];
        let mut lengths = vec![0i32; b];
        let mut prefill_dev_s = 0.0f64;
        let mut calls = 0u64;
        let mut last_logits: Vec<f32> = Vec::new();
        let mut t = p;
        while t < n {
            let step = chunk_eff.min(n - t);
            debug_assert!(
                req.is_vision[t..t + step].iter().all(|&v| !v),
                "partial suffix must be text-only"
            );
            // claim this chunk's pages (append pages + the possible tail
            // fork) out of the reserved worst case
            self.reclaim_pool_headroom(ctl.extend_chunk_claim(step));
            let len = slab.len();
            debug_assert_eq!(len, t, "suffix appends in order");
            let capacity = self
                .manifest()
                .capacity_bucket(len)
                .ok_or_else(|| anyhow!("suffix length {} exceeds all buckets", len))?;
            if step > 1 {
                // chunked extend: one device call for `step` rows, padded
                // to the smallest compiled chunk bucket
                let s_bucket = self
                    .manifest()
                    .extend_bucket(step)
                    .ok_or_else(|| anyhow!("extend chunk {} exceeds all compiled chunk buckets", step))?;
                let slab_n = m.n_layers * capacity * row; // one lane
                slab.copy_into_lane(
                    &mut self.ext_k[..slab_n],
                    &mut self.ext_v[..slab_n],
                    0,
                    capacity,
                );
                let mut toks = vec![0i32; s_bucket];
                let mut poss = vec![0i32; s_bucket];
                for i in 0..step {
                    toks[i] = req.ids[t + i];
                    poss[i] = (t + i) as i32;
                }
                // the gather buffers ride the call to the device thread
                // and come back in the reply; restore them before the
                // result is inspected so an error path leaks nothing
                let ek = std::mem::take(&mut self.ext_k);
                let evb = std::mem::take(&mut self.ext_v);
                let sw = self.send_wait_mark();
                let done = self.dev.extend(
                    1,
                    s_bucket,
                    capacity,
                    toks,
                    poss,
                    ek,
                    evb,
                    vec![len as i32],
                    vec![step as i32],
                )?;
                self.send_wait_record(sw);
                self.ext_k = done.k;
                self.ext_v = done.v;
                let (out, timing) = done.result?;
                prefill_dev_s += timing.total_s();
                calls += 1;
                self.obs.record(|o| {
                    o.extend_chunk_ms.record(timing.total_s() * 1000.0);
                    o.trace.record(req.id, TraceEvent::ExtendChunk { n: step as u32 });
                });
                for i in 0..step {
                    let k_new = out.row_kv(&out.k_new, &m, 0, i);
                    let v_new = out.row_kv(&out.v_new, &m, 0, i);
                    slab.append(&k_new, &v_new, (t + i) as i32, Modality::Text, 0.0);
                    // this row's Eq. 1 / Eq. 3 contributions: the cache
                    // columns (the unpruned prefix + earlier chunks),
                    // then the chunk columns up to and including itself
                    let (cache_cols, chunk_cols) = out.row_dap(0, i);
                    acc.push_row(&[&cache_cols[..len], &chunk_cols[..=i]]);
                }
                if t + step == n {
                    last_logits = out.lane_logits(&m, 0).to_vec();
                }
            } else {
                // one-token decode step — the pre-chunking path verbatim
                let slab_n = b * m.n_layers * capacity * row;
                slab.copy_into_lane(
                    &mut self.ext_k[..slab_n],
                    &mut self.ext_v[..slab_n],
                    0,
                    capacity,
                );
                tokens[0] = req.ids[t];
                positions[0] = t as i32;
                lengths[0] = len as i32;
                let ek = std::mem::take(&mut self.ext_k);
                let evb = std::mem::take(&mut self.ext_v);
                let sw = self.send_wait_mark();
                let done = self.dev.decode(
                    b,
                    capacity,
                    tokens.clone(),
                    positions.clone(),
                    ek,
                    evb,
                    lengths.clone(),
                )?;
                self.send_wait_record(sw);
                self.ext_k = done.k;
                self.ext_v = done.v;
                let (out, timing) = done.result?;
                prefill_dev_s += timing.total_s();
                calls += 1;
                self.obs.record(|o| {
                    o.extend_chunk_ms.record(timing.total_s() * 1000.0);
                    o.trace.record(req.id, TraceEvent::ExtendChunk { n: step as u32 });
                });
                let k_new = out.lane_kv(&m, &out.k_new, 0).to_vec();
                let v_new = out.lane_kv(&m, &out.v_new, 0).to_vec();
                slab.append(&k_new, &v_new, t as i32, Modality::Text, 0.0);
                // this text row's Eq. 1 / Eq. 3 contributions: cache
                // columns plus its own (dap_stats' row weight covers all
                // valid text rows; the causal diagonal is self-attention)
                let dap_row = out.lane_dap_row(0);
                acc.push_row(&[&dap_row[..len], &[out.lane_dap_self(0)]]);
                if t + 1 == n {
                    last_logits = out.lane_logits(&m, 0).to_vec();
                }
            }
            t += step;
        }
        let (colsum, colmax) = acc.into_stats();
        // the extension gathered into the ext_* buffers, not the decode
        // scratch, but the slab's sync bookkeeping cannot tell buffers
        // apart: it now claims lane-0 pages are synced somewhere the
        // decode step will never read. Force a clean resync on the first
        // real step; lane owners reset too so no other slab trusts a
        // stale claim about this engine's scratch
        slab.invalidate_sync();
        self.lane_owner.fill(0);

        // the retention decision, re-run for THIS request over its own
        // statistics — cold/warm equivalence holds because this is the
        // same pure function of (dap_sum, dap_max, modality, n) the cold
        // path would have evaluated
        let mut policy = self.cfg.policy.build();
        let mut is_vision = req.is_vision.clone();
        is_vision.resize(n, false);
        let pctx = PrefillCtx {
            dap_sum: &colsum,
            dap_max: &colmax,
            is_vision: &is_vision,
            n_tokens: n,
            k: &[],
            v: &[],
            bucket: n,
            meta: &m,
        };
        let decision = policy.prefill(&pctx);
        if decision.kv_override.is_some() {
            // defensive: partial_safe policies never rewrite KV; if one
            // does, the replay cannot honour it — recompute cold
            return Ok(Err(req));
        }
        if decision.retain.len() >= self.manifest().shapes.cache_capacity {
            bail!("prefill retain set exceeds cache capacity");
        }
        let retain = decision.retain;
        // apply the decision: compaction inside the adopted prefix forks
        // the written pages (CoW) — the last chunk-wise claim, worst case
        // every still-shared page. Reclaim for it now (cache pins were
        // deliberately not flushed for this up front); exhaustion falls
        // back to a cold prefill instead of panicking
        self.reclaim_pool_headroom(slab.shared_pages());
        let forks_before = self.pool_forks();
        if slab.try_compact(&retain).is_none() {
            return Ok(Err(req));
        }
        let forked = self.pool_forks() - forks_before;
        if forked > 0 {
            self.obs.event(req.id, TraceEvent::CowFork { pages: forked as u32 });
        }
        // rewrite the slot metadata to cold-injection semantics: the
        // score seeds are the request's own full-prompt DAP mass
        for (i, &src) in retain.iter().enumerate() {
            slab.meta_mut()[i] = SlotMeta {
                position: src as i32,
                modality: if is_vision[src] { Modality::Vision } else { Modality::Text },
                cum_score: colsum[src],
                cum_peak: colsum[src],
                last_score: colsum[src],
                marked: false,
                age: 0,
            };
        }

        // counted only once the warm start stuck: the engine total then
        // always equals the sum of per-request counts — a rare
        // cold-fallback after the chunk loop (try_compact exhaustion)
        // discards its calls from both, keeping the stats reconcilable
        self.extend_calls += calls;
        let prefill_len = slab.len();
        let first_token = self.sample(&last_logits);
        let mut stats = RequestStats {
            prefill_s: prefill_dev_s,
            // the suffix recompute *is* this path's prefill: extend_s
            // mirrors it so replies can show where warm-start time went
            // without changing prefill_s semantics
            extend_s: prefill_dev_s,
            prompt_tokens: n,
            vision_tokens: req.n_vision(),
            pruned_at_prefill: n - prefill_len,
            peak_kv_bytes: slab.kv_bytes(),
            prefix_hit: true,
            prefill_tokens_skipped: p,
            extend_calls: calls as usize,
            ..RequestStats::default()
        };
        stats.decisions = policy.decision_count();
        let mut ar = ActiveRequest {
            pos: n as i32,
            pending_token: first_token,
            req,
            slab,
            policy,
            generated: Vec::new(),
            prefill_len,
            done: false,
            forced: None,
            logits_trace: Vec::new(),
            score_trace: Vec::new(),
            evictions: Vec::new(),
            stats,
        };
        if self.cfg.capture_logits {
            ar.logits_trace.push(last_logits.clone());
        }
        ar.generated.push(first_token);
        self.check_done(&mut ar);
        // the warm start stuck: count it, and register the full prompt as
        // an exact entry so a repeat of this very question skips even the
        // suffix recompute next time
        self.prefix.note_partial_hit(p);
        self.register_exact_entry(
            probe.key.clone(),
            probe.fingerprint,
            n,
            &mut ar,
            &last_logits,
        );
        ar.stats.coord_s += t_start.elapsed().as_secs_f64() - prefill_dev_s;
        Ok(Ok(ar))
    }

    /// Register a freshly admitted request's retained pages as an exact
    /// whole-prompt entry (shared by the cold and partial-warm paths).
    fn register_exact_entry(
        &mut self,
        key: Vec<KeySym>,
        fingerprint: u64,
        prompt_len: usize,
        ar: &mut ActiveRequest,
        logits: &[f32],
    ) {
        if ar.slab.is_empty() {
            return;
        }
        let pages = ar.slab.mark_all_shared();
        let snapshot = ar.slab.meta().to_vec();
        let mut pool = lock_profiled(&self.pool, &self.obs);
        self.prefix.register(
            &mut pool,
            key,
            fingerprint,
            pages,
            snapshot,
            prompt_len,
            logits.to_vec(),
        );
    }

    /// Register a cold prefill's *unpruned* visual prefix as a partial
    /// warm-start donor: copy the prefix KV out of the prefill output
    /// into fresh cache-owned pages and store it with the prefix-row DAP
    /// contributions (`dap_psum`/`dap_pmax`). Best-effort — under pool
    /// pressure the copy is skipped rather than starving live lanes.
    fn register_prefix_entry(
        &mut self,
        pp: &PartialProbe,
        probe_key: &[KeySym],
        req: &Request,
        out: &PrefillOut,
    ) {
        let p = pp.prefix_tokens;
        let ps = self.cfg.page_slots.max(1);
        let n_pages = pages_for_slots(p, ps);
        if n_pages == 0 {
            return;
        }
        self.reclaim_pool_headroom(n_pages);
        let mut pool = lock_profiled(&self.pool, &self.obs);
        if pool.free_pages() < n_pages {
            return;
        }
        let mut pages = Vec::with_capacity(n_pages);
        for _ in 0..n_pages {
            match pool.alloc() {
                Some(pg) => pages.push(pg),
                None => {
                    for &pg in &pages {
                        pool.release(pg);
                    }
                    return;
                }
            }
        }
        let row = pool.row();
        let n_layers = pool.n_layers();
        for slot in 0..p {
            let (pg, off) = (pages[slot / ps], slot % ps);
            for l in 0..n_layers {
                let src = (l * out.bucket + slot) * row;
                pool.write_layer_row(
                    pg,
                    off,
                    l,
                    &out.k[src..src + row],
                    &out.v[src..src + row],
                );
            }
        }
        let meta: Vec<SlotMeta> = (0..p)
            .map(|j| SlotMeta {
                position: j as i32,
                modality: if req.is_vision[j] { Modality::Vision } else { Modality::Text },
                cum_score: out.dap_psum[j],
                cum_peak: out.dap_pmax[j],
                last_score: out.dap_psum[j],
                marked: false,
                age: 0,
            })
            .collect();
        let key = probe_key[..pp.prefix_syms].to_vec();
        self.prefix
            .register_prefix(&mut pool, key, pp.prefix_fp, pages.clone(), meta, p);
        // the cache holds its own references now (or the registration was
        // refused): drop the allocation references either way, so refused
        // registrations leak nothing and accepted ones are cache-owned
        for &pg in &pages {
            pool.release(pg);
        }
    }

    /// The full prefill path; registers the retained pages (and, for
    /// partial-safe policies, the unpruned visual prefix) in the prefix
    /// cache when `probe` is set (cache enabled and this was a miss).
    fn prefill_cold(
        &mut self,
        req: Request,
        probe: Option<PrefixProbe>,
    ) -> Result<ActiveRequest> {
        let t_start = Instant::now();
        let m = self.meta().clone();
        let n = req.prompt_len();
        let bucket = self
            .manifest()
            .prefill_bucket(n)
            .ok_or_else(|| anyhow!("prompt of {} tokens exceeds largest bucket", n))?;

        // pad to bucket
        let mut ids = req.ids.clone();
        ids.resize(bucket, vocab::PAD);
        let mut patches = req.patches.clone();
        patches.resize(bucket * m.patch_dim, 0.0);
        let mut is_vision_f: Vec<f32> =
            req.is_vision.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect();
        is_vision_f.resize(bucket, 0.0);

        // the reusable-prefix boundary makes the graph also emit the
        // prefix-row-restricted DAP stats a prefix entry caches; 0 when
        // nothing will be registered at a boundary
        let register_prefix = self.cfg.policy.partial_safe();
        let n_prefix = probe
            .as_ref()
            .filter(|_| register_prefix)
            .and_then(|pr| pr.partial.as_ref())
            .map_or(0, |pp| pp.prefix_tokens);
        let sw = self.send_wait_mark();
        let (out, timing) =
            self.dev.prefill(bucket, &ids, &patches, &is_vision_f, n, n_prefix)?;
        self.send_wait_record(sw);

        let t_coord = Instant::now();
        let mut policy = self.cfg.policy.build();
        let mut is_vision = req.is_vision.clone();
        is_vision.resize(bucket, false);
        let pctx = PrefillCtx {
            dap_sum: &out.dap_sum,
            dap_max: &out.dap_max,
            is_vision: &is_vision,
            n_tokens: n,
            k: &out.k,
            v: &out.v,
            bucket,
            meta: &m,
        };
        let decision = policy.prefill(&pctx);
        if decision.retain.len() >= self.manifest().shapes.cache_capacity {
            bail!("prefill retain set exceeds cache capacity");
        }

        let modality: Vec<Modality> = is_vision
            .iter()
            .map(|&b| if b { Modality::Vision } else { Modality::Text })
            .collect();
        // a cache full of cold prefixes must never starve a live
        // admission: reclaim pool headroom for the injection first
        self.reclaim_pool_headroom(pages_for_slots(
            decision.retain.len(),
            self.cfg.page_slots.max(1),
        ));
        let mut slab = KvSlab::in_pool(&self.pool, self.manifest().shapes.cache_capacity);
        match &decision.kv_override {
            Some((k, v)) => slab.inject_prefill(
                k,
                v,
                bucket,
                &decision.retain,
                &modality,
                &out.dap_sum,
            ),
            None => slab.inject_prefill(
                &out.k,
                &out.v,
                bucket,
                &decision.retain,
                &modality,
                &out.dap_sum,
            ),
        }

        let prefill_len = slab.len();
        let first_token = self.sample(&out.logits);
        let mut stats = RequestStats {
            prefill_s: timing.total_s(),
            prompt_tokens: n,
            vision_tokens: req.n_vision(),
            pruned_at_prefill: n - prefill_len,
            peak_kv_bytes: slab.kv_bytes(),
            ..RequestStats::default()
        };
        stats.coord_s += t_coord.elapsed().as_secs_f64();
        stats.decisions = policy.decision_count();
        let _ = t_start;

        let mut ar = ActiveRequest {
            pos: n as i32,
            pending_token: first_token,
            req,
            slab,
            policy,
            generated: Vec::new(),
            prefill_len,
            done: false,
            forced: None,
            logits_trace: Vec::new(),
            score_trace: Vec::new(),
            evictions: Vec::new(),
            stats,
        };
        if self.cfg.capture_logits {
            ar.logits_trace.push(out.logits.clone());
        }
        // teacher-forcing replaces the sampled first token too (set by
        // generate_forced below before any decode step runs)
        ar.generated.push(first_token);
        self.check_done(&mut ar);
        // register the retained prompt so identical prompts skip all of
        // the above (the cache retains the slab's pages, which become
        // copy-on-write — this request's own decode forks before any
        // write), and the unpruned visual prefix so prefix-sharing
        // prompts get partial warm starts with a per-request DAP replay
        if let Some(pr) = probe {
            self.register_exact_entry(
                pr.key.clone(),
                pr.fingerprint,
                n,
                &mut ar,
                &out.logits,
            );
            if register_prefix {
                if let Some(pp) = &pr.partial {
                    self.register_prefix_entry(pp, &pr.key, &ar.req, &out);
                }
            }
        }
        Ok(ar)
    }

    // ------------------------------------------------------------------
    // decode
    // ------------------------------------------------------------------

    /// One batched decode step over up to `cfg.batch` unfinished lanes —
    /// submit immediately followed by complete, no overlap window. The
    /// sequential baseline (`--engine-threads 1`) and the compatibility
    /// surface for existing drivers, benches and tests.
    pub fn decode_step(&mut self, lanes: &mut [&mut ActiveRequest]) -> Result<StepReport> {
        let b = self.cfg.batch;
        if lanes.len() > b {
            bail!("{} lanes > batch width {}", lanes.len(), b);
        }
        let mut live: Vec<(usize, &mut ActiveRequest)> = lanes
            .iter_mut()
            .enumerate()
            .filter(|(_, ar)| !ar.done)
            .map(|(i, ar)| (i, &mut **ar))
            .collect();
        if live.is_empty() {
            return Ok(StepReport::default());
        }
        let pending = self.submit_live(&mut live)?;
        self.complete_live(pending, &mut live)
    }

    /// Submit a decode step over an `Option`-lane slot map without
    /// waiting for the device. All host pre-work (headroom reclaim,
    /// capacity bucketing, dirty-page gather) runs here; then the batch
    /// leaves for the device thread with the scratch buffers inside it.
    /// Returns `None` when no lane is live.
    ///
    /// The returned [`PendingStep`] records *slot indices*, so between
    /// submit and [`Engine::step_complete`] the caller may fill `None`
    /// slots (speculative backfill: admission, prefix probes, prefill /
    /// extend of the next candidate) — but must leave submitted lanes
    /// untouched.
    pub fn step_submit(
        &mut self,
        lanes: &mut [Option<ActiveRequest>],
    ) -> Result<Option<PendingStep>> {
        if lanes.len() > self.cfg.batch {
            bail!("{} lanes > batch width {}", lanes.len(), self.cfg.batch);
        }
        let mut live: Vec<(usize, &mut ActiveRequest)> = lanes
            .iter_mut()
            .enumerate()
            .filter_map(|(i, l)| l.as_mut().filter(|ar| !ar.done).map(|ar| (i, ar)))
            .collect();
        if live.is_empty() {
            return Ok(None);
        }
        self.submit_live(&mut live).map(Some)
    }

    /// Collect a submitted step: wait for the device reply, run the
    /// per-lane post-processing (KV append, score accumulation, policy
    /// eviction, sampling, termination) and retire finished lanes. The
    /// second return pairs each retired request with its lane slot, as
    /// `step_lanes` does.
    pub fn step_complete(
        &mut self,
        pending: PendingStep,
        lanes: &mut [Option<ActiveRequest>],
    ) -> Result<(StepReport, Vec<(usize, ActiveRequest)>)> {
        let report = {
            // re-collect exactly the submitted slots, in submission
            // order — backfill may have filled other slots meanwhile
            let mut by_slot: Vec<Option<&mut ActiveRequest>> =
                lanes.iter_mut().map(|l| l.as_mut()).collect();
            let mut live: Vec<(usize, &mut ActiveRequest)> =
                Vec::with_capacity(pending.slots.len());
            for &slot in &pending.slots {
                let ar = by_slot
                    .get_mut(slot)
                    .and_then(|s| s.take())
                    .ok_or_else(|| anyhow!("submitted lane {} vanished mid-flight", slot))?;
                live.push((slot, ar));
            }
            self.complete_live(pending, &mut live)?
        };
        let mut retired = Vec::new();
        for (i, lane) in lanes.iter_mut().enumerate() {
            if let Some(mut ar) = lane.take_if(|ar| ar.done) {
                // retired lanes return their arena pages immediately —
                // admission headroom must not wait for the caller to
                // drop the finished request
                ar.slab.release_pages();
                retired.push((i, ar));
            }
        }
        Ok((report, retired))
    }

    /// Decode scratch size: every lane at the largest compiled capacity.
    fn scratch_len(&self) -> usize {
        let m = self.meta();
        self.cfg.batch
            * m.n_layers
            * self.manifest().shapes.cache_capacity
            * m.n_heads
            * m.d_head
    }

    /// Shared submit path over `(slot, lane)` pairs in lane order.
    fn submit_live(
        &mut self,
        live: &mut [(usize, &mut ActiveRequest)],
    ) -> Result<PendingStep> {
        let b = self.cfg.batch;
        // worst-case allocations this step: one append page per live
        // lane plus a CoW fork of every page it still maps shared (a
        // policy flush compacting inside the shared prefix forks them
        // all). Reclaim cache-ONLY entries up front so idle pins never
        // turn into an alloc panic mid-step; entries kept alive by live
        // lanes are left alone (evicting them frees nothing), and with
        // an unconstrained pool this check never evicts anything
        let need: usize = live.len()
            + live.iter().map(|(_, ar)| ar.slab.shared_pages()).sum::<usize>();
        self.reclaim_pool_headroom(need);
        let m = self.meta().clone();
        let t0 = Instant::now();

        // capacity bucket: smallest compiled C strictly above the longest
        // live cache in the batch
        let max_len = live.iter().map(|(_, ar)| ar.slab.len()).max().unwrap_or(0);
        let capacity = self
            .manifest()
            .capacity_bucket(max_len)
            .ok_or_else(|| anyhow!("cache length {} exceeds all buckets", max_len))?;

        let row = m.n_heads * m.d_head;
        let slab_n = b * m.n_layers * capacity * row;
        // scratch regions beyond each lane's live length are NOT zeroed:
        // stale floats are finite and the decode graph masks slots ≥ len
        // before the softmax, so skipping the clear saves a full
        // buffer-sized memset per step (§Perf opt 1).
        let mut k = self
            .scratch_k
            .take()
            .ok_or_else(|| anyhow!("decode step already in flight"))?;
        let mut v = self
            .scratch_v
            .take()
            .ok_or_else(|| anyhow!("scratch buffers travel together"))?;

        let mut tokens = vec![0i32; b];
        let mut positions = vec![0i32; b];
        let mut lengths = vec![0i32; b];
        let mut pages_copied = 0usize;
        let mut slots = Vec::with_capacity(live.len());
        for (lane, (slot, ar)) in live.iter_mut().enumerate() {
            slots.push(*slot);
            tokens[lane] = ar.pending_token;
            positions[lane] = ar.pos;
            lengths[lane] = ar.slab.len() as i32;
            // a different slab wrote this lane since our last sync (lane
            // churn, or another driver on this engine): full resync
            if self.lane_owner[lane] != ar.slab.sync_id() {
                ar.slab.invalidate_sync();
                self.lane_owner[lane] = ar.slab.sync_id();
            }
            // incremental page-granular gather: pages untouched since the
            // last step at this (lane, capacity) are already in scratch
            pages_copied += ar.slab.copy_into_lane(
                &mut k[..slab_n],
                &mut v[..slab_n],
                lane,
                capacity,
            );
        }
        let assemble_s = t0.elapsed().as_secs_f64();
        let sw = self.send_wait_mark();
        let rx = match self.dev.decode_async(b, capacity, tokens, positions, k, v, lengths) {
            Ok(rx) => {
                self.send_wait_record(sw);
                rx
            }
            Err(e) => {
                // the send consumed the scratch; restore fresh buffers so
                // the engine object stays usable past the error
                let n = self.scratch_len();
                self.scratch_k = Some(vec![0.0; n]);
                self.scratch_v = Some(vec![0.0; n]);
                self.lane_owner.fill(0);
                return Err(e);
            }
        };
        Ok(PendingStep {
            slots,
            capacity,
            rx,
            assemble_s,
            pages_copied,
            submitted_at: Instant::now(),
        })
    }

    /// Shared completion path: `live` must hold exactly the submitted
    /// lanes, in submission order.
    fn complete_live(
        &mut self,
        pending: PendingStep,
        live: &mut [(usize, &mut ActiveRequest)],
    ) -> Result<StepReport> {
        debug_assert_eq!(live.len(), pending.slots.len());
        // host time the caller spent between submit and this wait — the
        // realized overlap window (the scheduler caps it at pjrt_s when
        // it aggregates the overlap fraction)
        let overlap_host_s = pending.submitted_at.elapsed().as_secs_f64();
        let done = pending
            .rx
            .recv()
            .map_err(|_| anyhow!("device thread disconnected mid-step"))?;
        // scratch comes home first: an Err step must not lose the buffers
        self.scratch_k = Some(done.k);
        self.scratch_v = Some(done.v);
        let (out, timing) = done.result?;
        let m = self.meta().clone();

        self.last_timing = timing;
        // one enabled-check per step keeps the disabled path to a single
        // atomic load (the <2% overhead guardrail measures both modes)
        let obs_on = self.obs.enabled();
        if obs_on {
            self.obs.inner().decode_step_ms.record(timing.total_s() * 1000.0);
        }
        let t1 = Instant::now();
        let live_n = live.len();
        for (lane, (_, ar)) in live.iter_mut().enumerate() {
            self.post_lane(ar, lane, live_n, &out, &timing, &m, obs_on);
        }
        let coord_s = pending.assemble_s + t1.elapsed().as_secs_f64();
        for (_, ar) in live.iter_mut() {
            ar.stats.coord_s += coord_s / live_n as f64;
        }
        Ok(StepReport {
            capacity: pending.capacity,
            lanes: live_n,
            pjrt_s: timing.total_s(),
            coord_s,
            pages_copied: pending.pages_copied,
            overlap_host_s,
        })
    }

    /// Post-device processing for one lane of a completed step: append
    /// the new token's KV, fold attention scores into the policy, apply
    /// its eviction decision (with the CoW affordability gate and the
    /// capacity-wall fallback), sample the next token and account.
    #[allow(clippy::too_many_arguments)]
    fn post_lane(
        &mut self,
        ar: &mut ActiveRequest,
        lane: usize,
        live_n: usize,
        out: &DecodeOut,
        timing: &StepTiming,
        m: &ModelMeta,
        obs_on: bool,
    ) {
        {
            let step = ar.generated.len() - 1; // index of the token just processed

            // 1. append the processed token's KV
            let k_new = out.lane_kv(m, &out.k_new, lane).to_vec();
            let v_new = out.lane_kv(m, &out.v_new, lane).to_vec();
            let self_score = out.lane_self_score(lane);
            let modality = Modality::Text; // generated tokens are text
            ar.slab.append(&k_new, &v_new, ar.pos, modality, self_score);
            ar.pos += 1;

            // 2. accumulate this step's attention mass (mean + peak,
            // already reduced in-graph — §Perf opt 2). The graph emits
            // capacity-length vectors with slots ≥ length masked to
            // zero; slice to the live slots the slab expects.
            let live_len = ar.slab.len();
            ar.slab.add_scores(
                &out.lane_mean(lane)[..live_len],
                &out.lane_peak(lane)[..live_len],
            );
            if self.cfg.capture_scores {
                let snap: Vec<(i32, f32)> = ar
                    .slab
                    .meta()
                    .iter()
                    .enumerate()
                    .map(|(c, sm)| (sm.position, out.lane_mean(lane)[c]))
                    .collect();
                ar.score_trace.push(snap);
            }

            // 3. policy decision
            let ctx = DecodeCtx {
                slab: &ar.slab,
                step,
                prefill_len: ar.prefill_len,
                capacity_limit: self.manifest().shapes.cache_capacity - 1,
            };
            let decision = ar.policy.post_step(&ctx);
            for &s in &decision.mark {
                ar.slab.meta_mut()[s].marked = true;
            }
            if !decision.evict.is_empty() {
                // CoW affordability gate: an eviction inside a shared
                // prefix may fork up to every page this lane still maps
                // shared, and the OTHER lanes' appends this step must
                // still find pages (an append's exhaustion is a panic,
                // not a deferral). Defer the eviction unless the pool
                // can afford both; a fork-free eviction (nothing shared)
                // always proceeds.
                let affordable = ar.slab.shared_pages() == 0 || {
                    let pool = lock_profiled(&self.pool, &self.obs);
                    pool.free_pages() >= ar.slab.shared_pages() + live_n
                };
                if affordable {
                    let victims: Vec<(i32, f32, bool)> = decision
                        .evict
                        .iter()
                        .map(|&s| {
                            let sm = &ar.slab.meta()[s];
                            (sm.position, sm.cum_score, sm.marked)
                        })
                        .collect();
                    let forks_before = (obs_on && ar.slab.shared_pages() > 0)
                        .then(|| self.pool_forks());
                    match ar.slab.try_evict(&decision.evict) {
                        Some(evicted) => {
                            ar.evictions.push(EvictionEvent { step, victims });
                            ar.stats.evicted_at_decode += evicted;
                            if obs_on {
                                let forked = forks_before.map_or(0, |f0| {
                                    self.pool_forks() - f0
                                });
                                let mut o = self.obs.inner();
                                o.evicted_per_decision.record(evicted as f64);
                                o.trace.record(
                                    ar.req.id,
                                    TraceEvent::Evict {
                                        kind: EvictKind::Policy,
                                        slots: evicted as u32,
                                    },
                                );
                                if forked > 0 {
                                    o.trace.record(
                                        ar.req.id,
                                        TraceEvent::CowFork { pages: forked as u32 },
                                    );
                                }
                            }
                        }
                        None => {
                            // CoW fork exhausted mid-divergence: defer —
                            // the slab is untouched, the policy
                            // re-decides next step, and pages free as
                            // lanes retire or the cache reclaims. The
                            // recoverable form of the PR-3 fork panic.
                            self.fork_deferrals += 1;
                        }
                    }
                } else {
                    self.fork_deferrals += 1;
                }
            }
            // hard capacity fallback
            let limit = self.manifest().shapes.cache_capacity - 1;
            if ar.slab.len() >= limit {
                let need = ar.slab.len() + 1 - limit;
                let ctx = DecodeCtx {
                    slab: &ar.slab,
                    step,
                    prefill_len: ar.prefill_len,
                    capacity_limit: limit,
                };
                let force = ar.policy.capacity_fallback(&ctx, need);
                let victims: Vec<(i32, f32, bool)> = force
                    .iter()
                    .map(|&s| {
                        let sm = &ar.slab.meta()[s];
                        (sm.position, sm.cum_score, sm.marked)
                    })
                    .collect();
                match ar.slab.try_evict(&force) {
                    Some(evicted) => {
                        ar.evictions.push(EvictionEvent { step, victims });
                        ar.stats.evicted_at_decode += evicted;
                        if obs_on {
                            let mut o = self.obs.inner();
                            o.evicted_per_decision.record(evicted as f64);
                            o.trace.record(
                                ar.req.id,
                                TraceEvent::Evict {
                                    kind: EvictKind::Capacity,
                                    slots: evicted as u32,
                                },
                            );
                        }
                    }
                    None => {
                        // the hard wall cannot wait for a retry: the next
                        // append needs a slot, and possibly a page for
                        // the tail. Fall back to the fork-free aligned
                        // tail drop — no CoW, frees at least one whole
                        // page, and the aligned tail means the next
                        // append allocates fresh instead of forking.
                        // Sacrifices the newest context; counted as an
                        // emergency (NOT as a deferral — nothing is
                        // retried later), and the admission fork
                        // allowance makes it vanishingly rare.
                        let keep = ar.slab.tail_drop_keep(need);
                        let victims: Vec<(i32, f32, bool)> = ar.slab.meta()[keep..]
                            .iter()
                            .map(|sm| (sm.position, sm.cum_score, sm.marked))
                            .collect();
                        let dropped = ar.slab.drop_tail_aligned(need);
                        if dropped > 0 {
                            self.emergency_tail_drops += 1;
                            ar.evictions.push(EvictionEvent { step, victims });
                            ar.stats.evicted_at_decode += dropped;
                            if obs_on {
                                let mut o = self.obs.inner();
                                o.evicted_per_decision.record(dropped as f64);
                                o.trace.record(
                                    ar.req.id,
                                    TraceEvent::Evict {
                                        kind: EvictKind::Emergency,
                                        slots: dropped as u32,
                                    },
                                );
                            }
                        }
                    }
                }
            }

            // 4. next token
            let logits = out.lane_logits(m, lane);
            if self.cfg.capture_logits {
                ar.logits_trace.push(logits.to_vec());
            }
            let next = match &ar.forced {
                Some(script) if ar.generated.len() < script.len() => {
                    script[ar.generated.len()]
                }
                _ => self.sample(logits),
            };
            ar.pending_token = next;
            ar.generated.push(next);

            // 5. accounting + termination
            if obs_on {
                self.obs.inner().trace.record(ar.req.id, TraceEvent::DecodeStep);
            }
            ar.stats.steps += 1;
            ar.stats.decode_s += timing.total_s() / live_n as f64;
            ar.stats.decisions = ar.policy.decision_count();
            ar.stats.peak_kv_bytes = ar.stats.peak_kv_bytes.max(ar.slab.kv_bytes());
            ar.stats.kv_byte_steps += ar.slab.kv_bytes() as u64;
            self.check_done(ar);
        }
    }

    /// Termination / continuation rules: hard stops are max_new_tokens and
    /// the positional-table limit; EOS stops the request unless the
    /// request's min_new_tokens floor hasn't been reached, in which case a
    /// new story segment is started instead (the multi-segment generation
    /// the paper's Seed-Story pipeline performs across turns).
    fn check_done(&self, ar: &mut ActiveRequest) {
        let m = self.meta();
        let last = *ar.generated.last().unwrap_or(&vocab::PAD);
        if ar.generated.len() >= ar.req.max_new_tokens
            || (ar.pos as usize) + 1 >= m.max_pos
        {
            ar.done = true;
            return;
        }
        if last == vocab::EOS && ar.forced.is_none() {
            if ar.generated.len() < ar.req.min_new_tokens {
                let n = ar.generated.len();
                ar.generated[n - 1] = vocab::STORY_MARK;
                ar.pending_token = vocab::STORY_MARK;
            } else {
                ar.done = true;
            }
        }
    }

    fn sample(&mut self, logits: &[f32]) -> i32 {
        if self.cfg.temperature <= 0.0 {
            return argmax(logits) as i32;
        }
        let k = self.cfg.top_k.max(1).min(logits.len());
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        // total_cmp: a single NaN logit must not panic the serving loop
        // mid-batch; NaNs sort above +inf, i.e. deterministically first
        idx.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]));
        idx.truncate(k);
        let inv_t = 1.0 / self.cfg.temperature;
        let weights: Vec<f64> = {
            let mx = logits[idx[0]];
            idx.iter().map(|&i| (((logits[i] - mx) * inv_t) as f64).exp()).collect()
        };
        idx[self.rng.weighted(&weights)] as i32
    }

    // ------------------------------------------------------------------
    // convenience drivers
    // ------------------------------------------------------------------

    /// Generate a full completion for one request (batch lane 0 only).
    /// The returned request's arena pages are already released (callers
    /// keep metadata, traces and stats), so results can be retained
    /// while the engine serves further requests.
    pub fn generate(&mut self, req: Request) -> Result<ActiveRequest> {
        let mut ar = self.prefill(req)?;
        while !ar.done {
            let mut lanes = [&mut ar];
            self.decode_step(&mut lanes)?;
        }
        ar.slab.release_pages();
        Ok(ar)
    }

    /// Generate with a teacher-forcing script (fidelity evaluation): the
    /// fed tokens follow `script`, while logits/evictions evolve under this
    /// engine's policy.
    pub fn generate_forced(&mut self, req: Request, script: &[i32]) -> Result<ActiveRequest> {
        let mut ar = self.prefill(req)?;
        ar.forced = Some(script.to_vec());
        if !script.is_empty() {
            // replace the sampled first token so the trajectory matches
            ar.generated[0] = script[0];
            ar.pending_token = script[0];
            ar.done = false;
            self.check_done(&mut ar);
        }
        while !ar.done && ar.generated.len() < script.len() {
            let mut lanes = [&mut ar];
            self.decode_step(&mut lanes)?;
        }
        // replay is over either way (done, or script exhausted)
        ar.slab.release_pages();
        Ok(ar)
    }

    /// Distinct arena pages charged once against the page budget: pages
    /// pinned by the prefix cache plus pages mapped shared by a live
    /// lane, deduplicated — N requests sharing one visual prefix pay for
    /// it once (the lanes' own bounds exclude their stable shared pages;
    /// see scheduler/admission.rs).
    ///
    /// A shared *partial tail* page is counted here **and** stays in its
    /// lane's private bound (`KvSlab::fork_allowance_pages`). PR 3
    /// excluded it to avoid the double charge — but the double charge is
    /// exactly the fork reservation: when the lane's first append forks
    /// the tail, the fresh copy lands in the lane's bound while the
    /// original keeps living under the cache pin. Excluding it left the
    /// forked-off original uncharged, which is precisely how a
    /// budget-sized pool admitted to the brim could exhaust at the fork
    /// site (the PR-3 panic).
    pub fn shared_charge_pages(&self, lanes: &[Option<ActiveRequest>]) -> usize {
        let mut set: std::collections::BTreeSet<u32> =
            self.prefix.pinned_page_ids().into_iter().collect();
        for ar in lanes.iter().flatten() {
            for p in ar.slab.shared_page_ids() {
                set.insert(p);
            }
        }
        set.len()
    }

    /// Admission test for engine-direct drivers: live lane bounds +
    /// charged-once shared pages + the candidate's worst case
    /// (discounted via its pre-hashed probe) versus the budget.
    /// Only *exact* hits earn a discount: a partial hit's replayed
    /// retention decision may fork any adopted page, so partial
    /// candidates are charged their full worst case — the fork
    /// allowance that keeps the replay's CoW allocations covered
    /// (`peek_discount` returns 0 for prefix entries by construction).
    /// Reclaimable LRU prefix-cache entries are evicted only while
    /// their pins can actually close the candidate's shortfall —
    /// entries kept alive by live lanes are never touched, and an
    /// unadmittable candidate never flushes the cache. The discount is
    /// re-probed (cheap trie lookup, no re-hash) after each eviction,
    /// since evicting could remove the very entry it came from.
    fn admit_with_reclaim(
        &mut self,
        ctl: &AdmissionController,
        lanes: &[Option<ActiveRequest>],
        req: &Request,
        probe: Option<&(Vec<KeySym>, u64)>,
    ) -> bool {
        loop {
            let live: usize =
                lanes.iter().flatten().map(|ar| ctl.lane_bound_pages(ar)).sum();
            let shared = self.shared_charge_pages(lanes);
            let discount =
                probe.map_or(0, |(k, fp)| self.prefix_discount_probed(k, *fp));
            let cand = ctl.worst_case_pages(req).saturating_sub(discount);
            let shortfall = ctl.shortfall_pages(live, shared, cand);
            if shortfall == 0 {
                return true;
            }
            if self.prefix_reclaimable_pages() < shortfall || !self.prefix_reclaim_one() {
                return false;
            }
        }
    }

    /// Lane lifecycle hook for schedulers: one batched decode step over a
    /// slot map (None = free lane), draining lanes that finished during
    /// the step. Returns the step report plus `(lane_index, request)` for
    /// each retired lane, so callers tracking per-lane context (the
    /// serving scheduler's reply channels) can pair them back up.
    pub fn step_lanes(
        &mut self,
        lanes: &mut [Option<ActiveRequest>],
    ) -> Result<(StepReport, Vec<(usize, ActiveRequest)>)> {
        match self.step_submit(lanes)? {
            None => Ok((StepReport::default(), Vec::new())),
            Some(pending) => self.step_complete(pending, lanes),
        }
    }

    /// Run a set of requests to completion with continuous batching;
    /// returns finished requests in completion order plus step reports.
    pub fn run_batched(
        &mut self,
        requests: Vec<Request>,
    ) -> Result<(Vec<ActiveRequest>, Vec<StepReport>)> {
        let b = self.cfg.batch;
        let ctl = self.pool_admission();
        // hash each prompt's prefix probe once up front: a request that
        // waits for headroom is re-tested every round, and re-hashing a
        // multi-KB vision prompt per attempt would dwarf the trie lookup
        let probes_on = self.prefix_enabled();
        let mut queue: std::collections::VecDeque<(Request, Option<(Vec<KeySym>, u64)>)> =
            requests
                .into_iter()
                .map(|r| {
                    let probe =
                        probes_on.then(|| (request_key(&r), request_fingerprint(&r)));
                    (r, probe)
                })
                .collect();
        let mut lanes: Vec<Option<ActiveRequest>> = (0..b).map(|_| None).collect();
        let mut finished = Vec::new();
        let mut reports = Vec::new();

        loop {
            // admit — gated by the same page-bound math the scheduler's
            // admission uses: when --kv-budget shrank the arena below
            // batch × capacity, requests wait for live lanes to retire
            // instead of exhausting the pool. Shared pages (prefix cache
            // + CoW lanes) are charged once; cache pins are reclaimed
            // before a candidate is turned away
            for i in 0..b {
                if lanes[i].is_some() {
                    continue;
                }
                let Some((req, probe)) = queue.front() else { break };
                if !self.admit_with_reclaim(&ctl, &lanes, req, probe.as_ref()) {
                    if lanes.iter().all(|l| l.is_none()) {
                        // defensive: the pool floor of one full lane and a
                        // fully-reclaimed cache mean a single request
                        // always fits an idle arena
                        bail!(
                            "request {} cannot fit the KV arena ({} pages)",
                            req.id,
                            ctl.budget_pages
                        );
                    }
                    break; // headroom frees as live lanes evict/retire
                }
                let Some((req, _)) = queue.pop_front() else { break };
                let mut ar = self.prefill(req)?;
                if ar.done {
                    ar.slab.release_pages();
                    finished.push(ar);
                } else {
                    lanes[i] = Some(ar);
                }
            }
            if lanes.iter().all(|l| l.is_none()) {
                if queue.is_empty() {
                    break;
                }
                continue;
            }
            let (report, retired) = self.step_lanes(&mut lanes)?;
            reports.push(report);
            finished.extend(retired.into_iter().map(|(_, ar)| ar));
        }
        Ok((finished, reports))
    }
}
