//! Per-request serving state: the KV slab, the policy instance, the
//! generation trace and the accounting the benches report.

use crate::cache::{EvictionPolicy, KvSlab};
use crate::workload::Request;

/// One eviction event (theory instrumentation: Corollary 2.1 compares the
/// realized eviction losses of DDES vs greedy).
#[derive(Debug, Clone)]
pub struct EvictionEvent {
    /// decode step at which the eviction was applied
    pub step: usize,
    /// (original position, cumulative score at eviction, was marked earlier)
    pub victims: Vec<(i32, f32, bool)>,
}

#[derive(Debug, Clone, Default)]
pub struct RequestStats {
    pub prefill_s: f64,
    pub decode_s: f64,
    /// host-side coordination time (everything outside PJRT calls)
    pub coord_s: f64,
    /// enqueue → admission wait under the serving scheduler (0 for
    /// engine-direct drivers, which never queue)
    pub queue_s: f64,
    /// suffix-recompute device time of a partial warm start: the portion
    /// of `prefill_s` spent inside chunked-extend calls (== `prefill_s`
    /// on the partial path, 0 for cold prefills and exact hits —
    /// `prefill_s` keeps its established semantics either way)
    pub extend_s: f64,
    pub steps: usize,
    pub prompt_tokens: usize,
    pub vision_tokens: usize,
    pub pruned_at_prefill: usize,
    pub evicted_at_decode: usize,
    /// admitted from the prefix cache (no PJRT prefill ran)
    pub prefix_hit: bool,
    /// prompt tokens never recomputed because of that hit (== the full
    /// prompt for an exact-match hit, 0 on the cold path)
    pub prefill_tokens_skipped: usize,
    /// suffix-recompute device calls a partial warm start issued
    /// (chunked extend calls + decode-loop fallbacks): ≤ ⌈suffix/chunk⌉
    /// at `--extend-chunk` chunk; 0 for cold prefills and exact hits
    pub extend_calls: usize,
    /// peak live KV bytes over the request lifetime
    pub peak_kv_bytes: usize,
    /// sum over steps of live KV bytes (for mean occupancy)
    pub kv_byte_steps: u64,
    /// eviction-decision computations (sorts) the policy performed
    pub decisions: u64,
}

impl RequestStats {
    pub fn mean_kv_bytes(&self) -> f64 {
        if self.steps == 0 {
            self.peak_kv_bytes as f64
        } else {
            self.kv_byte_steps as f64 / self.steps as f64
        }
    }

    pub fn total_s(&self) -> f64 {
        self.prefill_s + self.decode_s
    }
}

/// A request admitted into the engine.
pub struct ActiveRequest {
    pub req: Request,
    pub slab: KvSlab,
    pub policy: Box<dyn EvictionPolicy>,
    /// tokens generated so far (excludes prompt)
    pub generated: Vec<i32>,
    /// next global position index (monotonic — survives eviction)
    pub pos: i32,
    /// live length right after prefill injection (the paper's `l`)
    pub prefill_len: usize,
    /// token to feed at the next decode step
    pub pending_token: i32,
    pub done: bool,
    /// teacher-forcing script (fidelity eval): when set, pending tokens
    /// come from here instead of sampling
    pub forced: Option<Vec<i32>>,
    /// per-step logits (kept only when the engine's capture_logits is on)
    pub logits_trace: Vec<Vec<f32>>,
    /// per-step (position, mean attention mass) snapshots (kept only when
    /// capture_scores is on; theory forward-loss measurement)
    pub score_trace: Vec<Vec<(i32, f32)>>,
    pub evictions: Vec<EvictionEvent>,
    pub stats: RequestStats,
}

impl ActiveRequest {
    pub fn generated_len(&self) -> usize {
        self.generated.len()
    }

    /// true once the request has produced all it is going to produce
    pub fn finished(&self) -> bool {
        self.done
    }
}
