//! R1 — lock-order rule.
//!
//! Enforces the deadlock discipline from docs/CONCURRENCY.md: PagePool
//! before Obs, never the reverse, and no guard of either held across a
//! device call or a channel send. The checker tracks `let`-bound guards
//! per line: a guard is born on the line that binds a pool/obs lock
//! expression and dies when the brace depth falls back to (or below)
//! its binding depth or an explicit `drop(name)` appears.
//!
//! Matching is lexical, tuned to this tree's idioms: pool locks go
//! through `cache::paged::lock_pool` / `lock_profiled` or a `.lock()`
//! whose receiver chain names a pool; obs access goes through
//! `.record(…)` / `.event(…)` / `.inner()` on an `obs`-named chain;
//! router replica-state locks are a `.lock()` whose chain names a
//! replica or the router (the serving tier keeps replica health in
//! lock-free atomics precisely so no such guard exists — if one ever
//! appears, it must not be held across a dispatch into a replica's
//! ingest channel, where a full mailbox blocks the router).
//! A `let` whose right-hand side spans lines is not tracked — `cargo
//! fmt` keeps the call opener on the binding line everywhere we care.

use super::lexer::{chain_before, has_call_token, SourceFile};
use super::{Finding, R1};

#[derive(PartialEq, Eq, Clone, Copy)]
enum GuardKind {
    Pool,
    Obs,
    /// Router replica-state lock (replica table, health map, …).
    Router,
}

struct Guard {
    /// Binding name, empty for patterns we cannot name (tuples etc.);
    /// unnamed guards still expire by depth.
    name: String,
    kind: GuardKind,
    /// Brace depth at the start of the binding line.
    depth: usize,
}

fn acquires_pool(code: &str) -> bool {
    if has_call_token(code, "lock_profiled(") || has_call_token(code, "lock_pool(") {
        return true;
    }
    code.match_indices(".lock()")
        .any(|(i, _)| chain_before(code, i).to_ascii_lowercase().contains("pool"))
}

fn acquires_router(code: &str) -> bool {
    code.match_indices(".lock()").any(|(i, _)| {
        let chain = chain_before(code, i).to_ascii_lowercase();
        !chain.contains("pool") && (chain.contains("replica") || chain.contains("router"))
    })
}

fn takes_obs(code: &str, in_obs_file: bool) -> bool {
    for pat in [".record(", ".event(", ".inner()"] {
        for (i, _) in code.match_indices(pat) {
            let chain = chain_before(code, i).to_ascii_lowercase();
            if chain.contains("obs") || (in_obs_file && pat == ".inner()" && chain == "self") {
                return true;
            }
        }
    }
    false
}

fn binds_obs_guard(rhs: &str, in_obs_file: bool) -> bool {
    rhs.match_indices(".inner()").any(|(i, _)| {
        let chain = chain_before(rhs, i).to_ascii_lowercase();
        chain.contains("obs") || (in_obs_file && chain == "self")
    })
}

fn touches_device(code: &str) -> bool {
    code.contains(".dev.") || code.contains(".send(")
}

fn drops_name(code: &str, name: &str) -> bool {
    if name.is_empty() {
        return false;
    }
    for (i, _) in code.match_indices("drop(") {
        if super::lexer::prev_is_ident(code, i) {
            continue;
        }
        if let Some(rest) = code[i + 5..].strip_prefix(name) {
            if rest.starts_with(')') {
                return true;
            }
        }
    }
    false
}

/// Extract `(binding_name, rhs)` from a `let name[: Ty] = rhs;` line.
/// Destructuring patterns yield an empty name (depth-only expiry).
fn guard_binding(code: &str) -> Option<(String, &str)> {
    let rest = code.trim_start().strip_prefix("let ")?;
    let eq = rest.find('=')?;
    let (pat, rhs) = (rest[..eq].trim(), &rest[eq + 1..]);
    let pat = pat.strip_prefix("mut ").unwrap_or(pat);
    let name = pat.split(':').next().unwrap_or("").trim();
    let named = !name.is_empty()
        && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_');
    Some((if named { name.to_string() } else { String::new() }, rhs))
}

pub fn check(file: &SourceFile) -> Vec<Finding> {
    let in_obs_file = file.path.contains("/obs/");
    let mut findings = Vec::new();
    let mut guards: Vec<Guard> = Vec::new();
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test {
            guards.clear();
            continue;
        }
        let code = line.code.as_str();
        // Expire guards whose scope closed. A line that *starts* with a
        // closing brace at the guard's own depth (`}`, `} else {`) ends
        // that guard's block even though the depth momentarily matches.
        let closes = code.trim_start().starts_with('}');
        guards.retain(|g| line.depth > g.depth || (line.depth == g.depth && !closes));
        guards.retain(|g| !drops_name(code, &g.name));

        let pool_live = guards.iter().any(|g| g.kind == GuardKind::Pool);
        let obs_live = guards.iter().any(|g| g.kind == GuardKind::Obs);
        let router_live = guards.iter().any(|g| g.kind == GuardKind::Router);
        let acq_pool = acquires_pool(code);
        let obs_touch = takes_obs(code, in_obs_file);
        let ln = idx + 1;
        if (pool_live || acq_pool) && obs_touch {
            findings.push(Finding {
                file: file.path.clone(),
                line: ln,
                rule: R1,
                message: "Obs lock taken while a PagePool guard is live".to_string(),
                hint: "record after the pool guard drops, or use the atomic enabled() gate",
            });
        }
        if obs_live && acq_pool {
            findings.push(Finding {
                file: file.path.clone(),
                line: ln,
                rule: R1,
                message: "PagePool lock taken while an Obs lock is live — inverts the documented order"
                    .to_string(),
                hint: "acquire the pool first: the order is PagePool before Obs (docs/CONCURRENCY.md)",
            });
        }
        if pool_live && acq_pool {
            findings.push(Finding {
                file: file.path.clone(),
                line: ln,
                rule: R1,
                message: "second PagePool lock while a PagePool guard is live".to_string(),
                hint: "reuse the live guard, or drop it before re-locking",
            });
        }
        if (pool_live || obs_live) && touches_device(code) {
            findings.push(Finding {
                file: file.path.clone(),
                line: ln,
                rule: R1,
                message: "device call or channel send while a lock guard is live".to_string(),
                hint: "drop the guard before crossing the device channel (docs/CONCURRENCY.md)",
            });
        }
        // The routing-tier discipline: a replica ingest channel's send
        // blocks when that replica's mailbox is full, so holding any
        // router replica-state lock across it stalls every other
        // replica's traffic (and can deadlock against a replica that
        // needs that lock to drain). docs/SERVING.md requires health to
        // stay in atomics; this catches the lock that sneaks back in.
        if router_live && code.contains(".send(") {
            findings.push(Finding {
                file: file.path.clone(),
                line: ln,
                rule: R1,
                message: "router replica-state lock held across a dispatch into a replica ingest channel"
                    .to_string(),
                hint: "snapshot under the lock, drop it, then send (docs/SERVING.md)",
            });
        }
        if let Some((name, rhs)) = guard_binding(code) {
            if acquires_pool(rhs) {
                guards.push(Guard { name, kind: GuardKind::Pool, depth: line.depth });
            } else if binds_obs_guard(rhs, in_obs_file) {
                guards.push(Guard { name, kind: GuardKind::Obs, depth: line.depth });
            } else if acquires_router(rhs) {
                guards.push(Guard { name, kind: GuardKind::Router, depth: line.depth });
            }
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::super::fixtures;
    use super::super::lexer::parse;
    use super::*;

    fn run(src: &str) -> Vec<Finding> {
        check(&parse("rust/src/cache/fixture.rs", src, false))
    }

    #[test]
    fn obs_under_pool_guard_fires_on_the_record_line() {
        let f = run(fixtures::R1_OBS_UNDER_POOL_GUARD);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, R1);
        assert_eq!(f[0].line, 4);
        assert!(f[0].message.contains("Obs lock"));
    }

    #[test]
    fn guard_across_device_call_fires() {
        let f = run(fixtures::R1_GUARD_ACROSS_DEVICE);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 4);
        assert!(f[0].message.contains("device call"));
    }

    #[test]
    fn inversion_fires() {
        let f = run(fixtures::R1_INVERSION);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 4);
        assert!(f[0].message.contains("inverts"));
    }

    #[test]
    fn send_under_guard_fires() {
        let f = run(fixtures::R1_SEND_UNDER_GUARD);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 4);
        assert!(f[0].message.contains("send"));
    }

    #[test]
    fn router_lock_across_dispatch_fires() {
        let f = check(&parse(
            "rust/src/router/fixture.rs",
            fixtures::R1_ROUTER_LOCK_ACROSS_DISPATCH,
            false,
        ));
        assert_eq!(f.len(), 1, "got: {f:?}");
        assert_eq!(f[0].rule, R1);
        assert_eq!(f[0].line, 4);
        assert!(f[0].message.contains("replica ingest channel"));
    }

    #[test]
    fn snapshot_then_send_is_clean() {
        // the sanctioned shape: copy what you need under the lock, drop
        // it, then dispatch
        let src = "fn ok(&self) {\n    let state = self.replicas.lock().unwrap();\n    let tx = state.links[0].tx.clone();\n    drop(state);\n    tx.send(job).unwrap();\n}\n";
        let f = check(&parse("rust/src/router/fixture.rs", src, false));
        assert!(f.is_empty(), "unexpected: {f:?}");
    }

    #[test]
    fn pool_chain_lock_is_not_a_router_guard() {
        // "replica_pool.lock()" is a pool lock; sending under it must
        // report the device-channel message, not the router one
        let src = "fn bad(&self) {\n    let pool = self.replica_pool.lock().unwrap();\n    self.tx.send(pool.free_pages()).ok();\n    drop(pool);\n}\n";
        let f = check(&parse("rust/src/router/fixture.rs", src, false));
        assert_eq!(f.len(), 1, "got: {f:?}");
        assert!(f[0].message.contains("device call or channel send"));
    }

    #[test]
    fn profiled_lock_helper_shape_is_clean() {
        // The canonical lock_profiled body: the else-branch re-lock must
        // not be seen as a second lock (the if-branch guard died at `}`).
        let src = "fn lp(&self) -> G {\n    if self.obs.enabled() {\n        let guard = lock_pool(&self.pool);\n        guard\n    } else {\n        lock_pool(&self.pool)\n    }\n}\n";
        let f = check(&parse("rust/src/cache/paged.rs", src, false));
        assert!(f.is_empty(), "unexpected: {f:?}");
    }

    #[test]
    fn drop_releases_the_guard() {
        let src = "fn ok(&self) {\n    let pool = lock_pool(&self.pool);\n    drop(pool);\n    self.obs.record(|o| o.n += 1);\n}\n";
        assert!(run(src).is_empty());
    }

    #[test]
    fn guards_do_not_leak_across_test_code() {
        let f = check(&parse("rust/src/cache/fixture.rs", fixtures::R1_OBS_UNDER_POOL_GUARD, true));
        assert!(f.is_empty());
    }
}
