//! A small line-oriented Rust lexer for `hae-lint`.
//!
//! Produces, per source line: the code text with comments stripped and
//! literal payloads blanked, the comment text, the contents of string
//! literals that close on the line, the brace depth at line start, and
//! whether the line sits inside `#[cfg(test)]` code. Rule matchers run
//! on `code`, so they can never fire on prose in a comment or on a
//! pattern quoted inside a string literal.
//!
//! This is deliberately not a full parser. The rules it feeds are
//! occurrence matchers over individual lines, and the tree is
//! `cargo fmt`-normalised (CI runs `cargo fmt --check`), so line-level
//! structure is stable enough to lean on.

/// One lexed source line.
#[derive(Debug, Clone, Default)]
pub struct LineInfo {
    /// Code with comments removed and string/char payloads blanked
    /// (delimiters kept, so quotes still mark where a literal sat).
    pub code: String,
    /// Comment text on the line (`//…` and `/* … */` payloads).
    pub comment: String,
    /// Contents of string literals that close on this line.
    pub strings: Vec<String>,
    /// Brace depth at the start of the line.
    pub depth: usize,
    /// Line is inside `#[cfg(test)]` code (or the whole file is tests).
    pub in_test: bool,
}

/// A lexed file: repo-relative path plus one [`LineInfo`] per line.
#[derive(Debug, Clone)]
pub struct SourceFile {
    pub path: String,
    pub lines: Vec<LineInfo>,
}

#[derive(Clone, Copy)]
enum Mode {
    Code,
    LineComment,
    BlockComment(usize),
    Str,
    RawStr(usize),
}

fn ends_in_ident(code: &str) -> bool {
    code.chars().next_back().is_some_and(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Lex `text` into per-line records. `assume_test` marks every line as
/// test code — used for integration tests and benches, which are test
/// targets in their entirety.
pub fn parse(path: &str, text: &str, assume_test: bool) -> SourceFile {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut lines: Vec<LineInfo> = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut strings: Vec<String> = Vec::new();
    let mut cur_str = String::new();
    let mut depth: usize = 0;
    let mut line_depth: usize = 0;
    let mut mode = Mode::Code;
    let mut i = 0usize;
    loop {
        if i >= n || chars[i] == '\n' {
            if matches!(mode, Mode::LineComment) {
                mode = Mode::Code;
            }
            lines.push(LineInfo {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
                strings: std::mem::take(&mut strings),
                depth: line_depth,
                in_test: false,
            });
            line_depth = depth;
            if i >= n {
                break;
            }
            i += 1;
            continue;
        }
        let c = chars[i];
        match mode {
            Mode::Code => {
                if c == '/' && i + 1 < n && chars[i + 1] == '/' {
                    mode = Mode::LineComment;
                    i += 2;
                } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                    mode = Mode::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    cur_str.clear();
                    mode = Mode::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !ends_in_ident(&code) {
                    // possible raw / byte string: r"…", r#"…"#, br"…", b"…"
                    let mut j = i;
                    if chars[j] == 'b'
                        && j + 1 < n
                        && (chars[j + 1] == 'r' || chars[j + 1] == '"')
                    {
                        j += 1;
                    }
                    if chars[j] == 'r' {
                        let mut k = j + 1;
                        let mut hashes = 0usize;
                        while k < n && chars[k] == '#' {
                            hashes += 1;
                            k += 1;
                        }
                        if k < n && chars[k] == '"' {
                            for &ch in &chars[i..=k] {
                                code.push(ch);
                            }
                            cur_str.clear();
                            mode = Mode::RawStr(hashes);
                            i = k + 1;
                            continue;
                        }
                    } else if chars[j] == '"' {
                        code.push('b');
                        code.push('"');
                        cur_str.clear();
                        mode = Mode::Str;
                        i = j + 1;
                        continue;
                    }
                    code.push(c);
                    i += 1;
                } else if c == '\'' {
                    if i + 1 < n && chars[i + 1] == '\\' {
                        // escaped char literal: '\n', '\'', '\x41', '\u{…}'
                        let mut j = (i + 3).min(n);
                        while j < n && chars[j] != '\'' && chars[j] != '\n' {
                            j += 1;
                        }
                        code.push('\'');
                        code.push('\'');
                        i = if j < n && chars[j] == '\'' { j + 1 } else { j };
                    } else if i + 2 < n && chars[i + 2] == '\'' && chars[i + 1] != '\'' {
                        // plain char literal: blank the payload so a '{'
                        // or '"' inside never confuses depth/strings
                        code.push('\'');
                        code.push('\'');
                        i += 3;
                    } else {
                        // lifetime (or a stray quote)
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    if c == '{' {
                        depth += 1;
                    } else if c == '}' {
                        depth = depth.saturating_sub(1);
                    }
                    code.push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                comment.push(c);
                i += 1;
            }
            Mode::BlockComment(d) => {
                if c == '*' && i + 1 < n && chars[i + 1] == '/' {
                    mode = if d <= 1 { Mode::Code } else { Mode::BlockComment(d - 1) };
                    i += 2;
                } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                    // block comments nest in Rust
                    comment.push_str("/*");
                    mode = Mode::BlockComment(d + 1);
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    if i + 1 < n && chars[i + 1] != '\n' {
                        cur_str.push('\\');
                        cur_str.push(chars[i + 1]);
                        i += 2;
                    } else {
                        // line-continuation backslash; the newline is
                        // handled by the line flush above
                        i += 1;
                    }
                } else if c == '"' {
                    code.push('"');
                    strings.push(std::mem::take(&mut cur_str));
                    mode = Mode::Code;
                    i += 1;
                } else {
                    cur_str.push(c);
                    i += 1;
                }
            }
            Mode::RawStr(h) => {
                if c == '"' {
                    let mut k = i + 1;
                    let mut cnt = 0usize;
                    while cnt < h && k < n && chars[k] == '#' {
                        cnt += 1;
                        k += 1;
                    }
                    if cnt == h {
                        code.push('"');
                        for _ in 0..h {
                            code.push('#');
                        }
                        strings.push(std::mem::take(&mut cur_str));
                        mode = Mode::Code;
                        i = k;
                    } else {
                        cur_str.push('"');
                        i += 1;
                    }
                } else {
                    cur_str.push(c);
                    i += 1;
                }
            }
        }
    }
    let mut file = SourceFile { path: path.to_string(), lines };
    mark_tests(&mut file.lines, assume_test);
    file
}

/// Mark lines inside `#[cfg(test)]` items. A region starts at the line
/// carrying the attribute, covers the braced item that follows, and ends
/// when the brace depth returns to the opener's level.
fn mark_tests(lines: &mut [LineInfo], assume_test: bool) {
    if assume_test {
        for l in lines.iter_mut() {
            l.in_test = true;
        }
        return;
    }
    let mut pending = false;
    let mut region: Option<usize> = None;
    for line in lines.iter_mut() {
        if let Some(d) = region {
            if line.depth > d {
                line.in_test = true;
                continue;
            }
            region = None;
        }
        if line.code.contains("#[cfg(test)]") {
            pending = true;
        }
        if pending {
            line.in_test = true;
            if line.code.contains('{') {
                region = Some(line.depth);
                pending = false;
            } else if line.code.contains(';') {
                // attribute on a brace-less item (`#[cfg(test)] use …;`)
                pending = false;
            }
        }
    }
}

/// True when the byte before index `i` is an identifier character —
/// used to reject matches that are suffixes of longer identifiers.
pub fn prev_is_ident(code: &str, i: usize) -> bool {
    i > 0 && {
        let b = code.as_bytes()[i - 1];
        b.is_ascii_alphanumeric() || b == b'_'
    }
}

/// True when `tok` occurs in `code` as a standalone token (no identifier
/// character immediately before it), e.g. a call of that exact name.
pub fn has_call_token(code: &str, tok: &str) -> bool {
    code.match_indices(tok).any(|(i, _)| !prev_is_ident(code, i))
}

/// The dotted receiver chain ending just before byte `dot_idx`, e.g.
/// `self.obs` for the `.record(` in `self.obs.record(f)`. Walking back
/// over ASCII identifier bytes and dots is UTF-8 safe: multi-byte chars
/// never contain those byte values, so the stop point is a boundary.
pub fn chain_before(code: &str, dot_idx: usize) -> &str {
    let bytes = code.as_bytes();
    let mut start = dot_idx;
    while start > 0 {
        let b = bytes[start - 1];
        if b.is_ascii_alphanumeric() || b == b'_' || b == b'.' {
            start -= 1;
        } else {
            break;
        }
    }
    &code[start..dot_idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_matching_rejects_identifier_suffixes() {
        assert!(has_call_token("let g = lock_pool(&self.pool);", "lock_pool("));
        assert!(!has_call_token("let g = my_lock_pool(&self.pool);", "lock_pool("));
    }

    #[test]
    fn chain_walks_back_over_dotted_path() {
        let code = "self.obs.record(f);";
        let dot = code.find(".record(").unwrap();
        assert_eq!(chain_before(code, dot), "self.obs");
        let code2 = "o.profile.pool_lock_wait_ms.record(w);";
        let dot2 = code2.find(".record(").unwrap();
        assert_eq!(chain_before(code2, dot2), "o.profile.pool_lock_wait_ms");
    }

    #[test]
    fn comments_and_strings_are_separated() {
        let src = "let x = \"a // not a comment\"; // real comment\n";
        let f = parse("t.rs", src, false);
        assert!(!f.lines[0].code.contains("not a comment"));
        assert_eq!(f.lines[0].strings, vec!["a // not a comment".to_string()]);
        assert!(f.lines[0].comment.contains("real comment"));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let src = "let s = r#\"let g = pool.lock();\"#;\nlet y = 1;\n";
        let f = parse("t.rs", src, false);
        assert!(!f.lines[0].code.contains("pool.lock()"));
        assert_eq!(f.lines[0].strings, vec!["let g = pool.lock();".to_string()]);
        assert_eq!(f.lines[1].code, "let y = 1;");
    }

    #[test]
    fn char_literals_do_not_confuse_depth() {
        let src = "fn f() {\n    let open = '{';\n    let q = '\\'';\n}\nfn g() {}\n";
        let f = parse("t.rs", src, false);
        assert_eq!(f.lines[1].depth, 1);
        assert_eq!(f.lines[3].depth, 1); // the closing `}` line starts at depth 1
        assert_eq!(f.lines[4].depth, 0);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> &'a str {\n    x\n}\n";
        let f = parse("t.rs", src, false);
        assert_eq!(f.lines[1].depth, 1);
        assert_eq!(f.lines[2].depth, 1);
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {\n        x();\n    }\n}\nfn live2() {}\n";
        let f = parse("t.rs", src, false);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test);
        assert!(f.lines[2].in_test);
        assert!(f.lines[4].in_test);
        assert!(!f.lines[7].in_test);
    }

    #[test]
    fn assume_test_marks_everything() {
        let f = parse("t.rs", "fn a() {}\nfn b() {}\n", true);
        assert!(f.lines.iter().all(|l| l.in_test));
    }
}
