//! Suppression comments for `hae-lint`.
//!
//! A suppression is a comment of the form documented in
//! docs/STATIC_ANALYSIS.md: the literal marker (see `MARKER`), a rule id
//! (or unambiguous prefix, e.g. `R1`) in parentheses, then a mandatory
//! free-text reason. It silences matching findings on its own line and
//! on the next line, so it works both as a trailing comment and as a
//! standalone comment directly above the offending line.
//!
//! Suppressions are counted: a reason-less suppression is itself a
//! finding, and the tree-wide count is capped in `analysis::lint_tree`.

use super::lexer::SourceFile;
use super::{Finding, RULE_SUPPRESSION};

/// The comment marker, kept out of doc comments in this module so the
/// linter never parses its own documentation as a suppression.
const MARKER: &str = "hae-lint: allow(";

#[derive(Debug, Clone)]
pub struct Suppression {
    /// 1-based line the comment sits on.
    pub line: usize,
    /// Rule id (or prefix) named in the parentheses.
    pub rule: String,
    /// Free text after the closing paren; must be non-empty.
    pub reason: String,
    /// Set by [`apply`] when the suppression silenced a finding.
    pub used: bool,
}

/// Collect every suppression comment in the file.
pub fn collect(file: &SourceFile) -> Vec<Suppression> {
    let mut out = Vec::new();
    for (idx, line) in file.lines.iter().enumerate() {
        if let Some(p) = line.comment.find(MARKER) {
            let rest = &line.comment[p + MARKER.len()..];
            if let Some(close) = rest.find(')') {
                out.push(Suppression {
                    line: idx + 1,
                    rule: rest[..close].trim().to_string(),
                    reason: rest[close + 1..].trim().to_string(),
                    used: false,
                });
            }
        }
    }
    out
}

/// Filter `findings` through the suppressions, marking the ones that
/// fired. A suppression on line N silences findings on lines N and N+1
/// whose rule id starts with the named rule. A used suppression with an
/// empty reason is converted into a finding of its own — silencing
/// without saying why is exactly the review rot the linter exists to
/// stop.
pub fn apply(
    sups: &mut [Suppression],
    path: &str,
    findings: Vec<Finding>,
) -> Vec<Finding> {
    let mut kept = Vec::new();
    for f in findings {
        let mut silenced = false;
        for s in sups.iter_mut() {
            if (f.line == s.line || f.line == s.line + 1)
                && !s.rule.is_empty()
                && f.rule.starts_with(s.rule.as_str())
            {
                s.used = true;
                silenced = true;
                break;
            }
        }
        if !silenced {
            kept.push(f);
        }
    }
    for s in sups.iter().filter(|s| s.used && s.reason.is_empty()) {
        kept.push(Finding {
            file: path.to_string(),
            line: s.line,
            rule: RULE_SUPPRESSION,
            message: "suppression without a reason".to_string(),
            hint: "append a short justification after the closing paren",
        });
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::super::lexer::parse;
    use super::*;

    fn finding(line: usize, rule: &'static str) -> Finding {
        Finding {
            file: "t.rs".into(),
            line,
            rule,
            message: "m".into(),
            hint: "h",
        }
    }

    #[test]
    fn collects_rule_and_reason() {
        let src = format!("let x = 1; // {}R1-lock-order) profiler by design\n", MARKER);
        let f = parse("t.rs", &src, false);
        let sups = collect(&f);
        assert_eq!(sups.len(), 1);
        assert_eq!(sups[0].rule, "R1-lock-order");
        assert_eq!(sups[0].reason, "profiler by design");
    }

    #[test]
    fn silences_same_and_next_line_with_prefix_match() {
        let src = format!("// {}R1) fine here\nbad();\nbad();\n", MARKER);
        let f = parse("t.rs", &src, false);
        let mut sups = collect(&f);
        let out = apply(
            &mut sups,
            "t.rs",
            vec![finding(2, "R1-lock-order"), finding(3, "R1-lock-order")],
        );
        // line 2 silenced (next line), line 3 survives
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].line, 3);
        assert!(sups[0].used);
    }

    #[test]
    fn reasonless_suppression_becomes_a_finding() {
        let src = format!("// {}R1)\nbad();\n", MARKER);
        let f = parse("t.rs", &src, false);
        let mut sups = collect(&f);
        let out = apply(&mut sups, "t.rs", vec![finding(2, "R1-lock-order")]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, RULE_SUPPRESSION);
    }

    #[test]
    fn wrong_rule_does_not_silence() {
        let src = format!("bad(); // {}R2) not the right rule\n", MARKER);
        let f = parse("t.rs", &src, false);
        let mut sups = collect(&f);
        let out = apply(&mut sups, "t.rs", vec![finding(1, "R1-lock-order")]);
        assert_eq!(out.len(), 1);
        assert!(!sups[0].used);
    }

    #[test]
    fn marker_inside_a_string_is_not_a_suppression() {
        let src = format!("let s = \"{}R1) nope\";\n", MARKER);
        let f = parse("t.rs", &src, false);
        assert!(collect(&f).is_empty());
    }
}
