//! R2 — refcount pairing rule.
//!
//! Page refcounts follow the accounting discipline from PR 4/5: every
//! module that bumps a page's refcount (`retain_page` / `retain_all`)
//! must also route frees through the typed release paths (`release`,
//! `release_pages`) so the pair is reviewable in one place. A retain in
//! a module with no release path is how leaked pages and
//! `hae_refcount_errors_total` incidents start.
//!
//! CoW fork transfer points that intentionally hand the balancing
//! release to another module carry a per-site suppression comment.

use super::lexer::SourceFile;
use super::{Finding, R2};

pub fn check(file: &SourceFile) -> Vec<Finding> {
    let mut retains: Vec<usize> = Vec::new();
    let mut has_release = false;
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = line.code.as_str();
        if code.contains(".release(") || code.contains(".release_pages(") || code.contains("fn release")
        {
            has_release = true;
        }
        if code.contains(".retain_page(") || code.contains(".retain_all(") {
            retains.push(idx + 1);
        }
    }
    if has_release {
        return Vec::new();
    }
    retains
        .into_iter()
        .map(|line| Finding {
            file: file.path.clone(),
            line,
            rule: R2,
            message: "refcount retain in a module with no typed release path".to_string(),
            hint: "route frees through release_pages()/release(), or suppress at a reviewed CoW transfer point",
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::fixtures;
    use super::super::lexer::parse;
    use super::*;

    #[test]
    fn retain_without_release_fires_per_site() {
        let f = check(&parse("rust/src/prefix/fixture.rs", fixtures::R2_RETAIN_WITHOUT_RELEASE, false));
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, R2);
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn paired_module_is_clean() {
        let f = check(&parse("rust/src/prefix/fixture.rs", fixtures::R2_PAIRED, false));
        assert!(f.is_empty());
    }

    #[test]
    fn associated_fn_call_is_not_a_retain_site() {
        // PrefillDecision::retain_all(n) is a constructor, not a
        // refcount bump; only dotted method calls count.
        let src = "fn d(n: usize) -> PrefillDecision {\n    PrefillDecision::retain_all(n)\n}\n";
        assert!(check(&parse("rust/src/cache/fixture.rs", src, false)).is_empty());
    }
}
