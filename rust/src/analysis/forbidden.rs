//! R3 — forbidden-API rule.
//!
//! Bans, with per-scope precision:
//! - `Rc<…>` / `RefCell<…>` anywhere in `rust/src` — the engine is
//!   thread-parallel (PR 7); single-thread interior mutability is a
//!   data race waiting for a refactor. First occurrence per file is
//!   reported (one fix usually removes them all).
//! - `partial_cmp(..).unwrap()` on one line — panics on NaN; scores
//!   and latencies are floats, use `total_cmp`.
//! - `std::process::exit` outside `rust/src/bin/` — skips destructors,
//!   so the device thread never joins and artifacts flush half-written.
//! - fixed port literals in `rust/tests/` and `benches/` — parallel CI
//!   shards collide; bind port 0 and read back the assigned address.
//! - bare `unwrap()` / `expect(` in the engine hot path (`coordinator/`,
//!   `cache/`, `scheduler/`, `device/`) outside `#[cfg(test)]` — a
//!   panic there poisons the pool mutex for every in-flight request.

use super::lexer::{prev_is_ident, SourceFile};
use super::{Finding, R3};

const HOT_DIRS: [&str; 4] = [
    "rust/src/coordinator/",
    "rust/src/cache/",
    "rust/src/scheduler/",
    "rust/src/device/",
];

const HOST_PREFIXES: [&str; 3] = ["127.0.0.1:", "0.0.0.0:", "localhost:"];

/// First fixed (non-zero) port in a string literal, if any.
fn fixed_port(s: &str) -> Option<u32> {
    for pre in HOST_PREFIXES {
        if let Some(p) = s.find(pre) {
            let digits: String = s[p + pre.len()..]
                .chars()
                .take_while(|c| c.is_ascii_digit())
                .collect();
            if let Ok(port) = digits.parse::<u32>() {
                if port > 0 {
                    return Some(port);
                }
            }
        }
    }
    None
}

/// `Rc` as a standalone token followed by `<` or `::` — a use of the
/// type, not the `use std::rc::Rc;` import or an `Rc`-prefixed ident.
fn uses_rc(code: &str) -> bool {
    code.match_indices("Rc").any(|(i, _)| {
        let rest = &code[i + 2..];
        !prev_is_ident(code, i) && (rest.starts_with('<') || rest.starts_with("::"))
    })
}

fn uses_refcell(code: &str) -> bool {
    code.match_indices("RefCell").any(|(i, _)| {
        let next_ident = code[i + 7..]
            .chars()
            .next()
            .is_some_and(|c| c.is_ascii_alphanumeric() || c == '_');
        !prev_is_ident(code, i) && !next_ident
    })
}

pub fn check(file: &SourceFile) -> Vec<Finding> {
    let path = file.path.as_str();
    let hot = HOT_DIRS.iter().any(|d| path.starts_with(d));
    let in_bin = path.starts_with("rust/src/bin/") || path == "rust/src/main.rs";
    let port_scope = path.starts_with("rust/tests/") || path.starts_with("benches/");
    let mut out = Vec::new();
    let mut rc_seen = false;
    let mut refcell_seen = false;
    let mut push = |out: &mut Vec<Finding>, line: usize, message: String, hint: &'static str| {
        out.push(Finding { file: path.to_string(), line, rule: R3, message, hint });
    };
    for (idx, line) in file.lines.iter().enumerate() {
        let ln = idx + 1;
        if port_scope {
            // Applies to test code too — that is the whole point.
            for s in &line.strings {
                if let Some(port) = fixed_port(s) {
                    push(
                        &mut out,
                        ln,
                        format!("fixed port {port} in test/bench code"),
                        "bind port 0 and read the assigned address back",
                    );
                }
            }
        }
        if line.in_test {
            continue;
        }
        let code = line.code.as_str();
        if !rc_seen && uses_rc(code) {
            rc_seen = true;
            push(
                &mut out,
                ln,
                "Rc<…> in library code".to_string(),
                "use Arc — the engine core is thread-parallel (docs/CONCURRENCY.md)",
            );
        }
        if !refcell_seen && uses_refcell(code) {
            refcell_seen = true;
            push(
                &mut out,
                ln,
                "RefCell<…> in library code".to_string(),
                "use Mutex/atomics, or confine to one thread with a reviewed suppression",
            );
        }
        if code.contains("partial_cmp(") && code.contains(".unwrap()") {
            push(
                &mut out,
                ln,
                "partial_cmp(..).unwrap() panics on NaN".to_string(),
                "use f32::total_cmp / f64::total_cmp",
            );
        }
        if !in_bin && code.contains("process::exit") {
            push(
                &mut out,
                ln,
                "process::exit outside bin/ skips destructors".to_string(),
                "return an error up to main() so device/obs threads shut down cleanly",
            );
        }
        if hot {
            if code.contains(".unwrap()") {
                push(
                    &mut out,
                    ln,
                    "bare unwrap() in the engine hot path".to_string(),
                    "propagate with ?, restructure with let-else, or suppress with a reason",
                );
            }
            if code.contains(".expect(") {
                push(
                    &mut out,
                    ln,
                    "expect() in the engine hot path".to_string(),
                    "propagate with ?, restructure with let-else, or suppress with a reason",
                );
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::fixtures;
    use super::super::lexer::parse;
    use super::*;

    #[test]
    fn forbidden_types_and_calls_fire_once_each() {
        let f = check(&parse("rust/src/server/fixture.rs", fixtures::R3_FORBIDDEN, false));
        let lines: Vec<usize> = f.iter().map(|x| x.line).collect();
        // RefCell on its import line, Rc at first use, partial_cmp and
        // process::exit at their call sites.
        assert_eq!(lines, vec![3, 6, 7, 8], "got: {f:?}");
        assert!(f.iter().all(|x| x.rule == R3));
    }

    #[test]
    fn hot_path_unwrap_and_expect_fire_outside_tests_only() {
        let f = check(&parse("rust/src/cache/fixture.rs", fixtures::R3_HOTPATH_UNWRAP, false));
        let lines: Vec<usize> = f.iter().map(|x| x.line).collect();
        assert_eq!(lines, vec![3, 7], "got: {f:?}");
    }

    #[test]
    fn hot_path_rule_is_scoped_to_hot_dirs() {
        let f = check(&parse("rust/src/server/fixture.rs", fixtures::R3_HOTPATH_UNWRAP, false));
        assert!(f.is_empty());
    }

    #[test]
    fn fixed_ports_fire_in_tests_but_port_zero_is_fine() {
        let f = check(&parse("rust/tests/fixture.rs", fixtures::R3_FIXED_PORT, true));
        assert_eq!(f.len(), 1, "got: {f:?}");
        assert_eq!(f[0].line, 3);
        assert!(f[0].message.contains("8472"));
    }

    #[test]
    fn unwrap_or_is_not_unwrap() {
        let src = "fn hot(&self) -> usize {\n    self.depth.checked_sub(1).unwrap_or(0)\n}\n";
        assert!(check(&parse("rust/src/cache/fixture.rs", src, false)).is_empty());
    }
}
