//! `hae-lint` — project invariant checker.
//!
//! Turns the prose contracts in docs/CONCURRENCY.md and the page
//! accounting discipline into machine-enforced rules, run by the
//! `hae_lint` binary (`make lint-hae`) on every push:
//!
//! - **R1 lock-order** ([`lock_order`]) — PagePool before Obs, no guard
//!   across a device call or channel send.
//! - **R2 refcount pairing** ([`refcount`]) — retains live in modules
//!   with typed release paths.
//! - **R3 forbidden APIs** ([`forbidden`]) — `Rc`/`RefCell`, NaN-unsafe
//!   comparisons, `process::exit`, fixed test ports, hot-path panics.
//! - **R4 metric drift** ([`metrics_doc`]) — emitted `hae_*` series and
//!   docs/OBSERVABILITY.md stay in lockstep; frozen snapshot keys stay
//!   produced.
//!
//! Pure logic over source text — no artifacts, no network, unit-testable
//! against the string fixtures in [`fixtures`]. The full rule catalog,
//! including the suppression syntax and its cap, lives in
//! docs/STATIC_ANALYSIS.md.

pub mod fixtures;
pub mod forbidden;
pub mod lexer;
pub mod lock_order;
pub mod metrics_doc;
pub mod refcount;
pub mod suppress;

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::Context;

pub const R1: &str = "R1-lock-order";
pub const R2: &str = "R2-refcount-pairing";
pub const R3: &str = "R3-forbidden-api";
pub const R4: &str = "R4-metric-drift";
/// Rule id for violations of the suppression mechanism itself
/// (reason-less suppressions, cap overflow).
pub const RULE_SUPPRESSION: &str = "suppression";
/// Tree-wide cap on suppressions in active use. The current tree uses
/// roughly half of this; hitting the cap means violations are being
/// waved through instead of fixed.
pub const MAX_SUPPRESSIONS: usize = 24;

/// One rule violation at a source location.
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
    pub hint: &'static str,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {} (fix: {})",
            self.file, self.line, self.rule, self.message, self.hint
        )
    }
}

/// Aggregate result of a tree walk.
#[derive(Debug, Default)]
pub struct TreeReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    pub suppressions_used: usize,
    pub suppressions_unused: usize,
}

/// Lint a single source string through R1–R3 plus suppressions — the
/// entry point fixture tests use. Paths under `rust/tests/` or
/// `benches/` are treated as all-test code, as in the tree walk.
pub fn check_str(path: &str, source: &str) -> Vec<Finding> {
    let assume_test = path.starts_with("rust/tests/") || path.starts_with("benches/");
    let file = lexer::parse(path, source, assume_test);
    let mut findings = lock_order::check(&file);
    findings.extend(refcount::check(&file));
    findings.extend(forbidden::check(&file));
    let mut sups = suppress::collect(&file);
    let mut out = suppress::apply(&mut sups, path, findings);
    out.sort_by(|a, b| a.line.cmp(&b.line).then(a.rule.cmp(b.rule)));
    out
}

/// Lint the whole repository rooted at `root`.
pub fn lint_tree(root: &Path) -> anyhow::Result<TreeReport> {
    let mut report = TreeReport::default();
    let mut emissions: Vec<metrics_doc::Emission> = Vec::new();

    let mut src_files = Vec::new();
    collect_rs(&root.join("rust/src"), &mut src_files)?;
    src_files.sort();
    for path in &src_files {
        let rel = rel_path(root, path);
        if rel.contains("analysis/fixtures") {
            // deliberately-broken linter fixtures
            continue;
        }
        let text =
            fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
        let file = lexer::parse(&rel, &text, false);
        report.files_scanned += 1;
        let mut findings = lock_order::check(&file);
        findings.extend(refcount::check(&file));
        findings.extend(forbidden::check(&file));
        if rel.ends_with("scheduler/metrics.rs") {
            findings.extend(metrics_doc::check_snapshot_keys(&file));
        }
        emissions.extend(metrics_doc::collect_emissions(&file));
        apply_suppressions(&mut report, &file, &rel, findings);
    }

    // Tests and benches: whole-file test code; only the R3 scopes that
    // target test code (fixed ports) apply there.
    let mut test_files = Vec::new();
    collect_rs(&root.join("rust/tests"), &mut test_files)?;
    collect_rs(&root.join("benches"), &mut test_files)?;
    test_files.sort();
    for path in &test_files {
        let rel = rel_path(root, path);
        let text =
            fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
        let file = lexer::parse(&rel, &text, true);
        report.files_scanned += 1;
        let findings = forbidden::check(&file);
        apply_suppressions(&mut report, &file, &rel, findings);
    }

    match fs::read_to_string(root.join("docs/OBSERVABILITY.md")) {
        Ok(doc) => report
            .findings
            .extend(metrics_doc::check_drift(&emissions, &doc, "docs/OBSERVABILITY.md")),
        Err(_) => report.findings.push(Finding {
            file: "docs/OBSERVABILITY.md".to_string(),
            line: 0,
            rule: R4,
            message: "docs/OBSERVABILITY.md is missing".to_string(),
            hint: "restore the observability catalog; R4 checks emitted series against it",
        }),
    }

    if report.suppressions_used > MAX_SUPPRESSIONS {
        report.findings.push(Finding {
            file: "(tree)".to_string(),
            line: 0,
            rule: RULE_SUPPRESSION,
            message: format!(
                "{} suppressions in use exceeds the cap of {MAX_SUPPRESSIONS}",
                report.suppressions_used
            ),
            hint: "fix violations instead of suppressing them",
        });
    }

    report
        .findings
        .sort_by(|a, b| a.file.cmp(&b.file).then(a.line.cmp(&b.line)).then(a.rule.cmp(b.rule)));
    Ok(report)
}

fn apply_suppressions(
    report: &mut TreeReport,
    file: &lexer::SourceFile,
    rel: &str,
    findings: Vec<Finding>,
) {
    let mut sups = suppress::collect(file);
    report.findings.extend(suppress::apply(&mut sups, rel, findings));
    report.suppressions_used += sups.iter().filter(|s| s.used).count();
    report.suppressions_unused += sups.iter().filter(|s| !s.used).count();
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    for entry in fs::read_dir(dir).with_context(|| format!("read {}", dir.display()))? {
        let p = entry.with_context(|| format!("read entry in {}", dir.display()))?.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, p: &Path) -> String {
    p.strip_prefix(root)
        .unwrap_or(p)
        .to_string_lossy()
        .replace('\\', "/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a_seeded_lock_order_inversion_is_caught() {
        // The acceptance scenario: drop an inverted-order snippet into a
        // scanned (non-hot) module and the linter reports R1 — which
        // makes the binary exit non-zero.
        let f = check_str("rust/src/server/fixture.rs", fixtures::R1_INVERSION);
        assert_eq!(f.len(), 1, "got: {f:?}");
        assert_eq!(f[0].rule, R1);
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn a_reasoned_suppression_lints_clean() {
        let f = check_str("rust/src/server/fixture.rs", fixtures::SUPPRESSED_WITH_REASON);
        assert!(f.is_empty(), "got: {f:?}");
    }

    #[test]
    fn a_reasonless_suppression_is_itself_a_finding() {
        let f = check_str("rust/src/server/fixture.rs", fixtures::SUPPRESSED_NO_REASON);
        assert_eq!(f.len(), 1, "got: {f:?}");
        assert_eq!(f[0].rule, RULE_SUPPRESSION);
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn findings_render_as_file_line_rule_hint() {
        let f = Finding {
            file: "rust/src/cache/slab.rs".to_string(),
            line: 7,
            rule: R1,
            message: "msg".to_string(),
            hint: "do the thing",
        };
        assert_eq!(
            f.to_string(),
            "rust/src/cache/slab.rs:7: [R1-lock-order] msg (fix: do the thing)"
        );
    }
}
