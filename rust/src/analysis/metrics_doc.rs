//! R4 — metric-registry drift rule.
//!
//! Two-way contract between code and docs/OBSERVABILITY.md:
//! every `hae_*` series emitted through the `obs::prometheus` helpers
//! must be documented, every documented series must still be emitted,
//! and every flat stats key frozen in the `snapshot_keys_are_stable`
//! test must actually be produced by the registry. Doc drift becomes a
//! lint failure instead of a review nit.

use std::collections::HashSet;

use super::lexer::{has_call_token, prev_is_ident, SourceFile};
use super::{Finding, R4};

/// One `hae_*` series emission site.
#[derive(Debug, Clone)]
pub struct Emission {
    pub file: String,
    pub line: usize,
    pub name: String,
    /// Histograms additionally emit `_bucket` / `_sum` / `_count`.
    pub histogram: bool,
}

/// Emission-helper call tokens, paired with whether they render a
/// histogram family. `labeled_gauge(` is listed before `gauge(`; the
/// token matcher already rejects the embedded `gauge(` (preceded by
/// `_`), this just keeps intent obvious.
const CALLS: [(&str, bool); 4] = [
    ("histogram(", true),
    ("counter(", false),
    ("labeled_gauge(", false),
    ("gauge(", false),
];

/// Find every emission in a file. `cargo fmt` may push the name
/// argument below the call token, so the first `hae_*` string within
/// two lines of the call names the series.
pub fn collect_emissions(file: &SourceFile) -> Vec<Emission> {
    let mut out = Vec::new();
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for (tok, histogram) in CALLS {
            if !has_call_token(&line.code, tok) {
                continue;
            }
            let window = &file.lines[idx..file.lines.len().min(idx + 3)];
            if let Some(name) = window
                .iter()
                .flat_map(|l| l.strings.iter())
                .find(|s| s.starts_with("hae_"))
            {
                out.push(Emission {
                    file: file.path.clone(),
                    line: idx + 1,
                    name: name.clone(),
                    histogram,
                });
            }
            break;
        }
    }
    out
}

/// `hae_*` tokens mentioned in the doc, with the first line each
/// appears on. Tokens ending in `_` (wildcard prose) are skipped.
pub fn doc_series(text: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    let mut seen = HashSet::new();
    for (idx, line) in text.lines().enumerate() {
        for (i, _) in line.match_indices("hae_") {
            if prev_is_ident(line, i) {
                continue;
            }
            let ext: String = line[i + 4..]
                .chars()
                .take_while(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || *c == '_')
                .collect();
            if ext.is_empty() || ext.ends_with('_') {
                continue;
            }
            let tok = format!("hae_{ext}");
            if seen.insert(tok.clone()) {
                out.push((idx + 1, tok));
            }
        }
    }
    out
}

/// Cross-check emissions against the doc, both directions.
pub fn check_drift(emissions: &[Emission], doc_text: &str, doc_path: &str) -> Vec<Finding> {
    let documented: HashSet<String> = doc_series(doc_text).into_iter().map(|(_, t)| t).collect();
    let emitted: HashSet<&str> = emissions.iter().map(|e| e.name.as_str()).collect();
    let hists: HashSet<&str> = emissions
        .iter()
        .filter(|e| e.histogram)
        .map(|e| e.name.as_str())
        .collect();
    let mut out = Vec::new();
    let mut reported: HashSet<&str> = HashSet::new();
    for e in emissions {
        if !documented.contains(&e.name) && reported.insert(e.name.as_str()) {
            out.push(Finding {
                file: e.file.clone(),
                line: e.line,
                rule: R4,
                message: format!("series {} emitted but not documented", e.name),
                hint: "add it to the series catalog in docs/OBSERVABILITY.md",
            });
        }
    }
    for (line, tok) in doc_series(doc_text) {
        if emitted.contains(tok.as_str()) {
            continue;
        }
        let base = tok
            .strip_suffix("_bucket")
            .or_else(|| tok.strip_suffix("_sum"))
            .or_else(|| tok.strip_suffix("_count"));
        if base.is_some_and(|b| hists.contains(b)) {
            continue;
        }
        out.push(Finding {
            file: doc_path.to_string(),
            line,
            rule: R4,
            message: format!("series {tok} documented but never emitted"),
            hint: "remove it from docs/OBSERVABILITY.md or restore the emission",
        });
    }
    out
}

/// Every key frozen by the snapshot-stability test must be produced by
/// non-test code in the same file (the registry's `snapshot()`).
pub fn check_snapshot_keys(file: &SourceFile) -> Vec<Finding> {
    let produced: HashSet<&str> = file
        .lines
        .iter()
        .filter(|l| !l.in_test)
        .flat_map(|l| l.strings.iter().map(|s| s.as_str()))
        .collect();
    let mut out = Vec::new();
    let mut markers = 0usize;
    for marker in ["const FROZEN", "const ADDITIVE"] {
        let Some(start) = file.lines.iter().position(|l| l.code.contains(marker)) else {
            continue;
        };
        markers += 1;
        for (off, line) in file.lines[start..].iter().enumerate() {
            for key in &line.strings {
                if !produced.contains(key.as_str()) {
                    out.push(Finding {
                        file: file.path.clone(),
                        line: start + off + 1,
                        rule: R4,
                        message: format!("snapshot key \"{key}\" frozen in the test but never produced"),
                        hint: "produce it in MetricsRegistry::snapshot or drop it from the frozen list",
                    });
                }
            }
            if line.code.contains("];") {
                break;
            }
        }
    }
    if markers < 2 {
        out.push(Finding {
            file: file.path.clone(),
            line: 1,
            rule: R4,
            message: "frozen snapshot-key markers (FROZEN / ADDITIVE consts) not found".to_string(),
            hint: "keep the snapshot_keys_are_stable test and its key lists intact",
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::lexer::parse;
    use super::*;

    #[test]
    fn emissions_are_collected_across_wrapped_calls() {
        let src = "fn p(out: &mut String) {\n    gauge(out, \"hae_queue_depth\", \"depth\", 1.0);\n    histogram(\n        out,\n        \"hae_ttft_ms\",\n        \"ttft\",\n    );\n}\n";
        let e = collect_emissions(&parse("rust/src/obs/fixture.rs", src, false));
        let names: Vec<&str> = e.iter().map(|x| x.name.as_str()).collect();
        assert_eq!(names, vec!["hae_queue_depth", "hae_ttft_ms"]);
        assert!(!e[0].histogram);
        assert!(e[1].histogram);
    }

    #[test]
    fn drift_fires_both_directions_and_accepts_histogram_suffixes() {
        let src = "fn p(out: &mut String) {\n    gauge(out, \"hae_queue_depth\", \"d\", 1.0);\n    histogram(out, \"hae_ttft_ms\", \"t\", &h);\n    counter(out, \"hae_secret_total\", \"s\", 2.0);\n}\n";
        let e = collect_emissions(&parse("rust/src/obs/fixture.rs", src, false));
        let doc = "## Series\n- `hae_queue_depth` — depth\n- `hae_ttft_ms` (histogram; also `hae_ttft_ms_bucket`)\n- `hae_ghost_series` — documented only\n";
        let f = check_drift(&e, doc, "docs/OBSERVABILITY.md");
        assert_eq!(f.len(), 2, "got: {f:?}");
        assert!(f[0].message.contains("hae_secret_total"));
        assert_eq!(f[0].line, 4);
        assert!(f[1].message.contains("hae_ghost_series"));
        assert_eq!(f[1].file, "docs/OBSERVABILITY.md");
        assert_eq!(f[1].line, 4);
    }

    #[test]
    fn frozen_keys_must_be_produced() {
        let src = "fn snapshot() {\n    out.push((\"queue_depth\", 1));\n}\n#[cfg(test)]\nmod tests {\n    const FROZEN: &[&str] = &[\n        \"queue_depth\", \"ghost_key\",\n    ];\n    const ADDITIVE: &[&str] = &[\n        \"queue_depth\",\n    ];\n}\n";
        let f = check_snapshot_keys(&parse("rust/src/scheduler/metrics.rs", src, false));
        assert_eq!(f.len(), 1, "got: {f:?}");
        assert!(f[0].message.contains("ghost_key"));
        assert_eq!(f[0].line, 7);
    }

    #[test]
    fn missing_markers_are_a_finding() {
        let f = check_snapshot_keys(&parse("rust/src/scheduler/metrics.rs", "fn a() {}\n", false));
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("markers"));
    }
}
