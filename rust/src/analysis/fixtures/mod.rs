//! Known-bad (and known-good) code fixtures for the linter's own tests.
//!
//! Each constant is a small Rust snippet, held as a string so the rules
//! can be exercised without touching the real tree. The files under
//! `rust/src/analysis/fixtures/` are excluded from `lint_tree`'s walk —
//! deliberately broken code must not fail the real lint run.
//!
//! Line numbers in the rule tests index into these snippets, so keep
//! the leading newline (line 1 is empty) when editing.

/// R1: an `Obs` record while the pool guard is still live (line 4).
pub const R1_OBS_UNDER_POOL_GUARD: &str = r#"
fn bad(&self) {
    let pool = lock_pool(&self.pool);
    self.obs.record(|o| o.counters.page_allocs += 1);
    drop(pool);
}
"#;

/// R1: a device call crosses a live pool guard (line 4).
pub const R1_GUARD_ACROSS_DEVICE: &str = r#"
fn bad(&mut self) -> anyhow::Result<()> {
    let pool = lock_profiled(&self.pool, &self.obs);
    let out = self.dev.decode(&pool.pages)?;
    drop(pool);
    Ok(out)
}
"#;

/// R1: locks taken in the inverted order — obs first, pool second
/// (line 4). Seeding this shape into a scanned file must make the
/// linter exit non-zero; the mod-level test proves `check_str` agrees.
pub const R1_INVERSION: &str = r#"
fn bad(&self) {
    let mut o = self.obs.inner();
    let pool = self.pool.lock().unwrap();
    o.counters.page_allocs += 1;
    drop(pool);
}
"#;

/// R1: a channel send while the pool guard is live (line 4).
pub const R1_SEND_UNDER_GUARD: &str = r#"
fn bad(&self) {
    let pool = lock_pool(&self.pool);
    self.tx.send(pool.free_pages()).ok();
    drop(pool);
}
"#;

/// R1: a router replica-state lock held across a dispatch into a
/// replica's ingest channel (line 4). The routing tier keeps replica
/// health in lock-free atomics so this guard shape must never exist;
/// a full replica mailbox would block the send with the lock held.
pub const R1_ROUTER_LOCK_ACROSS_DISPATCH: &str = r#"
fn bad(&self) {
    let state = self.replicas.lock().unwrap();
    state.links[0].tx.send(job).unwrap();
    drop(state);
}
"#;

/// R2: a retain with no release path anywhere in the module (line 4).
pub const R2_RETAIN_WITHOUT_RELEASE: &str = r#"
fn fork(&mut self, pages: &[usize]) {
    for &p in pages {
        self.pool.retain_page(p);
    }
}
"#;

/// R2: the same retain, balanced by a typed release path — clean.
pub const R2_PAIRED: &str = r#"
fn fork(&mut self, pages: &[usize]) {
    for &p in pages {
        self.pool.retain_page(p);
    }
}

fn drop_pages(&mut self, pages: &[usize]) {
    self.pool.release_pages(pages);
}
"#;

/// R3: forbidden APIs — `RefCell` import (line 3), `Rc` use (line 6),
/// `partial_cmp(..).unwrap()` (line 7), `process::exit` (line 8).
pub const R3_FORBIDDEN: &str = r#"
use std::rc::Rc;
use std::cell::RefCell;

fn bad(xs: &mut [f32]) {
    let shared = Rc::new(RefCell::new(0u32));
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    std::process::exit(2);
}
"#;

/// R3: bare `unwrap()` (line 3) and `expect(` (line 7) in hot-path
/// code; the copies inside `#[cfg(test)]` are exempt.
pub const R3_HOTPATH_UNWRAP: &str = r#"
fn hot(&mut self) -> usize {
    self.queue.pop_front().unwrap()
}

fn hot2(&mut self) -> usize {
    self.queue.front().copied().expect("non-empty")
}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let v = vec![1].pop().unwrap();
        assert_eq!(v, 1);
    }
}
"#;

/// R3: a fixed port in test code (line 3); port 0 (line 4) is fine.
pub const R3_FIXED_PORT: &str = r#"
fn spawn() -> std::net::TcpListener {
    let fixed = std::net::TcpListener::bind("127.0.0.1:8472").unwrap();
    let ephemeral = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    fixed
}
"#;

/// The R1 violation from `R1_OBS_UNDER_POOL_GUARD`, silenced by a
/// reasoned suppression on the preceding line — lints clean.
pub const SUPPRESSED_WITH_REASON: &str = r#"
fn tuned(&self) {
    let pool = lock_pool(&self.pool);
    // hae-lint: allow(R1-lock-order) profiler records under the pool guard by design
    self.obs.record(|o| o.counters.page_allocs += 1);
    drop(pool);
}
"#;

/// The same suppression without a reason — the suppression itself
/// becomes the finding.
pub const SUPPRESSED_NO_REASON: &str = r#"
fn tuned(&self) {
    let pool = lock_pool(&self.pool);
    // hae-lint: allow(R1-lock-order)
    self.obs.record(|o| o.counters.page_allocs += 1);
    drop(pool);
}
"#;
