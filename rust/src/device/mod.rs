//! The dedicated device thread.
//!
//! The PJRT client inside [`Runtime`] is not `Send`: the XLA C-API
//! handles are thread-affine, so the one hard rule of the threaded
//! engine is that **every device call executes on the single thread
//! that constructed the `Runtime`**. This module owns that rule. The
//! device thread is spawned with a factory closure, builds the
//! `Runtime` in place, and then serves [`DeviceCall`]s from a bounded
//! channel in strict FIFO order. Everything that crosses the channel is
//! plain owned data (`Vec`s, `Copy` scalars, output structs), so every
//! other thread in the process is free to be a real thread.
//!
//! Backpressure: the channel is bounded (`QUEUE_DEPTH`). The device
//! thread never blocks on the engine — it only receives, executes and
//! replies — so a full queue blocks the *caller*, which is the correct
//! direction and cannot deadlock (docs/CONCURRENCY.md).
//!
//! The decode/extend replies carry the lane-gather scratch buffers back
//! to the caller ([`DecodeDone::k`]/[`DecodeDone::v`]): the engine
//! moves its scratch `Vec`s into the call, the device slices the front
//! it needs, and the reply returns the allocation for reuse — no
//! per-step buffer churn on either side.

// hot-path panic discipline (hae-lint R3): violations need an inline
// #[allow] plus a reasoned suppression — see docs/STATIC_ANALYSIS.md
#![warn(clippy::unwrap_used, clippy::expect_used)]

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::model::{Manifest, ModelMeta};
use crate::runtime::{AnalysisOut, DecodeOut, ExtendOut, PrefillOut, Runtime, StepTiming};

/// Bounded request-queue depth. Deep enough that a prefill or extend
/// can queue behind an in-flight decode without blocking the engine's
/// overlap window; shallow enough that backpressure reaches admission
/// instead of hiding in the channel.
pub const QUEUE_DEPTH: usize = 4;

/// One request to the device thread. Args are owned; the reply sender
/// is the caller's rendezvous.
pub enum DeviceCall {
    Prefill {
        bucket: usize,
        ids: Vec<i32>,
        patches: Vec<f32>,
        is_vision: Vec<f32>,
        n_tokens: usize,
        n_prefix: usize,
        reply: Sender<Result<(PrefillOut, StepTiming)>>,
    },
    Decode {
        batch: usize,
        capacity: usize,
        tokens: Vec<i32>,
        positions: Vec<i32>,
        k: Vec<f32>,
        v: Vec<f32>,
        lengths: Vec<i32>,
        reply: Sender<DecodeDone>,
    },
    Extend {
        batch: usize,
        chunk: usize,
        capacity: usize,
        tokens: Vec<i32>,
        positions: Vec<i32>,
        k: Vec<f32>,
        v: Vec<f32>,
        lengths: Vec<i32>,
        n_new: Vec<i32>,
        reply: Sender<ExtendDone>,
    },
    Analysis {
        bucket: usize,
        ids: Vec<i32>,
        patches: Vec<f32>,
        is_vision: Vec<f32>,
        n_tokens: usize,
        reply: Sender<Result<(AnalysisOut, StepTiming)>>,
    },
    Warmup {
        batches: Vec<usize>,
        reply: Sender<Result<()>>,
    },
}

/// Raw channel-health counters shared between every [`DeviceHandle`]
/// clone and the serve loop. Always on: a handful of relaxed atomic ops
/// per device *call* (not per token) is noise next to the call itself,
/// and keeping them unconditional means `{"kind":"stats"}` reports
/// device health even with tracing off. The obs layer folds these into
/// its gated profile spans once per step (`obs::profile`).
#[derive(Debug, Default)]
pub struct ChannelStats {
    /// Cumulative wall-time callers spent blocked in `send` (µs) —
    /// nonzero means the bounded queue pushed back on the host.
    pub send_wait_us: AtomicU64,
    /// Total calls sent over the channel.
    pub calls: AtomicU64,
    /// Calls sent and not yet completed by the device thread
    /// (queued + executing); bounded by `QUEUE_DEPTH + 1`.
    pub in_flight: AtomicU64,
    /// High-water mark of `in_flight`.
    pub peak_in_flight: AtomicU64,
}

/// Decode reply: the result plus the gather scratch moved back to the
/// caller for reuse.
pub struct DecodeDone {
    pub result: Result<(DecodeOut, StepTiming)>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// Extend reply; same scratch round-trip as [`DecodeDone`].
pub struct ExtendDone {
    pub result: Result<(ExtendOut, StepTiming)>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// Owns the join handle; the last [`DeviceHandle`] clone to drop joins
/// the device thread (its senders are gone by then, so the serve loop
/// has already seen the disconnect and returned).
struct DeviceThread {
    join: Mutex<Option<JoinHandle<()>>>,
}

impl Drop for DeviceThread {
    fn drop(&mut self) {
        // a poisoned join mutex means a sibling drop panicked; skip the
        // join rather than double-panic during unwind
        let handle = self.join.lock().ok().and_then(|mut g| g.take());
        if let Some(h) = handle {
            if h.join().is_err() {
                eprintln!("device thread panicked during shutdown");
            }
        }
    }
}

/// Cloneable handle to the device thread. `Send + Sync` by
/// construction: the manifest is immutable shared data, the busy
/// counter is atomic, and each clone owns its *own* channel sender.
pub struct DeviceHandle {
    // field order matters: `tx` must drop before `shared`, so that the
    // last handle's drop disconnects the channel (serve loop exits)
    // before `DeviceThread::drop` joins the thread.
    tx: SyncSender<DeviceCall>,
    manifest: Arc<Manifest>,
    busy_us: Arc<AtomicU64>,
    chan: Arc<ChannelStats>,
    shared: Arc<DeviceThread>,
}

impl Clone for DeviceHandle {
    fn clone(&self) -> Self {
        DeviceHandle {
            tx: self.tx.clone(),
            manifest: Arc::clone(&self.manifest),
            busy_us: Arc::clone(&self.busy_us),
            chan: Arc::clone(&self.chan),
            shared: Arc::clone(&self.shared),
        }
    }
}

impl std::fmt::Debug for DeviceHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DeviceHandle")
            .field("model", &self.manifest.model)
            .field("busy_us", &self.busy_us.load(Ordering::Relaxed))
            .field("queue_depth", &self.chan.in_flight.load(Ordering::Relaxed))
            .finish()
    }
}

/// Spawn the device thread. The factory runs *on the new thread* (the
/// `Runtime` never crosses a thread boundary); its `Manifest` is cloned
/// back over a bootstrap channel so the handle can answer shape/meta
/// questions without a device round trip. A factory error is returned
/// here, after the thread has been joined.
pub fn spawn(
    factory: impl FnOnce() -> Result<Runtime> + Send + 'static,
) -> Result<DeviceHandle> {
    let (boot_tx, boot_rx) = mpsc::channel::<Result<Manifest>>();
    let (tx, rx) = mpsc::sync_channel::<DeviceCall>(QUEUE_DEPTH);
    let busy_us = Arc::new(AtomicU64::new(0));
    let busy = Arc::clone(&busy_us);
    let chan = Arc::new(ChannelStats::default());
    let chan_serve = Arc::clone(&chan);
    let join = thread::Builder::new()
        .name("hae-device".into())
        .spawn(move || {
            let rt = match factory() {
                Ok(rt) => {
                    // a dropped bootstrap receiver means the spawner
                    // gave up; nothing to serve
                    if boot_tx.send(Ok(rt.manifest.clone())).is_err() {
                        return;
                    }
                    rt
                }
                Err(e) => {
                    let _ = boot_tx.send(Err(e));
                    return;
                }
            };
            serve(&rt, &rx, &busy, &chan_serve);
        })
        .map_err(|e| anyhow!("spawning device thread: {e}"))?;
    let manifest = match boot_rx.recv() {
        Ok(Ok(m)) => m,
        Ok(Err(e)) => {
            let _ = join.join();
            return Err(e);
        }
        Err(_) => {
            let _ = join.join();
            return Err(anyhow!("device thread died before bootstrap"));
        }
    };
    Ok(DeviceHandle {
        tx,
        manifest: Arc::new(manifest),
        busy_us,
        chan,
        shared: Arc::new(DeviceThread { join: Mutex::new(Some(join)) }),
    })
}

/// The device thread's serve loop: strict FIFO, never blocks on a
/// caller (a dropped reply receiver is ignored), exits when every
/// handle is gone.
fn serve(rt: &Runtime, rx: &Receiver<DeviceCall>, busy_us: &AtomicU64, chan: &ChannelStats) {
    let m = rt.meta();
    let row = m.n_heads * m.d_head;
    let n_layers = m.n_layers;
    while let Ok(call) = rx.recv() {
        let t0 = Instant::now();
        match call {
            DeviceCall::Prefill { bucket, ids, patches, is_vision, n_tokens, n_prefix, reply } => {
                let r = rt.prefill(bucket, &ids, &patches, &is_vision, n_tokens, n_prefix);
                let _ = reply.send(r);
            }
            DeviceCall::Decode { batch, capacity, tokens, positions, k, v, lengths, reply } => {
                // scratch is sized for the engine's max batch; the
                // graph wants exactly batch * slab floats
                let want = batch * n_layers * capacity * row;
                let result = if k.len() < want || v.len() < want {
                    Err(anyhow!(
                        "decode scratch too small: {} < {} floats",
                        k.len().min(v.len()),
                        want
                    ))
                } else {
                    rt.decode(batch, capacity, &tokens, &positions, &k[..want], &v[..want], &lengths)
                };
                let _ = reply.send(DecodeDone { result, k, v });
            }
            DeviceCall::Extend { batch, chunk, capacity, tokens, positions, k, v, lengths, n_new, reply } => {
                let want = batch * n_layers * capacity * row;
                let result = if k.len() < want || v.len() < want {
                    Err(anyhow!(
                        "extend scratch too small: {} < {} floats",
                        k.len().min(v.len()),
                        want
                    ))
                } else {
                    rt.extend(
                        batch, chunk, capacity, &tokens, &positions, &k[..want], &v[..want],
                        &lengths, &n_new,
                    )
                };
                let _ = reply.send(ExtendDone { result, k, v });
            }
            DeviceCall::Analysis { bucket, ids, patches, is_vision, n_tokens, reply } => {
                let r = rt.analysis(bucket, &ids, &patches, &is_vision, n_tokens);
                let _ = reply.send(r);
            }
            DeviceCall::Warmup { batches, reply } => {
                let _ = reply.send(rt.warmup(&batches));
            }
        }
        busy_us.fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        chan.in_flight.fetch_sub(1, Ordering::Relaxed);
    }
}

impl DeviceHandle {
    pub fn meta(&self) -> &ModelMeta {
        &self.manifest.model
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Cumulative wall-time the device thread has spent executing calls
    /// (µs). `busy / elapsed` is the device-utilization companion to
    /// the scheduler's overlap fraction.
    pub fn busy_us(&self) -> u64 {
        self.busy_us.load(Ordering::Relaxed)
    }

    /// Cumulative wall-time callers have spent blocked in the channel
    /// send (µs) — the backpressure signal. The engine brackets device
    /// calls with deltas of this to build the gated send-wait histogram.
    pub fn send_wait_us(&self) -> u64 {
        self.chan.send_wait_us.load(Ordering::Relaxed)
    }

    /// Total calls sent to the device thread.
    pub fn calls(&self) -> u64 {
        self.chan.calls.load(Ordering::Relaxed)
    }

    /// Calls sent and not yet completed (queued + executing).
    pub fn queue_depth(&self) -> u64 {
        self.chan.in_flight.load(Ordering::Relaxed)
    }

    /// High-water mark of [`queue_depth`](Self::queue_depth).
    pub fn peak_queue_depth(&self) -> u64 {
        self.chan.peak_in_flight.load(Ordering::Relaxed)
    }

    fn send(&self, call: DeviceCall) -> Result<()> {
        let depth = self.chan.in_flight.fetch_add(1, Ordering::Relaxed) + 1;
        self.chan.peak_in_flight.fetch_max(depth, Ordering::Relaxed);
        let t0 = Instant::now();
        let sent = self.tx.send(call);
        self.chan.send_wait_us.fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        self.chan.calls.fetch_add(1, Ordering::Relaxed);
        if sent.is_err() {
            // nothing reached the queue; undo the optimistic increment
            self.chan.in_flight.fetch_sub(1, Ordering::Relaxed);
        }
        sent.map_err(|_| anyhow!("device thread disconnected"))
    }

    pub fn prefill(
        &self,
        bucket: usize,
        ids: &[i32],
        patches: &[f32],
        is_vision: &[f32],
        n_tokens: usize,
        n_prefix: usize,
    ) -> Result<(PrefillOut, StepTiming)> {
        let (reply, rx) = mpsc::channel();
        self.send(DeviceCall::Prefill {
            bucket,
            ids: ids.to_vec(),
            patches: patches.to_vec(),
            is_vision: is_vision.to_vec(),
            n_tokens,
            n_prefix,
            reply,
        })?;
        rx.recv().map_err(|_| anyhow!("device thread disconnected"))?
    }

    /// Submit a decode step and return immediately; the caller overlaps
    /// host work and collects the reply (with its scratch buffers) from
    /// the receiver. Scratch `Vec`s are moved in and handed back in the
    /// [`DecodeDone`].
    #[allow(clippy::too_many_arguments)]
    pub fn decode_async(
        &self,
        batch: usize,
        capacity: usize,
        tokens: Vec<i32>,
        positions: Vec<i32>,
        k: Vec<f32>,
        v: Vec<f32>,
        lengths: Vec<i32>,
    ) -> Result<Receiver<DecodeDone>> {
        let (reply, rx) = mpsc::channel();
        self.send(DeviceCall::Decode { batch, capacity, tokens, positions, k, v, lengths, reply })?;
        Ok(rx)
    }

    /// Blocking decode: submit and wait.
    #[allow(clippy::too_many_arguments)]
    pub fn decode(
        &self,
        batch: usize,
        capacity: usize,
        tokens: Vec<i32>,
        positions: Vec<i32>,
        k: Vec<f32>,
        v: Vec<f32>,
        lengths: Vec<i32>,
    ) -> Result<DecodeDone> {
        let rx = self.decode_async(batch, capacity, tokens, positions, k, v, lengths)?;
        rx.recv().map_err(|_| anyhow!("device thread disconnected"))
    }

    /// Blocking chunked extend: submit and wait. Queues FIFO behind any
    /// in-flight decode, which is what lets a warm start's suffix
    /// recompute ride the overlap window.
    #[allow(clippy::too_many_arguments)]
    pub fn extend(
        &self,
        batch: usize,
        chunk: usize,
        capacity: usize,
        tokens: Vec<i32>,
        positions: Vec<i32>,
        k: Vec<f32>,
        v: Vec<f32>,
        lengths: Vec<i32>,
        n_new: Vec<i32>,
    ) -> Result<ExtendDone> {
        let (reply, rx) = mpsc::channel();
        self.send(DeviceCall::Extend {
            batch, chunk, capacity, tokens, positions, k, v, lengths, n_new, reply,
        })?;
        rx.recv().map_err(|_| anyhow!("device thread disconnected"))
    }

    pub fn analysis(
        &self,
        bucket: usize,
        ids: &[i32],
        patches: &[f32],
        is_vision: &[f32],
        n_tokens: usize,
    ) -> Result<(AnalysisOut, StepTiming)> {
        let (reply, rx) = mpsc::channel();
        self.send(DeviceCall::Analysis {
            bucket,
            ids: ids.to_vec(),
            patches: patches.to_vec(),
            is_vision: is_vision.to_vec(),
            n_tokens,
            reply,
        })?;
        rx.recv().map_err(|_| anyhow!("device thread disconnected"))?
    }

    pub fn warmup(&self, batches: &[usize]) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.send(DeviceCall::Warmup { batches: batches.to_vec(), reply })?;
        rx.recv().map_err(|_| anyhow!("device thread disconnected"))?
    }
}
