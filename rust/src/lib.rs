//! hae-serve — Hierarchical Adaptive Eviction for KV-cache management in
//! multimodal LLM serving.
//!
//! Rust + JAX + Pallas three-layer reproduction of Ma et al., "Hierarchical
//! Adaptive Eviction for KV Cache Management in Multimodal Language Models"
//! (2026). See DESIGN.md for the architecture and EXPERIMENTS.md for the
//! paper-vs-measured results.

pub mod analysis;
pub mod attention;
pub mod cache;
pub mod coordinator;
pub mod device;
pub mod eval;
pub mod harness;
pub mod model;
pub mod obs;
pub mod prefix;
pub mod router;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod theory;
pub mod util;
pub mod workload;
