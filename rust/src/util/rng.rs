//! Deterministic PRNG (xoshiro256**) — the project's only randomness source.
//!
//! rand/rand_distr are not available offline, and determinism across the
//! workload generators, samplers and property tests matters more than
//! cryptographic quality, so we carry a small, well-known generator.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Choose k distinct indices from [0, n) (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Shuffle a slice in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Derive an independent stream (for per-request generators).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.05, "var {}", var);
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(4);
        let picks = r.choose_k(20, 8);
        assert_eq!(picks.len(), 8);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 8);
    }

    #[test]
    fn weighted_respects_mass() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[0.0, 1.0, 3.0])] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((2.4..3.6).contains(&ratio), "ratio {}", ratio);
    }
}
