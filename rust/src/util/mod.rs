//! Hand-rolled substrates: JSON, PRNG, statistics, CLI args, property tests.
//!
//! The offline build has no serde/rand/clap/proptest, so the project carries
//! small, tested implementations of exactly the pieces it needs
//! (DESIGN.md §4).

pub mod args;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
