//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.
//! Unknown keys are collected so callers can reject them with a helpful
//! message.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw args (without argv[0]). `flag_names` lists boolean flags
    /// that never take a value.
    pub fn parse(raw: &[String], flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.options.insert(body.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f32(&self, name: &str, default: f32) -> f32 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn mixed_forms() {
        let a = Args::parse(&v(&["serve", "--port", "9000", "--quiet", "--r=0.0015"]), &["quiet"]);
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.get("port"), Some("9000"));
        assert!(a.flag("quiet"));
        assert_eq!(a.f64("r", 0.0), 0.0015);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = Args::parse(&v(&["--verbose"]), &[]);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&v(&[]), &[]);
        assert_eq!(a.usize("missing", 7), 7);
        assert_eq!(a.get_or("missing", "x"), "x");
    }
}
