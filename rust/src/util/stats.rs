//! Small numeric/statistics helpers shared by eval, attention and benches.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance (0.0 for len < 2).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// p in [0,1]; linear interpolation between order statistics.
///
/// NaN-safe: samples sort by `total_cmp` (NaNs order after +inf) instead
/// of panicking — one poisoned latency sample must never take down a
/// long-lived metrics reservoir.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = p.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Several quantiles of the same sample set, sorting **once**.
///
/// `percentile` clones and sorts per call — fine for a single quantile,
/// quadratic waste when a caller wants p50/p95/p99 of the same vector
/// (the old stats-snapshot path re-sorted thousands of latency samples
/// for every quantile of every query). Same NaN semantics as
/// `percentile`: `total_cmp` ordering, empty input yields 0.0.
pub fn percentiles(xs: &[f64], qs: &[f64]) -> Vec<f64> {
    if xs.is_empty() {
        return vec![0.0; qs.len()];
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    qs.iter()
        .map(|p| {
            let rank = p.clamp(0.0, 1.0) * (v.len() - 1) as f64;
            let lo = rank.floor() as usize;
            let hi = rank.ceil() as usize;
            if lo == hi {
                v[lo]
            } else {
                v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
            }
        })
        .collect()
}

/// Indices of the k smallest values (ties broken by lower index).
/// NaN-safe: `total_cmp` ranks NaNs above every real value, so they are
/// the last candidates rather than a panic.
pub fn argmin_k(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    let k = k.min(xs.len());
    idx.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]).then(a.cmp(&b)));
    idx.truncate(k);
    idx
}

/// Indices of the k largest values (ties broken by lower index).
/// NaN-safe: `total_cmp` ranks NaNs above every real value, so a single
/// NaN score cannot panic a serving-loop sort mid-batch.
pub fn argmax_k(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    let k = k.min(xs.len());
    idx.sort_by(|&a, &b| xs[b].total_cmp(&xs[a]).then(a.cmp(&b)));
    idx.truncate(k);
    idx
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in xs.iter().enumerate() {
        if *x > xs[best] {
            best = i;
        }
    }
    best
}

/// log-sum-exp, numerically stable.
pub fn logsumexp(xs: &[f32]) -> f32 {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f32>().ln()
}

/// Softmax into a new vector.
pub fn softmax(xs: &[f32]) -> Vec<f32> {
    let lse = logsumexp(xs);
    xs.iter().map(|x| (x - lse).exp()).collect()
}

/// KL(p_logits || q_logits) between two softmax distributions given logits.
pub fn kl_from_logits(p_logits: &[f32], q_logits: &[f32]) -> f64 {
    assert_eq!(p_logits.len(), q_logits.len());
    let lp = logsumexp(p_logits);
    let lq = logsumexp(q_logits);
    let mut kl = 0.0f64;
    for (a, b) in p_logits.iter().zip(q_logits) {
        let p = (a - lp).exp() as f64;
        if p > 0.0 {
            kl += p * ((a - lp) as f64 - (b - lq) as f64);
        }
    }
    kl.max(0.0)
}

/// Least-squares slope/intercept of y over x.
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    if x.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(x);
    let my = mean(y);
    let mut num = 0.0;
    let mut den = 0.0;
    for (xi, yi) in x.iter().zip(y) {
        num += (xi - mx) * (yi - my);
        den += (xi - mx) * (xi - mx);
    }
    let slope = if den > 0.0 { num / den } else { 0.0 };
    (slope, my - slope * mx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
    }

    #[test]
    fn percentiles_match_single_calls_with_one_sort() {
        let xs = [9.0, 2.0, 7.0, 4.0, 1.0, 8.0];
        let qs = [0.0, 0.25, 0.5, 0.95, 1.0];
        let batch = percentiles(&xs, &qs);
        for (q, got) in qs.iter().zip(&batch) {
            assert_eq!(*got, percentile(&xs, *q), "q={}", q);
        }
    }

    #[test]
    fn percentile_empty_and_nan_regression() {
        // empty input: 0.0, never a panic or NaN
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentiles(&[], &[0.5, 0.99]), vec![0.0, 0.0]);
        // all-NaN input: total_cmp keeps the sort well-defined; the result
        // is NaN (faithful) but must not panic
        let all_nan = [f64::NAN, f64::NAN];
        assert!(percentile(&all_nan, 0.5).is_nan());
        // mixed: NaNs sort above +inf, reals keep their order statistics
        let mixed = [2.0, f64::NAN, 1.0, 3.0];
        assert_eq!(percentile(&mixed, 0.0), 1.0);
        let ps = percentiles(&mixed, &[0.0, 1.0]);
        assert_eq!(ps[0], 1.0);
        assert!(ps[1].is_nan(), "NaN is the top order statistic");
    }

    #[test]
    fn argmin_k_sorted() {
        let xs = [3.0f32, 1.0, 2.0, 0.5];
        assert_eq!(argmin_k(&xs, 2), vec![3, 1]);
        assert_eq!(argmax_k(&xs, 1), vec![0]);
    }

    #[test]
    fn nan_inputs_never_panic() {
        // a poisoned value sorts last (total_cmp: NaN > +inf) instead of
        // panicking the comparator mid-sort
        let xs = [3.0f32, f32::NAN, 2.0, 0.5];
        assert_eq!(argmin_k(&xs, 2), vec![3, 2]);
        assert_eq!(argmax_k(&xs, 1), vec![1], "NaN ranks above every real");
        assert_eq!(argmax_k(&xs, 2), vec![1, 0]);
        let ys = [1.0f64, f64::NAN, 3.0];
        let p = percentile(&ys, 0.0);
        assert_eq!(p, 1.0, "NaN sample sorts to the top, reals stay ordered");
    }

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn kl_zero_on_identical() {
        let l = [0.3f32, -1.0, 2.0];
        assert!(kl_from_logits(&l, &l) < 1e-9);
        assert!(kl_from_logits(&l, &[0.0, 0.0, 0.0]) > 0.0);
    }

    #[test]
    fn fit_recovers_line() {
        let x = [0.0, 1.0, 2.0, 3.0];
        let y = [1.0, 3.0, 5.0, 7.0];
        let (m, b) = linear_fit(&x, &y);
        assert!((m - 2.0).abs() < 1e-9);
        assert!((b - 1.0).abs() < 1e-9);
    }
}
