//! Minimal JSON parser/serializer.
//!
//! serde is not available in this offline build (see DESIGN.md §4 —
//! hand-rolled substrates), so this module implements the small JSON subset
//! the project needs: the artifact manifest written by `aot.py` and the
//! JSON-lines request protocol of the server. It supports the full JSON
//! grammar minus exotic number forms; strings handle the standard escapes.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path lookup: `j.path(&["model", "d_model"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().map_or(false, |c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().map_or(false, |c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().map_or(false, |c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one utf-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.path(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"m":{"k":[1,2.5,true,null,"s"]},"z":-3}"#;
        let j = Json::parse(src).unwrap();
        let s = j.to_string_compact();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }
}
