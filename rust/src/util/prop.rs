//! Miniature property-testing harness (proptest is unavailable offline).
//!
//! `run_prop` drives a closure with a seeded Rng for N cases; on failure it
//! reports the case seed so the exact input can be replayed. Generators are
//! plain functions over `Rng` — no macro magic, but enough to express the
//! coordinator/cache invariants in DESIGN.md §6 as randomized tests.

use super::rng::Rng;

pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 128, seed: 0xC0FFEE }
    }
}

/// Run `f` for `cfg.cases` random cases. `f` gets a per-case Rng and the
/// case index; it should panic (assert) on property violation.
pub fn run_prop<F: FnMut(&mut Rng, usize)>(name: &str, cfg: PropConfig, mut f: F) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng, case);
        }));
        if let Err(e) = result {
            eprintln!(
                "property '{}' failed at case {} (replay seed {:#x})",
                name, case, case_seed
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Generate a vector of f32 scores in [0, scale).
pub fn gen_scores(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| rng.f32() * scale).collect()
}

/// Generate a random partition of `n` positions into vision/text
/// (returns is_vision bools with at least one text token).
pub fn gen_modality(rng: &mut Rng, n: usize) -> Vec<bool> {
    let mut v: Vec<bool> = (0..n).map(|_| rng.bool(0.5)).collect();
    if v.iter().all(|&b| b) && !v.is_empty() {
        let i = rng.below(n);
        v[i] = false;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        run_prop("counter", PropConfig { cases: 17, seed: 1 }, |_, _| {
            count += 1;
        });
        assert_eq!(count, 17);
    }

    #[test]
    #[should_panic]
    fn propagates_failure() {
        run_prop("fails", PropConfig { cases: 5, seed: 2 }, |rng, _| {
            assert!(rng.f64() < 0.5, "intentional");
        });
    }

    #[test]
    fn modality_has_text() {
        run_prop("modality", PropConfig::default(), |rng, _| {
            let n = 1 + rng.below(32);
            let m = gen_modality(rng, n);
            assert!(m.iter().any(|&b| !b));
        });
    }
}
