fn main() -> Result<(), Box<dyn std::error::Error>> {
    let client = xla::PjRtClient::cpu()?;
    for path in ["/tmp/probe_nt.hlo.txt", "/tmp/probe_t.hlo.txt"] {
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
        let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]).reshape(&[2, 2])?;
        let y = xla::Literal::vec1(&[10f32, 20., 30., 40.]).reshape(&[2, 2])?;
        let out = exe.execute::<xla::Literal>(&[x, y])?;
        println!("{path}: outer={} inner={}", out.len(), out[0].len());
        for (i, b) in out[0].iter().enumerate() {
            println!("  out[{i}] shape={:?}", b.on_device_shape()?);
        }
        // try execute_b with buffer inputs
        let xb = client.buffer_from_host_buffer::<f32>(&[1., 2., 3., 4.], &[2, 2], None)?;
        let yb = client.buffer_from_host_buffer::<f32>(&[10., 20., 30., 40.], &[2, 2], None)?;
        let out2 = exe.execute_b::<xla::PjRtBuffer>(&[xb, yb])?;
        println!("  execute_b inner={}", out2[0].len());
    }
    Ok(())
}
