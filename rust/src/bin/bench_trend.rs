//! Bench trend recorder + regression gate: `make bench-trend`.
//!
//! Reads the `BENCH_*.json` reports of the current run (from
//! `HAE_BENCH_DIR`, default `.`), appends one flattened trend point to
//! `benches/trend/data.json` (`HAE_TREND_DIR` overrides the directory),
//! then diffs the run's headline metrics against the committed baseline
//! reports in `benches/baseline/` (`HAE_BASELINE_DIR`). Exits non-zero
//! when any headline moved beyond `HAE_TREND_THRESHOLD` (default 0.10,
//! relative) in its bad direction — the CI gate that makes perf numbers
//! stick across PRs instead of resetting with every scrolled-away log.
//!
//! All comparison logic is in `obs::trend` (unit-tested, filesystem
//! free); this binary only shuttles files.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use hae_serve::obs::bench_report::bench_dir;
use hae_serve::obs::trend;
use hae_serve::util::json::Json;

/// Load every `BENCH_*.json` in `dir` keyed by its `bench` name.
/// Unreadable or unparseable files are reported and skipped — the gate
/// judges metrics, not filesystem accidents.
fn load_reports(dir: &Path) -> BTreeMap<String, Json> {
    let mut out = BTreeMap::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(_) => return out,
    };
    for entry in entries.filter_map(|e| e.ok()) {
        let name = entry.file_name().to_string_lossy().into_owned();
        if !name.starts_with("BENCH_") || !name.ends_with(".json") {
            continue;
        }
        let parsed = std::fs::read_to_string(entry.path())
            .map_err(|e| e.to_string())
            .and_then(|body| Json::parse(body.trim()).map_err(|e| e.to_string()));
        match parsed {
            Ok(j) => {
                let bench = j
                    .get("bench")
                    .and_then(|v| v.as_str())
                    .map(String::from)
                    .unwrap_or_else(|| name.clone());
                out.insert(bench, j);
            }
            Err(e) => eprintln!("bench-trend: skipping {}: {}", name, e),
        }
    }
    out
}

fn env_dir(var: &str, default: &str) -> PathBuf {
    PathBuf::from(std::env::var(var).unwrap_or_else(|_| default.into()))
}

fn main() {
    let threshold: f64 = std::env::var("HAE_TREND_THRESHOLD")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(trend::DEFAULT_THRESHOLD);
    let trend_dir = env_dir("HAE_TREND_DIR", "benches/trend");
    let baseline_dir = env_dir("HAE_BASELINE_DIR", "benches/baseline");

    let current = load_reports(&bench_dir());
    if current.is_empty() {
        eprintln!(
            "bench-trend: no BENCH_*.json in {} (run `make bench-smoke` first)",
            bench_dir().display()
        );
        std::process::exit(1);
    }

    // 1. record: append this run to the trend history
    let data_path = trend_dir.join("data.json");
    let history = std::fs::read_to_string(&data_path)
        .ok()
        .and_then(|body| Json::parse(body.trim()).ok());
    let updated = trend::append_point(history, trend::trend_point(&current));
    if let Err(e) = std::fs::create_dir_all(&trend_dir)
        .and_then(|_| std::fs::write(&data_path, updated.to_string_compact() + "\n"))
    {
        eprintln!("bench-trend: cannot write {}: {}", data_path.display(), e);
        std::process::exit(1);
    }
    let points = updated.get("points").and_then(|v| v.as_arr()).map_or(0, |p| p.len());
    println!("trend   {} ({} point(s))", data_path.display(), points);

    // 2. gate: diff the headline metrics against the committed baseline
    let baseline = load_reports(&baseline_dir);
    let cmp = trend::compare(&current, &baseline, threshold);
    for key in &cmp.ok {
        println!("ok      {}", key);
    }
    for key in &cmp.skipped {
        println!("skipped {} (missing on one side)", key);
    }
    for r in &cmp.regressions {
        println!("REGRESSED {}", r.describe());
    }
    if cmp.regressions.is_empty() {
        println!(
            "bench-trend: {} headline(s) within {:.0}% of {}",
            cmp.ok.len(),
            100.0 * threshold,
            baseline_dir.display()
        );
    } else {
        eprintln!(
            "bench-trend: {} headline regression(s) beyond {:.0}% vs {} — \
             if intentional, refresh the baseline (docs/OBSERVABILITY.md)",
            cmp.regressions.len(),
            100.0 * threshold,
            baseline_dir.display()
        );
    }
    std::process::exit(trend::exit_code(&cmp));
}
