//! `hae-lint` — run the project invariant checker over the tree.
//!
//! Usage: `hae_lint [repo-root]` (default: current directory); wired as
//! `make lint-hae`. Exit codes: 0 clean, 1 findings, 2 I/O failure.
//! Rules and suppression syntax: docs/STATIC_ANALYSIS.md.

use std::path::PathBuf;

fn main() {
    let root = std::env::args().nth(1).map_or_else(|| PathBuf::from("."), PathBuf::from);
    let report = match hae_serve::analysis::lint_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("hae-lint: {e:#}");
            std::process::exit(2);
        }
    };
    for f in &report.findings {
        println!("{f}");
    }
    println!(
        "hae-lint: {} file(s) scanned, {} finding(s), {} suppression(s) used ({} unused)",
        report.files_scanned,
        report.findings.len(),
        report.suppressions_used,
        report.suppressions_unused
    );
    if !report.findings.is_empty() {
        std::process::exit(1);
    }
}
