//! Validate the `BENCH_*.json` reports the perf benches leave behind.
//!
//! `make bench-verify` (and the CI bench-smoke job) runs this after
//! `make bench-smoke`: every report must match the schema in
//! `obs::bench_report`, and at least `HAE_BENCH_MIN` (default 5 — one per
//! perf bench) must exist. Exit status is the whole interface so the
//! Makefile/CI can gate on it; the listing doubles as a human summary.

use hae_serve::obs::bench_report::{bench_dir, schema_problems};
use hae_serve::util::json::Json;

fn main() {
    let min: usize = std::env::var("HAE_BENCH_MIN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5);
    let dir = bench_dir();
    let mut names: Vec<_> = match std::fs::read_dir(&dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
            .collect(),
        Err(e) => {
            eprintln!("bench-verify: cannot read {}: {}", dir.display(), e);
            std::process::exit(1);
        }
    };
    names.sort();
    let mut bad = 0usize;
    for name in &names {
        let path = dir.join(name);
        let problems = match std::fs::read_to_string(&path) {
            Ok(body) => match Json::parse(body.trim()) {
                Ok(j) => schema_problems(&j),
                Err(e) => vec![format!("unparseable json: {}", e)],
            },
            Err(e) => vec![format!("unreadable: {}", e)],
        };
        if problems.is_empty() {
            println!("ok      {}", name);
        } else {
            bad += 1;
            for p in problems {
                println!("INVALID {}: {}", name, p);
            }
        }
    }
    if bad > 0 {
        eprintln!("bench-verify: {} invalid report(s)", bad);
        std::process::exit(1);
    }
    if names.len() < min {
        eprintln!(
            "bench-verify: found {} report(s) in {}, need >= {} (run `make bench-smoke`; HAE_BENCH_MIN overrides)",
            names.len(),
            dir.display(),
            min
        );
        std::process::exit(1);
    }
    println!("bench-verify: {} report(s) valid", names.len());
}
