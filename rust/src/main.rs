//! hae-serve CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   info                         — print manifest / model / artifact info
//!   generate [--kind K] [--policy P] [--n N] [--temperature T] [--batch B]
//!                                — run N requests end-to-end and report
//!   serve [--addr A] [--policy P] [--batch B]
//!                                — JSON-lines TCP server
//!   analyze [--n N]              — print observation stats (Figs. 2/3 style)
//!
//! Policies: full | hae[:r=..,alpha=..,rc=..,stage=prefill|decode] | h2o |
//!           snapkv | adakv | mustdrop | fastv | sparsevlm | tome | window |
//!           random   (see cache::PolicyKind::parse)

use anyhow::{anyhow, Result};
use hae_serve::cache::{PolicyKind, DEFAULT_PAGE_SLOTS};
use hae_serve::coordinator::{Engine, EngineConfig, DEFAULT_EXTEND_CHUNK};
use hae_serve::harness;
use hae_serve::model::vocab;
use hae_serve::runtime::Runtime;
use hae_serve::scheduler::{parse_kv_budget, SchedPolicy, SloTable};
use hae_serve::router::RouterPolicy;
use hae_serve::server::{serve_replicas, ServerConfig};
use hae_serve::util::args::Args;
use hae_serve::workload::{RequestBuilder, StoryGrammar, WorkloadKind};

const USAGE: &str = "usage: hae-serve <info|generate|serve|analyze> [options]
  --artifacts DIR   artifact directory (default ./artifacts or $HAE_ARTIFACTS)
  --policy SPEC     eviction policy (default hae)
  --kind KIND       workload: qa|story|video|mixed (default story)
  --n N             number of requests (default 4)
  --batch B         decode batch width (default 1)
  --temperature T   sampling temperature (default 0 = greedy)
  --seed S          workload seed (default 42)
  --addr A          serve: listen address (default 127.0.0.1:8472)
  --queue N         serve: admission queue depth (default 64)
  --kv-budget B     aggregate live-KV budget in bytes; sizes the shared
                    page arena and the serve admission budget; k/m/g
                    suffixes are KiB/MiB/GiB (default: engine ceiling)
  --page-slots N    token slots per KV arena page (default 16)
  --prefix-cache M  on|off: radix-tree prefix cache — identical prompts
                    skip prefill and share retained KV pages
                    copy-on-write (default on)
  --extend-chunk N  partial warm starts recompute their text suffix in
                    chunks of N tokens per device call (the extend
                    executables); N|full, clamped to the largest compiled
                    chunk; 1 = the one-token decode loop (default 8)
  --trace M         on|off: request-lifecycle trace journal + per-phase
                    histograms (queryable via {"kind":"trace"} and the
                    stats "phases" block; default on)
  --sched-policy P  serve: fifo | priority (default fifo)
  --slo SPEC        serve: per-class latency SLO targets as
                    class=ttft_ms:e2e_ms[,class=...], classes
                    qa|story|video|mixed, e.g. qa=200:2000,story=500:30000;
                    attainment is reported per class in the stats snapshot
                    and as hae_slo_*_attainment Prometheus series
                    (default: none)
  --engine-threads N serve: 1 = strictly sequential scheduler rounds,
                    >=2 = pipelined rounds overlapping host work (reply
                    delivery, ingest, lane backfill) with the device
                    window (default 2)
  --replicas N      serve: engine replicas behind one listener, each with
                    its own page pool, prefix cache and device thread;
                    the router places requests by vision-segment content
                    hash on a consistent-hash ring (default 1)
  --router P        serve: affinity | round_robin — placement policy for
                    workload lines (round_robin is the bench control arm;
                    default affinity)
  --shed-queue N    serve: shed with a typed {\"kind\":\"error\",
                    \"reason\":\"shed\"} reply when the target replica's
                    admission depth reaches N (default: never shed)
  --spill-occupancy F serve: spill affinity traffic to the ring's second
                    choice when the primary's pool occupancy >= F
                    (a fraction in 0..=1; default: never spill)
  --verbose         generate: print full token streams";

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &["verbose", "help"]);
    if args.flag("help") || args.positional.is_empty() {
        println!("{}", USAGE);
        return Ok(());
    }

    let artifact_dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(harness::artifact_dir);

    match args.positional[0].as_str() {
        "info" => info(&artifact_dir),
        "generate" => generate(&artifact_dir, &args),
        "serve" => run_server(&artifact_dir, &args),
        "analyze" => analyze(&artifact_dir, &args),
        other => Err(anyhow!("unknown subcommand '{}'\n{}", other, USAGE)),
    }
}

/// `--kv-budget` in bytes (shared by the engine arena and the serve
/// admission budget), or None when unset.
fn kv_budget_arg(args: &Args) -> Result<Option<usize>> {
    args.get("kv-budget")
        .map(|spec| {
            parse_kv_budget(spec).ok_or_else(|| anyhow!("bad --kv-budget '{}'", spec))
        })
        .transpose()
}

fn build_engine(
    artifact_dir: &std::path::Path,
    args: &Args,
) -> Result<(Engine, StoryGrammar)> {
    let policy = PolicyKind::parse(args.get_or("policy", "hae"))
        .map_err(|e| anyhow!(e))?;
    let kv_budget = kv_budget_arg(args)?;
    let prefix_cache = match args.get_or("prefix-cache", "on") {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => return Err(anyhow!("bad --prefix-cache '{}' (accepted: on, off)", other)),
    };
    let extend_chunk = match args.get_or("extend-chunk", "") {
        "" => DEFAULT_EXTEND_CHUNK,
        // "full": one call per suffix when a bucket fits it (the engine
        // clamps to the largest compiled chunk)
        "full" => usize::MAX,
        spec => spec.parse::<usize>().ok().filter(|&n| n >= 1).ok_or_else(|| {
            anyhow!("bad --extend-chunk '{}' (accepted: an integer ≥ 1, or 'full')", spec)
        })?,
    };
    let trace = match args.get_or("trace", "on") {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => return Err(anyhow!("bad --trace '{}' (accepted: on, off)", other)),
    };
    let cfg = EngineConfig {
        policy,
        temperature: args.f32("temperature", 0.0),
        top_k: args.usize("top-k", 8),
        seed: args.u64("engine-seed", 1),
        capture_logits: false,
        capture_scores: false,
        batch: args.usize("batch", 1),
        kv_budget,
        page_slots: args.usize("page-slots", DEFAULT_PAGE_SLOTS),
        prefix_cache,
        extend_chunk,
        trace,
    };
    let grammar =
        StoryGrammar::load(artifact_dir).unwrap_or_else(|_| StoryGrammar::uniform());
    Ok((Engine::from_artifact_dir(artifact_dir, cfg)?, grammar))
}

fn info(artifact_dir: &std::path::Path) -> Result<()> {
    let rt = Runtime::load(artifact_dir)?;
    let m = rt.meta();
    let sh = &rt.manifest.shapes;
    println!("artifact dir : {}", artifact_dir.display());
    println!(
        "model        : TinyMM — {} layers, d_model {}, {}×{} heads, vocab {}, mlp {}",
        m.n_layers, m.d_model, m.n_heads, m.d_head, m.vocab, m.d_mlp
    );
    println!(
        "vision       : {} patches × {} dims per image",
        m.n_patches, m.patch_dim
    );
    println!(
        "weights      : {} tensors, {} params, trained {} steps (seed {})",
        rt.manifest.weights.len(),
        rt.manifest.weights.iter().map(|w| w.numel).sum::<usize>(),
        rt.manifest.train_steps,
        rt.manifest.seed,
    );
    println!("prefill      : buckets {:?}", sh.prefill_buckets);
    println!(
        "decode       : batches {:?} × capacities {:?}",
        sh.decode_batches, sh.decode_capacities
    );
    println!("analysis     : buckets {:?}", sh.analysis_buckets);
    println!(
        "kv per token : {} bytes (f32, K+V, all layers)",
        m.kv_bytes_per_token()
    );
    println!(
        "kv arena     : {} slots/page default ({} bytes/page)",
        DEFAULT_PAGE_SLOTS,
        DEFAULT_PAGE_SLOTS * m.kv_bytes_per_token()
    );
    Ok(())
}

fn generate(artifact_dir: &std::path::Path, args: &Args) -> Result<()> {
    let (mut engine, grammar) = build_engine(artifact_dir, args)?;
    let meta = engine.meta().clone();
    let kind = WorkloadKind::parse(args.get_or("kind", "story"))
        .ok_or_else(|| anyhow!("unknown --kind (accepted: {})", WorkloadKind::accepted()))?;
    let n = args.usize("n", 4);
    let seed = args.u64("seed", 42);
    let verbose = args.flag("verbose");

    let requests = RequestBuilder::new(&meta, &grammar, seed).make_batch(kind, n);
    engine.warmup()?;
    let t0 = std::time::Instant::now();
    let (finished, reports) = engine.run_batched(requests)?;
    let wall = t0.elapsed().as_secs_f64();

    let mut total_tokens = 0usize;
    let mut correct = 0usize;
    let mut qa = 0usize;
    for ar in &finished {
        total_tokens += ar.generated.len();
        if let Some(exp) = ar.req.expected_answer {
            qa += 1;
            if ar.generated.get(1) == Some(&exp) {
                correct += 1;
            }
        }
        if verbose {
            let text: Vec<String> =
                ar.generated.iter().map(|&t| vocab::describe(t)).collect();
            println!(
                "req {} [{:?}] pruned {} evicted {} peak_kv {} KiB:\n  {}",
                ar.req.id,
                ar.req.kind,
                ar.stats.pruned_at_prefill,
                ar.stats.evicted_at_decode,
                ar.stats.peak_kv_bytes / 1024,
                text.join(" ")
            );
        }
    }
    let pjrt: f64 = reports.iter().map(|r| r.pjrt_s).sum();
    let coord: f64 = reports.iter().map(|r| r.coord_s).sum();
    println!(
        "policy {} | {} requests | {:.2}s wall | {:.1} tok/s | {:.0}% PJRT / {:.0}% coordinator",
        engine.cfg.policy.label(),
        finished.len(),
        wall,
        total_tokens as f64 / wall,
        100.0 * pjrt / wall,
        100.0 * coord / wall,
    );
    if qa > 0 {
        println!(
            "QA accuracy: {}/{} = {:.1}%",
            correct,
            qa,
            100.0 * correct as f64 / qa as f64
        );
    }
    let ps = engine.prefix_stats();
    if ps.hits + ps.partial_hits + ps.misses > 0 {
        println!(
            "prefix cache: {} exact + {} partial hits / {} misses, {} prefill tokens \
             skipped, {} extend calls (chunk {}), {} pages pinned",
            ps.hits,
            ps.partial_hits,
            ps.misses,
            ps.prefill_tokens_skipped,
            engine.extend_calls(),
            engine.effective_extend_chunk(),
            ps.pinned_pages
        );
    }
    Ok(())
}

fn run_server(artifact_dir: &std::path::Path, args: &Args) -> Result<()> {
    let replicas = args.usize("replicas", 1);
    if replicas == 0 {
        return Err(anyhow!("bad --replicas 0 (accepted: an integer ≥ 1)"));
    }
    // one engine per replica — each owns its own page pool, prefix cache
    // and device thread; the grammar is shared (read-only)
    let (first, grammar) = build_engine(artifact_dir, args)?;
    let mut engines = vec![first];
    for _ in 1..replicas {
        engines.push(build_engine(artifact_dir, args)?.0);
    }
    let sched_policy = SchedPolicy::parse(args.get_or("sched-policy", "fifo"))
        .ok_or_else(|| anyhow!("unknown --sched-policy (fifo|priority)"))?;
    let kv_budget = kv_budget_arg(args)?;
    let engine_threads = args.usize("engine-threads", 2);
    if engine_threads == 0 {
        return Err(anyhow!("bad --engine-threads 0 (accepted: an integer ≥ 1)"));
    }
    let slo = match args.get("slo") {
        Some(spec) => SloTable::parse(spec).map_err(|e| anyhow!(e))?,
        None => SloTable::default(),
    };
    let router_policy = {
        let spec = args.get_or("router", "affinity");
        RouterPolicy::parse(spec).ok_or_else(|| {
            anyhow!("bad --router '{}' (accepted: {})", spec, RouterPolicy::accepted())
        })?
    };
    let shed_queue = args.get("shed-queue").map(|spec| {
        spec.parse::<usize>()
            .map_err(|_| anyhow!("bad --shed-queue '{}' (accepted: an integer ≥ 0)", spec))
    });
    let shed_queue = shed_queue.transpose()?;
    let spill_occupancy = args
        .get("spill-occupancy")
        .map(|spec| {
            spec.parse::<f64>()
                .ok()
                .filter(|f| (0.0..=1.0).contains(f))
                .ok_or_else(|| {
                    anyhow!(
                        "bad --spill-occupancy '{}' (accepted: a fraction in 0..=1)",
                        spec
                    )
                })
        })
        .transpose()?;
    let cfg = ServerConfig {
        addr: args.get_or("addr", "127.0.0.1:8472").to_string(),
        queue_depth: args.usize("queue", 64),
        kv_budget,
        sched_policy,
        engine_threads,
        slo,
        router_policy,
        shed_queue,
        spill_occupancy,
    };
    serve_replicas(engines, cfg, grammar)
}

fn analyze(artifact_dir: &std::path::Path, args: &Args) -> Result<()> {
    let rt = Runtime::load(artifact_dir)?;
    let meta = rt.meta().clone();
    let grammar =
        StoryGrammar::load(artifact_dir).unwrap_or_else(|_| StoryGrammar::uniform());
    let mut builder = RequestBuilder::new(&meta, &grammar, args.u64("seed", 42));
    let n = args.usize("n", 20);
    // a manifest without analysis artifacts is a valid build product
    // (aot can be configured to skip them) — report it as a CLI error
    // naming the manifest field instead of panicking on .first()
    let bucket = *rt.manifest.shapes.analysis_buckets.first().ok_or_else(|| {
        anyhow!(
            "manifest '{}' lists no analysis buckets (artifacts.analysis_buckets \
             is empty) — rebuild artifacts with analysis variants to run `analyze`",
            artifact_dir.join("manifest.json").display()
        )
    })?;

    let mut acc = vec![[0.0f64; 3]; meta.n_layers];
    let mut count = 0;
    for _ in 0..n {
        let req = builder.make(WorkloadKind::Understanding);
        let mut ids = req.ids.clone();
        ids.resize(bucket, vocab::PAD);
        let mut patches = req.patches.clone();
        patches.resize(bucket * meta.patch_dim, 0.0);
        let mut isv: Vec<f32> =
            req.is_vision.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect();
        isv.resize(bucket, 0.0);
        let (out, _) = rt.analysis(bucket, &ids, &patches, &isv, req.prompt_len())?;
        for l in 0..meta.n_layers {
            let (o, v, t) = out.layer_sparsity(l);
            acc[l][0] += o as f64;
            acc[l][1] += v as f64;
            acc[l][2] += t as f64;
        }
        count += 1;
    }
    println!("attention sparsity over {} QA samples (relative ε):", count);
    println!("{:<8}{:>10}{:>10}{:>10}", "layer", "overall", "visual", "text");
    for (l, a) in acc.iter().enumerate() {
        println!(
            "{:<8}{:>9.1}%{:>9.1}%{:>9.1}%",
            l,
            100.0 * a[0] / count as f64,
            100.0 * a[1] / count as f64,
            100.0 * a[2] / count as f64
        );
    }
    Ok(())
}
