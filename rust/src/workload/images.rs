//! Synthetic image construction — exact mirror of python/compile/data.py's
//! `class_prototype` / `make_image`.
//!
//! An "image" is `n_patches` feature vectors; 2–4 informative patches carry
//! a (color, shape) class prototype over unit-scale background noise. The
//! informative-patch sparsity is what gives vision tokens their
//! concentrated attention columns (paper Fig. 3).

use crate::model::vocab::{N_COLORS, N_SHAPES};
use crate::util::rng::Rng;

/// Must match python/compile/data.py SIGNAL_GAIN.
pub const SIGNAL_GAIN: f32 = 3.0;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ImageClass {
    pub color: usize,
    pub shape: usize,
}

impl ImageClass {
    pub fn random(rng: &mut Rng) -> ImageClass {
        ImageClass { color: rng.below(N_COLORS), shape: rng.below(N_SHAPES) }
    }
}

/// Deterministic patch-space prototype for a class (mirror of
/// data.class_prototype).
pub fn class_prototype(class: ImageClass, patch_dim: usize) -> Vec<f32> {
    let mut proto = vec![0.0f32; patch_dim];
    proto[class.color] = SIGNAL_GAIN;
    proto[N_COLORS + class.shape] = SIGNAL_GAIN;
    proto[16 + (class.color * N_SHAPES + class.shape) % 8] = SIGNAL_GAIN / 2.0;
    proto
}

#[derive(Debug, Clone)]
pub struct SyntheticImage {
    pub class: ImageClass,
    /// `[n_patches * patch_dim]`, patch-major
    pub patches: Vec<f32>,
    /// which patches carry the class signal
    pub informative: Vec<bool>,
}

impl SyntheticImage {
    pub fn generate(
        rng: &mut Rng,
        class: ImageClass,
        n_patches: usize,
        patch_dim: usize,
    ) -> SyntheticImage {
        let mut patches = vec![0.0f32; n_patches * patch_dim];
        for x in &mut patches {
            *x = rng.normal() as f32 * 0.5;
        }
        let n_info = rng.range(2, 5);
        let info_idx = rng.choose_k(n_patches, n_info);
        let proto = class_prototype(class, patch_dim);
        let mut informative = vec![false; n_patches];
        for &i in &info_idx {
            informative[i] = true;
            for d in 0..patch_dim {
                patches[i * patch_dim + d] += proto[d] + rng.normal() as f32 * 0.2;
            }
        }
        SyntheticImage { class, patches, informative }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prototype_matches_python_layout() {
        let p = class_prototype(ImageClass { color: 2, shape: 5 }, 32);
        assert_eq!(p[2], SIGNAL_GAIN);
        assert_eq!(p[8 + 5], SIGNAL_GAIN);
        assert_eq!(p[16 + (2 * 8 + 5) % 8], SIGNAL_GAIN / 2.0);
        assert_eq!(p.iter().filter(|&&x| x != 0.0).count(), 3);
    }

    #[test]
    fn image_has_informative_patches() {
        let mut rng = Rng::new(11);
        let img = SyntheticImage::generate(
            &mut rng,
            ImageClass { color: 0, shape: 0 },
            16,
            32,
        );
        let n_info = img.informative.iter().filter(|&&b| b).count();
        assert!((2..=4).contains(&n_info));
        assert_eq!(img.patches.len(), 16 * 32);
        // informative patches must carry visibly more energy at the class dims
        let energy = |i: usize| img.patches[i * 32].abs();
        let info_e: f32 = (0..16).filter(|&i| img.informative[i]).map(energy).sum();
        let back_e: f32 = (0..16).filter(|&i| !img.informative[i]).map(energy).sum();
        let n_back = 16 - n_info;
        assert!(
            info_e / n_info as f32 > back_e / n_back as f32,
            "class-dim energy should concentrate in informative patches"
        );
    }
}
