//! Request construction for the four workload families.
//!
//! A `Request` carries the fully-materialised prompt (token ids, patch
//! features, modality mask) plus generation settings and — where the task
//! has one — the ground-truth answer token for accuracy-style metrics.
//!
//! Note on the story family: the paper's Seed-Story pipeline feeds images
//! group-by-group across turns; this runtime's decode executable only
//! embeds vision at prefill, so a story request carries all of its images
//! in the prompt and generates one long continuation (same KV-pressure
//! profile; DESIGN.md §3).

use crate::model::vocab::*;
use crate::model::ModelMeta;
use crate::util::rng::Rng;

use super::images::{ImageClass, SyntheticImage};
use super::StoryGrammar;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadKind {
    /// single-image QA (Tables 1/6 stand-in)
    Understanding,
    /// multi-image long generation (Table 2 / Seed-Story stand-in)
    Story,
    /// multi-frame QA over a "video" (Table 4 stand-in)
    Video,
    /// MMMU-like mixed blend (Table 3 ablation)
    Mixed,
}

impl WorkloadKind {
    /// Every kind, in [`index`](Self::index) order — the per-class
    /// metrics arrays (scheduler/metrics.rs) are indexed by this.
    pub const ALL: [WorkloadKind; 4] = [
        WorkloadKind::Understanding,
        WorkloadKind::Story,
        WorkloadKind::Video,
        WorkloadKind::Mixed,
    ];

    /// Dense index into per-class arrays; inverse of `ALL[i]`.
    pub fn index(self) -> usize {
        match self {
            WorkloadKind::Understanding => 0,
            WorkloadKind::Story => 1,
            WorkloadKind::Video => 2,
            WorkloadKind::Mixed => 3,
        }
    }

    /// Canonical wire/metric name (stats keys, Prometheus `class` label,
    /// `--slo` CLI keys). Each is accepted back by [`parse`](Self::parse).
    pub fn wire_name(self) -> &'static str {
        match self {
            WorkloadKind::Understanding => "qa",
            WorkloadKind::Story => "story",
            WorkloadKind::Video => "video",
            WorkloadKind::Mixed => "mixed",
        }
    }

    pub fn parse(s: &str) -> Option<WorkloadKind> {
        match s {
            "understanding" | "qa" => Some(WorkloadKind::Understanding),
            "story" => Some(WorkloadKind::Story),
            "video" => Some(WorkloadKind::Video),
            "mixed" | "mmmu" => Some(WorkloadKind::Mixed),
            _ => None,
        }
    }

    /// Accepted spec strings — parse-failure messages (CLI and the
    /// server's JSON error replies) list these instead of a bare
    /// rejection.
    pub fn accepted() -> &'static str {
        "understanding|qa, story, video, mixed|mmmu"
    }
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub kind: WorkloadKind,
    pub ids: Vec<i32>,
    /// `[prompt_len * patch_dim]` — zeros at text positions
    pub patches: Vec<f32>,
    pub is_vision: Vec<bool>,
    pub max_new_tokens: usize,
    /// keep generating past EOS until this many tokens exist (story
    /// tasks: an EOS below the floor starts a new segment instead)
    pub min_new_tokens: usize,
    /// ground-truth answer token (QA families)
    pub expected_answer: Option<i32>,
    pub images: Vec<ImageClass>,
}

impl Request {
    pub fn prompt_len(&self) -> usize {
        self.ids.len()
    }

    pub fn n_vision(&self) -> usize {
        self.is_vision.iter().filter(|&&b| b).count()
    }
}

/// Deterministic request factory.
pub struct RequestBuilder<'a> {
    meta: &'a ModelMeta,
    grammar: &'a StoryGrammar,
    rng: Rng,
    next_id: u64,
}

impl<'a> RequestBuilder<'a> {
    pub fn new(meta: &'a ModelMeta, grammar: &'a StoryGrammar, seed: u64) -> Self {
        RequestBuilder { meta, grammar, rng: Rng::new(seed), next_id: 0 }
    }

    fn push_image(
        &mut self,
        ids: &mut Vec<i32>,
        patches: &mut Vec<f32>,
        is_vision: &mut Vec<bool>,
        class: ImageClass,
    ) -> SyntheticImage {
        let img = SyntheticImage::generate(
            &mut self.rng,
            class,
            self.meta.n_patches,
            self.meta.patch_dim,
        );
        self.push_image_patches(ids, patches, is_vision, &img);
        img
    }

    /// Append an already-materialised image (shared-image requests reuse
    /// one `SyntheticImage` bit-for-bit across prompts).
    fn push_image_patches(
        &self,
        ids: &mut Vec<i32>,
        patches: &mut Vec<f32>,
        is_vision: &mut Vec<bool>,
        img: &SyntheticImage,
    ) {
        for p in 0..self.meta.n_patches {
            ids.push(IMG);
            is_vision.push(true);
            patches.extend_from_slice(
                &img.patches[p * self.meta.patch_dim..(p + 1) * self.meta.patch_dim],
            );
        }
    }

    fn push_text(
        &self,
        ids: &mut Vec<i32>,
        patches: &mut Vec<f32>,
        is_vision: &mut Vec<bool>,
        toks: &[i32],
    ) {
        for &t in toks {
            ids.push(t);
            is_vision.push(false);
            patches.extend(std::iter::repeat(0.0).take(self.meta.patch_dim));
        }
    }

    /// `[BOS][img][Q_attr][A:]` → expected answer = class word.
    pub fn understanding(&mut self) -> Request {
        let class = ImageClass::random(&mut self.rng);
        let mut ids = Vec::new();
        let mut patches = Vec::new();
        let mut is_vision = Vec::new();
        self.push_text(&mut ids, &mut patches, &mut is_vision, &[BOS]);
        self.push_image(&mut ids, &mut patches, &mut is_vision, class);
        let ask_color = self.rng.bool(0.5);
        let q = if ask_color { Q_COLOR } else { Q_SHAPE };
        let answer = if ask_color {
            color_token(class.color)
        } else {
            shape_token(class.shape)
        };
        // prompt ends at the question token: the model emits ANS_MARK from
        // the (always-full) prefill logits, then the answer itself through
        // the *pruned* cache — so accuracy actually measures cache quality
        self.push_text(&mut ids, &mut patches, &mut is_vision, &[q]);
        self.next_id += 1;
        Request {
            id: self.next_id - 1,
            kind: WorkloadKind::Understanding,
            ids,
            patches,
            is_vision,
            max_new_tokens: 4,
            min_new_tokens: 0,
            expected_answer: Some(answer),
            images: vec![class],
        }
    }

    /// Understanding request over a *shared* image: the image is drawn
    /// from a dedicated RNG seeded by `image_seed`, so every request
    /// built with the same seed — on any builder, any connection —
    /// carries a bit-identical `[BOS][img]` prompt prefix. This is the
    /// prefix cache's target pattern (many questions, one image): with
    /// only two question tokens, N requests produce at most two distinct
    /// prompts, and everything past the first two admissions is a warm
    /// hit. `ask_color` picks the question (and so the expected answer).
    pub fn understanding_shared(&mut self, image_seed: u64, ask_color: bool) -> Request {
        let mut img_rng = Rng::new(image_seed);
        let class = ImageClass::random(&mut img_rng);
        let img = SyntheticImage::generate(
            &mut img_rng,
            class,
            self.meta.n_patches,
            self.meta.patch_dim,
        );
        let mut ids = Vec::new();
        let mut patches = Vec::new();
        let mut is_vision = Vec::new();
        self.push_text(&mut ids, &mut patches, &mut is_vision, &[BOS]);
        self.push_image_patches(&mut ids, &mut patches, &mut is_vision, &img);
        let q = if ask_color { Q_COLOR } else { Q_SHAPE };
        let answer = if ask_color {
            color_token(class.color)
        } else {
            shape_token(class.shape)
        };
        self.push_text(&mut ids, &mut patches, &mut is_vision, &[q]);
        self.next_id += 1;
        Request {
            id: self.next_id - 1,
            kind: WorkloadKind::Understanding,
            ids,
            patches,
            is_vision,
            max_new_tokens: 4,
            min_new_tokens: 0,
            expected_answer: Some(answer),
            images: vec![class],
        }
    }

    /// Shared-image multi-question QA batch: `n` requests against one
    /// image, questions alternating color/shape deterministically — the
    /// workload `benches/perf_prefix_cache.rs` and the serve bench's
    /// shared-image client mix measure sharing on.
    pub fn shared_image_qa(&mut self, image_seed: u64, n: usize) -> Vec<Request> {
        (0..n)
            .map(|q| self.understanding_shared(image_seed, q % 2 == 0))
            .collect()
    }

    /// One turn of a multi-turn QA dialog over a shared image:
    /// `[BOS][img] ([q_i][ANS][a_i])×turn [q_turn]` — the prompt replays
    /// the prior turns' questions and ground-truth answers and ends at
    /// this turn's question. Every turn's prompt is therefore
    /// *distinct* (no exact-match reuse possible) and grows with
    /// history, while all turns share the `[BOS][img]` visual prefix
    /// bit-for-bit — the partial-prefix warm-start target pattern: the
    /// image's KV and a per-request DAP replay serve every turn, only
    /// the dialog suffix is recomputed. Questions alternate color/shape;
    /// the expected answer is this turn's.
    pub fn qa_dialog_turn(&mut self, image_seed: u64, turn: usize) -> Request {
        let mut img_rng = Rng::new(image_seed);
        let class = ImageClass::random(&mut img_rng);
        let img = SyntheticImage::generate(
            &mut img_rng,
            class,
            self.meta.n_patches,
            self.meta.patch_dim,
        );
        let mut ids = Vec::new();
        let mut patches = Vec::new();
        let mut is_vision = Vec::new();
        self.push_text(&mut ids, &mut patches, &mut is_vision, &[BOS]);
        self.push_image_patches(&mut ids, &mut patches, &mut is_vision, &img);
        let qa_pair = |i: usize| {
            if i % 2 == 0 {
                (Q_COLOR, color_token(class.color))
            } else {
                (Q_SHAPE, shape_token(class.shape))
            }
        };
        for i in 0..turn {
            let (q, a) = qa_pair(i);
            self.push_text(&mut ids, &mut patches, &mut is_vision, &[q, ANS_MARK, a]);
        }
        let (q, answer) = qa_pair(turn);
        self.push_text(&mut ids, &mut patches, &mut is_vision, &[q]);
        self.next_id += 1;
        Request {
            id: self.next_id - 1,
            kind: WorkloadKind::Understanding,
            ids,
            patches,
            is_vision,
            max_new_tokens: 4,
            min_new_tokens: 0,
            expected_answer: Some(answer),
            images: vec![class],
        }
    }

    /// A whole dialog: `n` turns against one image, prompts all distinct
    /// (the acceptance workload of the partial-prefix warm start —
    /// benches/perf_prefix_cache.rs asserts per-turn byte-identity with
    /// cold runs and a skip rate at least the shared-prefix fraction).
    pub fn shared_image_dialog(&mut self, image_seed: u64, n: usize) -> Vec<Request> {
        (0..n).map(|t| self.qa_dialog_turn(image_seed, t)).collect()
    }

    /// `[BOS] ([img][STORY][color][shape][w…])×(n-1) [img][STORY]` →
    /// long free generation continuing the last segment.
    pub fn story(&mut self, n_images: usize, seg_text: usize, max_new: usize) -> Request {
        let mut ids = Vec::new();
        let mut patches = Vec::new();
        let mut is_vision = Vec::new();
        let mut images = Vec::new();
        self.push_text(&mut ids, &mut patches, &mut is_vision, &[BOS]);
        for seg in 0..n_images {
            let class = ImageClass::random(&mut self.rng);
            images.push(class);
            self.push_image(&mut ids, &mut patches, &mut is_vision, class);
            self.push_text(&mut ids, &mut patches, &mut is_vision, &[STORY_MARK]);
            if seg + 1 == n_images {
                break; // generation continues this segment
            }
            let mut toks = vec![color_token(class.color), shape_token(class.shape)];
            let mut w = self.rng.below(N_STORY_WORDS);
            for _ in 0..seg_text.saturating_sub(2) {
                toks.push(story_token(w));
                w = self.grammar.next_word(w, &mut self.rng);
            }
            self.push_text(&mut ids, &mut patches, &mut is_vision, &toks);
        }
        self.next_id += 1;
        Request {
            id: self.next_id - 1,
            kind: WorkloadKind::Story,
            ids,
            patches,
            is_vision,
            max_new_tokens: max_new,
            min_new_tokens: max_new * 3 / 4,
            expected_answer: None,
            images,
        }
    }

    /// Multi-frame ("video") probe in the story format the model was
    /// trained on: `[BOS] ([frame][STORY][color][shape][w..])×(F-1)
    /// [frame][STORY]` — the model must caption the LAST frame, so the
    /// expected first token is that frame's color word. A policy that
    /// prunes the final frame's informative patches across the 4-frame
    /// visual context fails this probe (the Table 4 stress).
    pub fn video(&mut self, n_frames: usize) -> Request {
        let mut ids = Vec::new();
        let mut patches = Vec::new();
        let mut is_vision = Vec::new();
        let mut images = Vec::new();
        self.push_text(&mut ids, &mut patches, &mut is_vision, &[BOS]);
        for f in 0..n_frames {
            let class = ImageClass::random(&mut self.rng);
            images.push(class);
            self.push_image(&mut ids, &mut patches, &mut is_vision, class);
            if f + 1 == n_frames {
                // prompt ends at the frame: STORY_MARK comes from prefill
                // logits, the class word through the pruned cache
                break;
            }
            self.push_text(&mut ids, &mut patches, &mut is_vision, &[STORY_MARK]);
            let mut toks = vec![color_token(class.color), shape_token(class.shape)];
            let mut w = self.rng.below(N_STORY_WORDS);
            for _ in 0..4 {
                toks.push(story_token(w));
                w = self.grammar.next_word(w, &mut self.rng);
            }
            self.push_text(&mut ids, &mut patches, &mut is_vision, &toks);
        }
        let last = *images.last().expect("n_frames >= 1");
        let answer = color_token(last.color);
        self.next_id += 1;
        Request {
            id: self.next_id - 1,
            kind: WorkloadKind::Video,
            ids,
            patches,
            is_vision,
            max_new_tokens: 4,
            min_new_tokens: 0,
            expected_answer: Some(answer),
            images,
        }
    }

    /// MMMU-like blend for Table 3: QA-style prompt with a story tail and
    /// medium-length generation.
    pub fn mixed(&mut self) -> Request {
        if self.rng.bool(0.5) {
            let mut r = self.story(2, 10, 48);
            r.kind = WorkloadKind::Mixed;
            r
        } else {
            let mut r = self.understanding();
            r.kind = WorkloadKind::Mixed;
            r.max_new_tokens = 16;
            r
        }
    }

    pub fn make(&mut self, kind: WorkloadKind) -> Request {
        match kind {
            WorkloadKind::Understanding => self.understanding(),
            WorkloadKind::Story => self.story(3, 12, 160),
            WorkloadKind::Video => self.video(4),
            WorkloadKind::Mixed => self.mixed(),
        }
    }

    pub fn make_batch(&mut self, kind: WorkloadKind, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.make(kind)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelMeta;

    fn meta() -> ModelMeta {
        ModelMeta {
            vocab: 512,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_head: 32,
            d_mlp: 256,
            patch_dim: 32,
            n_patches: 16,
            max_pos: 640,
            dap_layer: 1,
        }
    }

    #[test]
    fn understanding_shape() {
        let m = meta();
        let g = StoryGrammar::uniform();
        let mut b = RequestBuilder::new(&m, &g, 1);
        let r = b.understanding();
        // BOS + 16 vision + Q
        assert_eq!(r.prompt_len(), 18);
        assert_eq!(r.n_vision(), 16);
        assert_eq!(r.patches.len(), 18 * 32);
        assert!(r.expected_answer.is_some());
        let ans = r.expected_answer.unwrap();
        assert!(is_color_token(ans) || is_shape_token(ans));
        // modality mask consistent with ids
        for (i, &isv) in r.is_vision.iter().enumerate() {
            assert_eq!(isv, r.ids[i] == IMG);
        }
    }

    #[test]
    fn story_has_n_images_and_open_tail() {
        let m = meta();
        let g = StoryGrammar::uniform();
        let mut b = RequestBuilder::new(&m, &g, 2);
        let r = b.story(3, 12, 100);
        assert_eq!(r.images.len(), 3);
        assert_eq!(r.n_vision(), 3 * 16);
        assert_eq!(*r.ids.last().unwrap(), STORY_MARK);
        assert_eq!(r.max_new_tokens, 100);
    }

    #[test]
    fn video_answer_refers_to_last_frame() {
        let m = meta();
        let g = StoryGrammar::uniform();
        let mut b = RequestBuilder::new(&m, &g, 3);
        let r = b.video(4);
        assert_eq!(r.n_vision(), 64);
        let last = *r.images.last().unwrap();
        assert_eq!(r.expected_answer.unwrap(), color_token(last.color));
        assert_eq!(*r.ids.last().unwrap(), IMG);
    }

    #[test]
    fn shared_image_qa_shares_the_prompt_prefix() {
        let m = meta();
        let g = StoryGrammar::uniform();
        let mut b = RequestBuilder::new(&m, &g, 7);
        let reqs = b.shared_image_qa(42, 8);
        assert_eq!(reqs.len(), 8);
        let prefix_len = 1 + m.n_patches; // [BOS][img]
        for r in &reqs {
            assert_eq!(r.prompt_len(), prefix_len + 1);
            assert_eq!(&r.ids[..prefix_len], &reqs[0].ids[..prefix_len]);
            assert_eq!(
                &r.patches[..prefix_len * m.patch_dim],
                &reqs[0].patches[..prefix_len * m.patch_dim],
                "bit-identical image features"
            );
            assert!(r.expected_answer.is_some());
        }
        // exactly two distinct prompts (color/shape question), alternating
        assert_eq!(reqs[0].ids, reqs[2].ids);
        assert_eq!(reqs[1].ids, reqs[3].ids);
        assert_ne!(reqs[0].ids, reqs[1].ids);
        // any builder at any workload seed reproduces the same prefix
        let mut b2 = RequestBuilder::new(&m, &g, 999);
        let other = b2.understanding_shared(42, true);
        assert_eq!(other.ids, reqs[0].ids);
        assert_eq!(other.patches, reqs[0].patches);
        // a different image seed diverges
        let diff = b2.understanding_shared(43, true);
        assert_ne!(diff.patches, reqs[0].patches);
    }

    #[test]
    fn dialog_turns_are_distinct_but_share_the_visual_prefix() {
        let m = meta();
        let g = StoryGrammar::uniform();
        let mut b = RequestBuilder::new(&m, &g, 7);
        let turns = b.shared_image_dialog(42, 8);
        assert_eq!(turns.len(), 8);
        let prefix_len = 1 + m.n_patches; // [BOS][img]
        for (t, r) in turns.iter().enumerate() {
            // [BOS][img] + 3 tokens per prior turn + this turn's question
            assert_eq!(r.prompt_len(), prefix_len + 3 * t + 1);
            assert_eq!(&r.ids[..prefix_len], &turns[0].ids[..prefix_len]);
            assert_eq!(
                &r.patches[..prefix_len * m.patch_dim],
                &turns[0].patches[..prefix_len * m.patch_dim],
                "bit-identical image features at every turn"
            );
            // the suffix after the image is text-only (the partial
            // warm start recomputes it through the decode path)
            assert!(r.is_vision[prefix_len..].iter().all(|&v| !v));
            assert!(r.expected_answer.is_some());
        }
        // every prompt is distinct: no exact-match hit can serve a turn
        for i in 0..turns.len() {
            for j in (i + 1)..turns.len() {
                assert_ne!(turns[i].ids, turns[j].ids, "turns {} vs {}", i, j);
            }
        }
        // a prior turn's whole prompt is a prefix of the next turn's
        // (the radix shape the partial lookup must not be shadowed by)
        assert_eq!(
            &turns[1].ids[..turns[0].ids.len()],
            &turns[0].ids[..],
            "dialog grows by appending to the previous prompt"
        );
        // any builder reproduces the same dialog for the same image seed
        let mut b2 = RequestBuilder::new(&m, &g, 999);
        let again = b2.qa_dialog_turn(42, 3);
        assert_eq!(again.ids, turns[3].ids);
        assert_eq!(again.patches, turns[3].patches);
    }

    #[test]
    fn ids_are_deterministic_per_seed() {
        let m = meta();
        let g = StoryGrammar::uniform();
        let r1 = RequestBuilder::new(&m, &g, 42).make(WorkloadKind::Story);
        let r2 = RequestBuilder::new(&m, &g, 42).make(WorkloadKind::Story);
        assert_eq!(r1.ids, r2.ids);
        assert_eq!(r1.patches, r2.patches);
    }

    #[test]
    fn prompts_fit_largest_bucket() {
        let m = meta();
        let g = StoryGrammar::uniform();
        let mut b = RequestBuilder::new(&m, &g, 4);
        for kind in [
            WorkloadKind::Understanding,
            WorkloadKind::Story,
            WorkloadKind::Video,
            WorkloadKind::Mixed,
        ] {
            for _ in 0..20 {
                let r = b.make(kind);
                assert!(r.prompt_len() <= 256, "{:?} prompt too long", kind);
            }
        }
    }
}
