//! Synthetic multimodal workload generators — the serving-time mirror of
//! python/compile/data.py.
//!
//! Requests sample from the same distribution the model was trained on:
//! identical token-id layout (model/vocab.rs), identical class-prototype
//! construction, and — for story text — the *exact* transition matrix the
//! trainer used (exported to artifacts/grammar.bin at build time).
//!
//! Three request families map to the paper's workloads (DESIGN.md §3):
//! * `understanding` — single-image QA (Table 1/6 stand-in)
//! * `story`         — multi-segment long generation (Table 2 / Seed-Story)
//! * `video`         — multi-frame QA (Table 4: TGIF/MSVD/MSRVT stand-in)
//! * `mixed`         — MMMU-like blend for the Table 3 ablation

pub mod images;
pub mod requests;

pub use images::{ImageClass, SyntheticImage};
pub use requests::{Request, RequestBuilder, WorkloadKind};

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::vocab;

/// Story-grammar transition matrix (row-stochastic, [W, W]).
pub struct StoryGrammar {
    trans: Vec<f32>,
    n: usize,
}

impl StoryGrammar {
    /// Load the build-time grammar from artifacts/grammar.bin.
    pub fn load(artifact_dir: &Path) -> Result<StoryGrammar> {
        let path = artifact_dir.join("grammar.bin");
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let n = vocab::N_STORY_WORDS;
        if bytes.len() != n * n * 4 {
            bail!("grammar.bin size {} != {}", bytes.len(), n * n * 4);
        }
        let trans: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(StoryGrammar { trans, n })
    }

    /// Uniform fallback when artifacts are absent (unit tests).
    pub fn uniform() -> StoryGrammar {
        let n = vocab::N_STORY_WORDS;
        StoryGrammar { trans: vec![1.0 / n as f32; n * n], n }
    }

    pub fn row(&self, word: usize) -> &[f32] {
        &self.trans[word * self.n..(word + 1) * self.n]
    }

    pub fn next_word(&self, word: usize, rng: &mut crate::util::rng::Rng) -> usize {
        let row = self.row(word);
        let weights: Vec<f64> = row.iter().map(|&w| w as f64).collect();
        rng.weighted(&weights)
    }

    /// Greedy most-likely next word (used by quality proxies).
    pub fn argmax_next(&self, word: usize) -> usize {
        crate::util::stats::argmax(self.row(word))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn uniform_grammar_samples_in_range() {
        let g = StoryGrammar::uniform();
        let mut rng = Rng::new(9);
        for w in [0, 5, 100] {
            let next = g.next_word(w, &mut rng);
            assert!(next < vocab::N_STORY_WORDS);
        }
    }

    #[test]
    fn loads_real_grammar_when_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if let Ok(g) = StoryGrammar::load(&dir) {
            // rows should be (approximately) stochastic and sparse
            let row = g.row(0);
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "row sum {}", sum);
            let nonzero = row.iter().filter(|&&x| x > 0.0).count();
            assert!(nonzero <= 12, "grammar rows should be sparse");
        }
    }
}
