//! Typed views over the tuple literals returned by the AOT executables.
//!
//! The output orders here mirror the return statements in
//! python/compile/model.py — any change there must be reflected here (the
//! shape checks below catch drift at the first call).

use anyhow::{bail, Result};
use xla::Literal;

use crate::model::ModelMeta;

fn take_f32(lit: &Literal, expect: usize, what: &str) -> Result<Vec<f32>> {
    let v = lit.to_vec::<f32>()?;
    if v.len() != expect {
        bail!("{}: got {} elements, expected {}", what, v.len(), expect);
    }
    Ok(v)
}

/// Prefill result: KV cache for the prompt + layer-0 DAP statistics.
#[derive(Debug, Clone)]
pub struct PrefillOut {
    /// `[vocab]` — logits at the last valid position
    pub logits: Vec<f32>,
    /// `[L, S, H, Dh]` slot-major KV
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// `[S]` — Eq. 1 text→key attention mass per column (dap layer)
    pub dap_sum: Vec<f32>,
    /// `[S]` — Eq. 3 max text→key attention per column (dap layer)
    pub dap_max: Vec<f32>,
    /// `[S]` — Eq. 1 mass restricted to text query rows `< n_prefix`
    /// (the prefix-row contribution a partial warm start caches; zeros
    /// when the call passed `n_prefix = 0`)
    pub dap_psum: Vec<f32>,
    /// `[S]` — Eq. 3 max restricted to text query rows `< n_prefix`
    pub dap_pmax: Vec<f32>,
    pub bucket: usize,
}

impl PrefillOut {
    pub fn from_literals(parts: Vec<Literal>, m: &ModelMeta, bucket: usize) -> Result<Self> {
        if parts.len() != 7 {
            bail!("prefill returned {} outputs, expected 7 (rebuild artifacts)", parts.len());
        }
        let kv = m.n_layers * bucket * m.n_heads * m.d_head;
        Ok(PrefillOut {
            logits: take_f32(&parts[0], m.vocab, "prefill.logits")?,
            k: take_f32(&parts[1], kv, "prefill.k")?,
            v: take_f32(&parts[2], kv, "prefill.v")?,
            dap_sum: take_f32(&parts[3], bucket, "prefill.dap_sum")?,
            dap_max: take_f32(&parts[4], bucket, "prefill.dap_max")?,
            dap_psum: take_f32(&parts[5], bucket, "prefill.dap_psum")?,
            dap_pmax: take_f32(&parts[6], bucket, "prefill.dap_pmax")?,
            bucket,
        })
    }

    /// Copy one token's K (or V) row `[L, H, Dh]` out of the bucket-major
    /// slab. `src` must be `self.k` or `self.v`.
    pub fn token_kv(&self, src: &[f32], m: &ModelMeta, slot: usize) -> Vec<f32> {
        let row = m.n_heads * m.d_head;
        let mut out = Vec::with_capacity(m.n_layers * row);
        for l in 0..m.n_layers {
            let base = (l * self.bucket + slot) * row;
            out.extend_from_slice(&src[base..base + row]);
        }
        out
    }
}

/// One decode step for a batch.
#[derive(Debug, Clone)]
pub struct DecodeOut {
    /// `[B, vocab]`
    pub logits: Vec<f32>,
    /// `[B, L, H, Dh]` — K/V of the token just processed
    pub k_new: Vec<f32>,
    pub v_new: Vec<f32>,
    /// `[B, C]` — layer/head-mean probability mass per cache slot
    pub attn_mean: Vec<f32>,
    /// `[B, C]` — max-over-heads of the layer-mean mass (AdaKV signal)
    pub attn_peak: Vec<f32>,
    /// `[B]` — mean mass on the new token itself
    pub self_mean: Vec<f32>,
    /// `[B, C]` — the dap layer's head-mean probability mass per cache
    /// slot: this query row's contribution to the Eq. 1 column sum /
    /// Eq. 3 column max. Partial warm starts accumulate these over the
    /// recomputed suffix rows to reconstruct the request's own DAP
    /// statistics (prefix/mod.rs).
    pub dap_row: Vec<f32>,
    /// `[B]` — the dap layer's head-mean mass on the token itself (the
    /// row's contribution to its own column)
    pub dap_row_self: Vec<f32>,
    pub batch: usize,
    pub capacity: usize,
}

impl DecodeOut {
    pub fn from_literals(
        parts: Vec<Literal>,
        m: &ModelMeta,
        batch: usize,
        capacity: usize,
    ) -> Result<Self> {
        if parts.len() != 8 {
            bail!("decode returned {} outputs, expected 8 (rebuild artifacts)", parts.len());
        }
        let row = m.n_heads * m.d_head;
        Ok(DecodeOut {
            logits: take_f32(&parts[0], batch * m.vocab, "decode.logits")?,
            k_new: take_f32(&parts[1], batch * m.n_layers * row, "decode.k_new")?,
            v_new: take_f32(&parts[2], batch * m.n_layers * row, "decode.v_new")?,
            attn_mean: take_f32(&parts[3], batch * capacity, "decode.attn_mean")?,
            attn_peak: take_f32(&parts[4], batch * capacity, "decode.attn_peak")?,
            self_mean: take_f32(&parts[5], batch, "decode.self_mean")?,
            dap_row: take_f32(&parts[6], batch * capacity, "decode.dap_row")?,
            dap_row_self: take_f32(&parts[7], batch, "decode.dap_row_self")?,
            batch,
            capacity,
        })
    }

    pub fn lane_logits<'a>(&'a self, m: &ModelMeta, lane: usize) -> &'a [f32] {
        &self.logits[lane * m.vocab..(lane + 1) * m.vocab]
    }

    /// `[L, H, Dh]` new-token K (or V) for one lane. `src` must be
    /// `self.k_new` or `self.v_new`.
    pub fn lane_kv<'a>(&'a self, m: &ModelMeta, src: &'a [f32], lane: usize) -> &'a [f32] {
        let n = m.n_layers * m.n_heads * m.d_head;
        &src[lane * n..(lane + 1) * n]
    }

    /// Layer/head-mean attention mass per cache slot for one lane.
    pub fn lane_mean<'a>(&'a self, lane: usize) -> &'a [f32] {
        &self.attn_mean[lane * self.capacity..(lane + 1) * self.capacity]
    }

    /// Max-over-heads mass per cache slot for one lane.
    pub fn lane_peak<'a>(&'a self, lane: usize) -> &'a [f32] {
        &self.attn_peak[lane * self.capacity..(lane + 1) * self.capacity]
    }

    /// Mean self-attention mass (initial score of the new slot).
    pub fn lane_self_score(&self, lane: usize) -> f32 {
        self.self_mean[lane]
    }

    /// Dap-layer head-mean row (this query's Eq. 1/3 contribution per
    /// cache slot) for one lane.
    pub fn lane_dap_row<'a>(&'a self, lane: usize) -> &'a [f32] {
        &self.dap_row[lane * self.capacity..(lane + 1) * self.capacity]
    }

    /// Dap-layer head-mean mass the lane's query put on itself.
    pub fn lane_dap_self(&self, lane: usize) -> f32 {
        self.dap_row_self[lane]
    }
}

/// One chunked extend step: S new token rows against a C-slot cache —
/// the batched suffix recompute of partial warm starts.
#[derive(Debug, Clone)]
pub struct ExtendOut {
    /// `[B, vocab]` — logits at each lane's LAST valid row (`n_new-1`)
    pub logits: Vec<f32>,
    /// `[B, L, S, H, Dh]` — K/V of the chunk's rows (rows ≥ n_new are
    /// padding garbage; never read them)
    pub k_new: Vec<f32>,
    pub v_new: Vec<f32>,
    /// `[B, S, C+S]` — the dap layer's head-mean probability row per
    /// chunk row: columns `0..C` over the cache slots, `C..C+S` over the
    /// chunk's own rows (`C+i` is row i's own column). Each valid row,
    /// taken in row order, is exactly the Eq. 1 / Eq. 3 contribution the
    /// one-token decode loop would have produced for that position, so
    /// host accumulation is order-identical (prefix/replay.rs).
    pub dap_rows: Vec<f32>,
    pub batch: usize,
    pub chunk: usize,
    pub capacity: usize,
}

impl ExtendOut {
    pub fn from_literals(
        parts: Vec<Literal>,
        m: &ModelMeta,
        batch: usize,
        chunk: usize,
        capacity: usize,
    ) -> Result<Self> {
        if parts.len() != 4 {
            bail!("extend returned {} outputs, expected 4 (rebuild artifacts)", parts.len());
        }
        let row = m.n_heads * m.d_head;
        Ok(ExtendOut {
            logits: take_f32(&parts[0], batch * m.vocab, "extend.logits")?,
            k_new: take_f32(&parts[1], batch * m.n_layers * chunk * row, "extend.k_new")?,
            v_new: take_f32(&parts[2], batch * m.n_layers * chunk * row, "extend.v_new")?,
            dap_rows: take_f32(
                &parts[3],
                batch * chunk * (capacity + chunk),
                "extend.dap_rows",
            )?,
            batch,
            chunk,
            capacity,
        })
    }

    pub fn lane_logits<'a>(&'a self, m: &ModelMeta, lane: usize) -> &'a [f32] {
        &self.logits[lane * m.vocab..(lane + 1) * m.vocab]
    }

    /// `[L, H, Dh]` K (or V) of one chunk row in one lane — the shape
    /// `KvSlab::append` takes. `src` must be `self.k_new` or `self.v_new`.
    pub fn row_kv(&self, src: &[f32], m: &ModelMeta, lane: usize, row: usize) -> Vec<f32> {
        let r = m.n_heads * m.d_head;
        let mut out = Vec::with_capacity(m.n_layers * r);
        for l in 0..m.n_layers {
            let base = ((lane * m.n_layers + l) * self.chunk + row) * r;
            out.extend_from_slice(&src[base..base + r]);
        }
        out
    }

    /// One chunk row's dap contributions, split at the cache/chunk
    /// boundary: `(cache_cols[C], chunk_cols[S])`. `chunk_cols[i]` is
    /// the row's own column when `i == row`.
    pub fn row_dap<'a>(&'a self, lane: usize, row: usize) -> (&'a [f32], &'a [f32]) {
        let w = self.capacity + self.chunk;
        let base = (lane * self.chunk + row) * w;
        let full = &self.dap_rows[base..base + w];
        full.split_at(self.capacity)
    }
}

/// Instrumented prefill (observation harnesses: Figs. 2/3/5).
#[derive(Debug, Clone)]
pub struct AnalysisOut {
    pub logits: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub dap_sum: Vec<f32>,
    pub dap_max: Vec<f32>,
    /// `[L, 3]` — (overall, visual, text) sparsity per layer (Eq. 7)
    pub sparsity: Vec<f32>,
    /// `[L, S]` — per-layer DAP column sums
    pub colsum: Vec<f32>,
    /// `[L, S]` — per-layer DAP column maxes
    pub colmax: Vec<f32>,
    /// `[H, S, S]` — layer-0 attention probabilities
    pub probs0: Vec<f32>,
    pub bucket: usize,
}

impl AnalysisOut {
    pub fn from_literals(parts: Vec<Literal>, m: &ModelMeta, bucket: usize) -> Result<Self> {
        if parts.len() != 9 {
            bail!("analysis returned {} outputs, expected 9", parts.len());
        }
        let kv = m.n_layers * bucket * m.n_heads * m.d_head;
        Ok(AnalysisOut {
            logits: take_f32(&parts[0], m.vocab, "analysis.logits")?,
            k: take_f32(&parts[1], kv, "analysis.k")?,
            v: take_f32(&parts[2], kv, "analysis.v")?,
            dap_sum: take_f32(&parts[3], bucket, "analysis.dap_sum")?,
            dap_max: take_f32(&parts[4], bucket, "analysis.dap_max")?,
            sparsity: take_f32(&parts[5], m.n_layers * 3, "analysis.sparsity")?,
            colsum: take_f32(&parts[6], m.n_layers * bucket, "analysis.colsum")?,
            colmax: take_f32(&parts[7], m.n_layers * bucket, "analysis.colmax")?,
            probs0: take_f32(&parts[8], m.n_heads * bucket * bucket, "analysis.probs0")?,
            bucket,
        })
    }

    /// (overall, visual, text) sparsity for a layer.
    pub fn layer_sparsity(&self, layer: usize) -> (f32, f32, f32) {
        let b = layer * 3;
        (self.sparsity[b], self.sparsity[b + 1], self.sparsity[b + 2])
    }

    pub fn layer_colsum(&self, layer: usize) -> &[f32] {
        &self.colsum[layer * self.bucket..(layer + 1) * self.bucket]
    }

    pub fn layer_colmax(&self, layer: usize) -> &[f32] {
        &self.colmax[layer * self.bucket..(layer + 1) * self.bucket]
    }
}
