//! Runtime — loads AOT HLO artifacts and executes them via PJRT.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): HLO text →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute_b`.
//! Weights are uploaded once at startup and stay device-resident as
//! `PjRtBuffer`s; per-step tensors (token ids, KV caches) are uploaded per
//! call — see DESIGN.md §2 for why caches are host-owned.
//!
//! Executables are compiled lazily on first use and cached, so binaries
//! that only ever decode at batch 1 never pay for the batch-4 variants.

pub mod outputs;

use std::cell::RefCell; // hae-lint: allow(R3-forbidden-api) device-thread-confined executable caches (docs/CONCURRENCY.md)
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::model::{Manifest, ModelMeta};
pub use outputs::{AnalysisOut, DecodeOut, ExtendOut, PrefillOut};

/// Wall-clock accounting for one executable call.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepTiming {
    /// host→device uploads (seconds)
    pub upload_s: f64,
    /// PJRT execute (seconds)
    pub execute_s: f64,
    /// device→host readback + unpacking (seconds)
    pub download_s: f64,
}

impl StepTiming {
    pub fn total_s(&self) -> f64 {
        self.upload_s + self.execute_s + self.download_s
    }
}

pub struct Runtime {
    client: PjRtClient,
    pub manifest: Manifest,
    weights: Vec<PjRtBuffer>,
    prefill: RefCell<BTreeMap<usize, PjRtLoadedExecutable>>,
    decode: RefCell<BTreeMap<(usize, usize), PjRtLoadedExecutable>>,
    /// chunked extend executables, keyed on (batch, chunk, capacity)
    extend: RefCell<BTreeMap<(usize, usize, usize), PjRtLoadedExecutable>>,
    analysis: RefCell<BTreeMap<usize, PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Load manifest + weights and initialise the PJRT CPU client.
    pub fn load(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let weights = upload_weights(&client, &manifest)?;
        Ok(Runtime {
            client,
            manifest,
            weights,
            prefill: RefCell::new(BTreeMap::new()),
            decode: RefCell::new(BTreeMap::new()),
            extend: RefCell::new(BTreeMap::new()),
            analysis: RefCell::new(BTreeMap::new()),
        })
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.manifest.model
    }

    fn compile(&self, name: &str) -> Result<PjRtLoadedExecutable> {
        let path = self.manifest.hlo_path(name);
        let proto = HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("loading HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", name))
    }

    fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(data, dims, None)?)
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<i32>(data, dims, None)?)
    }

    fn run(
        &self,
        exe: &PjRtLoadedExecutable,
        step_args: Vec<PjRtBuffer>,
    ) -> Result<(Vec<Literal>, StepTiming)> {
        let mut timing = StepTiming::default();
        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        args.extend(step_args.iter());
        let t0 = Instant::now();
        let out = exe.execute_b::<&PjRtBuffer>(&args)?;
        timing.execute_s = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let tuple = out[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        timing.download_s = t1.elapsed().as_secs_f64();
        Ok((parts, timing))
    }

    /// Run one prefill over `ids/patches/is_vision` (padded to `bucket`).
    ///
    /// `n_tokens` is the number of valid positions (≤ bucket).
    /// `n_prefix` marks the reusable-prefix boundary: the graph
    /// additionally emits the DAP statistics restricted to text query
    /// rows `< n_prefix` (`PrefillOut::dap_psum`/`dap_pmax`), which the
    /// prefix cache stores for partial warm starts. Pass 0 when the
    /// prompt has no reusable prefix — the restricted stats come back as
    /// zeros and are ignored.
    pub fn prefill(
        &self,
        bucket: usize,
        ids: &[i32],
        patches: &[f32],
        is_vision: &[f32],
        n_tokens: usize,
        n_prefix: usize,
    ) -> Result<(PrefillOut, StepTiming)> {
        let m = self.meta();
        if ids.len() != bucket || is_vision.len() != bucket {
            bail!("prefill args not padded to bucket {}", bucket);
        }
        if patches.len() != bucket * m.patch_dim {
            bail!("patches len {} != {}", patches.len(), bucket * m.patch_dim);
        }
        if !self.prefill.borrow().contains_key(&bucket) {
            if !self.manifest.shapes.prefill_buckets.contains(&bucket) {
                bail!("no prefill artifact for bucket {}", bucket);
            }
            let exe = self.compile(&format!("prefill_s{}", bucket))?;
            self.prefill.borrow_mut().insert(bucket, exe);
        }
        let t0 = Instant::now();
        let args = vec![
            self.buf_i32(ids, &[bucket])?,
            self.buf_f32(patches, &[bucket, m.patch_dim])?,
            self.buf_f32(is_vision, &[bucket])?,
            self.buf_i32(&[n_tokens as i32], &[])?,
            self.buf_i32(&[n_prefix as i32], &[])?,
        ];
        let upload_s = t0.elapsed().as_secs_f64();
        let cache = self.prefill.borrow();
        let exe = cache.get(&bucket).unwrap();
        let (parts, mut timing) = self.run(exe, args)?;
        timing.upload_s = upload_s;
        let out = PrefillOut::from_literals(parts, m, bucket)?;
        Ok((out, timing))
    }

    /// Run one batched decode step at (batch, capacity).
    ///
    /// `k_cache`/`v_cache` are `[B, L, C, H, Dh]` host slabs; `lengths[b]`
    /// live slots per lane. Lanes past the live batch can carry anything —
    /// set their length to 0 and token/pos to 0.
    #[allow(clippy::too_many_arguments)]
    pub fn decode(
        &self,
        batch: usize,
        capacity: usize,
        tokens: &[i32],
        positions: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
        lengths: &[i32],
    ) -> Result<(DecodeOut, StepTiming)> {
        let m = self.meta();
        let slab = m.n_layers * capacity * m.n_heads * m.d_head;
        if tokens.len() != batch || positions.len() != batch || lengths.len() != batch {
            bail!("decode scalar args must have len {}", batch);
        }
        if k_cache.len() != batch * slab || v_cache.len() != batch * slab {
            bail!(
                "decode cache len {} != {} (B{} C{})",
                k_cache.len(),
                batch * slab,
                batch,
                capacity
            );
        }
        for (b, &l) in lengths.iter().enumerate() {
            if l as usize >= capacity {
                bail!("lane {}: length {} must be < capacity {}", b, l, capacity);
            }
        }
        let key = (batch, capacity);
        if !self.decode.borrow().contains_key(&key) {
            if !self.manifest.shapes.decode_batches.contains(&batch)
                || !self.manifest.shapes.decode_capacities.contains(&capacity)
            {
                bail!("no decode artifact for batch {} capacity {}", batch, capacity);
            }
            let exe = self.compile(&format!("decode_b{}_c{}", batch, capacity))?;
            self.decode.borrow_mut().insert(key, exe);
        }
        let dims = [batch, m.n_layers, capacity, m.n_heads, m.d_head];
        let t0 = Instant::now();
        let args = vec![
            self.buf_i32(tokens, &[batch])?,
            self.buf_i32(positions, &[batch])?,
            self.buf_f32(k_cache, &dims)?,
            self.buf_f32(v_cache, &dims)?,
            self.buf_i32(lengths, &[batch])?,
        ];
        let upload_s = t0.elapsed().as_secs_f64();
        let cache = self.decode.borrow();
        let exe = cache.get(&key).unwrap();
        let (parts, mut timing) = self.run(exe, args)?;
        timing.upload_s = upload_s;
        let out = DecodeOut::from_literals(parts, m, batch, capacity)?;
        Ok((out, timing))
    }

    /// Run one chunked extend step at (batch, chunk, capacity): `chunk`
    /// new token rows per lane against an existing cache — the batched
    /// suffix recompute of partial warm starts.
    ///
    /// `tokens`/`positions` are `[B, S]` row-major (positions explicit,
    /// so suffix rows sit at their exact prompt offsets); `k_cache`/
    /// `v_cache` are `[B, L, C, H, Dh]` host slabs; `lengths[b]` live
    /// cache slots; `n_new[b]` valid rows (≤ chunk — the rest is
    /// padding the graph masks). Lane b's logits are taken at its row
    /// `n_new[b]-1`.
    #[allow(clippy::too_many_arguments)]
    pub fn extend(
        &self,
        batch: usize,
        chunk: usize,
        capacity: usize,
        tokens: &[i32],
        positions: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
        lengths: &[i32],
        n_new: &[i32],
    ) -> Result<(ExtendOut, StepTiming)> {
        let m = self.meta();
        let slab = m.n_layers * capacity * m.n_heads * m.d_head;
        if tokens.len() != batch * chunk || positions.len() != batch * chunk {
            bail!("extend row args must have len {}", batch * chunk);
        }
        if lengths.len() != batch || n_new.len() != batch {
            bail!("extend lane args must have len {}", batch);
        }
        if k_cache.len() != batch * slab || v_cache.len() != batch * slab {
            bail!(
                "extend cache len {} != {} (B{} C{})",
                k_cache.len(),
                batch * slab,
                batch,
                capacity
            );
        }
        for (b, (&l, &nn)) in lengths.iter().zip(n_new.iter()).enumerate() {
            if l as usize > capacity {
                bail!("lane {}: length {} exceeds capacity {}", b, l, capacity);
            }
            if nn as usize > chunk {
                bail!("lane {}: n_new {} exceeds chunk {}", b, nn, chunk);
            }
        }
        let key = (batch, chunk, capacity);
        if !self.extend.borrow().contains_key(&key) {
            if !self.manifest.shapes.extend_batches.contains(&batch)
                || !self.manifest.shapes.extend_chunks.contains(&chunk)
                || !self.manifest.shapes.decode_capacities.contains(&capacity)
            {
                bail!(
                    "no extend artifact for batch {} chunk {} capacity {} \
                     (run `make artifacts`)",
                    batch,
                    chunk,
                    capacity
                );
            }
            let exe =
                self.compile(&format!("extend_b{}_s{}_c{}", batch, chunk, capacity))?;
            self.extend.borrow_mut().insert(key, exe);
        }
        let dims = [batch, m.n_layers, capacity, m.n_heads, m.d_head];
        let t0 = Instant::now();
        let args = vec![
            self.buf_i32(tokens, &[batch, chunk])?,
            self.buf_i32(positions, &[batch, chunk])?,
            self.buf_f32(k_cache, &dims)?,
            self.buf_f32(v_cache, &dims)?,
            self.buf_i32(lengths, &[batch])?,
            self.buf_i32(n_new, &[batch])?,
        ];
        let upload_s = t0.elapsed().as_secs_f64();
        let cache = self.extend.borrow();
        let exe = cache.get(&key).unwrap();
        let (parts, mut timing) = self.run(exe, args)?;
        timing.upload_s = upload_s;
        let out = ExtendOut::from_literals(parts, m, batch, chunk, capacity)?;
        Ok((out, timing))
    }

    /// Run the analysis (instrumented prefill) variant.
    pub fn analysis(
        &self,
        bucket: usize,
        ids: &[i32],
        patches: &[f32],
        is_vision: &[f32],
        n_tokens: usize,
    ) -> Result<(AnalysisOut, StepTiming)> {
        let m = self.meta();
        if !self.analysis.borrow().contains_key(&bucket) {
            if !self.manifest.shapes.analysis_buckets.contains(&bucket) {
                bail!("no analysis artifact for bucket {}", bucket);
            }
            let exe = self.compile(&format!("analysis_s{}", bucket))?;
            self.analysis.borrow_mut().insert(bucket, exe);
        }
        let t0 = Instant::now();
        let args = vec![
            self.buf_i32(ids, &[bucket])?,
            self.buf_f32(patches, &[bucket, m.patch_dim])?,
            self.buf_f32(is_vision, &[bucket])?,
            self.buf_i32(&[n_tokens as i32], &[])?,
            // analysis shares the prefill graph: no reusable-prefix
            // boundary to report here
            self.buf_i32(&[0i32], &[])?,
        ];
        let upload_s = t0.elapsed().as_secs_f64();
        let cache = self.analysis.borrow();
        let exe = cache.get(&bucket).unwrap();
        let (parts, mut timing) = self.run(exe, args)?;
        timing.upload_s = upload_s;
        let out = AnalysisOut::from_literals(parts, m, bucket)?;
        Ok((out, timing))
    }

    /// Pre-compile a set of executables (used by the server to avoid
    /// first-request latency spikes).
    pub fn warmup(&self, batches: &[usize]) -> Result<()> {
        for &b in &self.manifest.shapes.prefill_buckets.clone() {
            if !self.prefill.borrow().contains_key(&b) {
                let exe = self.compile(&format!("prefill_s{}", b))?;
                self.prefill.borrow_mut().insert(b, exe);
            }
        }
        for &bt in batches {
            for &c in &self.manifest.shapes.decode_capacities.clone() {
                let key = (bt, c);
                if !self.decode.borrow().contains_key(&key) {
                    let exe = self.compile(&format!("decode_b{}_c{}", bt, c))?;
                    self.decode.borrow_mut().insert(key, exe);
                }
            }
        }
        Ok(())
    }
}

fn upload_weights(client: &PjRtClient, manifest: &Manifest) -> Result<Vec<PjRtBuffer>> {
    let bin = manifest.dir.join("weights.bin");
    let bytes = std::fs::read(&bin)
        .with_context(|| format!("reading {} (run `make artifacts`)", bin.display()))?;
    let floats: &[f32] = unsafe {
        std::slice::from_raw_parts(bytes.as_ptr() as *const f32, bytes.len() / 4)
    };
    let mut out = Vec::with_capacity(manifest.weights.len());
    for w in &manifest.weights {
        let start = w.offset / 4;
        let data = &floats[start..start + w.numel];
        let buf = client
            .buffer_from_host_buffer::<f32>(data, &w.shape, None)
            .with_context(|| format!("uploading weight {}", w.name))?;
        out.push(buf);
    }
    Ok(out)
}
