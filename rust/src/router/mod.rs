//! Prefix-affinity routing tier: N in-process engine replicas behind one
//! listener.
//!
//! The router owns the serve loop's front half. Connection threads feed
//! the one shared mailbox exactly as before; the router thread consumes
//! it, classifies each raw line, and forwards the line — unmodified —
//! into the chosen replica's own ingest channel, where the existing
//! per-replica serve loop (`server::replica_loop`) parses and schedules
//! it exactly as the single-engine server always has.
//!
//! # Placement
//!
//! * **Affinity** (default): requests carrying an `image_seed` (or a
//!   `seed`, which makes the whole prompt deterministic) are synthesized
//!   once at the router and keyed by the prompt's first vision-segment
//!   content hash ([`crate::prefix::vision_affinity_hash`] — the same
//!   extraction the prefix cache uses, so the router and the cache can
//!   never disagree about image identity). The hash is looked up on a
//!   consistent [`HashRing`], so every question about one image lands on
//!   the replica whose prefix cache already holds that image's unpruned
//!   visual prefix. Text-only / non-deterministic requests fall back to
//!   least-loaded placement (router backlog + scheduler queue + live
//!   lanes).
//! * **Round-robin** (`--router round_robin`): the control arm for the
//!   routing bench — placement ignores content, so the shared-image
//!   workload's prefix hit rate dilutes across replicas.
//!
//! # Robustness
//!
//! * **Load shedding** (`--shed-queue N`): when the target replica's
//!   admission depth (router backlog + scheduler queue) is at the bound,
//!   the router answers `{"kind":"error","reason":"shed"}` immediately
//!   instead of queueing — the client hears "back off" in microseconds
//!   rather than timing out behind a deep queue.
//! * **Spill** (`--spill-occupancy F`): when the primary's page-pool
//!   occupancy is at or above `F`, affinity traffic routes to the ring's
//!   second choice — a *stable* alternate per image, so spilled traffic
//!   builds a warm prefix on exactly one other replica instead of
//!   spraying cold prefills everywhere.
//!
//! Both are counted and exposed as `hae_router_*` Prometheus series
//! (docs/OBSERVABILITY.md), and `{"kind":"stats"}` at N>1 returns a
//! merged view that sums replica counters, recomputes the aggregate
//! prefix hit rate, and carries every replica's full snapshot under
//! `per_replica` (docs/SERVING.md).
//!
//! # Threading (docs/CONCURRENCY.md)
//!
//! The router runs on the serve thread and owns nothing but the ring,
//! its counters and the replica senders; per-replica health is published
//! by replica threads through lock-free atomics ([`ReplicaHealth`]).
//! The router holds **no lock across a send into a replica channel** —
//! there is no lock to hold — and hae-lint R1 enforces that shape for
//! future edits (docs/STATIC_ANALYSIS.md).

pub mod ring;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use crate::model::ModelMeta;
use crate::obs::prometheus::{counter, gauge, labeled_gauge};
use crate::server::{error_reply, synthesize, Job};
use crate::util::json::{num, obj, s, Json};
use crate::workload::{RequestBuilder, StoryGrammar};

pub use ring::{HashRing, DEFAULT_VNODES};

/// How long a control fan-out waits for one replica's reply before the
/// merged view proceeds without it (a replica deep in a decode step
/// answers at its next ingest drain, normally well under this).
const CONTROL_REPLY_TIMEOUT: Duration = Duration::from_secs(5);

const SHUTDOWN_OK: &str = "{\"ok\":true,\"shutdown\":true}";

/// Placement policy for workload lines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Consistent-hash on the vision-segment content hash; least-loaded
    /// for text-only requests.
    Affinity,
    /// Ignore content entirely — the bench control arm.
    RoundRobin,
}

impl RouterPolicy {
    pub fn parse(sp: &str) -> Option<RouterPolicy> {
        match sp {
            "affinity" => Some(RouterPolicy::Affinity),
            "round_robin" | "rr" => Some(RouterPolicy::RoundRobin),
            _ => None,
        }
    }

    pub fn accepted() -> &'static str {
        "affinity, round_robin"
    }
}

/// Router knobs (`--router`, `--shed-queue`, `--spill-occupancy`).
#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub policy: RouterPolicy,
    /// shed when the target replica's admission depth reaches this
    /// (None = never shed; a full replica channel then blocks instead)
    pub shed_queue: Option<usize>,
    /// spill affinity traffic to the ring's second choice when the
    /// primary's pool occupancy is at or above this fraction (None =
    /// never spill)
    pub spill_occupancy: Option<f64>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { policy: RouterPolicy::Affinity, shed_queue: None, spill_occupancy: None }
    }
}

/// Per-replica health, published by the replica's scheduler thread once
/// per round and read lock-free by the router for shed / spill /
/// least-loaded decisions. Atomics, not a mutex: the router must never
/// hold a replica-state lock across a dispatch into a replica channel
/// (hae-lint R1), and with atomics there is no lock to misuse.
#[derive(Debug, Default)]
pub struct ReplicaHealth {
    /// jobs forwarded by the router, not yet received by the replica loop
    backlog: AtomicUsize,
    /// scheduler admission-queue depth at last publish
    queued: AtomicUsize,
    /// live decode lanes at last publish
    active: AtomicUsize,
    pool_in_use: AtomicUsize,
    pool_pages: AtomicUsize,
    /// worst per-class SLO attainment × 1000 (1000 = all met / no targets)
    slo_milli: AtomicU64,
}

impl ReplicaHealth {
    pub fn new() -> ReplicaHealth {
        let h = ReplicaHealth::default();
        h.slo_milli.store(1000, Ordering::Relaxed);
        h
    }

    /// Router side: one job handed to this replica's channel.
    pub fn enqueue(&self) {
        self.backlog.fetch_add(1, Ordering::Relaxed);
    }

    /// Replica side: one job received off the channel. Saturating — a
    /// stray decrement must never wrap the gauge to usize::MAX.
    pub fn dequeue(&self) {
        let _ = self
            .backlog
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(1)));
    }

    /// Replica side: publish one round's scheduler/pool snapshot.
    pub fn publish(
        &self,
        queued: usize,
        active: usize,
        pool_in_use: usize,
        pool_pages: usize,
        slo_attainment: f64,
    ) {
        self.queued.store(queued, Ordering::Relaxed);
        self.active.store(active, Ordering::Relaxed);
        self.pool_in_use.store(pool_in_use, Ordering::Relaxed);
        self.pool_pages.store(pool_pages, Ordering::Relaxed);
        self.slo_milli.store((slo_attainment.clamp(0.0, 1.0) * 1000.0) as u64, Ordering::Relaxed);
    }

    /// Requests between this replica and admission: router backlog plus
    /// the scheduler queue — the quantity the shed bound is tested
    /// against.
    pub fn admission_depth(&self) -> usize {
        self.backlog.load(Ordering::Relaxed) + self.queued.load(Ordering::Relaxed)
    }

    /// Least-loaded score: everything queued or running.
    pub fn load_score(&self) -> usize {
        self.admission_depth() + self.active.load(Ordering::Relaxed)
    }

    /// Pool occupancy in [0,1]; 0 before the replica's first publish.
    pub fn pool_occupancy(&self) -> f64 {
        let pages = self.pool_pages.load(Ordering::Relaxed);
        if pages == 0 {
            return 0.0;
        }
        self.pool_in_use.load(Ordering::Relaxed) as f64 / pages as f64
    }

    pub fn slo_attainment(&self) -> f64 {
        self.slo_milli.load(Ordering::Relaxed) as f64 / 1000.0
    }
}

/// The router's handle to one replica: its ingest channel plus its
/// health block.
pub struct ReplicaLink {
    pub tx: mpsc::SyncSender<Job>,
    pub health: Arc<ReplicaHealth>,
}

/// Routing-decision counters, owned by the router loop (single-threaded
/// — plain integers, surfaced through merged stats and `hae_router_*`).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct RouterCounters {
    pub shed_total: u64,
    pub spill_total: u64,
    pub routed_affinity: u64,
    pub routed_least_loaded: u64,
    pub routed_round_robin: u64,
}

impl RouterCounters {
    /// The `"router"` block of the merged stats reply.
    fn json(&self, replicas: usize) -> Json {
        obj(vec![
            ("replicas", num(replicas as f64)),
            ("shed_total", num(self.shed_total as f64)),
            ("spill_total", num(self.spill_total as f64)),
            ("routed_affinity", num(self.routed_affinity as f64)),
            ("routed_least_loaded", num(self.routed_least_loaded as f64)),
            ("routed_round_robin", num(self.routed_round_robin as f64)),
        ])
    }
}

/// The typed shed reply — distinguishable from engine errors by
/// `kind == "error"` + `reason == "shed"` so clients can back off
/// instead of treating it as a request bug.
fn shed_reply(id: Option<i64>) -> String {
    let mut fields = vec![("kind", s("error")), ("reason", s("shed"))];
    if let Some(id) = id {
        fields.push(("id", num(id as f64)));
    }
    obj(fields).to_string_compact()
}

/// Replica flat-snapshot keys whose merged value is the sum across
/// replicas (counters, and gauges with additive semantics — pages and
/// bytes across N disjoint arenas add). Percentiles and rates are NOT
/// summable; the hit rate is recomputed from the summed counts and
/// everything else lives in `per_replica`.
const SUM_KEYS: &[&str] = &[
    "queue_depth",
    "lanes_occupied",
    "submitted",
    "completed",
    "failed",
    "rejected_queue_full",
    "rejected_kv_budget",
    "decode_steps",
    "extend_calls",
    "live_kv_bytes",
    "pool_pages",
    "live_pages",
    "free_pages",
    "refcount_errors",
    "prefix_hits",
    "prefix_partial_hits",
    "prefix_misses",
    "prefix_entries",
    "pages_shared",
    "prefill_tokens_skipped",
];

/// (series, flat key, is_counter): the aggregate Prometheus series the
/// merged exposition re-emits under the canonical names — summed across
/// replicas, each name exactly once. Histogram and percentile families
/// are per-replica quantities; scrape them from the `per_replica` stats
/// block instead (docs/SERVING.md).
const MERGED_SERIES: &[(&str, &str, bool)] = &[
    ("hae_queue_depth", "queue_depth", false),
    ("hae_lanes_occupied", "lanes_occupied", false),
    ("hae_requests_submitted_total", "submitted", true),
    ("hae_requests_completed_total", "completed", true),
    ("hae_requests_failed_total", "failed", true),
    ("hae_rejected_queue_full_total", "rejected_queue_full", true),
    ("hae_rejected_kv_budget_total", "rejected_kv_budget", true),
    ("hae_decode_steps_total", "decode_steps", true),
    ("hae_live_kv_bytes", "live_kv_bytes", false),
    ("hae_pool_pages", "pool_pages", false),
    ("hae_live_pages", "live_pages", false),
    ("hae_free_pages", "free_pages", false),
    ("hae_refcount_errors_total", "refcount_errors", true),
    ("hae_prefix_hits_total", "prefix_hits", true),
    ("hae_prefix_partial_hits_total", "prefix_partial_hits", true),
    ("hae_prefix_misses_total", "prefix_misses", true),
    ("hae_prefix_entries", "prefix_entries", false),
    ("hae_pages_shared", "pages_shared", false),
    ("hae_prefill_tokens_skipped_total", "prefill_tokens_skipped", true),
];

fn sum_key(snaps: &[Json], key: &str) -> f64 {
    snaps.iter().filter_map(|j| j.get(key).and_then(|v| v.as_f64())).sum()
}

/// Aggregate warm fraction, recomputed from the summed counts with the
/// registry's own definition ((hits + partial) / consulting admissions).
fn merged_hit_rate(snaps: &[Json]) -> f64 {
    let warm = sum_key(snaps, "prefix_hits") + sum_key(snaps, "prefix_partial_hits");
    let total = warm + sum_key(snaps, "prefix_misses");
    if total == 0.0 {
        0.0
    } else {
        warm / total
    }
}

/// Send `line` to every replica on a private reply channel, collect the
/// parsed replies (None for a replica that died or timed out — the
/// merged view degrades instead of wedging the router).
fn fan_out(links: &[ReplicaLink], line: &str) -> Vec<Option<Json>> {
    let mut waits = Vec::with_capacity(links.len());
    for link in links {
        let (rtx, rrx) = mpsc::channel::<String>();
        link.health.enqueue();
        if link.tx.send(Job { line: line.to_string(), reply: rtx }).is_err() {
            link.health.dequeue();
        }
        // a failed send dropped rtx, so the recv below errors immediately
        waits.push(rrx);
    }
    waits
        .into_iter()
        .map(|rrx| {
            rrx.recv_timeout(CONTROL_REPLY_TIMEOUT).ok().and_then(|l| Json::parse(&l).ok())
        })
        .collect()
}

/// Merged `{"kind":"stats"}` reply: summed flat counters, recomputed hit
/// rate, the router block, and every replica's full snapshot.
fn merged_stats_json(snaps: Vec<Json>, counters: &RouterCounters, replicas: usize) -> Json {
    let mut fields: Vec<(&str, Json)> =
        vec![("kind", s("stats")), ("replicas", num(replicas as f64))];
    for &key in SUM_KEYS {
        fields.push((key, num(sum_key(&snaps, key))));
    }
    fields.push(("prefix_hit_rate", num(merged_hit_rate(&snaps))));
    fields.push(("router", counters.json(replicas)));
    fields.push(("per_replica", Json::Arr(snaps)));
    obj(fields)
}

/// Append the `hae_router_*` series: decision counters plus per-replica
/// labeled health gauges. Emitted through the shared obs helpers so the
/// exposition shape — and the R4 metric/doc diff — stay uniform.
fn router_series(out: &mut String, c: &RouterCounters, links: &[ReplicaLink]) {
    gauge(out, "hae_router_replicas", "engine replicas behind the router", links.len() as f64);
    counter(out, "hae_router_shed_total", "requests answered with the typed shed reply", c.shed_total as f64);
    counter(out, "hae_router_spill_total", "affinity requests spilled to the second ring choice", c.spill_total as f64);
    counter(out, "hae_router_routed_affinity_total", "requests placed by consistent-hash affinity", c.routed_affinity as f64);
    counter(out, "hae_router_routed_least_loaded_total", "requests placed least-loaded (no stable affinity key)", c.routed_least_loaded as f64);
    counter(out, "hae_router_routed_round_robin_total", "requests placed round-robin (bench control arm)", c.routed_round_robin as f64);
    let labels: Vec<String> = (0..links.len()).map(|i| i.to_string()).collect();
    let depth_rows: Vec<(&str, f64)> = labels
        .iter()
        .zip(links)
        .map(|(l, link)| (l.as_str(), link.health.admission_depth() as f64))
        .collect();
    labeled_gauge(out, "hae_router_replica_queue_depth", "admission depth per replica (router backlog + scheduler queue)", "replica", &depth_rows);
    let occ_rows: Vec<(&str, f64)> = labels
        .iter()
        .zip(links)
        .map(|(l, link)| (l.as_str(), link.health.pool_occupancy()))
        .collect();
    labeled_gauge(out, "hae_router_replica_pool_occupancy", "arena occupancy fraction per replica", "replica", &occ_rows);
}

/// Merged Prometheus body at N>1: the summable canonical series (each
/// name once — scrapers must never see a duplicate family) plus the
/// router series.
fn merged_prometheus(snaps: &[Json], c: &RouterCounters, links: &[ReplicaLink]) -> String {
    let mut body = String::new();
    for &(series, key, is_counter) in MERGED_SERIES {
        let v = sum_key(snaps, key);
        if is_counter {
            counter(&mut body, series, "summed across replicas", v);
        } else {
            gauge(&mut body, series, "summed across replicas", v);
        }
    }
    gauge(&mut body, "hae_prefix_hit_rate", "warm fraction of cache-consulting admissions, all replicas", merged_hit_rate(snaps));
    router_series(&mut body, c, links);
    body
}

fn prometheus_reply(body: &str) -> String {
    obj(vec![("kind", s("stats")), ("format", s("prometheus")), ("body", s(body))])
        .to_string_compact()
}

/// Merged `{"kind":"trace"}` reply: concatenate every replica's retained
/// events (each request's events live wholly on the replica that served
/// it), re-sorted by timestamp; counts sum.
fn merged_trace(replies: Vec<Option<Json>>) -> Json {
    let mut count = 0.0;
    let mut dropped = 0.0;
    let mut events: Vec<Json> = Vec::new();
    for r in replies.into_iter().flatten() {
        count += r.get("count").and_then(|v| v.as_f64()).unwrap_or(0.0);
        dropped += r.get("dropped").and_then(|v| v.as_f64()).unwrap_or(0.0);
        if let Json::Obj(mut m) = r {
            if let Some(Json::Arr(ev)) = m.remove("events") {
                events.extend(ev);
            }
        }
    }
    events.sort_by(|a, b| {
        let ta = a.get("at_us").and_then(|v| v.as_f64()).unwrap_or(0.0);
        let tb = b.get("at_us").and_then(|v| v.as_f64()).unwrap_or(0.0);
        ta.partial_cmp(&tb).unwrap_or(std::cmp::Ordering::Equal)
    });
    obj(vec![
        ("kind", s("trace")),
        ("count", num(count)),
        ("dropped", num(dropped)),
        ("events", Json::Arr(events)),
    ])
}

fn least_loaded(links: &[ReplicaLink]) -> usize {
    links
        .iter()
        .enumerate()
        .min_by_key(|(_, l)| l.health.load_score())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// The router loop: consume the shared connection mailbox until a
/// shutdown line, forwarding raw lines into replica ingest channels.
/// Returns the decision counters (tests read them; the serve path reads
/// them through merged stats before this returns).
///
/// At N == 1 every control verb is forwarded raw to the only replica, so
/// the single-replica server's wire behavior is byte-identical to the
/// pre-router server (the one addition: the Prometheus body grows the
/// `hae_router_*` series).
pub(crate) fn router_loop(
    rx: mpsc::Receiver<Job>,
    meta: &ModelMeta,
    grammar: &StoryGrammar,
    links: &[ReplicaLink],
    cfg: &RouterConfig,
) -> RouterCounters {
    let n = links.len();
    let ring = HashRing::new(n as u32, DEFAULT_VNODES);
    // affinity synthesis only runs for lines carrying image_seed/seed,
    // whose prompts do not depend on builder state — any seed works here
    let mut builder = RequestBuilder::new(meta, grammar, 0xAFF1);
    let mut counters = RouterCounters::default();
    let mut rr_next = 0usize;

    while let Ok(job) = rx.recv() {
        if job.line.trim() == "shutdown" {
            // broadcast so every replica drains; their acks go to dummy
            // channels (the client hears ONE ok, from the router)
            for link in links {
                let (dtx, _drx) = mpsc::channel::<String>();
                link.health.enqueue();
                if link.tx.send(Job { line: "shutdown".into(), reply: dtx }).is_err() {
                    link.health.dequeue();
                }
            }
            let _ = job.reply.send(SHUTDOWN_OK.into());
            break;
        }
        let parsed = Json::parse(&job.line).ok();
        let kind = parsed.as_ref().and_then(|j| j.get("kind")).and_then(|v| v.as_str());
        match kind {
            Some("stats") => {
                let prom = parsed.as_ref().and_then(|j| j.get("format")).and_then(|v| v.as_str())
                    == Some("prometheus");
                if n == 1 && !prom {
                    forward(&links[0], job, cfg.shed_queue.is_some(), &mut counters);
                } else if n == 1 {
                    // unwrap the replica body and append the router series
                    match fan_out(links, &job.line).pop().flatten() {
                        Some(j) => {
                            let mut body = j
                                .get("body")
                                .and_then(|v| v.as_str())
                                .unwrap_or("")
                                .to_string();
                            router_series(&mut body, &counters, links);
                            let _ = job.reply.send(prometheus_reply(&body));
                        }
                        None => {
                            let _ = job
                                .reply
                                .send(error_reply(None, "replica stats unavailable"));
                        }
                    }
                } else {
                    let snaps: Vec<Json> =
                        fan_out(links, "{\"kind\":\"stats\"}").into_iter().flatten().collect();
                    let reply = if prom {
                        prometheus_reply(&merged_prometheus(&snaps, &counters, links))
                    } else {
                        merged_stats_json(snaps, &counters, n).to_string_compact()
                    };
                    let _ = job.reply.send(reply);
                }
                continue;
            }
            Some("trace") if n > 1 => {
                let replies = fan_out(links, &job.line);
                let _ = job.reply.send(merged_trace(replies).to_string_compact());
                continue;
            }
            Some("profile") if n > 1 => {
                let bodies: Vec<Json> =
                    fan_out(links, &job.line).into_iter().flatten().collect();
                let reply = obj(vec![
                    ("kind", s("profile")),
                    ("replicas", num(n as f64)),
                    ("per_replica", Json::Arr(bodies)),
                ]);
                let _ = job.reply.send(reply.to_string_compact());
                continue;
            }
            Some("trace") | Some("profile") => {
                forward(&links[0], job, cfg.shed_queue.is_some(), &mut counters);
                continue;
            }
            _ => {}
        }

        // workload line (or unparseable — the replica's ingest answers
        // with the same bad-json error the single-engine server sends)
        let id = parsed.as_ref().and_then(|j| j.get("id")).and_then(|v| v.as_i64());
        let affinity = match (cfg.policy, parsed.as_ref()) {
            (RouterPolicy::Affinity, Some(j))
                if j.get("image_seed").is_some() || j.get("seed").is_some() =>
            {
                synthesize(j, meta, grammar, &mut builder)
                    .ok()
                    .and_then(|(_, req)| crate::prefix::vision_affinity_hash(&req))
            }
            _ => None,
        };
        let mut target = match cfg.policy {
            RouterPolicy::RoundRobin => {
                counters.routed_round_robin += 1;
                let t = rr_next % n;
                rr_next += 1;
                t
            }
            RouterPolicy::Affinity => match affinity {
                Some(h) => {
                    counters.routed_affinity += 1;
                    ring.primary(h).unwrap_or(0) as usize
                }
                None => {
                    counters.routed_least_loaded += 1;
                    least_loaded(links)
                }
            },
        };
        // spill: primary pool hot → the stable second choice per image
        if let (Some(frac), Some(h)) = (cfg.spill_occupancy, affinity) {
            if links[target].health.pool_occupancy() >= frac {
                if let Some(second) = ring.second(h) {
                    counters.spill_total += 1;
                    target = second as usize;
                }
            }
        }
        // shed: answer immediately instead of queueing behind the bound
        if let Some(bound) = cfg.shed_queue {
            if links[target].health.admission_depth() >= bound {
                counters.shed_total += 1;
                let _ = job.reply.send(shed_reply(id));
                continue;
            }
        }
        // a false return means the job was shed at the full channel (the
        // bound check races with the replica's drain; the channel is the
        // backstop) or the replica is gone — both already answered
        let _ = forward(&links[target], job, cfg.shed_queue.is_some(), &mut counters);
    }
    counters
}

/// Hand one job to a replica channel. With shedding armed a full channel
/// sheds (the bound check races with the replica's drain, so the channel
/// is the backstop); without it the router blocks — the single-replica
/// default, matching the pre-router server's backpressure. Returns false
/// when the job was shed or the replica is gone.
fn forward(link: &ReplicaLink, job: Job, shed_on_full: bool, counters: &mut RouterCounters) -> bool {
    link.health.enqueue();
    match link.tx.try_send(job) {
        Ok(()) => true,
        Err(mpsc::TrySendError::Full(job)) => {
            link.health.dequeue();
            if shed_on_full {
                counters.shed_total += 1;
                let id = Json::parse(&job.line).ok().and_then(|j| {
                    j.get("id").and_then(|v| v.as_i64())
                });
                let _ = job.reply.send(shed_reply(id));
                false
            } else {
                link.health.enqueue();
                if link.tx.send(job).is_err() {
                    link.health.dequeue();
                    false
                } else {
                    true
                }
            }
        }
        Err(mpsc::TrySendError::Disconnected(job)) => {
            link.health.dequeue();
            let _ = job.reply.send(error_reply(None, "replica unavailable"));
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelMeta;
    use crate::obs::prometheus::parses_as_exposition;
    use std::sync::Mutex;

    fn meta() -> ModelMeta {
        ModelMeta {
            vocab: 512,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_head: 32,
            d_mlp: 256,
            patch_dim: 32,
            n_patches: 16,
            max_pos: 640,
            dap_layer: 1,
        }
    }

    type Seen = Arc<Mutex<Vec<String>>>;

    /// A fake replica: drains its channel, acks shutdown, answers stats
    /// with a canned snapshot, records workload lines.
    struct FakeReplica {
        link: ReplicaLink,
        seen: Seen,
        handle: std::thread::JoinHandle<()>,
    }

    fn fake_replica(stats: &str) -> FakeReplica {
        let (tx, rx) = mpsc::sync_channel::<Job>(64);
        let health = Arc::new(ReplicaHealth::new());
        let seen = Arc::new(Mutex::new(Vec::new()));
        let stats = stats.to_string();
        let handle = {
            let seen = seen.clone();
            let health = health.clone();
            std::thread::spawn(move || {
                for job in rx {
                    health.dequeue();
                    if job.line.trim() == "shutdown" {
                        let _ = job.reply.send(SHUTDOWN_OK.into());
                        break;
                    }
                    let parsed = Json::parse(&job.line).ok();
                    let kind = parsed
                        .as_ref()
                        .and_then(|j| j.get("kind"))
                        .and_then(|v| v.as_str())
                        .map(|v| v.to_string());
                    match kind.as_deref() {
                        Some("stats") => {
                            let prom = parsed
                                .as_ref()
                                .and_then(|j| j.get("format"))
                                .and_then(|v| v.as_str())
                                == Some("prometheus");
                            let reply = if prom {
                                prometheus_reply(
                                    "# HELP hae_fake one\n# TYPE hae_fake gauge\nhae_fake 1\n",
                                )
                            } else {
                                stats.clone()
                            };
                            let _ = job.reply.send(reply);
                        }
                        _ => {
                            seen.lock().unwrap().push(job.line.clone());
                            let _ = job.reply.send("{\"id\":0,\"tokens\":[]}".into());
                        }
                    }
                }
            })
        };
        FakeReplica { link: ReplicaLink { tx, health }, seen, handle }
    }

    struct Rig {
        tx: mpsc::SyncSender<Job>,
        router: std::thread::JoinHandle<RouterCounters>,
        fakes: Vec<(Seen, std::thread::JoinHandle<()>)>,
        links: Vec<ReplicaLink>,
    }

    /// Spin up `n` fakes plus a router thread over them.
    fn rig(n: usize, cfg: RouterConfig, stats: &str) -> Rig {
        let mut links = Vec::new();
        let mut links_for_router = Vec::new();
        let mut fakes = Vec::new();
        for _ in 0..n {
            let f = fake_replica(stats);
            links.push(ReplicaLink { tx: f.link.tx.clone(), health: f.link.health.clone() });
            links_for_router.push(f.link);
            fakes.push((f.seen, f.handle));
        }
        let (tx, rx) = mpsc::sync_channel::<Job>(64);
        let m = meta();
        let g = StoryGrammar::uniform();
        let router = std::thread::spawn(move || {
            router_loop(rx, &m, &g, &links_for_router, &cfg)
        });
        Rig { tx, router, fakes, links }
    }

    impl Rig {
        /// One request/reply round trip through the router.
        fn ask(&self, line: &str) -> String {
            let (rtx, rrx) = mpsc::channel::<String>();
            self.tx.send(Job { line: line.into(), reply: rtx }).unwrap();
            rrx.recv_timeout(Duration::from_secs(10)).expect("router replied")
        }

        fn shutdown(self) -> (RouterCounters, Vec<Vec<String>>) {
            let ok = self.ask("shutdown");
            assert_eq!(ok, SHUTDOWN_OK);
            let counters = self.router.join().unwrap();
            let mut seen = Vec::new();
            for (s, h) in self.fakes {
                h.join().unwrap();
                seen.push(s.lock().unwrap().clone());
            }
            (counters, seen)
        }
    }

    const CANNED: &str = r#"{"kind":"stats","submitted":3,"completed":2,"failed":0,"queue_depth":1,"refcount_errors":0,"prefix_hits":1,"prefix_partial_hits":1,"prefix_misses":2,"live_pages":5,"pool_pages":10}"#;

    fn wait_until_drained(r: &Rig) {
        // workload replies arrive per line, so asks are already synchronous
        for link in &r.links {
            for _ in 0..200 {
                if link.health.admission_depth() == 0 {
                    break;
                }
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }

    #[test]
    fn affinity_routes_one_image_to_one_replica() {
        let r = rig(2, RouterConfig::default(), CANNED);
        for (i, line) in [
            r#"{"id":1,"kind":"qa","image_seed":7,"q":"color"}"#,
            r#"{"id":2,"kind":"qa","image_seed":7,"q":"shape"}"#,
            r#"{"id":3,"kind":"qa","image_seed":7,"turn":0}"#,
            r#"{"id":4,"kind":"qa","image_seed":7,"turn":3}"#,
        ]
        .iter()
        .enumerate()
        {
            let reply = r.ask(line);
            assert!(reply.contains("tokens"), "line {} got {}", i, reply);
        }
        let (counters, seen) = r.shutdown();
        assert_eq!(counters.routed_affinity, 4);
        assert_eq!(counters.routed_least_loaded, 0);
        let (a, b) = (seen[0].len(), seen[1].len());
        assert_eq!(a + b, 4);
        assert!(
            a == 4 || b == 4,
            "same image split across replicas: {} / {}",
            a,
            b
        );
    }

    #[test]
    fn seeded_story_requests_also_route_by_affinity() {
        // "seed" makes the whole prompt (vision segments included)
        // deterministic, so the hash is stable across repeats
        let r = rig(2, RouterConfig::default(), CANNED);
        for _ in 0..3 {
            r.ask(r#"{"id":1,"kind":"qa","seed":42}"#);
        }
        let (counters, seen) = r.shutdown();
        assert_eq!(counters.routed_affinity, 3);
        assert!(seen[0].len() == 3 || seen[1].len() == 3, "seeded repeats split");
    }

    #[test]
    fn text_only_requests_go_least_loaded() {
        let r = rig(2, RouterConfig::default(), CANNED);
        // pin replica 0 as "busy": deep fake backlog
        for _ in 0..50 {
            r.links[0].health.enqueue();
        }
        for _ in 0..3 {
            let reply = r.ask(r#"{"id":5,"kind":"story"}"#);
            assert!(reply.contains("tokens"), "{}", reply);
        }
        for _ in 0..50 {
            r.links[0].health.dequeue();
        }
        let (counters, seen) = r.shutdown();
        assert_eq!(counters.routed_least_loaded, 3);
        assert_eq!(seen[1].len(), 3, "all text-only lines avoided the busy replica");
    }

    #[test]
    fn round_robin_ignores_content() {
        let cfg = RouterConfig { policy: RouterPolicy::RoundRobin, ..Default::default() };
        let r = rig(2, cfg, CANNED);
        for _ in 0..6 {
            r.ask(r#"{"id":1,"kind":"qa","image_seed":7}"#);
        }
        let (counters, seen) = r.shutdown();
        assert_eq!(counters.routed_round_robin, 6);
        assert_eq!(seen[0].len(), 3);
        assert_eq!(seen[1].len(), 3);
    }

    #[test]
    fn shed_reply_is_typed_and_echoes_id() {
        let cfg = RouterConfig { shed_queue: Some(0), ..Default::default() };
        let r = rig(2, cfg, CANNED);
        let reply = r.ask(r#"{"id":9,"kind":"qa","image_seed":7}"#);
        let j = Json::parse(&reply).unwrap();
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("error"));
        assert_eq!(j.get("reason").and_then(|v| v.as_str()), Some("shed"));
        assert_eq!(j.get("id").and_then(|v| v.as_i64()), Some(9));
        let (counters, seen) = r.shutdown();
        assert_eq!(counters.shed_total, 1);
        assert!(seen[0].is_empty() && seen[1].is_empty(), "shed line must not reach a replica");
    }

    #[test]
    fn control_verbs_are_never_shed() {
        let cfg = RouterConfig { shed_queue: Some(0), ..Default::default() };
        let r = rig(2, cfg, CANNED);
        let j = Json::parse(&r.ask(r#"{"kind":"stats"}"#)).unwrap();
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("stats"));
        r.shutdown();
    }

    #[test]
    fn spill_moves_hot_primary_traffic_to_stable_second_choice() {
        let cfg = RouterConfig { spill_occupancy: Some(0.9), ..Default::default() };
        let r = rig(2, cfg, CANNED);
        // find the primary the ring picks for this image, then mark it hot
        let m = meta();
        let g = StoryGrammar::uniform();
        let mut b = RequestBuilder::new(&m, &g, 1);
        let line = r#"{"id":1,"kind":"qa","image_seed":7}"#;
        let (_, req) = synthesize(&Json::parse(line).unwrap(), &m, &g, &mut b).unwrap();
        let h = crate::prefix::vision_affinity_hash(&req).unwrap();
        let ring = HashRing::new(2, DEFAULT_VNODES);
        let primary = ring.primary(h).unwrap() as usize;
        let second = ring.second(h).unwrap() as usize;
        r.links[primary].health.publish(0, 0, 95, 100, 1.0); // 95% occupancy
        for _ in 0..3 {
            r.ask(line);
        }
        wait_until_drained(&r);
        let (counters, seen) = r.shutdown();
        assert_eq!(counters.spill_total, 3);
        assert_eq!(seen[second].len(), 3, "spilled to the ring's second choice");
        assert!(seen[primary].is_empty());
    }

    #[test]
    fn cold_pool_never_spills() {
        let cfg = RouterConfig { spill_occupancy: Some(0.9), ..Default::default() };
        let r = rig(2, cfg, CANNED);
        r.ask(r#"{"id":1,"kind":"qa","image_seed":7}"#);
        let (counters, _) = r.shutdown();
        assert_eq!(counters.spill_total, 0, "unpublished health must read as cold");
    }

    #[test]
    fn merged_stats_sums_replica_counters() {
        let r = rig(2, RouterConfig::default(), CANNED);
        let j = Json::parse(&r.ask(r#"{"kind":"stats"}"#)).unwrap();
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("stats"));
        assert_eq!(j.get("replicas").and_then(|v| v.as_usize()), Some(2));
        // each canned replica reports submitted=3 → aggregate 6
        assert_eq!(j.get("submitted").and_then(|v| v.as_usize()), Some(6));
        assert_eq!(j.get("live_pages").and_then(|v| v.as_usize()), Some(10));
        assert_eq!(j.get("refcount_errors").and_then(|v| v.as_usize()), Some(0));
        // rate recomputed from summed counts: (2+2)/(2+2+4)
        let rate = j.get("prefix_hit_rate").and_then(|v| v.as_f64()).unwrap();
        assert!((rate - 0.5).abs() < 1e-9, "{}", rate);
        let per = j.get("per_replica").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].get("submitted").and_then(|v| v.as_usize()), Some(3));
        assert!(j.path(&["router", "shed_total"]).is_some());
        r.shutdown();
    }

    #[test]
    fn merged_prometheus_has_each_series_once() {
        let r = rig(2, RouterConfig::default(), CANNED);
        r.ask(r#"{"id":1,"kind":"qa","image_seed":7}"#);
        wait_until_drained(&r);
        let j = Json::parse(&r.ask(r#"{"kind":"stats","format":"prometheus"}"#)).unwrap();
        let body = j.get("body").and_then(|v| v.as_str()).unwrap();
        assert!(parses_as_exposition(body), "{}", body);
        assert!(body.contains("hae_router_shed_total 0"));
        assert!(body.contains("hae_router_routed_affinity_total 1"));
        assert!(body.contains("hae_requests_submitted_total 6"));
        assert!(body.contains("hae_router_replica_queue_depth{replica=\"1\"}"));
        // series (name + labels) must be unique — a scraper seeing the
        // same sample twice rejects the whole scrape
        let mut ids: Vec<&str> = body
            .lines()
            .filter(|l| !l.starts_with('#') && !l.trim().is_empty())
            .map(|l| l.rsplit_once(' ').map(|(id, _)| id).unwrap_or(l))
            .collect();
        let total = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), total, "duplicate series in merged exposition");
        r.shutdown();
    }

    #[test]
    fn single_replica_prometheus_appends_router_series() {
        let r = rig(1, RouterConfig::default(), CANNED);
        let j = Json::parse(&r.ask(r#"{"kind":"stats","format":"prometheus"}"#)).unwrap();
        let body = j.get("body").and_then(|v| v.as_str()).unwrap();
        assert!(body.contains("hae_fake 1"), "replica body preserved: {}", body);
        assert!(body.contains("hae_router_replicas 1"));
        assert!(parses_as_exposition(body), "{}", body);
        r.shutdown();
    }

    #[test]
    fn single_replica_stats_pass_through_untouched() {
        let r = rig(1, RouterConfig::default(), CANNED);
        // byte-identical passthrough: the replica's reply IS the reply
        assert_eq!(r.ask(r#"{"kind":"stats"}"#), CANNED);
        r.shutdown();
    }

    #[test]
    fn merged_trace_concatenates_and_sorts_events() {
        let a = r#"{"kind":"trace","count":1,"dropped":0,"events":[{"id":1,"at_us":50,"event":"enqueued"}]}"#;
        let b = r#"{"kind":"trace","count":1,"dropped":2,"events":[{"id":2,"at_us":10,"event":"enqueued"}]}"#;
        let merged = merged_trace(vec![
            Some(Json::parse(a).unwrap()),
            Some(Json::parse(b).unwrap()),
            None,
        ]);
        assert_eq!(merged.get("count").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(merged.get("dropped").and_then(|v| v.as_usize()), Some(2));
        let ev = merged.get("events").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0].get("id").and_then(|v| v.as_i64()), Some(2), "sorted by at_us");
    }

    #[test]
    fn router_policy_parses() {
        assert_eq!(RouterPolicy::parse("affinity"), Some(RouterPolicy::Affinity));
        assert_eq!(RouterPolicy::parse("round_robin"), Some(RouterPolicy::RoundRobin));
        assert_eq!(RouterPolicy::parse("rr"), Some(RouterPolicy::RoundRobin));
        assert_eq!(RouterPolicy::parse("nope"), None);
        assert!(RouterPolicy::accepted().contains("affinity"));
    }

    #[test]
    fn health_saturates_and_scores() {
        let h = ReplicaHealth::new();
        h.dequeue(); // must not wrap
        assert_eq!(h.admission_depth(), 0);
        h.enqueue();
        h.enqueue();
        h.publish(3, 2, 8, 10, 0.5);
        assert_eq!(h.admission_depth(), 5);
        assert_eq!(h.load_score(), 7);
        assert!((h.pool_occupancy() - 0.8).abs() < 1e-9);
        assert!((h.slo_attainment() - 0.5).abs() < 1e-9);
    }
}
