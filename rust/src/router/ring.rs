//! Consistent hash ring with virtual nodes.
//!
//! The routing tier's placement function: a request's vision-segment
//! content hash (`prefix::vision_affinity_hash`, the same 64-bit FNV the
//! prefix cache keys on) is looked up on the ring, and the owning
//! replica is the one whose prefix cache already holds that image. The
//! consistent-hashing property is what makes replica membership changes
//! cheap: adding or removing one replica remaps only ~K/N of K keys (the
//! keys the ring assigned to the changed replica), so the other
//! replicas' warm prefix caches stay warm.
//!
//! Virtual nodes (default [`DEFAULT_VNODES`] points per replica) smooth
//! the ownership split: with a single point per replica the arc lengths
//! — and therefore the load split — would be wildly uneven.

/// Points each replica contributes to the ring. 64 keeps the max/min
/// ownership ratio near 1 for small N while the ring stays a few KiB.
pub const DEFAULT_VNODES: usize = 64;

/// splitmix64 — the point hash. Deterministic in (replica, vnode), so
/// two rings built from the same membership are identical, which is what
/// makes "add the replica back" restore the original placement exactly.
#[inline]
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// Hash ring: sorted (point, replica) pairs; a key is owned by the first
/// point clockwise from its hash.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// sorted by point; ties broken by replica id (points are 64-bit
    /// splitmix outputs, so ties are astronomically unlikely, but the
    /// order must still be deterministic)
    points: Vec<(u64, u32)>,
    vnodes: usize,
}

impl HashRing {
    /// Ring over replicas `0..n` with `vnodes` points each.
    pub fn new(n: u32, vnodes: usize) -> HashRing {
        let mut ring = HashRing { points: Vec::new(), vnodes: vnodes.max(1) };
        for r in 0..n {
            ring.add(r);
        }
        ring
    }

    /// Add one replica's points (no-op if already present).
    pub fn add(&mut self, replica: u32) {
        if self.points.iter().any(|&(_, r)| r == replica) {
            return;
        }
        for v in 0..self.vnodes {
            let p = splitmix64(((replica as u64) << 32) ^ v as u64);
            self.points.push((p, replica));
        }
        self.points.sort_unstable();
    }

    /// Remove one replica's points (no-op if absent).
    pub fn remove(&mut self, replica: u32) {
        self.points.retain(|&(_, r)| r != replica);
    }

    /// Live replica count (not point count).
    pub fn replicas(&self) -> usize {
        let mut seen: Vec<u32> = self.points.iter().map(|&(_, r)| r).collect();
        seen.sort_unstable();
        seen.dedup();
        seen.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Index of the first point clockwise from `key` (wrapping).
    fn first_at_or_after(&self, key: u64) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        let i = self.points.partition_point(|&(p, _)| p < key);
        Some(if i == self.points.len() { 0 } else { i })
    }

    /// Owning replica of `key`: first point clockwise from its position.
    pub fn primary(&self, key: u64) -> Option<u32> {
        self.first_at_or_after(key).map(|i| self.points[i].1)
    }

    /// Second choice: the first point clockwise owned by a *different*
    /// replica than the primary — the spill target when the primary's
    /// pool is hot. None when the ring has fewer than two replicas.
    pub fn second(&self, key: u64) -> Option<u32> {
        let start = self.first_at_or_after(key)?;
        let primary = self.points[start].1;
        for off in 1..self.points.len() {
            let (_, r) = self.points[(start + off) % self.points.len()];
            if r != primary {
                return Some(r);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(n: usize) -> Vec<u64> {
        // deterministic key stream, disjoint from the point-hash inputs
        (0..n).map(|i| splitmix64(0xFEED_0000 ^ ((i as u64) << 7))).collect()
    }

    #[test]
    fn same_key_same_replica_deterministically() {
        let a = HashRing::new(4, DEFAULT_VNODES);
        let b = HashRing::new(4, DEFAULT_VNODES);
        for k in keys(1000) {
            assert_eq!(a.primary(k), b.primary(k));
            assert_eq!(a.primary(k), a.primary(k));
        }
    }

    #[test]
    fn ownership_is_roughly_balanced() {
        let ring = HashRing::new(4, DEFAULT_VNODES);
        let mut owned = [0usize; 4];
        let ks = keys(10_000);
        for &k in &ks {
            owned[ring.primary(k).unwrap() as usize] += 1;
        }
        // loose bound: every replica owns a real share (perfect = 2500)
        for (r, &n) in owned.iter().enumerate() {
            assert!(n > 1000, "replica {} owns only {} of 10k keys", r, n);
        }
    }

    #[test]
    fn removing_a_replica_remaps_only_its_own_keys() {
        let full = HashRing::new(4, DEFAULT_VNODES);
        let mut less = full.clone();
        less.remove(2);
        let ks = keys(10_000);
        let mut moved = 0usize;
        for &k in &ks {
            let before = full.primary(k).unwrap();
            let after = less.primary(k).unwrap();
            assert_ne!(after, 2, "removed replica still owns a key");
            if before != after {
                // the consistent-hashing property: only keys the removed
                // replica owned may move
                assert_eq!(before, 2, "key moved off a surviving replica");
                moved += 1;
            }
        }
        // ~K/N keys move (the removed replica's share); 2x slack for
        // vnode arc-length variance
        assert!(moved > 0, "removal remapped nothing");
        assert!(
            moved < ks.len() / 4 * 2,
            "removal remapped {} of {} keys (> 2x K/N)",
            moved,
            ks.len()
        );
    }

    #[test]
    fn adding_a_replica_back_restores_placement() {
        let full = HashRing::new(4, DEFAULT_VNODES);
        let mut churn = full.clone();
        churn.remove(2);
        churn.add(2);
        for k in keys(2000) {
            assert_eq!(full.primary(k), churn.primary(k));
        }
        assert_eq!(churn.replicas(), 4);
    }

    #[test]
    fn adding_a_replica_remaps_at_most_its_share() {
        let small = HashRing::new(3, DEFAULT_VNODES);
        let mut grown = small.clone();
        grown.add(3);
        let ks = keys(10_000);
        let mut moved = 0usize;
        for &k in &ks {
            let before = small.primary(k).unwrap();
            let after = grown.primary(k).unwrap();
            if before != after {
                // a key only moves by landing on the new replica
                assert_eq!(after, 3, "growth moved a key between old replicas");
                moved += 1;
            }
        }
        assert!(moved > 0);
        assert!(moved < ks.len() / 4 * 2, "growth remapped {} keys", moved);
    }

    #[test]
    fn second_choice_differs_from_primary() {
        let ring = HashRing::new(2, DEFAULT_VNODES);
        for k in keys(1000) {
            let p = ring.primary(k).unwrap();
            let s = ring.second(k).unwrap();
            assert_ne!(p, s);
        }
        // deterministic as well — the spill target is stable per image
        let again = HashRing::new(2, DEFAULT_VNODES);
        for k in keys(200) {
            assert_eq!(ring.second(k), again.second(k));
        }
    }

    #[test]
    fn degenerate_rings() {
        let empty = HashRing::new(0, DEFAULT_VNODES);
        assert!(empty.is_empty());
        assert_eq!(empty.primary(123), None);
        assert_eq!(empty.second(123), None);
        let one = HashRing::new(1, DEFAULT_VNODES);
        assert_eq!(one.primary(123), Some(0));
        assert_eq!(one.second(123), None, "no distinct second on a 1-ring");
        assert_eq!(one.replicas(), 1);
    }
}
