//! Token-id layout of the TinyMM synthetic vocabulary.
//!
//! MUST stay in sync with python/compile/data.py — the model was trained on
//! this layout, and the rust workload generators emit it at serving time.

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const IMG: i32 = 3;

pub const Q_COLOR: i32 = 8;
pub const Q_SHAPE: i32 = 9;
pub const ANS_MARK: i32 = 10;
pub const STORY_MARK: i32 = 11;

pub const COLOR_BASE: i32 = 16;
pub const SHAPE_BASE: i32 = 24;
pub const STORY_BASE: i32 = 64;

pub const N_COLORS: usize = 8;
pub const N_SHAPES: usize = 8;
pub const N_STORY_WORDS: usize = 160;

pub fn color_token(color: usize) -> i32 {
    debug_assert!(color < N_COLORS);
    COLOR_BASE + color as i32
}

pub fn shape_token(shape: usize) -> i32 {
    debug_assert!(shape < N_SHAPES);
    SHAPE_BASE + shape as i32
}

pub fn story_token(word: usize) -> i32 {
    debug_assert!(word < N_STORY_WORDS);
    STORY_BASE + word as i32
}

pub fn is_color_token(t: i32) -> bool {
    (COLOR_BASE..COLOR_BASE + N_COLORS as i32).contains(&t)
}

pub fn is_shape_token(t: i32) -> bool {
    (SHAPE_BASE..SHAPE_BASE + N_SHAPES as i32).contains(&t)
}

pub fn is_story_token(t: i32) -> bool {
    (STORY_BASE..STORY_BASE + N_STORY_WORDS as i32).contains(&t)
}

/// Human-readable rendering for logs/examples.
pub fn describe(t: i32) -> String {
    const COLORS: [&str; 8] =
        ["red", "blue", "green", "yellow", "purple", "orange", "black", "white"];
    const SHAPES: [&str; 8] =
        ["circle", "square", "triangle", "star", "hex", "ring", "cross", "wave"];
    match t {
        PAD => "<pad>".into(),
        BOS => "<bos>".into(),
        EOS => "<eos>".into(),
        IMG => "<img>".into(),
        Q_COLOR => "Q:color".into(),
        Q_SHAPE => "Q:shape".into(),
        ANS_MARK => "A:".into(),
        STORY_MARK => "<story>".into(),
        t if is_color_token(t) => COLORS[(t - COLOR_BASE) as usize].into(),
        t if is_shape_token(t) => SHAPES[(t - SHAPE_BASE) as usize].into(),
        t if is_story_token(t) => format!("w{}", t - STORY_BASE),
        t => format!("tok{}", t),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_disjoint() {
        for c in 0..N_COLORS {
            assert!(is_color_token(color_token(c)));
            assert!(!is_shape_token(color_token(c)));
            assert!(!is_story_token(color_token(c)));
        }
        for s in 0..N_SHAPES {
            assert!(is_shape_token(shape_token(s)));
        }
        for w in [0, 1, N_STORY_WORDS - 1] {
            assert!(is_story_token(story_token(w)));
        }
    }

    #[test]
    fn describe_total() {
        for t in 0..512 {
            assert!(!describe(t).is_empty());
        }
    }
}
