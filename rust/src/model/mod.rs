//! Model metadata and vocabulary (the rust mirror of the python compile
//! path's contracts).

pub mod meta;
pub mod vocab;

pub use meta::{ArtifactShapes, Manifest, ModelMeta, WeightEntry};
