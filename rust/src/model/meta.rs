//! Model/artifact metadata parsed from artifacts/manifest.json.
//!
//! The manifest is the contract between the python compile path and the
//! rust runtime: model dimensions, the weight table (name/shape/offset into
//! weights.bin) and the artifact table (which HLO files exist at which
//! static shapes). `Manifest::load` validates internal consistency so shape
//! mismatches fail loudly at startup instead of inside PJRT.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_mlp: usize,
    pub patch_dim: usize,
    pub n_patches: usize,
    pub max_pos: usize,
    /// layer whose attention feeds the DAP statistics (manifest "dap_layer")
    pub dap_layer: usize,
}

impl ModelMeta {
    pub fn d_attn(&self) -> usize {
        self.n_heads * self.d_head
    }

    /// Bytes of one KV entry (K+V for one token across all layers), f32.
    pub fn kv_bytes_per_token(&self) -> usize {
        2 * self.n_layers * self.n_heads * self.d_head * 4
    }
}

#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub numel: usize,
}

#[derive(Debug, Clone)]
pub struct ArtifactShapes {
    pub prefill_buckets: Vec<usize>,
    pub decode_batches: Vec<usize>,
    pub decode_capacities: Vec<usize>,
    pub analysis_buckets: Vec<usize>,
    pub cache_capacity: usize,
    /// chunked-extend executables (`extend_b{B}_s{S}_c{C}`): batch sizes
    /// and chunk (S) buckets; capacities reuse `decode_capacities`.
    /// Empty on pre-extend artifact sets — the engine then recomputes
    /// partial warm-start suffixes through the decode loop as before.
    pub extend_batches: Vec<usize>,
    pub extend_chunks: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelMeta,
    pub shapes: ArtifactShapes,
    pub weights: Vec<WeightEntry>,
    pub seed: u64,
    pub train_steps: usize,
}

fn usize_field(j: &Json, path: &[&str]) -> Result<usize> {
    j.path(path)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| anyhow!("manifest missing field {:?}", path))
}

fn usize_list(j: &Json, path: &[&str]) -> Result<Vec<usize>> {
    let arr = j
        .path(path)
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("manifest missing list {:?}", path))?;
    arr.iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("non-integer in {:?}", path)))
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let model = ModelMeta {
            vocab: usize_field(&j, &["model", "vocab"])?,
            d_model: usize_field(&j, &["model", "d_model"])?,
            n_layers: usize_field(&j, &["model", "n_layers"])?,
            n_heads: usize_field(&j, &["model", "n_heads"])?,
            d_head: usize_field(&j, &["model", "d_head"])?,
            d_mlp: usize_field(&j, &["model", "d_mlp"])?,
            patch_dim: usize_field(&j, &["model", "patch_dim"])?,
            n_patches: usize_field(&j, &["model", "n_patches"])?,
            max_pos: usize_field(&j, &["model", "max_pos"])?,
            dap_layer: j.path(&["model", "dap_layer"]).and_then(|v| v.as_usize()).unwrap_or(0),
        };

        let shapes = ArtifactShapes {
            prefill_buckets: usize_list(&j, &["artifacts", "prefill_buckets"])?,
            decode_batches: usize_list(&j, &["artifacts", "decode_batches"])?,
            decode_capacities: usize_list(&j, &["artifacts", "decode_capacities"])?,
            analysis_buckets: usize_list(&j, &["artifacts", "analysis_buckets"])?,
            cache_capacity: usize_field(&j, &["artifacts", "cache_capacity"])?,
            // absent on pre-extend manifests: default to no extend
            // executables rather than refusing the whole artifact set
            extend_batches: usize_list(&j, &["artifacts", "extend_batches"])
                .unwrap_or_default(),
            extend_chunks: usize_list(&j, &["artifacts", "extend_chunks"])
                .unwrap_or_default(),
        };

        let weights_json = j
            .get("weights")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest missing weights table"))?;
        let mut weights = Vec::with_capacity(weights_json.len());
        for w in weights_json {
            let name = w
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("weight entry missing name"))?
                .to_string();
            let shape = w
                .get("shape")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("weight {} missing shape", name))?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect::<Vec<_>>();
            let offset = usize_field(w, &["offset"])?;
            let numel = usize_field(w, &["numel"])?;
            if shape.iter().product::<usize>() != numel {
                bail!("weight {}: shape {:?} != numel {}", name, shape, numel);
            }
            weights.push(WeightEntry { name, shape, offset, numel });
        }

        // offsets must be contiguous and ascending
        let mut expected = 0usize;
        for w in &weights {
            if w.offset != expected {
                bail!("weight {} at offset {} (expected {})", w.name, w.offset, expected);
            }
            expected += w.numel * 4;
        }

        let seed = j.get("seed").and_then(|v| v.as_i64()).unwrap_or(0) as u64;
        let train_steps =
            j.get("train_steps").and_then(|v| v.as_usize()).unwrap_or(0);

        let m = Manifest {
            dir: dir.to_path_buf(),
            model,
            shapes,
            weights,
            seed,
            train_steps,
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        if self.shapes.decode_capacities.is_empty() {
            bail!("no decode capacities in manifest");
        }
        let mut caps = self.shapes.decode_capacities.clone();
        caps.sort_unstable();
        if caps != self.shapes.decode_capacities {
            bail!("decode capacities must be sorted ascending");
        }
        if *caps.last().unwrap() != self.shapes.cache_capacity {
            bail!("largest decode capacity must equal cache_capacity");
        }
        if self.model.max_pos < self.shapes.cache_capacity {
            bail!("positional table smaller than cache capacity");
        }
        let mut chunks = self.shapes.extend_chunks.clone();
        chunks.sort_unstable();
        if chunks != self.shapes.extend_chunks {
            bail!("extend chunks must be sorted ascending");
        }
        if self.shapes.extend_chunks.contains(&0) {
            bail!("extend chunk of 0 tokens is meaningless");
        }
        let total: usize = self.weights.iter().map(|w| w.numel).sum();
        let bin = self.dir.join("weights.bin");
        if let Ok(md) = std::fs::metadata(&bin) {
            if md.len() as usize != total * 4 {
                bail!(
                    "weights.bin size {} != manifest total {} bytes",
                    md.len(),
                    total * 4
                );
            }
        }
        Ok(())
    }

    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{}.hlo.txt", name))
    }

    /// Smallest prefill bucket that fits `n` tokens.
    pub fn prefill_bucket(&self, n: usize) -> Option<usize> {
        self.shapes.prefill_buckets.iter().copied().find(|&b| b >= n)
    }

    /// Smallest decode capacity bucket that fits `len` live slots
    /// (strictly greater, because the new token needs a free slot).
    pub fn capacity_bucket(&self, len: usize) -> Option<usize> {
        self.shapes.decode_capacities.iter().copied().find(|&c| c > len)
    }

    /// Smallest compiled extend chunk (S) bucket that fits `step` new
    /// rows; shorter chunks run padded with `n_new` masking the rest.
    /// None when no bucket fits (or no extend executables exist).
    pub fn extend_bucket(&self, step: usize) -> Option<usize> {
        self.shapes.extend_chunks.iter().copied().find(|&s| s >= step)
    }

    /// Largest compiled extend chunk for `batch` lanes — the ceiling on
    /// `--extend-chunk` (0 when no extend executables exist at that
    /// batch, in which case the suffix recompute falls back to the
    /// one-token decode loop).
    pub fn max_extend_chunk(&self, batch: usize) -> usize {
        if !self.shapes.extend_batches.contains(&batch) {
            return 0;
        }
        self.shapes.extend_chunks.iter().copied().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok()
    }

    #[test]
    fn loads_real_manifest() {
        let m = match repo_artifacts() {
            Some(m) => m,
            None => return, // artifacts not built in this environment
        };
        assert!(m.model.vocab >= 256);
        assert_eq!(m.model.d_attn(), m.model.n_heads * m.model.d_head);
        assert!(!m.weights.is_empty());
        assert_eq!(m.weights[0].offset, 0);
    }

    #[test]
    fn bucket_selection() {
        let m = match repo_artifacts() {
            Some(m) => m,
            None => return,
        };
        let smallest = m.shapes.prefill_buckets[0];
        assert_eq!(m.prefill_bucket(1), Some(smallest));
        assert_eq!(m.prefill_bucket(smallest), Some(smallest));
        assert!(m.prefill_bucket(100_000).is_none());
        // capacity bucket must strictly exceed live length
        let c0 = m.shapes.decode_capacities[0];
        assert_eq!(m.capacity_bucket(c0 - 1), Some(c0));
        assert!(m.capacity_bucket(c0).unwrap() > c0);
    }

    #[test]
    fn extend_bucket_selection() {
        let meta = ModelMeta {
            vocab: 512,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_head: 32,
            d_mlp: 256,
            patch_dim: 32,
            n_patches: 16,
            max_pos: 640,
            dap_layer: 1,
        };
        let mut m = Manifest {
            dir: PathBuf::from("."),
            model: meta,
            shapes: ArtifactShapes {
                prefill_buckets: vec![64, 256],
                decode_batches: vec![1, 4],
                decode_capacities: vec![128, 512],
                analysis_buckets: vec![128],
                cache_capacity: 512,
                extend_batches: vec![1],
                extend_chunks: vec![8, 32],
            },
            weights: Vec::new(),
            seed: 0,
            train_steps: 0,
        };
        assert_eq!(m.extend_bucket(1), Some(8), "short chunks run padded");
        assert_eq!(m.extend_bucket(8), Some(8));
        assert_eq!(m.extend_bucket(9), Some(32));
        assert_eq!(m.extend_bucket(32), Some(32));
        assert_eq!(m.extend_bucket(33), None, "no bucket fits");
        assert_eq!(m.max_extend_chunk(1), 32);
        assert_eq!(m.max_extend_chunk(4), 0, "batch 4 not compiled");
        // pre-extend manifests: everything degrades to the decode loop
        m.shapes.extend_batches.clear();
        m.shapes.extend_chunks.clear();
        assert_eq!(m.extend_bucket(2), None);
        assert_eq!(m.max_extend_chunk(1), 0);
        assert!(m.validate().is_ok(), "empty extend lists are valid");
    }

    #[test]
    fn kv_accounting() {
        let meta = ModelMeta {
            vocab: 512,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_head: 32,
            d_mlp: 256,
            patch_dim: 32,
            n_patches: 16,
            max_pos: 640,
            dap_layer: 1,
        };
        // 2 (K+V) * 4 layers * 4 heads * 32 dh * 4 bytes
        assert_eq!(meta.kv_bytes_per_token(), 4096);
    }
}
