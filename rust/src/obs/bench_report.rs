//! Machine-readable bench output: `BENCH_<name>.json`.
//!
//! Every `benches/perf_*.rs` target emits one report so perf trends can be
//! compared across PRs instead of resetting with every table printed to a
//! scrolled-away CI log. The schema is deliberately small and stable —
//! `make bench-verify` (rust/src/bin/bench_verify.rs) checks it and CI
//! archives the files as artifacts.
//!
//! ```json
//! {
//!   "bench": "page_pool",
//!   "rev": "392c282",
//!   "timestamp": 1754550000,
//!   "engine_threads": 1,
//!   "config": {"iters": "4000"},
//!   "metrics": {"alloc_free_mops": {"value": 12.3, "unit": "Mops/s"}}
//! }
//! ```
//!
//! `timestamp` (unix seconds at serialisation) and `engine_threads` (the
//! scheduler-overlap setting the run used; 1 when irrelevant) let
//! `bin/bench_trend` place each report on its trend axis without parsing
//! git history.

use std::collections::BTreeMap;
use std::io;
use std::path::PathBuf;
use std::process::Command;
use std::sync::OnceLock;

use crate::util::json::{num, obj, s, Json};

/// Directory reports are written into; overridable for tests and CI via
/// `HAE_BENCH_DIR` (default: current working directory, i.e. the repo root
/// under `cargo bench`).
pub fn bench_dir() -> PathBuf {
    PathBuf::from(std::env::var("HAE_BENCH_DIR").unwrap_or_else(|_| ".".into()))
}

/// Best-effort short git revision; "unknown" when git is unavailable
/// (bench output must never fail because the tree is not a checkout).
/// Cached for the process lifetime — a bench binary writing several
/// reports shells out to git once, not per report.
pub fn git_rev() -> String {
    static REV: OnceLock<String> = OnceLock::new();
    REV.get_or_init(|| {
        Command::new("git")
            .args(["rev-parse", "--short", "HEAD"])
            .output()
            .ok()
            .filter(|o| o.status.success())
            .and_then(|o| String::from_utf8(o.stdout).ok())
            .map(|v| v.trim().to_string())
            .filter(|v| !v.is_empty())
            .unwrap_or_else(|| "unknown".to_string())
    })
    .clone()
}

/// Unix seconds now; 0 if the clock is before the epoch (never panics —
/// report writing must not fail on a broken clock).
fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Accumulates config and metrics for one bench run, then serialises to
/// `BENCH_<name>.json`.
pub struct BenchReport {
    name: String,
    config: BTreeMap<String, String>,
    metrics: BTreeMap<String, (f64, String)>,
    /// scheduler-overlap setting the run used (1 = sequential rounds,
    /// also the value for benches where the engine never runs)
    engine_threads: usize,
}

impl BenchReport {
    pub fn new(name: &str) -> Self {
        BenchReport {
            name: name.to_string(),
            config: BTreeMap::new(),
            metrics: BTreeMap::new(),
            engine_threads: 1,
        }
    }

    pub fn config(&mut self, key: &str, value: impl ToString) -> &mut Self {
        self.config.insert(key.to_string(), value.to_string());
        self
    }

    pub fn engine_threads(&mut self, n: usize) -> &mut Self {
        self.engine_threads = n;
        self
    }

    pub fn metric(&mut self, key: &str, value: f64, unit: &str) -> &mut Self {
        self.metrics.insert(key.to_string(), (value, unit.to_string()));
        self
    }

    pub fn metric_count(&self) -> usize {
        self.metrics.len()
    }

    pub fn to_json(&self) -> Json {
        let config = Json::Obj(
            self.config.iter().map(|(k, v)| (k.clone(), s(v))).collect(),
        );
        let metrics = Json::Obj(
            self.metrics
                .iter()
                .map(|(k, (v, u))| {
                    (k.clone(), obj(vec![("value", num(*v)), ("unit", s(u))]))
                })
                .collect(),
        );
        obj(vec![
            ("bench", s(&self.name)),
            ("rev", s(&git_rev())),
            ("timestamp", num(unix_now() as f64)),
            ("engine_threads", num(self.engine_threads as f64)),
            ("config", config),
            ("metrics", metrics),
        ])
    }

    /// Write `BENCH_<name>.json` into [`bench_dir`], returning the path.
    pub fn write(&self) -> io::Result<PathBuf> {
        let path = bench_dir().join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json().to_string_compact() + "\n")?;
        Ok(path)
    }
}

/// Schema check shared by `bench_verify` and tests: returns a list of
/// human-readable problems (empty = valid).
pub fn schema_problems(j: &Json) -> Vec<String> {
    let mut out = Vec::new();
    match j.get("bench").and_then(|v| v.as_str()) {
        Some(b) if !b.is_empty() => {}
        _ => out.push("missing or empty 'bench'".into()),
    }
    if j.get("rev").and_then(|v| v.as_str()).is_none() {
        out.push("missing 'rev'".into());
    }
    if j.get("timestamp").and_then(|v| v.as_f64()).is_none() {
        out.push("missing numeric 'timestamp'".into());
    }
    if j.get("engine_threads").and_then(|v| v.as_f64()).is_none() {
        out.push("missing numeric 'engine_threads'".into());
    }
    if j.get("config").and_then(|v| v.as_obj()).is_none() {
        out.push("missing 'config' object".into());
    }
    match j.get("metrics").and_then(|v| v.as_obj()) {
        None => out.push("missing 'metrics' object".into()),
        Some(m) if m.is_empty() => out.push("'metrics' is empty".into()),
        Some(m) => {
            for (k, v) in m {
                if v.get("value").and_then(|x| x.as_f64()).is_none() {
                    out.push(format!("metric '{}' missing numeric 'value'", k));
                }
                if v.get("unit").and_then(|x| x.as_str()).is_none() {
                    out.push(format!("metric '{}' missing 'unit'", k));
                }
            }
            // serve_batch runs with artifacts set config.engine_sections
            // and must then carry the pipeline-comparison keys — a report
            // that silently dropped them would hide a lost measurement
            let engine_sections = j
                .path(&["config", "engine_sections"])
                .and_then(|v| v.as_str())
                == Some("true");
            if j.get("bench").and_then(|v| v.as_str()) == Some("serve_batch")
                && engine_sections
            {
                for key in [
                    "decode_tok_s_single_thread",
                    "decode_tok_s_pipelined",
                    "ttft_p50_ms_single_thread",
                    "ttft_p50_ms_pipelined",
                    "host_device_overlap_frac",
                ] {
                    if !m.contains_key(key) {
                        out.push(format!(
                            "serve_batch with engine_sections misses metric '{}'",
                            key
                        ));
                    }
                }
            }
            // same contract for the routing bench: once the Zipfian
            // comparison ran (config.routing_sections), its headline
            // keys must all be present
            let routing_sections = j
                .path(&["config", "routing_sections"])
                .and_then(|v| v.as_str())
                == Some("true");
            if j.get("bench").and_then(|v| v.as_str()) == Some("perf_router")
                && routing_sections
            {
                for key in [
                    "prefix_hit_rate_affinity",
                    "prefix_hit_rate_round_robin",
                    "prefix_hit_rate_single",
                    "shed_total",
                ] {
                    if !m.contains_key(key) {
                        out.push(format!(
                            "perf_router with routing_sections misses metric '{}'",
                            key
                        ));
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_serialises_to_valid_schema() {
        let mut r = BenchReport::new("unit_test");
        r.config("iters", 100).metric("throughput", 12.5, "Mops/s");
        let j = r.to_json();
        assert!(schema_problems(&j).is_empty(), "{:?}", schema_problems(&j));
        assert_eq!(j.get("bench").and_then(|v| v.as_str()), Some("unit_test"));
        assert_eq!(
            j.path(&["metrics", "throughput", "value"]).and_then(|v| v.as_f64()),
            Some(12.5)
        );
        assert_eq!(
            j.path(&["config", "iters"]).and_then(|v| v.as_str()),
            Some("100")
        );
        assert!(j.get("timestamp").and_then(|v| v.as_f64()).unwrap_or(-1.0) >= 0.0);
        assert_eq!(j.get("engine_threads").and_then(|v| v.as_usize()), Some(1));
        let mut r2 = BenchReport::new("unit_test");
        r2.engine_threads(2).metric("x", 1.0, "n");
        assert_eq!(
            r2.to_json().get("engine_threads").and_then(|v| v.as_usize()),
            Some(2)
        );
    }

    #[test]
    fn serve_batch_engine_sections_requires_pipeline_keys() {
        let mut r = BenchReport::new("serve_batch");
        r.config("engine_sections", "true");
        r.metric("req_s_hae_b4_c8", 1.0, "req/s");
        let probs = schema_problems(&r.to_json());
        assert_eq!(probs.len(), 5, "one problem per missing key: {:?}", probs);
        r.metric("decode_tok_s_single_thread", 10.0, "tok/s")
            .metric("decode_tok_s_pipelined", 11.0, "tok/s")
            .metric("ttft_p50_ms_single_thread", 5.0, "ms")
            .metric("ttft_p50_ms_pipelined", 4.0, "ms")
            .metric("host_device_overlap_frac", 0.4, "frac");
        assert!(schema_problems(&r.to_json()).is_empty());
        // without the flag (artifacts absent) the keys are optional
        let mut bare = BenchReport::new("serve_batch");
        bare.metric("lane_sync_full_us_per_step", 1.0, "us");
        assert!(schema_problems(&bare.to_json()).is_empty());
    }

    #[test]
    fn perf_router_routing_sections_requires_headline_keys() {
        let mut r = BenchReport::new("perf_router");
        r.config("routing_sections", "true");
        r.metric("ring_lookup_mops", 5.0, "Mops/s");
        let probs = schema_problems(&r.to_json());
        assert_eq!(probs.len(), 4, "one problem per missing key: {:?}", probs);
        r.metric("prefix_hit_rate_affinity", 0.8, "frac")
            .metric("prefix_hit_rate_round_robin", 0.5, "frac")
            .metric("prefix_hit_rate_single", 0.85, "frac")
            .metric("shed_total", 0.0, "count");
        assert!(schema_problems(&r.to_json()).is_empty());
        // without the flag (artifacts absent, ring section only) the
        // routing keys are optional
        let mut bare = BenchReport::new("perf_router");
        bare.metric("ring_lookup_mops", 5.0, "Mops/s");
        assert!(schema_problems(&bare.to_json()).is_empty());
    }

    #[test]
    fn schema_check_flags_missing_keys() {
        let bad = Json::parse(r#"{"bench":"x","metrics":{"m":{"value":"nope"}}}"#).unwrap();
        let probs = schema_problems(&bad);
        assert!(probs.iter().any(|p| p.contains("rev")));
        assert!(probs.iter().any(|p| p.contains("timestamp")));
        assert!(probs.iter().any(|p| p.contains("engine_threads")));
        assert!(probs.iter().any(|p| p.contains("config")));
        assert!(probs.iter().any(|p| p.contains("numeric 'value'")));
        assert!(probs.iter().any(|p| p.contains("unit")));
        let empty = Json::parse(r#"{"bench":"x","rev":"r","config":{},"metrics":{}}"#).unwrap();
        assert!(schema_problems(&empty).iter().any(|p| p.contains("empty")));
    }

    #[test]
    fn write_roundtrip_in_temp_dir() {
        let dir = std::env::temp_dir().join(format!("hae_bench_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::env::set_var("HAE_BENCH_DIR", &dir);
        let mut r = BenchReport::new("roundtrip");
        r.metric("x", 1.0, "count");
        let path = r.write().unwrap();
        std::env::remove_var("HAE_BENCH_DIR");
        let body = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(body.trim()).unwrap();
        assert!(schema_problems(&j).is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
