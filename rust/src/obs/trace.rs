//! Request-lifecycle trace journal.
//!
//! A fixed-capacity ring of typed, `Copy` events with request id and
//! monotonic microsecond timestamps. Recording on the hot path is alloc-free:
//! the backing vector is reserved up front, events carry no heap data, and a
//! full ring overwrites the oldest record in place. Queries (`for_request`,
//! `last`) allocate — they run on the stats path, not the decode loop.

use std::time::Instant;

use crate::util::json::{num, obj, s, Json};

/// Which mechanism evicted KV slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictKind {
    /// The eviction policy's own decision (HAE scoring).
    Policy,
    /// Capacity-wall fallback eviction when a lane hits its slab ceiling.
    Capacity,
    /// Emergency aligned tail drop after every gentler option failed.
    Emergency,
}

impl EvictKind {
    pub fn as_str(self) -> &'static str {
        match self {
            EvictKind::Policy => "policy",
            EvictKind::Capacity => "capacity",
            EvictKind::Emergency => "emergency",
        }
    }
}

/// Why a request left the scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetireReason {
    Completed,
    Failed,
    Rejected,
}

impl RetireReason {
    pub fn as_str(self) -> &'static str {
        match self {
            RetireReason::Completed => "completed",
            RetireReason::Failed => "failed",
            RetireReason::Rejected => "rejected",
        }
    }
}

/// One lifecycle event. `Copy` by construction so recording never touches
/// the allocator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceEvent {
    /// Request entered the admission queue.
    Enqueued,
    /// Admission granted; worst-case page reservation at admit time.
    Admitted { pages: u32 },
    /// Engine began prefill (any path: cold, partial, exact hit).
    PrefillStart,
    /// Prefill finished and the request holds a lane (or completed).
    PrefillEnd,
    /// Warm start adopted shared prefix pages copy-free.
    PartialAdopt { shared_pages: u32 },
    /// One chunked-extend device call recomputed `n` suffix tokens.
    ExtendChunk { n: u32 },
    /// One decode step advanced this request by one token.
    DecodeStep,
    /// KV slots evicted from this request's slab.
    Evict { kind: EvictKind, slots: u32 },
    /// Copy-on-write fork materialised `pages` private pages.
    CowFork { pages: u32 },
    /// Request left the system.
    Retired { reason: RetireReason },
}

impl TraceEvent {
    pub fn name(self) -> &'static str {
        match self {
            TraceEvent::Enqueued => "enqueued",
            TraceEvent::Admitted { .. } => "admitted",
            TraceEvent::PrefillStart => "prefill_start",
            TraceEvent::PrefillEnd => "prefill_end",
            TraceEvent::PartialAdopt { .. } => "partial_adopt",
            TraceEvent::ExtendChunk { .. } => "extend_chunk",
            TraceEvent::DecodeStep => "decode_step",
            TraceEvent::Evict { .. } => "evict",
            TraceEvent::CowFork { .. } => "cow_fork",
            TraceEvent::Retired { .. } => "retired",
        }
    }
}

/// A journal entry: request id, microseconds since journal creation, event.
#[derive(Debug, Clone, Copy)]
pub struct TraceRecord {
    pub id: u64,
    pub at_us: u64,
    pub event: TraceEvent,
}

impl TraceRecord {
    /// Wire form: `{"id":N,"at_us":T,"event":"...", ...payload}`.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("id", num(self.id as f64)),
            ("at_us", num(self.at_us as f64)),
            ("event", s(self.event.name())),
        ];
        match self.event {
            TraceEvent::Admitted { pages } => pairs.push(("pages", num(pages as f64))),
            TraceEvent::PartialAdopt { shared_pages } => {
                pairs.push(("shared_pages", num(shared_pages as f64)))
            }
            TraceEvent::ExtendChunk { n } => pairs.push(("n", num(n as f64))),
            TraceEvent::Evict { kind, slots } => {
                pairs.push(("policy", s(kind.as_str())));
                pairs.push(("slots", num(slots as f64)));
            }
            TraceEvent::CowFork { pages } => pairs.push(("pages", num(pages as f64))),
            TraceEvent::Retired { reason } => pairs.push(("reason", s(reason.as_str()))),
            _ => {}
        }
        obj(pairs)
    }
}

/// Default journal capacity: ~1.5 MiB of 24-byte records, enough for the
/// full lifecycle of thousands of requests before wrapping.
pub const DEFAULT_TRACE_CAP: usize = 65_536;

/// Fixed-capacity ring of [`TraceRecord`]s in insertion (= chronological)
/// order.
#[derive(Debug)]
pub struct TraceJournal {
    buf: Vec<TraceRecord>,
    /// Ring bound. `Vec::with_capacity` may over-allocate, so the wrap
    /// arithmetic uses this stored bound rather than `buf.capacity()`.
    cap: usize,
    next: usize,
    total: u64,
    epoch: Instant,
}

impl TraceJournal {
    pub fn new() -> Self {
        TraceJournal::with_capacity(DEFAULT_TRACE_CAP)
    }

    pub fn with_capacity(cap: usize) -> Self {
        assert!(cap > 0);
        TraceJournal {
            buf: Vec::with_capacity(cap),
            cap,
            next: 0,
            total: 0,
            epoch: Instant::now(),
        }
    }

    /// Append one event. Alloc-free: pushes stay within the reserved
    /// capacity until the ring is full, then overwrite the oldest slot.
    pub fn record(&mut self, id: u64, event: TraceEvent) {
        let at_us = self.epoch.elapsed().as_micros() as u64;
        let rec = TraceRecord { id, at_us, event };
        if self.buf.len() < self.cap {
            self.buf.push(rec);
        } else {
            self.buf[self.next] = rec;
        }
        self.next = (self.next + 1) % self.cap;
        self.total += 1;
    }

    /// Records currently retained (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Total events ever recorded, including overwritten ones.
    pub fn total_recorded(&self) -> u64 {
        self.total
    }

    /// Iterate retained records oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = &TraceRecord> {
        let (tail, head) = if self.buf.len() < self.cap {
            (&self.buf[..], &self.buf[..0])
        } else {
            (&self.buf[self.next..], &self.buf[..self.next])
        };
        tail.iter().chain(head.iter())
    }

    /// All retained events for one request, chronological.
    pub fn for_request(&self, id: u64) -> Vec<TraceRecord> {
        self.iter().filter(|r| r.id == id).copied().collect()
    }

    /// The most recent `k` events, chronological.
    pub fn last(&self, k: usize) -> Vec<TraceRecord> {
        let n = self.buf.len();
        let skip = n.saturating_sub(k);
        self.iter().skip(skip).copied().collect()
    }
}

impl Default for TraceJournal {
    fn default() -> Self {
        TraceJournal::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_in_order_with_monotonic_timestamps() {
        let mut j = TraceJournal::with_capacity(64);
        j.record(1, TraceEvent::Enqueued);
        j.record(1, TraceEvent::Admitted { pages: 3 });
        j.record(2, TraceEvent::Enqueued);
        j.record(1, TraceEvent::PrefillStart);
        j.record(1, TraceEvent::PrefillEnd);
        j.record(1, TraceEvent::Retired { reason: RetireReason::Completed });

        let ev = j.for_request(1);
        assert_eq!(ev.len(), 5);
        assert_eq!(ev[0].event, TraceEvent::Enqueued);
        assert_eq!(ev[1].event, TraceEvent::Admitted { pages: 3 });
        assert_eq!(
            ev.last().unwrap().event,
            TraceEvent::Retired { reason: RetireReason::Completed }
        );
        assert!(ev.windows(2).all(|w| w[0].at_us <= w[1].at_us));
        assert_eq!(j.for_request(2).len(), 1);
        assert_eq!(j.for_request(99).len(), 0);
    }

    #[test]
    fn ring_wraps_keeping_newest() {
        let mut j = TraceJournal::with_capacity(8);
        for i in 0..20u64 {
            j.record(i, TraceEvent::DecodeStep);
        }
        assert_eq!(j.len(), 8);
        assert_eq!(j.total_recorded(), 20);
        let ids: Vec<u64> = j.iter().map(|r| r.id).collect();
        assert_eq!(ids, (12..20).collect::<Vec<_>>(), "oldest overwritten first");
        assert!(
            j.iter().collect::<Vec<_>>().windows(2).all(|w| w[0].at_us <= w[1].at_us),
            "chronological after wrap"
        );
        let last3: Vec<u64> = j.last(3).iter().map(|r| r.id).collect();
        assert_eq!(last3, vec![17, 18, 19]);
        // capacity never grew: ring stayed at its pre-sized bound
        assert_eq!(j.capacity(), 8);
    }

    #[test]
    fn last_handles_short_journals() {
        let mut j = TraceJournal::with_capacity(8);
        j.record(7, TraceEvent::Enqueued);
        assert_eq!(j.last(100).len(), 1);
        assert_eq!(j.last(0).len(), 0);
    }

    #[test]
    fn json_wire_form_carries_payload() {
        let mut j = TraceJournal::with_capacity(8);
        j.record(5, TraceEvent::Evict { kind: EvictKind::Emergency, slots: 16 });
        let rec = j.last(1)[0];
        let json = rec.to_json();
        assert_eq!(json.get("id").and_then(|v| v.as_i64()), Some(5));
        assert_eq!(json.get("event").and_then(|v| v.as_str()), Some("evict"));
        assert_eq!(json.get("policy").and_then(|v| v.as_str()), Some("emergency"));
        assert_eq!(json.get("slots").and_then(|v| v.as_i64()), Some(16));
    }
}
